"""L2 correctness: the MLP forward/train_step (which call the L1 Pallas
kernels) vs pure-jnp references; training reduces the loss."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import mlp_forward_ref


def make_params(key, in_dim=24, width=64, layers=2):
    shapes = model.init_shapes(in_dim, width, layers)
    params = []
    for i, s in enumerate(shapes):
        key, sub = jax.random.split(key)
        if len(s) == 1:
            params.append(jnp.zeros(s, jnp.float32))
        else:
            params.append(jax.random.normal(sub, s, jnp.float32) * np.sqrt(2.0 / s[0]))
    return params


def test_init_shapes_layout():
    shapes = model.init_shapes(24, 64, 2)
    assert shapes == [(24, 64), (64,), (64, 64), (64,), (64, 1), (1,)]


def test_forward_matches_ref():
    key = jax.random.PRNGKey(0)
    params = make_params(key)
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 24), jnp.float32)
    got = model.forward(x, *params)[0]
    want = mlp_forward_ref(x, params)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_train_step_reduces_loss():
    key = jax.random.PRNGKey(2)
    params = make_params(key)
    n = len(params)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    x = jax.random.normal(jax.random.PRNGKey(3), (256, 24), jnp.float32)
    true_w = jax.random.uniform(jax.random.PRNGKey(4), (24,), jnp.float32)
    y = 5.0 + jnp.abs(x @ true_w) + 1.0
    mask = jnp.ones((256,), jnp.float32)
    step = jax.jit(model.train_step)
    losses = []
    state = list(params) + m + v
    for t in range(1, 101):
        out = step(x, y, mask, jnp.float32(t), jnp.float32(5e-3), jnp.float32(1e-4), *state)
        losses.append(float(out[0]))
        state = list(out[1:])
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])
    assert len(out) == 1 + 3 * n


def test_mask_ignores_padded_rows():
    key = jax.random.PRNGKey(5)
    params = make_params(key)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    x = jax.random.normal(jax.random.PRNGKey(6), (256, 24), jnp.float32)
    y = jnp.abs(x[:, 0]) + 1.0
    full = jnp.ones((256,), jnp.float32)
    # Garbage in masked rows must not change the loss.
    y_bad = y.at[128:].set(1e9)
    half = full.at[128:].set(0.0)
    state = list(params) + m + v
    args = (jnp.float32(1), jnp.float32(5e-3), jnp.float32(1e-4))
    l_clean = model.train_step(x, y, half, *args, *state)[0]
    l_garbage = model.train_step(x, y_bad, half, *args, *state)[0]
    np.testing.assert_allclose(l_clean, l_garbage, rtol=1e-6)
