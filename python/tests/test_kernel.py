"""L1 correctness: Pallas fused_dense / matmul vs the pure-jnp oracle,
swept over shapes and dtypes with hypothesis (deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_dense, fused_dense_ref
from compile.kernels.fused_dense import matmul, mxu_utilization_estimate, vmem_bytes, _pick_block


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


@pytest.mark.parametrize("relu", [True, False])
@pytest.mark.parametrize("shape", [(256, 24, 64), (256, 64, 64), (128, 128, 1), (8, 3, 5)])
def test_fused_dense_matches_ref(shape, relu):
    B, K, N = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x, w, b = rand(k1, (B, K), jnp.float32), rand(k2, (K, N), jnp.float32), rand(k3, (N,), jnp.float32)
    got = fused_dense(x, w, b, relu)
    want = fused_dense_ref(x, w, b, relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 64),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_dense_hypothesis_shapes(b, k, n, relu, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(k1, (b, k), jnp.float32)
    w = rand(k2, (k, n), jnp.float32)
    bias = rand(k3, (n,), jnp.float32)
    got = fused_dense(x, w, bias, relu)
    want = fused_dense_ref(x, w, bias, relu)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([8, 32, 256]),
    k=st.sampled_from([24, 64, 128]),
    n=st.sampled_from([1, 64, 128]),
)
def test_fused_dense_bf16(b, k, n):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    x = rand(k1, (b, k), jnp.bfloat16)
    w = rand(k2, (k, n), jnp.bfloat16)
    bias = rand(k3, (n,), jnp.bfloat16)
    got = fused_dense(x, w, bias, True).astype(jnp.float32)
    want = fused_dense_ref(x, w, bias, True).astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(1, 48), n=st.integers(1, 64))
def test_matmul_matches_jnp(m, k, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    a = rand(k1, (m, k), jnp.float32)
    b = rand(k2, (k, n), jnp.float32)
    np.testing.assert_allclose(matmul(a, b), a @ b, rtol=1e-5, atol=1e-5)


def test_fused_dense_gradients_match_jnp():
    """custom_vjp backward (Pallas matmuls) vs jax autodiff on the oracle."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    x = rand(k1, (32, 24), jnp.float32)
    w = rand(k2, (24, 16), jnp.float32)
    b = rand(k3, (16,), jnp.float32)

    def loss_pallas(x, w, b):
        return jnp.sum(fused_dense(x, w, b, True) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(fused_dense_ref(x, w, b, True) ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gp, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_pick_block_divides():
    for dim in [1, 7, 24, 100, 128, 256, 300]:
        b = _pick_block(dim, 128)
        assert dim % b == 0
        assert 1 <= b <= 128


def test_vmem_budget():
    # The chosen tiling must fit a TPU core's ~16 MiB VMEM with margin.
    assert vmem_bytes(128, 128, 128) < 1 << 20  # < 1 MiB
    assert 0.0 < mxu_utilization_estimate(128, 24, 64) <= 1.0
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
