"""AOT path: lowering produces parseable HLO text with the input/output
arity the rust runtime (predict::mlp) expects."""

import json
import os

import jax
import jax.numpy as jnp

from compile import aot, model


def test_variants_declared():
    assert len(aot.VARIANTS) >= 2
    for v in aot.VARIANTS:
        assert v["in_dim"] == 24
        assert v["batch"] == 256


def test_lowered_hlo_text_structure():
    v = aot.VARIANTS[0]
    arts = aot.lower_variant(v)
    fwd = arts[f"mlp_forward_{v['name']}.hlo.txt"]
    trn = arts[f"mlp_train_{v['name']}.hlo.txt"]
    assert "HloModule" in fwd and "HloModule" in trn

    def entry_arity(hlo: str) -> int:
        # entry_computation_layout={(<inputs>)->...}
        sig = hlo.split("entry_computation_layout={(", 1)[1].split("->", 1)[0]
        return sig.count("f32[")

    # forward: x + 2*(layers+1) params
    n_params = 2 * (v["layers"] + 1)
    assert entry_arity(fwd) == 1 + n_params
    # train: x, y, mask, t, lr, wd + 3*n_params state tensors
    assert entry_arity(trn) == 6 + 3 * n_params


def test_artifacts_on_disk_match_meta():
    art_dir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    meta_path = os.path.join(art_dir, "mlp_meta.json")
    if not os.path.exists(meta_path):
        import pytest

        pytest.skip("artifacts not built (run `make artifacts`)")
    meta = json.load(open(meta_path))
    for v in meta["variants"]:
        for kind in ("forward", "train"):
            p = os.path.join(art_dir, f"mlp_{kind}_{v['name']}.hlo.txt")
            assert os.path.exists(p), p
            assert "HloModule" in open(p).read(200)


def test_train_step_numerics_through_hlo_roundtrip():
    """Compile the lowered stablehlo back through jax and compare one step."""
    v = aot.VARIANTS[0]
    b, d = v["batch"], v["in_dim"]
    shapes = model.init_shapes(d, v["width"], v["layers"])
    key = jax.random.PRNGKey(0)
    params = []
    for s in shapes:
        key, sub = jax.random.split(key)
        params.append(jax.random.normal(sub, s, jnp.float32) * 0.05)
    zeros = [jnp.zeros(s, jnp.float32) for s in shapes]
    x = jax.random.normal(jax.random.PRNGKey(1), (b, d), jnp.float32)
    y = jnp.abs(x[:, 0]) + 1.0
    mask = jnp.ones((b,), jnp.float32)
    out = model.train_step(
        x, y, mask, jnp.float32(1), jnp.float32(5e-3), jnp.float32(1e-4),
        *params, *zeros, *zeros,
    )
    assert float(out[0]) > 0.0
    assert len(out) == 1 + 3 * len(params)
