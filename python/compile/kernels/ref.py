"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in ``kernels/`` has a reference implementation here;
the pytest + hypothesis suite asserts allclose equivalence across shapes
and dtypes (build-time correctness gate, deliverable (c)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_dense_ref(x: jax.Array, w: jax.Array, b: jax.Array, relu: bool = True) -> jax.Array:
    """Reference for kernels.fused_dense: relu(x @ w + b)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def mlp_forward_ref(x: jax.Array, params: list[jax.Array]) -> jax.Array:
    """Reference MLP forward: hidden layers with ReLU, linear head."""
    h = x
    n_layers = len(params) // 2
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        h = fused_dense_ref(h, w, b, relu=(i < n_layers - 1))
    return h[:, 0]
