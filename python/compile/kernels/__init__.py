"""Pallas kernels (L1) and their pure-jnp oracles."""

from .fused_dense import fused_dense, mxu_utilization_estimate, vmem_bytes  # noqa: F401
from .ref import fused_dense_ref, mlp_forward_ref  # noqa: F401
