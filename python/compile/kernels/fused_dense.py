"""L1: the MLP predictor's fused dense layer as a Pallas kernel.

The hot-spot of the latency-predictor MLP (Section 4.2 of the paper) is the
batched dense layer. On the paper's mobile GPUs this is an OpenCL kernel; on
our TPU-style target we express it as a single fused Pallas kernel:
``y = relu(x @ W + b)`` with the matmul, bias add and activation fused so the
intermediate never round-trips through HBM.

Autodiff: Pallas interpret-mode kernels have no built-in reverse rule, so
``fused_dense`` carries a ``custom_vjp`` whose backward pass is built from
the same tiled Pallas matmul kernel (dx = g @ W^T, dW = x^T @ g) — both the
forward and backward of the L2 train step execute L1 kernels.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the (batch x out) block
is tiled into VMEM via BlockSpec; each grid step feeds a (bm, K) x (K, bn)
tile pair to the MXU via ``jnp.dot`` with fp32 accumulation. Block sizes are
multiples of the (8, 128) TPU lane layout where the problem permits.

Pallas runs with ``interpret=True`` everywhere in this repo: the CPU PJRT
plugin cannot execute Mosaic custom-calls, so real-TPU lowering is treated as
a compile-only target (see /opt/xla-example/README.md); numerics are
validated against ``ref.py`` by the pytest + hypothesis suite.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (keeps grids exact)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return max(b, 1)


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Tiled Pallas matmul (used by the fused_dense backward pass)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm = _pick_block(M, 128)
    bn = _pick_block(N, 128)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        interpret=True,
    )(a, b)


def _fused_dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    """One (bm, bn) output tile: full-K matmul + bias + optional ReLU."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _fused_dense_impl(x: jax.Array, w: jax.Array, b: jax.Array, relu: bool) -> jax.Array:
    B, K = x.shape
    K2, N = w.shape
    assert K == K2, f"inner dims mismatch: {K} vs {K2}"
    assert b.shape == (N,)
    bm = _pick_block(B, 128)
    bn = _pick_block(N, 128)
    return pl.pallas_call(
        functools.partial(_fused_dense_kernel, relu=relu),
        grid=(B // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_dense(x: jax.Array, w: jax.Array, b: jax.Array, relu: bool = True) -> jax.Array:
    """Fused ``relu(x @ w + b)`` as a tiled Pallas call.

    x: (B, K) activations; w: (K, N) weights; b: (N,) bias. K is kept whole
    per tile (the MLP's K <= 128 fits VMEM comfortably: three 128x128 fp32
    tiles = 192 KiB of the ~16 MiB budget).
    """
    return _fused_dense_impl(x, w, b, relu)


def _fused_dense_fwd(x, w, b, relu):
    y = _fused_dense_impl(x, w, b, relu)
    return y, (x, w, y)


def _fused_dense_bwd(relu, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0).astype(g.dtype)
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


fused_dense.defvjp(_fused_dense_fwd, _fused_dense_bwd)


def vmem_bytes(bm: int, bk: int, bn: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one grid step (x + w + out tiles + bias).

    Used by DESIGN.md §Perf to check the schedule against the ~16 MiB VMEM
    budget of a TPU core.
    """
    return dtype_bytes * (bm * bk + bk * bn + bm * bn + bn)


def mxu_utilization_estimate(bm: int, bk: int, bn: int) -> float:
    """Fraction of 128x128 MXU lanes a (bm,bk)x(bk,bn) tile pair keeps busy."""
    fill = (min(bm, 128) / 128.0) * (min(bn, 128) / 128.0) * (min(bk, 128) / 128.0)
    return min(fill, 1.0)
