"""L2: the MLP latency predictor's forward pass and Adam train step in JAX.

The forward pass calls the L1 Pallas ``fused_dense`` kernel for every layer,
so the whole predictor lowers into a single HLO module that the rust
coordinator executes via PJRT. The training objective is the paper's
mean-square *percentage* error (Section 4.2), masked for padded batch rows.

Positional signatures (the rust side, ``predict::mlp``, passes literals in
exactly this order):

  forward(x, *params)                          -> (pred,)
  train_step(x, y, mask, t, lr, wd, *params, *m, *v)
                                               -> (loss, *params, *m, *v)

``params`` is [W0, b0, W1, b1, ..., W_out, b_out].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import fused_dense

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def init_shapes(in_dim: int, width: int, layers: int) -> list[tuple[int, ...]]:
    """Weight/bias shapes in positional order (matches predict::mlp)."""
    shapes: list[tuple[int, ...]] = []
    fan_in = in_dim
    for _ in range(layers):
        shapes.append((fan_in, width))
        shapes.append((width,))
        fan_in = width
    shapes.append((fan_in, 1))
    shapes.append((1,))
    return shapes


def forward(x: jax.Array, *params: jax.Array) -> tuple[jax.Array]:
    """MLP forward: Pallas fused dense layers, ReLU on hidden, linear head."""
    h = x
    n_layers = len(params) // 2
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        h = fused_dense(h, w, b, relu=(i < n_layers - 1))
    return (h[:, 0],)


def _loss(params: tuple[jax.Array, ...], x, y, mask):
    pred = forward(x, *params)[0]
    rel = (pred - y) / jnp.maximum(y, 1e-9)
    return jnp.sum(mask * rel * rel) / jnp.maximum(jnp.sum(mask), 1.0)


def train_step(x, y, mask, t, lr, wd, *state: jax.Array):
    """One Adam step on the masked relative-error loss.

    ``state`` is params + m + v concatenated (each ``n_params`` tensors).
    Returns (loss, new_params..., new_m..., new_v...).
    """
    n = len(state) // 3
    params = tuple(state[:n])
    m = tuple(state[n : 2 * n])
    v = tuple(state[2 * n :])
    loss, grads = jax.value_and_grad(_loss)(params, x, y, mask)
    t = t.astype(jnp.float32)
    out_p, out_m, out_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        nm = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        nv = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        mhat = nm / (1.0 - ADAM_B1**t)
        vhat = nv / (1.0 - ADAM_B2**t)
        np_ = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + wd * p)
        out_p.append(np_)
        out_m.append(nm)
        out_v.append(nv)
    return (loss, *out_p, *out_m, *out_v)
