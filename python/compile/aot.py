"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for the rust
runtime (L3). Runs once at build time (`make artifacts`); Python is never on
the prediction path.

HLO *text* is the interchange format (NOT ``.serialize()``): jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# AOT-compiled MLP architecture variants (the rust side grid-searches over
# these, mirroring the paper's layer/width tuning within fixed shapes).
VARIANTS = [
    {"name": "h64l2", "layers": 2, "width": 64, "in_dim": 24, "batch": 256},
    {"name": "h128l2", "layers": 2, "width": 128, "in_dim": 24, "batch": 256},
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_variant(v: dict) -> dict[str, str]:
    b, d = v["batch"], v["in_dim"]
    shapes = model.init_shapes(d, v["width"], v["layers"])
    param_specs = [f32(*s) for s in shapes]

    fwd = jax.jit(model.forward).lower(f32(b, d), *param_specs)

    scalars = [f32(), f32(), f32()]  # t, lr, wd
    state = param_specs * 3  # params + m + v
    trn = jax.jit(model.train_step).lower(
        f32(b, d), f32(b), f32(b), *scalars, *state
    )
    return {
        f"mlp_forward_{v['name']}.hlo.txt": to_hlo_text(fwd),
        f"mlp_train_{v['name']}.hlo.txt": to_hlo_text(trn),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for v in VARIANTS:
        for name, text in lower_variant(v).items():
            path = os.path.join(args.out_dir, name)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")
    meta = {"format": "edgelat-artifacts-v1", "variants": VARIANTS}
    meta_path = os.path.join(args.out_dir, "mlp_meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
