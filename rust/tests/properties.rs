//! Property-based tests over randomized inputs (seeded, dependency-free —
//! the offline crate set has no proptest, so cases are generated with the
//! library's own PRNG; failures print the offending seed for replay).
//!
//! Invariants covered: graph validity and model-file round-trips over the
//! whole NAS space, Algorithm C.1 fusion conservation laws, kernel-selection
//! consistency, feature-vector alignment (what the per-bucket trainers
//! require), simulator sanity (positivity, determinism, monotonicity),
//! predictor numeric hygiene, `Graph::fingerprint` stability/sensitivity
//! (the plan-cache key), lowered-plan parity: `plan::lower` ==
//! `framework::deduce_units` across all 72 scenarios, plan-path
//! predictions bit-identical to the string-keyed path, and the workload
//! cost model across sampled SoCs (contention monotone, batch scaling
//! sub-linear with non-increasing per-item amortized cost).

use edgelat::device::{CoreCombo, DataRep, Target};
use edgelat::features::{features, kernel_features};
use edgelat::graph::modelfile::{from_model_file, to_model_file};
use edgelat::predict::{train, Method};
use edgelat::tflite::{compile, fusion, CompileOptions, GpuKind};
use edgelat::util::Rng;

const CASES: usize = 60;

#[test]
fn prop_sampled_graphs_always_validate() {
    for seed in 0..CASES as u64 {
        let arch = edgelat::nas::sample(seed, seed as usize * 7);
        arch.graph
            .validate()
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(arch.graph.flops() > 0, "seed {seed}");
    }
}

#[test]
fn prop_model_file_roundtrip_identity() {
    for seed in 0..CASES as u64 {
        let g = edgelat::nas::sample(seed ^ 0xfeed, 3).graph;
        let back = from_model_file(&to_model_file(&g)).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(g, back, "seed {seed}");
    }
}

#[test]
fn prop_fusion_conserves_ops_and_only_absorbs_linkables() {
    for seed in 0..CASES as u64 {
        let g = edgelat::nas::sample(seed ^ 0xabc, 11).graph;
        let kernels = fusion::fuse(&g);
        // Conservation: every op in exactly one kernel.
        let mut seen: Vec<usize> = kernels.iter().flat_map(|k| k.ops.iter().copied()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..g.nodes.len()).collect::<Vec<_>>(), "seed {seed}");
        // Absorbed ops are linkable; kernel count <= node count.
        assert!(kernels.len() <= g.nodes.len());
        for k in &kernels {
            for &op in k.fused_ops() {
                assert!(g.nodes[op].op.is_linkable(), "seed {seed}: op {op}");
            }
            // Root of a multi-op kernel feeds its first fused op as input 0.
            if let Some(&first_fused) = k.fused_ops().first() {
                let root_out = g.nodes[k.ops[0]].outputs[0];
                assert_eq!(
                    g.nodes[first_fused].inputs[0], root_out,
                    "seed {seed}: fusion chain broken"
                );
            }
        }
    }
}

#[test]
fn prop_fusion_deterministic() {
    for seed in 0..20u64 {
        let g = edgelat::nas::sample(seed, 5).graph;
        let a = fusion::fuse(&g);
        let b = fusion::fuse(&g);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.ops, y.ops, "seed {seed}");
        }
    }
}

#[test]
fn prop_kernel_selection_respects_gates() {
    for seed in 0..CASES as u64 {
        let g = edgelat::nas::sample(seed ^ 0x5e1, 2).graph;
        for gpu in [GpuKind::Adreno6xx, GpuKind::Mali, GpuKind::PowerVR] {
            let c = compile(&g, gpu, CompileOptions::default());
            for k in &c.kernels {
                match k.impl_ {
                    edgelat::tflite::KernelImpl::Winograd => {
                        let info = edgelat::tflite::select::conv_info(&g, k.root()).unwrap();
                        assert!(edgelat::tflite::select::check_winograd(gpu, &info));
                        assert_eq!(info.kernel_h, 3);
                        assert_eq!(info.stride, 1);
                        assert_eq!(info.groups, 1);
                    }
                    edgelat::tflite::KernelImpl::GroupedConv2D => {
                        let info = edgelat::tflite::select::conv_info(&g, k.root()).unwrap();
                        assert!(info.groups > 1);
                        assert!(edgelat::tflite::select::check_grouped_conv2d(&info));
                    }
                    _ => {}
                }
            }
        }
    }
}

#[test]
fn prop_feature_rows_align_within_buckets() {
    // All rows routed to the same predictor bucket must have the same
    // dimension — the exact precondition of ScenarioPredictor::train_from.
    use std::collections::HashMap;
    let mut cpu_dims: HashMap<String, usize> = HashMap::new();
    let mut gpu_dims: HashMap<String, usize> = HashMap::new();
    let mut graphs: Vec<_> =
        (0..30).map(|i| edgelat::nas::sample(99, i).graph).collect();
    graphs.extend(edgelat::zoo::all_graphs().into_iter().take(20));
    for g in &graphs {
        for n in &g.nodes {
            let b = edgelat::features::cpu_bucket(n);
            let d = features(g, n).len();
            let e = cpu_dims.entry(b.clone()).or_insert(d);
            assert_eq!(*e, d, "cpu bucket {b} in {}", g.name);
        }
        for gpu in [GpuKind::Adreno6xx, GpuKind::Mali] {
            for k in compile(g, gpu, CompileOptions::default()).kernels {
                let b = edgelat::features::bucket_of(g, &k);
                let d = kernel_features(g, &k).len();
                let e = gpu_dims.entry(b.clone()).or_insert(d);
                assert_eq!(*e, d, "gpu bucket {b} in {}", g.name);
            }
        }
    }
    assert!(cpu_dims.len() >= 6, "{cpu_dims:?}");
}

#[test]
fn prop_features_finite_nonnegative() {
    for seed in 0..CASES as u64 {
        let g = edgelat::nas::sample(seed ^ 0xf00, 1).graph;
        for n in &g.nodes {
            for (i, f) in features(&g, n).iter().enumerate() {
                assert!(f.is_finite() && *f >= 0.0, "seed {seed} op {} feat {i}", n.id);
            }
        }
    }
}

#[test]
fn prop_simulator_positive_and_deterministic() {
    let socs = edgelat::device::socs();
    for seed in 0..20u64 {
        let g = edgelat::nas::sample(seed, 4).graph;
        let soc = &socs[(seed % 4) as usize];
        let mut counts = vec![0; soc.clusters.len()];
        counts[0] = 1;
        let targets = [
            Target::Cpu { combo: CoreCombo::new(counts), rep: DataRep::Fp32 },
            Target::Gpu { options: CompileOptions::default() },
        ];
        for t in &targets {
            let a = edgelat::device::run(soc, &g, t, seed, 0);
            let b = edgelat::device::run(soc, &g, t, seed, 0);
            assert_eq!(a.end_to_end_ms, b.end_to_end_ms, "seed {seed}");
            assert!(a.end_to_end_ms > 0.0);
            assert!(a.per_op.iter().all(|o| o.latency_ms > 0.0), "seed {seed}");
        }
    }
}

#[test]
fn prop_noisefree_cost_monotone_in_homogeneous_cores() {
    // For substantial parallel ops on homogeneous cores, more cores never
    // hurt (Insight 1's degradation is hetero-only). Tiny ops are
    // sync-dominated — on real devices too — so the property applies above
    // a 0.2 ms floor.
    use edgelat::device::cost::cpu_op_ms;
    let soc = edgelat::device::soc_by_name("HelioP35").unwrap();
    let mut checked = 0usize;
    for seed in 0..30u64 {
        let g = edgelat::nas::sample(seed ^ 0x77, 6).graph;
        for n in g.nodes.iter().filter(|n| n.op.cpu_parallel()) {
            let one = cpu_op_ms(&soc, &g, n, &CoreCombo::new(vec![1, 0]), DataRep::Fp32, 0);
            if one < 0.2 {
                continue;
            }
            checked += 1;
            let mut prev = one;
            for k in 2..=4usize {
                let combo = CoreCombo::new(vec![k, 0]);
                let ms = cpu_op_ms(&soc, &g, n, &combo, DataRep::Fp32, 0);
                assert!(
                    ms <= prev * 1.05,
                    "seed {seed} op {}: {k} cores {ms} vs {prev}",
                    n.id
                );
                prev = ms;
            }
        }
    }
    assert!(checked > 100, "property exercised on only {checked} ops");
}

#[test]
fn prop_predictors_numerically_sane_on_random_data() {
    let mut rng = Rng::new(5);
    for case in 0..6u64 {
        let n = 40 + (case as usize) * 17;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            x.push(vec![
                rng.range_f64(0.0, 1e7),
                rng.range_f64(0.0, 1e3),
                rng.range_f64(1.0, 7.0),
            ]);
            y.push(rng.range_f64(1e-3, 1e3));
        }
        for m in Method::native() {
            let model = train(*m, &x, &y, case, None);
            for v in x.iter().take(10) {
                let p = model.predict_raw(v);
                assert!(p.is_finite() && p > 0.0, "{} case {case}: {p}", m.name());
            }
        }
    }
}

#[test]
fn prop_lasso_weights_nonnegative_always() {
    let mut rng = Rng::new(9);
    for case in 0..10u64 {
        let n = 60;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let row: Vec<f64> = (0..5).map(|_| rng.range_f64(-10.0, 10.0)).collect();
            y.push(rng.range_f64(0.1, 100.0));
            x.push(row);
        }
        let s = edgelat::features::Standardizer::fit(&x);
        let l = edgelat::predict::lasso::Lasso::fit(&s.transform_all(&x), &y, 1e-3);
        assert!(l.weights.iter().all(|&w| w >= 0.0), "case {case}: {:?}", l.weights);
    }
}

#[test]
fn prop_gpu_dispatch_count_at_least_kernels() {
    for seed in 0..CASES as u64 {
        let g = edgelat::nas::sample(seed ^ 0x9d, 8).graph;
        let c = compile(&g, GpuKind::PowerVR, CompileOptions::default());
        assert!(c.dispatch_count() >= c.kernels.len(), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// Graph::fingerprint properties — the engine's plan-cache key must be stable
// under renaming and sensitive to any structural edit.

#[test]
fn prop_fingerprint_stable_under_node_renaming_across_zoo() {
    for g in edgelat::zoo::all_graphs() {
        let mut renamed = g.clone();
        renamed.name = format!("renamed__{}", g.name);
        assert_eq!(
            g.fingerprint(),
            renamed.fingerprint(),
            "{}: renamed copy must hash alike",
            g.name
        );
    }
}

#[test]
fn prop_fingerprint_sensitive_to_shape_edits() {
    for g in edgelat::zoo::all_graphs().into_iter().take(20) {
        let mut edited = g.clone();
        // Widen one tensor by a channel: a different architecture.
        edited.tensors[0].shape.c += 1;
        assert_ne!(g.fingerprint(), edited.fingerprint(), "{}: shape edit", g.name);
    }
}

#[test]
fn prop_fingerprint_sensitive_to_op_edits() {
    let mut edited_any = 0;
    for g in edgelat::zoo::all_graphs() {
        let mut edited = g.clone();
        let Some(n) =
            edited.nodes.iter_mut().find(|n| matches!(n.op, edgelat::graph::Op::Conv2D { .. }))
        else {
            continue;
        };
        if let edgelat::graph::Op::Conv2D { stride, .. } = &mut n.op {
            *stride += 1;
        }
        assert_ne!(g.fingerprint(), edited.fingerprint(), "{}: op edit", g.name);
        edited_any += 1;
    }
    assert!(edited_any > 0, "zoo should contain standard convolutions");
}

#[test]
fn prop_fingerprint_sensitive_to_connectivity_edits() {
    for seed in 0..CASES as u64 {
        let g = edgelat::nas::sample(seed ^ 0x51ab, 9).graph;
        let mut edited = g.clone();
        // Rewire one consumer to a different (existing) tensor.
        let Some(n) = edited.nodes.iter_mut().find(|n| !n.inputs.is_empty()) else {
            continue;
        };
        let t = n.inputs[0];
        n.inputs[0] = if t == 0 { 1 } else { t - 1 };
        assert_ne!(g.fingerprint(), edited.fingerprint(), "seed {seed}: rewire");
    }
}

// ---------------------------------------------------------------------------
// Plan parity — `plan::lower` must agree with the string-keyed reference
// deduction (`framework::deduce_units`) everywhere: all 72 scenarios, every
// deduction mode, representative zoo models. Feature rows must be
// bit-identical (the plan IR is a re-packing, not a re-derivation).

#[test]
fn prop_plan_lower_matches_deduce_units_all_72_scenarios() {
    let graphs = [
        edgelat::zoo::mobilenets::mobilenet_v2(0.5),
        edgelat::zoo::resnets::resnet(10, 1.0),
        edgelat::nas::sample(0x91a4, 7).graph,
    ];
    let it = edgelat::plan::interner();
    let scenarios = edgelat::scenario::all_scenarios();
    assert_eq!(scenarios.len(), 72, "the paper's 72 measurement scenarios");
    for sc in &scenarios {
        for g in &graphs {
            for mode in [
                edgelat::framework::DeductionMode::Full,
                edgelat::framework::DeductionMode::NoFusion,
                edgelat::framework::DeductionMode::NoSelection,
            ] {
                let plan = edgelat::plan::lower(sc, mode, g);
                let reference = edgelat::framework::deduce_units(sc, mode, g);
                assert_eq!(plan.len(), reference.len(), "{} {} {mode:?}", sc.id, g.name);
                for (i, (rb, rf)) in reference.iter().enumerate() {
                    assert_eq!(it.name(plan.bucket(i)), rb, "{} {} unit {i}", sc.id, g.name);
                    let row = plan.row(i);
                    assert_eq!(row.len(), rf.len(), "{} {} unit {i}", sc.id, g.name);
                    for (a, b) in row.iter().zip(rf) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{} {} unit {i}: {a} vs {b}",
                            sc.id,
                            g.name
                        );
                    }
                }
            }
        }
    }
}

// Lowered-path predictions must be bit-identical to the pre-refactor
// string-keyed path: reconstruct the old predict loop (deduce_units +
// by-name model lookup + per-unit predict_raw, summed in unit order) and
// compare against `predict`/`predict_plan`.

#[test]
fn prop_plan_predictions_bit_identical_to_string_keyed_path() {
    use edgelat::framework::{deduce_units, DeductionMode, ScenarioPredictor};
    let socs = edgelat::device::socs();
    let scenarios = [
        edgelat::scenario::one_large_core("Snapdragon855").unwrap(),
        edgelat::scenario::Scenario::gpu(&socs[0]),
    ];
    let train: Vec<_> = edgelat::nas::sample_dataset(77, 14)
        .into_iter()
        .map(|a| a.graph)
        .collect();
    let probes: Vec<_> = edgelat::nas::sample_dataset(1077, 6)
        .into_iter()
        .map(|a| a.graph)
        .collect();
    for sc in &scenarios {
        let profiles = edgelat::profiler::profile_set(sc, &train, 7, 3);
        for &method in edgelat::predict::Method::native() {
            let pred = ScenarioPredictor::train_from(
                sc,
                &profiles,
                method,
                DeductionMode::Full,
                1,
                None,
            );
            for g in &probes {
                // The pre-refactor string-keyed serve loop, verbatim
                // (per-unit sum first, T_overhead added last — the same
                // float-addition order as the original `predict`).
                let mut sum = 0.0;
                for (bucket, f) in deduce_units(sc, DeductionMode::Full, g) {
                    sum += match pred.model_named(&bucket) {
                        Some(m) => m.predict_raw(&f),
                        None => pred.fallback_ms,
                    };
                }
                let reference = pred.t_overhead_ms + sum;
                let plan_path = pred.predict(g);
                assert_eq!(
                    plan_path.to_bits(),
                    reference.to_bits(),
                    "{} {} on {}: {plan_path} vs {reference}",
                    sc.id,
                    method.name(),
                    g.name
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Workload cost-model properties across *sampled* SocSpecs — the contention
// and batch axes must behave physically on every device the fleet sampler
// can produce, not just the four builtin SoCs.

fn wl_spec(load: f64, share: f64, batch: usize) -> edgelat::workload::WorkloadSpec {
    edgelat::workload::WorkloadSpec {
        name: "prop".into(),
        batch,
        cpu_load: vec![load],
        gpu_share: share,
    }
}

#[test]
fn prop_contention_monotone_across_sampled_socs() {
    // More co-runner load never makes a CPU op faster; a larger GPU quota
    // share never makes a kernel slower — and the unloaded / full-quota
    // endpoints are bit-identical to the isolated model.
    use edgelat::device::cost::{cpu_op_ms_under, gpu_kernel_ms_under};
    let mut checked = 0usize;
    for (si, spec) in edgelat::device::sample_specs(0x10ad, 6).iter().enumerate() {
        let soc = &spec.soc;
        let g = edgelat::nas::sample(si as u64 ^ 0xc0, 6).graph;
        let combo = CoreCombo::new(spec.combos[0].clone());
        for n in &g.nodes {
            let mut prev = cpu_op_ms_under(soc, &g, n, &combo, DataRep::Fp32, 0, None);
            for load in [0.0, 0.25, 0.5, 0.75, 1.0] {
                let w = wl_spec(load, 1.0, 1);
                let ms = cpu_op_ms_under(soc, &g, n, &combo, DataRep::Fp32, 0, Some(&w));
                if load == 0.0 {
                    assert_eq!(ms.to_bits(), prev.to_bits(), "{}: unloaded != isolated", soc.name);
                }
                assert!(ms >= prev, "{} op {} load {load}: {ms} < {prev}", soc.name, n.id);
                prev = ms;
                checked += 1;
            }
        }
        let compiled = compile(&g, soc.gpu.kind, CompileOptions::default());
        for k in &compiled.kernels {
            let mut prev = f64::INFINITY;
            for share in [0.25, 0.5, 0.75, 1.0] {
                let w = wl_spec(0.0, share, 1);
                let ms = gpu_kernel_ms_under(soc, &g, k, Some(&w));
                assert!(ms <= prev, "{} share {share}: {ms} > {prev}", soc.name);
                prev = ms;
                checked += 1;
            }
            let iso = gpu_kernel_ms_under(soc, &g, k, None);
            assert_eq!(prev.to_bits(), iso.to_bits(), "{}: full quota != isolated", soc.name);
        }
    }
    assert!(checked > 100, "property exercised on only {checked} points");
}

#[test]
fn prop_batch_scaling_sublinear_with_amortized_per_item_cost() {
    // Whole-batch latency for b items sits in [1x, b x) the single-item
    // cost (fixed per-op/per-dispatch overheads are paid once per batch,
    // variable work scales sub-linearly), so the per-item amortized cost
    // never increases with batch size — on every sampled SoC.
    use edgelat::device::cost::{cpu_op_ms_under, gpu_kernel_ms_under};
    let mut checked = 0usize;
    for (si, spec) in edgelat::device::sample_specs(0xba7c, 6).iter().enumerate() {
        let soc = &spec.soc;
        let g = edgelat::nas::sample(si as u64 ^ 0xb5, 4).graph;
        let combo = CoreCombo::new(spec.combos[0].clone());
        for n in &g.nodes {
            let one = cpu_op_ms_under(soc, &g, n, &combo, DataRep::Fp32, 0, None);
            let mut prev_per_item = one;
            for b in [2usize, 4, 8, 16] {
                let w = wl_spec(0.0, 1.0, b);
                let ms = cpu_op_ms_under(soc, &g, n, &combo, DataRep::Fp32, 0, Some(&w));
                assert!(ms >= one, "{} op {} batch {b}: {ms} < one item {one}", soc.name, n.id);
                assert!(
                    ms < b as f64 * one,
                    "{} op {} batch {b}: {ms} not sub-linear vs {one}",
                    soc.name,
                    n.id
                );
                let per_item = ms / b as f64;
                assert!(
                    per_item <= prev_per_item,
                    "{} op {} batch {b}: per-item {per_item} > {prev_per_item}",
                    soc.name,
                    n.id
                );
                prev_per_item = per_item;
                checked += 1;
            }
        }
        let compiled = compile(&g, soc.gpu.kind, CompileOptions::default());
        for k in &compiled.kernels {
            let one = gpu_kernel_ms_under(soc, &g, k, None);
            let mut prev_per_item = one;
            for b in [2usize, 4, 8, 16] {
                let w = wl_spec(0.0, 1.0, b);
                let ms = gpu_kernel_ms_under(soc, &g, k, Some(&w));
                assert!(ms >= one, "{} batch {b}: {ms} < one item {one}", soc.name);
                assert!(ms < b as f64 * one, "{} batch {b}: {ms} not sub-linear", soc.name);
                let per_item = ms / b as f64;
                assert!(per_item <= prev_per_item, "{} batch {b}: per-item grew", soc.name);
                prev_per_item = per_item;
                checked += 1;
            }
        }
    }
    assert!(checked > 100, "property exercised on only {checked} points");
}
