//! Integration tests for the latency-constrained NAS search subsystem:
//! seed/thread-count reproducibility (byte-level, on the JSON artifact),
//! Pareto-front non-dominance, budget enforcement against the engine's
//! own predictions, elitism monotonicity, and plan-cache traffic.
//!
//! The engines here serve hand-built constant/linear Lasso bundles
//! (identity standardizer, unit or zero weights), so tests run at search
//! speed without any profiling or training — exactly the serving-side
//! contract `search::run` depends on.

use edgelat::engine::{EngineBuilder, LatencyEngine, PredictorBundle};
use edgelat::features::Standardizer;
use edgelat::framework::DeductionMode;
use edgelat::nas::SynthArch;
use edgelat::predict::{lasso::Lasso, BucketModel, Method, NativeModel};
use edgelat::search::{self, dominates, SearchConfig};
use std::collections::BTreeMap;

/// A bundle whose every bucket predicts `intercept + w * x0` — constant
/// per-unit latency when `w == 0`, first-feature-proportional when not.
/// Identity standardizer over one feature, so predictions are exact.
fn linear_bundle(sc_id: &str, intercept: f64, w: f64) -> PredictorBundle {
    let mut models = BTreeMap::new();
    for name in edgelat::plan::interner().names() {
        models.insert(
            name.to_string(),
            BucketModel {
                standardizer: Standardizer { mean: vec![0.0], std: vec![1.0] },
                model: NativeModel::Lasso(Lasso {
                    weights: vec![w],
                    intercept,
                    alpha: 0.0,
                }),
                floor: 0.0,
            },
        );
    }
    let scenario = edgelat::scenario::by_id(sc_id)
        .unwrap_or_else(|| panic!("builtin scenario {sc_id}"));
    PredictorBundle {
        scenario: (*scenario).clone(),
        method: Method::Lasso,
        mode: DeductionMode::Full,
        t_overhead_ms: 1.0,
        fallback_ms: intercept.max(0.5),
        models,
    }
}

const SC_A: &str = "Snapdragon855/cpu/1L/fp32";
const SC_B: &str = "HelioP35/cpu/1L/fp32";
const SC_C: &str = "Exynos9820/cpu/1L/fp32";

fn engine(threads: usize) -> LatencyEngine {
    EngineBuilder::new()
        .bundle(linear_bundle(SC_A, 0.5, 0.0))
        .bundle(linear_bundle(SC_B, 0.0, 0.01))
        .bundle(linear_bundle(SC_C, 0.5, 0.0))
        .threads(threads)
        .build()
        .expect("engine")
}

fn cfg(budget: Option<f64>) -> SearchConfig {
    SearchConfig {
        seed: 77,
        population: 10,
        generations: 4,
        budget_ms: budget,
        elite: 2,
        tournament: 3,
        mutation_rate: 0.35,
        crossover_rate: 0.5,
    }
}

#[test]
fn fixed_seed_output_is_byte_reproducible_across_runs_and_thread_counts() {
    let ids = vec![SC_A.to_string(), SC_B.to_string()];
    let c = cfg(Some(40.0));
    let mut artifacts = Vec::new();
    for threads in [1usize, 2, 8] {
        let eng = engine(threads);
        let out = search::run(&eng, &ids, &c).expect("search runs");
        artifacts.push(search::report_json(&c, &out).to_string());
    }
    // Same engine, second run: also identical.
    let eng = engine(3);
    let a = search::report_json(&c, &search::run(&eng, &ids, &c).unwrap()).to_string();
    let b = search::report_json(&c, &search::run(&eng, &ids, &c).unwrap()).to_string();
    artifacts.push(a);
    artifacts.push(b);
    for w in artifacts.windows(2) {
        assert_eq!(w[0], w[1], "search artifact not byte-reproducible");
    }
    // And it is valid JSON with the declared format tag.
    let doc = edgelat::util::Json::parse(&artifacts[0]).expect("valid JSON");
    assert_eq!(doc.req_str("format").unwrap(), "edgelat.search");
    assert_eq!(doc.req_usize("version").unwrap(), 1);
}

#[test]
fn every_reported_front_is_non_dominated() {
    let ids = vec![SC_A.to_string(), SC_B.to_string()];
    let eng = engine(4);
    let out = search::run(&eng, &ids, &cfg(None)).unwrap();
    assert_eq!(out.scenarios.len(), 2);
    for s in &out.scenarios {
        assert!(!s.front.is_empty(), "{}: empty front", s.scenario_id);
        for p in &s.front {
            assert!(
                !s.front.iter().any(|q| dominates(q, p)),
                "{}: {} is dominated",
                s.scenario_id,
                p.name
            );
            assert!(p.latency_ms.is_finite() && p.proxy.is_finite());
        }
        // Sorted by latency ascending (deterministic render order).
        assert!(s
            .front
            .windows(2)
            .all(|w| w[0].latency_ms <= w[1].latency_ms));
    }
}

#[test]
fn survivors_respect_the_budget_per_the_engines_own_predictions() {
    let ids = vec![SC_A.to_string()];
    let eng = engine(4);
    let budget = 40.0;
    let out = search::run(&eng, &ids, &cfg(Some(budget))).unwrap();
    let s = &out.scenarios[0];
    let mut checked = 0usize;
    for surv in &s.survivors {
        // Rebuild the survivor from its genome and re-serve it: the
        // recorded latency must be the engine's own prediction, bit for
        // bit, and feasible survivors must sit within the budget.
        let arch = SynthArch::rebuild(0, &surv.blocks, surv.head_c);
        assert_eq!(arch.graph.fingerprint(), surv.fingerprint, "{}", surv.name);
        let req = edgelat::engine::PredictRequest::new(&arch.graph, SC_A);
        let resp = eng.predict(&req).expect("served");
        assert_eq!(
            resp.e2e_ms.to_bits(),
            surv.latency_ms.to_bits(),
            "{}: recorded latency is not the engine's prediction",
            surv.name
        );
        assert_eq!(surv.feasible, surv.latency_ms <= budget, "{}", surv.name);
        if surv.feasible {
            assert!(surv.latency_ms <= budget);
            checked += 1;
        }
    }
    // The constant-per-unit engine prices these graphs well inside 40ms,
    // so the budget is satisfiable and feasible survivors must exist.
    assert!(checked > 0, "no feasible survivor to check");
    assert_eq!(s.evaluated, 10 * 4);
    assert!(s.feasible <= s.evaluated);
}

#[test]
fn elitism_never_loses_the_best_feasible_candidate() {
    // With unconstrained search, the final best survivor's proxy must be
    // at least generation 0's best: elites are copied forward and
    // re-scored to identical predictions.
    let ids = vec![SC_A.to_string()];
    let eng = engine(2);
    let c = cfg(None);
    let out = search::run(&eng, &ids, &c).unwrap();
    let gen0_best = (0..c.population)
        .map(|i| search::accuracy_proxy(&edgelat::nas::sample(c.seed, i).graph))
        .fold(f64::NEG_INFINITY, f64::max);
    let final_best = out.scenarios[0].survivors[0].proxy;
    assert!(
        final_best >= gen0_best,
        "final best {final_best} < generation-0 best {gen0_best}"
    );
}

#[test]
fn repeat_survivors_hit_the_plan_cache_across_generations() {
    let ids = vec![SC_A.to_string()];
    let eng = engine(4);
    let before = eng.cache_stats();
    search::run(&eng, &ids, &cfg(None)).unwrap();
    let after = eng.cache_stats();
    assert!(
        after.hits > before.hits,
        "elite re-scoring produced no plan-cache hits (hits {} -> {})",
        before.hits,
        after.hits
    );
    assert!(after.misses > before.misses, "fresh candidates must miss once");
}

#[test]
fn cross_device_rank_correlation_covers_every_pair() {
    let ids = vec![SC_A.to_string(), SC_B.to_string(), SC_C.to_string()];
    let eng = engine(4);
    let out = search::run(&eng, &ids, &cfg(None)).unwrap();
    assert_eq!(out.rank_correlation.len(), 3, "3 scenarios -> 3 pairs");
    for (a, b, rho) in &out.rank_correlation {
        assert_ne!(a, b);
        assert!(
            rho.is_nan() || (-1.0..=1.0).contains(rho),
            "{a} vs {b}: rho={rho}"
        );
    }
    // SC_A and SC_C serve identical constant bundles: identical latencies,
    // perfect rank agreement.
    let ac = out
        .rank_correlation
        .iter()
        .find(|(a, b, _)| a == SC_A && b == SC_C)
        .expect("A-C pair present");
    assert!((ac.2 - 1.0).abs() < 1e-12, "identical devices must correlate at 1.0, got {}", ac.2);
}

#[test]
fn a_scenarios_result_is_independent_of_its_position_in_the_list() {
    // The per-scenario RNG stream derives from the scenario id, not its
    // index: searching B alone and searching A,B together must produce
    // the same result for B (adding a comparison device cannot change an
    // existing device's search trajectory).
    let c = cfg(Some(40.0));
    let solo = search::run(&engine(2), &[SC_B.to_string()], &c).unwrap();
    let multi =
        search::run(&engine(4), &[SC_A.to_string(), SC_B.to_string()], &c).unwrap();
    let solo_b = &solo.scenarios[0];
    let multi_b = &multi.scenarios[1];
    assert_eq!(multi_b.scenario_id, SC_B);
    assert_eq!(solo_b.front, multi_b.front, "B's Pareto front moved with its position");
    assert_eq!(solo_b.evaluated, multi_b.evaluated);
    assert_eq!(solo_b.feasible, multi_b.feasible);
    let lat = |s: &edgelat::search::ScenarioSearch| -> Vec<u64> {
        s.survivors.iter().map(|x| x.latency_ms.to_bits()).collect()
    };
    assert_eq!(lat(solo_b), lat(multi_b));
}

#[test]
fn unknown_scenario_fails_the_whole_search() {
    let eng = engine(2);
    let err = search::run(&eng, &["NoSuch/gpu".to_string()], &cfg(None));
    assert!(err.is_err(), "unknown scenario must not silently return an empty front");
}
