//! Property tests for `tflite::fusion` (Algorithm C.1) from the
//! integration tree, driven by the synthetic NAS space — the graphs the
//! GPU deduction path actually sees at search scale:
//!
//! 1. the fused kernel list preserves topological validity (every op in
//!    exactly one kernel, ops in ascending order inside a kernel, and the
//!    list executable front-to-back);
//! 2. fusion never increases the kernel count;
//! 3. the merge pass is idempotent — fusing twice equals fusing once.

use edgelat::graph::Graph;
use edgelat::tflite::fusion::{merge_pass, no_fuse};
use edgelat::tflite::{fuse, FusedKernel};
use std::collections::HashSet;

fn nas_graphs(seed: u64, n: usize) -> Vec<Graph> {
    edgelat::nas::sample_dataset(seed, n).into_iter().map(|a| a.graph).collect()
}

fn subject_graphs() -> Vec<Graph> {
    let mut graphs = nas_graphs(2022, 40);
    graphs.push(edgelat::zoo::mobilenets::mobilenet_v2(1.0));
    graphs.push(edgelat::zoo::resnets::resnet(18, 1.0));
    graphs
}

/// Every original op appears in exactly one kernel, ops inside a kernel
/// are in ascending topological (node-id) order, and walking the kernel
/// list front-to-back never reads a tensor that has not been produced.
fn assert_topologically_valid(g: &Graph, kernels: &[FusedKernel]) {
    let mut seen_ops: Vec<usize> = Vec::new();
    let mut ready: HashSet<usize> = g.inputs.iter().copied().collect();
    for k in kernels {
        assert!(!k.ops.is_empty(), "{}: empty kernel", g.name);
        assert!(
            k.ops.windows(2).all(|w| w[0] < w[1]),
            "{}: kernel ops out of order: {:?}",
            g.name,
            k.ops
        );
        seen_ops.extend(&k.ops);
        for &s in &k.src {
            assert!(
                ready.contains(&s),
                "{}: kernel rooted at op {} reads tensor {s} before it is produced",
                g.name,
                k.root()
            );
        }
        ready.extend(k.dst.iter().copied());
    }
    seen_ops.sort_unstable();
    let expect: Vec<usize> = (0..g.nodes.len()).collect();
    assert_eq!(seen_ops, expect, "{}: op multiset not preserved", g.name);
}

#[test]
fn fused_graphs_preserve_topological_validity() {
    for g in subject_graphs() {
        assert_topologically_valid(&g, &fuse(&g));
    }
}

#[test]
fn fusion_never_increases_unit_count() {
    for g in subject_graphs() {
        let unfused = no_fuse(&g);
        let fused = fuse(&g);
        assert!(
            fused.len() <= unfused.len(),
            "{}: {} fused kernels > {} unfused",
            g.name,
            fused.len(),
            unfused.len()
        );
        assert_eq!(unfused.len(), g.nodes.len());
    }
}

#[test]
fn merge_pass_is_idempotent_across_the_nas_space() {
    for g in subject_graphs() {
        let once = fuse(&g);
        let twice = merge_pass(&g, once.clone());
        assert_eq!(
            twice, once,
            "{}: a second merge pass changed the kernel list",
            g.name
        );
    }
}

#[test]
fn no_fuse_is_one_kernel_per_node_and_fuse_actually_merges() {
    // Sanity anchors for the properties above: the trivial compilation is
    // the identity partition, and the NAS space contains real fusion
    // opportunities (conv/dwconv + activation chains everywhere).
    let graphs = nas_graphs(7, 20);
    let mut merged_any = 0usize;
    for g in &graphs {
        let unfused = no_fuse(g);
        for (i, k) in unfused.iter().enumerate() {
            assert_eq!(k.ops, vec![i]);
        }
        if fuse(g).len() < unfused.len() {
            merged_any += 1;
        }
    }
    assert!(
        merged_any >= graphs.len() / 2,
        "fusion merged something in only {merged_any}/{} graphs",
        graphs.len()
    );
}
