//! Integration: the three-layer stack. The AOT artifacts (JAX L2 lowering
//! of the Pallas L1 fused_dense kernels) are loaded and executed from rust
//! via PJRT, and the MLP latency predictor trains and predicts end-to-end.
//!
//! Requires `make artifacts`; tests are skipped (not failed) when the
//! artifact directory is absent so `cargo test` works pre-build.

use edgelat::predict::mlp::MlpContext;
use edgelat::predict::{train, Method};
use edgelat::runtime::{literal_f32, to_vec_f32, Runtime};
use edgelat::util::{mape, Rng};

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if Runtime::artifacts_available(&dir) {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn forward_executable_runs_and_matches_shapes() {
    let Some(dir) = artifact_dir() else { return };
    let ctx = MlpContext::load(&dir).expect("loading MLP artifacts");
    assert!(ctx.variants.len() >= 2);
    let v = &ctx.variants[0];
    assert_eq!(v.in_dim, 24);
    assert_eq!(v.batch, 256);
    // Zero weights -> zero predictions.
    let x = vec![0.5f32; v.batch * v.in_dim];
    let mut inputs = vec![literal_f32(&x, &[v.batch as i64, v.in_dim as i64]).unwrap()];
    for s in &v.param_shapes {
        let n: i64 = s.iter().product();
        inputs.push(literal_f32(&vec![0f32; n as usize], s).unwrap());
    }
    let out = v.forward.run(&inputs).expect("forward");
    assert_eq!(out.len(), 1);
    let pred = to_vec_f32(&out[0]).unwrap();
    assert_eq!(pred.len(), v.batch);
    assert!(pred.iter().all(|&p| p == 0.0));
}

#[test]
fn train_step_reduces_loss_from_rust() {
    let Some(dir) = artifact_dir() else { return };
    let ctx = MlpContext::load(&dir).expect("loading MLP artifacts");
    let v = &ctx.variants[0];
    let np = v.param_shapes.len();
    let mut rng = Rng::new(7);
    // He-init params, zero moments.
    let mut params: Vec<Vec<f32>> = v
        .param_shapes
        .iter()
        .map(|s| {
            let n: i64 = s.iter().product();
            if s.len() == 1 {
                vec![0.0; n as usize]
            } else {
                let std = (2.0 / s[0] as f64).sqrt();
                (0..n).map(|_| (rng.normal() * std) as f32).collect()
            }
        })
        .collect();
    let mut m: Vec<Vec<f32>> =
        v.param_shapes.iter().map(|s| vec![0.0; s.iter().product::<i64>() as usize]).collect();
    let mut vv = m.clone();
    // Synthetic target: y = 2 + |3*x0 + x1|.
    let mut xb = vec![0f32; v.batch * v.in_dim];
    let mut yb = vec![0f32; v.batch];
    for r in 0..v.batch {
        let a = rng.range_f64(-1.0, 1.0) as f32;
        let b = rng.range_f64(-1.0, 1.0) as f32;
        xb[r * v.in_dim] = a;
        xb[r * v.in_dim + 1] = b;
        yb[r] = 2.0 + (3.0 * a + b).abs();
    }
    let mask = vec![1f32; v.batch];
    let mut first_loss = None;
    let mut last_loss = 0f32;
    for t in 1..=60 {
        let mut inputs = vec![
            literal_f32(&xb, &[v.batch as i64, v.in_dim as i64]).unwrap(),
            literal_f32(&yb, &[v.batch as i64]).unwrap(),
            literal_f32(&mask, &[v.batch as i64]).unwrap(),
            xla::Literal::scalar(t as f32),
            xla::Literal::scalar(5e-3f32),
            xla::Literal::scalar(1e-4f32),
        ];
        for (p, s) in params.iter().chain(&m).chain(&vv).zip(
            v.param_shapes.iter().cycle(),
        ) {
            inputs.push(literal_f32(p, s).unwrap());
        }
        let outs = v.train.run(&inputs).expect("train step");
        assert_eq!(outs.len(), 1 + 3 * np);
        let loss = to_vec_f32(&outs[0]).unwrap()[0];
        if first_loss.is_none() {
            first_loss = Some(loss);
        }
        last_loss = loss;
        for (k, p) in params.iter_mut().enumerate() {
            *p = to_vec_f32(&outs[1 + k]).unwrap();
        }
        for (k, p) in m.iter_mut().enumerate() {
            *p = to_vec_f32(&outs[1 + np + k]).unwrap();
        }
        for (k, p) in vv.iter_mut().enumerate() {
            *p = to_vec_f32(&outs[1 + 2 * np + k]).unwrap();
        }
    }
    let first = first_loss.unwrap();
    assert!(
        last_loss < first * 0.5,
        "loss did not fall: first={first} last={last_loss}"
    );
}

#[test]
fn mlp_predictor_fits_toy_latency_problem() {
    let Some(dir) = artifact_dir() else { return };
    let ctx = MlpContext::load(&dir).expect("loading MLP artifacts");
    // Same toy roofline problem the native predictors are tested on.
    let mut rng = Rng::new(3);
    let gen = |rng: &mut Rng, n: usize| {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let flops = rng.range_f64(1.0, 100.0);
            let mem = rng.range_f64(1.0, 100.0);
            x.push(vec![flops, mem]);
            y.push((0.8 * flops).max(0.5 * mem) + 1.0);
        }
        (x, y)
    };
    let (x, y) = gen(&mut rng, 400);
    let (xt, yt) = gen(&mut rng, 100);
    let model = train(Method::Mlp, &x, &y, 11, Some(&ctx));
    let pred: Vec<f64> = xt.iter().map(|v| model.predict_raw(v)).collect();
    let err = mape(&pred, &yt);
    assert!(err < 0.25, "MLP toy MAPE {err}");
}
