//! Property tests for the compiled LUT tier across the whole scenario
//! universe: for every builtin scenario (all 72) under every deduction
//! mode, a LUT-served prediction is within the compile-time relative
//! error bound of the scalar reference on every plan row; rows the tier
//! declines (no table, out of grid) fall back **bit-identically**; and
//! the engine's opt-in LUT tier serves real traffic within the bound
//! while its counters account for every row.

use edgelat::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use edgelat::features::Standardizer;
use edgelat::framework::{DeductionMode, ScenarioPredictor};
use edgelat::graph::Graph;
use edgelat::plan::LoweredGraph;
use edgelat::predict::lasso::Lasso;
use edgelat::predict::lut::LutSpec;
use edgelat::predict::{BucketModel, Method, NativeModel, TrainedModel};
use edgelat::scenario::Registry;
use std::collections::BTreeMap;

const MODES: [DeductionMode; 3] =
    [DeductionMode::Full, DeductionMode::NoFusion, DeductionMode::NoSelection];

fn graphs(seed: u64, n: usize) -> Vec<Graph> {
    edgelat::nas::sample_dataset(seed, n).into_iter().map(|a| a.graph).collect()
}

/// A deterministic Lasso predictor for one (scenario, mode): one linear
/// model per bucket observed in `plans`, dimensioned to the narrowest
/// observed row. Linear models make LUT interpolation exact up to float
/// rounding, so the bound check isolates the *tier's* behaviour (grid
/// construction, probing, fallback) from model curvature.
fn linear_predictor<'a>(
    sc: &edgelat::scenario::Scenario,
    mode: DeductionMode,
    plans: &[LoweredGraph],
) -> ScenarioPredictor<'a> {
    let it = edgelat::plan::interner();
    let mut dims: BTreeMap<String, usize> = BTreeMap::new();
    for p in plans {
        for (b, row) in p.iter() {
            let d = dims.entry(it.name(b).to_string()).or_insert(row.len());
            *d = (*d).min(row.len()).max(1);
        }
    }
    let mut models = BTreeMap::new();
    for (name, d) in dims {
        let weights: Vec<f64> = (0..d).map(|j| 1e-3 * (j + 1) as f64).collect();
        models.insert(
            name,
            TrainedModel::Owned(BucketModel {
                standardizer: Standardizer { mean: vec![0.0; d], std: vec![1.0; d] },
                model: NativeModel::Lasso(Lasso { weights, intercept: 5.0, alpha: 0.01 }),
                floor: 0.0,
            }),
        );
    }
    ScenarioPredictor::from_parts((*sc).clone(), Method::Lasso, mode, models, 1.0, 0.5)
}

#[test]
fn lut_error_bound_holds_across_all_builtin_scenarios_and_modes() {
    let reg = Registry::with_builtin();
    assert_eq!(reg.all().len(), 72, "the builtin scenario universe");
    // Small grids keep 72 x 3 compilations cheap; the bound contract is
    // resolution-independent.
    let spec = LutSpec { max_rel_err: 0.05, resolution: 5, max_table_entries: 4096 };
    let gs = graphs(77, 2);
    let mut total_served = 0u64;
    let mut total_tables = 0usize;
    for sc in reg.all() {
        for mode in MODES {
            let pred = linear_predictor(sc, mode, &[]);
            let plans: Vec<LoweredGraph> = gs.iter().map(|g| pred.lower(g)).collect();
            // Rebuild with the buckets this (scenario, mode) actually
            // produces, then compile tables on the same plans.
            let pred = linear_predictor(sc, mode, &plans);
            let refs: Vec<&LoweredGraph> = plans.iter().collect();
            let pack = pred.compile_lut(&spec, &refs);
            total_tables += pack.coverage();
            assert!(pack.max_rel_err <= pack.bound, "{} {:?}", sc.id, mode);
            for (g, pl) in gs.iter().zip(&plans) {
                let want = pred.predict_plan_rows_scalar(pl);
                let got = pred.predict_plan_rows_lut(pl, Some(&pack));
                assert_eq!(want.len(), got.len());
                for (i, (w, v)) in want.iter().zip(&got).enumerate() {
                    let rel = (w - v).abs() / w.abs().max(1e-12);
                    assert!(
                        rel <= spec.max_rel_err + 1e-9,
                        "{} {:?} {} unit {i}: lut {v} vs scalar {w} (rel {rel})",
                        sc.id,
                        mode,
                        g.name,
                    );
                }
            }
            total_served += pack.counts().served();
        }
    }
    assert!(total_tables > 0, "no scenario compiled any table");
    assert!(total_served > 0, "the LUT tier never served a row");
}

#[test]
fn rows_without_tables_fall_back_bit_identically() {
    // A pack compiled from no plans has no tables: every row falls back,
    // and the LUT path must be bit-identical to the plain SoA path.
    let reg = Registry::with_builtin();
    let sc = reg.one_large_core("Exynos9820").expect("builtin soc");
    let gs = graphs(78, 2);
    let pred = linear_predictor(&sc, DeductionMode::Full, &[]);
    let plans: Vec<LoweredGraph> = gs.iter().map(|g| pred.lower(g)).collect();
    let pred = linear_predictor(&sc, DeductionMode::Full, &plans);
    let empty = pred.compile_lut(&LutSpec::default(), &[]);
    assert_eq!(empty.coverage(), 0);
    let mut rows = 0u64;
    for pl in &plans {
        let plain = pred.predict_plan_rows(pl);
        let via_lut = pred.predict_plan_rows_lut(pl, Some(&empty));
        for (a, b) in plain.iter().zip(&via_lut) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        rows += plain.len() as u64;
    }
    let c = empty.counts();
    assert_eq!(c.fallbacks, rows, "every row must be counted as a fallback");
    assert_eq!(c.served(), 0);
}

#[test]
fn out_of_grid_rows_fall_back_bit_identically_to_the_scalar_path() {
    // Compile on one workload, probe with another: rows outside the
    // calibration grid must be declined and served bit-identically to the
    // plain path (exact hits are bit-identical by construction, so only
    // interpolated rows may differ — and those stay within the bound).
    let reg = Registry::with_builtin();
    let sc = reg.one_large_core("Snapdragon855").expect("builtin soc");
    let calib = graphs(79, 2);
    let probe = graphs(4242, 2);
    let pred = linear_predictor(&sc, DeductionMode::Full, &[]);
    let cal_plans: Vec<LoweredGraph> = calib.iter().map(|g| pred.lower(g)).collect();
    let pred = linear_predictor(&sc, DeductionMode::Full, &cal_plans);
    let refs: Vec<&LoweredGraph> = cal_plans.iter().collect();
    let spec = LutSpec { max_rel_err: 0.05, resolution: 5, max_table_entries: 4096 };
    let pack = pred.compile_lut(&spec, &refs);
    let before = pack.counts();
    for g in &probe {
        let pl = pred.lower(g);
        let want = pred.predict_plan_rows_scalar(&pl);
        let got = pred.predict_plan_rows_lut(&pl, Some(&pack));
        for (w, v) in want.iter().zip(&got) {
            // Within the bound if a table answered, bit-identical if not.
            let rel = (w - v).abs() / w.abs().max(1e-12);
            assert!(rel <= spec.max_rel_err + 1e-9, "lut {v} vs scalar {w}");
        }
    }
    let after = pack.counts();
    assert!(
        after.fallbacks > before.fallbacks,
        "an unseen workload should push some rows off the grid"
    );
}

#[test]
fn engine_lut_tier_is_opt_in_bounded_and_counted() {
    let sc = edgelat::scenario::one_large_core("HelioP35").unwrap();
    let train_g = graphs(6100, 12);
    let profiles = edgelat::profiler::profile_set(&sc, &train_g, 6100, 3);
    let bundle =
        PredictorBundle::train(&sc, &profiles, Method::Gbdt, DeductionMode::Full, 4).unwrap();

    let plain = EngineBuilder::new().bundle(bundle.clone()).build().unwrap();
    assert!(!plain.lut_enabled(), "the LUT tier is opt-in");
    assert_eq!(plain.lut_tables(), 0);

    let lut = EngineBuilder::new()
        .bundle(bundle)
        .lut(LutSpec::default())
        .build()
        .unwrap();
    assert!(lut.lut_enabled());

    // Predict the engine's own calibration workload: rows land in-grid,
    // so the tier actually serves, and every answer stays within the
    // bound of the plain engine's (scalar-compiled) numbers.
    let probes: Vec<Graph> =
        edgelat::nas::sample_dataset(0xed6e, 4).into_iter().map(|a| a.graph).collect();
    for g in &probes {
        let req = PredictRequest::new(g, sc.id.clone());
        let a = plain.predict(&req).expect("plain serve").e2e_ms;
        let b = lut.predict(&req).expect("lut serve").e2e_ms;
        let rel = (a - b).abs() / a.abs().max(1e-12);
        assert!(rel <= LutSpec::default().max_rel_err + 1e-9, "{}: {a} vs {b}", g.name);
    }
    let counts = lut.lut_counts();
    assert!(
        counts.served() + counts.fallbacks > 0,
        "an enabled tier must account for every row it saw"
    );
    assert!(lut.lut_tables() > 0, "calibration compiled no tables");
}
