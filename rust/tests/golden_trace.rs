//! Golden-trace regression fixture: a committed `PredictorBundle`
//! (`tests/data/golden_bundle.json`) plus its expected per-unit
//! predictions (`tests/data/golden_expected.json`) over a fixed graph.
//!
//! The bundle's Lasso models are constructed so every prediction is exact
//! integer arithmetic in f64 (identity standardizers, one unit weight on
//! a shape-derived feature), so the assertions are **bit-identical**, not
//! approximate. Any silent numeric drift — in bundle (de)serialization,
//! the standardizer, the Lasso scan, plan lowering order, bucket
//! assignment, fallback handling, or the engine serve path — trips this
//! test. Intentional format changes must update the fixture files.

use edgelat::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use edgelat::graph::{EwKind, Graph, GraphBuilder, Padding};
use edgelat::predict::Method;
use edgelat::util::Json;
use std::path::PathBuf;

/// Locate a fixture under `tests/data/`, robust to where the build
/// harness roots the manifest (repo root or `rust/`).
fn data_path(name: &str) -> PathBuf {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    for cand in [root.join("rust/tests/data").join(name), root.join("tests/data").join(name)] {
        if cand.exists() {
            return cand;
        }
    }
    panic!("fixture {name} not found under {}", root.display());
}

fn read_json(name: &str) -> Json {
    let text = std::fs::read_to_string(data_path(name)).expect("readable fixture");
    Json::parse(&text).expect("fixture parses")
}

/// The fixed graph the expected predictions were computed for. One unit
/// per op on the CPU scenario; the ElementWise op has no bucket model in
/// the bundle and must take the fallback path.
fn golden_graph() -> Graph {
    let mut b = GraphBuilder::new("golden", 8, 8, 4);
    let x = b.input_tensor();
    let t = b.conv(x, 8, 3, 1, Padding::Same);
    let t = b.relu(t);
    let t = b.ew_const(EwKind::Abs, t);
    let t = b.avg_pool(t, 3, 2);
    let t = b.mean(t);
    let t = b.fc(t, 10);
    let t = b.softmax(t);
    b.finish(vec![t])
}

fn expected_units(expected: &Json) -> Vec<(String, f64)> {
    expected
        .req("per_unit")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|row| {
            let row = row.as_arr().unwrap();
            (row[0].as_str().unwrap().to_string(), row[1].as_f64().unwrap())
        })
        .collect()
}

#[test]
fn golden_v2_bundle_loads_and_upgrades_losslessly() {
    let parsed = read_json("golden_bundle.json");
    // The committed fixture is deliberately kept at version 2 (id-only, no
    // embedded device) — the compatibility contract for pre-v3 bundles.
    assert_eq!(parsed.req_usize("version").unwrap(), 2);
    let bundle = PredictorBundle::load(data_path("golden_bundle.json")).expect("bundle loads");
    assert_eq!(bundle.scenario_id(), "Snapdragon855/cpu/1L/fp32");
    assert_eq!(bundle.scenario.soc.name, "Snapdragon855");
    assert_eq!(bundle.method, Method::Lasso);
    assert_eq!(bundle.t_overhead_ms.to_bits(), 2.0f64.to_bits());
    assert_eq!(bundle.fallback_ms.to_bits(), 3.0f64.to_bits());
    assert_eq!(bundle.models.len(), 6);
    // Re-serializing writes the current (v4) schema: same metadata and
    // models, plus the embedded device descriptor (and no workload key —
    // an isolated bundle stays isolated); loading it back is lossless and
    // byte-stable from then on.
    let v3 = bundle.to_json();
    assert_eq!(v3.req_usize("version").unwrap(), 4);
    assert!(v3.get("workload").is_none(), "isolated upgrade must not grow a workload key");
    assert_eq!(v3.req("device").unwrap().req_str("name").unwrap(), "Snapdragon855");
    let carried =
        ["scenario", "method", "mode", "t_overhead_ms", "fallback_ms", "interner", "buckets"];
    for key in carried {
        assert_eq!(
            v3.req(key).unwrap(),
            parsed.req(key).unwrap(),
            "{key} drifted in the v2→v3 upgrade"
        );
    }
    let reloaded = PredictorBundle::from_json(&v3).expect("v3 reload");
    assert_eq!(reloaded.scenario, bundle.scenario);
    assert_eq!(
        reloaded.to_json().to_string(),
        v3.to_string(),
        "v3 re-serialization must be byte-stable"
    );
}

#[test]
fn golden_predictions_are_bit_identical_via_the_predictor() {
    let bundle = PredictorBundle::load(data_path("golden_bundle.json")).unwrap();
    let expected = read_json("golden_expected.json");
    let g = golden_graph();
    let pred = bundle.to_predictor().expect("predictor assembles");
    let units = pred.predict_units(&g);
    let want = expected_units(&expected);
    assert_eq!(units.len(), want.len(), "unit count drifted");
    for (i, ((gb, gv), (wb, wv))) in units.iter().zip(&want).enumerate() {
        assert_eq!(gb, wb, "unit {i} bucket");
        assert_eq!(gv.to_bits(), wv.to_bits(), "unit {i} ({gb}): got {gv}, want {wv}");
    }
    let e2e = pred.predict(&g);
    assert_eq!(e2e.to_bits(), expected.req_f64("e2e_ms").unwrap().to_bits(), "e2e {e2e}");
    assert_eq!(
        pred.t_overhead_ms.to_bits(),
        expected.req_f64("t_overhead_ms").unwrap().to_bits()
    );
}

#[test]
fn golden_predictions_are_bit_identical_via_the_engine() {
    let bundle = PredictorBundle::load(data_path("golden_bundle.json")).unwrap();
    let expected = read_json("golden_expected.json");
    let g = golden_graph();
    let engine = EngineBuilder::new().bundle(bundle).threads(2).build().expect("engine");
    let req = PredictRequest::new(&g, "Snapdragon855/cpu/1L/fp32");
    let resp = engine.predict(&req).expect("served");
    assert_eq!(resp.e2e_ms.to_bits(), expected.req_f64("e2e_ms").unwrap().to_bits());
    assert_eq!(
        resp.fallback_units,
        expected.req_usize("fallback_units").unwrap(),
        "the ElementWise unit must take the fallback path"
    );
    let want = expected_units(&expected);
    assert_eq!(resp.per_unit.len(), want.len());
    for ((gb, gv), (wb, wv)) in resp.per_unit.iter().zip(&want) {
        assert_eq!(*gb, wb.as_str());
        assert_eq!(gv.to_bits(), wv.to_bits(), "{gb}");
    }
    // Batch serving returns the same bits as single serving.
    let batch = engine.predict_batch(&[req.clone(), req.clone()]);
    for slot in batch {
        let r = slot.expect("batch slot served");
        assert_eq!(r.e2e_ms.to_bits(), resp.e2e_ms.to_bits());
    }
}
