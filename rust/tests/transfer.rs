//! Integration: few-shot device onboarding. Train a source bundle on a
//! builtin SoC, register a never-seen sampled SoC, adapt with K profiled
//! graphs, and check the ISSUE acceptance bar end to end: the transferred
//! predictor beats the proxy baseline on RMSPE at every budget and never
//! ranks worse (tie-aware Spearman), the accuracy-vs-budget artifact is
//! byte-reproducible across thread counts, and a `TransferBundle`
//! round-trips bit-exactly through both encodings and serves identically
//! from either.

use edgelat::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use edgelat::framework::DeductionMode;
use edgelat::graph::Graph;
use edgelat::plan::{self, LoweredGraph};
use edgelat::predict::Method;
use edgelat::profiler::{profile_set, ModelProfile};
use edgelat::scenario::{Registry, Scenario};
use edgelat::transfer::{adapt, eval, ProxyPredictor, TransferBundle};
use edgelat::util::{rmspe_guarded, spearman, Json};

fn graphs(seed: u64, n: usize) -> Vec<Graph> {
    edgelat::nas::sample_dataset(seed, n).into_iter().map(|a| a.graph).collect()
}

/// Registry with the builtins plus one seed-sampled SoC the source bundle
/// has never seen; returns the registry and the sampled SoC's name.
fn registry_with_sampled(seed: u64) -> (Registry, String) {
    let mut registry = Registry::with_builtin();
    let spec = edgelat::device::sample_specs(seed, 1).remove(0);
    let name = spec.soc.name.clone();
    registry.register_soc(spec).expect("sampled spec registers");
    (registry, name)
}

struct Fixture {
    source: PredictorBundle,
    target: Scenario,
    pool_graphs: Vec<Graph>,
    pool_profiles: Vec<ModelProfile>,
    eval_actual: Vec<f64>,
    eval_plans: Vec<LoweredGraph>,
}

fn fixture() -> Fixture {
    let (registry, target_name) = registry_with_sampled(77);
    let src_sc = registry.one_large_core("Snapdragon855").unwrap();
    let pool_graphs = graphs(500, 40);
    let src_profiles = profile_set(&src_sc, &pool_graphs, 500, 2);
    let source =
        PredictorBundle::train(&src_sc, &src_profiles, Method::Lasso, DeductionMode::Full, 500)
            .expect("source trains");

    let target = registry.one_large_core(&target_name).unwrap();
    let pool_profiles = profile_set(&target, &pool_graphs, 501, 2);
    let eval_graphs = graphs(600, 16);
    let eval_profiles = profile_set(&target, &eval_graphs, 601, 2);
    let eval_actual: Vec<f64> = eval_profiles.iter().map(|p| p.end_to_end_ms).collect();
    let eval_plans: Vec<LoweredGraph> =
        eval_graphs.iter().map(|g| plan::lower(&target, DeductionMode::Full, g)).collect();
    Fixture { source, target, pool_graphs, pool_profiles, eval_actual, eval_plans }
}

#[test]
fn adapted_beats_proxy_at_every_budget_on_a_never_seen_soc() {
    let fx = fixture();
    let proxy = ProxyPredictor::new(&fx.source).expect("proxy compiles");
    let proxy_pred: Vec<f64> = fx.eval_plans.iter().map(|pl| proxy.predict_plan(pl)).collect();
    let (proxy_rmspe, _) = rmspe_guarded(&proxy_pred, &fx.eval_actual);
    let proxy_spear = spearman(&proxy_pred, &fx.eval_actual);
    assert!(proxy_rmspe.is_finite() && proxy_rmspe > 0.0, "{proxy_rmspe}");
    assert!(proxy_spear.is_finite(), "{proxy_spear}");

    for k in [5usize, 10, 20, 40] {
        let report =
            adapt(&fx.source, &fx.target, &fx.pool_graphs[..k], &fx.pool_profiles[..k])
                .expect("adapt");
        let tp = report.bundle.predictor().expect("transfer predictor compiles");
        let pred: Vec<f64> = fx.eval_plans.iter().map(|pl| tp.predict_plan(pl)).collect();
        let (rmspe, _) = rmspe_guarded(&pred, &fx.eval_actual);
        let spear = spearman(&pred, &fx.eval_actual);
        assert!(
            rmspe.is_finite() && rmspe < proxy_rmspe,
            "K={k}: adapted RMSPE {rmspe} must beat proxy {proxy_rmspe}"
        );
        assert!(
            spear.is_finite() && spear >= proxy_spear,
            "K={k}: adapted Spearman {spear} must not rank worse than proxy {proxy_spear}"
        );
        assert_eq!(report.bundle.budget, k);
    }
}

#[test]
fn transfer_bundle_roundtrips_bit_exact_in_both_encodings_and_serves_identically() {
    let fx = fixture();
    let report = adapt(&fx.source, &fx.target, &fx.pool_graphs[..10], &fx.pool_profiles[..10])
        .expect("adapt");
    let tb = report.bundle;

    // JSON round trip is byte-stable.
    let text = tb.to_json().to_string();
    let back = TransferBundle::from_json(&Json::parse(&text).unwrap()).expect("json parses back");
    assert_eq!(back.to_json().to_string(), text, "JSON re-emit must be byte-identical");

    // Binary round trip is byte-stable, and the two encodings describe
    // the same bundle.
    let bytes = tb.to_bin_bytes().expect("bin encodes");
    let back2 = TransferBundle::from_bin_bytes(&bytes).expect("bin decodes");
    assert_eq!(back2.to_bin_bytes().expect("re-encode"), bytes);
    assert_eq!(back2.to_json().to_string(), text, "both encodings describe one bundle");

    // Engines built from the two on-disk encodings predict bit-identically
    // on the transferred target scenario.
    let dir = std::env::temp_dir().join(format!("edgelat_transfer_rt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let jpath = dir.join("t.json");
    let bpath = dir.join("t.bin");
    tb.save(&jpath).expect("json saved");
    tb.save_bin(&bpath).expect("bin saved");
    let e_json = EngineBuilder::new().bundle_file(&jpath).unwrap().build().unwrap();
    let e_bin = EngineBuilder::new().bundle_file(&bpath).unwrap().build().unwrap();
    let tp = tb.predictor().expect("in-memory predictor");
    for (i, g) in graphs(700, 6).iter().enumerate() {
        let req = PredictRequest::new(g, tb.scenario_id());
        let a = e_json.predict(&req).expect("json engine serves");
        let b = e_bin.predict(&req).expect("bin engine serves");
        assert_eq!(a.e2e_ms.to_bits(), b.e2e_ms.to_bits(), "graph {i}");
        // And both match the in-process transfer predictor exactly.
        let pl = plan::lower(&fx.target, DeductionMode::Full, g);
        assert_eq!(a.e2e_ms.to_bits(), tp.predict_plan(&pl).to_bits(), "graph {i}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn eval_artifact_is_byte_reproducible_and_meets_the_headline_bar() {
    // Thread count must change speed only, never bytes: a 1-thread and a
    // 4-thread run of the same seed must emit identical artifacts.
    let a = eval::run(&eval::EvalConfig { quick: true, seed: 2022, threads: 1 })
        .expect("eval runs")
        .to_string();
    let b = eval::run(&eval::EvalConfig { quick: true, seed: 2022, threads: 4 })
        .expect("eval runs")
        .to_string();
    assert_eq!(a, b, "transfer-eval artifact must be byte-reproducible across thread counts");

    let doc = Json::parse(&a).expect("artifact parses");
    assert!(!a.contains("NaN") && !a.contains("inf"), "bare NaN/inf leaked into artifact");
    assert_eq!(doc.req("format").unwrap().as_str().unwrap(), eval::EVAL_FORMAT);
    let summary = doc.req("summary").expect("summary present");
    assert!(summary.req_f64("pairs").unwrap() >= 1.0);
    // The acceptance bar: at the headline budget the transferred
    // predictor beats the proxy on RMSPE and never ranks worse, for
    // every evaluated (source, target) pair.
    assert_eq!(summary.req("adapted_beats_proxy_rmspe").unwrap(), &Json::Bool(true));
    assert_eq!(summary.req("adapted_no_worse_spearman").unwrap(), &Json::Bool(true));
    assert_eq!(summary.req_f64("degenerate_pairs").unwrap(), 0.0);
}
