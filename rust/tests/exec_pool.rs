//! Integration: the shared worker-pool subsystem and the sharded engine
//! cache — ordered batch results with per-slot errors, memo hit/miss
//! accounting through the serving engine, and parallel == sequential
//! equivalence for profiling.

use edgelat::engine::{EngineBuilder, LatencyEngine, PredictRequest, PredictorBundle};
use edgelat::exec_pool::{ExecPool, ShardedCache};
use edgelat::framework::DeductionMode;
use edgelat::graph::Graph;
use edgelat::predict::Method;
use edgelat::profiler::{profile_set, profile_set_with};
use edgelat::scenario::{one_large_core, Scenario};

fn nas_graphs(seed: u64, n: usize) -> Vec<Graph> {
    edgelat::nas::sample_dataset(seed, n).into_iter().map(|a| a.graph).collect()
}

fn small_engine(sc: &Scenario, seed: u64, threads: usize) -> (LatencyEngine, Vec<Graph>) {
    let graphs = nas_graphs(seed, 8);
    let profiles = profile_set(sc, &graphs, seed, 2);
    let bundle =
        PredictorBundle::train(sc, &profiles, Method::Gbdt, DeductionMode::Full, 1).unwrap();
    let engine = EngineBuilder::new().bundle(bundle).threads(threads).build().unwrap();
    (engine, graphs)
}

#[test]
fn predict_batch_preserves_order_and_per_slot_errors() {
    let sc = one_large_core("HelioP35").unwrap();
    let (engine, graphs) = small_engine(&sc, 77, 4);
    // Interleave good requests with unknown-scenario and wrong-method
    // ones: every slot must line up with its request, and the bad slots
    // must carry their own errors without poisoning the good ones.
    let reqs: Vec<PredictRequest> = graphs
        .iter()
        .enumerate()
        .map(|(i, g)| match i % 3 {
            0 => PredictRequest::new(g, sc.id.clone()),
            1 => PredictRequest::new(g, "NoSuch/cpu/1L/fp32"),
            _ => PredictRequest::new(g, sc.id.clone()).with_method(Method::Lasso),
        })
        .collect();
    let out = engine.predict_batch(&reqs);
    assert_eq!(out.len(), reqs.len());
    for (i, slot) in out.iter().enumerate() {
        match i % 3 {
            0 => {
                let resp = slot.as_ref().expect("good request served");
                let seq = engine.predict(&reqs[i]).expect("sequential serve");
                assert_eq!(resp.e2e_ms.to_bits(), seq.e2e_ms.to_bits(), "slot {i}");
                assert_eq!(resp.per_unit.len(), seq.per_unit.len());
            }
            1 => {
                let err = slot.as_ref().expect_err("unknown scenario must error");
                assert!(err.to_string().contains("NoSuch"), "slot {i}: {err}");
            }
            _ => {
                let err = slot.as_ref().expect_err("wrong method must error");
                assert!(err.to_string().contains("Lasso"), "slot {i}: {err}");
            }
        }
    }
}

#[test]
fn predict_batch_is_identical_for_any_thread_count() {
    let sc = one_large_core("Snapdragon710").unwrap();
    let graphs = nas_graphs(31, 10);
    let profiles = profile_set(&sc, &graphs, 31, 2);
    let bundle =
        PredictorBundle::train(&sc, &profiles, Method::Lasso, DeductionMode::Full, 2).unwrap();
    let mut outputs: Vec<Vec<u64>> = Vec::new();
    for threads in [1usize, 2, 8] {
        let engine = EngineBuilder::new()
            .bundle(bundle.clone())
            .threads(threads)
            .build()
            .unwrap();
        let reqs: Vec<PredictRequest> =
            graphs.iter().map(|g| PredictRequest::new(g, sc.id.clone())).collect();
        outputs.push(
            engine
                .predict_batch(&reqs)
                .into_iter()
                .map(|r| r.expect("served").e2e_ms.to_bits())
                .collect(),
        );
    }
    assert_eq!(outputs[0], outputs[1]);
    assert_eq!(outputs[0], outputs[2]);
}

#[test]
fn engine_cache_stats_count_hits_misses_and_sharing() {
    let sc = one_large_core("Exynos9820").unwrap();
    let (engine, graphs) = small_engine(&sc, 55, 2);
    let g = &graphs[0];
    let s0 = engine.cache_stats();
    assert_eq!((s0.hits, s0.misses), (0, 0), "fresh engine");
    let req = PredictRequest::new(g, sc.id.clone());
    engine.predict(&req).unwrap();
    let s1 = engine.cache_stats();
    assert_eq!(s1.misses, 1, "first deduction is a miss");
    assert_eq!(s1.hits, 0);
    for _ in 0..3 {
        engine.predict(&req).unwrap();
    }
    let s2 = engine.cache_stats();
    assert_eq!(s2.misses, 1, "same graph never re-deduces");
    assert_eq!(s2.hits, 3);
    // A whole batch over distinct graphs: one miss per distinct graph.
    let reqs: Vec<PredictRequest> =
        graphs.iter().map(|x| PredictRequest::new(x, sc.id.clone())).collect();
    engine.predict_batch(&reqs);
    let s3 = engine.cache_stats();
    assert_eq!(s3.misses as usize, graphs.len(), "one deduction per distinct graph");
    engine.predict_batch(&reqs);
    let s4 = engine.cache_stats();
    assert_eq!(s4.misses, s3.misses, "warm batch is all hits");
    assert_eq!(s4.hits, s3.hits + reqs.len() as u64);
}

#[test]
fn sharded_cache_keeps_other_shards_warm_on_eviction() {
    let cache: ShardedCache<u64, u64> = ShardedCache::new(4, 64);
    assert_eq!(cache.shard_count(), 4);
    assert_eq!(cache.capacity(), 64);
    for k in 0..1000u64 {
        cache.insert(k, k * 2);
    }
    let st = cache.stats();
    assert!(st.evictions > 0, "1000 inserts into capacity 64 must evict");
    // Per-shard clears leave the rest of the cache populated.
    assert!(!cache.is_empty());
    assert!(cache.len() <= 64);
}

#[test]
fn pool_map_equivalence_across_thread_counts_on_real_profiling() {
    let sc = one_large_core("Snapdragon855").unwrap();
    let graphs = nas_graphs(91, 6);
    let seq = profile_set_with(&ExecPool::new(1), &sc, &graphs, 9, 2);
    let par = profile_set_with(&ExecPool::new(6), &sc, &graphs, 9, 2);
    assert_eq!(seq.len(), par.len());
    for (a, b) in seq.iter().zip(&par) {
        assert_eq!(a.model, b.model);
        assert_eq!(a.end_to_end_ms.to_bits(), b.end_to_end_ms.to_bits(), "{}", a.model);
        assert_eq!(a.ops.len(), b.ops.len());
        for (x, y) in a.ops.iter().zip(&b.ops) {
            assert_eq!(x.latency_ms.to_bits(), y.latency_ms.to_bits());
        }
    }
}
