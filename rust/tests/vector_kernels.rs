//! Vectorized-kernel parity: the structure-of-arrays batch kernels behind
//! `ScenarioPredictor::predict_plan_rows` must be **bit-identical** to the
//! scalar per-row reference (`predict_plan_rows_scalar`) for every native
//! method, across the full builtin scenario matrix (all 72 scenarios x all
//! deduction modes) and across a sampled fleet of synthetic SoCs. This is
//! the acceptance bar of the SoA refactor: breadth-first evaluation over a
//! dense matrix is a layout change, never a numeric one.

use edgelat::framework::{DeductionMode, ScenarioPredictor};
use edgelat::graph::Graph;
use edgelat::plan;
use edgelat::predict::Method;
use edgelat::profiler::profile_set;
use edgelat::scenario::Registry;

fn zoo_graphs() -> Vec<Graph> {
    vec![
        edgelat::zoo::mobilenets::mobilenet_v1(0.75),
        edgelat::zoo::resnets::resnet(18, 0.25),
    ]
}

fn train_graphs(seed: u64, n: usize) -> Vec<Graph> {
    edgelat::nas::sample_dataset(seed, n).into_iter().map(|a| a.graph).collect()
}

/// Assert the vectorized and scalar plan paths agree to the bit on every
/// unit of every (scenario, mode, graph) triple handed in.
fn assert_parity(
    pred: &ScenarioPredictor<'_>,
    scenarios: &[std::sync::Arc<edgelat::scenario::Scenario>],
    label: &str,
) {
    let graphs = zoo_graphs();
    let modes = [DeductionMode::Full, DeductionMode::NoFusion, DeductionMode::NoSelection];
    let mut units = 0usize;
    for sc in scenarios {
        for mode in modes {
            for g in &graphs {
                let pl = plan::lower(sc, mode, g);
                let vectorized = pred.predict_plan_rows(&pl);
                let scalar = pred.predict_plan_rows_scalar(&pl);
                assert_eq!(vectorized.len(), scalar.len());
                for (i, (v, s)) in vectorized.iter().zip(&scalar).enumerate() {
                    assert_eq!(
                        v.to_bits(),
                        s.to_bits(),
                        "{label}: scenario {} mode {mode:?} unit {i}: \
                         vectorized {v} != scalar {s}",
                        sc.id
                    );
                }
                units += vectorized.len();
            }
        }
    }
    assert!(units > 0, "{label}: parity sweep evaluated no units");
}

/// Every native method, all 72 builtin scenarios, all deduction modes.
#[test]
fn vectorized_matches_scalar_across_builtin_matrix() {
    let registry = Registry::builtin();
    let sc = registry.one_large_core("Snapdragon855").unwrap();
    let profiles = profile_set(&sc, &train_graphs(41, 10), 41, 2);
    for method in [Method::Lasso, Method::RandomForest, Method::Gbdt] {
        let pred =
            ScenarioPredictor::train_from(&sc, &profiles, method, DeductionMode::Full, 41, None);
        assert_parity(&pred, registry.all(), &format!("{method:?}"));
    }
}

/// The sampled fleet universe: plans from synthetic SoCs the predictor has
/// never seen still evaluate bit-identically through the kernels (modeled
/// buckets vectorize, unmodeled ones take the same fallback on both paths).
#[test]
fn vectorized_matches_scalar_over_sampled_fleet() {
    let mut reg = Registry::new();
    for spec in edgelat::device::sample_specs(97, 10) {
        reg.register_soc(spec).unwrap();
    }
    let sc = Registry::builtin().one_large_core("Snapdragon855").unwrap();
    let profiles = profile_set(&sc, &train_graphs(97, 10), 97, 2);
    let pred =
        ScenarioPredictor::train_from(&sc, &profiles, Method::Gbdt, DeductionMode::Full, 97, None);
    assert_parity(&pred, reg.all(), "fleet");
}
