//! Integration: the serving engine layer. Train → serialize → deserialize →
//! predictions bit-identical for every native method; corrupted and
//! version-mismatched bundles rejected with clear errors; the loaded
//! engine matches the in-memory predictor, single and batched.

use edgelat::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use edgelat::framework::{DeductionMode, ScenarioPredictor};
use edgelat::graph::Graph;
use edgelat::predict::Method;
use edgelat::profiler::{profile_set, ModelProfile};
use edgelat::scenario::Scenario;
use edgelat::util::Json;

fn training_set(sc: &Scenario, n: usize, seed: u64) -> (Vec<Graph>, Vec<ModelProfile>) {
    let graphs: Vec<Graph> =
        edgelat::nas::sample_dataset(seed, n).into_iter().map(|a| a.graph).collect();
    let profiles = profile_set(sc, &graphs, seed, 3);
    (graphs, profiles)
}

fn probe_graphs(seed: u64, n: usize) -> Vec<Graph> {
    edgelat::nas::sample_dataset(seed, n).into_iter().map(|a| a.graph).collect()
}

#[test]
fn bundle_roundtrip_bit_identical_for_all_native_methods() {
    let sc = edgelat::scenario::one_large_core("HelioP35").unwrap();
    let (_, profiles) = training_set(&sc, 16, 100);
    let probes = probe_graphs(200, 8);
    for &method in Method::native() {
        let pred =
            ScenarioPredictor::train_from(&sc, &profiles, method, DeductionMode::Full, 3, None);
        let bundle = PredictorBundle::from_predictor(&pred).expect("bundle");
        // Serialize to text and back — the full on-disk path.
        let text = bundle.to_json().to_string();
        let back = PredictorBundle::from_json(&Json::parse(&text).unwrap()).unwrap();
        let pred2 = back.to_predictor().expect("rebuild predictor");
        assert_eq!(pred2.t_overhead_ms.to_bits(), pred.t_overhead_ms.to_bits());
        for g in &probes {
            let a = pred.predict(g);
            let b = pred2.predict(g);
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{} on {}: {a} vs {b}",
                method.name(),
                g.name
            );
        }
    }
}

#[test]
fn gpu_bundle_roundtrip_bit_identical() {
    // GPU scenarios exercise kernel deduction (fusion + selection) and the
    // fused-kernel feature extras; the round-trip must hold there too.
    let soc = edgelat::device::soc_by_name("Exynos9820").unwrap();
    let sc = Scenario::gpu(&soc);
    let (_, profiles) = training_set(&sc, 12, 300);
    let pred =
        ScenarioPredictor::train_from(&sc, &profiles, Method::Lasso, DeductionMode::Full, 1, None);
    let bundle = PredictorBundle::from_predictor(&pred).expect("bundle");
    let back =
        PredictorBundle::from_json(&Json::parse(&bundle.to_json().to_string()).unwrap()).unwrap();
    let pred2 = back.to_predictor().unwrap();
    for g in probe_graphs(400, 6) {
        assert_eq!(pred.predict(&g).to_bits(), pred2.predict(&g).to_bits(), "{}", g.name);
    }
}

#[test]
fn bundle_file_roundtrip_via_save_and_load() {
    let sc = edgelat::scenario::one_large_core("Snapdragon710").unwrap();
    let (_, profiles) = training_set(&sc, 12, 500);
    let pred =
        ScenarioPredictor::train_from(&sc, &profiles, Method::Gbdt, DeductionMode::Full, 2, None);
    let bundle = PredictorBundle::from_predictor(&pred).expect("bundle");
    let path = std::env::temp_dir()
        .join(format!("edgelat_test_bundle_{}.json", std::process::id()));
    bundle.save(&path).expect("save");
    let engine = EngineBuilder::new()
        .bundle_file(&path)
        .expect("load bundle file")
        .build()
        .expect("build engine");
    let g = probe_graphs(600, 1).pop().unwrap();
    let resp = engine.predict(&PredictRequest::new(&g, sc.id.clone())).expect("served");
    assert_eq!(resp.e2e_ms.to_bits(), pred.predict(&g).to_bits());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupted_and_mismatched_bundles_rejected_with_clear_errors() {
    // Not JSON at all.
    assert!(Json::parse("definitely not json").is_err());
    // JSON but not a bundle.
    let err = PredictorBundle::from_json(&Json::parse("{}").unwrap()).unwrap_err();
    assert!(err.contains("format"), "{err}");
    // Wrong format tag.
    let err = PredictorBundle::from_json(
        &Json::parse(r#"{"format":"something.else","version":1}"#).unwrap(),
    )
    .unwrap_err();
    assert!(err.contains("not a predictor bundle"), "{err}");

    // A real bundle with a bumped version must be rejected, naming the
    // version in the error.
    let sc = edgelat::scenario::one_large_core("HelioP35").unwrap();
    let (_, profiles) = training_set(&sc, 10, 700);
    let bundle =
        PredictorBundle::train(&sc, &profiles, Method::Lasso, DeductionMode::Full, 1).unwrap();
    let mut j = bundle.to_json();
    if let Json::Obj(m) = &mut j {
        m.insert("version".into(), Json::Num(999.0));
    }
    let err = PredictorBundle::from_json(&j).unwrap_err();
    assert!(err.contains("version 999"), "{err}");

    // Truncated document (corrupted file) fails to parse.
    let text = bundle.to_json().to_string();
    assert!(Json::parse(&text[..text.len() / 2]).is_err());

    // A bucket whose model kind disagrees with the bundle method.
    let mut j = bundle.to_json();
    if let Json::Obj(m) = &mut j {
        m.insert("method".into(), Json::str("gbdt"));
    }
    let err = PredictorBundle::from_json(&j).unwrap_err();
    assert!(err.contains("bundle method"), "{err}");

    // MLP bundles are unsupported, with a message that says why.
    let err = PredictorBundle::train(&sc, &profiles, Method::Mlp, DeductionMode::Full, 1)
        .unwrap_err();
    assert!(err.to_string().contains("MLP"), "{err}");
}

#[test]
fn engine_serves_multiple_scenarios_and_batch_matches_sequential() {
    let sc_cpu = edgelat::scenario::one_large_core("Snapdragon855").unwrap();
    let soc = edgelat::device::soc_by_name("Snapdragon855").unwrap();
    let sc_gpu = Scenario::gpu(&soc);
    let (_, p_cpu) = training_set(&sc_cpu, 12, 900);
    let (_, p_gpu) = training_set(&sc_gpu, 12, 900);
    let b_cpu =
        PredictorBundle::train(&sc_cpu, &p_cpu, Method::Gbdt, DeductionMode::Full, 4).unwrap();
    let b_gpu =
        PredictorBundle::train(&sc_gpu, &p_gpu, Method::Gbdt, DeductionMode::Full, 4).unwrap();
    let engine = EngineBuilder::new().bundle(b_cpu).bundle(b_gpu).threads(4).build().unwrap();
    assert_eq!(engine.len(), 2);
    assert_eq!(engine.scenario_ids(), vec![sc_cpu.id.as_str(), sc_gpu.id.as_str()]);

    let probes = probe_graphs(1000, 10);
    let mut reqs: Vec<PredictRequest> = Vec::new();
    for g in &probes {
        reqs.push(PredictRequest::new(g, sc_cpu.id.clone()));
        reqs.push(PredictRequest::new(g, sc_gpu.id.clone()).with_method(Method::Gbdt));
    }
    let batch = engine.predict_batch(&reqs);
    assert_eq!(batch.len(), reqs.len());
    for (req, out) in reqs.iter().zip(&batch) {
        let batch_resp = out.as_ref().expect("batch slot served");
        let seq_resp = engine.predict(req).expect("sequential serve");
        assert_eq!(batch_resp.e2e_ms.to_bits(), seq_resp.e2e_ms.to_bits());
        assert_eq!(batch_resp.per_unit.len(), seq_resp.per_unit.len());
        assert!(batch_resp.e2e_ms.is_finite() && batch_resp.e2e_ms > 0.0);
        assert!(batch_resp.e2e_ms >= batch_resp.t_overhead_ms);
    }

    // Unknown scenario / method surfaces as a per-slot error, not a panic.
    let g = &probes[0];
    let bad = engine.predict(&PredictRequest::new(g, "NoSuch/gpu"));
    assert!(bad.unwrap_err().to_string().contains("NoSuch/gpu"));
    let bad = engine.predict(&PredictRequest::new(g, sc_cpu.id.clone()).with_method(Method::Lasso));
    assert!(bad.unwrap_err().to_string().contains("Lasso"));
}

#[test]
fn bundle_serializes_the_intern_table_and_rejects_unknown_buckets() {
    let sc = edgelat::scenario::one_large_core("HelioP35").unwrap();
    let (_, profiles) = training_set(&sc, 10, 1500);
    let bundle =
        PredictorBundle::train(&sc, &profiles, Method::Lasso, DeductionMode::Full, 2).unwrap();
    let j = bundle.to_json();

    // The serialized table is the build's interner, names in BucketId
    // order — the symbol set every model key must resolve against.
    let table = j.req("interner").unwrap().as_arr().expect("interner array");
    let it = edgelat::plan::interner();
    assert_eq!(table.len(), it.len());
    for (i, n) in table.iter().enumerate() {
        assert_eq!(n.as_str().unwrap(), it.names()[i]);
    }

    // A model keyed by a bucket absent from the table is rejected.
    let mut tampered = bundle.to_json();
    if let Json::Obj(m) = &mut tampered {
        let Some(Json::Obj(buckets)) = m.get_mut("buckets") else { panic!("buckets obj") };
        let (k, v) = buckets
            .iter()
            .next()
            .map(|(k, v)| (k.clone(), v.clone()))
            .expect("at least one bucket model");
        buckets.remove(&k);
        buckets.insert("MysteryKernel".into(), v);
    }
    let err = PredictorBundle::from_json(&tampered).unwrap_err();
    assert!(err.contains("MysteryKernel"), "{err}");

    // A bundle with no table at all (e.g. a pre-plan v1 file with a bumped
    // version) is rejected by the schema, naming the missing field.
    let mut no_table = bundle.to_json();
    if let Json::Obj(m) = &mut no_table {
        m.remove("interner");
    }
    let err = PredictorBundle::from_json(&no_table).unwrap_err();
    assert!(err.contains("interner"), "{err}");
}

#[test]
fn engine_per_unit_buckets_are_interned_names() {
    let sc = edgelat::scenario::one_large_core("Snapdragon855").unwrap();
    let (_, profiles) = training_set(&sc, 10, 1700);
    let bundle =
        PredictorBundle::train(&sc, &profiles, Method::Gbdt, DeductionMode::Full, 3).unwrap();
    let engine = EngineBuilder::new().bundle(bundle).build().unwrap();
    let g = probe_graphs(1800, 1).pop().unwrap();
    let resp = engine.predict(&PredictRequest::new(&g, sc.id.clone())).unwrap();
    assert_eq!(resp.per_unit.len(), g.nodes.len());
    let it = edgelat::plan::interner();
    for (b, ms) in &resp.per_unit {
        // &'static str straight out of the symbol table.
        assert!(it.resolve(b).is_some(), "{b}");
        assert!(ms.is_finite() && *ms > 0.0);
    }
}

#[test]
fn engine_memoized_deduction_is_consistent() {
    // Repeated queries for the same graph must hit the deduction cache and
    // return identical responses.
    let sc = edgelat::scenario::one_large_core("Exynos9820").unwrap();
    let (_, profiles) = training_set(&sc, 10, 1100);
    let bundle =
        PredictorBundle::train(&sc, &profiles, Method::Lasso, DeductionMode::Full, 5).unwrap();
    let engine = EngineBuilder::new().bundle(bundle).build().unwrap();
    let g = probe_graphs(1200, 1).pop().unwrap();
    let req = PredictRequest::new(&g, sc.id.clone());
    let first = engine.predict(&req).unwrap();
    for _ in 0..5 {
        let again = engine.predict(&req).unwrap();
        assert_eq!(first.e2e_ms.to_bits(), again.e2e_ms.to_bits());
    }
}

#[test]
fn v2_bundles_resolve_ids_against_the_builtin_registry() {
    let sc = edgelat::scenario::one_large_core("HelioP35").unwrap();
    let (_, profiles) = training_set(&sc, 10, 1300);
    let bundle =
        PredictorBundle::train(&sc, &profiles, Method::Lasso, DeductionMode::Full, 6).unwrap();

    // Downgrade the v3 document to the v2 shape: id only, no embedded
    // device descriptor. A builtin id resolves and predicts identically...
    let downgrade = |id: &str| {
        let mut j = bundle.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("version".into(), Json::Num(2.0));
            m.insert("scenario".into(), Json::str(id));
            m.remove("device");
            m.remove("target");
        }
        j
    };
    let v2 = PredictorBundle::from_json(&downgrade(&sc.id)).expect("v2 bundle loads");
    assert_eq!(v2.scenario_id(), sc.id);
    assert_eq!(v2.scenario, bundle.scenario);
    let g = probe_graphs(1350, 1).pop().unwrap();
    let a = bundle.to_predictor().unwrap().predict(&g);
    let b = v2.to_predictor().unwrap().predict(&g);
    assert_eq!(a.to_bits(), b.to_bits());

    // ...while an id outside the builtin universe is a clear error that
    // names the scenario and points at the v3 migration.
    let err = PredictorBundle::from_json(&downgrade("Imaginary/cpu/1L/fp32")).unwrap_err();
    assert!(err.contains("Imaginary"), "{err}");
    assert!(err.contains("v3") || err.contains("descriptor"), "{err}");
}

#[test]
fn hand_assembled_invalid_scenario_rejected_before_serving() {
    // Bundle fields are pub: a programmatically assembled bundle whose
    // scenario disagrees with its own device (combo arity vs clusters)
    // must be a typed error at build/to_predictor time, never a panic
    // inside the cost model.
    let sc = edgelat::scenario::one_large_core("HelioP35").unwrap();
    let (_, profiles) = training_set(&sc, 8, 1600);
    let mut bundle =
        PredictorBundle::train(&sc, &profiles, Method::Lasso, DeductionMode::Full, 8).unwrap();
    // HelioP35 has 2 clusters; force a 3-count combo into the scenario.
    let tampered = edgelat::scenario::Scenario {
        soc: bundle.scenario.soc.clone(),
        target: edgelat::device::Target::Cpu {
            combo: edgelat::device::CoreCombo::new(vec![1, 0, 3]),
            rep: edgelat::device::DataRep::Fp32,
        },
        id: bundle.scenario.id.clone(),
        workload: None,
    };
    bundle.scenario = tampered;
    let err = bundle.to_predictor().unwrap_err();
    assert!(err.to_string().contains("combo"), "{err}");
    let err = EngineBuilder::new().bundle(bundle.clone()).build().unwrap_err();
    assert!(err.to_string().contains("combo"), "{err}");
    // Same for out-of-range device parameters.
    bundle.scenario = (*edgelat::scenario::by_id(&sc.id).unwrap()).clone();
    bundle.scenario.soc.mem_gbps = f64::NAN;
    let err = EngineBuilder::new().bundle(bundle).build().unwrap_err();
    assert!(err.to_string().contains("mem_gbps"), "{err}");

    let good =
        PredictorBundle::train(&sc, &profiles, Method::Lasso, DeductionMode::Full, 8).unwrap();
    // An id that disagrees with an otherwise-valid descriptor is rejected
    // too — the engine must never serve one device's cost model under
    // another scenario's id (same rule the v3 loader enforces).
    let mut wrong_id = good.clone();
    let other = (*edgelat::scenario::by_id("HelioP35/cpu/2L/fp32").unwrap()).clone();
    wrong_id.scenario = edgelat::scenario::Scenario { id: good.scenario.id.clone(), ..other };
    let err = wrong_id.to_predictor().unwrap_err();
    assert!(err.to_string().contains("disagrees"), "{err}");

    // Fractional schema versions are rejected, not truncated.
    let mut frac = good.to_json();
    if let Json::Obj(m) = &mut frac {
        m.insert("version".into(), Json::Num(2.7));
    }
    let err = PredictorBundle::from_json(&frac).unwrap_err();
    assert!(err.contains("version"), "{err}");
}

#[test]
fn v3_bundle_embeds_its_device_descriptor() {
    // The v3 document is self-describing: the `device` block carries the
    // full SoC spec and `target` the concrete combo/rep.
    let sc = edgelat::scenario::one_large_core("Snapdragon710").unwrap();
    let (_, profiles) = training_set(&sc, 8, 1400);
    let bundle =
        PredictorBundle::train(&sc, &profiles, Method::Lasso, DeductionMode::Full, 7).unwrap();
    let j = bundle.to_json();
    assert_eq!(j.req_usize("version").unwrap(), 4);
    let device = j.req("device").unwrap();
    assert_eq!(device.req_str("name").unwrap(), "Snapdragon710");
    assert!(device.req("clusters").unwrap().as_arr().unwrap().len() == 2);
    let target = j.req("target").unwrap();
    assert_eq!(target.req_str("kind").unwrap(), "cpu");
    assert_eq!(target.req_str("rep").unwrap(), "fp32");
    // Tampering with the embedded device (invalid parameters) is rejected
    // with the same validation a spec file gets.
    let mut tampered = bundle.to_json();
    if let Json::Obj(m) = &mut tampered {
        let Some(Json::Obj(d)) = m.get_mut("device") else { panic!("device obj") };
        d.insert("mem_gbps".into(), Json::Num(-1.0));
    }
    let err = PredictorBundle::from_json(&tampered).unwrap_err();
    assert!(err.contains("mem_gbps"), "{err}");
}
