//! Integration: the open device universe.
//!
//! The acceptance path of the registry redesign: a never-before-seen SoC
//! defined only by a JSON spec is registered, profiled, trained into a v3
//! predictor bundle, reloaded **without the spec available anywhere** (the
//! descriptor travels inside the bundle), and served via `predict_batch` —
//! plus the spec round-trip property (builtin SoCs → JSON → registry
//! reproduces all 72 scenario ids, combos, and lowered plans exactly) and
//! the I/O error contract (paths named in errors).

use edgelat::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use edgelat::framework::DeductionMode;
use edgelat::graph::Graph;
use edgelat::plan;
use edgelat::predict::Method;
use edgelat::profiler::{profile_by_id, profile_set};
use edgelat::scenario::{Registry, ScenarioError};
use edgelat::util::Json;
use std::path::PathBuf;

/// A SoC that exists nowhere in the source tree: big.LITTLE with an
/// Adreno-class GPU, described entirely as data.
const PHANTOM_SPEC: &str = r#"{
  "format": "edgelat.device_spec",
  "version": 1,
  "name": "PhantomX1",
  "platform": "Integration-test handset",
  "clusters": [
    {"kind": "large", "name": "Cortex-X1", "count": 1, "ghz": 2.9, "flops_per_cycle": 16.0, "int8_speedup": 3.1, "stream_gbps": 9.0},
    {"kind": "small", "name": "Cortex-A55", "count": 4, "ghz": 1.9, "flops_per_cycle": 8.0, "int8_speedup": 2.2, "stream_gbps": 3.6}
  ],
  "gpu": {"kind": "Adreno6xx", "name": "Adreno 660", "gflops": 1500.0, "mem_gbps": 44.0, "dispatch_us": 25.0, "overhead_ms": 2.9, "overhead_sigma": 0.09, "run_sigma": 0.03},
  "mem_gbps": 44.0,
  "cpu_op_overhead_us": 2.8,
  "cpu_overhead_ms": 0.6,
  "hetero_sync_mult": 2.3,
  "quant_ew_penalty": 2.5,
  "noise_base": 0.011,
  "noise_per_small_core": 0.014,
  "noise_per_extra_core": 0.005,
  "combos": [[1, 0], [0, 2], [1, 2]]
}"#;

fn nas_graphs(seed: u64, n: usize) -> Vec<Graph> {
    edgelat::nas::sample_dataset(seed, n).into_iter().map(|a| a.graph).collect()
}

/// Locate a repo file, robust to where the build harness roots the
/// manifest (repo root or `rust/`).
fn repo_path(rel: &str) -> PathBuf {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    for cand in [root.join(rel), root.join("..").join(rel)] {
        if cand.exists() {
            return cand;
        }
    }
    panic!("{rel} not found under {}", root.display());
}

#[test]
fn never_seen_soc_trains_serializes_and_serves_without_its_spec() {
    // 1. Register the phantom device from JSON alone.
    let mut reg = Registry::with_builtin();
    let name = reg.load_spec_json(PHANTOM_SPEC).expect("phantom spec registers");
    assert_eq!(name, "PhantomX1");
    assert_eq!(reg.scenario_count(), 72 + 3 * 2 + 1);

    // 2. Profile + train a bundle for a phantom scenario, through the same
    //    registry-resolved path the CLI uses.
    let sc = reg.by_id("PhantomX1/cpu/1L+2S/fp32").expect("registered scenario");
    let train = nas_graphs(41, 12);
    let profiles = profile_set(&sc, &train, 41, 2);
    let bundle =
        PredictorBundle::train(&sc, &profiles, Method::Gbdt, DeductionMode::Full, 41).unwrap();
    let pred = bundle.to_predictor().expect("in-memory predictor");

    // 3. Serialize, then reload in a "fresh process": nothing but the
    //    bundle file — no registry, no spec on disk.
    let path = std::env::temp_dir()
        .join(format!("edgelat_phantom_bundle_{}.json", std::process::id()));
    bundle.save(&path).expect("save");
    drop(reg);
    let reloaded = PredictorBundle::load(&path).expect("v3 bundle loads with no spec anywhere");
    assert_eq!(reloaded.scenario_id(), "PhantomX1/cpu/1L+2S/fp32");
    assert_eq!(reloaded.scenario.soc.gpu.name, "Adreno 660");
    assert!(Registry::builtin().by_id("PhantomX1/cpu/1L+2S/fp32").is_none());

    // 4. Serve a batch from the loaded engine; bit-identical to the
    //    in-memory predictor trained before serialization.
    let engine = EngineBuilder::new().bundle(reloaded).threads(2).build().expect("engine");
    let probes = nas_graphs(77, 6);
    let reqs: Vec<PredictRequest> =
        probes.iter().map(|g| PredictRequest::new(g, "PhantomX1/cpu/1L+2S/fp32")).collect();
    for (g, slot) in probes.iter().zip(engine.predict_batch(&reqs)) {
        let resp = slot.expect("batch slot served");
        assert_eq!(resp.e2e_ms.to_bits(), pred.predict(g).to_bits(), "{}", g.name);
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn custom_device_searches_alongside_builtin_scenarios() {
    // Multi-scenario NAS search over a registered custom device next to a
    // builtin one, both served by one engine.
    let mut reg = Registry::with_builtin();
    reg.load_spec_json(PHANTOM_SPEC).unwrap();
    let ids = ["PhantomX1/cpu/1L/fp32", "Snapdragon855/cpu/1L/fp32"];
    let train = nas_graphs(90, 10);
    let mut builder = EngineBuilder::new();
    for id in ids {
        let sc = reg.by_id(id).expect("registered scenario");
        let profiles = profile_set(&sc, &train, 90, 2);
        builder = builder.bundle(
            PredictorBundle::train(&sc, &profiles, Method::Lasso, DeductionMode::Full, 90)
                .unwrap(),
        );
    }
    let engine = builder.threads(2).build().unwrap();
    let mut cfg = edgelat::search::SearchConfig::quick();
    cfg.population = 8;
    cfg.generations = 2;
    let ids: Vec<String> = ids.iter().map(|s| s.to_string()).collect();
    let outcome = edgelat::search::run(&engine, &ids, &cfg).expect("search over custom device");
    assert_eq!(outcome.scenarios.len(), 2);
    assert_eq!(outcome.scenarios[0].scenario_id, "PhantomX1/cpu/1L/fp32");
    assert!(outcome.scenarios.iter().all(|s| !s.front.is_empty()));
    // Two scenarios share gen 0, so the cross-device summary exists.
    assert_eq!(outcome.rank_correlation.len(), 1);
}

#[test]
fn builtin_specs_roundtrip_reproduces_all_72_scenarios_and_plans() {
    // Serialize every builtin spec to JSON text and rebuild a registry
    // from nothing but that text.
    let builtin = Registry::builtin();
    let mut rebuilt = Registry::new();
    for spec in builtin.specs() {
        rebuilt.load_spec_json(&spec.to_json().to_string()).expect("spec text re-registers");
    }
    assert_eq!(rebuilt.scenario_count(), 72);

    // Ids, order, combos, and SoC parameters reproduce exactly.
    for (a, b) in builtin.specs().iter().zip(rebuilt.specs()) {
        assert_eq!(a.combos, b.combos, "{}", a.soc.name);
        assert_eq!(a.soc, b.soc, "{}", a.soc.name);
    }
    let probe = nas_graphs(7, 1).pop().unwrap();
    for (a, b) in builtin.all().iter().zip(rebuilt.all()) {
        assert_eq!(a.id, b.id);
        // Lowered plans are bit-identical: same buckets, same feature
        // rows, for every scenario and the same probe graph.
        let pa = plan::lower(a, DeductionMode::Full, &probe);
        let pb = plan::lower(b, DeductionMode::Full, &probe);
        assert_eq!(pa.len(), pb.len(), "{}", a.id);
        for i in 0..pa.len() {
            assert_eq!(pa.bucket(i), pb.bucket(i), "{} unit {i}", a.id);
            let (ra, rb) = (pa.row(i), pb.row(i));
            assert_eq!(ra.len(), rb.len());
            for (x, y) in ra.iter().zip(rb) {
                assert_eq!(x.to_bits(), y.to_bits(), "{} unit {i}", a.id);
            }
        }
    }
}

#[test]
fn committed_example_spec_registers_and_profiles() {
    let text = std::fs::read_to_string(repo_path("examples/specs/custom_soc.json"))
        .expect("committed example spec");
    let mut reg = Registry::with_builtin();
    let name = reg.load_spec_json(&text).expect("example spec registers");
    assert_eq!(name, "Dimensity700");
    // The registry-threaded profiling path works for the new device and
    // fails typed for unknown ids.
    let g = nas_graphs(3, 1).pop().unwrap();
    let p = profile_by_id(&reg, "Dimensity700/gpu", &g, 3, 2).expect("profiles custom gpu");
    assert!(p.end_to_end_ms > 0.0);
    assert_eq!(
        profile_by_id(&reg, "Dimensity700/npu", &g, 3, 2).unwrap_err(),
        ScenarioError::UnknownScenario("Dimensity700/npu".into())
    );
}

#[test]
fn bundle_io_errors_name_the_path() {
    let missing = "/definitely/not/a/real/dir/bundle.json";
    let err = PredictorBundle::load(missing).unwrap_err();
    assert!(err.to_string().contains(missing), "{err}");
    // The builder's file path reports the same way.
    let err = EngineBuilder::new().bundle_file(missing).unwrap_err();
    assert!(err.to_string().contains(missing), "{err}");
    // Write failures too.
    let sc = edgelat::scenario::one_large_core("HelioP35").unwrap();
    let profiles = profile_set(&sc, &nas_graphs(5, 4), 5, 1);
    let bundle =
        PredictorBundle::train(&sc, &profiles, Method::Lasso, DeductionMode::Full, 5).unwrap();
    let unwritable = "/definitely/not/a/real/dir/out.json";
    let err = bundle.save(unwritable).unwrap_err();
    assert!(err.to_string().contains(unwritable), "{err}");
}
