//! Integration: the contention- and batch-aware scenario universe end to
//! end — the acceptance contract of the workload subsystem.
//!
//! (1) Registering workload presets never perturbs the paper's 72 isolated
//! scenarios: ids, lowered plans, and trained predictions stay
//! bit-identical to the builtin registry's, while the cross-product
//! universe exceeds 200 scenarios. (2) A bundle for a never-seen
//! (sampled SoC × sampled workload) pair round-trips losslessly through
//! both the JSON and binary encodings — the descriptors travel inside the
//! bundle, no registry needed on the loading side. (3) The serve daemon
//! answers that workload-qualified bundle over TCP bit-identically to
//! calling `predict_batch` in-process.

use edgelat::device::{sample_specs, sample_workloads};
use edgelat::engine::{binfmt, EngineBuilder, PredictRequest, PredictorBundle};
use edgelat::features::WORKLOAD_FEATURE_DIM;
use edgelat::framework::{DeductionMode, ScenarioPredictor};
use edgelat::graph::Graph;
use edgelat::plan;
use edgelat::predict::Method;
use edgelat::profiler::profile_set;
use edgelat::scenario::Registry;
use edgelat::serve::{protocol, BundleFleet, ServeConfig, Server};
use edgelat::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

fn dataset(seed: u64, n: usize) -> Vec<Graph> {
    edgelat::nas::sample_dataset(seed, n).into_iter().map(|a| a.graph).collect()
}

#[test]
fn workload_registration_preserves_the_72_builtin_scenarios_bit_exactly() {
    let base = Registry::builtin();
    let mut reg = Registry::with_builtin();
    reg.register_builtin_workloads().unwrap();
    // Three presets cross every isolated scenario: 72 × (1 + 3).
    assert_eq!(reg.scenario_count(), 288);
    assert!(reg.scenario_count() > 200, "the issue's universe floor");
    assert_eq!(reg.isolated_count(), 72);
    assert_eq!(reg.contended_count(), 216);
    assert_eq!(reg.workload_count(), 3);

    let g = edgelat::zoo::mobilenets::mobilenet_v2(0.5);
    let wl_name = &edgelat::workload::builtin_presets()[0].name;
    for (a, b) in base.all().iter().zip(reg.all().iter().take(72)) {
        // Same ids in the same order, still isolated.
        assert_eq!(a.id, b.id);
        assert!(b.workload.is_none(), "{}", b.id);
        assert_eq!(**a, **b, "{}: scenario drifted under workload registration", a.id);
        // Lowered plans are bit-identical — same buckets, same rows, no
        // workload columns appended to the isolated path.
        let pa = plan::lower(a, DeductionMode::Full, &g);
        let pb = plan::lower(b, DeductionMode::Full, &g);
        assert_eq!(pa.len(), pb.len(), "{}", a.id);
        for i in 0..pa.len() {
            assert_eq!(pa.bucket(i), pb.bucket(i), "{} unit {i}", a.id);
            let (ra, rb) = (pa.row(i), pb.row(i));
            assert_eq!(ra.len(), rb.len(), "{} unit {i}", a.id);
            for (x, y) in ra.iter().zip(rb) {
                assert_eq!(x.to_bits(), y.to_bits(), "{} unit {i}", a.id);
            }
        }
        // The qualified counterpart exists and its rows grow by exactly
        // the workload feature block.
        let q = reg.by_id(&format!("{}@{wl_name}", a.id)).expect("qualified id enumerates");
        let pq = plan::lower(&q, DeductionMode::Full, &g);
        assert_eq!(pq.len(), pa.len(), "{}", q.id);
        for i in 0..pq.len() {
            assert_eq!(pq.row(i).len(), pa.row(i).len() + WORKLOAD_FEATURE_DIM, "{}", q.id);
        }
    }

    // Predictions through a registry that knows about workloads are
    // bit-identical to the builtin path for an isolated scenario.
    let train = dataset(0x5eed, 6);
    let probes = dataset(0x9e77, 3);
    let id = "Snapdragon855/cpu/1L/fp32";
    let sc_a = base.resolve(id).unwrap();
    let sc_b = reg.resolve(id).unwrap();
    let pred_a = ScenarioPredictor::train_from(
        &sc_a,
        &profile_set(&sc_a, &train, 11, 2),
        Method::Lasso,
        DeductionMode::Full,
        3,
        None,
    );
    let pred_b = ScenarioPredictor::train_from(
        &sc_b,
        &profile_set(&sc_b, &train, 11, 2),
        Method::Lasso,
        DeductionMode::Full,
        3,
        None,
    );
    for g in &probes {
        let (x, y) = (pred_a.predict(g), pred_b.predict(g));
        assert_eq!(x.to_bits(), y.to_bits(), "{}: {x} vs {y}", g.name);
    }
}

#[test]
fn never_seen_soc_workload_bundle_roundtrips_and_serves_bit_identically() {
    // A SoC and a workload the builtin universe has never heard of,
    // straight from the fleet samplers.
    let spec = sample_specs(0xed9e, 1).pop().unwrap();
    let wl = sample_workloads(0xed9e, 1).pop().unwrap();
    let mut reg = Registry::new();
    reg.register_workload(wl.clone()).unwrap();
    reg.register_soc(spec.clone()).unwrap();
    let sc = reg
        .one_large_core(&spec.soc.name)
        .unwrap()
        .with_workload(Arc::new(wl.clone()));
    // The qualified pair is enumerated by the cross-product, not just
    // constructible by hand.
    assert_eq!(reg.by_id(&sc.id).as_deref(), Some(&sc), "{}", sc.id);
    assert!(sc.id.ends_with(&format!("@{}", wl.name)), "{}", sc.id);

    let train = dataset(0xfee1, 8);
    let profiles = profile_set(&sc, &train, 0xfee1, 2);
    let pred =
        ScenarioPredictor::train_from(&sc, &profiles, Method::Gbdt, DeductionMode::Full, 7, None);
    let bundle = PredictorBundle::from_predictor(&pred).unwrap();
    let probes = dataset(0xadd1, 4);
    let expected: Vec<f64> = {
        let p = bundle.to_predictor().expect("workload bundle assembles");
        probes.iter().map(|g| p.predict(g)).collect()
    };

    // --- JSON round-trip: v4, workload descriptor embedded, byte-stable.
    let j = bundle.to_json();
    assert_eq!(j.req_usize("version").unwrap(), 4);
    assert_eq!(j.req("workload").unwrap().req_str("name").unwrap(), wl.name);
    let from_json = PredictorBundle::from_json(&j).expect("v4 workload bundle loads");
    assert_eq!(from_json.scenario, bundle.scenario);
    assert_eq!(
        from_json.to_json().to_string(),
        j.to_string(),
        "JSON re-serialization must be byte-stable"
    );

    // --- Binary round-trip: the conditional workload version, lossless.
    let bytes = bundle.to_bin_bytes().unwrap();
    let info = binfmt::inspect_bin(&bytes).expect("binary bundle inspects");
    assert_eq!(info.req_usize("version").unwrap(), binfmt::BIN_VERSION_WORKLOAD as usize);
    assert_eq!(info.req_str("scenario").unwrap(), sc.id);
    let from_bin = PredictorBundle::from_bin_bytes(&bytes).expect("binary decodes");
    assert_eq!(from_bin.scenario, bundle.scenario);
    assert_eq!(
        from_bin.to_json().to_string(),
        j.to_string(),
        "binary decode must reproduce the JSON document exactly"
    );

    // Both decoded copies predict bit-identically to the original.
    for (back, enc) in [(&from_json, "json"), (&from_bin, "bin")] {
        let p = back.to_predictor().expect("decoded bundle assembles");
        for (g, want) in probes.iter().zip(&expected) {
            let got = p.predict(g);
            assert_eq!(got.to_bits(), want.to_bits(), "{enc} {}: {got} vs {want}", g.name);
        }
    }

    // An isolated bundle for the same never-seen SoC stays on the v1
    // binary encoding — byte-compatibility for the existing fleet.
    let sc_iso = reg.one_large_core(&spec.soc.name).unwrap();
    let iso_pred = ScenarioPredictor::train_from(
        &sc_iso,
        &profile_set(&sc_iso, &train, 0xfee1, 2),
        Method::Gbdt,
        DeductionMode::Full,
        7,
        None,
    );
    let iso_bytes = PredictorBundle::from_predictor(&iso_pred).unwrap().to_bin_bytes().unwrap();
    let iso_info = binfmt::inspect_bin(&iso_bytes).unwrap();
    assert_eq!(iso_info.req_usize("version").unwrap(), binfmt::BIN_VERSION as usize);

    // --- Serve: the daemon answers the workload-qualified id over TCP
    // bit-identically to in-process predict_batch on the same bundle.
    let dir = std::env::temp_dir().join(format!("edgelat_wl_serve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    bundle.save_bin(dir.join("contended.bin")).unwrap();
    let engine = EngineBuilder::new().bundle(bundle).threads(2).build().expect("engine");
    let reqs: Vec<PredictRequest> =
        probes.iter().map(|g| PredictRequest::new(g, sc.id.clone())).collect();
    let in_process: Vec<f64> = engine
        .predict_batch(&reqs)
        .into_iter()
        .map(|r| r.expect("in-process serves the qualified id").e2e_ms)
        .collect();

    let fleet = BundleFleet::load(&dir, Some(2)).expect("fleet loads the .bin bundle");
    assert_eq!(fleet.scenario_ids(), vec![sc.id.clone()]);
    let srv = Server::bind("127.0.0.1:0".parse().unwrap(), ServeConfig::default(), fleet)
        .expect("bind");
    let addr = srv.addr();
    let daemon = std::thread::spawn(move || srv.run());

    let mut s = TcpStream::connect(addr).expect("connect to daemon");
    s.set_nodelay(true).ok();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut rd = BufReader::new(s.try_clone().unwrap());
    for (i, g) in probes.iter().enumerate() {
        let line = protocol::predict_line(&sc.id, g, Some(i as u64), None, false);
        s.write_all(line.as_bytes()).unwrap();
        s.write_all(b"\n").unwrap();
        s.flush().unwrap();
        let mut reply = String::new();
        rd.read_line(&mut reply).expect("reply line");
        let r = Json::parse(reply.trim()).expect("reply is valid JSON");
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string());
        assert_eq!(r.req_usize("id").unwrap(), i);
        assert_eq!(r.req_str("scenario").unwrap(), sc.id);
        let got = r.req_f64("e2e_ms").unwrap();
        assert_eq!(
            got.to_bits(),
            in_process[i].to_bits(),
            "probe {i}: daemon {got} vs in-process {}",
            in_process[i]
        );
    }
    drop(s);
    drop(rd);

    let j = edgelat::serve::loadgen::request_drain(addr).expect("drain");
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
    daemon.join().expect("daemon thread").expect("clean drain exits without error");
    let _ = std::fs::remove_dir_all(&dir);
}
