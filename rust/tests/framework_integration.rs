//! Cross-module integration: the full paper pipeline (Sections 3-5 chained)
//! at small scale — dataset generation → profiling → training → prediction
//! → evaluation — plus reproduction of the paper's headline *qualitative*
//! findings on the simulated substrate (the calibration targets of
//! DESIGN.md §7).

use edgelat::device::{soc_by_name, CoreCombo, DataRep, Target};
use edgelat::framework::{evaluate, DeductionMode, ScenarioPredictor};
use edgelat::predict::Method;
use edgelat::profiler::{profile, profile_set};
use edgelat::scenario::Scenario;
use edgelat::tflite::CompileOptions;
use edgelat::util::mean;

/// Section 1's motivating crossover: MobileNet (w0.75) and ResNet18 (w0.25)
/// are comparable on one medium core but diverge with three medium cores
/// (paper: 28.4 vs 28.1 ms, then 11.8 vs 14.7 ms — 24.6% apart).
#[test]
fn mobilenet_resnet_multicore_crossover() {
    let soc = soc_by_name("Snapdragon855").unwrap();
    let mn = edgelat::zoo::mobilenets::mobilenet_v1(0.75);
    let rn = edgelat::zoo::resnets::resnet(18, 0.25);
    let e2e = |g, counts: Vec<usize>| {
        let t = Target::Cpu { combo: CoreCombo::new(counts), rep: DataRep::Fp32 };
        let runs: Vec<f64> =
            (0..7).map(|i| edgelat::device::run(&soc, g, &t, 3, i).end_to_end_ms).collect();
        edgelat::util::median(&runs)
    };
    let (mn1, rn1) = (e2e(&mn, vec![0, 1, 0]), e2e(&rn, vec![0, 1, 0]));
    let (mn3, rn3) = (e2e(&mn, vec![0, 3, 0]), e2e(&rn, vec![0, 3, 0]));
    // Same latency class on one medium core (paper: 28.4 vs 28.1 ms; our
    // substrate keeps them within ~2x of each other).
    let gap1 = (mn1 - rn1).abs() / rn1.min(mn1);
    assert!(gap1 < 1.2, "1-core gap {gap1:.2}: mn={mn1:.1} rn={rn1:.1}");
    // The paper's point: multicore *speedups vary across architectures*
    // (24.6% divergence at 3 cores). Require a clear scaling difference.
    let (smn, srn) = (mn1 / mn3, rn1 / rn3);
    assert!(
        (smn - srn).abs() / srn.min(smn) > 0.02,
        "3-core speedups too similar: mn {smn:.2}x vs rn {srn:.2}x"
    );
    assert!(smn > 1.4 && srn > 1.4, "both should still benefit: {smn:.2} {srn:.2}");
}

/// Insight 3 calibration: fusion yields ≈1.2x mean end-to-end speedup and
/// >40% kernel-count reduction across the zoo.
#[test]
fn fusion_speedup_band() {
    let zoo: Vec<_> = edgelat::zoo::all_graphs().into_iter().take(30).collect();
    let mut speedups = Vec::new();
    let mut reductions = Vec::new();
    for soc in edgelat::device::socs() {
        let on = Scenario::gpu(&soc);
        let off = Scenario {
            target: Target::Gpu { options: CompileOptions { fusion: false, ..Default::default() } },
            id: format!("{}/gpu/nofusion", soc.name),
            soc: soc.clone(),
            workload: None,
        };
        for g in &zoo {
            let a = profile(&off, g, 1, 3).end_to_end_ms;
            let b = profile(&on, g, 1, 3).end_to_end_ms;
            speedups.push(a / b);
            let k = edgelat::tflite::compile(g, soc.gpu.kind, CompileOptions::default())
                .kernels
                .len();
            reductions.push(1.0 - k as f64 / g.nodes.len() as f64);
        }
    }
    let m = mean(&speedups);
    assert!((1.08..1.45).contains(&m), "mean fusion speedup {m:.3} (paper: 1.22x)");
    let r = mean(&reductions);
    assert!(r > 0.40, "mean kernel reduction {r:.2} (paper: >45%)");
}

/// Insight 2 calibration: element-wise ops degrade ~2-3x under int8 on the
/// flagship SoCs while conv-heavy end-to-end still speeds up.
#[test]
fn quantization_elementwise_degradation_band() {
    for soc_name in ["Snapdragon855", "Exynos9820"] {
        let soc = soc_by_name(soc_name).unwrap();
        let g = edgelat::zoo::resnets::resnet(18, 1.0); // has residual adds
        let mut counts = vec![0; soc.clusters.len()];
        counts[0] = 1;
        let f = Scenario::cpu(&soc, counts.clone(), DataRep::Fp32).unwrap();
        let q = Scenario::cpu(&soc, counts, DataRep::Int8).unwrap();
        let pf = profile(&f, &g, 5, 5);
        let pq = profile(&q, &g, 5, 5);
        let ew = |p: &edgelat::profiler::ModelProfile| -> f64 {
            p.ops
                .iter()
                .filter(|o| o.bucket == "ElementWise")
                .map(|o| o.latency_ms)
                .sum()
        };
        let ratio = ew(&pq) / ew(&pf);
        assert!(
            (1.8..3.5).contains(&ratio),
            "{soc_name}: element-wise int8/fp32 ratio {ratio:.2} (paper: ~2.55x)"
        );
        assert!(pq.end_to_end_ms < pf.end_to_end_ms, "{soc_name}: int8 should win overall");
    }
}

/// The default-NAS pipeline end-to-end: GBDT single-digit MAPE in
/// distribution; Lasso worse than trees in distribution (Fig 14 ordering).
#[test]
fn default_setting_pipeline_ordering() {
    let sc = edgelat::scenario::one_large_core("Snapdragon710").unwrap();
    let graphs: Vec<_> =
        edgelat::nas::sample_dataset(77, 80).into_iter().map(|a| a.graph).collect();
    let profiles = profile_set(&sc, &graphs, 77, 5);
    let (tr_p, te_p) = profiles.split_at(60);
    let te_g = &graphs[60..];
    let mut errs = std::collections::HashMap::new();
    for m in Method::native() {
        let pred = ScenarioPredictor::train_from(&sc, tr_p, *m, DeductionMode::Full, 1, None);
        let ev = evaluate(&pred, te_g, te_p);
        errs.insert(m.name(), ev.end_to_end_mape);
    }
    assert!(errs["GBDT"] < 0.10, "GBDT {:.3}", errs["GBDT"]);
    assert!(errs["GBDT"] <= errs["Lasso"], "{errs:?}");
}

/// Dataset shift (Section 5.3): with only 30 training NAs, Lasso transfers
/// to the real-world zoo at least as well as it does with complex methods'
/// *small-data* fits (the paper's Section 5.5 headline).
#[test]
fn lasso_small_data_transfers_to_zoo() {
    let sc = edgelat::scenario::one_large_core("HelioP35").unwrap();
    let train_g: Vec<_> =
        edgelat::nas::sample_dataset(2022, 30).into_iter().map(|a| a.graph).collect();
    let tr_p = profile_set(&sc, &train_g, 2022, 5);
    let zoo: Vec<_> = edgelat::zoo::all_graphs().into_iter().take(40).collect();
    let te_p = profile_set(&sc, &zoo, 2022, 5);
    let lasso = ScenarioPredictor::train_from(&sc, &tr_p, Method::Lasso, DeductionMode::Full, 1, None);
    let ev = evaluate(&lasso, &zoo, &te_p);
    // The simulated substrate's narrow-channel efficiency curve is harder
    // on a linear model than the paper's devices; the qualitative claim
    // (a 30-NA Lasso transfers usably to unseen real-world NAs) holds.
    assert!(
        ev.end_to_end_mape < 0.30,
        "Lasso@30 on zoo: {:.3} (paper band ~5-10%)",
        ev.end_to_end_mape
    );
}

/// Model files round-trip through the whole prediction path: predicting
/// from a serialized+reloaded file equals predicting from the live graph.
#[test]
fn prediction_from_model_file_identical() {
    let sc = edgelat::scenario::one_large_core("Snapdragon855").unwrap();
    let train_g: Vec<_> =
        edgelat::nas::sample_dataset(9, 40).into_iter().map(|a| a.graph).collect();
    let tr_p = profile_set(&sc, &train_g, 9, 3);
    let pred = ScenarioPredictor::train_from(&sc, &tr_p, Method::Gbdt, DeductionMode::Full, 1, None);
    let g = edgelat::zoo::by_name("mobilenetv2_wd100").unwrap();
    let file = edgelat::graph::modelfile::to_model_file(&g);
    let g2 = edgelat::graph::modelfile::from_model_file(&file).unwrap();
    assert_eq!(pred.predict(&g), pred.predict(&g2));
}

/// GPU scenario: the kernel deduction (Section 4.1) exactly matches what
/// the simulated device executed for every zoo model on every GPU.
#[test]
fn kernel_deduction_matches_device_on_all_gpus() {
    let zoo: Vec<_> = edgelat::zoo::all_graphs().into_iter().take(25).collect();
    for soc in edgelat::device::socs() {
        let sc = Scenario::gpu(&soc);
        for g in &zoo {
            let p = profile(&sc, g, 4, 1);
            let deduced = edgelat::tflite::compile(g, soc.gpu.kind, CompileOptions::default());
            assert_eq!(
                deduced.kernels.len(),
                p.ops.len(),
                "{} on {}",
                g.name,
                soc.gpu.name
            );
            for (k, o) in deduced.kernels.iter().zip(&p.ops) {
                assert_eq!(k.impl_, o.kernel, "{} on {}", g.name, soc.gpu.name);
            }
        }
    }
}
