//! Integration: the `edgelat serve` daemon end to end over real TCP.
//!
//! Boots the daemon on an ephemeral port around a two-scenario bundle
//! fleet and asserts the acceptance contract of the serving subsystem:
//! 64 concurrent pipelined requests across both scenarios answered
//! bit-identically to calling `predict_batch` in-process on the same
//! bundles; malformed lines get typed error replies on a connection that
//! keeps working; a hot reload mid-stream never drops or corrupts an
//! in-flight response; `stats` reports real counters; and `drain` answers
//! everything accepted and exits cleanly with a matching summary.

use edgelat::engine::{EngineBuilder, LatencyEngine, PredictRequest, PredictorBundle};
use edgelat::framework::{DeductionMode, ScenarioPredictor};
use edgelat::graph::Graph;
use edgelat::predict::Method;
use edgelat::profiler::profile_set;
use edgelat::scenario::Scenario;
use edgelat::serve::{protocol, BundleFleet, ServeConfig, Server};
use edgelat::util::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

const CPU_ID: &str = "Snapdragon855/cpu/1L/fp32";
const GPU_ID: &str = "Snapdragon855/gpu";

/// Train the two tiny bundles once and save them as a fleet directory.
fn make_bundle_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("edgelat_serve_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("mkdir fleet dir");
    let train: Vec<Graph> =
        edgelat::nas::sample_dataset(42, 8).into_iter().map(|a| a.graph).collect();
    let sc_cpu = edgelat::scenario::one_large_core("Snapdragon855").unwrap();
    let cpu = ScenarioPredictor::train_from(
        &sc_cpu,
        &profile_set(&sc_cpu, &train, 42, 2),
        Method::Gbdt,
        DeductionMode::Full,
        42,
        None,
    );
    PredictorBundle::from_predictor(&cpu).unwrap().save(dir.join("cpu.json")).unwrap();
    let soc = edgelat::device::soc_by_name("Snapdragon855").unwrap();
    let sc_gpu = Scenario::gpu(&soc);
    let gpu = ScenarioPredictor::train_from(
        &sc_gpu,
        &profile_set(&sc_gpu, &train, 42, 2),
        Method::Lasso,
        DeductionMode::Full,
        42,
        None,
    );
    PredictorBundle::from_predictor(&gpu).unwrap().save(dir.join("gpu.json")).unwrap();
    dir
}

/// The in-process ground truth: an engine built from the same files.
fn reference_engine(dir: &Path) -> LatencyEngine {
    EngineBuilder::new()
        .bundle_file(dir.join("cpu.json"))
        .unwrap()
        .bundle_file(dir.join("gpu.json"))
        .unwrap()
        .threads(2)
        .build()
        .unwrap()
}

fn connect(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).expect("connect to daemon");
    s.set_nodelay(true).ok();
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s
}

fn send_line(s: &mut TcpStream, line: &str) {
    s.write_all(line.as_bytes()).unwrap();
    s.write_all(b"\n").unwrap();
    s.flush().unwrap();
}

fn read_reply(rd: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    rd.read_line(&mut line).expect("reply line");
    assert!(!line.is_empty(), "daemon closed the connection instead of replying");
    Json::parse(line.trim()).expect("reply is valid JSON")
}

#[test]
fn daemon_serves_reloads_and_drains_bit_identically() {
    let dir = make_bundle_dir("e2e");
    let reference = reference_engine(&dir);
    let workload: Vec<Graph> =
        edgelat::nas::sample_dataset(777, 8).into_iter().map(|a| a.graph).collect();
    let ids = [CPU_ID, GPU_ID];
    // Ground truth through the exact API the daemon uses.
    let reqs: Vec<PredictRequest> = workload
        .iter()
        .flat_map(|g| ids.iter().map(move |id| PredictRequest::new(g, id.to_string())))
        .collect();
    let expected: Vec<f64> = reference
        .predict_batch(&reqs)
        .into_iter()
        .map(|r| r.expect("reference serves").e2e_ms)
        .collect();
    let expect_ms = |graph_i: usize, sc_i: usize| expected[graph_i * 2 + sc_i];

    let fleet = BundleFleet::load(&dir, Some(2)).expect("fleet");
    let cfg = ServeConfig {
        max_batch: 16,
        max_wait: Duration::from_micros(2000),
        ..ServeConfig::default()
    };
    let srv = Server::bind("127.0.0.1:0".parse().unwrap(), cfg, fleet).expect("bind");
    let addr = srv.addr();
    assert_ne!(addr.port(), 0, "ephemeral port resolved");
    let daemon = std::thread::spawn(move || srv.run());

    // --- Wave 1: 16 connections x 4 pipelined requests = 64 concurrent
    // requests across both scenarios, replies in order, bit-identical.
    std::thread::scope(|scope| {
        for c in 0..16usize {
            let (workload, expected_ok) = (&workload, &expect_ms);
            scope.spawn(move || {
                let mut s = connect(addr);
                let mut rd = BufReader::new(s.try_clone().unwrap());
                for k in 0..4usize {
                    let graph_i = (c * 4 + k) % workload.len();
                    let sc_i = (c + k) % 2;
                    let line = protocol::predict_line(
                        ids[sc_i],
                        &workload[graph_i],
                        Some((c * 100 + k) as u64),
                        None,
                        false,
                    );
                    send_line(&mut s, &line);
                }
                for k in 0..4usize {
                    let graph_i = (c * 4 + k) % workload.len();
                    let sc_i = (c + k) % 2;
                    let j = read_reply(&mut rd);
                    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{}", j.to_string());
                    // In-order delivery: reply k echoes request k's id.
                    assert_eq!(j.req_usize("id").unwrap(), c * 100 + k);
                    assert_eq!(j.req_str("scenario").unwrap(), ids[sc_i]);
                    let got = j.req_f64("e2e_ms").unwrap();
                    let want = expected_ok(graph_i, sc_i);
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "client {c} req {k}: {got} vs direct {want}"
                    );
                }
            });
        }
    });

    // --- Malformed input: typed error replies, connection survives.
    {
        let mut s = connect(addr);
        let mut rd = BufReader::new(s.try_clone().unwrap());
        send_line(&mut s, "this is not json");
        let j = read_reply(&mut rd);
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.req("error").unwrap().req_str("code").unwrap(), "bad_json");
        // Unknown scenario: accepted by the wire layer, fails per-slot in
        // the engine with a typed code and the id echoed.
        let line = protocol::predict_line("NoSuchSoc/gpu", &workload[0], Some(9001), None, false);
        send_line(&mut s, &line);
        let j = read_reply(&mut rd);
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.req("error").unwrap().req_str("code").unwrap(), "no_predictor");
        assert_eq!(j.req_usize("id").unwrap(), 9001);
        // The same connection still serves a valid request afterwards.
        let line = protocol::predict_line(CPU_ID, &workload[0], Some(9002), None, true);
        send_line(&mut s, &line);
        let j = read_reply(&mut rd);
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{}", j.to_string());
        assert_eq!(j.req_f64("e2e_ms").unwrap().to_bits(), expect_ms(0, 0).to_bits());
        assert!(j.req("per_unit").unwrap().as_arr().unwrap().len() > 1, "detail decomposition");
    }

    // --- Hot reload mid-stream: 4 clients pump pipelined predictions
    // while reloads swap the engine twice; no reply is dropped, every
    // reply stays bit-identical (same bundles on disk), and the
    // generation advances.
    std::thread::scope(|scope| {
        for c in 0..4usize {
            let (workload, expected_ok) = (&workload, &expect_ms);
            scope.spawn(move || {
                let mut s = connect(addr);
                let mut rd = BufReader::new(s.try_clone().unwrap());
                for k in 0..10usize {
                    let graph_i = (c + k) % workload.len();
                    let sc_i = k % 2;
                    let line = protocol::predict_line(
                        ids[sc_i],
                        &workload[graph_i],
                        Some((7000 + c * 10 + k) as u64),
                        None,
                        false,
                    );
                    send_line(&mut s, &line);
                    let j = read_reply(&mut rd);
                    assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{}", j.to_string());
                    let got = j.req_f64("e2e_ms").unwrap();
                    assert_eq!(
                        got.to_bits(),
                        expected_ok(graph_i, sc_i).to_bits(),
                        "reload corrupted an in-flight response (client {c}, req {k})"
                    );
                }
            });
        }
        scope.spawn(move || {
            for _ in 0..2 {
                std::thread::sleep(Duration::from_millis(20));
                let j = edgelat::serve::loadgen::request_reload(addr).expect("reload");
                assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{}", j.to_string());
                assert_eq!(j.req_usize("bundles").unwrap(), 2);
            }
        });
    });

    // --- Stats reflect what happened.
    let stats = edgelat::serve::loadgen::request_stats(addr).expect("stats");
    assert_eq!(stats.req_usize("generation").unwrap(), 3, "two reloads happened");
    let scenarios = stats.req("scenarios").unwrap().as_arr().unwrap();
    assert_eq!(scenarios.len(), 2);
    let requests = stats.req("requests").unwrap();
    // 64 (wave 1) + 2 (malformed section predicts) + 40 (reload wave).
    assert_eq!(requests.req_usize("predict").unwrap(), 106);
    assert_eq!(requests.req_usize("ok").unwrap(), 105);
    assert_eq!(requests.req_usize("errors").unwrap(), 1, "the unknown-scenario slot");
    assert_eq!(requests.req_usize("malformed").unwrap(), 1);
    assert!(stats.req("batches").unwrap().req_f64("count").unwrap() >= 1.0);
    assert!(stats.req("batches").unwrap().req_f64("mean").unwrap() >= 1.0);
    let hit_rate = stats.req("plan_cache").unwrap().req_f64("hit_rate").unwrap();
    assert!((0.0..=1.0).contains(&hit_rate), "hit_rate={hit_rate}");
    assert!(hit_rate > 0.0, "repeated graphs must hit the plan cache");
    assert!(stats.req("service_us").unwrap().req_f64("p99").unwrap() > 0.0);

    // --- Drain: acknowledged, then the daemon exits cleanly with a
    // summary that matches the stats.
    let j = edgelat::serve::loadgen::request_drain(addr).expect("drain");
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(j.req_usize("served").unwrap(), 105);
    let summary = daemon
        .join()
        .expect("daemon thread")
        .expect("clean drain exits without error");
    assert_eq!(summary.served_ok, 105);
    assert_eq!(summary.served_err, 1);
    assert_eq!(summary.malformed, 1);
    assert!(summary.batches >= 1);
    assert!(summary.mean_batch >= 1.0);
    assert_eq!(summary.reloads, 2);

    // A drained daemon is gone: new connections are refused (or reset).
    std::thread::sleep(Duration::from_millis(50));
    assert!(TcpStream::connect(addr).is_err(), "listener closed after drain");
    let _ = std::fs::remove_dir_all(&dir);
}
