//! Profiling harness — the analogue of the TFLite Model Benchmark Tool (CPU)
//! and OpenCL command-queue timestamp collection (GPU) used in Section 4.3.1.
//! Repeats each inference, aggregates per-op medians, and assembles training
//! datasets for the per-op-type predictors.

use crate::device;
use crate::exec_pool::ExecPool;
use crate::graph::Graph;
use crate::plan;
use crate::scenario::{Registry, Scenario, ScenarioError};
use crate::tflite::KernelImpl;
use crate::util::stats;

/// One profiled op (CPU) or kernel (GPU): its predictor bucket, Table 3
/// feature vector, and median measured latency.
#[derive(Debug, Clone)]
pub struct OpRecord {
    pub op: usize,
    pub bucket: String,
    pub kernel: KernelImpl,
    pub features: Vec<f64>,
    pub latency_ms: f64,
}

/// Profile of one model under one scenario.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub model: String,
    pub ops: Vec<OpRecord>,
    /// Median end-to-end latency across runs.
    pub end_to_end_ms: f64,
    /// All end-to-end samples (for variance studies, Fig 32).
    pub samples: Vec<f64>,
}

impl ModelProfile {
    pub fn op_sum_ms(&self) -> f64 {
        self.ops.iter().map(|o| o.latency_ms).sum()
    }

    /// Measured overhead: end-to-end minus op sum (the Fig 10 gap).
    pub fn overhead_ms(&self) -> f64 {
        self.end_to_end_ms - self.op_sum_ms()
    }
}

/// Profile one model: `runs` repetitions, per-op median, end-to-end median.
pub fn profile(sc: &Scenario, g: &Graph, seed: u64, runs: usize) -> ModelProfile {
    assert!(runs >= 1);
    let traces =
        device::exec::run_many_under(&sc.soc, g, &sc.target, sc.workload.as_deref(), seed, runs);
    let n_ops = traces[0].per_op.len();
    let mut ops = Vec::with_capacity(n_ops);
    // Structure is per-graph (identical across runs): lower once through
    // the plan IR — the same deduction the predictors evaluate against, so
    // profiled units and predicted units align by construction.
    let lowered = plan::lower(sc, crate::framework::DeductionMode::Full, g);
    let it = plan::interner();
    debug_assert_eq!(lowered.len(), n_ops);
    for i in 0..n_ops {
        let lat: Vec<f64> = traces.iter().map(|t| t.per_op[i].latency_ms).collect();
        ops.push(OpRecord {
            op: traces[0].per_op[i].op,
            bucket: it.name(lowered.bucket(i)).to_string(),
            kernel: lowered.kernel(i),
            features: lowered.row(i).to_vec(),
            latency_ms: stats::median(&lat),
        });
    }
    let samples: Vec<f64> = traces.iter().map(|t| t.end_to_end_ms).collect();
    ModelProfile {
        model: g.name.clone(),
        ops,
        end_to_end_ms: stats::median(&samples),
        samples,
    }
}

/// Profile a set of models in parallel on a machine-sized [`ExecPool`].
pub fn profile_set(sc: &Scenario, graphs: &[Graph], seed: u64, runs: usize) -> Vec<ModelProfile> {
    profile_set_with(&ExecPool::default(), sc, graphs, seed, runs)
}

/// Profile a set of models on a caller-provided pool. The scenario-sweep
/// prefetcher profiles many scenarios concurrently and hands each one a
/// slice of the machine (`ExecPool::new(1)` = fully sequential).
///
/// Every graph keeps the same per-graph seed derivation as the sequential
/// loop (`profile(sc, g, seed, runs)` is pure per graph), so the result is
/// bit-identical for any thread count — asserted by
/// `profile_set_matches_sequential`.
pub fn profile_set_with(
    pool: &ExecPool,
    sc: &Scenario,
    graphs: &[Graph],
    seed: u64,
    runs: usize,
) -> Vec<ModelProfile> {
    pool.map(graphs, |_, g| profile(sc, g, seed, runs))
}

/// Profile a model under a scenario resolved by id against a [`Registry`]
/// — the registry-threaded entry point (CLI, services, custom devices). An
/// unknown id is a typed error, never a panic.
pub fn profile_by_id(
    reg: &Registry,
    scenario_id: &str,
    g: &Graph,
    seed: u64,
    runs: usize,
) -> Result<ModelProfile, ScenarioError> {
    Ok(profile(&reg.resolve(scenario_id)?, g, seed, runs))
}

/// A per-bucket training dataset: feature rows + latency targets.
#[derive(Debug, Clone, Default)]
pub struct BucketData {
    pub x: Vec<Vec<f64>>,
    pub y: Vec<f64>,
}

/// Group profiled ops into per-bucket datasets (Section 4.2: one model per
/// op type per scenario).
pub fn bucket_datasets(
    profiles: &[ModelProfile],
) -> std::collections::BTreeMap<String, BucketData> {
    let mut map: std::collections::BTreeMap<String, BucketData> = Default::default();
    for p in profiles {
        for o in &p.ops {
            let e = map.entry(o.bucket.clone()).or_default();
            e.x.push(o.features.clone());
            e.y.push(o.latency_ms);
        }
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario;

    #[test]
    fn profile_is_deterministic() {
        let sc = scenario::one_large_core("Snapdragon855").unwrap();
        let g = crate::zoo::mobilenets::mobilenet_v1(0.5);
        let a = profile(&sc, &g, 42, 5);
        let b = profile(&sc, &g, 42, 5);
        assert_eq!(a.end_to_end_ms, b.end_to_end_ms);
        assert_eq!(a.ops.len(), b.ops.len());
    }

    #[test]
    fn gpu_profile_buckets_include_winograd_on_mali_only() {
        let g = crate::zoo::resnets::resnet(16, 1.0);
        let mali = Scenario::gpu(&crate::device::soc_by_name("Exynos9820").unwrap());
        let adreno = Scenario::gpu(&crate::device::soc_by_name("Snapdragon855").unwrap());
        let pm = profile(&mali, &g, 1, 3);
        let pa = profile(&adreno, &g, 1, 3);
        assert!(pm.ops.iter().any(|o| o.bucket == "Winograd"));
        assert!(pa.ops.iter().all(|o| o.bucket != "Winograd"));
    }

    #[test]
    fn overhead_positive_on_average() {
        let sc = Scenario::gpu(&crate::device::soc_by_name("HelioP35").unwrap());
        let g = crate::zoo::mobilenets::mobilenet_v2(0.5);
        let p = profile(&sc, &g, 3, 7);
        assert!(p.overhead_ms() > 0.0);
    }

    #[test]
    fn bucket_datasets_cover_conv() {
        let sc = scenario::one_large_core("HelioP35").unwrap();
        let graphs = vec![
            crate::zoo::mobilenets::mobilenet_v1(0.25),
            crate::zoo::resnets::resnet(10, 1.0),
        ];
        let profiles = profile_set(&sc, &graphs, 2, 3);
        let data = bucket_datasets(&profiles);
        assert!(data.contains_key("Conv2D"));
        assert!(data.contains_key("DepthwiseConv2D"));
        let conv = &data["Conv2D"];
        assert_eq!(conv.x.len(), conv.y.len());
        assert!(conv.x.len() > 10);
        assert!(conv.y.iter().all(|&y| y > 0.0));
    }

    #[test]
    fn profile_set_matches_sequential() {
        let sc = scenario::one_large_core("Snapdragon710").unwrap();
        let graphs = vec![
            crate::zoo::mobilenets::mobilenet_v1(0.25),
            crate::zoo::mobilenets::mobilenet_v1(0.5),
            crate::zoo::mobilenets::mobilenet_v1(0.75),
            crate::zoo::resnets::resnet(10, 1.0),
            crate::zoo::mobilenets::mobilenet_v2(0.5),
        ];
        // Bit-identical across thread counts, not just for end-to-end:
        // every per-op latency, feature row, and raw sample must match the
        // fully sequential pool. The per-graph seed derivation is the same
        // in all cases.
        let seq = profile_set_with(&ExecPool::new(1), &sc, &graphs, 5, 3);
        for pool in [ExecPool::new(3), ExecPool::default()] {
            let par = profile_set_with(&pool, &sc, &graphs, 5, 3);
            assert_eq!(par.len(), seq.len());
            for (p, s) in par.iter().zip(&seq) {
                assert_eq!(p.model, s.model);
                assert_eq!(p.end_to_end_ms.to_bits(), s.end_to_end_ms.to_bits(), "{}", p.model);
                assert_eq!(p.samples.len(), s.samples.len());
                for (a, b) in p.samples.iter().zip(&s.samples) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}", p.model);
                }
                assert_eq!(p.ops.len(), s.ops.len(), "{}", p.model);
                for (po, so) in p.ops.iter().zip(&s.ops) {
                    assert_eq!(po.bucket, so.bucket);
                    assert_eq!(po.latency_ms.to_bits(), so.latency_ms.to_bits());
                    assert_eq!(po.features, so.features);
                }
            }
        }
        // The convenience wrapper (machine-sized pool) agrees too.
        let par = profile_set(&sc, &graphs, 5, 3);
        for (g, p) in graphs.iter().zip(&par) {
            let s = profile(&sc, g, 5, 3);
            assert_eq!(p.end_to_end_ms.to_bits(), s.end_to_end_ms.to_bits(), "{}", g.name);
        }
    }
}
