//! Lasso (Eq. 1 of the paper): linear model with L1 regularization and
//! *nonnegative* weights, minimizing mean square **percentage** error
//! (weighted least squares with weights 1/y_i^2), trained by coordinate
//! descent. The alpha hyperparameter is grid-searched over [1e-5, 1e2].

use crate::predict::{cv, soa, FeatureMatrix, Regressor};
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct Lasso {
    pub weights: Vec<f64>,
    pub intercept: f64,
    pub alpha: f64,
}

impl Lasso {
    /// Coordinate descent for: min_w (1/N) Σ v_i (y_i - b - w·x_i)^2 + α‖w‖₁
    /// with v_i = 1/y_i² and w >= 0; the intercept b is unpenalized.
    ///
    /// Uses the covariance trick: after weighted-centering, precompute the
    /// d×d Gram matrix G = X̃ᵀVX̃ and c = X̃ᵀVỹ once (O(n·d²)); each
    /// coordinate update is then O(d) instead of O(n), so the many passes
    /// needed on correlated Table 3 features are nearly free
    /// (EXPERIMENTS.md §Perf: ~750ms → ~3ms on a Conv2D bucket).
    pub fn fit(x: &[Vec<f64>], y: &[f64], alpha: f64) -> Lasso {
        let n = x.len();
        let d = x[0].len();
        let v: Vec<f64> = y.iter().map(|&yi| 1.0 / (yi * yi).max(1e-18)).collect();
        let vsum: f64 = v.iter().sum();
        // Weighted means (the unpenalized intercept absorbs them).
        let mut mu_x = vec![0.0f64; d];
        let mut mu_y = 0.0;
        for ((xi, &yi), &vi) in x.iter().zip(y).zip(&v) {
            for (m, &xij) in mu_x.iter_mut().zip(xi) {
                *m += vi * xij;
            }
            mu_y += vi * yi;
        }
        for m in &mut mu_x {
            *m /= vsum;
        }
        mu_y /= vsum;
        // Gram matrix and correlation vector on centered data.
        let mut gram = vec![0.0f64; d * d];
        let mut c = vec![0.0f64; d];
        let mut xt = vec![0.0f64; d];
        for ((xi, &yi), &vi) in x.iter().zip(y).zip(&v) {
            for (t, (&xij, &m)) in xt.iter_mut().zip(xi.iter().zip(&mu_x)) {
                *t = xij - m;
            }
            let yc = yi - mu_y;
            for j in 0..d {
                let vx = vi * xt[j];
                c[j] += vx * yc;
                for k in j..d {
                    gram[j * d + k] += vx * xt[k];
                }
            }
        }
        for j in 0..d {
            for k in 0..j {
                gram[j * d + k] = gram[k * d + j];
            }
        }
        let mut w = vec![0.0f64; d];
        let an2 = alpha * n as f64 / 2.0;
        for _pass in 0..5000 {
            let mut max_delta: f64 = 0.0;
            for j in 0..d {
                let zj = gram[j * d + j];
                if zj <= 1e-18 {
                    continue;
                }
                // rho_j = c_j - Σ_{k≠j} G_jk w_k
                let mut dot = 0.0;
                for k in 0..d {
                    dot += gram[j * d + k] * w[k];
                }
                let rho = c[j] - dot + zj * w[j];
                let new_w = ((rho - an2) / zj).max(0.0);
                let delta = new_w - w[j];
                if delta != 0.0 {
                    w[j] = new_w;
                    max_delta = max_delta.max(delta.abs());
                }
            }
            if max_delta < 1e-12 {
                break;
            }
        }
        let b = mu_y - w.iter().zip(&mu_x).map(|(wj, m)| wj * m).sum::<f64>();
        Lasso { weights: w, intercept: b, alpha }
    }

    /// Grid-search alpha in [1e-5, 1e2] by 5-fold CV (paper Section 4.2).
    pub fn fit_cv(x: &[Vec<f64>], y: &[f64], seed: u64) -> Lasso {
        let alphas: Vec<f64> =
            (0..8).map(|i| 1e-5 * 10f64.powi(i)).collect(); // 1e-5 .. 1e2
        let best =
            cv::grid_search(&alphas, x, y, seed, |&a, xt, yt| Lasso::fit(xt, yt, a));
        Lasso::fit(x, y, best)
    }

    /// Serialize for `engine::bundle` (weights round-trip bit-exactly).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("lasso")),
            ("weights", Json::from_f64s(&self.weights)),
            ("intercept", Json::Num(self.intercept)),
            ("alpha", Json::Num(self.alpha)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Lasso, String> {
        let weights = j.req_f64_arr("weights")?;
        if weights.is_empty() {
            return Err("lasso: empty weight vector".into());
        }
        let intercept = j.req_f64("intercept")?;
        if weights.iter().any(|w| !w.is_finite()) || !intercept.is_finite() {
            return Err("lasso: non-finite weights/intercept".into());
        }
        Ok(Lasso { weights, intercept, alpha: j.req_f64("alpha")? })
    }

    /// Feature importance = weight magnitude (features are standardized, so
    /// weights are comparable — Section 5.5.2 uses this).
    pub fn importances(&self) -> Vec<(usize, f64)> {
        let mut v: Vec<(usize, f64)> =
            self.weights.iter().copied().enumerate().collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }
}

impl Regressor for Lasso {
    fn predict_one(&self, x: &[f64]) -> f64 {
        self.intercept + self.weights.iter().zip(x).map(|(w, x)| w * x).sum::<f64>()
    }

    /// Blocked GEMV over the dense arena for uniform-width matrices
    /// (`predict::soa::lasso_gemv`); bit-identical to the scalar row loop,
    /// which remains the path for ragged views.
    fn predict(&self, xs: &FeatureMatrix<'_>) -> Vec<f64> {
        if let Some(w) = xs.uniform_width() {
            let mut out = vec![0.0; xs.len()];
            soa::lasso_gemv(&self.weights, self.intercept, xs.values(), w, &mut out);
            out
        } else {
            xs.rows().map(|x| self.predict_one(x)).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::Standardizer;
    use crate::util::{mape, Rng};

    fn linear_data(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.range_f64(1.0, 50.0);
            let b = rng.range_f64(1.0, 50.0);
            x.push(vec![a, b]);
            y.push(10.0 + 3.0 * a + 0.5 * b);
        }
        (x, y)
    }

    #[test]
    fn recovers_linear_relationship() {
        let (x, y) = linear_data(200, 1);
        let s = Standardizer::fit(&x);
        let xs = s.transform_all(&x);
        let m = Lasso::fit(&xs, &y, 1e-5);
        let pred: Vec<f64> = xs.iter().map(|v| m.predict_one(v)).collect();
        assert!(mape(&pred, &y) < 0.01, "mape={}", mape(&pred, &y));
    }

    #[test]
    fn weights_nonnegative() {
        // Anti-correlated feature should be zeroed, not negative.
        let mut rng = Rng::new(2);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..200 {
            let a = rng.range_f64(1.0, 50.0);
            x.push(vec![a, -a]);
            y.push(5.0 + 2.0 * a);
        }
        let s = Standardizer::fit(&x);
        let m = Lasso::fit(&s.transform_all(&x), &y, 1e-4);
        assert!(m.weights.iter().all(|&w| w >= 0.0), "{:?}", m.weights);
    }

    #[test]
    fn large_alpha_sparsifies() {
        let (x, y) = linear_data(200, 3);
        let s = Standardizer::fit(&x);
        let xs = s.transform_all(&x);
        let loose = Lasso::fit(&xs, &y, 1e-6);
        let tight = Lasso::fit(&xs, &y, 50.0);
        let nz = |m: &Lasso| m.weights.iter().filter(|&&w| w > 1e-9).count();
        assert!(nz(&tight) <= nz(&loose));
        assert_eq!(nz(&tight), 0, "alpha=50 should kill all weights");
    }

    #[test]
    fn percentage_loss_weights_fast_ops() {
        // Two clusters: fast ops (y~1) and slow ops (y~1000) with a feature
        // that only explains the fast ones. The 1/y² weighting must favour
        // accuracy on the fast cluster (the paper's Section 5.3 anomaly).
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..50 {
            let f = 1.0 + (i % 10) as f64 / 10.0;
            x.push(vec![f, 0.0]);
            y.push(f); // fast: y == feature0
        }
        for i in 0..50 {
            let f = 1.0 + (i % 10) as f64 / 10.0;
            x.push(vec![f, 1.0]);
            y.push(1000.0 + 300.0 * f); // slow cluster
        }
        let s = Standardizer::fit(&x);
        let xs = s.transform_all(&x);
        let m = Lasso::fit(&xs, &y, 1e-5);
        let fast_pred: Vec<f64> = xs[..50].iter().map(|v| m.predict_one(v).max(1e-9)).collect();
        let fast_err = mape(&fast_pred, &y[..50]);
        let slow_pred: Vec<f64> = xs[50..].iter().map(|v| m.predict_one(v).max(1e-9)).collect();
        let slow_err = mape(&slow_pred, &y[50..]);
        assert!(fast_err < slow_err, "fast={fast_err} slow={slow_err}");
    }

    #[test]
    fn cv_selects_reasonable_alpha() {
        let (x, y) = linear_data(150, 5);
        let s = Standardizer::fit(&x);
        let m = Lasso::fit_cv(&s.transform_all(&x), &y, 7);
        assert!(m.alpha <= 1e-1, "alpha={}", m.alpha);
    }

    #[test]
    fn json_roundtrip_is_bit_identical() {
        let (x, y) = linear_data(120, 11);
        let s = Standardizer::fit(&x);
        let xs = s.transform_all(&x);
        let m = Lasso::fit(&xs, &y, 1e-4);
        let back =
            Lasso::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.intercept.to_bits(), m.intercept.to_bits());
        for v in xs.iter().take(20) {
            assert_eq!(m.predict_one(v).to_bits(), back.predict_one(v).to_bits());
        }
    }

    #[test]
    fn importances_sorted() {
        let (x, y) = linear_data(100, 6);
        let s = Standardizer::fit(&x);
        let m = Lasso::fit(&s.transform_all(&x), &y, 1e-5);
        let imp = m.importances();
        assert_eq!(imp.len(), 2);
        assert!(imp[0].1 >= imp[1].1);
        assert_eq!(imp[0].0, 0); // feature 0 has coefficient 3.0 > 0.5
    }
}
