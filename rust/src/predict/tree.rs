//! CART regression tree with weighted squared loss (weights 1/y², aligning
//! the split criterion with the paper's percentage-error objective). The
//! building block for both `forest` (RF) and `gbdt`.

use crate::util::Json;

/// Tree hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Number of features considered per split (None = all; RF uses sqrt).
    pub max_features: Option<usize>,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams { max_depth: 16, min_samples_split: 2, max_features: None }
    }
}

#[derive(Debug, Clone)]
enum NodeKind {
    Leaf { value: f64 },
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

/// A fitted regression tree (nodes stored in a flat arena).
#[derive(Debug, Clone)]
pub struct Tree {
    nodes: Vec<NodeKind>,
}

struct Builder<'a> {
    x: &'a [Vec<f64>],
    y: &'a [f64],
    w: &'a [f64],
    params: TreeParams,
    nodes: Vec<NodeKind>,
    rng_state: u64,
}

impl<'a> Builder<'a> {
    fn next_rand(&mut self) -> u64 {
        // splitmix64 step for feature subsampling
        self.rng_state = self.rng_state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.rng_state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Build a node from per-feature presorted member lists (`sorted[f]` is
    /// this node's members ordered by feature f). Sorting happens once at
    /// the root; splits partition the lists stably in O(F·n) — the
    /// classic presort optimization (EXPERIMENTS.md §Perf).
    fn build(&mut self, sorted: Vec<Vec<u32>>, depth: usize) -> usize {
        let idx = &sorted[0];
        let n = idx.len();
        let leaf_value = self.weighted_mean_u32(idx);
        if depth >= self.params.max_depth || n < self.params.min_samples_split || n < 2 {
            self.nodes.push(NodeKind::Leaf { value: leaf_value });
            return self.nodes.len() - 1;
        }

        // Candidate features.
        let d = self.x[0].len();
        let mut feats: Vec<usize> = (0..d).collect();
        if let Some(mf) = self.params.max_features {
            // Fisher-Yates partial shuffle.
            let mf = mf.min(d);
            for i in 0..mf {
                let j = i + (self.next_rand() as usize) % (d - i);
                feats.swap(i, j);
            }
            feats.truncate(mf);
        }

        // Best split by weighted SSE reduction.
        let sse = |sw: f64, swy: f64, swyy: f64| -> f64 {
            if sw <= 0.0 {
                0.0
            } else {
                swyy - swy * swy / sw
            }
        };
        let (mut sw_t, mut swy_t, mut swyy_t) = (0.0, 0.0, 0.0);
        for &i in idx.iter() {
            let i = i as usize;
            sw_t += self.w[i];
            swy_t += self.w[i] * self.y[i];
            swyy_t += self.w[i] * self.y[i] * self.y[i];
        }
        let total_sse = sse(sw_t, swy_t, swyy_t);
        if total_sse <= swyy_t * 1e-12 {
            // Constant target (up to catastrophic-cancellation noise).
            self.nodes.push(NodeKind::Leaf { value: leaf_value });
            return self.nodes.len() - 1;
        }
        // Numerically meaningful gains only.
        let min_gain = (total_sse * 1e-9).max(1e-18);
        let (mut best_gain, mut best_f, mut best_thr) = (min_gain, usize::MAX, 0.0f64);
        for &f in &feats {
            let order = &sorted[f];
            // Prefix scans of w, w*y, w*y².
            let (mut sw_l, mut swy_l, mut swyy_l) = (0.0, 0.0, 0.0);
            for k in 0..n - 1 {
                let i = order[k] as usize;
                sw_l += self.w[i];
                swy_l += self.w[i] * self.y[i];
                swyy_l += self.w[i] * self.y[i] * self.y[i];
                let xv = self.x[i][f];
                let xn = self.x[order[k + 1] as usize][f];
                if xn <= xv {
                    continue; // ties: can't split here
                }
                let gain = total_sse
                    - sse(sw_l, swy_l, swyy_l)
                    - sse(sw_t - sw_l, swy_t - swy_l, swyy_t - swyy_l);
                if gain > best_gain {
                    best_gain = gain;
                    best_f = f;
                    best_thr = 0.5 * (xv + xn);
                }
            }
        }

        if best_f == usize::MAX {
            self.nodes.push(NodeKind::Leaf { value: leaf_value });
            return self.nodes.len() - 1;
        }

        // Stable partition of every feature's order by the split predicate.
        let goes_left: Vec<bool> = {
            // Membership via a bitmap over the full dataset.
            let mut gl = vec![false; self.x.len()];
            for &i in idx.iter() {
                gl[i as usize] = self.x[i as usize][best_f] <= best_thr;
            }
            gl
        };
        let mut left_sorted: Vec<Vec<u32>> = Vec::with_capacity(d);
        let mut right_sorted: Vec<Vec<u32>> = Vec::with_capacity(d);
        for order in &sorted {
            let mut l = Vec::with_capacity(n / 2);
            let mut r = Vec::with_capacity(n / 2);
            for &i in order {
                if goes_left[i as usize] {
                    l.push(i);
                } else {
                    r.push(i);
                }
            }
            left_sorted.push(l);
            right_sorted.push(r);
        }
        drop(sorted);
        debug_assert!(!left_sorted[0].is_empty() && !right_sorted[0].is_empty());
        let l = self.build(left_sorted, depth + 1);
        let r = self.build(right_sorted, depth + 1);
        self.nodes.push(NodeKind::Split { feature: best_f, threshold: best_thr, left: l, right: r });
        self.nodes.len() - 1
    }

    fn weighted_mean_u32(&self, idx: &[u32]) -> f64 {
        let mut sw = 0.0;
        let mut swy = 0.0;
        for &i in idx {
            let i = i as usize;
            sw += self.w[i];
            swy += self.w[i] * self.y[i];
        }
        if sw > 0.0 {
            swy / sw
        } else {
            0.0
        }
    }
}

impl Tree {
    /// Fit on (x, y) with optional per-sample weights (default 1/y²).
    pub fn fit(x: &[Vec<f64>], y: &[f64], w: Option<&[f64]>, params: TreeParams, seed: u64) -> Tree {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let default_w: Vec<f64>;
        let w = match w {
            Some(w) => w,
            None => {
                default_w = y.iter().map(|&yi| 1.0 / (yi * yi).max(1e-18)).collect();
                &default_w
            }
        };
        let mut b = Builder { x, y, w, params, nodes: Vec::new(), rng_state: seed ^ 0xABCD };
        // Presort every feature once; node splits partition these stably.
        let d = x[0].len();
        let sorted: Vec<Vec<u32>> = (0..d)
            .map(|f| {
                let mut order: Vec<u32> = (0..x.len() as u32).collect();
                order.sort_by(|&a, &b| {
                    x[a as usize][f].partial_cmp(&x[b as usize][f]).unwrap()
                });
                order
            })
            .collect();
        let root = b.build(sorted, 0);
        debug_assert_eq!(root, b.nodes.len() - 1);
        Tree { nodes: b.nodes }
    }

    pub fn predict_one(&self, x: &[f64]) -> f64 {
        let mut i = self.nodes.len() - 1; // root is last-pushed
        loop {
            match &self.nodes[i] {
                NodeKind::Leaf { value } => return *value,
                NodeKind::Split { feature, threshold, left, right } => {
                    i = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Largest feature index referenced by any split (`None` for a pure
    /// leaf). Bundle loading uses this to reject trees that would index
    /// past the feature vector at prediction time.
    pub fn max_feature_index(&self) -> Option<usize> {
        self.nodes
            .iter()
            .filter_map(|n| match n {
                NodeKind::Split { feature, .. } => Some(*feature),
                NodeKind::Leaf { .. } => None,
            })
            .max()
    }

    /// Longest root-to-leaf path length (0 for a single-leaf tree).
    pub fn depth(&self) -> usize {
        // Children precede parents in the arena, so one ascending pass
        // resolves every subtree height before its parent needs it.
        let mut h = vec![0usize; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            if let NodeKind::Split { left, right, .. } = n {
                h[i] = 1 + h[*left].max(h[*right]);
            }
        }
        h[self.nodes.len() - 1]
    }

    /// Append this tree's nodes to flat structure-of-arrays arenas (see
    /// `predict::soa`) and return the absolute index of the root.
    ///
    /// Splits keep their `feature`/`threshold` and absolute child indices;
    /// leaves are encoded as self-loops (`left == right == own index`) with
    /// `threshold = +inf` so the level-synchronous walk can evaluate every
    /// row unconditionally — a row parked on a leaf compares against +inf
    /// and stays put. Within one tree, children still precede parents, so
    /// any row not yet on a leaf strictly decreases its node index each
    /// step and the walk terminates in at most `depth()` + 1 passes.
    pub(crate) fn flatten_into(
        &self,
        feature: &mut Vec<u32>,
        threshold: &mut Vec<f64>,
        left: &mut Vec<u32>,
        right: &mut Vec<u32>,
        value: &mut Vec<f64>,
    ) -> u32 {
        let base = feature.len() as u32;
        for (i, n) in self.nodes.iter().enumerate() {
            match n {
                NodeKind::Leaf { value: v } => {
                    feature.push(0);
                    threshold.push(f64::INFINITY);
                    left.push(base + i as u32);
                    right.push(base + i as u32);
                    value.push(*v);
                }
                NodeKind::Split { feature: f, threshold: t, left: l, right: r } => {
                    feature.push(*f as u32);
                    threshold.push(*t);
                    left.push(base + *l as u32);
                    right.push(base + *r as u32);
                    value.push(0.0);
                }
            }
        }
        base + (self.nodes.len() - 1) as u32
    }

    /// Rebuild one tree from the flat SoA arenas [`flatten_into`] writes
    /// (the binary bundle format stores trees in exactly that layout).
    /// `start..=root` is this tree's absolute node span; indices inside
    /// the arenas are absolute too. The encoding is exactly invertible:
    /// a leaf is a self-loop (`left == right == own index`) carrying
    /// `threshold == +inf` and a finite value, a split points strictly
    /// downward within the span and carries a finite threshold — anything
    /// else is a corruption error, never a panic or an OOB read.
    pub(crate) fn from_flat(
        feature: &[u32],
        threshold: &[f64],
        left: &[u32],
        right: &[u32],
        value: &[f64],
        start: usize,
        root: usize,
    ) -> Result<Tree, String> {
        let len = feature.len();
        if threshold.len() != len || left.len() != len || right.len() != len || value.len() != len {
            return Err("tree arenas: column length mismatch".into());
        }
        if start > root || root >= len {
            return Err(format!("tree span {start}..={root} out of bounds (arena {len})"));
        }
        let mut nodes = Vec::with_capacity(root - start + 1);
        for i in start..=root {
            let (l, r) = (left[i] as usize, right[i] as usize);
            if l == i && r == i {
                if threshold[i] != f64::INFINITY {
                    return Err(format!("tree node {i}: leaf without +inf threshold"));
                }
                if !value[i].is_finite() {
                    return Err(format!("tree node {i}: non-finite leaf value"));
                }
                nodes.push(NodeKind::Leaf { value: value[i] });
            } else {
                if !threshold[i].is_finite() {
                    return Err(format!("tree node {i}: non-finite split threshold"));
                }
                if l < start || l >= i || r < start || r >= i {
                    return Err(format!(
                        "tree node {i}: child index out of order (left {l}, right {r})"
                    ));
                }
                nodes.push(NodeKind::Split {
                    feature: feature[i] as usize,
                    threshold: threshold[i],
                    left: l - start,
                    right: r - start,
                });
            }
        }
        Ok(Tree { nodes })
    }

    /// Serialize the node arena for `engine::bundle`: each node is a compact
    /// array, `[0, value]` for leaves and `[1, feature, threshold, left,
    /// right]` for splits. f64 values round-trip bit-exactly through
    /// `util::json` (shortest-repr emit + exact parse).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.nodes
                .iter()
                .map(|n| match n {
                    NodeKind::Leaf { value } => {
                        Json::Arr(vec![Json::Num(0.0), Json::Num(*value)])
                    }
                    NodeKind::Split { feature, threshold, left, right } => Json::Arr(vec![
                        Json::Num(1.0),
                        Json::Num(*feature as f64),
                        Json::Num(*threshold),
                        Json::Num(*left as f64),
                        Json::Num(*right as f64),
                    ]),
                })
                .collect(),
        )
    }

    /// Rebuild a tree from [`Tree::to_json`] output. Child indices are
    /// validated against the arena invariant (children precede parents; the
    /// root is last), so a corrupted bundle fails here with a clear error
    /// instead of looping at prediction time.
    pub fn from_json(j: &Json) -> Result<Tree, String> {
        let arr = j.as_arr().ok_or("tree: expected a node array")?;
        if arr.is_empty() {
            return Err("tree: empty node array".into());
        }
        let mut nodes = Vec::with_capacity(arr.len());
        for (i, nj) in arr.iter().enumerate() {
            let v = nj
                .as_arr()
                .ok_or_else(|| format!("tree node {i}: expected an array"))?;
            let num = |k: usize| -> Result<f64, String> {
                v.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("tree node {i}: field {k} is not a number"))
            };
            let tag = num(0)? as i64;
            let node = match (tag, v.len()) {
                (0, 2) => {
                    let value = num(1)?;
                    if !value.is_finite() {
                        return Err(format!("tree node {i}: non-finite leaf value"));
                    }
                    NodeKind::Leaf { value }
                }
                (1, 5) => {
                    let feature = num(1)? as usize;
                    let threshold = num(2)?;
                    if !threshold.is_finite() {
                        return Err(format!("tree node {i}: non-finite threshold"));
                    }
                    let left = num(3)? as usize;
                    let right = num(4)? as usize;
                    if left >= i || right >= i {
                        return Err(format!(
                            "tree node {i}: child index out of order (left {left}, right {right})"
                        ));
                    }
                    NodeKind::Split { feature, threshold, left, right }
                }
                _ => {
                    return Err(format!(
                        "tree node {i}: malformed (tag {tag}, {} fields)",
                        v.len()
                    ))
                }
            };
            nodes.push(node);
        }
        Ok(Tree { nodes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{mape, Rng};

    #[test]
    fn memorizes_training_data_at_full_depth() {
        let x: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..50).map(|i| (i * i + 1) as f64).collect();
        let t = Tree::fit(&x, &y, None, TreeParams::default(), 0);
        for (xi, &yi) in x.iter().zip(&y) {
            let p = t.predict_one(xi);
            assert!(
                (p - yi).abs() <= 1e-9 * yi.abs().max(1.0),
                "pred {p} vs {yi}"
            );
        }
    }

    #[test]
    fn respects_max_depth() {
        let x: Vec<Vec<f64>> = (0..256).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..256).map(|i| i as f64 + 1.0).collect();
        let t = Tree::fit(
            &x,
            &y,
            None,
            TreeParams { max_depth: 3, ..Default::default() },
            0,
        );
        // depth-3 binary tree: at most 2^4 - 1 nodes.
        assert!(t.node_count() <= 15, "{}", t.node_count());
    }

    #[test]
    fn min_samples_split_limits_growth() {
        let x: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..100).map(|i| i as f64 + 1.0).collect();
        let small = Tree::fit(&x, &y, None, TreeParams { min_samples_split: 50, ..Default::default() }, 0);
        let big = Tree::fit(&x, &y, None, TreeParams::default(), 0);
        assert!(small.node_count() < big.node_count());
    }

    #[test]
    fn learns_step_function() {
        // Piecewise-constant target: exactly what trees represent.
        let mut rng = Rng::new(4);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..300 {
            let a = rng.range_f64(0.0, 10.0);
            x.push(vec![a]);
            y.push(if a < 3.0 { 5.0 } else if a < 7.0 { 50.0 } else { 500.0 });
        }
        let t = Tree::fit(&x, &y, None, TreeParams { max_depth: 4, ..Default::default() }, 0);
        let pred: Vec<f64> = x.iter().map(|v| t.predict_one(v)).collect();
        assert!(mape(&pred, &y) < 0.02);
    }

    #[test]
    fn handles_constant_target() {
        let x: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let y = vec![7.0; 20];
        let t = Tree::fit(&x, &y, None, TreeParams::default(), 0);
        assert_eq!(t.node_count(), 1);
        assert!((t.predict_one(&[3.0]) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip_is_bit_identical() {
        let (x, y) = crate::predict::toy_problem(200, 12);
        let t = Tree::fit(&x, &y, None, TreeParams::default(), 3);
        let back = Tree::from_json(&Json::parse(&t.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.node_count(), t.node_count());
        for v in x.iter().take(50) {
            assert_eq!(t.predict_one(v).to_bits(), back.predict_one(v).to_bits());
        }
    }

    #[test]
    fn from_json_rejects_malformed_nodes() {
        // Not an array.
        assert!(Tree::from_json(&Json::parse("{}").unwrap()).is_err());
        // Empty arena.
        assert!(Tree::from_json(&Json::parse("[]").unwrap()).is_err());
        // Split whose child points at itself/forward: would loop at predict.
        let err =
            Tree::from_json(&Json::parse("[[0,1.0],[1,0,0.5,1,0]]").unwrap()).unwrap_err();
        assert!(err.contains("child index"), "{err}");
        // Bad tag / arity.
        assert!(Tree::from_json(&Json::parse("[[2,1.0]]").unwrap()).is_err());
        assert!(Tree::from_json(&Json::parse("[[0,1.0,2.0]]").unwrap()).is_err());
    }

    #[test]
    fn feature_subsampling_changes_tree() {
        let mut rng = Rng::new(5);
        let x: Vec<Vec<f64>> = (0..200)
            .map(|_| (0..6).map(|_| rng.range_f64(0.0, 1.0)).collect())
            .collect();
        let y: Vec<f64> = x.iter().map(|v| 1.0 + v.iter().sum::<f64>()).collect();
        let p = TreeParams { max_features: Some(2), max_depth: 4, ..Default::default() };
        let a = Tree::fit(&x, &y, None, p, 1);
        let b = Tree::fit(&x, &y, None, p, 2);
        let differs = x.iter().any(|v| a.predict_one(v) != b.predict_one(v));
        assert!(differs, "different seeds should subsample different features");
    }
}
