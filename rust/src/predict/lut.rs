//! Compiled lookup-table prediction tier: per-bucket direct-lookup
//! tables with multilinear interpolation.
//!
//! For a closed workload (a fixed set of lowered plans), the feature rows
//! a bucket's model will ever see span a small grid of distinct values
//! per dimension. [`LutPack::compile`] pre-evaluates a trained model over
//! that grid once, so the hot path becomes an index computation (binary
//! search per axis + one table read, or a 2^k-corner multilinear blend)
//! instead of a 100+-tree ensemble walk. Rows outside the grid — new
//! feature values, too-short rows, buckets whose grid would explode —
//! fall back to the SoA kernels bit-identically; a compiled table is
//! *dropped* at build time if any calibration or held-out row
//! interpolates outside the declared relative-error bound, so a served
//! LUT value is always within `LutSpec::max_rel_err` of the full model.
//!
//! Accounting mirrors `exec_pool::CacheStats`: lock-free counters for
//! exact lookups, interpolations, and fallbacks ([`LutStats`] /
//! [`LutCounts`]), surfaced through the engine and the serve daemon's
//! `stats` verb so the fallback rate is observable in production.

use crate::plan::LoweredGraph;
use std::sync::atomic::{AtomicU64, Ordering};

/// Hard cap on model dimensionality a table will be attempted for. The
/// probe keeps its per-axis state in stack arrays; wider models (none of
/// the paper's buckets exceed 13 features) always use the SoA path.
const MAX_DIMS: usize = 16;

/// At most this many axes may interpolate in one probe (2^k corners are
/// blended). More fractional axes than this is a miss, not a blow-up.
const MAX_INTERP_DIMS: usize = 6;

/// Grid-compilation knobs for [`LutPack::compile`].
#[derive(Debug, Clone, Copy)]
pub struct LutSpec {
    /// Declared bound: a bucket table is dropped unless every verified
    /// interpolated row lands within this relative error of the full
    /// model. Exact grid hits are bit-identical by construction.
    pub max_rel_err: f64,
    /// Knots per axis when an axis has more distinct observed values
    /// than this (it then becomes a uniform linspace over the observed
    /// range); axes at or under it keep the exact observed values.
    pub resolution: usize,
    /// Per-bucket table size cap (product of axis knot counts). A bucket
    /// whose grid would exceed this gets no table and stays on SoA.
    pub max_table_entries: usize,
}

impl Default for LutSpec {
    fn default() -> LutSpec {
        LutSpec { max_rel_err: 0.05, resolution: 33, max_table_entries: 1 << 18 }
    }
}

/// One bucket's compiled table: per-axis sorted knots, row-major strides,
/// and the pre-evaluated model values at every grid point.
pub struct BucketLut {
    axes: Vec<Vec<f64>>,
    strides: Vec<usize>,
    table: Vec<f64>,
}

enum Probe {
    /// Every coordinate hit a knot exactly: the stored model value,
    /// bit-identical to evaluating the model on this row.
    Exact(f64),
    /// Multilinear blend of the surrounding grid corners.
    Interp(f64),
    /// Out of grid (or non-finite input): serve from the SoA kernel.
    Miss,
}

impl BucketLut {
    /// Grid points in this table.
    pub fn entries(&self) -> usize {
        self.table.len()
    }

    fn probe(&self, row: &[f64]) -> Probe {
        let nd = self.axes.len();
        if row.len() < nd {
            return Probe::Miss;
        }
        let mut base = 0usize;
        let mut fr = [(0usize, 0.0f64); MAX_INTERP_DIMS];
        let mut nf = 0usize;
        for j in 0..nd {
            let a = &self.axes[j];
            let v = row[j];
            // NaN fails both comparisons, so non-finite rows miss here.
            if !(v >= a[0] && v <= a[a.len() - 1]) {
                return Probe::Miss;
            }
            match a.binary_search_by(|x| x.total_cmp(&v)) {
                Ok(i) => base += i * self.strides[j],
                Err(i) => {
                    // Strictly inside the range, so 1 <= i <= len - 1.
                    if nf == MAX_INTERP_DIMS {
                        return Probe::Miss;
                    }
                    let (lo, hi) = (a[i - 1], a[i]);
                    base += (i - 1) * self.strides[j];
                    fr[nf] = (self.strides[j], (v - lo) / (hi - lo));
                    nf += 1;
                }
            }
        }
        if nf == 0 {
            return Probe::Exact(self.table[base]);
        }
        let mut acc = 0.0f64;
        for corner in 0..(1usize << nf) {
            let mut w = 1.0f64;
            let mut idx = base;
            for (k, &(stride, frac)) in fr[..nf].iter().enumerate() {
                if corner >> k & 1 == 1 {
                    w *= frac;
                    idx += stride;
                } else {
                    w *= 1.0 - frac;
                }
            }
            acc += w * self.table[idx];
        }
        Probe::Interp(acc)
    }
}

/// Lock-free LUT-tier counters (`CacheStats` idiom, but atomics: one
/// pack is shared immutably across prediction threads).
#[derive(Default)]
pub struct LutStats {
    lookups: AtomicU64,
    interpolations: AtomicU64,
    fallbacks: AtomicU64,
}

/// A snapshot of [`LutStats`], mergeable across engine generations.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LutCounts {
    /// Rows served by an exact grid hit (bit-identical to the model).
    pub lookups: u64,
    /// Rows served by multilinear interpolation (within the bound).
    pub interpolations: u64,
    /// Rows the LUT tier declined (no table, out of grid) while enabled.
    pub fallbacks: u64,
}

impl LutCounts {
    /// Fold another snapshot in (reload-surviving totals).
    pub fn merge(&self, other: &LutCounts) -> LutCounts {
        LutCounts {
            lookups: self.lookups + other.lookups,
            interpolations: self.interpolations + other.interpolations,
            fallbacks: self.fallbacks + other.fallbacks,
        }
    }

    /// Rows the tier answered (exact + interpolated).
    pub fn served(&self) -> u64 {
        self.lookups + self.interpolations
    }
}

/// A set of per-bucket compiled tables for one predictor, plus the bound
/// they were verified against and live counters.
pub struct LutPack {
    tables: Vec<Option<BucketLut>>,
    /// The declared bound every surviving table was verified against.
    pub bound: f64,
    /// Largest relative error actually measured on a verified
    /// interpolated row across all surviving tables (<= `bound`).
    pub max_rel_err: f64,
    stats: LutStats,
}

impl LutPack {
    /// Compile tables for every bucket with a model, calibrated on the
    /// feature rows of `plans`.
    ///
    /// `dims[b]` is the model's feature dimension for bucket `b` (`None`
    /// when the bucket has no model). `eval(b, row)` evaluates the full
    /// model — it must be the exact function the LUT replaces
    /// (`predict_raw` semantics, floor clamp included).
    ///
    /// Per bucket: rows are split even/odd into calibration and held-out
    /// halves; axis knots come from the calibration half (exact distinct
    /// values, or a uniform linspace past `spec.resolution`); the table
    /// is filled by evaluating the model at every grid point; then every
    /// row of *both* halves that the table would interpolate is checked
    /// against the full model, and the whole table is dropped if any
    /// exceeds `spec.max_rel_err`. Buckets whose grid would exceed
    /// `spec.max_table_entries` (or with no usable rows) get no table.
    pub fn compile<F>(
        spec: &LutSpec,
        dims: &[Option<usize>],
        plans: &[&LoweredGraph],
        mut eval: F,
    ) -> LutPack
    where
        F: FnMut(usize, &[f64]) -> Option<f64>,
    {
        let mut tables: Vec<Option<BucketLut>> = Vec::with_capacity(dims.len());
        let mut worst = 0.0f64;
        for (bi, d) in dims.iter().enumerate() {
            let built = d
                .filter(|&d| d > 0 && d <= MAX_DIMS)
                .and_then(|d| compile_bucket(spec, bi, d, plans, &mut eval));
            if let Some((lut, err)) = built {
                worst = worst.max(err);
                tables.push(Some(lut));
            } else {
                tables.push(None);
            }
        }
        LutPack { tables, bound: spec.max_rel_err, max_rel_err: worst, stats: LutStats::default() }
    }

    /// Serve one row from the compiled tier. `None` means "use the SoA
    /// kernel" (no table for this bucket, or the row is out of grid);
    /// both outcomes are counted.
    pub fn lookup(&self, bucket: usize, row: &[f64]) -> Option<f64> {
        let Some(Some(lut)) = self.tables.get(bucket).map(Option::as_ref) else {
            self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        match lut.probe(row) {
            Probe::Exact(v) => {
                self.stats.lookups.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            Probe::Interp(v) => {
                self.stats.interpolations.fetch_add(1, Ordering::Relaxed);
                Some(v)
            }
            Probe::Miss => {
                self.stats.fallbacks.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Buckets that got a verified table.
    pub fn coverage(&self) -> usize {
        self.tables.iter().filter(|t| t.is_some()).count()
    }

    /// Total pre-evaluated grid points across all tables.
    pub fn table_entries(&self) -> usize {
        self.tables.iter().flatten().map(BucketLut::entries).sum()
    }

    /// Snapshot of the tier's counters.
    pub fn counts(&self) -> LutCounts {
        LutCounts {
            lookups: self.stats.lookups.load(Ordering::Relaxed),
            interpolations: self.stats.interpolations.load(Ordering::Relaxed),
            fallbacks: self.stats.fallbacks.load(Ordering::Relaxed),
        }
    }
}

/// Build + verify one bucket's table; `None` drops the bucket to SoA.
/// Returns the table and the worst verified relative error.
fn compile_bucket<F>(
    spec: &LutSpec,
    bi: usize,
    d: usize,
    plans: &[&LoweredGraph],
    eval: &mut F,
) -> Option<(BucketLut, f64)>
where
    F: FnMut(usize, &[f64]) -> Option<f64>,
{
    // Gather this bucket's observed (finite, wide-enough) rows.
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for p in plans {
        for (b, row) in p.iter() {
            if b.index() == bi && row.len() >= d && row[..d].iter().all(|v| v.is_finite()) {
                rows.push(row[..d].to_vec());
            }
        }
    }
    if rows.is_empty() {
        return None;
    }
    // Even rows calibrate the grid; odd rows are held out for the
    // verification pass (which also re-checks the calibration rows —
    // linspace'd axes make even calibration rows interpolate).
    let calib: Vec<&Vec<f64>> = rows.iter().step_by(2).collect();
    let mut axes: Vec<Vec<f64>> = Vec::with_capacity(d);
    for j in 0..d {
        let mut vals: Vec<f64> = calib.iter().map(|r| r[j]).collect();
        vals.sort_by(f64::total_cmp);
        vals.dedup();
        if vals.len() > spec.resolution.max(2) {
            let (lo, hi) = (vals[0], vals[vals.len() - 1]);
            let n = spec.resolution.max(2);
            let mut knots: Vec<f64> = (0..n)
                .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
                .collect();
            knots[n - 1] = hi; // pin the endpoint against rounding
            knots.dedup();
            vals = knots;
        }
        axes.push(vals);
    }
    let mut entries = 1usize;
    for a in &axes {
        entries = entries.checked_mul(a.len())?;
        if entries > spec.max_table_entries {
            return None;
        }
    }
    // Row-major strides, last axis fastest.
    let mut strides = vec![0usize; d];
    let mut s = 1usize;
    for j in (0..d).rev() {
        strides[j] = s;
        s *= axes[j].len();
    }
    // Fill: odometer over the cartesian product of knots.
    let mut table = Vec::with_capacity(entries);
    let mut idx = vec![0usize; d];
    let mut point = vec![0.0f64; d];
    'fill: loop {
        for j in 0..d {
            point[j] = axes[j][idx[j]];
        }
        table.push(eval(bi, &point)?);
        for j in (0..d).rev() {
            idx[j] += 1;
            if idx[j] < axes[j].len() {
                continue 'fill;
            }
            idx[j] = 0;
        }
        break;
    }
    debug_assert_eq!(table.len(), entries);
    let lut = BucketLut { axes, strides, table };
    // Verify: every row (calibration and held-out) that the table would
    // interpolate must land within the declared bound of the full model.
    // Exact hits are bit-identical by construction; misses go to SoA.
    let mut worst = 0.0f64;
    for row in &rows {
        if let Probe::Interp(got) = lut.probe(row) {
            let want = eval(bi, row)?;
            let rel = (got - want).abs() / want.abs().max(1e-12);
            if !(rel <= spec.max_rel_err) {
                return None;
            }
            worst = worst.max(rel);
        }
    }
    Some((lut, worst))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::DeductionMode;
    use crate::plan;
    use crate::scenario::Registry;

    /// A deterministic linear "model": LUT interpolation of a linear
    /// function is exact up to float rounding, so every table survives.
    fn linear_eval(_b: usize, row: &[f64]) -> Option<f64> {
        Some(1.0 + row.iter().enumerate().map(|(i, v)| (i + 1) as f64 * v).sum::<f64>())
    }

    fn sample_plans(sc: &crate::scenario::Scenario) -> Vec<LoweredGraph> {
        crate::nas::sample_dataset(42, 4)
            .into_iter()
            .map(|a| plan::lower(sc, DeductionMode::Full, &a.graph))
            .collect()
    }

    #[test]
    fn compiled_pack_serves_observed_rows_and_counts() {
        let reg = Registry::with_builtin();
        let sc = reg.one_large_core("Snapdragon855").expect("builtin soc");
        let plans = sample_plans(&sc);
        let refs: Vec<&LoweredGraph> = plans.iter().collect();
        let nb = crate::plan::interner().len();
        // Every bucket gets a nominal 4-dim linear model.
        let dims: Vec<Option<usize>> = vec![Some(4); nb];
        let pack = LutPack::compile(&LutSpec::default(), &dims, &refs, linear_eval);
        assert!(pack.coverage() > 0, "no bucket compiled a table");
        assert!(pack.max_rel_err <= pack.bound);
        let mut served = 0u64;
        for p in &plans {
            for (b, row) in p.iter() {
                if let Some(got) = pack.lookup(b.index(), row) {
                    let want = linear_eval(b.index(), row).unwrap();
                    let rel = (got - want).abs() / want.abs().max(1e-12);
                    assert!(rel <= pack.bound + 1e-9, "rel={rel}");
                    served += 1;
                }
            }
        }
        assert!(served > 0, "pack served nothing on its own calibration rows");
        let c = pack.counts();
        assert_eq!(c.served(), served);
        // Calibration rows with all-knot coordinates are exact hits.
        assert!(c.lookups > 0, "expected exact grid hits on calibration rows");
    }

    #[test]
    fn out_of_grid_and_short_rows_miss() {
        let lut = BucketLut {
            axes: vec![vec![0.0, 1.0], vec![10.0, 20.0]],
            strides: vec![2, 1],
            table: vec![0.0, 1.0, 2.0, 3.0],
        };
        assert!(matches!(lut.probe(&[0.5]), Probe::Miss), "short row must miss");
        assert!(matches!(lut.probe(&[2.0, 15.0]), Probe::Miss), "out of range must miss");
        assert!(matches!(lut.probe(&[f64::NAN, 15.0]), Probe::Miss), "NaN must miss");
        assert!(matches!(lut.probe(&[0.0, 10.0]), Probe::Exact(v) if v == 0.0));
        // Bilinear midpoint of [0,1,2,3] corners: (0+1+2+3)/4 = 1.5.
        assert!(matches!(lut.probe(&[0.5, 15.0]), Probe::Interp(v) if (v - 1.5).abs() < 1e-12));
    }

    #[test]
    fn merged_counts_accumulate() {
        let a = LutCounts { lookups: 1, interpolations: 2, fallbacks: 3 };
        let b = LutCounts { lookups: 10, interpolations: 20, fallbacks: 30 };
        let m = a.merge(&b);
        assert_eq!(m, LutCounts { lookups: 11, interpolations: 22, fallbacks: 33 });
        assert_eq!(m.served(), 33);
    }
}
