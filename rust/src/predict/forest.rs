//! Random Forest: bagged regression trees with feature subsampling.
//! Hyperparameters follow the paper (Section 4.2): number of trees in
//! 1..10 and min samples to split in 2..50, tuned by 5-fold CV.

use crate::predict::cv;
use crate::predict::tree::{Tree, TreeParams};
use crate::predict::{soa, FeatureMatrix, Regressor};
use crate::util::{Json, Rng};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ForestParams {
    pub n_trees: usize,
    pub min_samples_split: usize,
}

#[derive(Debug, Clone)]
pub struct RandomForest {
    pub trees: Vec<Tree>,
    pub params: ForestParams,
}

impl RandomForest {
    pub fn fit(x: &[Vec<f64>], y: &[f64], params: ForestParams, seed: u64) -> RandomForest {
        let n = x.len();
        let d = x[0].len();
        let max_features = ((d as f64).sqrt().ceil() as usize).max(1);
        let mut trees = Vec::with_capacity(params.n_trees);
        for t in 0..params.n_trees {
            let mut rng = Rng::derive(seed, &[0xf0, t as u64]);
            // Bootstrap sample.
            let idx: Vec<usize> = (0..n).map(|_| rng.range_usize(0, n - 1)).collect();
            let bx: Vec<Vec<f64>> = idx.iter().map(|&i| x[i].clone()).collect();
            let by: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
            let tp = TreeParams {
                max_depth: 24,
                min_samples_split: params.min_samples_split,
                max_features: if params.n_trees > 1 { Some(max_features) } else { None },
            };
            trees.push(Tree::fit(&bx, &by, None, tp, seed.wrapping_add(t as u64)));
        }
        RandomForest { trees, params }
    }

    /// Grid search over the paper's hyperparameter ranges.
    pub fn fit_cv(x: &[Vec<f64>], y: &[f64], seed: u64) -> RandomForest {
        let grid: Vec<ForestParams> = [1usize, 3, 5, 10]
            .iter()
            .flat_map(|&n_trees| {
                [2usize, 8, 20, 50]
                    .iter()
                    .map(move |&mss| ForestParams { n_trees, min_samples_split: mss })
            })
            .collect();
        let best =
            cv::grid_search(&grid, x, y, seed, |p, xt, yt| RandomForest::fit(xt, yt, *p, seed));
        RandomForest::fit(x, y, best, seed)
    }

    /// Serialize for `engine::bundle`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("rf")),
            ("n_trees", Json::Num(self.params.n_trees as f64)),
            ("min_samples_split", Json::Num(self.params.min_samples_split as f64)),
            ("trees", Json::Arr(self.trees.iter().map(Tree::to_json).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RandomForest, String> {
        let trees: Vec<Tree> = j
            .req("trees")?
            .as_arr()
            .ok_or("rf: 'trees' is not an array")?
            .iter()
            .enumerate()
            .map(|(i, t)| Tree::from_json(t).map_err(|e| format!("rf tree {i}: {e}")))
            .collect::<Result<_, _>>()?;
        if trees.is_empty() {
            return Err("rf: no trees".into());
        }
        Ok(RandomForest {
            trees,
            params: ForestParams {
                n_trees: j.req_usize("n_trees")?,
                min_samples_split: j.req_usize("min_samples_split")?,
            },
        })
    }
}

impl Regressor for RandomForest {
    fn predict_one(&self, x: &[f64]) -> f64 {
        let s: f64 = self.trees.iter().map(|t| t.predict_one(x)).sum();
        s / self.trees.len() as f64
    }

    /// Level-synchronous SoA walk over the whole matrix (`predict::soa`):
    /// per row, leaves accumulate in tree order from 0 and divide by the
    /// tree count last — the exact operation sequence of `predict_one`, so
    /// results are bit-identical.
    fn predict(&self, xs: &FeatureMatrix<'_>) -> Vec<f64> {
        let k = soa::EnsembleKernel::from_trees(&self.trees, 0.0, 1.0, self.trees.len() as f64);
        soa::ensemble_predict_matrix(&k, xs, |x| self.predict_one(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mape;

    #[test]
    fn forest_fits_nonlinear_target() {
        let (x, y) = crate::predict::toy_problem(500, 1);
        let (xt, yt) = crate::predict::toy_problem(100, 2);
        let f = RandomForest::fit(&x, &y, ForestParams { n_trees: 10, min_samples_split: 2 }, 3);
        let pred: Vec<f64> = xt.iter().map(|v| f.predict_one(v)).collect();
        assert!(mape(&pred, &yt) < 0.12, "mape={}", mape(&pred, &yt));
    }

    #[test]
    fn more_trees_reduce_variance() {
        let (x, y) = crate::predict::toy_problem(300, 4);
        let (xt, yt) = crate::predict::toy_problem(100, 5);
        let err = |n_trees: usize| {
            let f = RandomForest::fit(&x, &y, ForestParams { n_trees, min_samples_split: 2 }, 6);
            mape(&xt.iter().map(|v| f.predict_one(v)).collect::<Vec<_>>(), &yt)
        };
        assert!(err(10) < err(1) * 1.05, "10 trees {} vs 1 tree {}", err(10), err(1));
    }

    #[test]
    fn cv_returns_valid_params() {
        let (x, y) = crate::predict::toy_problem(200, 7);
        let f = RandomForest::fit_cv(&x, &y, 8);
        assert!((1..=10).contains(&f.params.n_trees));
        assert!((2..=50).contains(&f.params.min_samples_split));
    }

    #[test]
    fn json_roundtrip_is_bit_identical() {
        let (x, y) = crate::predict::toy_problem(200, 13);
        let f = RandomForest::fit(&x, &y, ForestParams { n_trees: 4, min_samples_split: 4 }, 5);
        let back =
            RandomForest::from_json(&Json::parse(&f.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.params, f.params);
        assert_eq!(back.trees.len(), f.trees.len());
        for v in x.iter().take(30) {
            assert_eq!(f.predict_one(v).to_bits(), back.predict_one(v).to_bits());
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let (x, y) = crate::predict::toy_problem(150, 9);
        let a = RandomForest::fit(&x, &y, ForestParams { n_trees: 5, min_samples_split: 2 }, 42);
        let b = RandomForest::fit(&x, &y, ForestParams { n_trees: 5, min_samples_split: 2 }, 42);
        for v in x.iter().take(10) {
            assert_eq!(a.predict_one(v), b.predict_one(v));
        }
    }
}
