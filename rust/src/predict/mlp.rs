//! The MLP latency predictor, executed through the AOT JAX/Pallas stack:
//! `python/compile/model.py` defines the forward pass (whose dense layers
//! are the L1 Pallas `fused_dense` kernel) and an Adam train step with the
//! paper's relative-error loss; `aot.py` lowers both to HLO text once; this
//! module drives training and inference from rust via PJRT (`runtime`).
//!
//! Hyperparameters follow Section 4.2 (layer count / width grid, Adam with
//! lr in {5e-3, 5e-4, 5e-5}, early stopping on a 20% validation split),
//! restricted to the AOT-compiled variants listed in `mlp_meta.json`.

use crate::predict::{FeatureMatrix, FeatureMatrixBuf, Regressor};
use crate::runtime::{literal_f32, to_vec_f32, Executable, Runtime};
use crate::util::{mape, Json, Rng};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// One AOT-compiled MLP architecture variant.
pub struct MlpVariant {
    pub name: String,
    pub layers: usize,
    pub width: usize,
    pub in_dim: usize,
    pub batch: usize,
    pub train: Executable,
    pub forward: Executable,
    /// Weight/bias tensor shapes in positional order.
    pub param_shapes: Vec<Vec<i64>>,
}

/// Loaded artifacts + PJRT client shared by all MLP trainings.
pub struct MlpContext {
    pub runtime: Runtime,
    pub variants: Vec<MlpVariant>,
}

impl MlpContext {
    /// Load every variant listed in `mlp_meta.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<MlpContext> {
        let runtime = Runtime::cpu(&dir)?;
        let meta = runtime.metadata("mlp_meta.json")?;
        let mut variants = Vec::new();
        for v in meta
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("mlp_meta.json missing variants"))?
        {
            let name = v.get("name").and_then(Json::as_str).context("variant name")?.to_string();
            let layers = v.get("layers").and_then(Json::as_usize).context("layers")?;
            let width = v.get("width").and_then(Json::as_usize).context("width")?;
            let in_dim = v.get("in_dim").and_then(Json::as_usize).context("in_dim")?;
            let batch = v.get("batch").and_then(Json::as_usize).context("batch")?;
            let train = runtime.load(&format!("mlp_train_{name}.hlo.txt"))?;
            let forward = runtime.load(&format!("mlp_forward_{name}.hlo.txt"))?;
            let mut param_shapes: Vec<Vec<i64>> = Vec::new();
            let mut fan_in = in_dim as i64;
            for _ in 0..layers {
                param_shapes.push(vec![fan_in, width as i64]);
                param_shapes.push(vec![width as i64]);
                fan_in = width as i64;
            }
            param_shapes.push(vec![fan_in, 1]);
            param_shapes.push(vec![1]);
            variants.push(MlpVariant { name, layers, width, in_dim, batch, train, forward, param_shapes });
        }
        if variants.is_empty() {
            return Err(anyhow!("no MLP variants in mlp_meta.json"));
        }
        Ok(MlpContext { runtime, variants })
    }
}

/// A trained MLP: the winning variant index + its weights (host copies).
pub struct MlpModel<'c> {
    ctx: &'c MlpContext,
    variant: usize,
    params: Vec<Vec<f32>>,
}

fn he_init(shapes: &[Vec<i64>], rng: &mut Rng) -> Vec<Vec<f32>> {
    shapes
        .iter()
        .map(|s| {
            let n: i64 = s.iter().product();
            if s.len() == 1 {
                vec![0.0; n as usize] // biases start at zero
            } else {
                let std = (2.0 / s[0] as f64).sqrt();
                (0..n).map(|_| (rng.normal() * std) as f32).collect()
            }
        })
        .collect()
}

/// Pad a feature row to `in_dim` (Table 3 vectors are shorter than the
/// fixed AOT input width).
fn pad_row(x: &[f64], in_dim: usize) -> Vec<f32> {
    let mut v = vec![0f32; in_dim];
    for (o, i) in v.iter_mut().zip(x) {
        *o = *i as f32;
    }
    v
}

struct TrainData {
    x: Vec<Vec<f32>>,
    y: Vec<f32>,
}

impl<'c> MlpModel<'c> {
    /// Train with grid search over variants and learning rates, early
    /// stopping on a 20% validation split (paper Section 4.2).
    pub fn fit(ctx: &'c MlpContext, x: &[Vec<f64>], y: &[f64], seed: u64) -> MlpModel<'c> {
        let mut rng = Rng::derive(seed, &[0x31b]);
        let n = x.len();
        if n < 8 {
            // Too little data for a validation split or meaningful SGD:
            // train the first variant briefly on everything.
            let tr = TrainData {
                x: x.iter().map(|r| pad_row(r, ctx.variants[0].in_dim)).collect(),
                y: y.iter().map(|&v| v as f32).collect(),
            };
            let params = train_variant(ctx, 0, &tr, 5e-3, seed).expect("MLP train step failed");
            return MlpModel { ctx, variant: 0, params };
        }
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let n_val = (n / 5).max(1).min(n - 1);
        let (val_idx, tr_idx) = idx.split_at(n_val);

        let lrs = [5e-3f32, 5e-4];
        let mut best: Option<(f64, usize, Vec<Vec<f32>>)> = None;
        for (vi, variant) in ctx.variants.iter().enumerate() {
            let tr = TrainData {
                x: tr_idx.iter().map(|&i| pad_row(&x[i], variant.in_dim)).collect(),
                y: tr_idx.iter().map(|&i| y[i] as f32).collect(),
            };
            let mut val_x = FeatureMatrixBuf::new();
            for &i in val_idx {
                val_x.push_row(&x[i]);
            }
            let val_y: Vec<f64> = val_idx.iter().map(|&i| y[i]).collect();
            for &lr in &lrs {
                let params = train_variant(ctx, vi, &tr, lr, seed).expect("MLP train step failed");
                let model = MlpModel { ctx, variant: vi, params };
                let pred: Vec<f64> =
                    model.predict_batch(&val_x.view()).iter().map(|&p| (p as f64).max(1e-9)).collect();
                let err = mape(&pred, &val_y);
                if best.as_ref().map(|b| err < b.0).unwrap_or(true) {
                    best = Some((err, vi, model.params));
                }
            }
        }
        let (_, variant, params) = best.unwrap();
        MlpModel { ctx, variant, params }
    }

    /// Batched forward pass through the AOT executable. Rows are cast to
    /// f32 and zero-padded to the variant's fixed input width while being
    /// packed into each PJRT batch literal — no per-row `Vec` allocation.
    pub fn predict_batch(&self, xs: &FeatureMatrix<'_>) -> Vec<f32> {
        let v = &self.ctx.variants[self.variant];
        let b = v.batch;
        let n = xs.len();
        let mut out = Vec::with_capacity(n);
        let mut start = 0;
        while start < n {
            let end = (start + b).min(n);
            let mut flat = vec![0f32; b * v.in_dim];
            for r in start..end {
                let dst = &mut flat[(r - start) * v.in_dim..(r - start + 1) * v.in_dim];
                for (o, i) in dst.iter_mut().zip(xs.row(r)) {
                    *o = *i as f32;
                }
            }
            let mut inputs =
                vec![literal_f32(&flat, &[b as i64, v.in_dim as i64]).expect("x literal")];
            for (p, s) in self.params.iter().zip(&v.param_shapes) {
                inputs.push(literal_f32(p, s).expect("param literal"));
            }
            let outs = v.forward.run(&inputs).expect("forward failed");
            let pred = to_vec_f32(&outs[0]).expect("forward output");
            out.extend_from_slice(&pred[..end - start]);
            start = end;
        }
        out
    }
}

/// Run the Adam training loop for one (variant, lr) configuration.
fn train_variant(
    ctx: &MlpContext,
    vi: usize,
    data: &TrainData,
    lr: f32,
    seed: u64,
) -> Result<Vec<Vec<f32>>> {
    let v = &ctx.variants[vi];
    let b = v.batch;
    let n = data.x.len();
    let mut rng = Rng::derive(seed, &[0x714, vi as u64, lr.to_bits() as u64]);
    let mut params = he_init(&v.param_shapes, &mut rng);
    let mut m: Vec<Vec<f32>> = v.param_shapes.iter().map(|s| vec![0.0; s.iter().product::<i64>() as usize]).collect();
    let mut vv: Vec<Vec<f32>> = m.clone();

    // Hold out 20% of the *training* rows for early stopping.
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let n_es = (n / 5).max(1).min(n.saturating_sub(1)).max(1);
    let (es_idx, tr_idx) = order.split_at(n_es.min(n - 1).max(1));
    // The early-stopping rows are fixed for the whole run: widen them to
    // f64 once (rows in `data.x` are already padded to `in_dim`; f32 ->
    // f64 -> f32 round-trips exactly).
    let mut es_x = FeatureMatrixBuf::new();
    let mut es_row: Vec<f64> = Vec::with_capacity(v.in_dim);
    for &i in es_idx {
        es_row.clear();
        es_row.extend(data.x[i].iter().map(|&f| f as f64));
        es_x.push_row(&es_row);
    }

    let max_epochs = 200usize;
    let patience = 50usize;
    let wd = 1e-4f32;
    let mut best_loss = f64::INFINITY;
    let mut best_params = params.clone();
    let mut since_best = 0usize;
    let mut t_step = 0f32;

    let steps_per_epoch = tr_idx.len().div_ceil(b).max(1);
    for _epoch in 0..max_epochs {
        for s in 0..steps_per_epoch {
            t_step += 1.0;
            // Assemble a batch (wrapping) with mask for padding rows.
            let mut xb = vec![0f32; b * v.in_dim];
            let mut yb = vec![1f32; b];
            let mut mask = vec![0f32; b];
            for r in 0..b {
                let k = s * b + r;
                if k >= tr_idx.len() {
                    break;
                }
                let i = tr_idx[k];
                xb[r * v.in_dim..(r + 1) * v.in_dim].copy_from_slice(&data.x[i]);
                yb[r] = data.y[i];
                mask[r] = 1.0;
            }
            let mut inputs = vec![
                literal_f32(&xb, &[b as i64, v.in_dim as i64])?,
                literal_f32(&yb, &[b as i64])?,
                literal_f32(&mask, &[b as i64])?,
                xla::Literal::scalar(t_step),
                xla::Literal::scalar(lr),
                xla::Literal::scalar(wd),
            ];
            for (p, sh) in params.iter().zip(&v.param_shapes) {
                inputs.push(literal_f32(p, sh)?);
            }
            for (p, sh) in m.iter().zip(&v.param_shapes) {
                inputs.push(literal_f32(p, sh)?);
            }
            for (p, sh) in vv.iter().zip(&v.param_shapes) {
                inputs.push(literal_f32(p, sh)?);
            }
            let outs = v.train.run(&inputs)?;
            // outs: [loss, params..., m..., v...]
            let np = v.param_shapes.len();
            if outs.len() != 1 + 3 * np {
                return Err(anyhow!("train step returned {} outputs, expected {}", outs.len(), 1 + 3 * np));
            }
            for (k, p) in params.iter_mut().enumerate() {
                *p = to_vec_f32(&outs[1 + k])?;
            }
            for (k, p) in m.iter_mut().enumerate() {
                *p = to_vec_f32(&outs[1 + np + k])?;
            }
            for (k, p) in vv.iter_mut().enumerate() {
                *p = to_vec_f32(&outs[1 + 2 * np + k])?;
            }
        }
        // Early-stopping check on the held-out slice.
        let model = MlpModel { ctx, variant: vi, params: params.clone() };
        let pred = model.predict_batch(&es_x.view());
        let mut loss = 0.0f64;
        for (p, &i) in pred.iter().zip(es_idx) {
            let e = (*p as f64 - data.y[i] as f64) / data.y[i].max(1e-9) as f64;
            loss += e * e;
        }
        loss /= es_idx.len() as f64;
        if loss < best_loss {
            best_loss = loss;
            best_params = params.clone();
            since_best = 0;
        } else {
            since_best += 1;
            if since_best * steps_per_epoch >= patience {
                break;
            }
        }
    }
    Ok(best_params)
}

impl<'c> Regressor for MlpModel<'c> {
    fn predict_one(&self, x: &[f64]) -> f64 {
        let mut m = FeatureMatrixBuf::new();
        m.push_row(x);
        self.predict_batch(&m.view())[0] as f64
    }

    /// THE f32 cast point: the AOT forward pass computes in f32, so this
    /// is the single place where [`predict_batch`](MlpModel::predict_batch)
    /// output widens to the trait's `f64` return. Every other `Regressor`
    /// computes in f64 end to end.
    fn predict(&self, xs: &FeatureMatrix<'_>) -> Vec<f64> {
        self.predict_batch(xs).into_iter().map(|p| p as f64).collect()
    }
}
