//! Gradient-Boosted Decision Trees: least-squares boosting with shrinkage
//! on 1/y²-weighted loss (percentage error). Hyperparameters per the paper
//! (Section 4.2): number of boosting stages in 1..200 and min samples to
//! split in 2..7, tuned by 5-fold CV.

use crate::predict::cv;
use crate::predict::tree::{Tree, TreeParams};
use crate::predict::{soa, FeatureMatrix, Regressor};
use crate::util::Json;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GbdtParams {
    pub n_stages: usize,
    pub min_samples_split: usize,
    pub learning_rate: f64,
    pub max_depth: usize,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams { n_stages: 100, min_samples_split: 2, learning_rate: 0.1, max_depth: 4 }
    }
}

#[derive(Debug, Clone)]
pub struct Gbdt {
    pub init: f64,
    pub trees: Vec<Tree>,
    pub params: GbdtParams,
}

impl Gbdt {
    pub fn fit(x: &[Vec<f64>], y: &[f64], params: GbdtParams, seed: u64) -> Gbdt {
        let n = x.len();
        let w: Vec<f64> = y.iter().map(|&yi| 1.0 / (yi * yi).max(1e-18)).collect();
        let sw: f64 = w.iter().sum();
        let init = w.iter().zip(y).map(|(wi, yi)| wi * yi).sum::<f64>() / sw;
        let mut pred = vec![init; n];
        let mut trees = Vec::with_capacity(params.n_stages);
        let tp = TreeParams {
            max_depth: params.max_depth,
            min_samples_split: params.min_samples_split,
            max_features: None,
        };
        for stage in 0..params.n_stages {
            let resid: Vec<f64> = y.iter().zip(&pred).map(|(yi, pi)| yi - pi).collect();
            // Weighted leaf means are the optimal step for weighted L2.
            let t = Tree::fit(x, &resid, Some(&w), tp, seed.wrapping_add(stage as u64));
            for (pi, xi) in pred.iter_mut().zip(x) {
                *pi += params.learning_rate * t.predict_one(xi);
            }
            trees.push(t);
        }
        Gbdt { init, trees, params }
    }

    /// Grid search over the paper's ranges (stages 1..200, min split 2..7).
    ///
    /// Staged evaluation: boosting is incremental, so one 200-stage fit per
    /// (fold, min_split) yields the CV error at *every* checkpoint — 2x5
    /// full fits instead of 6x5 partial ones (EXPERIMENTS.md §Perf).
    pub fn fit_cv(x: &[Vec<f64>], y: &[f64], seed: u64) -> Gbdt {
        const CHECKPOINTS: [usize; 3] = [25, 100, 200];
        const SPLITS: [usize; 2] = [2, 7];
        if x.len() < 10 {
            return Gbdt::fit(x, y, GbdtParams::default(), seed);
        }
        let folds = cv::kfold(x.len(), 5, seed);
        let mut best = (f64::INFINITY, GbdtParams::default());
        for &mss in &SPLITS {
            // Accumulated |rel err| per checkpoint across folds.
            let mut errs = [0.0f64; CHECKPOINTS.len()];
            let mut counts = [0usize; CHECKPOINTS.len()];
            for (tr, te) in &folds {
                let xt = cv::take(x, tr);
                let yt = cv::take(y, tr);
                let params = GbdtParams {
                    n_stages: *CHECKPOINTS.last().unwrap(),
                    min_samples_split: mss,
                    ..Default::default()
                };
                let model = Gbdt::fit(&xt, &yt, params, seed);
                // Evaluate incrementally: running prediction per test row.
                let mut preds: Vec<f64> = te.iter().map(|_| model.init).collect();
                let mut stage = 0usize;
                for (ci, &ck) in CHECKPOINTS.iter().enumerate() {
                    while stage < ck.min(model.trees.len()) {
                        for (p, &i) in preds.iter_mut().zip(te.iter()) {
                            *p += model.params.learning_rate * model.trees[stage].predict_one(&x[i]);
                        }
                        stage += 1;
                    }
                    for (p, &i) in preds.iter().zip(te.iter()) {
                        errs[ci] += ((p.max(1e-9) - y[i]) / y[i]).abs();
                        counts[ci] += 1;
                    }
                }
            }
            for (ci, &ck) in CHECKPOINTS.iter().enumerate() {
                let m = errs[ci] / counts[ci].max(1) as f64;
                if m < best.0 {
                    best = (
                        m,
                        GbdtParams { n_stages: ck, min_samples_split: mss, ..Default::default() },
                    );
                }
            }
        }
        Gbdt::fit(x, y, best.1, seed)
    }

    /// Serialize for `engine::bundle` (init/shrinkage/trees round-trip
    /// bit-exactly, so boosted predictions are reproduced bit-identically).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("gbdt")),
            ("init", Json::Num(self.init)),
            ("n_stages", Json::Num(self.params.n_stages as f64)),
            ("min_samples_split", Json::Num(self.params.min_samples_split as f64)),
            ("learning_rate", Json::Num(self.params.learning_rate)),
            ("max_depth", Json::Num(self.params.max_depth as f64)),
            ("trees", Json::Arr(self.trees.iter().map(Tree::to_json).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Gbdt, String> {
        let trees: Vec<Tree> = j
            .req("trees")?
            .as_arr()
            .ok_or("gbdt: 'trees' is not an array")?
            .iter()
            .enumerate()
            .map(|(i, t)| Tree::from_json(t).map_err(|e| format!("gbdt tree {i}: {e}")))
            .collect::<Result<_, _>>()?;
        if trees.is_empty() {
            // fit_cv always boosts at least one stage; an empty ensemble
            // means a truncated/corrupted bundle, not a trained model.
            return Err("gbdt: no trees".into());
        }
        let init = j.req_f64("init")?;
        let learning_rate = j.req_f64("learning_rate")?;
        if !init.is_finite() || !learning_rate.is_finite() {
            return Err("gbdt: non-finite init/learning_rate".into());
        }
        Ok(Gbdt {
            init,
            trees,
            params: GbdtParams {
                n_stages: j.req_usize("n_stages")?,
                min_samples_split: j.req_usize("min_samples_split")?,
                learning_rate,
                max_depth: j.req_usize("max_depth")?,
            },
        })
    }
}

impl Regressor for Gbdt {
    fn predict_one(&self, x: &[f64]) -> f64 {
        let mut p = self.init;
        for t in &self.trees {
            p += self.params.learning_rate * t.predict_one(x);
        }
        p
    }

    /// Level-synchronous SoA walk over the whole matrix (`predict::soa`):
    /// per row, stages accumulate `learning_rate * leaf` onto `init` in
    /// stage order — the exact operation sequence of `predict_one`, so
    /// results are bit-identical.
    fn predict(&self, xs: &FeatureMatrix<'_>) -> Vec<f64> {
        let k = soa::EnsembleKernel::from_trees(
            &self.trees,
            self.init,
            self.params.learning_rate,
            1.0,
        );
        soa::ensemble_predict_matrix(&k, xs, |x| self.predict_one(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mape;

    #[test]
    fn gbdt_fits_roofline_target_well() {
        let (x, y) = crate::predict::toy_problem(600, 1);
        let (xt, yt) = crate::predict::toy_problem(150, 2);
        let m = Gbdt::fit(&x, &y, GbdtParams::default(), 3);
        let pred: Vec<f64> = xt.iter().map(|v| m.predict_one(v)).collect();
        assert!(mape(&pred, &yt) < 0.08, "mape={}", mape(&pred, &yt));
    }

    #[test]
    fn more_stages_fit_train_better() {
        let (x, y) = crate::predict::toy_problem(300, 4);
        let train_err = |stages: usize| {
            let m = Gbdt::fit(&x, &y, GbdtParams { n_stages: stages, ..Default::default() }, 5);
            mape(&x.iter().map(|v| m.predict_one(v)).collect::<Vec<_>>(), &y)
        };
        assert!(train_err(100) < train_err(5));
    }

    #[test]
    fn cv_params_in_paper_ranges() {
        let (x, y) = crate::predict::toy_problem(200, 6);
        let m = Gbdt::fit_cv(&x, &y, 7);
        assert!((1..=200).contains(&m.params.n_stages));
        assert!((2..=7).contains(&m.params.min_samples_split));
    }

    #[test]
    fn json_roundtrip_is_bit_identical() {
        let (x, y) = crate::predict::toy_problem(150, 14);
        let m = Gbdt::fit(&x, &y, GbdtParams { n_stages: 30, ..Default::default() }, 9);
        let back = Gbdt::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.init.to_bits(), m.init.to_bits());
        assert_eq!(back.trees.len(), m.trees.len());
        for v in x.iter().take(30) {
            assert_eq!(m.predict_one(v).to_bits(), back.predict_one(v).to_bits());
        }
    }

    #[test]
    fn init_is_weighted_mean() {
        let x = vec![vec![0.0]; 3];
        let y = vec![1.0, 10.0, 100.0];
        let m = Gbdt::fit(&x, &y, GbdtParams { n_stages: 0, ..Default::default() }, 0);
        // weights 1, 0.01, 0.0001 -> weighted mean close to 1.2ish
        let w: Vec<f64> = y.iter().map(|&v| 1.0 / (v * v)).collect();
        let expect = w.iter().zip(&y).map(|(a, b)| a * b).sum::<f64>() / w.iter().sum::<f64>();
        assert!((m.init - expect).abs() < 1e-12);
    }
}
