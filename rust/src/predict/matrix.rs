//! Borrowed feature-matrix views — the batch-prediction primitive.
//!
//! [`FeatureMatrix`] is a zero-copy view over a flat `&[f64]` arena, either
//! dense (every row the same width) or ragged (explicit row offsets, the
//! same layout as `plan::LoweredGraph`'s feature arena). It is the argument
//! type of [`Regressor::predict`](crate::predict::Regressor::predict): hot
//! callers hand whole matrices to the vectorized SoA kernels instead of
//! cloning per-row `Vec<f64>`s.
//!
//! [`FeatureMatrixBuf`] is the owned builder for callers that gather rows
//! (cross-validation folds, MLP validation splits) before predicting.

/// A borrowed, read-only matrix of feature rows over a flat value arena.
#[derive(Clone, Copy)]
pub struct FeatureMatrix<'a> {
    values: &'a [f64],
    /// Dense row width; ignored when `offsets` is present.
    width: usize,
    /// Ragged layout: `offsets[i]..offsets[i+1]` is row `i` (first entry 0).
    offsets: Option<&'a [u32]>,
}

impl<'a> FeatureMatrix<'a> {
    /// Dense view: `values` holds `values.len() / width` rows of `width`
    /// contiguous features each. `width == 0` is only valid for an empty
    /// matrix.
    pub fn dense(values: &'a [f64], width: usize) -> FeatureMatrix<'a> {
        if width == 0 {
            assert!(values.is_empty(), "width-0 matrix must be empty");
        } else {
            assert_eq!(values.len() % width, 0, "arena not a multiple of width");
        }
        FeatureMatrix { values, width, offsets: None }
    }

    /// Ragged view over `values` with explicit row boundaries — the layout
    /// of `plan::LoweredGraph`'s feature arena. `offsets` must start at 0,
    /// be non-decreasing, and end at `values.len()`.
    pub fn with_offsets(values: &'a [f64], offsets: &'a [u32]) -> FeatureMatrix<'a> {
        assert!(!offsets.is_empty() && offsets[0] == 0, "offsets must start at 0");
        assert_eq!(*offsets.last().unwrap() as usize, values.len());
        debug_assert!(offsets.windows(2).all(|w| w[0] <= w[1]));
        FeatureMatrix { values, width: 0, offsets: Some(offsets) }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        match self.offsets {
            Some(o) => o.len() - 1,
            None => {
                if self.width == 0 {
                    0
                } else {
                    self.values.len() / self.width
                }
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Row `i` as a feature slice.
    pub fn row(&self, i: usize) -> &'a [f64] {
        match self.offsets {
            Some(o) => &self.values[o[i] as usize..o[i + 1] as usize],
            None => &self.values[i * self.width..(i + 1) * self.width],
        }
    }

    /// Iterate rows in order.
    pub fn rows(&self) -> impl Iterator<Item = &'a [f64]> + '_ {
        (0..self.len()).map(|i| self.row(i))
    }

    /// `Some(w)` when every row has the same width `w` (so [`values`]
    /// (Self::values) is a dense row-major matrix the SoA kernels can walk
    /// directly), `None` for genuinely ragged views. O(rows) for
    /// offset-based views, O(1) for dense ones.
    pub fn uniform_width(&self) -> Option<usize> {
        match self.offsets {
            None => Some(self.width),
            Some(o) => {
                if o.len() < 2 {
                    // Zero rows: trivially uniform (width 0, empty arena).
                    return Some(0);
                }
                let w = (o[1] - o[0]) as usize;
                o.windows(2).all(|p| (p[1] - p[0]) as usize == w).then_some(w)
            }
        }
    }

    /// The flat row-major value arena backing this view.
    pub fn values(&self) -> &'a [f64] {
        self.values
    }
}

/// Owned builder for a [`FeatureMatrix`]: push rows (any widths), then
/// [`view`](Self::view) borrows them as the matrix primitive. Rows land in
/// one flat arena — no per-row allocation.
#[derive(Default, Clone)]
pub struct FeatureMatrixBuf {
    values: Vec<f64>,
    offsets: Vec<u32>,
}

impl FeatureMatrixBuf {
    pub fn new() -> FeatureMatrixBuf {
        FeatureMatrixBuf { values: Vec::new(), offsets: vec![0] }
    }

    /// Build from per-row `Vec`s (test/bridge convenience).
    pub fn from_rows<R: AsRef<[f64]>>(rows: &[R]) -> FeatureMatrixBuf {
        let mut b = FeatureMatrixBuf::new();
        for r in rows {
            b.push_row(r.as_ref());
        }
        b
    }

    pub fn push_row(&mut self, row: &[f64]) {
        self.values.extend_from_slice(row);
        self.offsets.push(self.values.len() as u32);
    }

    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn clear(&mut self) {
        self.values.clear();
        self.offsets.truncate(1);
    }

    pub fn view(&self) -> FeatureMatrix<'_> {
        FeatureMatrix::with_offsets(&self.values, &self.offsets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_view_rows() {
        let vals = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let m = FeatureMatrix::dense(&vals, 3);
        assert_eq!(m.len(), 2);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.uniform_width(), Some(3));
        assert_eq!(m.rows().count(), 2);
    }

    #[test]
    fn ragged_buf_roundtrip() {
        let mut b = FeatureMatrixBuf::new();
        b.push_row(&[1.0, 2.0]);
        b.push_row(&[3.0]);
        b.push_row(&[]);
        let m = b.view();
        assert_eq!(m.len(), 3);
        assert_eq!(m.row(0), &[1.0, 2.0]);
        assert_eq!(m.row(1), &[3.0]);
        assert_eq!(m.row(2), &[] as &[f64]);
        assert_eq!(m.uniform_width(), None);
    }

    #[test]
    fn uniform_offsets_detected() {
        let b = FeatureMatrixBuf::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let m = b.view();
        assert_eq!(m.uniform_width(), Some(2));
        // Uniform offset rows are contiguous: the arena IS the dense matrix.
        assert_eq!(m.values(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn empty_matrices() {
        let m = FeatureMatrix::dense(&[], 0);
        assert!(m.is_empty());
        let b = FeatureMatrixBuf::new();
        assert!(b.is_empty());
        assert_eq!(b.view().uniform_width(), Some(0));
    }

    #[test]
    fn clear_resets_buf() {
        let mut b = FeatureMatrixBuf::from_rows(&[vec![1.0]]);
        b.clear();
        assert!(b.is_empty());
        b.push_row(&[9.0, 8.0]);
        assert_eq!(b.view().row(0), &[9.0, 8.0]);
    }
}
