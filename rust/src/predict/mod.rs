//! Per-operation latency predictors (Section 4.2): Lasso, Random Forest,
//! Gradient-Boosted Decision Trees — implemented from scratch (no ML crates
//! offline) — plus the AOT-compiled JAX/Pallas MLP driven through PJRT
//! (`predict::mlp`, see `runtime`).
//!
//! All models minimize the (root-)mean-square *percentage* error on
//! standardized features, matching the paper's objective; hyperparameters
//! are tuned by 5-fold cross-validation as described per method.

pub mod cv;
pub mod forest;
pub mod gbdt;
pub mod lasso;
pub mod mlp;
pub mod tree;

use crate::features::Standardizer;


/// A trained regressor over standardized feature vectors.
///
/// Not `Send`: the MLP variant holds PJRT handles. Training and evaluation
/// parallelism lives in the profiler (pure simulation), not in the models.
pub trait Regressor {
    fn predict_one(&self, x: &[f64]) -> f64;

    fn predict(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict_one(x)).collect()
    }
}

/// The ML methods compared throughout Section 5.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Lasso,
    RandomForest,
    Gbdt,
    /// AOT JAX/Pallas MLP; requires `artifacts/` (see `predict::mlp`).
    Mlp,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Lasso => "Lasso",
            Method::RandomForest => "RF",
            Method::Gbdt => "GBDT",
            Method::Mlp => "MLP",
        }
    }

    pub fn all() -> &'static [Method] {
        &[Method::Lasso, Method::RandomForest, Method::Gbdt, Method::Mlp]
    }

    /// The three methods that train without AOT artifacts.
    pub fn native() -> &'static [Method] {
        &[Method::Lasso, Method::RandomForest, Method::Gbdt]
    }
}

/// A trained per-bucket model: standardizer + regressor + target floor.
/// The lifetime ties MLP models to their PJRT context.
pub struct TrainedModel<'a> {
    pub standardizer: Standardizer,
    pub inner: Box<dyn Regressor + 'a>,
    /// Predictions are clamped to this floor (a fraction of the smallest
    /// training latency) — latency is positive.
    pub floor: f64,
}

impl<'a> TrainedModel<'a> {
    pub fn predict_raw(&self, x: &[f64]) -> f64 {
        let xs = self.standardizer.transform(x);
        self.inner.predict_one(&xs).max(self.floor)
    }
}

/// Train a model of the given method on (features, latency) data.
///
/// `mlp_ctx` supplies the PJRT runtime context when `method == Mlp`; the
/// native methods ignore it.
pub fn train<'a>(
    method: Method,
    x: &[Vec<f64>],
    y: &[f64],
    seed: u64,
    mlp_ctx: Option<&'a mlp::MlpContext>,
) -> TrainedModel<'a> {
    assert_eq!(x.len(), y.len());
    assert!(!x.is_empty(), "cannot train on empty dataset");
    let standardizer = Standardizer::fit(x);
    let xs = standardizer.transform_all(x);
    let floor = y.iter().copied().fold(f64::INFINITY, f64::min) * 0.1;
    let inner: Box<dyn Regressor + 'a> = match method {
        Method::Lasso => Box::new(lasso::Lasso::fit_cv(&xs, y, seed)),
        Method::RandomForest => Box::new(forest::RandomForest::fit_cv(&xs, y, seed)),
        Method::Gbdt => Box::new(gbdt::Gbdt::fit_cv(&xs, y, seed)),
        Method::Mlp => {
            let ctx = mlp_ctx.expect("MLP training requires an MlpContext (artifacts)");
            Box::new(mlp::MlpModel::fit(ctx, &xs, y, seed))
        }
    };
    TrainedModel { standardizer, inner, floor }
}

/// Generate a synthetic regression problem for predictor unit tests:
/// y = roofline-like max(a*flops, b*mem) + noise over 3 features.
#[cfg(test)]
pub(crate) fn toy_problem(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = crate::util::Rng::new(seed);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let flops = rng.range_f64(1.0, 100.0);
        let mem = rng.range_f64(1.0, 100.0);
        let k = rng.range_f64(1.0, 7.0);
        let target = (0.8 * flops).max(0.5 * mem) + 0.05 * k;
        x.push(vec![flops, mem, k]);
        y.push(target * rng.lognormal_unit_mean(0.02));
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mape;

    #[test]
    fn all_native_methods_fit_toy_problem() {
        let (x, y) = toy_problem(400, 3);
        let (xt, yt) = toy_problem(100, 4);
        for m in Method::native() {
            let model = train(*m, &x, &y, 7, None);
            let pred: Vec<f64> = xt.iter().map(|v| model.predict_raw(v)).collect();
            let err = mape(&pred, &yt);
            let bound = match m {
                Method::Lasso => 0.30, // linear model on a max() target
                _ => 0.12,
            };
            assert!(err < bound, "{}: mape={err}", m.name());
        }
    }

    #[test]
    fn nonlinear_methods_beat_lasso_on_roofline() {
        let (x, y) = toy_problem(600, 5);
        let (xt, yt) = toy_problem(150, 6);
        let errs: Vec<f64> = Method::native()
            .iter()
            .map(|m| {
                let model = train(*m, &x, &y, 11, None);
                mape(&xt.iter().map(|v| model.predict_raw(v)).collect::<Vec<_>>(), &yt)
            })
            .collect();
        // Lasso is index 0; trees should beat it on the nonlinear target.
        assert!(errs[1] < errs[0], "RF {} vs Lasso {}", errs[1], errs[0]);
        assert!(errs[2] < errs[0], "GBDT {} vs Lasso {}", errs[2], errs[0]);
    }

    #[test]
    fn predictions_clamped_positive() {
        let (x, y) = toy_problem(100, 8);
        let model = train(Method::Lasso, &x, &y, 1, None);
        // Extreme extrapolation must not go negative.
        let p = model.predict_raw(&[-1e6, -1e6, -1e6]);
        assert!(p > 0.0);
    }

    #[test]
    fn training_deterministic_in_seed() {
        let (x, y) = toy_problem(200, 9);
        let a = train(Method::Gbdt, &x, &y, 42, None);
        let b = train(Method::Gbdt, &x, &y, 42, None);
        for v in x.iter().take(20) {
            assert_eq!(a.predict_raw(v), b.predict_raw(v));
        }
    }
}
