//! Per-operation latency predictors (Section 4.2): Lasso, Random Forest,
//! Gradient-Boosted Decision Trees — implemented from scratch (no ML crates
//! offline) — plus the AOT-compiled JAX/Pallas MLP driven through PJRT
//! (`predict::mlp`, see `runtime`).
//!
//! All models minimize the (root-)mean-square *percentage* error on
//! standardized features, matching the paper's objective; hyperparameters
//! are tuned by 5-fold cross-validation as described per method.

pub mod cv;
pub mod forest;
pub mod gbdt;
pub mod lasso;
pub mod lut;
pub mod matrix;
pub mod mlp;
pub(crate) mod soa;
pub mod tree;

use crate::features::Standardizer;
use crate::util::Json;

pub use matrix::{FeatureMatrix, FeatureMatrixBuf};

/// A trained regressor over standardized feature vectors.
///
/// Implementations need not be `Send`: the MLP variant holds PJRT handles.
/// The serving path (`engine`) only uses the owned [`NativeModel`] variants,
/// which are `Send + Sync`.
pub trait Regressor {
    fn predict_one(&self, x: &[f64]) -> f64;

    /// Batch-predict over a borrowed [`FeatureMatrix`] — the one
    /// batch-prediction primitive. The default walks rows through
    /// [`predict_one`](Self::predict_one); the native models override it
    /// with the vectorized SoA kernels (`predict::soa`), which are
    /// bit-identical to that row loop.
    fn predict(&self, xs: &FeatureMatrix<'_>) -> Vec<f64> {
        xs.rows().map(|x| self.predict_one(x)).collect()
    }
}

/// The ML methods compared throughout Section 5.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Lasso,
    RandomForest,
    Gbdt,
    /// AOT JAX/Pallas MLP; requires `artifacts/` (see `predict::mlp`).
    Mlp,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Lasso => "Lasso",
            Method::RandomForest => "RF",
            Method::Gbdt => "GBDT",
            Method::Mlp => "MLP",
        }
    }

    pub fn all() -> &'static [Method] {
        &[Method::Lasso, Method::RandomForest, Method::Gbdt, Method::Mlp]
    }

    /// The three methods that train without AOT artifacts.
    pub fn native() -> &'static [Method] {
        &[Method::Lasso, Method::RandomForest, Method::Gbdt]
    }

    /// Parse a method name as accepted by the CLI and bundle files.
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "lasso" => Some(Method::Lasso),
            "rf" | "randomforest" | "random_forest" => Some(Method::RandomForest),
            "gbdt" => Some(Method::Gbdt),
            "mlp" => Some(Method::Mlp),
            _ => None,
        }
    }
}

/// An owned, serializable regressor — the three from-scratch methods. Unlike
/// the MLP (PJRT handles), these are plain data: `Send + Sync`, cloneable,
/// and JSON round-trippable, which is what lets `engine::PredictorBundle`
/// persist a trained predictor and serve it without retraining.
#[derive(Clone)]
pub enum NativeModel {
    Lasso(lasso::Lasso),
    RandomForest(forest::RandomForest),
    Gbdt(gbdt::Gbdt),
}

impl NativeModel {
    pub fn method(&self) -> Method {
        match self {
            NativeModel::Lasso(_) => Method::Lasso,
            NativeModel::RandomForest(_) => Method::RandomForest,
            NativeModel::Gbdt(_) => Method::Gbdt,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            NativeModel::Lasso(m) => m.to_json(),
            NativeModel::RandomForest(m) => m.to_json(),
            NativeModel::Gbdt(m) => m.to_json(),
        }
    }

    /// Dispatch on the `kind` tag written by each model's `to_json`.
    pub fn from_json(j: &Json) -> Result<NativeModel, String> {
        match j.req_str("kind")? {
            "lasso" => lasso::Lasso::from_json(j).map(NativeModel::Lasso),
            "rf" => forest::RandomForest::from_json(j).map(NativeModel::RandomForest),
            "gbdt" => gbdt::Gbdt::from_json(j).map(NativeModel::Gbdt),
            other => Err(format!("unknown model kind '{other}'")),
        }
    }
}

impl Regressor for NativeModel {
    fn predict_one(&self, x: &[f64]) -> f64 {
        match self {
            NativeModel::Lasso(m) => m.predict_one(x),
            NativeModel::RandomForest(m) => m.predict_one(x),
            NativeModel::Gbdt(m) => m.predict_one(x),
        }
    }

    fn predict(&self, xs: &FeatureMatrix<'_>) -> Vec<f64> {
        // Dispatch to each model's vectorized override.
        match self {
            NativeModel::Lasso(m) => m.predict(xs),
            NativeModel::RandomForest(m) => m.predict(xs),
            NativeModel::Gbdt(m) => m.predict(xs),
        }
    }
}

/// An owned trained per-bucket model: standardizer + native regressor +
/// target floor. The deployable unit of the serving engine.
#[derive(Clone)]
pub struct BucketModel {
    pub standardizer: Standardizer,
    pub model: NativeModel,
    /// Predictions are clamped to this floor (a fraction of the smallest
    /// training latency) — latency is positive.
    pub floor: f64,
}

impl BucketModel {
    pub fn predict_raw(&self, x: &[f64]) -> f64 {
        let mut scratch = Vec::with_capacity(x.len());
        self.predict_raw_with(x, &mut scratch)
    }

    /// [`predict_raw`](Self::predict_raw) with a caller-provided
    /// standardization buffer — the plan hot paths reuse one scratch `Vec`
    /// across every unit instead of allocating per prediction. Bit-identical
    /// to the allocating variant.
    pub fn predict_raw_with(&self, x: &[f64], scratch: &mut Vec<f64>) -> f64 {
        self.standardizer.transform_into(x, scratch);
        self.model.predict_one(scratch).max(self.floor)
    }

    /// Feature-vector width this model was trained on.
    pub fn feature_dim(&self) -> usize {
        self.standardizer.mean.len()
    }

    /// Train an owned model with one of the native methods.
    ///
    /// Panics if `method == Method::Mlp` — the MLP stays engine-external
    /// behind the [`Regressor`] trait (see [`train`]).
    pub fn train_native(method: Method, x: &[Vec<f64>], y: &[f64], seed: u64) -> BucketModel {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty(), "cannot train on empty dataset");
        let standardizer = Standardizer::fit(x);
        let xs = standardizer.transform_all(x);
        let floor = y.iter().copied().fold(f64::INFINITY, f64::min) * 0.1;
        let model = match method {
            Method::Lasso => NativeModel::Lasso(lasso::Lasso::fit_cv(&xs, y, seed)),
            Method::RandomForest => {
                NativeModel::RandomForest(forest::RandomForest::fit_cv(&xs, y, seed))
            }
            Method::Gbdt => NativeModel::Gbdt(gbdt::Gbdt::fit_cv(&xs, y, seed)),
            Method::Mlp => panic!("MLP is not a native serializable model"),
        };
        BucketModel { standardizer, model, floor }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dim", Json::Num(self.feature_dim() as f64)),
            ("floor", Json::Num(self.floor)),
            ("standardizer", self.standardizer.to_json()),
            ("model", self.model.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<BucketModel, String> {
        let standardizer = Standardizer::from_json(j.req("standardizer")?)?;
        let floor = j.req_f64("floor")?;
        if !floor.is_finite() {
            return Err("non-finite floor".into());
        }
        let model = NativeModel::from_json(j.req("model")?)?;
        let dim = j.req_usize("dim")?;
        if standardizer.mean.len() != dim {
            return Err(format!(
                "feature dim mismatch: standardizer has {}, metadata says {dim}",
                standardizer.mean.len()
            ));
        }
        match &model {
            NativeModel::Lasso(l) => {
                if l.weights.len() != dim {
                    return Err(format!(
                        "feature dim mismatch: lasso has {} weights, metadata says {dim}",
                        l.weights.len()
                    ));
                }
            }
            // Tree splits must index inside the feature vector, or a
            // corrupted bundle would panic at prediction time.
            NativeModel::RandomForest(forest::RandomForest { trees, .. })
            | NativeModel::Gbdt(gbdt::Gbdt { trees, .. }) => {
                if let Some(mf) = trees.iter().filter_map(|t| t.max_feature_index()).max() {
                    if mf >= dim {
                        return Err(format!(
                            "feature dim mismatch: a tree splits on feature {mf}, metadata says {dim}"
                        ));
                    }
                }
            }
        }
        Ok(BucketModel { standardizer, model, floor })
    }
}

/// A trained per-bucket model as used by `framework::ScenarioPredictor`:
/// either an owned serializable [`BucketModel`], or an engine-external
/// regressor (the MLP, whose lifetime ties it to its PJRT context).
pub enum TrainedModel<'a> {
    Owned(BucketModel),
    External {
        standardizer: Standardizer,
        inner: Box<dyn Regressor + 'a>,
        floor: f64,
    },
}

impl<'a> TrainedModel<'a> {
    pub fn predict_raw(&self, x: &[f64]) -> f64 {
        let mut scratch = Vec::with_capacity(x.len());
        self.predict_raw_with(x, &mut scratch)
    }

    /// Scratch-buffer variant of [`predict_raw`](Self::predict_raw); see
    /// [`BucketModel::predict_raw_with`].
    pub fn predict_raw_with(&self, x: &[f64], scratch: &mut Vec<f64>) -> f64 {
        match self {
            TrainedModel::Owned(m) => m.predict_raw_with(x, scratch),
            TrainedModel::External { standardizer, inner, floor } => {
                standardizer.transform_into(x, scratch);
                inner.predict_one(scratch).max(*floor)
            }
        }
    }

    /// The owned serializable model, if this is not an MLP.
    pub fn as_owned(&self) -> Option<&BucketModel> {
        match self {
            TrainedModel::Owned(m) => Some(m),
            TrainedModel::External { .. } => None,
        }
    }

    /// Feature-vector width this model was trained on.
    pub fn feature_dim(&self) -> usize {
        match self {
            TrainedModel::Owned(m) => m.feature_dim(),
            TrainedModel::External { standardizer, .. } => standardizer.mean.len(),
        }
    }
}

/// Train a model of the given method on (features, latency) data.
///
/// `mlp_ctx` supplies the PJRT runtime context when `method == Mlp`; the
/// native methods ignore it and produce owned serializable models.
pub fn train<'a>(
    method: Method,
    x: &[Vec<f64>],
    y: &[f64],
    seed: u64,
    mlp_ctx: Option<&'a mlp::MlpContext>,
) -> TrainedModel<'a> {
    assert_eq!(x.len(), y.len());
    assert!(!x.is_empty(), "cannot train on empty dataset");
    if method == Method::Mlp {
        let standardizer = Standardizer::fit(x);
        let xs = standardizer.transform_all(x);
        let floor = y.iter().copied().fold(f64::INFINITY, f64::min) * 0.1;
        let ctx = mlp_ctx.expect("MLP training requires an MlpContext (artifacts)");
        let inner: Box<dyn Regressor + 'a> = Box::new(mlp::MlpModel::fit(ctx, &xs, y, seed));
        return TrainedModel::External { standardizer, inner, floor };
    }
    TrainedModel::Owned(BucketModel::train_native(method, x, y, seed))
}

/// Generate a synthetic regression problem for predictor unit tests:
/// y = roofline-like max(a*flops, b*mem) + noise over 3 features.
#[cfg(test)]
pub(crate) fn toy_problem(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = crate::util::Rng::new(seed);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let flops = rng.range_f64(1.0, 100.0);
        let mem = rng.range_f64(1.0, 100.0);
        let k = rng.range_f64(1.0, 7.0);
        let target = (0.8 * flops).max(0.5 * mem) + 0.05 * k;
        x.push(vec![flops, mem, k]);
        y.push(target * rng.lognormal_unit_mean(0.02));
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mape;

    #[test]
    fn all_native_methods_fit_toy_problem() {
        let (x, y) = toy_problem(400, 3);
        let (xt, yt) = toy_problem(100, 4);
        for m in Method::native() {
            let model = train(*m, &x, &y, 7, None);
            let pred: Vec<f64> = xt.iter().map(|v| model.predict_raw(v)).collect();
            let err = mape(&pred, &yt);
            let bound = match m {
                Method::Lasso => 0.30, // linear model on a max() target
                _ => 0.12,
            };
            assert!(err < bound, "{}: mape={err}", m.name());
        }
    }

    #[test]
    fn nonlinear_methods_beat_lasso_on_roofline() {
        let (x, y) = toy_problem(600, 5);
        let (xt, yt) = toy_problem(150, 6);
        let errs: Vec<f64> = Method::native()
            .iter()
            .map(|m| {
                let model = train(*m, &x, &y, 11, None);
                mape(&xt.iter().map(|v| model.predict_raw(v)).collect::<Vec<_>>(), &yt)
            })
            .collect();
        // Lasso is index 0; trees should beat it on the nonlinear target.
        assert!(errs[1] < errs[0], "RF {} vs Lasso {}", errs[1], errs[0]);
        assert!(errs[2] < errs[0], "GBDT {} vs Lasso {}", errs[2], errs[0]);
    }

    #[test]
    fn predictions_clamped_positive() {
        let (x, y) = toy_problem(100, 8);
        let model = train(Method::Lasso, &x, &y, 1, None);
        // Extreme extrapolation must not go negative.
        let p = model.predict_raw(&[-1e6, -1e6, -1e6]);
        assert!(p > 0.0);
    }

    #[test]
    fn method_parse_roundtrips_names() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.name()), Some(*m), "{}", m.name());
        }
        assert_eq!(Method::parse("randomforest"), Some(Method::RandomForest));
        assert_eq!(Method::parse("nope"), None);
    }

    #[test]
    fn native_training_yields_owned_models() {
        let (x, y) = toy_problem(120, 21);
        for m in Method::native() {
            let model = train(*m, &x, &y, 3, None);
            let owned = model.as_owned().expect("native methods are owned");
            assert_eq!(owned.model.method(), *m);
            assert_eq!(owned.feature_dim(), 3);
        }
    }

    #[test]
    fn bucket_model_json_roundtrip_bit_identical() {
        let (x, y) = toy_problem(200, 22);
        for m in Method::native() {
            let model = BucketModel::train_native(*m, &x, &y, 5);
            let text = model.to_json().to_string();
            let back =
                BucketModel::from_json(&crate::util::Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.floor.to_bits(), model.floor.to_bits());
            for v in x.iter().take(25) {
                assert_eq!(
                    model.predict_raw(v).to_bits(),
                    back.predict_raw(v).to_bits(),
                    "{}",
                    m.name()
                );
            }
        }
    }

    #[test]
    fn bucket_model_rejects_dim_mismatch() {
        let (x, y) = toy_problem(60, 23);
        let model = BucketModel::train_native(Method::Lasso, &x, &y, 1);
        let mut j = model.to_json();
        if let crate::util::Json::Obj(m) = &mut j {
            m.insert("dim".into(), crate::util::Json::Num(99.0));
        }
        let err = BucketModel::from_json(&j).unwrap_err();
        assert!(err.contains("dim mismatch"), "{err}");
    }

    #[test]
    fn training_deterministic_in_seed() {
        let (x, y) = toy_problem(200, 9);
        let a = train(Method::Gbdt, &x, &y, 42, None);
        let b = train(Method::Gbdt, &x, &y, 42, None);
        for v in x.iter().take(20) {
            assert_eq!(a.predict_raw(v), b.predict_raw(v));
        }
    }
}
