//! Flat structure-of-arrays prediction kernels, evaluated breadth-first
//! over whole feature matrices.
//!
//! The scalar paths (`Tree::predict_one` and friends) walk one row at a
//! time through an enum arena — a chain of unpredictable branches per node.
//! Here every ensemble is compiled once into parallel `feature` /
//! `threshold` / `left` / `right` / `value` arrays and walked
//! *level-synchronously*: all rows of a block advance one step per pass in
//! a tight branch-free-bodied loop the compiler can autovectorize, and
//! Lasso becomes a blocked GEMV over the dense feature arena. The scalar
//! path remains the reference implementation; every kernel is proven
//! bit-identical to it (same operations, same order — see the parity tests
//! here and in `tests/vector_kernels.rs`).
//!
//! Layers above compile kernels once per trained model:
//! `framework::ScenarioPredictor` and the engine both keep a per-bucket
//! [`BucketKernel`] table next to their model table and evaluate whole
//! lowered plans through [`eval_plan_grouped`].

use crate::plan::LoweredGraph;
use crate::predict::lut::LutPack;
use crate::predict::matrix::FeatureMatrix;
use crate::predict::tree::Tree;
use crate::predict::{BucketModel, NativeModel};

/// Rows walked per level-synchronous pass. One block's worth of cursor
/// state lives in a stack array, and its feature rows stay cache-resident
/// across all trees of the ensemble.
const BLOCK: usize = 64;

/// A tree ensemble flattened into one structure-of-arrays node arena.
///
/// Unifies RF and GBDT accumulation: `out[r] = fold(init, += scale *
/// leaf_t(r))`, divided by `divisor` at the end (RF: `init = 0, scale = 1,
/// divisor = n_trees`; GBDT: `init = f0, scale = learning_rate, divisor =
/// 1`). `scale = 1` multiplies and `divisor = 1` skips the division, so
/// both specializations are bit-identical to their scalar formulas.
pub(crate) struct EnsembleKernel {
    feature: Vec<u32>,
    threshold: Vec<f64>,
    left: Vec<u32>,
    right: Vec<u32>,
    value: Vec<f64>,
    /// Absolute root index per tree, in accumulation order.
    roots: Vec<u32>,
    init: f64,
    scale: f64,
    divisor: f64,
    /// Minimum row width any split can index (`max_feature_index + 1`).
    min_width: usize,
}

impl EnsembleKernel {
    pub(crate) fn from_trees(trees: &[Tree], init: f64, scale: f64, divisor: f64) -> EnsembleKernel {
        let total: usize = trees.iter().map(Tree::node_count).sum();
        let mut k = EnsembleKernel {
            feature: Vec::with_capacity(total),
            threshold: Vec::with_capacity(total),
            left: Vec::with_capacity(total),
            right: Vec::with_capacity(total),
            value: Vec::with_capacity(total),
            roots: Vec::with_capacity(trees.len()),
            init,
            scale,
            divisor,
            min_width: trees
                .iter()
                .filter_map(Tree::max_feature_index)
                .max()
                .map_or(0, |f| f + 1),
        };
        for t in trees {
            let root =
                t.flatten_into(&mut k.feature, &mut k.threshold, &mut k.left, &mut k.right, &mut k.value);
            k.roots.push(root);
        }
        k
    }

    pub(crate) fn min_width(&self) -> usize {
        self.min_width
    }

    /// Evaluate all rows of a dense `width`-wide matrix into `out`
    /// (`out.len()` rows). Requires `width >= max(min_width, 1)` — leaves
    /// unconditionally read feature 0 (comparing against their `+inf`
    /// threshold), so even a leaf-only ensemble needs one column.
    pub(crate) fn predict_into(&self, values: &[f64], width: usize, out: &mut [f64]) {
        let n = out.len();
        assert_eq!(values.len(), n * width, "arena/row-count mismatch");
        assert!(n == 0 || width >= self.min_width.max(1), "matrix narrower than the ensemble");
        out.fill(self.init);
        let mut start = 0;
        while start < n {
            let bn = (n - start).min(BLOCK);
            let rows = &values[start * width..(start + bn) * width];
            for &root in &self.roots {
                let mut cur = [root; BLOCK];
                // Level-synchronous descent: every pass advances each row
                // one node. A row on a split strictly decreases its index
                // (children precede parents); a row parked on a leaf
                // self-loops and stops counting as moved, so the walk ends
                // after at most depth+1 passes.
                loop {
                    let mut moved = 0usize;
                    for r in 0..bn {
                        let i = cur[r] as usize;
                        let x = rows[r * width + self.feature[i] as usize];
                        let next = if x <= self.threshold[i] { self.left[i] } else { self.right[i] };
                        moved += (next != cur[r]) as usize;
                        cur[r] = next;
                    }
                    if moved == 0 {
                        break;
                    }
                }
                for r in 0..bn {
                    out[start + r] += self.scale * self.value[cur[r] as usize];
                }
            }
            start += bn;
        }
        if self.divisor != 1.0 {
            for v in out.iter_mut() {
                *v /= self.divisor;
            }
        }
    }
}

/// Blocked GEMV: `out[r] = intercept + dot(weights, row_r)` over a dense
/// `width`-wide matrix, four rows per pass so the dot products run as
/// independent accumulator streams. Uses the first `min(weights.len(),
/// width)` columns — the same truncation as the scalar `zip` in
/// `Lasso::predict_one`, and per-row accumulation order is identical, so
/// results are bit-identical.
pub(crate) fn lasso_gemv(weights: &[f64], intercept: f64, values: &[f64], width: usize, out: &mut [f64]) {
    let n = out.len();
    assert_eq!(values.len(), n * width, "arena/row-count mismatch");
    let w = &weights[..weights.len().min(width)];
    let mut r = 0;
    while r + 4 <= n {
        let base = r * width;
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for (j, &wj) in w.iter().enumerate() {
            a0 += wj * values[base + j];
            a1 += wj * values[base + width + j];
            a2 += wj * values[base + 2 * width + j];
            a3 += wj * values[base + 3 * width + j];
        }
        out[r] = intercept + a0;
        out[r + 1] = intercept + a1;
        out[r + 2] = intercept + a2;
        out[r + 3] = intercept + a3;
        r += 4;
    }
    while r < n {
        let base = r * width;
        let mut acc = 0.0f64;
        for (j, &wj) in w.iter().enumerate() {
            acc += wj * values[base + j];
        }
        out[r] = intercept + acc;
        r += 1;
    }
}

/// Matrix-predict helper for the ensemble `Regressor::predict` overrides:
/// compile once per call, run the kernel over a uniform-width matrix, and
/// fall back to the scalar row loop for ragged or too-narrow views
/// (preserving the scalar path's semantics, including its panics on rows
/// shorter than a split's feature index). Hot paths that predict the same
/// model repeatedly should cache a [`BucketKernel`] instead.
pub(crate) fn ensemble_predict_matrix(
    k: &EnsembleKernel,
    xs: &FeatureMatrix<'_>,
    scalar: impl Fn(&[f64]) -> f64,
) -> Vec<f64> {
    match xs.uniform_width() {
        Some(w) if w >= k.min_width().max(1) => {
            let mut out = vec![0.0; xs.len()];
            k.predict_into(xs.values(), w, &mut out);
            out
        }
        _ => xs.rows().map(scalar).collect(),
    }
}

/// A native model compiled to its vectorized form.
pub(crate) enum SoaKernel {
    Lasso { weights: Vec<f64>, intercept: f64 },
    Ensemble(EnsembleKernel),
}

impl SoaKernel {
    pub(crate) fn compile(m: &NativeModel) -> SoaKernel {
        match m {
            NativeModel::Lasso(l) => {
                SoaKernel::Lasso { weights: l.weights.clone(), intercept: l.intercept }
            }
            NativeModel::RandomForest(f) => SoaKernel::Ensemble(EnsembleKernel::from_trees(
                &f.trees,
                0.0,
                1.0,
                f.trees.len() as f64,
            )),
            NativeModel::Gbdt(g) => SoaKernel::Ensemble(EnsembleKernel::from_trees(
                &g.trees,
                g.init,
                g.params.learning_rate,
                1.0,
            )),
        }
    }

    /// Narrowest row this kernel can evaluate without falling back.
    pub(crate) fn min_width(&self) -> usize {
        match self {
            // GEMV truncates like the scalar zip, so any width works.
            SoaKernel::Lasso { .. } => 0,
            SoaKernel::Ensemble(k) => k.min_width(),
        }
    }

    pub(crate) fn predict_into(&self, values: &[f64], width: usize, out: &mut [f64]) {
        match self {
            SoaKernel::Lasso { weights, intercept } => {
                lasso_gemv(weights, *intercept, values, width, out)
            }
            SoaKernel::Ensemble(k) => k.predict_into(values, width, out),
        }
    }
}

/// A [`BucketModel`] compiled for matrix evaluation: standardizer
/// parameters + SoA kernel + prediction floor. Compiled once at predictor
/// construction and reused for every plan.
pub(crate) struct BucketKernel {
    mean: Vec<f64>,
    std: Vec<f64>,
    floor: f64,
    kernel: SoaKernel,
}

impl BucketKernel {
    pub(crate) fn compile(m: &BucketModel) -> BucketKernel {
        BucketKernel {
            mean: m.standardizer.mean.clone(),
            std: m.standardizer.std.clone(),
            floor: m.floor,
            kernel: SoaKernel::compile(&m.model),
        }
    }

    /// Feature width the model was trained on (standardized row length).
    pub(crate) fn dim(&self) -> usize {
        self.mean.len()
    }

    fn usable(&self) -> bool {
        // Trained models always satisfy this (bundle loading validates
        // max_feature_index < dim); the guard keeps a corrupted table on
        // the scalar path instead of asserting in the kernel.
        let d = self.dim();
        d > 0 && d >= self.kernel.min_width()
    }
}

/// Evaluate every unit of a lowered plan, vectorized per bucket.
///
/// Units are grouped by bucket (counting sort, execution order preserved
/// within a group), each group's rows standardized into one dense matrix,
/// run through the bucket's [`BucketKernel`], floor-clamped, and scattered
/// back to execution order. Units without a kernel — no trained model,
/// engine-external (MLP) models, or rows narrower than the model's feature
/// dim (mixed-width conv buckets) — go through `scalar_eval`, which
/// returns `None` to mean "no model: charge `fallback_ms`".
///
/// Returns the per-unit latencies in execution order plus the number of
/// fallback units. Bit-identical to the scalar reference loop: the
/// standardization arithmetic, kernel accumulation order, and `max(floor)`
/// clamp all match `BucketModel::predict_raw_with` operation for
/// operation.
///
/// When a compiled [`LutPack`] is supplied, each unit is first offered to
/// the LUT tier: an in-grid row is answered from the table (exact hits
/// bit-identical to the model, interpolations within the pack's verified
/// bound) and skips both the kernel matrix and the scalar path; a miss
/// flows through the SoA/scalar machinery unchanged, so `lut: None` is
/// exactly the pre-LUT behaviour.
pub(crate) fn eval_plan_grouped<F>(
    p: &LoweredGraph,
    kernels: &[Option<BucketKernel>],
    fallback_ms: f64,
    lut: Option<&LutPack>,
    mut scalar_eval: F,
) -> (Vec<f64>, usize)
where
    F: FnMut(usize, &[f64], &mut Vec<f64>) -> Option<f64>,
{
    let n = p.len();
    let mut out = vec![0.0f64; n];
    let mut fallback = 0usize;
    let mut scratch: Vec<f64> = Vec::new();
    let nb = kernels.len();
    // LUT pre-pass: serve what the compiled tier can, mark it done.
    let mut lut_served: Vec<bool> = Vec::new();
    if let Some(pack) = lut {
        lut_served = vec![false; n];
        for (i, (b, row)) in p.iter().enumerate() {
            if let Some(v) = pack.lookup(b.index(), row) {
                out[i] = v;
                lut_served[i] = true;
            }
        }
    }
    let served = |i: usize| !lut_served.is_empty() && lut_served[i];
    let kernel_ok = |bi: usize, row: &[f64]| match kernels.get(bi) {
        Some(Some(k)) => k.usable() && row.len() >= k.dim(),
        _ => false,
    };
    // Pass 1: count kernel-eligible units per bucket; everything else is
    // evaluated scalar in place.
    let mut counts = vec![0u32; nb];
    for (i, (b, row)) in p.iter().enumerate() {
        if !served(i) && kernel_ok(b.index(), row) {
            counts[b.index()] += 1;
        }
    }
    let mut starts = vec![0u32; nb + 1];
    for b in 0..nb {
        starts[b + 1] = starts[b] + counts[b];
    }
    let mut order = vec![0u32; starts[nb] as usize];
    let mut cursor: Vec<u32> = starts[..nb].to_vec();
    for (i, (b, row)) in p.iter().enumerate() {
        if served(i) {
            continue;
        }
        if kernel_ok(b.index(), row) {
            order[cursor[b.index()] as usize] = i as u32;
            cursor[b.index()] += 1;
        } else {
            match scalar_eval(b.index(), row, &mut scratch) {
                Some(v) => out[i] = v,
                None => {
                    out[i] = fallback_ms;
                    fallback += 1;
                }
            }
        }
    }
    // Pass 2: one standardized dense matrix + one kernel launch per bucket.
    let mut mat: Vec<f64> = Vec::new();
    let mut vals: Vec<f64> = Vec::new();
    for b in 0..nb {
        let (lo, hi) = (starts[b] as usize, starts[b + 1] as usize);
        if lo == hi {
            continue;
        }
        let k = kernels[b].as_ref().expect("counted bucket has a kernel");
        let d = k.dim();
        let rows = &order[lo..hi];
        mat.clear();
        mat.reserve(rows.len() * d);
        for &i in rows {
            let row = p.row(i as usize);
            for j in 0..d {
                mat.push((row[j] - k.mean[j]) / k.std[j]);
            }
        }
        vals.clear();
        vals.resize(rows.len(), 0.0);
        k.kernel.predict_into(&mat, d, &mut vals);
        for (&i, &v) in rows.iter().zip(vals.iter()) {
            out[i as usize] = v.max(k.floor);
        }
    }
    (out, fallback)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predict::tree::TreeParams;
    use crate::predict::{toy_problem, Method};
    use crate::util::Rng;

    fn random_rows(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f64>> {
        (0..n).map(|_| (0..d).map(|_| rng.range_f64(-3.0, 3.0)).collect()).collect()
    }

    fn flatten(rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().flat_map(|r| r.iter().copied()).collect()
    }

    #[test]
    fn ensemble_kernel_bit_identical_across_depths() {
        // Adversarial depths: stumps, shallow, and fully-grown deep trees,
        // with a row count that straddles block boundaries (2*64 + 7).
        for &max_depth in &[1usize, 2, 4, 24] {
            let (x, y) = toy_problem(220, max_depth as u64 + 1);
            let trees: Vec<Tree> = (0..5)
                .map(|t| {
                    let p = TreeParams { max_depth, max_features: Some(2), ..Default::default() };
                    Tree::fit(&x, &y, None, p, t)
                })
                .collect();
            assert!(trees.iter().all(|t| t.depth() <= max_depth));
            let k = EnsembleKernel::from_trees(&trees, 0.25, 0.5, 3.0);
            let mut rng = Rng::new(max_depth as u64);
            let rows = random_rows(&mut rng, 135, 3);
            let mut out = vec![0.0; rows.len()];
            k.predict_into(&flatten(&rows), 3, &mut out);
            for (row, got) in rows.iter().zip(&out) {
                let mut want = 0.25;
                for t in &trees {
                    want += 0.5 * t.predict_one(row);
                }
                want /= 3.0;
                assert_eq!(got.to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn single_leaf_tree_kernel() {
        let x: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let y = vec![7.0; 20];
        let t = Tree::fit(&x, &y, None, TreeParams::default(), 0);
        assert_eq!(t.node_count(), 1);
        assert_eq!(t.depth(), 0);
        let k = EnsembleKernel::from_trees(std::slice::from_ref(&t), 0.0, 1.0, 1.0);
        assert_eq!(k.min_width(), 0);
        let vals = [0.5, -2.0, 9.0];
        let mut out = vec![0.0; 3];
        k.predict_into(&vals, 1, &mut out);
        for (v, got) in vals.iter().zip(&out) {
            assert_eq!(got.to_bits(), t.predict_one(&[*v]).to_bits());
        }
    }

    #[test]
    fn flattened_arenas_are_nan_free_with_leaf_self_loops() {
        let (x, y) = toy_problem(300, 9);
        let t = Tree::fit(&x, &y, None, TreeParams::default(), 2);
        let k = EnsembleKernel::from_trees(std::slice::from_ref(&t), 0.0, 1.0, 1.0);
        assert_eq!(k.threshold.len(), t.node_count());
        for i in 0..k.threshold.len() {
            assert!(!k.threshold[i].is_nan());
            let is_leaf = k.left[i] == i as u32 && k.right[i] == i as u32;
            if is_leaf {
                assert_eq!(k.threshold[i], f64::INFINITY);
            } else {
                // Splits point strictly downward and carry finite thresholds.
                assert!(k.threshold[i].is_finite());
                assert!((k.left[i] as usize) < i && (k.right[i] as usize) < i);
                assert!((k.feature[i] as usize) < k.min_width());
            }
        }
    }

    #[test]
    fn lasso_gemv_bit_identical_with_truncation() {
        use crate::predict::lasso::Lasso;
        let l = Lasso { weights: vec![0.7, -1.3, 2.1], intercept: 0.4, alpha: 0.0 };
        let mut rng = Rng::new(11);
        // Wider rows than weights (extra cols ignored) and narrower rows
        // (dot truncated) — both must match the scalar zip exactly.
        for &w in &[5usize, 3, 2] {
            let rows = random_rows(&mut rng, 9, w);
            let mut out = vec![0.0; rows.len()];
            lasso_gemv(&l.weights, l.intercept, &flatten(&rows), w, &mut out);
            for (row, got) in rows.iter().zip(&out) {
                assert_eq!(got.to_bits(), l.predict_one(row).to_bits());
            }
        }
    }

    #[test]
    fn compiled_native_kernels_match_predict_one() {
        use crate::predict::Regressor;
        let (x, y) = toy_problem(250, 17);
        let mut rng = Rng::new(5);
        let rows = random_rows(&mut rng, 70, 3);
        let flat = flatten(&rows);
        for m in Method::native() {
            let bm = BucketModel::train_native(*m, &x, &y, 3);
            let k = SoaKernel::compile(&bm.model);
            let mut out = vec![0.0; rows.len()];
            k.predict_into(&flat, 3, &mut out);
            for (row, got) in rows.iter().zip(&out) {
                assert_eq!(got.to_bits(), bm.model.predict_one(row).to_bits(), "{}", m.name());
            }
        }
    }
}
