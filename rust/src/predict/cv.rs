//! K-fold cross-validation and grid-search helpers shared by the predictors.

use crate::predict::{FeatureMatrixBuf, Regressor};
use crate::util::{mape, Rng};

/// Deterministic k-fold index split.
pub fn kfold(n: usize, k: usize, seed: u64) -> Vec<(Vec<usize>, Vec<usize>)> {
    let k = k.min(n).max(2);
    let mut idx: Vec<usize> = (0..n).collect();
    Rng::derive(seed, &[0xcf]).shuffle(&mut idx);
    let mut folds = Vec::with_capacity(k);
    let chunk = n.div_ceil(k);
    for f in 0..k {
        let lo = f * chunk;
        let hi = ((f + 1) * chunk).min(n);
        if lo >= hi {
            continue;
        }
        let test: Vec<usize> = idx[lo..hi].to_vec();
        let train: Vec<usize> = idx[..lo].iter().chain(&idx[hi..]).copied().collect();
        folds.push((train, test));
    }
    folds
}

pub fn take<T: Clone>(xs: &[T], idx: &[usize]) -> Vec<T> {
    idx.iter().map(|&i| xs[i].clone()).collect()
}

/// One fold's materialized data: training rows/targets plus the held-out
/// rows gathered into a flat matrix for batch scoring.
struct Fold {
    train_x: Vec<Vec<f64>>,
    train_y: Vec<f64>,
    test_x: FeatureMatrixBuf,
    actual: Vec<f64>,
}

/// Grid search: evaluate `fit(param, train_x, train_y)` on each fold, score
/// by MAPE, return the best parameter. Small datasets fall back to fewer
/// folds automatically.
///
/// Fold data is materialized once (not once per parameter) and held-out
/// predictions go through [`Regressor::predict`] — one matrix call per
/// (param, fold) instead of a `predict_one` per row, so the native models'
/// vectorized kernels carry CV too.
pub fn grid_search<P: Clone, M, F>(
    params: &[P],
    x: &[Vec<f64>],
    y: &[f64],
    seed: u64,
    fit: F,
) -> P
where
    F: Fn(&P, &[Vec<f64>], &[f64]) -> M,
    M: Regressor,
{
    assert!(!params.is_empty());
    if x.len() < 10 || params.len() == 1 {
        return params[0].clone();
    }
    let folds: Vec<Fold> = kfold(x.len(), 5, seed)
        .iter()
        .map(|(tr, te)| {
            let mut test_x = FeatureMatrixBuf::new();
            for &i in te {
                test_x.push_row(&x[i]);
            }
            Fold {
                train_x: take(x, tr),
                train_y: take(y, tr),
                test_x,
                actual: te.iter().map(|&i| y[i]).collect(),
            }
        })
        .collect();
    let mut best = (f64::INFINITY, 0usize);
    for (pi, p) in params.iter().enumerate() {
        let mut errs = Vec::new();
        for f in &folds {
            let model = fit(p, &f.train_x, &f.train_y);
            let mut pred = model.predict(&f.test_x.view());
            for v in pred.iter_mut() {
                *v = v.max(1e-9);
            }
            errs.push(mape(&pred, &f.actual));
        }
        let score = errs.iter().sum::<f64>() / errs.len() as f64;
        if score < best.0 {
            best = (score, pi);
        }
    }
    params[best.1].clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kfold_partitions() {
        let folds = kfold(103, 5, 1);
        assert_eq!(folds.len(), 5);
        let mut all: Vec<usize> = folds.iter().flat_map(|(_, te)| te.clone()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        for (tr, te) in &folds {
            assert_eq!(tr.len() + te.len(), 103);
            assert!(te.iter().all(|i| !tr.contains(i)));
        }
    }

    #[test]
    fn kfold_handles_tiny_n() {
        let folds = kfold(3, 5, 2);
        assert!(!folds.is_empty());
        let total: usize = folds.iter().map(|(_, te)| te.len()).sum();
        assert_eq!(total, 3);
    }

    /// Toy model for the grid-search contract: predict `scale * x[0]`.
    struct Scale(f64);

    impl Regressor for Scale {
        fn predict_one(&self, x: &[f64]) -> f64 {
            self.0 * x[0]
        }
    }

    #[test]
    fn grid_search_picks_correct_scale() {
        // y = 2x; candidate scales {1.0, 2.0, 3.0}: fit = multiply by scale.
        let x: Vec<Vec<f64>> = (1..60).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (1..60).map(|i| 2.0 * i as f64).collect();
        let best = grid_search(&[1.0, 2.0, 3.0], &x, &y, 3, |&s, _xt, _yt| Scale(s));
        assert_eq!(best, 2.0);
    }
}
