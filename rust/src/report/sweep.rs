//! Parallel scenario-sweep driver for the multi-scenario figures.
//!
//! The heterogeneity figures (15/30, 23/31) and the method comparison
//! (14) evaluate an independent train+test cell per (scenario, method) —
//! up to 68 CPU combos x 2 representations at `--full` scale. Each cell is
//! pure in its inputs, so the sweep runs in two pool passes:
//!
//! 1. **Prefetch**: every profile set any cell needs is computed in
//!    parallel across scenarios ([`ReportCtx::prefetch_profiles`]).
//! 2. **Evaluate**: cells run concurrently against the now-read-only
//!    cache, results collected in cell order. Cells evaluating the same
//!    (scenario, dataset) share one lowered plan set through
//!    [`ReportCtx::test_plans`] — the test graphs are lowered once, not
//!    once per model family.
//!
//! Ordered collection + pure cells ⇒ the produced tables are *identical*
//! to the sequential loops they replaced (asserted below), just faster.

use crate::exec_pool::ExecPool;
use crate::report::{DataSet, ReportCtx};
use crate::scenario::Scenario;

/// Run `eval` over every cell on the shared pool, returning results in
/// cell order. `needs` declares which (scenario, dataset) profile sets a
/// cell reads; they are prefetched before evaluation so cells can use the
/// borrowed `_cached` accessors on a shared `&ReportCtx`.
pub fn run<C, R, N, F>(ctx: &mut ReportCtx, cells: &[C], needs: N, eval: F) -> Vec<R>
where
    C: Sync,
    R: Send,
    N: Fn(&C) -> Vec<(Scenario, DataSet)>,
    F: Fn(&ReportCtx, &C) -> R + Sync,
{
    let pairs: Vec<(Scenario, DataSet)> = cells.iter().flat_map(|c| needs(c)).collect();
    ctx.prefetch_profiles(&pairs);
    let ctx: &ReportCtx = ctx;
    ExecPool::default().map(cells, |_, c| eval(ctx, c))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::framework::{evaluate, DeductionMode, ScenarioPredictor};
    use crate::predict::Method;
    use crate::report::ReportConfig;
    use crate::scenario::one_large_core;

    /// The acceptance property for the parallelized figure sweeps: the
    /// sweep driver produces bit-identical numbers to the plain sequential
    /// loop over the same cells (same profiles, same training, same
    /// evaluation), regardless of pool scheduling.
    #[test]
    fn sweep_matches_sequential_evaluation() {
        let cfg = ReportConfig {
            n_synth: 10,
            n_train: 8,
            runs: 2,
            zoo_cap: Some(2),
            ..Default::default()
        };
        let socs = crate::device::socs();
        let cells: Vec<Scenario> = vec![
            one_large_core("HelioP35").unwrap(),
            one_large_core("Snapdragon855").unwrap(),
            Scenario::gpu(&socs[0]),
        ];
        let seed = cfg.seed;

        let cell_eval = |ctx: &ReportCtx, sc: &Scenario| -> f64 {
            let (tr, te) = ctx.synth_profiles_split_cached(sc);
            let test_g = ctx.synth_split().1.to_vec();
            let pred =
                ScenarioPredictor::train_from(sc, tr, Method::Gbdt, DeductionMode::Full, seed, None);
            evaluate(&pred, &test_g, te).end_to_end_mape
        };

        // Parallel: through the sweep driver.
        let mut ctx = ReportCtx::new(cfg.clone());
        let par = run(&mut ctx, &cells, |sc| vec![(sc.clone(), DataSet::Synth)], cell_eval);

        // Sequential reference: a fresh context, cells one at a time.
        let mut ctx_seq = ReportCtx::new(cfg);
        let seq: Vec<f64> = cells
            .iter()
            .map(|sc| {
                ctx_seq.profiles(sc, DataSet::Synth);
                cell_eval(&ctx_seq, sc)
            })
            .collect();

        assert_eq!(par.len(), seq.len());
        for ((sc, a), b) in cells.iter().zip(&par).zip(&seq) {
            assert_eq!(a.to_bits(), b.to_bits(), "{}: parallel {a} vs sequential {b}", sc.id);
        }
    }
}
