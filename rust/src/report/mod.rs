//! Figure/table regeneration — one function per table AND figure of the
//! paper's evaluation (see DESIGN.md §6 for the experiment index). Each
//! returns markdown [`Table`]s with the same rows/series the paper reports;
//! `edgelat reproduce --figure N` prints them.
//!
//! Absolute milliseconds come from the simulated substrate, so the *shape*
//! of each result (who wins, rough factors, crossovers) is the reproduction
//! target, not the paper's absolute numbers (DESIGN.md §7).

pub mod eval;
pub mod study;
pub mod sweep;

use crate::device::Soc;
use crate::exec_pool::ExecPool;
use crate::framework::DeductionMode;
use crate::graph::Graph;
use crate::plan::{self, LoweredGraph};
use crate::profiler::{profile_set, profile_set_with, ModelProfile};
use crate::scenario::{Registry, Scenario};
use crate::util::Table;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Configuration for a reproduction run. The defaults regenerate every
/// figure at a scale that completes in minutes on a laptop; `full()` uses
/// the paper's full 1000-architecture dataset.
#[derive(Debug, Clone)]
pub struct ReportConfig {
    pub seed: u64,
    /// Synthetic dataset size (paper: 1000).
    pub n_synth: usize,
    /// Synthetic train/test split (paper: 900/100).
    pub n_train: usize,
    /// Profiling repetitions per (model, scenario).
    pub runs: usize,
    /// Cap on zoo models (None = all 102).
    pub zoo_cap: Option<usize>,
    /// Artifact dir for MLP figures (None disables MLP rows).
    pub artifacts: Option<std::path::PathBuf>,
}

impl Default for ReportConfig {
    fn default() -> Self {
        ReportConfig {
            seed: 2022,
            n_synth: 160,
            n_train: 120,
            runs: 5,
            zoo_cap: None,
            artifacts: None,
        }
    }
}

impl ReportConfig {
    /// The paper-scale configuration (1000 synthetic NAs, 900/100 split).
    pub fn full() -> Self {
        ReportConfig { n_synth: 1000, n_train: 900, runs: 10, ..Default::default() }
    }

    /// A fast smoke configuration for tests.
    pub fn smoke() -> Self {
        ReportConfig { n_synth: 40, n_train: 30, runs: 3, zoo_cap: Some(20), ..Default::default() }
    }
}

/// Shared state across figure functions: built graphs and profile caches
/// (each (scenario, dataset) pair is profiled once per process).
pub struct ReportCtx {
    pub cfg: ReportConfig,
    /// The device universe the figures sweep: builtin by default, but any
    /// registry works — register a custom SoC and every per-SoC figure
    /// includes it.
    registry: Arc<Registry>,
    zoo: Vec<Graph>,
    synth: Vec<Graph>,
    profiles: HashMap<String, Vec<ModelProfile>>,
    /// Lowered test plans, keyed by (scenario id, mode, dataset): each
    /// (scenario, graph) is lowered once and the plan is shared across all
    /// model families of a figure (Lasso/RF/GBDT rows re-use one plan set
    /// instead of re-deducing per family). `Mutex` + `Arc` so sweep
    /// workers can fill and read it through a shared `&ReportCtx`.
    plans: Mutex<HashMap<String, Arc<Vec<LoweredGraph>>>>,
}

impl ReportCtx {
    pub fn new(cfg: ReportConfig) -> ReportCtx {
        ReportCtx::with_registry(cfg, Arc::new(Registry::with_builtin()))
    }

    /// Build a context over a caller-supplied device universe — the path
    /// for regenerating figures with runtime-registered SoCs included.
    pub fn with_registry(cfg: ReportConfig, registry: Arc<Registry>) -> ReportCtx {
        let mut zoo = crate::zoo::all_graphs();
        if let Some(cap) = cfg.zoo_cap {
            zoo.truncate(cap);
        }
        let synth = crate::nas::sample_dataset(cfg.seed, cfg.n_synth)
            .into_iter()
            .map(|a| a.graph)
            .collect();
        ReportCtx {
            cfg,
            registry,
            zoo,
            synth,
            profiles: HashMap::new(),
            plans: Mutex::new(HashMap::new()),
        }
    }

    /// The device universe the figures run over.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Registered SoCs (cloned), in registration order — what the per-SoC
    /// figure loops iterate.
    pub fn socs(&self) -> Vec<Soc> {
        self.registry.socs()
    }

    /// The studied core combos of a SoC yielded by [`socs`](Self::socs).
    pub fn combos(&self, soc: &Soc) -> Vec<Vec<usize>> {
        self.registry
            .combos(&soc.name)
            .expect("figure loops iterate registered SoCs only")
    }

    pub fn zoo(&self) -> &[Graph] {
        &self.zoo
    }

    pub fn synth(&self) -> &[Graph] {
        &self.synth
    }

    pub fn synth_split(&self) -> (&[Graph], &[Graph]) {
        let n = self.cfg.n_train.min(self.synth.len().saturating_sub(1));
        self.synth.split_at(n)
    }

    /// Profile a dataset under a scenario, cached by (scenario id, set tag).
    pub fn profiles(&mut self, sc: &Scenario, set: DataSet) -> &[ModelProfile] {
        let key = profile_key(sc, set);
        if !self.profiles.contains_key(&key) {
            let graphs: &[Graph] = match set {
                DataSet::Zoo => &self.zoo,
                DataSet::Synth => &self.synth,
            };
            let p = profile_set(sc, graphs, self.cfg.seed, self.cfg.runs);
            self.profiles.insert(key.clone(), p);
        }
        &self.profiles[&key]
    }

    /// Fill the profile cache for every listed (scenario, dataset) pair,
    /// computing the missing ones **in parallel across scenarios** on the
    /// shared pool. Each scenario's own graphs are profiled on an inner
    /// pool sized so outer x inner ≈ the machine: a wide sweep gets one
    /// worker per scenario, a single missing scenario still fans out over
    /// its graphs. Results are bit-identical to on-demand [`profiles`]
    /// (per-graph profiling is pure), so figures built from prefetched
    /// caches match their sequential counterparts exactly.
    ///
    /// [`profiles`]: Self::profiles
    pub fn prefetch_profiles(&mut self, pairs: &[(Scenario, DataSet)]) {
        let mut seen = std::collections::HashSet::new();
        let missing: Vec<(Scenario, DataSet)> = pairs
            .iter()
            .filter(|(sc, set)| {
                let key = profile_key(sc, *set);
                !self.profiles.contains_key(&key) && seen.insert(key)
            })
            .cloned()
            .collect();
        if missing.is_empty() {
            return;
        }
        let pool = ExecPool::default();
        let inner = ExecPool::new(pool.threads().div_ceil(missing.len().min(pool.threads())));
        let computed = pool.map(&missing, |_, (sc, set)| {
            let graphs: &[Graph] = match set {
                DataSet::Zoo => &self.zoo,
                DataSet::Synth => &self.synth,
            };
            profile_set_with(&inner, sc, graphs, self.cfg.seed, self.cfg.runs)
        });
        for ((sc, set), p) in missing.iter().zip(computed) {
            self.profiles.insert(profile_key(sc, *set), p);
        }
    }

    /// Read-only profile access for parallel sweep evaluation (shared
    /// `&self` across pool workers). Panics if the pair was never
    /// profiled — sweep cells must declare their needs so
    /// [`prefetch_profiles`](Self::prefetch_profiles) runs first.
    pub fn profiles_cached(&self, sc: &Scenario, set: DataSet) -> &[ModelProfile] {
        self.profiles
            .get(&profile_key(sc, set))
            .unwrap_or_else(|| panic!("profiles for {} ({set:?}) not prefetched", sc.id))
            .as_slice()
    }

    /// The test graphs a dataset evaluates against: the held-out synthetic
    /// split, or the (possibly capped) zoo.
    pub fn test_graphs(&self, set: DataSet) -> &[Graph] {
        match set {
            DataSet::Synth => self.synth_split().1,
            DataSet::Zoo => &self.zoo,
        }
    }

    /// Lowered plans for the test graphs of `set` under (scenario, mode),
    /// computed once and shared: every model family of a figure row (and
    /// every sweep cell hitting the same scenario) evaluates against the
    /// same `Arc`'d plan set. Takes `&self` so sweep workers can call it
    /// concurrently; a racing duplicate lowers the same pure value and the
    /// first insert wins.
    pub fn test_plans(
        &self,
        sc: &Scenario,
        mode: DeductionMode,
        set: DataSet,
    ) -> Arc<Vec<LoweredGraph>> {
        let key = format!("{}#{}#{set:?}", sc.id, mode.name());
        if let Some(p) = self.plans.lock().expect("plan cache lock").get(&key) {
            return p.clone();
        }
        let lowered = Arc::new(
            self.test_graphs(set).iter().map(|g| plan::lower(sc, mode, g)).collect::<Vec<_>>(),
        );
        self.plans.lock().expect("plan cache lock").entry(key).or_insert(lowered).clone()
    }

    /// Number of cached (scenario, mode, dataset) plan sets.
    pub fn plans_cached(&self) -> usize {
        self.plans.lock().expect("plan cache lock").len()
    }

    /// Split synthetic profiles consistently with `synth_split`.
    pub fn synth_profiles_split(&mut self, sc: &Scenario) -> (Vec<ModelProfile>, Vec<ModelProfile>) {
        self.profiles(sc, DataSet::Synth);
        let (a, b) = self.synth_profiles_split_cached(sc);
        (a.to_vec(), b.to_vec())
    }

    /// Borrowed variant of [`synth_profiles_split`](Self::synth_profiles_split)
    /// for prefetched scenarios — no cloning, usable from sweep workers.
    pub fn synth_profiles_split_cached(&self, sc: &Scenario) -> (&[ModelProfile], &[ModelProfile]) {
        let n = self.cfg.n_train.min(self.synth.len().saturating_sub(1));
        self.profiles_cached(sc, DataSet::Synth).split_at(n)
    }
}

/// Cache key of one (scenario, dataset) profile set.
fn profile_key(sc: &Scenario, set: DataSet) -> String {
    format!("{}#{set:?}", sc.id)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataSet {
    Zoo,
    Synth,
}

/// Figure/table registry: id -> generator.
pub fn reproduce(id: &str, ctx: &mut ReportCtx) -> Vec<Table> {
    match id {
        "2" | "26" => study::fig02_multicore(ctx, id == "26"),
        "3" => study::fig03_op_speedup(ctx),
        "4" | "27" => study::fig04_quantization(ctx, id == "27"),
        "5" => study::fig05_quant_opwise(ctx),
        "6" | "28" => study::fig06_fusion(ctx, id == "28"),
        "7" | "29" => study::fig07_fusion_opwise(ctx, id == "29"),
        "8" => study::fig08_winograd(ctx),
        "9" => study::fig09_grouped(ctx),
        "10" => study::fig10_overhead(ctx),
        "11" => study::fig11_breakdown_zoo(ctx),
        "13" => study::fig13_breakdown_synth(ctx),
        "14" => eval::fig14_methods_synth(ctx),
        "15" | "30" => eval::fig15_gbdt_multicore(ctx, id == "30"),
        "16" => eval::fig16_gbdt_gpu(ctx),
        "17" => eval::fig17_conv_ranges(ctx),
        "18" => eval::fig18_methods_zoo(ctx),
        "19" => eval::fig19_fusion_ablation(ctx),
        "20" => eval::fig20_selection_ablation(ctx),
        "21" | "t4" | "table4" => eval::fig21_train_size_synth(ctx),
        "22" | "t5" | "table5" => eval::fig22_train_size_zoo(ctx),
        "23" | "31" => eval::fig23_lasso_multicore(ctx, id == "31"),
        "24" => eval::fig24_lasso_gpu(ctx),
        "25" => study::fig25_zoo_scatter(ctx),
        "32" => eval::fig32_cov(ctx),
        "33" => eval::fig33_mlp_train_size(ctx),
        "t2" | "table2" => eval::table2_winograd(ctx),
        other => panic!("unknown figure/table id '{other}' (see DESIGN.md §6)"),
    }
}

/// All reproducible ids, in paper order.
pub fn all_ids() -> Vec<&'static str> {
    vec![
        "2", "3", "4", "5", "6", "7", "8", "t2", "9", "10", "11", "13", "14", "15", "16", "17",
        "18", "19", "20", "21", "22", "23", "24", "25", "26", "27", "28", "29", "30", "31", "32",
        "33",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_builds_and_caches() {
        let mut ctx = ReportCtx::new(ReportConfig::smoke());
        assert_eq!(ctx.zoo().len(), 20);
        assert_eq!(ctx.synth().len(), 40);
        let sc = crate::scenario::one_large_core("HelioP35").unwrap();
        let a = ctx.profiles(&sc, DataSet::Zoo).len();
        let b = ctx.profiles(&sc, DataSet::Zoo).len();
        assert_eq!(a, b);
        assert_eq!(a, 20);
    }

    #[test]
    fn prefetch_profiles_matches_on_demand() {
        let cfg = ReportConfig {
            n_synth: 8,
            n_train: 6,
            runs: 2,
            zoo_cap: Some(3),
            ..Default::default()
        };
        let mut pre = ReportCtx::new(cfg.clone());
        let mut lazy = ReportCtx::new(cfg);
        let sc1 = crate::scenario::one_large_core("HelioP35").unwrap();
        let sc2 = crate::scenario::one_large_core("Snapdragon855").unwrap();
        pre.prefetch_profiles(&[
            (sc1.clone(), DataSet::Synth),
            (sc1.clone(), DataSet::Synth), // duplicates are computed once
            (sc2.clone(), DataSet::Zoo),
        ]);
        for (sc, set) in [(&sc1, DataSet::Synth), (&sc2, DataSet::Zoo)] {
            let a = pre.profiles_cached(sc, set).to_vec();
            let b = lazy.profiles(sc, set);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.end_to_end_ms.to_bits(), y.end_to_end_ms.to_bits(), "{}", x.model);
                assert_eq!(x.ops.len(), y.ops.len());
            }
        }
        // Prefetching again is a no-op (already cached).
        pre.prefetch_profiles(&[(sc1.clone(), DataSet::Synth)]);
        let (tr, te) = pre.synth_profiles_split_cached(&sc1);
        assert_eq!(tr.len(), 6);
        assert_eq!(te.len(), 2);
    }

    #[test]
    fn test_plans_lower_once_and_share() {
        let ctx = ReportCtx::new(ReportConfig::smoke());
        let sc = crate::scenario::one_large_core("HelioP35").unwrap();
        let a = ctx.test_plans(&sc, DeductionMode::Full, DataSet::Synth);
        let b = ctx.test_plans(&sc, DeductionMode::Full, DataSet::Synth);
        // Same Arc: the second caller (another model family, another sweep
        // cell) reuses the first lowering.
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.len(), ctx.test_graphs(DataSet::Synth).len());
        assert_eq!(ctx.plans_cached(), 1);
        let z = ctx.test_plans(&sc, DeductionMode::Full, DataSet::Zoo);
        assert_eq!(z.len(), ctx.zoo().len());
        assert_eq!(ctx.plans_cached(), 2);
        // A different mode lowers separately (ablations change deduction).
        let n = ctx.test_plans(&sc, DeductionMode::NoFusion, DataSet::Synth);
        assert!(!Arc::ptr_eq(&a, &n));
        assert_eq!(ctx.plans_cached(), 3);
    }

    #[test]
    fn ctx_sweeps_a_custom_registry() {
        let mut custom = crate::device::builtin_specs()[3].clone();
        custom.soc.name = "ReportSoc".into();
        let mut reg = Registry::with_builtin();
        reg.register_soc(custom).unwrap();
        let ctx = ReportCtx::with_registry(ReportConfig::smoke(), Arc::new(reg));
        // Figure loops over ctx.socs()/ctx.combos() now include the custom
        // device alongside the four builtin ones.
        assert_eq!(ctx.socs().len(), 5);
        let soc = ctx.socs().pop().unwrap();
        assert_eq!(soc.name, "ReportSoc");
        assert_eq!(ctx.combos(&soc).len(), 7);
        assert!(ctx.registry().by_id("ReportSoc/gpu").is_some());
    }

    #[test]
    fn split_consistent() {
        let ctx = ReportCtx::new(ReportConfig::smoke());
        let (tr, te) = ctx.synth_split();
        assert_eq!(tr.len(), 30);
        assert_eq!(te.len(), 10);
    }
}
