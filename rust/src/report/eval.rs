//! Section 5 evaluation figures and tables: prediction accuracy across ML
//! methods (Fig 14/18, Tables 4/5 = Figs 21/22), hardware heterogeneity
//! (Figs 15/16/30, 23/24/31), dataset shift (Fig 17), framework
//! optimization ablations (Figs 19/20), variance (Fig 32), the MLP
//! train-size anomaly (Fig 33), and Winograd applicability (Table 2).

use crate::device::{DataRep, Soc, Target};
use crate::framework::{
    evaluate, evaluate_lowered, DeductionMode, Evaluation, ScenarioPredictor,
};
use crate::graph::Graph;
use crate::predict::mlp::MlpContext;
use crate::predict::Method;
use crate::profiler::ModelProfile;
use crate::report::{sweep, DataSet, ReportCtx};
use crate::scenario::{Registry, Scenario};
use crate::tflite::{compile, select, CompileOptions};
use crate::util::table::pct;
use crate::util::{cov, mape, mean, Table};

fn mlp_ctx(ctx: &ReportCtx) -> Option<MlpContext> {
    let dir = ctx
        .cfg
        .artifacts
        .clone()
        .unwrap_or_else(crate::runtime::Runtime::default_dir);
    if crate::runtime::Runtime::artifacts_available(&dir) {
        MlpContext::load(&dir).ok()
    } else {
        None
    }
}

fn methods_with_mlp(mlp: bool) -> Vec<Method> {
    let mut m = Method::native().to_vec();
    if mlp {
        m.push(Method::Mlp);
    }
    m
}

/// Train+evaluate one (scenario, method) on a train/test profile split;
/// returns (end-to-end MAPE, per-bucket MAPEs). The test plans come from
/// the context's shared plan cache, so every model family evaluated for
/// the same (scenario, dataset) reuses one lowering.
fn eval_method(
    ctx: &ReportCtx,
    sc: &Scenario,
    train_p: &[ModelProfile],
    test: DataSet,
    test_p: &[ModelProfile],
    method: Method,
    seed: u64,
    mlp: Option<&MlpContext>,
) -> crate::framework::Evaluation {
    let pred =
        ScenarioPredictor::train_from(sc, train_p, method, DeductionMode::Full, seed, mlp);
    let plans = ctx.test_plans(sc, DeductionMode::Full, test);
    evaluate_lowered(&pred, ctx.test_graphs(test), &plans, test_p)
}

/// The headline per-platform scenario of Figs 14/18: the GPU, or one
/// large CPU core (fp32).
fn fig_scenario(soc: &Soc, is_gpu: bool) -> Scenario {
    if is_gpu {
        Scenario::gpu(soc)
    } else {
        let mut counts = vec![0; soc.clusters.len()];
        counts[0] = 1;
        Scenario::cpu(soc, counts, DataRep::Fp32)
            .expect("one large core is valid on every registered SoC")
    }
}

/// One Fig 14 table row: a method's MAPE averaged over the platforms'
/// evaluations, end-to-end plus the dominant op columns.
fn fig14_row(table: &mut Table, method: Method, evs: &[Evaluation], op_cols: &[&str]) {
    let e2e: Vec<f64> = evs.iter().map(|e| e.end_to_end_mape).collect();
    let mut row = vec![method.name().to_string(), pct(mean(&e2e))];
    for c in op_cols {
        let per: Vec<f64> = evs.iter().filter_map(|e| e.per_bucket_mape.get(*c).copied()).collect();
        row.push(if per.is_empty() { "-".into() } else { pct(mean(&per)) });
    }
    table.row(row);
}

/// Fig 14: MAPE of each method, synthetic 900/100 split, averaged across
/// platforms; end-to-end plus the four dominant op types.
pub fn fig14_methods_synth(ctx: &mut ReportCtx) -> Vec<Table> {
    let mlp = mlp_ctx(ctx);
    let op_cols = ["Conv2D", "DepthwiseConv2D", "Mean", "Pooling"];
    let header = {
        let mut h = vec!["method", "end-to-end"];
        h.extend(op_cols);
        h
    };
    let mut cpu = Table::new(
        "Fig 14a — MAPE on synthetic NAs, CPU (1 large core, avg across 4 platforms)",
        &header,
    );
    let mut gpu =
        Table::new("Fig 14b — MAPE on synthetic NAs, GPU (avg across 4 platforms)", &header);
    let seed = ctx.cfg.seed;
    // One sweep cell per (native method, target, platform): every cell is
    // an independent train+evaluate, so the shared pool runs them all
    // concurrently; the three methods hitting the same scenario share one
    // lowered plan set through the context's plan cache. MLP rows
    // (artifact-gated; the PJRT context is not shareable across threads)
    // run sequentially afterwards, which also keeps them last in each
    // table exactly as before.
    let mut cells: Vec<(Method, bool, Scenario)> = Vec::new();
    for &method in Method::native() {
        for is_gpu in [false, true] {
            for soc in ctx.socs() {
                cells.push((method, is_gpu, fig_scenario(&soc, is_gpu)));
            }
        }
    }
    let evs = sweep::run(
        ctx,
        &cells,
        |(_, _, sc)| vec![(sc.clone(), DataSet::Synth)],
        |ctx, (method, _, sc)| {
            let (tr, te) = ctx.synth_profiles_split_cached(sc);
            eval_method(ctx, sc, tr, DataSet::Synth, te, *method, seed, None)
        },
    );
    let n_soc = ctx.socs().len();
    for (group, chunk) in evs.chunks(n_soc).enumerate() {
        let (method, is_gpu, _) = &cells[group * n_soc];
        fig14_row(if *is_gpu { &mut gpu } else { &mut cpu }, *method, chunk, &op_cols);
    }
    if let Some(mlp) = &mlp {
        for is_gpu in [false, true] {
            let mut evs = Vec::new();
            for soc in ctx.socs() {
                let sc = fig_scenario(&soc, is_gpu);
                let (tr, te) = ctx.synth_profiles_split(&sc);
                evs.push(eval_method(
                    ctx,
                    &sc,
                    &tr,
                    DataSet::Synth,
                    &te,
                    Method::Mlp,
                    seed,
                    Some(mlp),
                ));
            }
            fig14_row(if is_gpu { &mut gpu } else { &mut cpu }, Method::Mlp, &evs, &op_cols);
        }
    }
    vec![cpu, gpu]
}

/// One multicore-sweep cell: a platform's core combo in both data
/// representations (one output table row).
struct ComboCell {
    soc_name: String,
    fp32: Scenario,
    int8: Scenario,
}

/// The (platform x core combo) cells of Figs 15/30 and 23/31, in table
/// order, over the context's registered device universe.
fn combo_cells(reg: &Registry, full: bool) -> Vec<ComboCell> {
    let mut cells = Vec::new();
    for soc in reg.socs() {
        let combos = reg.combos(&soc.name).expect("iterating registered SoCs");
        let combos: Vec<Vec<usize>> =
            if full { combos } else { combos.into_iter().take(6).collect() };
        for counts in combos {
            cells.push(ComboCell {
                soc_name: soc.name.to_string(),
                fp32: Scenario::cpu(&soc, counts.clone(), DataRep::Fp32)
                    .expect("combo drawn from the SoC's own cluster table"),
                int8: Scenario::cpu(&soc, counts, DataRep::Int8)
                    .expect("combo drawn from the SoC's own cluster table"),
            });
        }
    }
    cells
}

/// Group per-cell rows into one table per platform (cells arrive in
/// platform order, so tables materialize in order too).
fn combo_tables(
    cells: &[ComboCell],
    rows: Vec<Vec<String>>,
    title: impl Fn(&str) -> String,
) -> Vec<Table> {
    let mut tables: Vec<Table> = Vec::new();
    let mut last_soc: Option<&str> = None;
    for (cell, row) in cells.iter().zip(rows) {
        if last_soc != Some(cell.soc_name.as_str()) {
            tables.push(Table::new(&title(&cell.soc_name), &["combo", "fp32 MAPE", "int8 MAPE"]));
            last_soc = Some(cell.soc_name.as_str());
        }
        tables.last_mut().expect("table exists for current soc").row(row);
    }
    tables
}

/// Fig 15 (30): GBDT end-to-end predictions per core combo, fp32 + int8.
pub fn fig15_gbdt_multicore(ctx: &mut ReportCtx, full: bool) -> Vec<Table> {
    let seed = ctx.cfg.seed;
    let cells = combo_cells(ctx.registry(), full);
    let rows = sweep::run(
        ctx,
        &cells,
        |c| vec![(c.fp32.clone(), DataSet::Synth), (c.int8.clone(), DataSet::Synth)],
        |ctx, c| {
            let mut row = vec![c.fp32.combo_label()];
            for sc in [&c.fp32, &c.int8] {
                let (tr, te) = ctx.synth_profiles_split_cached(sc);
                let ev = eval_method(ctx, sc, tr, DataSet::Synth, te, Method::Gbdt, seed, None);
                row.push(pct(ev.end_to_end_mape));
            }
            row
        },
    );
    combo_tables(&cells, rows, |soc| {
        format!(
            "Fig {} — GBDT end-to-end MAPE per core combo (synthetic), {soc}",
            if full { 30 } else { 15 }
        )
    })
}

/// Fig 16: GBDT on the four GPUs, with Conv2D vs Winograd split.
pub fn fig16_gbdt_gpu(ctx: &mut ReportCtx) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 16 — GBDT on GPUs (synthetic): per-kernel and end-to-end MAPE",
        &["gpu", "Conv2D", "Winograd", "DepthwiseConv2D", "end-to-end"],
    );
    let seed = ctx.cfg.seed;
    for soc in ctx.socs() {
        let sc = Scenario::gpu(&soc);
        let (tr, te) = ctx.synth_profiles_split(&sc);
        let ev = eval_method(ctx, &sc, &tr, DataSet::Synth, &te, Method::Gbdt, seed, None);
        let get = |b: &str| ev.per_bucket_mape.get(b).map(|&m| pct(m)).unwrap_or("-".into());
        t.row(vec![
            soc.gpu.name.to_string(),
            get("Conv2D"),
            get("Winograd"),
            get("DepthwiseConv2D"),
            pct(ev.end_to_end_mape),
        ]);
    }
    vec![t]
}

/// A one-row SKIPPED table for figures pinned to a specific paper device
/// that the context's registry does not contain (a custom-only universe
/// built via `ReportCtx::with_registry` is valid; these figures just have
/// nothing to measure there).
fn skipped_missing_soc(title: &str, soc: &str) -> Vec<Table> {
    let mut t = Table::new(title, &["status"]);
    t.row(vec![format!("SKIPPED: SoC '{soc}' is not in this context's registry")]);
    vec![t]
}

/// Fig 17: convolution latency-range distribution, synthetic vs zoo, and
/// Lasso accuracy per range (Helio P35, 1 large core).
pub fn fig17_conv_ranges(ctx: &mut ReportCtx) -> Vec<Table> {
    let Ok(sc) = ctx.registry().one_large_core("HelioP35") else {
        return skipped_missing_soc("Fig 17 — conv latency ranges (Helio P35)", "HelioP35");
    };
    let bins = [0.0, 10.0, 50.0, f64::INFINITY];
    let bin_names = ["<10ms", "10-50ms", ">50ms"];
    let mut a = Table::new(
        "Fig 17a — % of end-to-end latency from convolutions by latency range (Helio P35, 1 large core)",
        &["dataset", bin_names[0], bin_names[1], bin_names[2]],
    );
    for (set, name) in [(DataSet::Synth, "synthetic"), (DataSet::Zoo, "real-world")] {
        let profs = ctx.profiles(&sc, set).to_vec();
        let mut frac = [0.0f64; 3];
        let mut total = 0.0;
        for p in &profs {
            for o in &p.ops {
                if o.bucket == "Conv2D" || o.bucket == "GroupedConv2D" {
                    let b = (0..3)
                        .find(|&i| o.latency_ms >= bins[i] && o.latency_ms < bins[i + 1])
                        .unwrap();
                    frac[b] += o.latency_ms;
                }
            }
            total += p.end_to_end_ms;
        }
        a.row(vec![
            name.to_string(),
            pct(frac[0] / total),
            pct(frac[1] / total),
            pct(frac[2] / total),
        ]);
    }
    // 17b: Lasso per-range conv accuracy (trained on synthetic).
    let (tr, _) = ctx.synth_profiles_split(&sc);
    let pred = ScenarioPredictor::train_from(&sc, &tr, Method::Lasso, DeductionMode::Full, 1, None);
    let mut b = Table::new(
        "Fig 17b — Lasso conv MAPE by latency range (trained on synthetic)",
        &["test set", bin_names[0], bin_names[1], bin_names[2]],
    );
    for (set, name) in [(DataSet::Synth, "synthetic"), (DataSet::Zoo, "real-world")] {
        let profs = ctx.profiles(&sc, set).to_vec();
        let model = pred.model_named("Conv2D").expect("conv model");
        let mut per_bin: [(Vec<f64>, Vec<f64>); 3] = Default::default();
        // One shared standardization scratch across every conv row instead
        // of a fresh allocation per prediction.
        let mut scratch = Vec::new();
        for p in &profs {
            for o in &p.ops {
                if o.bucket == "Conv2D" {
                    let bi = (0..3)
                        .find(|&i| o.latency_ms >= bins[i] && o.latency_ms < bins[i + 1])
                        .unwrap();
                    per_bin[bi].0.push(model.predict_raw_with(&o.features, &mut scratch));
                    per_bin[bi].1.push(o.latency_ms);
                }
            }
        }
        let cell = |i: usize| {
            if per_bin[i].0.is_empty() {
                "-".to_string()
            } else {
                pct(mape(&per_bin[i].0, &per_bin[i].1))
            }
        };
        b.row(vec![name.to_string(), cell(0), cell(1), cell(2)]);
    }
    vec![a, b]
}

/// Fig 18: methods trained on synthetic, tested on the real-world zoo.
pub fn fig18_methods_zoo(ctx: &mut ReportCtx) -> Vec<Table> {
    let mlp = mlp_ctx(ctx);
    let methods = methods_with_mlp(mlp.is_some());
    let mut cpu = Table::new(
        "Fig 18a — MAPE on real-world NAs (train: synthetic), CPU 1 large core (avg 4 platforms)",
        &["method", "end-to-end"],
    );
    let mut gpu = Table::new(
        "Fig 18b — MAPE on real-world NAs (train: synthetic), GPUs (avg 4 platforms)",
        &["method", "end-to-end"],
    );
    let seed = ctx.cfg.seed;
    for &method in &methods {
        for (is_gpu, table) in [(false, &mut cpu), (true, &mut gpu)] {
            let mut e2e = Vec::new();
            for soc in ctx.socs() {
                let sc = fig_scenario(&soc, is_gpu);
                let (tr, _) = ctx.synth_profiles_split(&sc);
                let te = ctx.profiles(&sc, DataSet::Zoo).to_vec();
                let ev = eval_method(ctx, &sc, &tr, DataSet::Zoo, &te, method, seed, mlp.as_ref());
                e2e.push(ev.end_to_end_mape);
            }
            table.row(vec![method.name().to_string(), pct(mean(&e2e))]);
        }
    }
    vec![cpu, gpu]
}

/// Fig 19: fusion deduction accuracy + error reduction from modeling fusion.
pub fn fig19_fusion_ablation(ctx: &mut ReportCtx) -> Vec<Table> {
    // 19a: deduced kernel counts match "measured" ones exactly (we run the
    // same Algorithm C.1 the simulated device runs; the paper's deduction
    // also matches closely).
    let mut a = Table::new(
        "Fig 19a — deduced vs measured kernel count (zoo, Mali G76)",
        &["model", "measured kernels", "deduced kernels", "match"],
    );
    let e9820 = crate::device::soc_by_name("Exynos9820").unwrap();
    let sg = Scenario::gpu(&e9820);
    let zoo = ctx.zoo().to_vec();
    let profs = ctx.profiles(&sg, DataSet::Zoo).to_vec();
    let mut matches = 0;
    for (g, p) in zoo.iter().zip(&profs) {
        let deduced = compile(g, e9820.gpu.kind, CompileOptions::default()).kernels.len();
        if deduced == p.ops.len() {
            matches += 1;
        }
        if a.rows.len() < 8 {
            a.row(vec![
                g.name.clone(),
                format!("{}", p.ops.len()),
                format!("{deduced}"),
                if deduced == p.ops.len() { "yes".into() } else { "NO".into() },
            ]);
        }
    }
    a.row(vec!["TOTAL".into(), format!("{}", zoo.len()), format!("{matches} match"), pct(matches as f64 / zoo.len() as f64)]);

    // 19b/c: end-to-end MAPE with vs without fusion modeling, per GPU.
    let mut b = Table::new(
        "Fig 19b/c — end-to-end MAPE with vs without fusion modeling (zoo, GBDT)",
        &["gpu", "with fusion (paper)", "w/o fusion", "error reduction"],
    );
    let seed = ctx.cfg.seed;
    for soc in ctx.socs() {
        let sc = Scenario::gpu(&soc);
        let (tr, _) = ctx.synth_profiles_split(&sc);
        let te = ctx.profiles(&sc, DataSet::Zoo).to_vec();
        let full = ScenarioPredictor::train_from(&sc, &tr, Method::Gbdt, DeductionMode::Full, seed, None);
        let ev_full = evaluate(&full, &zoo_slice(ctx), &te);
        // The w/o-fusion baseline trains on unfused profiling runs.
        let sc_nf = Scenario {
            target: Target::Gpu { options: CompileOptions { fusion: false, ..Default::default() } },
            id: format!("{}/gpu/nofusion", soc.name),
            soc: soc.clone(),
            workload: None,
        };
        let tr_nf = {
            let n = ctx.cfg.n_train.min(ctx.synth().len().saturating_sub(1));
            ctx.profiles(&sc_nf, DataSet::Synth)[..n].to_vec()
        };
        let nf = ScenarioPredictor::train_from(&sc_nf, &tr_nf, Method::Gbdt, DeductionMode::NoFusion, seed, None);
        let ev_nf = evaluate(&nf, &zoo_slice(ctx), &te);
        b.row(vec![
            soc.gpu.name.to_string(),
            pct(ev_full.end_to_end_mape),
            pct(ev_nf.end_to_end_mape),
            pct(ev_nf.end_to_end_mape - ev_full.end_to_end_mape),
        ]);
    }
    vec![a, b]
}

fn zoo_slice(ctx: &ReportCtx) -> Vec<Graph> {
    ctx.zoo().to_vec()
}

/// Fig 20: kernel-selection ablation on PowerVR GE8320.
pub fn fig20_selection_ablation(ctx: &mut ReportCtx) -> Vec<Table> {
    let p35 = crate::device::soc_by_name("HelioP35").unwrap();
    let sc = Scenario::gpu(&p35);
    let (tr, _) = ctx.synth_profiles_split(&sc);
    let te = ctx.profiles(&sc, DataSet::Zoo).to_vec();
    let zoo = ctx.zoo().to_vec();
    let seed = ctx.cfg.seed;
    // Restrict to NAs that actually use Winograd kernels on PowerVR.
    let mut wino_g = Vec::new();
    let mut wino_p = Vec::new();
    for (g, p) in zoo.iter().zip(&te) {
        if p.ops.iter().any(|o| o.bucket == "Winograd") {
            wino_g.push(g.clone());
            wino_p.push(p.clone());
        }
    }
    let full = ScenarioPredictor::train_from(&sc, &tr, Method::Gbdt, DeductionMode::Full, seed, None);
    let nosel =
        ScenarioPredictor::train_from(&sc, &tr, Method::Gbdt, DeductionMode::NoSelection, seed, None);
    let ev_full = evaluate(&full, &wino_g, &wino_p);
    let ev_nosel = evaluate(&nosel, &wino_g, &wino_p);
    let mut a = Table::new(
        "Fig 20a — end-to-end MAPE on Winograd-using NAs, PowerVR GE8320 (GBDT)",
        &["predictor", "MAPE"],
    );
    a.row(vec!["with kernel selection (paper)".into(), pct(ev_full.end_to_end_mape)]);
    a.row(vec!["w/o kernel selection".into(), pct(ev_nosel.end_to_end_mape)]);

    // 20b: Winograd-kernel prediction error under both predictors.
    let mut b = Table::new(
        "Fig 20b — Winograd-kernel MAPE with vs without selection modeling",
        &["predictor", "Winograd-kernel MAPE"],
    );
    let wino_err = |pred: &ScenarioPredictor| -> f64 {
        let mut ps = Vec::new();
        let mut as_ = Vec::new();
        for (g, p) in wino_g.iter().zip(&wino_p) {
            // Lower once per graph; per-unit rows come off the plan with
            // no bucket strings in the loop.
            let rows = pred.predict_plan_rows(&pred.lower(g));
            if rows.len() != p.ops.len() {
                continue;
            }
            for (pm, o) in rows.iter().zip(&p.ops) {
                if o.bucket == "Winograd" {
                    ps.push(*pm);
                    as_.push(o.latency_ms);
                }
            }
        }
        mape(&ps, &as_)
    };
    b.row(vec!["with selection".into(), pct(wino_err(&full))]);
    b.row(vec!["w/o selection".into(), pct(wino_err(&nosel))]);
    vec![a, b]
}

/// Figs 21/22 + Tables 4/5 helper: method x train-size sweep.
///
/// The MLP rows run only at >= default scale: the sweep retrains the AOT
/// MLP hundreds of times (sizes x scenarios x buckets), which dwarfs the
/// smoke budget; Figs 14/18/33 cover MLP behaviour at every scale.
fn train_size_sweep(ctx: &mut ReportCtx, test: DataSet, title: &str) -> Vec<Table> {
    let mlp = if ctx.cfg.n_synth >= 100 { mlp_ctx(ctx) } else { None };
    let methods = methods_with_mlp(mlp.is_some());
    let sizes = [30usize, 100, ctx.cfg.n_train];
    let mut tables = Vec::new();
    let mut t = Table::new(title, &{
        let mut h = vec!["method", "train size"];
        for soc in ctx.socs() {
            h.push(Box::leak(format!("{} CPU", soc.name).into_boxed_str()) as &str);
            h.push(Box::leak(format!("{} GPU", soc.name).into_boxed_str()) as &str);
        }
        h.push("avg CPU");
        h.push("avg GPU");
        h
    });
    let seed = ctx.cfg.seed;
    for &method in &methods {
        for &n in &sizes {
            let n = n.min(ctx.cfg.n_train);
            let mut row = vec![method.name().to_string(), format!("{n}")];
            let mut cpu_all = Vec::new();
            let mut gpu_all = Vec::new();
            for soc in ctx.socs() {
                for is_gpu in [false, true] {
                    let sc = fig_scenario(&soc, is_gpu);
                    let (tr_full, te_synth) = ctx.synth_profiles_split(&sc);
                    let tr = &tr_full[..n.min(tr_full.len())];
                    let te_p: Vec<ModelProfile> = match test {
                        DataSet::Synth => te_synth,
                        DataSet::Zoo => ctx.profiles(&sc, DataSet::Zoo).to_vec(),
                    };
                    let ev = eval_method(ctx, &sc, tr, test, &te_p, method, seed, mlp.as_ref());
                    row.push(pct(ev.end_to_end_mape));
                    if is_gpu {
                        gpu_all.push(ev.end_to_end_mape);
                    } else {
                        cpu_all.push(ev.end_to_end_mape);
                    }
                }
            }
            row.push(pct(mean(&cpu_all)));
            row.push(pct(mean(&gpu_all)));
            t.row(row);
        }
    }
    tables.push(t);
    tables
}

/// Fig 21 + Table 4: train-size sweep, tested on synthetic NAs.
pub fn fig21_train_size_synth(ctx: &mut ReportCtx) -> Vec<Table> {
    train_size_sweep(
        ctx,
        DataSet::Synth,
        "Fig 21 / Table 4 — end-to-end MAPE vs training-set size (synthetic test set; CPU = 1 large core)",
    )
}

/// Fig 22 + Table 5: train-size sweep, tested on the real-world zoo.
pub fn fig22_train_size_zoo(ctx: &mut ReportCtx) -> Vec<Table> {
    train_size_sweep(
        ctx,
        DataSet::Zoo,
        "Fig 22 / Table 5 — end-to-end MAPE vs training-set size (real-world test set; CPU = 1 large core)",
    )
}

/// Fig 23 (31): Lasso with 30 training NAs, multicore combos, zoo test.
pub fn fig23_lasso_multicore(ctx: &mut ReportCtx, full: bool) -> Vec<Table> {
    let seed = ctx.cfg.seed;
    let cells = combo_cells(ctx.registry(), full);
    let rows = sweep::run(
        ctx,
        &cells,
        |c| {
            vec![
                (c.fp32.clone(), DataSet::Synth),
                (c.fp32.clone(), DataSet::Zoo),
                (c.int8.clone(), DataSet::Synth),
                (c.int8.clone(), DataSet::Zoo),
            ]
        },
        |ctx, c| {
            let mut row = vec![c.fp32.combo_label()];
            for sc in [&c.fp32, &c.int8] {
                let (tr_full, _) = ctx.synth_profiles_split_cached(sc);
                let tr = &tr_full[..30.min(tr_full.len())];
                let te = ctx.profiles_cached(sc, DataSet::Zoo);
                let ev = eval_method(ctx, sc, tr, DataSet::Zoo, te, Method::Lasso, seed, None);
                row.push(pct(ev.end_to_end_mape));
            }
            row
        },
    );
    combo_tables(&cells, rows, |soc| {
        format!(
            "Fig {} — Lasso (30 training NAs) end-to-end MAPE per combo (zoo), {soc}",
            if full { 31 } else { 23 }
        )
    })
}

/// Fig 24: Lasso (30 NAs) on the four GPUs + feature-importance analysis.
pub fn fig24_lasso_gpu(ctx: &mut ReportCtx) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 24 — Lasso (30 training NAs) on GPUs (zoo test set)",
        &["gpu", "end-to-end MAPE"],
    );
    let zoo = ctx.zoo().to_vec();
    let seed = ctx.cfg.seed;
    let mut imp = Table::new(
        "Section 5.5.2 — top Lasso features for Conv2D / DepthwiseConv2D (feature index per Table 3)",
        &["gpu", "bucket", "top-1 feature", "top-2 feature"],
    );
    // Table 3 conv feature names (kernel rows add fused-extras features).
    let conv_names = [
        "in_h", "in_w", "in_c", "out_h", "out_w", "filters", "stride", "kh", "kw", "in_size",
        "out_size", "param_size", "FLOPs", "fused_extra_bytes", "fused_count",
    ];
    for soc in ctx.socs() {
        let sc = Scenario::gpu(&soc);
        let (tr_full, _) = ctx.synth_profiles_split(&sc);
        let tr = &tr_full[..30.min(tr_full.len())];
        let pred =
            ScenarioPredictor::train_from(&sc, tr, Method::Lasso, DeductionMode::Full, seed, None);
        let te = ctx.profiles(&sc, DataSet::Zoo).to_vec();
        let ev = evaluate(&pred, &zoo, &te);
        t.row(vec![soc.gpu.name.to_string(), pct(ev.end_to_end_mape)]);
        // The owned-model redesign makes trained models inspectable: pull
        // the fitted Lasso straight out of the bucket model instead of
        // re-fitting on the raw bucket data.
        for bucket in ["Conv2D", "DepthwiseConv2D"] {
            let Some(owned) = pred.model_named(bucket).and_then(|m| m.as_owned()) else {
                continue;
            };
            if let crate::predict::NativeModel::Lasso(l) = &owned.model {
                let ims = l.importances();
                if ims.len() >= 2 {
                    let nm = |i: usize| conv_names.get(i).copied().unwrap_or("?").to_string();
                    imp.row(vec![
                        soc.gpu.name.to_string(),
                        bucket.to_string(),
                        nm(ims[0].0),
                        nm(ims[1].0),
                    ]);
                }
            }
        }
    }
    vec![t, imp]
}

/// Fig 32: coefficient of variation of end-to-end latency vs core count.
pub fn fig32_cov(ctx: &mut ReportCtx) -> Vec<Table> {
    let mut tables = Vec::new();
    for soc in ctx.socs() {
        let mut t = Table::new(
            &format!("Fig 32 — CoV of end-to-end latency per combo (synthetic test NAs), {}", soc.name),
            &["combo", "mean CoV", "max CoV"],
        );
        for counts in ctx.combos(&soc) {
            let sc = Scenario::cpu(&soc, counts, DataRep::Fp32)
                .expect("combo drawn from the SoC's own cluster table");
            let profs = ctx.profiles(&sc, DataSet::Synth).to_vec();
            let covs: Vec<f64> = profs.iter().take(60).map(|p| cov(&p.samples)).collect();
            t.row(vec![
                sc.combo_label(),
                format!("{:.3}", mean(&covs)),
                format!("{:.3}", covs.iter().cloned().fold(0.0, f64::max)),
            ]);
        }
        tables.push(t);
    }
    tables
}

/// Fig 33: MLP per-op-type error vs train size (S855, 1 large core).
pub fn fig33_mlp_train_size(ctx: &mut ReportCtx) -> Vec<Table> {
    let Some(mlp) = mlp_ctx(ctx) else {
        let mut t = Table::new("Fig 33 — MLP per-op error vs train size", &["status"]);
        t.row(vec!["SKIPPED: artifacts/ not built (run `make artifacts`)".into()]);
        return vec![t];
    };
    let Ok(sc) = ctx.registry().one_large_core("Snapdragon855") else {
        return skipped_missing_soc("Fig 33 — MLP per-op error vs train size", "Snapdragon855");
    };
    let (tr_full, te) = ctx.synth_profiles_split(&sc);
    let test_g = ctx.synth_split().1.to_vec();
    let seed = ctx.cfg.seed;
    let mut t = Table::new(
        "Fig 33 — MLP MAPE vs train size on Snapdragon855 (1 large core, synthetic)",
        &["train size", "end-to-end", "Conv2D", "Concat/Split", "#concat/split samples"],
    );
    for &n in &[30usize, 100, ctx.cfg.n_train] {
        let n = n.min(tr_full.len());
        let tr = &tr_full[..n];
        let pred =
            ScenarioPredictor::train_from(&sc, tr, Method::Mlp, DeductionMode::Full, seed, Some(&mlp));
        let ev = evaluate(&pred, &test_g, &te);
        let samples: usize = tr
            .iter()
            .flat_map(|p| p.ops.iter())
            .filter(|o| o.bucket == "Concat/Split")
            .count();
        let get = |b: &str| ev.per_bucket_mape.get(b).map(|&m| pct(m)).unwrap_or("-".into());
        t.row(vec![
            format!("{n}"),
            pct(ev.end_to_end_mape),
            get("Conv2D"),
            get("Concat/Split"),
            format!("{samples}"),
        ]);
    }
    vec![t]
}

/// Table 2: Winograd applicability of the three ResNet16 convolutions.
pub fn table2_winograd(_ctx: &mut ReportCtx) -> Vec<Table> {
    let g = crate::zoo::resnets::resnet(16, 1.0);
    let mut t = Table::new(
        "Table 2 — Winograd applicability, ResNet16 convolutions (3x3, stride 1, 1 group)",
        &["in_c", "out_c", "out_h", "src_depth", "dst_depth", "total_tiles", "Adreno", "Mali"],
    );
    let targets = [(64usize, 64usize, 56usize), (128, 128, 28), (256, 256, 14)];
    for (in_c, out_c, out_h) in targets {
        let node = g
            .nodes
            .iter()
            .find(|n| {
                if let crate::graph::Op::Conv2D { kh: 3, kw: 3, stride: 1, groups: 1, out_c: oc, .. } = n.op {
                    g.shape(n.inputs[0]).c == in_c && oc == out_c && g.shape(n.outputs[0]).h == out_h
                } else {
                    false
                }
            })
            .expect("ResNet16 conv present");
        let info = select::conv_info(&g, node.id).unwrap();
        let src_depth = info.input_channel.div_ceil(4);
        let dst_depth = info.output_channel.div_ceil(4);
        let tiles = info.output_height.div_ceil(4) * info.output_width.div_ceil(4);
        t.row(vec![
            format!("{in_c}"),
            format!("{out_c}"),
            format!("{out_h}"),
            format!("{src_depth}"),
            format!("{dst_depth}"),
            format!("{tiles}"),
            if select::check_winograd(crate::tflite::GpuKind::Adreno6xx, &info) { "Yes".into() } else { "No".to_string() },
            if select::check_winograd(crate::tflite::GpuKind::Mali, &info) { "Yes".into() } else { "No".to_string() },
        ]);
    }
    vec![t]
}
