//! Section 3 measurement-study figures: multithreading (Figs 2/3/26),
//! quantization (Figs 4/5/27), kernel fusion (Figs 6/7/28/29), kernel
//! selection (Figs 8/9), framework overhead (Fig 10), latency breakdowns
//! (Figs 11/13), and the zoo scatter (Fig 25).

use crate::device::{DataRep, Target};
use crate::graph::OpType;
use crate::report::{DataSet, ReportCtx};
use crate::scenario::Scenario;
use crate::tflite::{compile, CompileOptions};
use crate::util::table::{ms, pct};
use crate::util::{mean, BoxStats, Table};

fn boxrow(label: &str, xs: &[f64], with_outliers: bool) -> Vec<String> {
    let b = BoxStats::from(xs);
    let mut row = vec![
        label.to_string(),
        format!("{}", b.n),
        ms(b.whisker_lo),
        ms(b.q1),
        ms(b.median),
        ms(b.q3),
        ms(b.whisker_hi),
        ms(b.mean),
    ];
    if with_outliers {
        row.push(
            b.outliers.iter().map(|&o| ms(o)).collect::<Vec<_>>().join(" "),
        );
    } else {
        row.push(format!("{}", b.outliers.len()));
    }
    row
}

fn box_header(with_outliers: bool) -> Vec<&'static str> {
    if with_outliers {
        vec!["config", "n", "whisk_lo", "q1", "median", "q3", "whisk_hi", "mean", "outlier values (ms)"]
    } else {
        vec!["config", "n", "whisk_lo", "q1", "median", "q3", "whisk_hi", "mean", "#outliers"]
    }
}

/// Fig 2 (Fig 26 with outlier values): end-to-end latency of the zoo per
/// multicore configuration, per SoC.
pub fn fig02_multicore(ctx: &mut ReportCtx, outliers: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for soc in ctx.socs() {
        let mut t = Table::new(
            &format!("Fig {} — multicore end-to-end latency (ms), {} ({})", if outliers { 26 } else { 2 }, soc.name, soc.platform),
            &box_header(outliers),
        );
        for counts in ctx.combos(&soc) {
            let sc = Scenario::cpu(&soc, counts, DataRep::Fp32)
                .expect("combo drawn from the SoC's own cluster table");
            let e2e: Vec<f64> = ctx
                .profiles(&sc, DataSet::Zoo)
                .iter()
                .map(|p| p.end_to_end_ms)
                .collect();
            t.row(boxrow(&sc.combo_label(), &e2e, outliers));
        }
        tables.push(t);
    }
    tables
}

/// Fig 3: op-wise speedup over one core as homogeneous core count grows.
pub fn fig03_op_speedup(ctx: &mut ReportCtx) -> Vec<Table> {
    let mut tables = Vec::new();
    let op_types = [
        OpType::Conv2D,
        OpType::DepthwiseConv2D,
        OpType::FullyConnected,
        OpType::Pooling,
        OpType::Mean,
        OpType::ElementWise,
        OpType::ConcatSplit,
    ];
    for soc in ctx.socs() {
        // The largest homogeneous cluster with >= 2 cores. A registered
        // custom device may have none (all count-1 clusters) — nothing to
        // sweep there, not a panic.
        let Some((ci, cluster)) =
            soc.clusters.iter().enumerate().find(|(_, c)| c.count >= 2)
        else {
            continue;
        };
        let mut t = Table::new(
            &format!(
                "Fig 3 — op-wise speedup vs 1 core on {} ({} cluster)",
                soc.name, cluster.name
            ),
            &{
                let mut h = vec!["op type"];
                for k in 2..=cluster.count {
                    h.push(Box::leak(format!("{k} cores").into_boxed_str()));
                }
                h
            },
        );
        // Profile per-op latencies at 1..count cores.
        let mut per_cores: Vec<std::collections::HashMap<OpType, Vec<f64>>> = Vec::new();
        for k in 1..=cluster.count {
            let mut counts = vec![0; soc.clusters.len()];
            counts[ci] = k;
            let sc = Scenario::cpu(&soc, counts, DataRep::Fp32)
                .expect("combo drawn from the SoC's own cluster table");
            let mut by_type: std::collections::HashMap<OpType, Vec<f64>> = Default::default();
            for p in ctx.profiles(&sc, DataSet::Zoo) {
                for o in &p.ops {
                    let ty = bucket_optype(&o.bucket);
                    by_type.entry(ty).or_default().push(o.latency_ms);
                }
            }
            per_cores.push(by_type);
        }
        for ty in op_types {
            let base = per_cores[0].get(&ty).map(|v| mean(v)).unwrap_or(f64::NAN);
            let mut row = vec![ty.name().to_string()];
            for k in 2..=cluster.count {
                let cur = per_cores[k - 1].get(&ty).map(|v| mean(v)).unwrap_or(f64::NAN);
                row.push(format!("{:.2}x", base / cur));
            }
            t.row(row);
        }
        tables.push(t);
    }
    tables
}

fn bucket_optype(bucket: &str) -> OpType {
    match bucket {
        "Conv2D" | "Winograd" | "GroupedConv2D" | "NaiveGroupedConv2D" => OpType::Conv2D,
        "DepthwiseConv2D" => OpType::DepthwiseConv2D,
        "FullyConnected" => OpType::FullyConnected,
        "Pooling" => OpType::Pooling,
        "Mean" => OpType::Mean,
        "Concat/Split" => OpType::ConcatSplit,
        "Pad" => OpType::Pad,
        "ElementWise" => OpType::ElementWise,
        "Activation" => OpType::Activation,
        "Softmax" => OpType::Softmax,
        _ => OpType::Reshape,
    }
}

/// Fig 4 (27): quantization speedup on end-to-end latency per core combo.
pub fn fig04_quantization(ctx: &mut ReportCtx, outliers: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for soc in ctx.socs() {
        let mut t = Table::new(
            &format!("Fig {} — int8 speedup over fp32 (end-to-end), {}", if outliers { 27 } else { 4 }, soc.name),
            &box_header(outliers),
        );
        for counts in ctx.combos(&soc).into_iter().take(5) {
            let f = Scenario::cpu(&soc, counts.clone(), DataRep::Fp32)
                .expect("combo drawn from the SoC's own cluster table");
            let q = Scenario::cpu(&soc, counts, DataRep::Int8)
                .expect("combo drawn from the SoC's own cluster table");
            let ef: Vec<f64> =
                ctx.profiles(&f, DataSet::Zoo).iter().map(|p| p.end_to_end_ms).collect();
            let eq: Vec<f64> =
                ctx.profiles(&q, DataSet::Zoo).iter().map(|p| p.end_to_end_ms).collect();
            let speedup: Vec<f64> = ef.iter().zip(&eq).map(|(a, b)| a / b).collect();
            t.row(boxrow(&f.combo_label(), &speedup, outliers));
        }
        tables.push(t);
    }
    tables
}

/// Fig 5: per-op-type quantization speedup (element-wise/pad degrade).
pub fn fig05_quant_opwise(ctx: &mut ReportCtx) -> Vec<Table> {
    let mut tables = Vec::new();
    for soc in ctx.socs() {
        let mut counts = vec![0; soc.clusters.len()];
        counts[0] = 1;
        let f = Scenario::cpu(&soc, counts.clone(), DataRep::Fp32)
            .expect("combo drawn from the SoC's own cluster table");
        let q = Scenario::cpu(&soc, counts, DataRep::Int8)
            .expect("combo drawn from the SoC's own cluster table");
        let pf = ctx.profiles(&f, DataSet::Zoo).to_vec();
        let pq = ctx.profiles(&q, DataSet::Zoo).to_vec();
        let mut t = Table::new(
            &format!("Fig 5 — int8 speedup per op type, {} (1 large core)", soc.name),
            &["op type", "n", "mean speedup", "median speedup"],
        );
        let mut by_type: std::collections::BTreeMap<String, Vec<f64>> = Default::default();
        for (a, b) in pf.iter().zip(&pq) {
            for (oa, ob) in a.ops.iter().zip(&b.ops) {
                by_type
                    .entry(oa.bucket.clone())
                    .or_default()
                    .push(oa.latency_ms / ob.latency_ms);
            }
        }
        for (ty, sp) in by_type {
            let med = crate::util::median(&sp);
            t.row(vec![ty, format!("{}", sp.len()), format!("{:.2}x", mean(&sp)), format!("{med:.2}x")]);
        }
        tables.push(t);
    }
    tables
}

/// Fig 6 (28): kernel fusion — (a) kernel-count reduction, (b) speedup.
pub fn fig06_fusion(ctx: &mut ReportCtx, outliers: bool) -> Vec<Table> {
    let mut a = Table::new(
        "Fig 6a — OpenCL kernels with vs without fusion (zoo)",
        &["model", "ops", "kernels (fused)", "reduction"],
    );
    let zoo: Vec<_> = ctx.zoo().to_vec();
    let mut reductions = Vec::new();
    for g in zoo.iter() {
        let fused = compile(&g, crate::tflite::GpuKind::Mali, CompileOptions::default());
        let red = 1.0 - fused.kernels.len() as f64 / g.nodes.len() as f64;
        reductions.push(red);
        if a.rows.len() < 12 {
            a.row(vec![
                g.name.clone(),
                format!("{}", g.nodes.len()),
                format!("{}", fused.kernels.len()),
                pct(red),
            ]);
        }
    }
    a.row(vec![
        "MEAN (all)".into(),
        "-".into(),
        "-".into(),
        pct(mean(&reductions)),
    ]);

    let mut b = Table::new(
        &format!("Fig {} — fusion end-to-end speedup per GPU", if outliers { 28 } else { 6 }),
        &box_header(outliers),
    );
    for soc in ctx.socs() {
        let on = Scenario::gpu(&soc);
        let off = Scenario {
            target: Target::Gpu { options: CompileOptions { fusion: false, ..Default::default() } },
            id: format!("{}/gpu/nofusion", soc.name),
            soc: soc.clone(),
            workload: None,
        };
        let eon: Vec<f64> =
            ctx.profiles(&on, DataSet::Zoo).iter().map(|p| p.end_to_end_ms).collect();
        let eoff: Vec<f64> =
            ctx.profiles(&off, DataSet::Zoo).iter().map(|p| p.end_to_end_ms).collect();
        let speedup: Vec<f64> = eoff.iter().zip(&eon).map(|(a, b)| a / b).collect();
        b.row(boxrow(&soc.gpu.name, &speedup, outliers));
    }
    vec![a, b]
}

/// Fig 7 (29): fusion speedup per op type (element-wise ops vanish).
pub fn fig07_fusion_opwise(ctx: &mut ReportCtx, outliers: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for soc in ctx.socs().into_iter().take(2) {
        let on = Scenario::gpu(&soc);
        let off = Scenario {
            target: Target::Gpu { options: CompileOptions { fusion: false, ..Default::default() } },
            id: format!("{}/gpu/nofusion", soc.name),
            soc: soc.clone(),
            workload: None,
        };
        let pon = ctx.profiles(&on, DataSet::Zoo).to_vec();
        let poff = ctx.profiles(&off, DataSet::Zoo).to_vec();
        let mut t = Table::new(
            &format!(
                "Fig {} — per-op-type cost with fusion on/off, {} (total ms over zoo)",
                if outliers { 29 } else { 7 },
                soc.gpu.name
            ),
            &["op type", "unfused total", "fused total (incl. absorbed)", "speedup"],
        );
        // With fusion, an absorbed op's cost is inside its root kernel; we
        // attribute fused-kernel cost to the root type and count standalone
        // element-wise kernels separately — mirroring how the paper
        // attributes OpenCL timestamps.
        let mut unfused: std::collections::BTreeMap<String, f64> = Default::default();
        let mut fused: std::collections::BTreeMap<String, f64> = Default::default();
        for p in &poff {
            for o in &p.ops {
                *unfused.entry(o.bucket.clone()).or_default() += o.latency_ms;
            }
        }
        for p in &pon {
            for o in &p.ops {
                *fused.entry(o.bucket.clone()).or_default() += o.latency_ms;
            }
        }
        for (ty, un) in &unfused {
            let fu = fused.get(ty).copied().unwrap_or(0.0);
            let speedup = if fu > 0.0 { format!("{:.2}x", un / fu) } else { "fully fused".into() };
            t.row(vec![ty.clone(), ms(*un), ms(fu), speedup]);
        }
        tables.push(t);
    }
    tables
}

/// Fig 8: Winograd end-to-end speedup per GPU (none on Adreno).
pub fn fig08_winograd(ctx: &mut ReportCtx) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 8 — Winograd kernels: end-to-end speedup per GPU (zoo)",
        &["gpu", "NAs with Winograd", "mean speedup", "max speedup"],
    );
    for soc in ctx.socs() {
        let on = Scenario::gpu(&soc);
        let off = Scenario {
            target: Target::Gpu { options: CompileOptions { winograd: false, ..Default::default() } },
            id: format!("{}/gpu/nowinograd", soc.name),
            soc: soc.clone(),
            workload: None,
        };
        let eon = ctx.profiles(&on, DataSet::Zoo).to_vec();
        let eoff = ctx.profiles(&off, DataSet::Zoo).to_vec();
        let mut speedups = Vec::new();
        let mut with_wino = 0usize;
        for (a, b) in eoff.iter().zip(&eon) {
            let has = b.ops.iter().any(|o| o.bucket == "Winograd");
            if has {
                with_wino += 1;
                speedups.push(a.end_to_end_ms / b.end_to_end_ms);
            }
        }
        let (m, mx) = if speedups.is_empty() {
            ("-".to_string(), "-".to_string())
        } else {
            (
                format!("{:.2}x", mean(&speedups)),
                format!("{:.2}x", speedups.iter().cloned().fold(0.0, f64::max)),
            )
        };
        t.row(vec![soc.gpu.name.to_string(), format!("{with_wino}"), m, mx]);
    }
    vec![t]
}

/// Fig 9: optimized grouped_convolution_2d speedup per GPU.
pub fn fig09_grouped(ctx: &mut ReportCtx) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 9 — grouped_convolution_2d kernel: end-to-end speedup (zoo NAs with grouped convs)",
        &["gpu", "model", "naive (ms)", "optimized (ms)", "speedup"],
    );
    // Grouped-convolution NAs (ResNeXt / RegNetX); built explicitly so the
    // figure regenerates even when a zoo cap excludes them.
    let grouped: Vec<crate::graph::Graph> = {
        let mut v: Vec<_> = ctx
            .zoo()
            .iter()
            .filter(|g| g.op_type_histogram().contains_key(&OpType::GroupedConv2D))
            .take(3)
            .cloned()
            .collect();
        if v.is_empty() {
            v.push(crate::zoo::resnets::regnetx("004"));
            v.push(crate::zoo::resnets::resnext(26));
        }
        v
    };
    for soc in ctx.socs() {
        let on = Scenario::gpu(&soc);
        let off = Scenario {
            target: Target::Gpu { options: CompileOptions { grouped: false, ..Default::default() } },
            id: format!("{}/gpu/nogrouped", soc.name),
            soc: soc.clone(),
            workload: None,
        };
        for g in &grouped {
            let a = crate::profiler::profile(&off, g, ctx.cfg.seed, ctx.cfg.runs);
            let b = crate::profiler::profile(&on, g, ctx.cfg.seed, ctx.cfg.runs);
            t.row(vec![
                soc.gpu.name.to_string(),
                g.name.clone(),
                ms(a.end_to_end_ms),
                ms(b.end_to_end_ms),
                format!("{:.2}x", a.end_to_end_ms / b.end_to_end_ms),
            ]);
        }
    }
    vec![t]
}

/// Fig 10: end-to-end minus op-sum gap (framework overhead) per device.
pub fn fig10_overhead(ctx: &mut ReportCtx) -> Vec<Table> {
    let mut cpu = Table::new(
        "Fig 10a — end-to-end minus Σop (ms), CPUs (1 large core, zoo)",
        &box_header(false),
    );
    let mut gpu = Table::new("Fig 10b — end-to-end minus Σkernel (ms), GPUs (zoo)", &box_header(false));
    for soc in ctx.socs() {
        let mut counts = vec![0; soc.clusters.len()];
        counts[0] = 1;
        let sc = Scenario::cpu(&soc, counts, DataRep::Fp32)
            .expect("combo drawn from the SoC's own cluster table");
        let gaps: Vec<f64> =
            ctx.profiles(&sc, DataSet::Zoo).iter().map(|p| p.overhead_ms()).collect();
        cpu.row(boxrow(&soc.name, &gaps, false));
        let sg = Scenario::gpu(&soc);
        let gg: Vec<f64> =
            ctx.profiles(&sg, DataSet::Zoo).iter().map(|p| p.overhead_ms()).collect();
        gpu.row(boxrow(&soc.gpu.name, &gg, false));
    }
    vec![cpu, gpu]
}

fn breakdown(profiles: &[crate::profiler::ModelProfile], title: &str) -> Table {
    let mut t = Table::new(title, &["op type", "median % of end-to-end", "mean %"]);
    let mut fracs: std::collections::BTreeMap<String, Vec<f64>> = Default::default();
    let all_types: std::collections::BTreeSet<String> = profiles
        .iter()
        .flat_map(|p| p.ops.iter().map(|o| o.bucket.clone()))
        .collect();
    for p in profiles {
        let mut per: std::collections::BTreeMap<String, f64> = Default::default();
        for o in &p.ops {
            *per.entry(o.bucket.clone()).or_default() += o.latency_ms;
        }
        for ty in &all_types {
            fracs
                .entry(ty.clone())
                .or_default()
                .push(per.get(ty).copied().unwrap_or(0.0) / p.end_to_end_ms);
        }
    }
    for (ty, fr) in fracs {
        t.row(vec![ty, pct(crate::util::median(&fr)), pct(mean(&fr))]);
    }
    t
}

/// Fig 11: latency breakdown of the zoo per op type (CPU + GPUs).
pub fn fig11_breakdown_zoo(ctx: &mut ReportCtx) -> Vec<Table> {
    let mut tables = Vec::new();
    let s855 = crate::device::soc_by_name("Snapdragon855").unwrap();
    let sc = Scenario::cpu(&s855, vec![1, 0, 0], DataRep::Fp32).expect("1L is valid on S855");
    let p = ctx.profiles(&sc, DataSet::Zoo).to_vec();
    tables.push(breakdown(&p, "Fig 11 — latency breakdown, Pixel 4 CPU (1 large core, zoo)"));
    for soc_name in ["Snapdragon855", "Exynos9820"] {
        let soc = crate::device::soc_by_name(soc_name).unwrap();
        let sg = Scenario::gpu(&soc);
        let p = ctx.profiles(&sg, DataSet::Zoo).to_vec();
        tables.push(breakdown(
            &p,
            &format!("Fig 11 — latency breakdown, {} (zoo; note Winograd on Mali only)", soc.gpu.name),
        ));
    }
    tables
}

/// Fig 13: latency breakdown of the synthetic dataset (mirrors Fig 11).
pub fn fig13_breakdown_synth(ctx: &mut ReportCtx) -> Vec<Table> {
    let mut tables = Vec::new();
    let s855 = crate::device::soc_by_name("Snapdragon855").unwrap();
    let sc = Scenario::cpu(&s855, vec![1, 0, 0], DataRep::Fp32).expect("1L is valid on S855");
    let p = ctx.profiles(&sc, DataSet::Synth).to_vec();
    tables.push(breakdown(&p, "Fig 13 — latency breakdown, Pixel 4 CPU (synthetic dataset)"));
    let e9820 = crate::device::soc_by_name("Exynos9820").unwrap();
    let sg = Scenario::gpu(&e9820);
    let p = ctx.profiles(&sg, DataSet::Synth).to_vec();
    tables.push(breakdown(&p, "Fig 13 — latency breakdown, Mali G76 (synthetic dataset)"));
    tables
}

/// Fig 25: zoo model size vs end-to-end latency on Adreno 640.
pub fn fig25_zoo_scatter(ctx: &mut ReportCtx) -> Vec<Table> {
    let s855 = crate::device::soc_by_name("Snapdragon855").unwrap();
    let sg = Scenario::gpu(&s855);
    let zoo = ctx.zoo().to_vec();
    let profs = ctx.profiles(&sg, DataSet::Zoo).to_vec();
    let mut t = Table::new(
        "Fig 25 — zoo: parameters vs end-to-end latency (Adreno 640)",
        &["model", "params (M)", "flops (G)", "latency (ms)"],
    );
    for (g, p) in zoo.iter().zip(&profs) {
        t.row(vec![
            g.name.clone(),
            format!("{:.2}", g.params() as f64 / 1e6),
            format!("{:.2}", g.flops() as f64 / 1e9),
            ms(p.end_to_end_ms),
        ]);
    }
    vec![t]
}
