//! Deterministic, dependency-free PRNG (xoshiro256** seeded via SplitMix64).
//!
//! Every stochastic component of the library (device noise, NAS sampling,
//! train/test splits, model initialization) draws from this generator so that
//! all figures and tables in EXPERIMENTS.md are exactly reproducible from the
//! seeds recorded there.

/// xoshiro256** generator. Small, fast, and high quality for simulation use.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream from this seed and a stream label.
    /// Used to give every (model, scenario, run) its own reproducible stream.
    pub fn derive(seed: u64, labels: &[u64]) -> Self {
        let mut h = seed ^ 0xD6E8_FEB8_6659_FD93;
        for &l in labels {
            let mut sm = h ^ l.wrapping_mul(0x2545_F491_4F6C_DD1D);
            h = splitmix64(&mut sm);
        }
        Rng::new(h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform usize in [lo, hi] (inclusive).
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        lo + (self.next_u64() % span) as usize
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len() - 1)]
    }

    /// Standard normal deviate (Box-Muller, with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Log-normal multiplicative factor with E[ln X] = 0 and Std[ln X] = sigma.
    /// The mean is then exp(sigma^2/2) ~= 1 for small sigma; we recentre so the
    /// *mean* is exactly 1, keeping simulated latencies unbiased.
    pub fn lognormal_unit_mean(&mut self, sigma: f64) -> f64 {
        let z = self.normal();
        (sigma * z - 0.5 * sigma * sigma).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_differs_by_label() {
        let mut a = Rng::derive(7, &[1, 2]);
        let mut b = Rng::derive(7, &[1, 3]);
        let same = (0..16).all(|_| a.next_u64() == b.next_u64());
        assert!(!same);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let mut acc = 0.0;
        for _ in 0..20_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        let mean = acc / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn range_usize_inclusive() {
        let mut r = Rng::new(3);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            let v = r.range_usize(2, 6);
            assert!((2..=6).contains(&v));
            seen[v - 2] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m += z;
            v += z * z;
        }
        let mean = m / n as f64;
        let var = v / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_unit_mean_is_unbiased() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mut acc = 0.0;
        for _ in 0..n {
            acc += r.lognormal_unit_mean(0.08);
        }
        let mean = acc / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
    }
}
