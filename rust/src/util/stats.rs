//! Summary statistics used throughout the evaluation harness: boxplot
//! five-number summaries (matching the paper's plotting convention of
//! 1.5x-IQR whiskers), MAPE, coefficient of variation.

/// Five-number boxplot summary with 1.5x-IQR whiskers, the convention used
/// by every boxplot figure in the paper (Figs 2, 4, 6, 8, 9, 15, 16, ...).
#[derive(Debug, Clone, PartialEq)]
pub struct BoxStats {
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
    /// Lower whisker: smallest datum >= q1 - 1.5*IQR.
    pub whisker_lo: f64,
    /// Upper whisker: largest datum <= q3 + 1.5*IQR.
    pub whisker_hi: f64,
    /// Data outside the whiskers.
    pub outliers: Vec<f64>,
    pub n: usize,
}

/// Linear-interpolation quantile (same as numpy's default) on a sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "quantile of empty slice");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, 0.5)
}

pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    var.sqrt()
}

/// Coefficient of variation (std/mean) — Fig 32 of the paper.
pub fn cov(xs: &[f64]) -> f64 {
    std_dev(xs) / mean(xs)
}

impl BoxStats {
    pub fn from(xs: &[f64]) -> BoxStats {
        assert!(!xs.is_empty(), "BoxStats of empty slice");
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q1 = quantile_sorted(&v, 0.25);
        let q3 = quantile_sorted(&v, 0.75);
        let iqr = q3 - q1;
        let lo_fence = q1 - 1.5 * iqr;
        let hi_fence = q3 + 1.5 * iqr;
        let whisker_lo = *v
            .iter()
            .find(|&&x| x >= lo_fence)
            .unwrap_or(&v[0]);
        let whisker_hi = *v
            .iter()
            .rev()
            .find(|&&x| x <= hi_fence)
            .unwrap_or(v.last().unwrap());
        let outliers = v
            .iter()
            .copied()
            .filter(|&x| x < lo_fence || x > hi_fence)
            .collect();
        BoxStats {
            min: v[0],
            q1,
            median: quantile_sorted(&v, 0.5),
            q3,
            max: *v.last().unwrap(),
            mean: mean(&v),
            whisker_lo,
            whisker_hi,
            outliers,
            n: v.len(),
        }
    }

    /// Render as the compact one-line form used in figure reports.
    pub fn render(&self) -> String {
        format!(
            "n={:<4} q1={:9.3} med={:9.3} q3={:9.3} whisk=[{:9.3},{:9.3}] mean={:9.3} outliers={}",
            self.n, self.q1, self.median, self.q3, self.whisker_lo, self.whisker_hi, self.mean,
            self.outliers.len()
        )
    }
}

/// Smallest `|actual|` a percentage-error metric will divide by. A
/// zero-latency profile row (or a non-finite one) used to poison a whole
/// figure/loss with `inf`/NaN through the `(p - a) / a` term; rows below
/// this threshold are skipped and counted instead.
pub const MIN_PCT_DENOM: f64 = 1e-9;

/// Whether a (pred, actual) pair is usable by a percentage-error metric.
fn pct_row_ok(p: f64, a: f64) -> bool {
    p.is_finite() && a.is_finite() && a.abs() >= MIN_PCT_DENOM
}

/// Mean absolute percentage error — the paper's headline accuracy metric.
///
/// Rows with a zero/near-zero or non-finite `actual` (or a non-finite
/// prediction) are skipped; use [`mape_guarded`] to observe how many.
pub fn mape(pred: &[f64], actual: &[f64]) -> f64 {
    mape_guarded(pred, actual).0
}

/// [`mape`] with an explicit dropped-row count: `(value, dropped)`. NaN
/// when every row was dropped.
pub fn mape_guarded(pred: &[f64], actual: &[f64]) -> (f64, usize) {
    assert_eq!(pred.len(), actual.len());
    assert!(!pred.is_empty());
    let mut acc = 0.0;
    let mut kept = 0usize;
    for (&p, &a) in pred.iter().zip(actual) {
        if pct_row_ok(p, a) {
            acc += ((p - a) / a).abs();
            kept += 1;
        }
    }
    let value = if kept == 0 { f64::NAN } else { acc / kept as f64 };
    (value, pred.len() - kept)
}

/// Average ranks (1-based) with ties sharing their mean rank — the
/// fractional-ranking convention Spearman's rho assumes.
fn average_ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut ranks = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        // Positions i..=j (0-based) share the mean of ranks i+1..=j+1.
        let r = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            ranks[k] = r;
        }
        i = j + 1;
    }
    ranks
}

/// Spearman rank correlation (Pearson over fractional ranks, tie-aware).
/// Used by the multi-scenario search to answer the "one proxy device"
/// question: does ranking candidates by device A's predicted latency agree
/// with device B's? Returns NaN for fewer than 2 points or a constant side.
pub fn spearman(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.len() < 2 {
        return f64::NAN;
    }
    let (ra, rb) = (average_ranks(a), average_ranks(b));
    let (ma, mb) = (mean(&ra), mean(&rb));
    let mut num = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (x, y) in ra.iter().zip(&rb) {
        num += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    num / (va.sqrt() * vb.sqrt())
}

/// Root-mean-square percentage error (the training loss of Section 4.2).
///
/// Same zero/non-finite-denominator guard as [`mape`]; use
/// [`rmspe_guarded`] for the dropped-row count.
pub fn rmspe(pred: &[f64], actual: &[f64]) -> f64 {
    rmspe_guarded(pred, actual).0
}

/// [`rmspe`] with an explicit dropped-row count: `(value, dropped)`. NaN
/// when every row was dropped (or the input is empty).
pub fn rmspe_guarded(pred: &[f64], actual: &[f64]) -> (f64, usize) {
    assert_eq!(pred.len(), actual.len());
    let mut acc = 0.0;
    let mut kept = 0usize;
    for (&p, &a) in pred.iter().zip(actual) {
        if pct_row_ok(p, a) {
            let e = (p - a) / a;
            acc += e * e;
            kept += 1;
        }
    }
    let value = if kept == 0 { f64::NAN } else { (acc / kept as f64).sqrt() };
    (value, pred.len() - kept)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_match_numpy_convention() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile_sorted(&v, 0.25) - 1.75).abs() < 1e-12);
        assert!((quantile_sorted(&v, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile_sorted(&v, 0.75) - 3.25).abs() < 1e-12);
    }

    #[test]
    fn box_stats_basic() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let b = BoxStats::from(&xs);
        assert_eq!(b.min, 1.0);
        assert_eq!(b.max, 100.0);
        assert!((b.median - 50.5).abs() < 1e-9);
        assert!(b.outliers.is_empty());
    }

    #[test]
    fn box_stats_detects_outliers() {
        let mut xs: Vec<f64> = (1..=20).map(|x| x as f64).collect();
        xs.push(1000.0);
        let b = BoxStats::from(&xs);
        assert_eq!(b.outliers, vec![1000.0]);
        assert!(b.whisker_hi <= 20.0);
    }

    #[test]
    fn mape_zero_for_exact() {
        let p = [1.0, 2.0, 3.0];
        assert_eq!(mape(&p, &p), 0.0);
    }

    #[test]
    fn mape_value() {
        let p = [110.0, 90.0];
        let a = [100.0, 100.0];
        assert!((mape(&p, &a) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn cov_of_constant_is_zero() {
        assert_eq!(cov(&[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn rmspe_weights_large_errors_more() {
        let a = [100.0, 100.0];
        assert!(rmspe(&[120.0, 100.0], &a) > mape(&[120.0, 100.0], &a));
    }

    #[test]
    fn spearman_perfect_monotone_is_one() {
        let a: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let b: Vec<f64> = a.iter().map(|x| x * x + 3.0).collect(); // monotone, nonlinear
        assert!((spearman(&a, &b) - 1.0).abs() < 1e-12);
        let rev: Vec<f64> = a.iter().map(|x| -x).collect();
        assert!((spearman(&a, &rev) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties_with_average_ranks() {
        // Classic tie case: rho of [1,2,2,3] vs [1,2,3,4] via fractional
        // ranks [1, 2.5, 2.5, 4].
        let a = [1.0, 2.0, 2.0, 3.0];
        let b = [1.0, 2.0, 3.0, 4.0];
        let r = spearman(&a, &b);
        assert!((r - 0.9486832980505138).abs() < 1e-12, "r={r}");
        // Symmetric.
        assert_eq!(r.to_bits(), spearman(&b, &a).to_bits());
    }

    #[test]
    fn spearman_degenerate_inputs_are_nan() {
        assert!(spearman(&[1.0], &[2.0]).is_nan());
        assert!(spearman(&[5.0, 5.0, 5.0], &[1.0, 2.0, 3.0]).is_nan());
    }

    #[test]
    fn zero_latency_rows_are_dropped_not_poisonous() {
        // One zero-actual row used to turn the whole metric into inf/NaN.
        let p = [110.0, 90.0, 50.0];
        let a = [100.0, 100.0, 0.0];
        let (m, dropped) = mape_guarded(&p, &a);
        assert_eq!(dropped, 1);
        assert!((m - 0.1).abs() < 1e-12, "m={m}");
        assert!(mape(&p, &a).is_finite());
        let (r, dropped) = rmspe_guarded(&p, &a);
        assert_eq!(dropped, 1);
        assert!((r - 0.1).abs() < 1e-12, "r={r}");
        assert!(rmspe(&p, &a).is_finite());
    }

    #[test]
    fn non_finite_rows_are_dropped_and_counted() {
        let p = [f64::NAN, 105.0, 100.0];
        let a = [100.0, f64::INFINITY, 100.0];
        let (m, dropped) = mape_guarded(&p, &a);
        assert_eq!(dropped, 2);
        assert_eq!(m, 0.0);
        let (r, dropped) = rmspe_guarded(&p, &a);
        assert_eq!(dropped, 2);
        assert_eq!(r, 0.0);
    }

    #[test]
    fn all_rows_dropped_yields_nan_with_full_count() {
        let p = [1.0, 2.0];
        let a = [0.0, MIN_PCT_DENOM / 2.0];
        let (m, dropped) = mape_guarded(&p, &a);
        assert!(m.is_nan());
        assert_eq!(dropped, 2);
        let (r, dropped) = rmspe_guarded(&p, &a);
        assert!(r.is_nan());
        assert_eq!(dropped, 2);
    }

    #[test]
    fn clean_rows_unchanged_by_the_guard() {
        let p = [110.0, 90.0, 55.0];
        let a = [100.0, 100.0, 50.0];
        let (m, dropped) = mape_guarded(&p, &a);
        assert_eq!(dropped, 0);
        assert_eq!(m.to_bits(), mape(&p, &a).to_bits());
        let (r, dropped) = rmspe_guarded(&p, &a);
        assert_eq!(dropped, 0);
        assert_eq!(r.to_bits(), rmspe(&p, &a).to_bits());
    }

    #[test]
    fn single_element() {
        let b = BoxStats::from(&[7.0]);
        assert_eq!(b.median, 7.0);
        assert_eq!(b.q1, 7.0);
        assert_eq!(b.q3, 7.0);
    }
}
