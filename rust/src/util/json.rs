//! Minimal JSON value type with emitter and parser.
//!
//! The crate set available in this environment has no serde, so model files
//! (our analogue of `.tflite` — see `graph::modelfile`) and datasets are
//! (de)serialized through this small, fully-tested JSON implementation.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Build a number array from an f64 slice (bundle serialization).
    pub fn from_f64s(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Required-key accessors with descriptive errors, used by the trained-
    /// model (de)serializers in `predict` and `engine::bundle`.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| format!("key '{key}' is not a number"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, String> {
        let x = self.req_f64(key)?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(format!("key '{key}' is not a non-negative integer"));
        }
        Ok(x as usize)
    }

    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| format!("key '{key}' is not a string"))
    }

    /// Parse this value as an array of non-negative integers — the one
    /// copy of the coercion rule shared by device-spec combos and bundle
    /// target counts (same rule as [`req_usize`](Self::req_usize), applied
    /// element-wise).
    pub fn usize_arr(&self) -> Result<Vec<usize>, String> {
        let arr = self.as_arr().ok_or_else(|| "not an array".to_string())?;
        arr.iter()
            .enumerate()
            .map(|(i, x)| {
                x.as_f64()
                    .filter(|v| *v >= 0.0 && v.fract() == 0.0)
                    .map(|v| v as usize)
                    .ok_or_else(|| format!("[{i}] is not a non-negative integer"))
            })
            .collect()
    }

    pub fn req_f64_arr(&self, key: &str) -> Result<Vec<f64>, String> {
        let arr = self
            .req(key)?
            .as_arr()
            .ok_or_else(|| format!("key '{key}' is not an array"))?;
        arr.iter()
            .map(|x| {
                x.as_f64()
                    .ok_or_else(|| format!("key '{key}' has a non-number element"))
            })
            .collect()
    }

    /// Serialize to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance by one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let j = Json::obj(vec![
            ("name", Json::str("conv1")),
            ("stride", Json::num(2.0)),
            ("shape", Json::arr(vec![Json::num(224.0), Json::num(224.0), Json::num(3.0)])),
            ("fused", Json::Bool(true)),
            ("extra", Json::Null),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_whitespace_and_escapes() {
        let s = r#" { "a" : [ 1 , 2.5 , -3e2 ] , "s" : "x\"y\n" } "#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(j.get("s").unwrap().as_str(), Some("x\"y\n"));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn rejects_unterminated() {
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn integers_emit_without_decimal() {
        assert_eq!(Json::num(5.0).to_string(), "5");
        assert_eq!(Json::num(5.5).to_string(), "5.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn f64_roundtrip_is_bit_exact() {
        // Rust's shortest-repr Display + parse::<f64> round-trips exactly;
        // bundle serialization relies on this for bit-identical predictions.
        // (-0.0 is the one exception: the integer fast-path emits "0", which
        // parses back as +0.0 — arithmetic-identical in every sum/compare.)
        let vals = [
            0.1,
            1.0 / 3.0,
            -1.75,
            std::f64::consts::PI,
            1.23e-17,
            98765.43210987654,
            f64::MIN_POSITIVE,
        ];
        let j = Json::from_f64s(&vals);
        let back = Json::parse(&j.to_string()).unwrap();
        for (a, b) in vals.iter().zip(back.as_arr().unwrap()) {
            assert_eq!(a.to_bits(), b.as_f64().unwrap().to_bits(), "{a}");
        }
    }

    #[test]
    fn req_accessors_report_missing_and_mistyped_keys() {
        let j = Json::parse(r#"{"a": 1.5, "s": "x", "v": [1, 2.5], "bad": ["x"]}"#).unwrap();
        assert_eq!(j.req_f64("a").unwrap(), 1.5);
        assert_eq!(j.req_str("s").unwrap(), "x");
        assert_eq!(j.req_f64_arr("v").unwrap(), vec![1.0, 2.5]);
        assert!(j.req("nope").unwrap_err().contains("nope"));
        assert!(j.req_f64("s").unwrap_err().contains("not a number"));
        assert!(j.req_usize("a").is_err());
        assert!(j.req_f64_arr("bad").unwrap_err().contains("non-number"));
    }

    #[test]
    fn unicode_passthrough() {
        let j = Json::str("λatency μs");
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_str(), Some("λatency μs"));
    }
}
