//! Dependency-free utilities: deterministic PRNG, summary statistics, a
//! small JSON implementation (no serde in the offline crate set), and the
//! wall-clock timing harness shared by `cargo bench` and `edgelat bench`.

pub mod json;
pub mod prng;
pub mod stats;
pub mod table;
pub mod timing;

pub use json::Json;
pub use prng::Rng;
pub use stats::{
    cov, mape, mape_guarded, mean, median, rmspe, rmspe_guarded, spearman, std_dev, BoxStats,
};
pub use table::Table;
