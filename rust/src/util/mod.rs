//! Dependency-free utilities: deterministic PRNG, summary statistics, and a
//! small JSON implementation (no serde in the offline crate set).

pub mod json;
pub mod prng;
pub mod stats;
pub mod table;

pub use json::Json;
pub use prng::Rng;
pub use stats::{cov, mape, mean, median, rmspe, std_dev, BoxStats};
pub use table::Table;
