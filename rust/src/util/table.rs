//! Plain-text table rendering for figure/table reproduction output
//! (`edgelat reproduce ...`) and for EXPERIMENTS.md. Markdown-compatible.

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Render as GitHub-flavored markdown.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Render as CSV (for downstream plotting).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .header
            .iter()
            .map(|s| esc(s))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a fraction as a percentage with one decimal, e.g. 0.063 -> "6.3%".
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Format milliseconds with adaptive precision.
pub fn ms(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.1}")
    } else if x >= 10.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_markdown() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("### demo"));
        assert!(r.contains("| a "));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.063), "6.3%");
    }
}
