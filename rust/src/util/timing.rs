//! Shared wall-clock micro-benchmark harness and streaming histogram (no
//! criterion in the offline crate set).
//!
//! [`time_named`] runs warmup + N timed iterations and summarizes
//! mean/min/p50 per run for `cargo bench --bench pipeline` and the
//! `edgelat bench` subcommand. [`LogHistogram`] is the shared streaming
//! percentile helper underneath it: fixed log-spaced buckets, `AtomicU64`
//! counts, O(1) `record` with **no per-sample allocation** — the serve
//! daemon's metrics endpoint and the open-loop load generator stream
//! service latencies into it from many threads at once.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Smallest value [`LogHistogram`] resolves. Everything at or below it
/// (including zero, negatives, and NaN) lands in the first bucket.
pub const HIST_FLOOR: f64 = 1e-9;

/// Log-spaced sub-buckets per octave (factor-of-two span). Eight per
/// octave bounds the quantization error at 2^(1/8) - 1 ≈ 9% relative.
const SUB_BUCKETS: usize = 8;

/// Octaves covered above [`HIST_FLOOR`]: 1e-9 · 2^44 ≈ 1.8e4, wide enough
/// for nanosecond timings and multi-hour aggregates on one scale. Values
/// past the top edge clamp into the last bucket (still finite).
const OCTAVES: usize = 44;

const N_BUCKETS: usize = SUB_BUCKETS * OCTAVES;

/// A streaming histogram over fixed log-spaced buckets.
///
/// `record` is lock-free (one relaxed `fetch_add` per sample) and takes
/// `&self`, so one histogram can be shared across worker threads without
/// wrapping it in a mutex. Percentile reads are point-in-time snapshots:
/// racing a concurrent `record` can at worst miss that sample, never
/// return a value outside the recorded range.
#[derive(Debug)]
pub struct LogHistogram {
    counts: Box<[AtomicU64]>,
    total: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> LogHistogram {
        LogHistogram::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            counts: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            total: AtomicU64::new(0),
        }
    }

    /// Number of buckets (fixed at construction).
    pub fn bucket_count() -> usize {
        N_BUCKETS
    }

    /// Exclusive upper edge of bucket `i`: `HIST_FLOOR * 2^((i+1)/8)`.
    /// Bucket `i` covers `[upper_edge(i-1), upper_edge(i))`, so the edge
    /// is an upper bound on every value counted in the bucket.
    pub fn upper_edge(i: usize) -> f64 {
        HIST_FLOOR * 2f64.powf((i as f64 + 1.0) / SUB_BUCKETS as f64)
    }

    fn index(v: f64) -> usize {
        if v.is_nan() || v <= HIST_FLOOR {
            return 0;
        }
        let i = ((v / HIST_FLOOR).log2() * SUB_BUCKETS as f64).floor() as isize;
        i.clamp(0, N_BUCKETS as isize - 1) as usize
    }

    /// Count one sample. O(1), allocation-free, callable from any thread.
    pub fn record(&self, v: f64) {
        self.counts[Self::index(v)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// The `q`-quantile (`q` clamped to `[0, 1]`), reported as the upper
    /// edge of the bucket holding the target rank — a conservative
    /// overestimate within 9% of the true quantile, and never below any
    /// recorded sample of lower rank (so `min ≤ p50 ≤ p99` always holds).
    /// NaN when the histogram is empty; callers emitting JSON must guard
    /// that case.
    pub fn percentile(&self, q: f64) -> f64 {
        let total = self.total.load(Ordering::Relaxed);
        if total == 0 {
            return f64::NAN;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= target {
                return Self::upper_edge(i);
            }
        }
        // Counts recorded after `total` was read; the last edge bounds them.
        Self::upper_edge(N_BUCKETS - 1)
    }

    /// The populated buckets as `(upper_edge, count)` pairs in ascending
    /// order — the compact wire form the serve `stats` endpoint emits.
    pub fn nonzero_buckets(&self) -> Vec<(f64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter_map(|(i, c)| {
                let n = c.load(Ordering::Relaxed);
                (n > 0).then(|| (Self::upper_edge(i), n))
            })
            .collect()
    }
}

/// Timing summary of one benchmarked operation.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
}

impl Sample {
    /// One human-readable report line.
    pub fn render(&self) -> String {
        format!(
            "{:<44} mean {}  min {}  p50 {}  (n={})",
            self.name,
            fmt_secs(self.mean_s),
            fmt_secs(self.min_s),
            fmt_secs(self.p50_s),
            self.iters
        )
    }
}

/// Format a duration in s/ms/µs with a stable width.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:9.3} s ")
    } else if s >= 1e-3 {
        format!("{:9.3} ms", s * 1e3)
    } else {
        format!("{:9.3} µs", s * 1e6)
    }
}

/// Time `f`: ~iters/10 warmup calls, then `iters` timed calls. The p50 is
/// streamed through a [`LogHistogram`] (bucket upper edge, ≤9% high)
/// rather than sorting a per-sample vector.
pub fn time_named<F: FnMut()>(name: &str, iters: usize, mut f: F) -> Sample {
    let iters = iters.max(1);
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let hist = LogHistogram::new();
    let mut sum = 0.0f64;
    let mut min_s = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let s = t0.elapsed().as_secs_f64();
        hist.record(s);
        sum += s;
        min_s = min_s.min(s);
    }
    Sample {
        name: name.to_string(),
        iters,
        mean_s: sum / iters as f64,
        min_s,
        p50_s: hist.percentile(0.5),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_statistics_are_consistent() {
        let mut calls = 0usize;
        let s = time_named("noop", 10, || calls += 1);
        assert_eq!(s.iters, 10);
        assert!(calls >= 10, "timed calls + warmup, got {calls}");
        assert!(s.min_s <= s.p50_s && s.p50_s >= 0.0);
        assert!(s.mean_s >= s.min_s);
        assert!(s.render().contains("noop"));
    }

    #[test]
    fn fmt_secs_picks_sensible_units() {
        assert!(fmt_secs(2.5).contains("s"));
        assert!(fmt_secs(2.5e-3).contains("ms"));
        assert!(fmt_secs(2.5e-6).contains("µs"));
    }

    #[test]
    fn bucket_boundaries_are_half_open_at_the_upper_edge() {
        // A value just under bucket i's upper edge counts in bucket i; a
        // value just over it counts in bucket i+1. (Exact edges are not
        // probed: 2^(k/8) is irrational for k not a multiple of 8, so the
        // float log cannot be asserted either way at the edge itself.)
        for i in [0usize, 1, 7, 8, 100, 239, LogHistogram::bucket_count() - 2] {
            let edge = LogHistogram::upper_edge(i);
            let h = LogHistogram::new();
            h.record(edge * 0.995);
            assert_eq!(
                h.nonzero_buckets(),
                vec![(edge, 1)],
                "bucket {i}: value below the edge must count under it"
            );
            let h = LogHistogram::new();
            h.record(edge * 1.005);
            assert_eq!(
                h.nonzero_buckets(),
                vec![(LogHistogram::upper_edge(i + 1), 1)],
                "bucket {i}: value above the edge must count in the next bucket"
            );
        }
    }

    #[test]
    fn floor_and_overflow_values_clamp_into_the_terminal_buckets() {
        let h = LogHistogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(f64::NAN);
        h.record(HIST_FLOOR);
        assert_eq!(h.nonzero_buckets(), vec![(LogHistogram::upper_edge(0), 4)]);
        let h = LogHistogram::new();
        h.record(1e30);
        h.record(f64::INFINITY);
        let top = LogHistogram::upper_edge(LogHistogram::bucket_count() - 1);
        assert_eq!(h.nonzero_buckets(), vec![(top, 2)]);
        assert!(h.percentile(0.99).is_finite());
    }

    #[test]
    fn percentiles_are_conservative_and_monotonic() {
        let h = LogHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-6); // 1µs ..= 1000µs, uniform
        }
        assert_eq!(h.count(), 1000);
        let (p50, p95, p99) = (h.percentile(0.5), h.percentile(0.95), h.percentile(0.99));
        // Each quantile is an upper bound on the true value, within the
        // 9% bucket-width guarantee.
        assert!((500e-6..=500e-6 * 1.1).contains(&p50), "p50={p50}");
        assert!((950e-6..=950e-6 * 1.1).contains(&p95), "p95={p95}");
        assert!((990e-6..=990e-6 * 1.1).contains(&p99), "p99={p99}");
        assert!(p50 <= p95 && p95 <= p99);
        // Extremes: q=0 covers the first sample, q=1 the last.
        assert!(h.percentile(0.0) >= 1e-6);
        assert!(h.percentile(1.0) >= 1000e-6);
    }

    #[test]
    fn empty_histogram_reports_nan_not_a_bucket_edge() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert!(h.percentile(0.5).is_nan());
        assert!(h.nonzero_buckets().is_empty());
    }

    #[test]
    fn concurrent_records_are_all_counted() {
        let h = LogHistogram::new();
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = &h;
                s.spawn(move || {
                    for i in 0..1000 {
                        h.record((t * 1000 + i) as f64 * 1e-7);
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
        assert_eq!(h.nonzero_buckets().iter().map(|(_, c)| c).sum::<u64>(), 4000);
    }
}
