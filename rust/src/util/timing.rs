//! Shared wall-clock micro-benchmark harness (no criterion in the offline
//! crate set): warmup + N timed iterations, mean/min/p50 per run. Used by
//! `cargo bench --bench pipeline` and the `edgelat bench` subcommand.

use std::time::Instant;

/// Timing summary of one benchmarked operation.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
}

impl Sample {
    /// One human-readable report line.
    pub fn render(&self) -> String {
        format!(
            "{:<44} mean {}  min {}  p50 {}  (n={})",
            self.name,
            fmt_secs(self.mean_s),
            fmt_secs(self.min_s),
            fmt_secs(self.p50_s),
            self.iters
        )
    }
}

/// Format a duration in s/ms/µs with a stable width.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:9.3} s ")
    } else if s >= 1e-3 {
        format!("{:9.3} ms", s * 1e3)
    } else {
        format!("{:9.3} µs", s * 1e6)
    }
}

/// Time `f`: ~iters/10 warmup calls, then `iters` timed calls.
pub fn time_named<F: FnMut()>(name: &str, iters: usize, mut f: F) -> Sample {
    let iters = iters.max(1);
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).expect("durations are finite"));
    Sample {
        name: name.to_string(),
        iters,
        mean_s: samples.iter().sum::<f64>() / samples.len() as f64,
        min_s: samples[0],
        p50_s: samples[samples.len() / 2],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_statistics_are_consistent() {
        let mut calls = 0usize;
        let s = time_named("noop", 10, || calls += 1);
        assert_eq!(s.iters, 10);
        assert!(calls >= 10, "timed calls + warmup, got {calls}");
        assert!(s.min_s <= s.p50_s && s.p50_s >= 0.0);
        assert!(s.mean_s >= s.min_s);
        assert!(s.render().contains("noop"));
    }

    #[test]
    fn fmt_secs_picks_sensible_units() {
        assert!(fmt_secs(2.5).contains("s"));
        assert!(fmt_secs(2.5e-3).contains("ms"));
        assert!(fmt_secs(2.5e-6).contains("µs"));
    }
}
