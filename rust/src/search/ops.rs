//! Variation operators over `BlockSpec` genomes, plus the cheap accuracy
//! proxy the search optimizes against its latency constraint.
//!
//! Operators act purely at the spec level: they never look at channel
//! divisibility, because `nas::SynthArch::rebuild` repairs every block
//! against the channel count actually flowing into it at realization time
//! (a mutation upstream can change what is divisible downstream). All
//! randomness comes from the caller's `Rng`, so a seeded search is fully
//! deterministic.

use crate::graph::Graph;
use crate::nas::{branch_ew_kinds, channel_range, BlockSpec};
use crate::util::Rng;

/// Probability that a block mutation resamples the whole block instead of
/// tweaking one parameter of the existing one.
const RESAMPLE_P: f64 = 0.2;

/// Cheap accuracy proxy (higher is better): log-FLOPs plus half
/// log-params. Log-FLOPs is the standing NAS capacity heuristic (the
/// repo's `nas_latency_constrained` example uses it alone); the parameter
/// term breaks ties between architectures that buy the same compute with
/// very different widths. Pure in the graph, so it is free at search
/// scale — the expensive objective is the latency side, served by the
/// engine.
pub fn accuracy_proxy(g: &Graph) -> f64 {
    (g.flops().max(1) as f64).ln() + 0.5 * (g.params().max(1) as f64).ln()
}

/// Sample a fresh block spec for position `i`, uniform over the space's
/// block types and parameter marginals (Section 4.3.2). Divisibility is
/// *not* enforced here — rebuild repairs it in context.
pub fn random_block(rng: &mut Rng, i: usize) -> BlockSpec {
    let (lo, hi) = channel_range(i);
    let out_c = rng.range_usize(lo, hi);
    match rng.range_usize(0, 4) {
        0 => {
            let k = *rng.choice(&[3usize, 5, 7]);
            let groups = if rng.bool(0.5) { 4 * rng.range_usize(1, 16) } else { 1 };
            BlockSpec::Conv { k, groups, out_c }
        }
        1 => BlockSpec::DwSeparable { k: *rng.choice(&[3usize, 5, 7]), out_c },
        2 => BlockSpec::Bottleneck {
            k: *rng.choice(&[3usize, 5, 7]),
            expand: *rng.choice(&[1usize, 3, 6]),
            se: rng.bool(0.5),
            out_c,
        },
        3 => BlockSpec::Pool { avg: rng.bool(0.5), k: *rng.choice(&[1usize, 3]) },
        _ => BlockSpec::SplitEwConcat {
            ways: rng.range_usize(2, 4),
            ew: *rng.choice(branch_ew_kinds()),
        },
    }
}

/// Mutate one block: with probability [`RESAMPLE_P`] resample it
/// entirely, otherwise perturb a single parameter (kernel size, channel
/// count, expansion, SE flag, pool kind, split arity, branch op).
pub fn mutate_block(rng: &mut Rng, spec: &BlockSpec, i: usize) -> BlockSpec {
    if rng.bool(RESAMPLE_P) {
        return random_block(rng, i);
    }
    let (lo, hi) = channel_range(i);
    match spec {
        BlockSpec::Conv { k, groups, out_c } => match rng.range_usize(0, 2) {
            0 => {
                BlockSpec::Conv { k: *rng.choice(&[3usize, 5, 7]), groups: *groups, out_c: *out_c }
            }
            1 => BlockSpec::Conv { k: *k, groups: *groups, out_c: rng.range_usize(lo, hi) },
            _ => {
                // Toggle grouping: plain ↔ a fresh 4k group count.
                let groups = if *groups > 1 { 1 } else { 4 * rng.range_usize(1, 16) };
                BlockSpec::Conv { k: *k, groups, out_c: *out_c }
            }
        },
        BlockSpec::DwSeparable { k, out_c } => {
            if rng.bool(0.5) {
                BlockSpec::DwSeparable { k: *rng.choice(&[3usize, 5, 7]), out_c: *out_c }
            } else {
                BlockSpec::DwSeparable { k: *k, out_c: rng.range_usize(lo, hi) }
            }
        }
        BlockSpec::Bottleneck { k, expand, se, out_c } => match rng.range_usize(0, 3) {
            0 => BlockSpec::Bottleneck {
                k: *rng.choice(&[3usize, 5, 7]),
                expand: *expand,
                se: *se,
                out_c: *out_c,
            },
            1 => BlockSpec::Bottleneck {
                k: *k,
                expand: *rng.choice(&[1usize, 3, 6]),
                se: *se,
                out_c: *out_c,
            },
            2 => BlockSpec::Bottleneck { k: *k, expand: *expand, se: !*se, out_c: *out_c },
            _ => BlockSpec::Bottleneck {
                k: *k,
                expand: *expand,
                se: *se,
                out_c: rng.range_usize(lo, hi),
            },
        },
        BlockSpec::Pool { avg, k } => {
            if rng.bool(0.5) {
                BlockSpec::Pool { avg: !*avg, k: *k }
            } else {
                BlockSpec::Pool { avg: *avg, k: *rng.choice(&[1usize, 3]) }
            }
        }
        BlockSpec::SplitEwConcat { ways, ew } => {
            if rng.bool(0.5) {
                BlockSpec::SplitEwConcat { ways: rng.range_usize(2, 4), ew: *ew }
            } else {
                BlockSpec::SplitEwConcat { ways: *ways, ew: *rng.choice(branch_ew_kinds()) }
            }
        }
    }
}

/// Mutate a genome: each block independently with probability `rate`, and
/// the head width with probability `rate` (resampled from its range).
pub fn mutate(
    rng: &mut Rng,
    blocks: &[BlockSpec],
    head_c: usize,
    rate: f64,
) -> (Vec<BlockSpec>, usize) {
    let out: Vec<BlockSpec> = blocks
        .iter()
        .enumerate()
        .map(|(i, b)| if rng.bool(rate) { mutate_block(rng, b, i) } else { b.clone() })
        .collect();
    let head = if rng.bool(rate) {
        let (lo, hi) = channel_range(9);
        rng.range_usize(lo, hi)
    } else {
        head_c
    };
    (out, head)
}

/// One-point crossover: blocks before the cut come from parent `a`, the
/// rest (and the head width) from parent `b`.
pub fn crossover(
    rng: &mut Rng,
    a: (&[BlockSpec], usize),
    b: (&[BlockSpec], usize),
) -> (Vec<BlockSpec>, usize) {
    debug_assert_eq!(a.0.len(), b.0.len());
    let cut = rng.range_usize(1, a.0.len() - 1);
    let mut blocks = a.0[..cut].to_vec();
    blocks.extend_from_slice(&b.0[cut..]);
    (blocks, b.1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::SynthArch;

    #[test]
    fn operators_are_deterministic_in_the_seed() {
        let base = crate::nas::sample(3, 0);
        for seed in [1u64, 99] {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let m1 = mutate(&mut r1, &base.blocks, base.head_c, 0.5);
            let m2 = mutate(&mut r2, &base.blocks, base.head_c, 0.5);
            assert_eq!(m1, m2);
        }
    }

    #[test]
    fn mutated_genomes_always_rebuild_into_valid_graphs() {
        let mut rng = Rng::new(41);
        let mut arch = crate::nas::sample(41, 0);
        for step in 0..60 {
            let (blocks, head) = mutate(&mut rng, &arch.blocks, arch.head_c, 0.6);
            arch = SynthArch::rebuild(step, &blocks, head);
            arch.graph.validate().unwrap_or_else(|e| panic!("step {step}: {e}"));
        }
    }

    #[test]
    fn crossover_mixes_both_parents() {
        let a = crate::nas::sample(7, 1);
        let b = crate::nas::sample(7, 2);
        let mut rng = Rng::new(5);
        let (blocks, head) = crossover(&mut rng, (&a.blocks, a.head_c), (&b.blocks, b.head_c));
        assert_eq!(blocks.len(), 9);
        assert_eq!(head, b.head_c);
        // The child realizes into a valid graph.
        SynthArch::rebuild(0, &blocks, head).graph.validate().unwrap();
    }

    #[test]
    fn zero_rate_mutation_is_identity() {
        let a = crate::nas::sample(11, 3);
        let mut rng = Rng::new(1);
        let (blocks, head) = mutate(&mut rng, &a.blocks, a.head_c, 0.0);
        assert_eq!(blocks, a.blocks);
        assert_eq!(head, a.head_c);
    }

    #[test]
    fn accuracy_proxy_monotone_in_capacity() {
        // A wider model of the same family has more FLOPs and params.
        let small = crate::zoo::mobilenets::mobilenet_v2(0.5);
        let big = crate::zoo::mobilenets::mobilenet_v2(1.0);
        assert!(accuracy_proxy(&big) > accuracy_proxy(&small));
        assert!(accuracy_proxy(&small).is_finite());
    }
}
