//! Pareto-front bookkeeping for the latency-constrained search: the
//! latency/accuracy-proxy trade-off curve each scenario reports.

use crate::util::Json;
use std::collections::HashSet;

/// One evaluated candidate on (or considered for) a scenario's front.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontPoint {
    /// Candidate name (`synth_NNNN`, birth order across the whole run).
    pub name: String,
    /// Engine-predicted end-to-end latency on the scenario.
    pub latency_ms: f64,
    /// Accuracy proxy ([`ops::accuracy_proxy`](super::ops::accuracy_proxy)).
    pub proxy: f64,
    pub flops: u64,
    pub params: u64,
    /// Structural graph fingerprint — the dedup key (mutation can breed
    /// the same architecture twice under different names).
    pub fingerprint: u64,
}

/// `p` dominates `q`: no worse on both objectives (latency ↓, proxy ↑)
/// and strictly better on at least one.
pub fn dominates(p: &FrontPoint, q: &FrontPoint) -> bool {
    p.latency_ms <= q.latency_ms
        && p.proxy >= q.proxy
        && (p.latency_ms < q.latency_ms || p.proxy > q.proxy)
}

/// The non-dominated subset of `points`, deduplicated by graph
/// fingerprint (first occurrence wins — candidates are fed in birth
/// order) and sorted by (latency ↑, proxy ↓, name) so the output is
/// deterministic for any evaluation order.
pub fn pareto_front(points: &[FrontPoint]) -> Vec<FrontPoint> {
    let mut seen = HashSet::new();
    let uniq: Vec<&FrontPoint> =
        points.iter().filter(|p| seen.insert(p.fingerprint)).collect();
    let mut front: Vec<FrontPoint> = Vec::new();
    for p in &uniq {
        if !uniq.iter().any(|q| dominates(q, p)) {
            front.push((*p).clone());
        }
    }
    front.sort_by(|a, b| {
        a.latency_ms
            .total_cmp(&b.latency_ms)
            .then(b.proxy.total_cmp(&a.proxy))
            .then(a.name.cmp(&b.name))
    });
    front
}

impl FrontPoint {
    /// The JSON row of the `edgelat search` front output.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("latency_ms", Json::Num(self.latency_ms)),
            ("proxy", Json::Num(self.proxy)),
            ("flops", Json::num(self.flops as f64)),
            ("params", Json::num(self.params as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(name: &str, lat: f64, proxy: f64, fp: u64) -> FrontPoint {
        FrontPoint {
            name: name.into(),
            latency_ms: lat,
            proxy,
            flops: 1,
            params: 1,
            fingerprint: fp,
        }
    }

    #[test]
    fn front_is_non_dominated_and_sorted() {
        let pts = vec![
            p("a", 10.0, 5.0, 1),
            p("b", 20.0, 9.0, 2),
            p("c", 15.0, 4.0, 3), // dominated by a
            p("d", 5.0, 2.0, 4),
            p("e", 20.0, 8.0, 5), // dominated by b
        ];
        let front = pareto_front(&pts);
        let names: Vec<&str> = front.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["d", "a", "b"]);
        for x in &front {
            assert!(!front.iter().any(|y| dominates(y, x)), "{} dominated", x.name);
        }
    }

    #[test]
    fn duplicate_fingerprints_collapse() {
        let pts = vec![p("a", 10.0, 5.0, 1), p("b", 10.0, 5.0, 1), p("c", 30.0, 1.0, 2)];
        let front = pareto_front(&pts);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].name, "a");
    }

    #[test]
    fn equal_points_with_distinct_structure_both_survive() {
        // Neither strictly dominates the other.
        let pts = vec![p("a", 10.0, 5.0, 1), p("b", 10.0, 5.0, 2)];
        assert_eq!(pareto_front(&pts).len(), 2);
    }

    #[test]
    fn single_point_is_its_own_front() {
        let pts = vec![p("solo", 3.0, 3.0, 9)];
        assert_eq!(pareto_front(&pts), pts);
    }
}
