//! Latency-constrained evolutionary search over the synthetic NAS space —
//! the predictor-in-the-loop workload the paper's framework exists for
//! (Section 1: evaluate huge candidate sets without measuring each one).
//!
//! The repo could *sample* the Section 4.3.2 space (`nas::sample_dataset`)
//! but never *search* it; this module closes the loop on top of the
//! serving stack:
//!
//! - Candidates are genomes (`Vec<BlockSpec>` + head width) realized
//!   through `nas::SynthArch::rebuild`, which repairs the space's
//!   divisibility constraints in context — variation operators
//!   ([`ops`]) never produce an invalid graph.
//! - Every generation is scored with **one** `LatencyEngine::predict_batch`
//!   call over the `ExecPool`; plans are memoized by graph fingerprint in
//!   the engine's sharded cache, so elite survivors re-scored in later
//!   generations are cache hits, not re-lowerings.
//! - Selection is (μ+λ)-style with tournament parents: feasible
//!   candidates (predicted latency within budget) rank by accuracy proxy,
//!   infeasible ones rank by latency (pressure toward feasibility).
//! - Multi-scenario mode evolves one population per scenario and reports
//!   a per-scenario Pareto front (latency vs. proxy) over everything
//!   evaluated, plus a cross-device Spearman summary over the shared
//!   generation-0 population — the "one proxy device" question of
//!   PAPERS.md, answered from our own predictors.
//!
//! Everything is deterministic in `SearchConfig::seed`: the engine's
//! batch results are thread-count-invariant, the PRNG streams derive from
//! the seed, and all orderings carry total tie-breakers — `edgelat
//! search` output is byte-reproducible (asserted in `tests/search.rs`).

pub mod ops;
pub mod pareto;

pub use ops::accuracy_proxy;
pub use pareto::{dominates, pareto_front, FrontPoint};

use crate::engine::{EngineError, LatencyEngine, PredictRequest};
use crate::nas::{BlockSpec, SynthArch};
use crate::util::{spearman, Json, Rng};

/// Knobs of one search run. All sizes are clamped to sane minima by
/// [`run`]; determinism depends only on the field values.
#[derive(Debug, Clone)]
pub struct SearchConfig {
    pub seed: u64,
    /// Candidates per generation (≥ 2).
    pub population: usize,
    /// Generations including the sampled generation 0 (≥ 1).
    pub generations: usize,
    /// Latency constraint in ms; `None` searches unconstrained.
    pub budget_ms: Option<f64>,
    /// Top-ranked survivors copied unchanged into the next generation.
    pub elite: usize,
    /// Tournament size for parent selection.
    pub tournament: usize,
    /// Per-block (and head-width) mutation probability.
    pub mutation_rate: f64,
    /// Probability an offspring is a two-parent crossover.
    pub crossover_rate: f64,
}

impl SearchConfig {
    /// Smoke scale: completes in seconds on a warm engine.
    pub fn quick() -> SearchConfig {
        SearchConfig {
            seed: 2022,
            population: 12,
            generations: 3,
            budget_ms: None,
            elite: 2,
            tournament: 3,
            mutation_rate: 0.3,
            crossover_rate: 0.5,
        }
    }

    /// Default scale for a real search.
    pub fn full() -> SearchConfig {
        SearchConfig { population: 32, generations: 8, elite: 4, ..SearchConfig::quick() }
    }
}

/// One candidate scored on one scenario. Carries its genome so callers
/// can rebuild the winning architectures (`SynthArch::rebuild`).
#[derive(Debug, Clone)]
pub struct Scored {
    pub name: String,
    pub blocks: Vec<BlockSpec>,
    pub head_c: usize,
    /// Engine-predicted end-to-end latency.
    pub latency_ms: f64,
    pub proxy: f64,
    pub flops: u64,
    pub params: u64,
    pub fingerprint: u64,
    /// Within the latency budget (always true when unconstrained).
    pub feasible: bool,
}

impl Scored {
    fn point(&self) -> FrontPoint {
        FrontPoint {
            name: self.name.clone(),
            latency_ms: self.latency_ms,
            proxy: self.proxy,
            flops: self.flops,
            params: self.params,
            fingerprint: self.fingerprint,
        }
    }
}

/// The per-scenario outcome: the Pareto front over everything evaluated,
/// plus the final population (best-first under the search ranking).
#[derive(Debug, Clone)]
pub struct ScenarioSearch {
    pub scenario_id: String,
    /// Non-dominated (latency ↑ is worse, proxy ↑ is better) subset of
    /// every candidate evaluated for this scenario.
    pub front: Vec<FrontPoint>,
    /// Predictions served for this scenario (population × generations).
    pub evaluated: usize,
    /// Evaluations that satisfied the latency budget.
    pub feasible: usize,
    /// Final population, ranked best-first.
    pub survivors: Vec<Scored>,
}

/// A whole run: per-scenario searches plus the cross-device summary.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub scenarios: Vec<ScenarioSearch>,
    /// Pairwise Spearman rank correlation of predicted latency over the
    /// shared generation-0 population — how well one device's predictor
    /// ranks candidates for another.
    pub rank_correlation: Vec<(String, String, f64)>,
    /// Total predictions served across scenarios and generations.
    pub candidates_evaluated: usize,
}

#[derive(Clone)]
struct Genome {
    blocks: Vec<BlockSpec>,
    head_c: usize,
}

/// Rank best-first: feasible before infeasible; feasible by proxy
/// descending, infeasible by latency ascending; fingerprint then name as
/// total tie-breakers so the order (hence the whole run) is deterministic.
fn rank(pop: &mut [Scored]) {
    pop.sort_by(|a, b| {
        b.feasible
            .cmp(&a.feasible)
            .then_with(|| {
                if a.feasible {
                    b.proxy.total_cmp(&a.proxy)
                } else {
                    a.latency_ms.total_cmp(&b.latency_ms)
                }
            })
            .then_with(|| a.fingerprint.cmp(&b.fingerprint))
            .then_with(|| a.name.cmp(&b.name))
    });
}

/// Tournament pick over a best-first-ranked population: the best (lowest
/// index) of `k` uniform draws.
fn tournament_pick(rng: &mut Rng, n: usize, k: usize) -> usize {
    (0..k).map(|_| rng.range_usize(0, n - 1)).min().expect("k >= 1")
}

/// Stable FNV-1a label of a scenario id for RNG-stream derivation: the
/// per-scenario stream depends on the scenario itself, never on its
/// position in the request list, so adding a comparison device to a run
/// cannot change an existing device's search trajectory.
fn stream_label(id: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in id.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The one place an architecture plus an engine prediction becomes a
/// [`Scored`] — generation 0 and every later generation go through it, so
/// scoring semantics (feasibility rule, proxy, identity fields) cannot
/// diverge between the two paths.
fn to_scored(a: &SynthArch, latency_ms: f64, budget_ms: Option<f64>) -> Scored {
    Scored {
        name: a.graph.name.clone(),
        blocks: a.blocks.clone(),
        head_c: a.head_c,
        latency_ms,
        proxy: accuracy_proxy(&a.graph),
        flops: a.graph.flops(),
        params: a.graph.params(),
        fingerprint: a.graph.fingerprint(),
        feasible: budget_ms.map(|b| latency_ms <= b).unwrap_or(true),
    }
}

/// Score one realized population on one scenario with a single
/// `predict_batch` call. Fails on the first serving error (unknown
/// scenario / method mismatch poisons the whole search, not one slot).
fn score(
    engine: &LatencyEngine,
    scenario_id: &str,
    archs: &[SynthArch],
    budget_ms: Option<f64>,
) -> Result<Vec<Scored>, EngineError> {
    let reqs: Vec<PredictRequest> =
        archs.iter().map(|a| PredictRequest::new(&a.graph, scenario_id)).collect();
    let resps = engine.predict_batch(&reqs);
    archs
        .iter()
        .zip(resps)
        .map(|(a, r)| Ok(to_scored(a, r?.e2e_ms, budget_ms)))
        .collect()
}

/// Run the search against a loaded engine for one or more of its
/// scenarios. Generation 0 is sampled from the space (`nas::sample`, so
/// the same seed draws the same initial population for every scenario —
/// that shared set is what the rank-correlation summary compares); later
/// generations are bred per scenario by elitism + tournament selection +
/// crossover + mutation, realized through `SynthArch::rebuild`.
pub fn run(
    engine: &LatencyEngine,
    scenario_ids: &[String],
    cfg: &SearchConfig,
) -> Result<SearchOutcome, EngineError> {
    assert!(!scenario_ids.is_empty(), "search needs at least one scenario");
    let pop_n = cfg.population.max(2);
    let gens = cfg.generations.max(1);
    let elite = cfg.elite.clamp(1, pop_n - 1);
    let tour = cfg.tournament.max(1);

    // Generation 0, shared across scenarios; scored for every scenario in
    // one cross-scenario batch (pop × scenarios requests on the pool).
    let init: Vec<SynthArch> = (0..pop_n).map(|i| crate::nas::sample(cfg.seed, i)).collect();
    let mut gen0: Vec<Vec<Scored>> = Vec::with_capacity(scenario_ids.len());
    {
        let reqs: Vec<PredictRequest> = scenario_ids
            .iter()
            .flat_map(|sid| init.iter().map(move |a| PredictRequest::new(&a.graph, sid.clone())))
            .collect();
        let mut resps = engine.predict_batch(&reqs).into_iter();
        for _sid in scenario_ids {
            let mut scored = Vec::with_capacity(pop_n);
            for a in &init {
                let r = resps.next().expect("one response per request")?;
                scored.push(to_scored(a, r.e2e_ms, cfg.budget_ms));
            }
            gen0.push(scored);
        }
    }

    // Cross-device summary over the shared generation-0 latencies.
    let mut rank_correlation = Vec::new();
    for i in 0..scenario_ids.len() {
        for j in (i + 1)..scenario_ids.len() {
            let a: Vec<f64> = gen0[i].iter().map(|s| s.latency_ms).collect();
            let b: Vec<f64> = gen0[j].iter().map(|s| s.latency_ms).collect();
            rank_correlation.push((
                scenario_ids[i].clone(),
                scenario_ids[j].clone(),
                spearman(&a, &b),
            ));
        }
    }

    let mut candidates_evaluated = pop_n * scenario_ids.len();
    let mut scenarios = Vec::with_capacity(scenario_ids.len());
    for (sid, first) in scenario_ids.iter().zip(gen0) {
        // Each scenario evolves on its own id-derived stream, so its
        // result is independent of how many sibling scenarios the call
        // carries and of its position among them (asserted in
        // `tests/search.rs`).
        let mut rng = Rng::derive(cfg.seed, &[0x5ea7c4, stream_label(sid)]);
        let mut archive: Vec<FrontPoint> = first.iter().map(Scored::point).collect();
        let mut feasible = first.iter().filter(|s| s.feasible).count();
        let mut evaluated = pop_n;
        let mut pop = first;
        rank(&mut pop);
        // Per-scenario birth counter; generation 0 used ids 0..pop_n.
        let mut next_id = pop_n;
        for _gen in 1..gens {
            let mut genomes: Vec<Genome> = pop[..elite]
                .iter()
                .map(|s| Genome { blocks: s.blocks.clone(), head_c: s.head_c })
                .collect();
            while genomes.len() < pop_n {
                let pa = tournament_pick(&mut rng, pop_n, tour);
                let (blocks, head_c) = if rng.bool(cfg.crossover_rate) {
                    let pb = tournament_pick(&mut rng, pop_n, tour);
                    ops::crossover(
                        &mut rng,
                        (&pop[pa].blocks, pop[pa].head_c),
                        (&pop[pb].blocks, pop[pb].head_c),
                    )
                } else {
                    (pop[pa].blocks.clone(), pop[pa].head_c)
                };
                let (blocks, head_c) = ops::mutate(&mut rng, &blocks, head_c, cfg.mutation_rate);
                genomes.push(Genome { blocks, head_c });
            }
            // Realize and score the whole generation in one batch. Elites
            // rebuild to structurally identical graphs (rebuild is a
            // fixpoint on repaired specs), so their plans come out of the
            // engine's fingerprint-keyed cache.
            let archs: Vec<SynthArch> = genomes
                .iter()
                .map(|g| {
                    let a = SynthArch::rebuild(next_id, &g.blocks, g.head_c);
                    next_id += 1;
                    a
                })
                .collect();
            let scored = score(engine, sid, &archs, cfg.budget_ms)?;
            evaluated += scored.len();
            feasible += scored.iter().filter(|s| s.feasible).count();
            archive.extend(scored.iter().map(Scored::point));
            pop = scored;
            rank(&mut pop);
        }
        scenarios.push(ScenarioSearch {
            scenario_id: sid.clone(),
            front: pareto_front(&archive),
            evaluated,
            feasible,
            survivors: pop,
        });
        candidates_evaluated += evaluated - pop_n;
    }

    Ok(SearchOutcome { scenarios, rank_correlation, candidates_evaluated })
}

/// The `edgelat search` JSON artifact. Deterministic for a fixed config:
/// object keys are sorted by the emitter, arrays follow input order, and
/// no wall-clock values are included (timing goes to stderr, keeping the
/// artifact byte-reproducible). Spearman of degenerate pairs (constant
/// latencies) serializes as `null`.
pub fn report_json(cfg: &SearchConfig, out: &SearchOutcome) -> Json {
    let scenarios = out
        .scenarios
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("scenario", Json::str(s.scenario_id.clone())),
                ("evaluated", Json::num(s.evaluated as f64)),
                ("feasible", Json::num(s.feasible as f64)),
                ("front", Json::Arr(s.front.iter().map(FrontPoint::to_json).collect())),
            ])
        })
        .collect();
    let corr: Vec<Json> = out
        .rank_correlation
        .iter()
        .map(|(a, b, r)| {
            Json::obj(vec![
                ("a", Json::str(a.clone())),
                ("b", Json::str(b.clone())),
                ("spearman", if r.is_finite() { Json::Num(*r) } else { Json::Null }),
            ])
        })
        .collect();
    // Degenerate correlations (NaN from constant latencies) are counted
    // and skipped, never averaged in silently — consumers aggregating the
    // pair list can subtract them without re-scanning for nulls.
    let degenerate = out.rank_correlation.iter().filter(|(_, _, r)| !r.is_finite()).count();
    Json::obj(vec![
        ("format", Json::str("edgelat.search")),
        ("version", Json::num(1.0)),
        ("seed", Json::num(cfg.seed as f64)),
        ("population", Json::num(cfg.population as f64)),
        ("generations", Json::num(cfg.generations as f64)),
        ("budget_ms", cfg.budget_ms.map(Json::Num).unwrap_or(Json::Null)),
        ("candidates_evaluated", Json::num(out.candidates_evaluated as f64)),
        ("degenerate_pairs", Json::num(degenerate as f64)),
        ("scenarios", Json::Arr(scenarios)),
        ("rank_correlation", Json::Arr(corr)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scored(name: &str, lat: f64, proxy: f64, feasible: bool, fp: u64) -> Scored {
        Scored {
            name: name.into(),
            blocks: Vec::new(),
            head_c: 1200,
            latency_ms: lat,
            proxy,
            flops: 1,
            params: 1,
            fingerprint: fp,
            feasible,
        }
    }

    #[test]
    fn degenerate_spearman_is_counted_and_nulled_not_averaged() {
        // A NaN rank correlation (constant latencies on one device) must
        // surface as `null` in the pair list AND as a degenerate_pairs
        // count in the artifact — never as a bare NaN token (invalid
        // JSON) and never silently included in downstream means.
        let out = SearchOutcome {
            scenarios: Vec::new(),
            rank_correlation: vec![
                ("A/cpu".into(), "B/cpu".into(), 0.75),
                ("A/cpu".into(), "C/cpu".into(), f64::NAN),
                ("B/cpu".into(), "C/cpu".into(), f64::NAN),
            ],
            candidates_evaluated: 0,
        };
        let doc = report_json(&SearchConfig::quick(), &out);
        let text = doc.to_string();
        assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
        let doc = Json::parse(&text).expect("valid JSON");
        assert_eq!(doc.req_usize("degenerate_pairs").unwrap(), 2);
        let corr = doc.req("rank_correlation").unwrap().as_arr().unwrap();
        assert_eq!(corr.len(), 3);
        assert_eq!(corr[0].req_f64("spearman").unwrap(), 0.75);
        assert_eq!(corr[1].get("spearman"), Some(&Json::Null));
        assert_eq!(corr[2].get("spearman"), Some(&Json::Null));
    }

    #[test]
    fn ranking_prefers_feasible_then_proxy_then_latency() {
        let mut pop = vec![
            scored("slow_infeasible", 90.0, 9.0, false, 1),
            scored("fast_infeasible", 70.0, 1.0, false, 2),
            scored("weak_feasible", 10.0, 2.0, true, 3),
            scored("strong_feasible", 20.0, 8.0, true, 4),
        ];
        rank(&mut pop);
        let names: Vec<&str> = pop.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            ["strong_feasible", "weak_feasible", "fast_infeasible", "slow_infeasible"]
        );
    }

    #[test]
    fn ranking_breaks_exact_ties_deterministically() {
        let mut a = vec![
            scored("x", 10.0, 5.0, true, 2),
            scored("y", 10.0, 5.0, true, 1),
        ];
        let mut b = a.clone();
        b.reverse();
        rank(&mut a);
        rank(&mut b);
        let na: Vec<&str> = a.iter().map(|s| s.name.as_str()).collect();
        let nb: Vec<&str> = b.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(na, nb);
        assert_eq!(na, ["y", "x"], "fingerprint breaks the tie");
    }

    #[test]
    fn tournament_pick_is_best_of_k() {
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let i = tournament_pick(&mut rng, 10, 3);
            assert!(i < 10);
        }
        // k = n draws with a tiny population still terminate and stay in
        // range; k=1 is a uniform pick.
        let mut rng = Rng::new(8);
        assert!(tournament_pick(&mut rng, 2, 1) < 2);
    }

    #[test]
    fn quick_and_full_configs_are_sane() {
        for cfg in [SearchConfig::quick(), SearchConfig::full()] {
            assert!(cfg.population >= 2);
            assert!(cfg.generations >= 1);
            assert!(cfg.elite < cfg.population);
            assert!((0.0..=1.0).contains(&cfg.mutation_rate));
            assert!((0.0..=1.0).contains(&cfg.crossover_rate));
        }
    }
}
