//! Shared worker-pool subsystem for the repo's hot fan-out paths.
//!
//! The paper's value proposition is cheap prediction at NAS scale —
//! thousands of candidate architectures across 72 hardware scenarios
//! (Section 4.3) — so every layer above the device simulator has a fan-out
//! loop: the engine's `predict_batch`, the profiler's per-graph profiling,
//! and the multi-scenario figure sweeps in `report`. Before this module
//! each of those either ran sequentially or hand-rolled its own
//! `std::thread::scope`; they now share one substrate:
//!
//! - [`ExecPool`]: a scoped worker pool (no rayon in the offline crate
//!   set). Work is claimed in chunks from an atomic queue head, so uneven
//!   per-item cost (graphs differ wildly in op count) balances across
//!   workers without per-item contention. Results are collected **in input
//!   order**, and a fallible job simply maps to `R = Result<_, _>` so each
//!   slot carries its own error — one bad item never poisons the batch.
//! - [`ShardedCache`]: an N-way sharded memo (per-shard locks keyed by
//!   hash, per-shard capacity with per-shard eviction) so concurrent
//!   readers stop serializing on a single global `Mutex<HashMap>`. The
//!   engine's kernel-deduction memo is the flagship user.
//!
//! Everything here is `std`-only and deterministic in its outputs: a
//! `map` over pure per-item work returns bit-identical results for any
//! thread count, which the profiler and figure-sweep tests assert.

pub mod cache;

pub use cache::{CacheStats, ShardedCache};

use std::sync::atomic::{AtomicUsize, Ordering};

/// A scoped worker pool over `std::thread`. Cheap to construct (it holds
/// only a thread count; workers are spawned per `map` inside a
/// `thread::scope`), so it can live in a long-lived engine or be built on
/// the fly for a one-off sweep.
#[derive(Debug, Clone)]
pub struct ExecPool {
    threads: usize,
}

impl Default for ExecPool {
    fn default() -> ExecPool {
        ExecPool::with_default_parallelism()
    }
}

impl ExecPool {
    /// A pool with exactly `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> ExecPool {
        ExecPool { threads: threads.max(1) }
    }

    /// A pool sized to the machine's available parallelism.
    pub fn with_default_parallelism() -> ExecPool {
        ExecPool::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
    }

    /// Worker count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Workers actually spawned for a `map` over `n` items: never more
    /// than `n`, so a pool sized for big batches does not pay spawn cost
    /// for idle workers on tiny inputs (an 8-thread pool mapping 2 items
    /// spawns 2). With 0 or 1 items (or 1 thread) `map` runs inline and
    /// spawns nothing.
    pub fn workers_for(&self, n: usize) -> usize {
        let w = self.threads.min(n);
        if w <= 1 {
            0
        } else {
            w
        }
    }

    /// Apply `f` to every item and return the results **in input order**.
    ///
    /// `f` receives `(index, &item)` and must be pure per item for the
    /// output to be independent of the thread count (every caller in this
    /// crate satisfies that; the profiler/report tests assert it).
    ///
    /// Per-item errors: instantiate `R = Result<T, E>` — each output slot
    /// then carries its own error and the batch always completes. A panic
    /// inside `f`, by contrast, propagates out of `map`.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        // Sizing rule lives in `workers_for` (tested directly): more
        // threads than items must not spawn idle workers.
        let workers = self.workers_for(n);
        if workers == 0 {
            return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        // Chunked work queue: workers claim `chunk` indices at a time from
        // a shared head. Chunks ~4x smaller than an even split keep slow
        // items from stranding a worker while the rest idle.
        let chunk = (n / (workers * 4)).max(1);
        let next = AtomicUsize::new(0);
        let f = &f;
        let items_ref = items;
        let per_worker: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let next = &next;
                    scope.spawn(move || {
                        let mut local: Vec<(usize, R)> = Vec::new();
                        loop {
                            let start = next.fetch_add(chunk, Ordering::Relaxed);
                            if start >= n {
                                break;
                            }
                            let end = (start + chunk).min(n);
                            for i in start..end {
                                local.push((i, f(i, &items_ref[i])));
                            }
                        }
                        local
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(local) => local,
                    // Re-raise the worker's own panic payload so the
                    // original message/location reaches the caller instead
                    // of a generic pool error.
                    Err(payload) => std::panic::resume_unwind(payload),
                })
                .collect()
        });
        // Ordered collection: scatter each worker's (index, result) pairs
        // back into input order.
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in per_worker.into_iter().flatten() {
            debug_assert!(slots[i].is_none(), "index {i} claimed twice");
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|s| s.expect("every index claimed exactly once"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_input_yields_empty_output() {
        let out: Vec<u32> = ExecPool::new(8).map(&[] as &[u32], |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn order_preserved_for_any_thread_count() {
        let items: Vec<usize> = (0..257).collect();
        for threads in [1, 2, 3, 7, 32, 400] {
            let out = ExecPool::new(threads).map(&items, |i, &x| {
                assert_eq!(i, x, "index/item alignment");
                x * x
            });
            assert_eq!(out.len(), items.len());
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * i, "threads={threads}");
            }
        }
    }

    #[test]
    fn each_item_processed_exactly_once() {
        let calls = AtomicUsize::new(0);
        let items: Vec<u32> = (0..1000).collect();
        let out = ExecPool::new(5).map(&items, |_, &x| {
            calls.fetch_add(1, Ordering::Relaxed);
            x
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1000);
        assert_eq!(out, items);
    }

    #[test]
    fn per_item_error_slots_do_not_poison_the_batch() {
        let items: Vec<u32> = (0..50).collect();
        let out: Vec<Result<u32, String>> = ExecPool::new(4).map(&items, |_, &x| {
            if x % 7 == 0 {
                Err(format!("bad item {x}"))
            } else {
                Ok(x * 2)
            }
        });
        for (i, slot) in out.iter().enumerate() {
            let x = i as u32;
            match slot {
                Ok(v) => {
                    assert_ne!(x % 7, 0);
                    assert_eq!(*v, x * 2);
                }
                Err(e) => {
                    assert_eq!(x % 7, 0);
                    assert!(e.contains(&format!("{x}")), "{e}");
                }
            }
        }
    }

    #[test]
    fn uneven_work_is_balanced_and_complete() {
        // Item cost varies by orders of magnitude; chunked claiming must
        // still cover every index once and keep ordering.
        let items: Vec<usize> = (0..64).collect();
        let out = ExecPool::new(8).map(&items, |_, &x| {
            let spins: u64 = if x % 16 == 0 { 20_000 } else { 10 };
            let mut acc = x as u64;
            for i in 0..spins {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            std::hint::black_box(acc);
            x + 1
        });
        assert_eq!(out, (1..=64).collect::<Vec<_>>());
    }

    #[test]
    fn zero_threads_clamps_to_one() {
        let pool = ExecPool::new(0);
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.map(&[1, 2, 3], |_, &x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn more_threads_than_items_spawns_no_idle_workers() {
        let pool = ExecPool::new(64);
        assert_eq!(pool.workers_for(0), 0, "empty input spawns nothing");
        assert_eq!(pool.workers_for(1), 0, "single item runs inline");
        assert_eq!(pool.workers_for(2), 2);
        assert_eq!(pool.workers_for(3), 3);
        assert_eq!(pool.workers_for(64), 64);
        assert_eq!(pool.workers_for(1000), 64, "capped by the pool size");
        assert_eq!(ExecPool::new(1).workers_for(100), 0, "one thread runs inline");
        // The cap is observable: no worker thread ever runs `f` for a
        // single-item map (it executes on the caller's thread).
        let caller = std::thread::current().id();
        let out = pool.map(&[7], |_, &x| {
            assert_eq!(std::thread::current().id(), caller);
            x * 2
        });
        assert_eq!(out, vec![14]);
    }

    #[test]
    fn tiny_inputs_are_thread_count_invariant() {
        // Regression pin for the idle-worker fix: results over tiny inputs
        // are bit-identical for every thread count, including counts far
        // above the item count.
        for items in [vec![3.5f64], vec![1.25, 2.5], vec![0.1, 0.2, 0.3]] {
            let expect: Vec<f64> = items.iter().map(|x| (x * 1.7).sin()).collect();
            for threads in [1, 2, 3, 8, 64, 1024] {
                let got = ExecPool::new(threads).map(&items, |_, x| (x * 1.7).sin());
                assert_eq!(got.len(), expect.len());
                for (g, e) in got.iter().zip(&expect) {
                    assert_eq!(g.to_bits(), e.to_bits(), "threads={threads}");
                }
            }
        }
    }
}
