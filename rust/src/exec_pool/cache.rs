//! N-way sharded memo cache.
//!
//! A single `Mutex<HashMap>` memo serializes every concurrent reader on
//! one lock — exactly the hot path `LatencyEngine::predict_batch` fans
//! out. [`ShardedCache`] splits the key space across N independently
//! locked shards (shard = hash of key), so concurrent lookups of distinct
//! keys proceed in parallel, and overflow evicts **one shard** instead of
//! clearing the whole cache — a full batch keeps (N-1)/N of its warmth.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Cumulative cache counters (monotonic since construction).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// `get` calls that found the key.
    pub hits: u64,
    /// `get` calls that did not.
    pub misses: u64,
    /// Entries dropped by per-shard overflow clears.
    pub evictions: u64,
}

impl CacheStats {
    /// Counter-wise sum of two snapshots, saturating — aggregating many
    /// long-lived caches must never wrap back to small numbers.
    pub fn merge(&self, other: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_add(other.hits),
            misses: self.misses.saturating_add(other.misses),
            evictions: self.evictions.saturating_add(other.evictions),
        }
    }

    /// Aggregate any number of snapshots into one (e.g. the serve fleet
    /// merging every retired engine generation's counters with the live
    /// engine's, or a caller summing per-cache stats).
    pub fn merged(stats: impl IntoIterator<Item = CacheStats>) -> CacheStats {
        stats.into_iter().fold(CacheStats::default(), |acc, s| acc.merge(&s))
    }

    /// Counters accumulated since an `earlier` snapshot of the same cache,
    /// saturating at zero (a swapped-out cache restarts its counters; a
    /// stale "earlier" must not underflow into u64::MAX-sized deltas).
    pub fn delta_since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits.saturating_sub(earlier.hits),
            misses: self.misses.saturating_sub(earlier.misses),
            evictions: self.evictions.saturating_sub(earlier.evictions),
        }
    }

    /// Total lookups (hits + misses, saturating).
    pub fn lookups(&self) -> u64 {
        self.hits.saturating_add(self.misses)
    }

    /// Hit fraction in `[0, 1]`. A cache that has seen no lookups reports
    /// 0.0 — never a division-by-zero NaN that would poison downstream
    /// JSON artifacts and gates.
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.lookups();
        if lookups == 0 {
            0.0
        } else {
            self.hits as f64 / lookups as f64
        }
    }
}

/// A concurrent memo: per-shard `Mutex<HashMap>` with per-shard capacity.
///
/// Values are cloned out (use `Arc<V>` for anything non-trivial). The
/// intended usage for an expensive pure computation is get → compute
/// **outside any lock** → [`insert`](ShardedCache::insert); a racing
/// duplicate computes the same value and the first insert wins, so every
/// caller observes one canonical value per key.
#[derive(Debug)]
pub struct ShardedCache<K, V> {
    shards: Vec<Mutex<HashMap<K, V>>>,
    per_shard_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl<K: Hash + Eq, V: Clone> ShardedCache<K, V> {
    /// A cache with `shards` independent locks and `capacity` total
    /// entries (split evenly; both clamped to at least 1).
    pub fn new(shards: usize, capacity: usize) -> ShardedCache<K, V> {
        let n = shards.max(1);
        ShardedCache {
            shards: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
            per_shard_cap: (capacity / n).max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard_of(&self, key: &K) -> usize {
        // DefaultHasher with fixed keys: deterministic across calls within
        // a process, which is all shard routing needs.
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    /// Look up a key, counting the hit or miss.
    pub fn get(&self, key: &K) -> Option<V> {
        let shard = self.shards[self.shard_of(key)].lock().unwrap();
        let found = shard.get(key).cloned();
        drop(shard);
        match found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Insert a value, returning the canonical one: if another thread
    /// raced the same key in first, *its* value is kept and returned
    /// (first insert wins). When the target shard is at capacity it is
    /// cleared — only that shard; the other N-1 keep their entries.
    pub fn insert(&self, key: K, value: V) -> V {
        let mut shard = self.shards[self.shard_of(&key)].lock().unwrap();
        if shard.len() >= self.per_shard_cap && !shard.contains_key(&key) {
            self.evictions.fetch_add(shard.len() as u64, Ordering::Relaxed);
            shard.clear();
        }
        shard.entry(key).or_insert(value).clone()
    }

    /// Total entries across all shards (a point-in-time sum).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of shards (independent locks).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total capacity (per-shard cap x shard count).
    pub fn capacity(&self) -> usize {
        self.per_shard_cap * self.shards.len()
    }

    /// Snapshot of the cumulative counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_counts_hits_and_misses() {
        let c: ShardedCache<u32, u32> = ShardedCache::new(4, 64);
        assert_eq!(c.get(&1), None);
        c.insert(1, 10);
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&2), None);
        let st = c.stats();
        assert_eq!(st.hits, 1);
        assert_eq!(st.misses, 2);
        assert_eq!(st.evictions, 0);
    }

    #[test]
    fn first_insert_wins_on_races() {
        let c: ShardedCache<u32, u32> = ShardedCache::new(2, 16);
        assert_eq!(c.insert(7, 70), 70);
        // A "racing" duplicate insert must observe the canonical value.
        assert_eq!(c.insert(7, 999), 70);
        assert_eq!(c.get(&7), Some(70));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn single_shard_eviction_is_a_full_clear_at_capacity() {
        let c: ShardedCache<u64, u64> = ShardedCache::new(1, 8);
        for k in 0..8 {
            c.insert(k, k);
        }
        assert_eq!(c.len(), 8);
        assert_eq!(c.stats().evictions, 0);
        c.insert(100, 100);
        assert_eq!(c.stats().evictions, 8);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&100), Some(100));
    }

    #[test]
    fn eviction_clears_one_shard_not_the_whole_cache() {
        let c: ShardedCache<u64, u64> = ShardedCache::new(4, 16); // 4 per shard
        let mut k = 0u64;
        loop {
            let len_before = c.len();
            let ev_before = c.stats().evictions;
            c.insert(k, k);
            let evicted = c.stats().evictions - ev_before;
            if evicted > 0 {
                // A global clear would have dropped ~len_before entries;
                // a per-shard clear drops at most one shard's worth.
                assert!(evicted <= 4, "evicted {evicted} > one shard");
                assert_eq!(c.len(), len_before - evicted as usize + 1);
                assert!(!c.is_empty());
                return;
            }
            k += 1;
            assert!(k < 10_000, "eviction never triggered");
        }
    }

    #[test]
    fn concurrent_inserts_and_gets_are_consistent() {
        use std::sync::Arc;
        let c: Arc<ShardedCache<u64, u64>> = Arc::new(ShardedCache::new(8, 1 << 20));
        std::thread::scope(|scope| {
            for t in 0..8u64 {
                let c = Arc::clone(&c);
                scope.spawn(move || {
                    for k in 0..500u64 {
                        let canonical = c.insert(k, k * 1000 + t);
                        // Whatever thread won, every observer agrees on
                        // one value derived from the key.
                        assert_eq!(canonical / 1000, k);
                        assert_eq!(c.get(&k), Some(canonical));
                    }
                });
            }
        });
        assert_eq!(c.len(), 500);
        for k in 0..500u64 {
            let v = c.get(&k).unwrap();
            assert_eq!(v / 1000, k);
        }
    }

    #[test]
    fn stats_merge_and_delta_saturate() {
        let a = CacheStats { hits: 10, misses: 5, evictions: 1 };
        let b = CacheStats { hits: 2, misses: 3, evictions: 0 };
        assert_eq!(a.merge(&b), CacheStats { hits: 12, misses: 8, evictions: 1 });
        // Aggregation over an iterator, identity on the empty case.
        assert_eq!(CacheStats::merged([a, b]), a.merge(&b));
        assert_eq!(CacheStats::merged([]), CacheStats::default());
        // Near-overflow counters saturate instead of wrapping.
        let huge = CacheStats { hits: u64::MAX - 1, misses: u64::MAX, evictions: 0 };
        let sum = huge.merge(&a);
        assert_eq!(sum.hits, u64::MAX);
        assert_eq!(sum.misses, u64::MAX);
        assert_eq!(huge.lookups(), u64::MAX);
        // Deltas against a *newer* snapshot (cache swapped underneath the
        // caller) clamp at zero rather than underflowing.
        assert_eq!(b.delta_since(&a), CacheStats::default());
        assert_eq!(
            a.delta_since(&b),
            CacheStats { hits: 8, misses: 2, evictions: 1 }
        );
    }

    #[test]
    fn hit_rate_is_a_real_rate_never_nan() {
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
        let st = CacheStats { hits: 3, misses: 1, evictions: 0 };
        assert_eq!(st.hit_rate(), 0.75);
        let all_miss = CacheStats { hits: 0, misses: 9, evictions: 2 };
        assert_eq!(all_miss.hit_rate(), 0.0);
        let all_hit = CacheStats { hits: 9, misses: 0, evictions: 0 };
        assert_eq!(all_hit.hit_rate(), 1.0);
        // The saturated extreme still yields a finite rate in [0, 1].
        let huge = CacheStats { hits: u64::MAX, misses: u64::MAX, evictions: 0 };
        let r = huge.hit_rate();
        assert!(r.is_finite() && (0.0..=1.0).contains(&r));
    }

    #[test]
    fn capacity_and_shard_accessors() {
        let c: ShardedCache<u8, u8> = ShardedCache::new(0, 0);
        assert_eq!(c.shard_count(), 1);
        assert_eq!(c.capacity(), 1);
        let c: ShardedCache<u8, u8> = ShardedCache::new(16, 4096);
        assert_eq!(c.shard_count(), 16);
        assert_eq!(c.capacity(), 4096);
    }
}
