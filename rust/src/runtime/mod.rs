//! PJRT runtime: loads AOT-compiled HLO artifacts (produced once by
//! `python/compile/aot.py`, see `make artifacts`) and executes them on the
//! CPU PJRT client. This is the only bridge to the L2/L1 JAX+Pallas code —
//! Python never runs at prediction time.
//!
//! Interchange format is HLO *text* (not serialized proto): jax >= 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// A PJRT CPU client plus the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
}

/// A compiled executable; call [`Executable::run`] with positional inputs.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, artifact_dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Default artifact dir: `$EDGELAT_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("EDGELAT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.artifact_dir.join(name)
    }

    /// Load and compile an HLO-text artifact.
    pub fn load(&self, name: &str) -> Result<Executable> {
        let path = self.artifact_path(name);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing HLO text {path_str}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        Ok(Executable { exe, name: name.to_string() })
    }

    /// Read artifact metadata (JSON emitted by aot.py).
    pub fn metadata(&self, name: &str) -> Result<crate::util::Json> {
        let s = std::fs::read_to_string(self.artifact_path(name))
            .with_context(|| format!("reading {name}"))?;
        crate::util::Json::parse(&s).map_err(|e| anyhow!("parsing {name}: {e}"))
    }

    /// Whether the artifact directory has been built.
    pub fn artifacts_available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("mlp_meta.json").exists()
    }
}

impl Executable {
    /// Execute with positional literal inputs; the jax functions are lowered
    /// with `return_tuple=True`, so the single output tuple is unpacked into
    /// a vector of literals.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e:?}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling result of {}: {e:?}", self.name))
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    if expect != data.len() as i64 {
        return Err(anyhow!("literal shape {dims:?} wants {expect} elements, got {}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape to {dims:?}: {e:?}"))
}

/// Extract a literal back to a flat f32 vector.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Integration tests that need built artifacts live in rust/tests/;
    // here we only exercise the pure helpers.

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        assert!(literal_f32(&[1.0, 2.0], &[3, 3]).is_err());
    }

    #[test]
    fn default_dir_env_override() {
        // No EDGELAT_ARTIFACTS set in tests -> "artifacts".
        if std::env::var("EDGELAT_ARTIFACTS").is_err() {
            assert_eq!(Runtime::default_dir(), PathBuf::from("artifacts"));
        }
    }
}
