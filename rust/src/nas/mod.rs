//! The synthetic NAS space of Section 4.3.2 and Fig. 12.
//!
//! A synthetic architecture is a sequence of 9 building blocks that halves
//! the input width/height after blocks 1, 3, 5, 7 and 9, followed by a 1x1
//! convolution and a fully-connected layer producing a 1000-d output. The
//! type and parameters of each block are sampled uniformly at random:
//!
//! 1. convolution (kernel 3x3/5x5/7x7, optional group count 4k, 1<=k<=16)
//! 2. depthwise-separable convolution (kernel 3x3/5x5/7x7)
//! 3. linear bottleneck (kernel 3/5/7, expansion 1/3/6, optional SE)
//! 4. average or max pooling (pool size 1x1 or 3x3)
//! 5. split (2/3/4 ways) + element-wise op per branch + concat
//!
//! Output channels: C1..C5 ~ U[8, 80], C6..C9 ~ U[80, 400],
//! C10 (head conv) ~ U[1200, 1800]. Divisibility constraints (groups and
//! splits) are enforced by resampling, preserving the uniform marginals the
//! paper describes.

use crate::graph::{ActKind, EwKind, Graph, GraphBuilder, Padding, TensorId};
use crate::util::Rng;

/// Block descriptors, recorded so experiments can stratify by block type.
#[derive(Debug, Clone, PartialEq)]
pub enum BlockSpec {
    Conv { k: usize, groups: usize, out_c: usize },
    DwSeparable { k: usize, out_c: usize },
    Bottleneck { k: usize, expand: usize, se: bool, out_c: usize },
    Pool { avg: bool, k: usize },
    SplitEwConcat { ways: usize, ew: EwKind },
}

/// A sampled synthetic architecture: the spec and the built graph.
pub struct SynthArch {
    pub index: usize,
    pub blocks: Vec<BlockSpec>,
    pub head_c: usize,
    pub graph: Graph,
}

/// Unary element-wise ops that are numerically safe on activations.
const BRANCH_EW: [EwKind; 4] = [EwKind::Abs, EwKind::Neg, EwKind::Square, EwKind::Copy];

/// The element-wise kinds a split branch may apply — exported so the
/// search mutation operators draw from the same set as the sampler.
pub fn branch_ew_kinds() -> &'static [EwKind] {
    &BRANCH_EW
}

/// Output-channel sampling range for block position `i` (0-based; 9 means
/// the head conv). The marginals of Section 4.3.2, shared with the search
/// mutation operators so mutated channels stay inside the space.
pub fn channel_range(i: usize) -> (usize, usize) {
    match i {
        0..=4 => (8, 80),
        5..=8 => (80, 400),
        _ => (1200, 1800),
    }
}

fn sample_channels(rng: &mut Rng, i: usize) -> usize {
    let (lo, hi) = channel_range(i);
    rng.range_usize(lo, hi)
}

/// Largest group count of the form 4k (k<=16) dividing both channel counts,
/// at most the sampled `want`; falls back to 1 (no grouping).
fn fit_groups(want: usize, in_c: usize, out_c: usize) -> usize {
    let mut g = want;
    while g > 1 {
        if g % 4 == 0 && in_c % g == 0 && out_c % g == 0 {
            return g;
        }
        g -= 4;
    }
    1
}

fn fit_split(want: usize, c: usize) -> usize {
    for w in (2..=want).rev() {
        if c % w == 0 {
            return w;
        }
    }
    1
}

/// Sample one block spec. `i` is the 0-based block index (channels range
/// depends on position).
fn sample_block(rng: &mut Rng, i: usize, in_c: usize) -> BlockSpec {
    let out_c = sample_channels(rng, i);
    match rng.range_usize(0, 4) {
        0 => {
            let k = *rng.choice(&[3usize, 5, 7]);
            let groups = if rng.bool(0.5) {
                // groups = 4k, k in 1..=16, fitted to divisibility
                let want = 4 * rng.range_usize(1, 16);
                // grouped conv wants channel counts divisible by the group
                // count; round out_c up to a multiple of 4 to give groups a
                // chance (uniformity over multiples of 4, as the space's
                // grouped configurations require).
                let out_c4 = out_c.div_ceil(4) * 4;
                let g = fit_groups(want, in_c, out_c4);
                if g > 1 {
                    return BlockSpec::Conv { k, groups: g, out_c: out_c4 };
                }
                1
            } else {
                1
            };
            BlockSpec::Conv { k, groups, out_c }
        }
        1 => BlockSpec::DwSeparable { k: *rng.choice(&[3usize, 5, 7]), out_c },
        2 => BlockSpec::Bottleneck {
            k: *rng.choice(&[3usize, 5, 7]),
            expand: *rng.choice(&[1usize, 3, 6]),
            se: rng.bool(0.5),
            out_c,
        },
        3 => BlockSpec::Pool { avg: rng.bool(0.5), k: *rng.choice(&[1usize, 3]) },
        _ => {
            let want = rng.range_usize(2, 4);
            let ways = fit_split(want, in_c);
            if ways < 2 {
                // Channels not divisible: degrade to a pooling block, which
                // is the cheapest structure-preserving alternative.
                BlockSpec::Pool { avg: true, k: 1 }
            } else {
                BlockSpec::SplitEwConcat { ways, ew: *rng.choice(&BRANCH_EW) }
            }
        }
    }
}

fn apply_block(b: &mut GraphBuilder, t: TensorId, spec: &BlockSpec, halve: bool) -> TensorId {
    let stride = if halve { 2 } else { 1 };
    match spec {
        BlockSpec::Conv { k, groups, out_c } => {
            let t = if *groups > 1 {
                b.grouped_conv(t, *out_c, *k, stride, *groups)
            } else {
                b.conv(t, *out_c, *k, stride, Padding::Same)
            };
            b.relu(t)
        }
        BlockSpec::DwSeparable { k, out_c } => b.dw_separable(t, *out_c, *k, stride, ActKind::Relu),
        BlockSpec::Bottleneck { k, expand, se, out_c } => {
            b.inverted_residual(t, *out_c, *k, stride, *expand, *se, ActKind::Relu6)
        }
        BlockSpec::Pool { avg, k } => {
            if *avg {
                b.avg_pool(t, *k, stride)
            } else {
                b.max_pool(t, *k, stride)
            }
        }
        BlockSpec::SplitEwConcat { ways, ew } => {
            let parts = b.split(t, *ways);
            let outs: Vec<TensorId> = parts
                .into_iter()
                .map(|p| {
                    if *ew == EwKind::Copy {
                        p
                    } else {
                        b.ew_const(*ew, p)
                    }
                })
                .collect();
            let t = b.concat(outs);
            if halve {
                b.max_pool(t, 2, 2)
            } else {
                t
            }
        }
    }
}

/// Deterministically repair a block spec so it satisfies the space's
/// divisibility constraints for the given input channel count. The repair
/// rules mirror [`sample_block`]: grouped convolutions round `out_c` up to
/// a multiple of 4 and fit the group count with [`fit_groups`]; splits fit
/// the way count with [`fit_split`] and degrade to 1x1 average pooling
/// when the channels do not divide. Specs that already satisfy the
/// constraints come back unchanged, so rebuilding a sampled architecture
/// reproduces it exactly (asserted in tests).
pub fn repair_block(spec: &BlockSpec, in_c: usize) -> BlockSpec {
    match spec {
        BlockSpec::Conv { k, groups, out_c } if *groups > 1 => {
            let out_c4 = out_c.div_ceil(4) * 4;
            let g = fit_groups(*groups, in_c, out_c4);
            if g > 1 {
                BlockSpec::Conv { k: *k, groups: g, out_c: out_c4 }
            } else {
                BlockSpec::Conv { k: *k, groups: 1, out_c: *out_c }
            }
        }
        BlockSpec::SplitEwConcat { ways, ew } => {
            let w = fit_split(*ways, in_c);
            if w < 2 {
                BlockSpec::Pool { avg: true, k: 1 }
            } else {
                BlockSpec::SplitEwConcat { ways: w, ew: *ew }
            }
        }
        other => other.clone(),
    }
}

impl SynthArch {
    /// Build a synthetic architecture from an explicit spec sequence — the
    /// spec→graph path the latency-constrained search (`crate::search`)
    /// uses to realize mutated/crossed-over candidates. Each block is
    /// repaired against the actual input channel count at its position
    /// (mutations upstream can break a downstream block's divisibility),
    /// and the repaired specs are what the returned arch records, so
    /// `rebuild(rebuild(..).blocks)` is a fixpoint. `head_c` is clamped to
    /// the space's U[1200, 1800] head range.
    pub fn rebuild(index: usize, blocks: &[BlockSpec], head_c: usize) -> SynthArch {
        assert_eq!(blocks.len(), 9, "a synthetic architecture has 9 blocks");
        let head_c = head_c.clamp(1200, 1800);
        let mut b = GraphBuilder::new(&format!("synth_{index:04}"), 224, 224, 3);
        let mut t = b.input_tensor();
        let mut repaired = Vec::with_capacity(9);
        for (i, spec) in blocks.iter().enumerate() {
            let in_c = b.shape(t).c;
            let spec = repair_block(spec, in_c);
            t = apply_block(&mut b, t, &spec, i % 2 == 0);
            repaired.push(spec);
        }
        t = b.conv(t, head_c, 1, 1, Padding::Same);
        t = b.relu(t);
        let out = b.head(t, 1000);
        SynthArch { index, blocks: repaired, head_c, graph: b.finish(vec![out]) }
    }
}

/// Sample synthetic architecture number `index` from the space, seeded.
pub fn sample(seed: u64, index: usize) -> SynthArch {
    let mut rng = Rng::derive(seed, &[0x5a5a, index as u64]);
    let mut b = GraphBuilder::new(&format!("synth_{index:04}"), 224, 224, 3);
    let mut t = b.input_tensor();
    let mut blocks = Vec::with_capacity(9);
    for i in 0..9 {
        let in_c = b.shape(t).c;
        let spec = sample_block(&mut rng, i, in_c);
        // Halve after blocks 1,3,5,7,9 (1-indexed) = 0,2,4,6,8 (0-indexed).
        let halve = i % 2 == 0;
        t = apply_block(&mut b, t, &spec, halve);
        blocks.push(spec);
    }
    let head_c = sample_channels(&mut rng, 9);
    t = b.conv(t, head_c, 1, 1, Padding::Same);
    t = b.relu(t);
    let out = b.head(t, 1000);
    SynthArch { index, blocks, head_c, graph: b.finish(vec![out]) }
}

/// Sample the full synthetic dataset (1000 architectures in the paper).
pub fn sample_dataset(seed: u64, n: usize) -> Vec<SynthArch> {
    (0..n).map(|i| sample(seed, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpType;

    #[test]
    fn samples_are_deterministic() {
        let a = sample(1, 7);
        let b = sample(1, 7);
        assert_eq!(a.graph, b.graph);
        let c = sample(2, 7);
        assert!(c.graph != a.graph || c.blocks != a.blocks);
    }

    #[test]
    fn all_sampled_graphs_validate() {
        for arch in sample_dataset(42, 100) {
            arch.graph
                .validate()
                .unwrap_or_else(|e| panic!("{}: {e}", arch.graph.name));
        }
    }

    #[test]
    fn spatial_resolution_halves_five_times() {
        for arch in sample_dataset(7, 20) {
            // Find the input shape of the head 1x1 conv (7x7 for 224 input).
            let head_conv = &arch.graph.nodes[arch.graph.nodes.len() - 5];
            let s = arch.graph.shape(head_conv.inputs[0]);
            assert_eq!((s.h, s.w), (7, 7), "{}", arch.graph.name);
        }
    }

    #[test]
    fn head_channels_in_range() {
        for arch in sample_dataset(3, 50) {
            assert!((1200..=1800).contains(&arch.head_c));
        }
    }

    #[test]
    fn block_type_marginals_roughly_uniform() {
        let archs = sample_dataset(11, 400);
        let mut counts = [0usize; 5];
        for a in &archs {
            for blk in &a.blocks {
                let i = match blk {
                    BlockSpec::Conv { .. } => 0,
                    BlockSpec::DwSeparable { .. } => 1,
                    BlockSpec::Bottleneck { .. } => 2,
                    BlockSpec::Pool { .. } => 3,
                    BlockSpec::SplitEwConcat { .. } => 4,
                };
                counts[i] += 1;
            }
        }
        let total: usize = counts.iter().sum();
        for (i, &c) in counts.iter().enumerate() {
            let frac = c as f64 / total as f64;
            // Each type should appear with ~20% frequency (split blocks can
            // degrade to pooling on indivisible channels).
            assert!(
                (0.10..0.32).contains(&frac),
                "block type {i} frequency {frac:.3}; counts={counts:?}"
            );
        }
    }

    #[test]
    fn grouped_convs_appear_and_satisfy_divisibility() {
        let archs = sample_dataset(13, 200);
        let mut grouped = 0;
        for a in &archs {
            for n in &a.graph.nodes {
                if let crate::graph::Op::Conv2D { groups, out_c, .. } = n.op {
                    if groups > 1 {
                        grouped += 1;
                        let in_c = a.graph.shape(n.inputs[0]).c;
                        assert_eq!(in_c % groups, 0);
                        assert_eq!(out_c % groups, 0);
                        assert_eq!(groups % 4, 0);
                    }
                }
            }
        }
        // Uniform channel sampling makes 4k-divisibility fairly rare — the
        // space still yields a steady supply of grouped configurations.
        assert!(grouped > 25, "expected many grouped convs, got {grouped}");
    }

    #[test]
    fn rebuild_reproduces_sampled_architectures() {
        // The spec→graph path must be a faithful inverse of the sampler:
        // rebuilding a sampled arch from its recorded specs yields the
        // same specs (repair is identity on valid specs) and same graph.
        for arch in sample_dataset(29, 60) {
            let r = SynthArch::rebuild(arch.index, &arch.blocks, arch.head_c);
            assert_eq!(r.blocks, arch.blocks, "synth_{:04}", arch.index);
            assert_eq!(r.head_c, arch.head_c);
            assert_eq!(r.graph, arch.graph, "synth_{:04}", arch.index);
        }
    }

    #[test]
    fn rebuild_repairs_invalid_specs() {
        // Force constraint violations: a grouped conv whose groups cannot
        // divide the incoming 3 channels, and a split over them.
        let blocks = vec![
            BlockSpec::SplitEwConcat { ways: 4, ew: EwKind::Abs }, // in_c=3: degrade
            BlockSpec::Conv { k: 3, groups: 8, out_c: 30 },
            BlockSpec::Conv { k: 5, groups: 1, out_c: 33 },
            BlockSpec::SplitEwConcat { ways: 3, ew: EwKind::Neg }, // 33 % 3 == 0: keep
            BlockSpec::Pool { avg: false, k: 3 },
            BlockSpec::Bottleneck { k: 5, expand: 3, se: true, out_c: 100 },
            BlockSpec::DwSeparable { k: 7, out_c: 200 },
            BlockSpec::Conv { k: 3, groups: 4, out_c: 300 }, // 200%4==0, 300→300
            BlockSpec::Pool { avg: true, k: 1 },
        ];
        let arch = SynthArch::rebuild(7, &blocks, 5000);
        arch.graph.validate().unwrap();
        assert_eq!(arch.head_c, 1800, "head clamped into range");
        // Block 0 degraded to pooling (3 channels split 4 ways impossible).
        assert_eq!(arch.blocks[0], BlockSpec::Pool { avg: true, k: 1 });
        // Block 3 kept its 3-way split (33 divisible by 3).
        assert!(matches!(arch.blocks[3], BlockSpec::SplitEwConcat { ways: 3, .. }));
        // Rebuild over repaired specs is a fixpoint.
        let again = SynthArch::rebuild(7, &arch.blocks, arch.head_c);
        assert_eq!(again.blocks, arch.blocks);
        assert_eq!(again.graph, arch.graph);
    }

    #[test]
    fn dataset_covers_all_major_op_types() {
        let archs = sample_dataset(17, 100);
        let mut seen = std::collections::HashSet::new();
        for a in &archs {
            for t in a.graph.op_type_histogram().keys() {
                seen.insert(*t);
            }
        }
        for t in [
            OpType::Conv2D,
            OpType::GroupedConv2D,
            OpType::DepthwiseConv2D,
            OpType::FullyConnected,
            OpType::Pooling,
            OpType::Mean,
            OpType::ConcatSplit,
            OpType::ElementWise,
        ] {
            assert!(seen.contains(&t), "missing {t:?}");
        }
    }
}
