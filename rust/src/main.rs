//! edgelat CLI — the L3 coordinator entrypoint.
//!
//! Subcommands:
//!   reproduce   regenerate paper figures/tables (see DESIGN.md §6)
//!   generate    emit model files (zoo / synthetic NAS samples)
//!   profile     profile a model under a scenario on the simulated device
//!   evaluate    train + evaluate a predictor for a scenario
//!   predict     end-to-end latency prediction for a model file
//!   list        list scenarios / zoo models
//!
//! Arg parsing is hand-rolled: the offline crate set has no clap.

use edgelat::framework::{evaluate, DeductionMode, ScenarioPredictor};
use edgelat::graph::modelfile;
use edgelat::predict::Method;
use edgelat::profiler::{profile, profile_set};
use edgelat::report::{all_ids, reproduce, ReportConfig, ReportCtx};
use edgelat::scenario::{all_scenarios, by_id};
use edgelat::util::table::ms;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    match cmd {
        "reproduce" => cmd_reproduce(rest),
        "generate" => cmd_generate(rest),
        "profile" => cmd_profile(rest),
        "evaluate" => cmd_evaluate(rest),
        "predict" => cmd_predict(rest),
        "list" => cmd_list(rest),
        "help" | "--help" | "-h" => usage(),
        other => {
            eprintln!("unknown command '{other}'");
            usage();
            std::process::exit(2);
        }
    }
}

fn usage() {
    println!(
        "edgelat — Inference Latency Prediction at the Edge (reproduction)

USAGE:
  edgelat reproduce [--figure ID | --all] [--full|--smoke] [--seed S] [--csv DIR]
  edgelat generate  [--zoo | --synth N] [--seed S] --out DIR
  edgelat profile   --model NAME --scenario ID [--runs R] [--seed S]
  edgelat evaluate  --scenario ID --method {{lasso|rf|gbdt|mlp}} [--train N] [--test {{synth|zoo}}]
  edgelat predict   --model-file PATH --scenario ID [--method M] [--train N]
  edgelat list      {{scenarios|models|figures}}

Figures/tables: {}",
        all_ids().join(" ")
    );
}

fn flag(rest: &[String], name: &str) -> Option<String> {
    rest.iter().position(|a| a == name).and_then(|i| rest.get(i + 1).cloned())
}

fn has(rest: &[String], name: &str) -> bool {
    rest.iter().any(|a| a == name)
}

fn parse_method(s: &str) -> Method {
    match s.to_lowercase().as_str() {
        "lasso" => Method::Lasso,
        "rf" | "randomforest" => Method::RandomForest,
        "gbdt" => Method::Gbdt,
        "mlp" => Method::Mlp,
        other => {
            eprintln!("unknown method '{other}'");
            std::process::exit(2);
        }
    }
}

fn report_config(rest: &[String]) -> ReportConfig {
    let mut cfg = if has(rest, "--full") {
        ReportConfig::full()
    } else if has(rest, "--smoke") {
        ReportConfig::smoke()
    } else {
        ReportConfig::default()
    };
    if let Some(s) = flag(rest, "--seed") {
        cfg.seed = s.parse().expect("--seed u64");
    }
    let dir = edgelat::runtime::Runtime::default_dir();
    if edgelat::runtime::Runtime::artifacts_available(&dir) {
        cfg.artifacts = Some(dir);
    }
    cfg
}

fn cmd_reproduce(rest: &[String]) {
    let cfg = report_config(rest);
    let csv_dir = flag(rest, "--csv");
    let ids: Vec<String> = if has(rest, "--all") {
        all_ids().iter().map(|s| s.to_string()).collect()
    } else if let Some(f) = flag(rest, "--figure").or_else(|| flag(rest, "--table")) {
        vec![f]
    } else {
        eprintln!("need --figure ID or --all");
        std::process::exit(2);
    };
    let mut ctx = ReportCtx::new(cfg);
    for id in ids {
        let start = std::time::Instant::now();
        let tables = reproduce(&id, &mut ctx);
        for t in &tables {
            println!("{}", t.render());
            if let Some(dir) = &csv_dir {
                std::fs::create_dir_all(dir).expect("mkdir csv dir");
                let slug: String = t
                    .title
                    .chars()
                    .map(|c| if c.is_alphanumeric() { c } else { '_' })
                    .take(60)
                    .collect();
                let path = format!("{dir}/fig{id}_{slug}.csv");
                std::fs::write(&path, t.to_csv()).expect("write csv");
            }
        }
        eprintln!("[fig {id}] done in {:.1}s", start.elapsed().as_secs_f64());
    }
}

fn cmd_generate(rest: &[String]) {
    let out = flag(rest, "--out").unwrap_or_else(|| "models".into());
    std::fs::create_dir_all(&out).expect("mkdir out");
    let seed: u64 = flag(rest, "--seed").map(|s| s.parse().unwrap()).unwrap_or(2022);
    let graphs = if let Some(n) = flag(rest, "--synth") {
        edgelat::nas::sample_dataset(seed, n.parse().expect("--synth N"))
            .into_iter()
            .map(|a| a.graph)
            .collect()
    } else {
        edgelat::zoo::all_graphs()
    };
    for g in &graphs {
        let path = format!("{out}/{}.json", g.name);
        std::fs::write(&path, modelfile::to_model_file(g)).expect("write model file");
    }
    println!("wrote {} model files to {out}/", graphs.len());
}

fn cmd_profile(rest: &[String]) {
    let name = flag(rest, "--model").expect("--model NAME");
    let sc_id = flag(rest, "--scenario").expect("--scenario ID");
    let runs: usize = flag(rest, "--runs").map(|s| s.parse().unwrap()).unwrap_or(10);
    let seed: u64 = flag(rest, "--seed").map(|s| s.parse().unwrap()).unwrap_or(2022);
    let g = edgelat::zoo::by_name(&name)
        .or_else(|| {
            std::fs::read_to_string(&name).ok().and_then(|s| modelfile::from_model_file(&s).ok())
        })
        .unwrap_or_else(|| {
            eprintln!("model '{name}' not in zoo and not a readable model file");
            std::process::exit(2);
        });
    let sc = by_id(&sc_id).unwrap_or_else(|| {
        eprintln!("unknown scenario '{sc_id}' (see `edgelat list scenarios`)");
        std::process::exit(2);
    });
    let p = profile(&sc, &g, seed, runs);
    println!("model: {}  scenario: {}  runs: {runs}", p.model, sc.id);
    println!(
        "end-to-end median: {} ms  (op sum {} + overhead {})",
        ms(p.end_to_end_ms),
        ms(p.op_sum_ms()),
        ms(p.overhead_ms())
    );
    println!("\n{:<28} {:>22} {:>12}", "bucket", "kernel", "latency ms");
    for o in p.ops.iter().take(40) {
        println!("{:<28} {:>22} {:>12}", o.bucket, o.kernel.name(), ms(o.latency_ms));
    }
    if p.ops.len() > 40 {
        println!("... ({} more)", p.ops.len() - 40);
    }
}

fn cmd_evaluate(rest: &[String]) {
    let sc_id = flag(rest, "--scenario").expect("--scenario ID");
    let method = parse_method(&flag(rest, "--method").unwrap_or_else(|| "gbdt".into()));
    let n_train: usize = flag(rest, "--train").map(|s| s.parse().unwrap()).unwrap_or(120);
    let test = flag(rest, "--test").unwrap_or_else(|| "synth".into());
    let seed: u64 = flag(rest, "--seed").map(|s| s.parse().unwrap()).unwrap_or(2022);
    let sc = by_id(&sc_id).expect("unknown scenario");
    let train_g: Vec<_> = edgelat::nas::sample_dataset(seed, n_train + 40)
        .into_iter()
        .map(|a| a.graph)
        .collect();
    let (tr_g, te_synth) = train_g.split_at(n_train);
    let tr_p = profile_set(&sc, tr_g, seed, 5);
    let mlp_ctx = if method == Method::Mlp {
        Some(
            edgelat::predict::mlp::MlpContext::load(edgelat::runtime::Runtime::default_dir())
                .expect("MLP needs artifacts (make artifacts)"),
        )
    } else {
        None
    };
    let pred = ScenarioPredictor::train_from(
        &sc,
        &tr_p,
        method,
        DeductionMode::Full,
        seed,
        mlp_ctx.as_ref(),
    );
    let (te_g, te_p): (Vec<_>, Vec<_>) = if test == "zoo" {
        let g = edgelat::zoo::all_graphs();
        let p = profile_set(&sc, &g, seed, 5);
        (g, p)
    } else {
        let p = profile_set(&sc, te_synth, seed, 5);
        (te_synth.to_vec(), p)
    };
    let ev = evaluate(&pred, &te_g, &te_p);
    println!(
        "scenario {}  method {}  train {}  test {} ({} NAs)",
        sc.id,
        method.name(),
        n_train,
        test,
        te_g.len()
    );
    println!("end-to-end MAPE: {:.2}%", ev.end_to_end_mape * 100.0);
    println!("T_overhead estimate: {} ms", ms(pred.t_overhead_ms));
    for (b, m) in &ev.per_bucket_mape {
        println!("  {b:<24} MAPE {:.2}%", m * 100.0);
    }
}

fn cmd_predict(rest: &[String]) {
    let path = flag(rest, "--model-file").expect("--model-file PATH");
    let sc_id = flag(rest, "--scenario").expect("--scenario ID");
    let method = parse_method(&flag(rest, "--method").unwrap_or_else(|| "gbdt".into()));
    let n_train: usize = flag(rest, "--train").map(|s| s.parse().unwrap()).unwrap_or(120);
    let seed: u64 = 2022;
    let s = std::fs::read_to_string(&path).expect("reading model file");
    let g = modelfile::from_model_file(&s).expect("parsing model file");
    let sc = by_id(&sc_id).expect("unknown scenario");
    let train_g: Vec<_> =
        edgelat::nas::sample_dataset(seed, n_train).into_iter().map(|a| a.graph).collect();
    let tr_p = profile_set(&sc, &train_g, seed, 5);
    let pred = ScenarioPredictor::train_from(&sc, &tr_p, method, DeductionMode::Full, seed, None);
    let e = pred.predict(&g);
    println!("{}: predicted end-to-end latency on {} = {} ms", g.name, sc.id, ms(e));
    for (b, m) in pred.predict_units(&g).iter().take(30) {
        println!("  {b:<24} {} ms", ms(*m));
    }
}

fn cmd_list(rest: &[String]) {
    match rest.first().map(|s| s.as_str()).unwrap_or("scenarios") {
        "scenarios" => {
            for s in all_scenarios() {
                println!("{}", s.id);
            }
        }
        "models" => {
            for g in edgelat::zoo::all_graphs() {
                println!(
                    "{:<28} params={:>9}  flops={:>12}  ops={}",
                    g.name,
                    g.params(),
                    g.flops(),
                    g.nodes.len()
                );
            }
        }
        "figures" => println!("{}", all_ids().join("\n")),
        other => {
            eprintln!("unknown list target '{other}'");
            std::process::exit(2);
        }
    }
}
