//! edgelat CLI — the L3 coordinator entrypoint.
//!
//! Subcommands:
//!   reproduce   regenerate paper figures/tables (see DESIGN.md §6)
//!   generate    emit model files (zoo / synthetic NAS samples)
//!   profile     profile a model under a scenario on the simulated device
//!   train       train a predictor once and serialize it as a bundle
//!   evaluate    train (or load) + evaluate a predictor for a scenario
//!   predict     end-to-end latency prediction for a model file
//!   search      latency-constrained NAS search served by the engine
//!   serve       persistent micro-batching prediction daemon (JSON/TCP)
//!   transfer    few-shot onboard a new device from a trained bundle
//!   serve-bench open-loop load generator against a running daemon
//!   bench       time the pipeline hot paths, write BENCH_pipeline.json
//!   bundle      convert/inspect predictor bundles (JSON <-> binary)
//!   devices     list/show/validate device specs (the open SoC universe)
//!   workload    validate workload specs / emit the contended accuracy artifact
//!   list        list scenarios / zoo models
//!
//! Flag parsing lives in `edgelat::cli` (hand-rolled — the offline crate
//! set has no clap) so every parser is unit-tested; this binary only maps
//! parse errors to `exit(2)`.

use edgelat::cli;
use edgelat::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use edgelat::framework::{evaluate, DeductionMode, ScenarioPredictor};
use edgelat::graph::modelfile;
use edgelat::predict::Method;
use edgelat::profiler::{profile, profile_set};
use edgelat::report::{all_ids, reproduce, ReportConfig, ReportCtx};
use edgelat::scenario::{Registry, Scenario};
use edgelat::util::table::ms;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    match cmd {
        "reproduce" => cmd_reproduce(rest),
        "generate" => cmd_generate(rest),
        "profile" => cmd_profile(rest),
        "train" => cmd_train(rest),
        "evaluate" => cmd_evaluate(rest),
        "predict" => cmd_predict(rest),
        "search" => cmd_search(rest),
        "serve" => cmd_serve(rest),
        "transfer" => cmd_transfer(rest),
        "serve-bench" => cmd_serve_bench(rest),
        "bench" => cmd_bench(rest),
        "bundle" => cmd_bundle(rest),
        "devices" => cmd_devices(rest),
        "workload" => cmd_workload(rest),
        "list" => cmd_list(rest),
        "help" | "--help" | "-h" => usage(),
        other => {
            eprintln!("unknown command '{other}'");
            usage();
            std::process::exit(2);
        }
    }
}

fn usage() {
    println!(
        "edgelat — Inference Latency Prediction at the Edge (reproduction)

USAGE:
  edgelat reproduce [--figure ID | --all] [--full|--smoke] [--seed S] [--csv DIR]
  edgelat generate  [--zoo | --synth N] [--seed S] --out DIR
  edgelat profile   --model NAME --scenario ID [--runs R] [--seed S]
  edgelat train     --scenario ID --method {{lasso|rf|gbdt}} --out BUNDLE.json
                    [--mode {{full|nofusion|noselection}}] [--train N] [--runs R] [--seed S]
  edgelat evaluate  --scenario ID [--method {{lasso|rf|gbdt|mlp}} | --bundle BUNDLE.json]
                    [--train N] [--test {{synth|zoo}}] [--seed S] [--out BUNDLE.json]
  edgelat predict   --model-file PATH [--bundle BUNDLE.json | --scenario ID [--method M]
                    [--train N] [--seed S] [--out BUNDLE.json]]
  edgelat search    --scenario ID[,ID...] [--budget MS] [--seed S] [--method M]
                    [--population P] [--generations G] [--train N] [--runs R]
                    [--threads N] [--quick] [--out FRONT.json]
  edgelat serve     --bundles DIR [--addr IP:PORT] [--threads N] [--max-batch B]
                    [--max-wait-us U] [--queue-cap Q] [--drain-grace-ms MS] [--lut]
  edgelat transfer  --from-bundle SRC --to SCENARIO --out FILE[.bin] [--budget K]
                    [--runs R] [--seed S]   (few-shot onboard a new device)
  edgelat transfer eval [--quick] [--seed S] [--threads N] [--out CURVE.json]
  edgelat bundle    convert IN OUT | inspect FILE   (.json <-> .bin, by extension)
  edgelat serve-bench --addr IP:PORT [--quick] [--clients C] [--rps R]
                    [--duration-s S] [--seed S] [--drain] [--out REPORT.json]
  edgelat bench     [--quick] [--threads N] [--out BENCH_pipeline.json]
  edgelat devices   list | show SOC | validate --spec FILE.json [--spec ...]
  edgelat workload  validate --spec FILE.json [--spec ...]
                    | eval [--quick] [--seed S] [--out EVAL.json]
  edgelat list      {{scenarios|models|figures}}

Bring your own device: reproduce/profile/train/evaluate/predict/search/list
accept `--device-spec FILE.json` (repeatable) to register SoCs on top of
the four builtin Table 1 devices — every scenario of a registered SoC is
addressable by id, and a bundle trained for it embeds the full device
descriptor, so it loads and serves anywhere without the spec file.

Bring your own workload: the same subcommands accept `--workload-spec
FILE.json` (repeatable) to register contention/batch regimes (batch size,
per-cluster co-runner load, GPU quota share). Each registered workload
qualifies every scenario as `BASE@WORKLOAD`; a bundle trained for a
qualified scenario embeds the workload descriptor too.

The train-once/serve workflow: `train` profiles synthetic NAs once and writes
a serialized predictor bundle; `predict --bundle` / `evaluate --bundle` then
serve from it without re-profiling or retraining. `search` runs the paper's
motivating workload end to end: an evolutionary latency-constrained NAS
search scored entirely by the serving engine (per-scenario Pareto fronts of
predicted latency vs. accuracy proxy, byte-reproducible for a fixed seed).
`serve` keeps a directory of bundles resident as a long-lived daemon —
line-oriented JSON over TCP, concurrent requests micro-batched into the
engine, hot `reload`, graceful `drain`, and a `stats` endpoint; `serve-bench`
measures a running daemon open-loop (requests/s, p50/p99). `transfer`
onboards a new device few-shot: a trained source bundle plus K profiled
target samples (default 10) become a transfer bundle — per-bucket
recalibration under a monotone latency map — that serves under the target
scenario id anywhere a trained bundle does; `transfer eval` writes the
byte-reproducible accuracy-vs-budget curve artifact.

Figures/tables: {}",
        all_ids().join(" ")
    );
}

/// Map a flag-parse error to the CLI exit contract (message + exit 2).
fn or_die<T>(r: Result<T, String>) -> T {
    r.unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    })
}

/// Profile `n` synthetic NAS architectures and train a scenario predictor —
/// the shared one-time training path behind `train`, `evaluate`, `predict`,
/// `search`.
fn train_predictor(
    sc: &Scenario,
    method: Method,
    mode: DeductionMode,
    n_train: usize,
    seed: u64,
    runs: usize,
) -> ScenarioPredictor<'static> {
    let train_g: Vec<_> =
        edgelat::nas::sample_dataset(seed, n_train).into_iter().map(|a| a.graph).collect();
    let tr_p = profile_set(sc, &train_g, seed, runs);
    ScenarioPredictor::train_from(sc, &tr_p, method, mode, seed, None)
}

/// Honor `--out BUNDLE.json` after training. The flag is an explicit
/// request, so failing to produce the bundle is a hard error (exit 2),
/// consistent with `edgelat train`.
fn maybe_save_bundle(rest: &[String], pred: &ScenarioPredictor) {
    let Some(out) = or_die(cli::flag(rest, "--out")) else { return };
    let b = PredictorBundle::from_predictor(pred).unwrap_or_else(|e| {
        eprintln!("cannot save bundle {out}: {e}");
        std::process::exit(2);
    });
    b.save(&out).unwrap_or_else(|e| {
        eprintln!("writing bundle {out}: {e}");
        std::process::exit(2);
    });
    println!("wrote bundle {out} ({} bucket models)", b.models.len());
}

fn report_config(rest: &[String]) -> ReportConfig {
    let mut cfg = if cli::has(rest, "--full") {
        ReportConfig::full()
    } else if cli::has(rest, "--smoke") {
        ReportConfig::smoke()
    } else {
        ReportConfig::default()
    };
    cfg.seed = or_die(cli::u64_flag(rest, "--seed", cfg.seed));
    let dir = edgelat::runtime::Runtime::default_dir();
    if edgelat::runtime::Runtime::artifacts_available(&dir) {
        cfg.artifacts = Some(dir);
    }
    cfg
}

fn cmd_reproduce(rest: &[String]) {
    let cfg = report_config(rest);
    let csv_dir = or_die(cli::flag(rest, "--csv"));
    let ids: Vec<String> = if cli::has(rest, "--all") {
        all_ids().iter().map(|s| s.to_string()).collect()
    } else if let Some(f) =
        or_die(cli::flag(rest, "--figure")).or_else(|| or_die(cli::flag(rest, "--table")))
    {
        vec![f]
    } else {
        eprintln!("need --figure ID or --all");
        std::process::exit(2);
    };
    // Figures sweep whatever universe is registered: builtin by default,
    // plus any --device-spec registrations.
    let reg = or_die(cli::registry_flag(rest));
    let mut ctx = ReportCtx::with_registry(cfg, std::sync::Arc::new(reg));
    for id in ids {
        let start = std::time::Instant::now();
        let tables = reproduce(&id, &mut ctx);
        for t in &tables {
            println!("{}", t.render());
            if let Some(dir) = &csv_dir {
                std::fs::create_dir_all(dir).expect("mkdir csv dir");
                let slug: String = t
                    .title
                    .chars()
                    .map(|c| if c.is_alphanumeric() { c } else { '_' })
                    .take(60)
                    .collect();
                let path = format!("{dir}/fig{id}_{slug}.csv");
                std::fs::write(&path, t.to_csv()).expect("write csv");
            }
        }
        eprintln!("[fig {id}] done in {:.1}s", start.elapsed().as_secs_f64());
    }
}

fn cmd_generate(rest: &[String]) {
    let out = or_die(cli::flag(rest, "--out")).unwrap_or_else(|| "models".into());
    std::fs::create_dir_all(&out).expect("mkdir out");
    let seed = or_die(cli::seed_flag(rest));
    let graphs = if let Some(n) = or_die(cli::flag(rest, "--synth")) {
        edgelat::nas::sample_dataset(seed, n.parse().expect("--synth N"))
            .into_iter()
            .map(|a| a.graph)
            .collect()
    } else {
        edgelat::zoo::all_graphs()
    };
    for g in &graphs {
        let path = format!("{out}/{}.json", g.name);
        std::fs::write(&path, modelfile::to_model_file(g)).expect("write model file");
    }
    println!("wrote {} model files to {out}/", graphs.len());
}

fn cmd_profile(rest: &[String]) {
    let name = or_die(cli::flag(rest, "--model")).unwrap_or_else(|| {
        eprintln!("need --model NAME");
        std::process::exit(2);
    });
    let runs = or_die(cli::usize_flag(rest, "--runs", 10));
    let seed = or_die(cli::seed_flag(rest));
    let g = edgelat::zoo::by_name(&name)
        .or_else(|| {
            std::fs::read_to_string(&name).ok().and_then(|s| modelfile::from_model_file(&s).ok())
        })
        .unwrap_or_else(|| {
            eprintln!("model '{name}' not in zoo and not a readable model file");
            std::process::exit(2);
        });
    let reg = or_die(cli::registry_flag(rest));
    let sc = or_die(cli::scenario_flag(rest, &reg));
    let p = profile(&sc, &g, seed, runs);
    println!("model: {}  scenario: {}  runs: {runs}", p.model, sc.id);
    println!(
        "end-to-end median: {} ms  (op sum {} + overhead {})",
        ms(p.end_to_end_ms),
        ms(p.op_sum_ms()),
        ms(p.overhead_ms())
    );
    println!("\n{:<28} {:>22} {:>12}", "bucket", "kernel", "latency ms");
    for o in p.ops.iter().take(40) {
        println!("{:<28} {:>22} {:>12}", o.bucket, o.kernel.name(), ms(o.latency_ms));
    }
    if p.ops.len() > 40 {
        println!("... ({} more)", p.ops.len() - 40);
    }
}

fn cmd_train(rest: &[String]) {
    let reg = or_die(cli::registry_flag(rest));
    let sc = or_die(cli::scenario_flag(rest, &reg));
    let out = or_die(cli::flag(rest, "--out")).unwrap_or_else(|| {
        eprintln!("need --out BUNDLE.json");
        std::process::exit(2);
    });
    let method = or_die(cli::method_flag(rest, Method::Gbdt));
    if method == Method::Mlp {
        eprintln!("bundles hold the native methods (lasso|rf|gbdt); the MLP stays engine-external");
        std::process::exit(2);
    }
    let (n_train, seed, runs) = (
        or_die(cli::train_flag(rest)),
        or_die(cli::seed_flag(rest)),
        or_die(cli::runs_flag(rest)),
    );
    let mode = or_die(cli::mode_flag(rest));
    let t0 = std::time::Instant::now();
    let pred = train_predictor(&sc, method, mode, n_train, seed, runs);
    let bundle = PredictorBundle::from_predictor(&pred).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    bundle.save(&out).unwrap_or_else(|e| {
        eprintln!("writing bundle {out}: {e}");
        std::process::exit(2);
    });
    println!(
        "trained {} on {} ({} NAs, {} runs) in {:.1}s",
        method.name(),
        sc.id,
        n_train,
        runs,
        t0.elapsed().as_secs_f64()
    );
    println!(
        "wrote {out}: {} bucket models, T_overhead {} ms",
        bundle.models.len(),
        ms(bundle.t_overhead_ms)
    );
    for (b, d) in bundle.feature_dims() {
        println!("  {b:<24} {d} features");
    }
}

fn cmd_evaluate(rest: &[String]) {
    let reg = or_die(cli::registry_flag(rest));
    let sc = or_die(cli::scenario_flag(rest, &reg));
    let test = or_die(cli::flag(rest, "--test")).unwrap_or_else(|| "synth".into());
    let (n_train, seed, runs) = (
        or_die(cli::train_flag(rest)),
        or_die(cli::seed_flag(rest)),
        or_die(cli::runs_flag(rest)),
    );
    let bundle_path = or_die(cli::flag(rest, "--bundle"));
    let train_g: Vec<_> = edgelat::nas::sample_dataset(seed, n_train + 40)
        .into_iter()
        .map(|a| a.graph)
        .collect();
    let (tr_g, te_synth) = train_g.split_at(n_train);
    let requested_method = or_die(cli::method_flag_opt(rest));
    let method = requested_method.unwrap_or(Method::Gbdt);
    // Fail before the minutes of profiling/training, not after: an MLP
    // predictor can never satisfy a requested --out bundle.
    if method == Method::Mlp
        && bundle_path.is_none()
        && or_die(cli::flag(rest, "--out")).is_some()
    {
        eprintln!("--out: bundles hold the native methods (lasso|rf|gbdt); the MLP is not serializable");
        std::process::exit(2);
    }
    let mlp_ctx = if method == Method::Mlp && bundle_path.is_none() {
        Some(
            edgelat::predict::mlp::MlpContext::load(edgelat::runtime::Runtime::default_dir())
                .expect("MLP needs artifacts (make artifacts)"),
        )
    } else {
        None
    };
    let pred = if let Some(bp) = &bundle_path {
        // Serve from a bundle: no profiling of training NAs, no retraining.
        let b = PredictorBundle::load(bp).unwrap_or_else(|e| {
            eprintln!("loading bundle {bp}: {e}");
            std::process::exit(2);
        });
        if b.scenario_id() != sc.id {
            eprintln!(
                "bundle {bp} was trained for scenario {} (got --scenario {})",
                b.scenario_id(),
                sc.id
            );
            std::process::exit(2);
        }
        // v3 bundles embed their device, so an id match alone is not
        // enough: ground truth below is profiled on the registry's device,
        // and a same-named SoC with different cost-model parameters would
        // silently measure a device mismatch.
        if b.scenario != *sc {
            eprintln!(
                "bundle {bp} embeds a device descriptor for '{}' that disagrees with this \
                 registry's parameters; evaluate with the matching --device-spec",
                b.scenario_id()
            );
            std::process::exit(2);
        }
        // --method must not silently disagree with what the bundle holds.
        if requested_method.is_some() && method != b.method {
            eprintln!(
                "bundle {bp} holds {} models but --method {} was requested; drop --method or retrain",
                b.method.name(),
                method.name()
            );
            std::process::exit(2);
        }
        if test != "zoo" {
            // The bundle does not record its training seed/size, so the
            // synthetic test split drawn here may overlap the NAs the
            // bundle was trained on if the seeds coincide.
            eprintln!(
                "note: synthetic test NAs are drawn with --seed {seed}; if the bundle was \
                 trained from the same seed, held-out MAPE may be optimistic (use --test zoo \
                 or a different --seed for a clean split)"
            );
        }
        b.to_predictor().unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        })
    } else {
        let tr_p = profile_set(&sc, tr_g, seed, runs);
        ScenarioPredictor::train_from(
            &sc,
            &tr_p,
            method,
            DeductionMode::Full,
            seed,
            mlp_ctx.as_ref(),
        )
    };
    let (te_g, te_p): (Vec<_>, Vec<_>) = if test == "zoo" {
        let g = edgelat::zoo::all_graphs();
        let p = profile_set(&sc, &g, seed, runs);
        (g, p)
    } else {
        let p = profile_set(&sc, te_synth, seed, runs);
        (te_synth.to_vec(), p)
    };
    let ev = evaluate(&pred, &te_g, &te_p);
    println!(
        "scenario {}  method {}{}  test {} ({} NAs)",
        sc.id,
        pred.method.name(),
        match &bundle_path {
            Some(bp) => format!("  bundle {bp}"),
            None => format!("  train {n_train}"),
        },
        test,
        te_g.len()
    );
    println!("end-to-end MAPE: {:.2}%", ev.end_to_end_mape * 100.0);
    println!("T_overhead estimate: {} ms", ms(pred.t_overhead_ms));
    for (b, m) in &ev.per_bucket_mape {
        println!("  {b:<24} MAPE {:.2}%", m * 100.0);
    }
    maybe_save_bundle(rest, &pred);
}

fn cmd_predict(rest: &[String]) {
    let path = or_die(cli::flag(rest, "--model-file")).unwrap_or_else(|| {
        eprintln!("need --model-file PATH");
        std::process::exit(2);
    });
    let s = std::fs::read_to_string(&path).expect("reading model file");
    let g = modelfile::from_model_file(&s).expect("parsing model file");

    if let Some(bp) = or_die(cli::flag(rest, "--bundle")) {
        // Serving path: load the trained predictor, no re-profiling or
        // retraining on this invocation.
        let bundle = PredictorBundle::load(&bp).unwrap_or_else(|e| {
            eprintln!("loading bundle {bp}: {e}");
            std::process::exit(2);
        });
        // --out is an explicit request even here: re-save the loaded
        // bundle (a validated copy) rather than silently ignoring it.
        if let Some(out) = or_die(cli::flag(rest, "--out")) {
            bundle.save(&out).unwrap_or_else(|e| {
                eprintln!("writing bundle {out}: {e}");
                std::process::exit(2);
            });
            println!("wrote bundle {out} ({} bucket models)", bundle.models.len());
        }
        let engine = EngineBuilder::new().bundle(bundle).build().unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
        // Default to the bundle's own scenario; --scenario can override
        // (useful once multiple bundles are loaded). An explicit --method
        // is enforced by the engine rather than silently ignored.
        let sc_id = or_die(cli::flag(rest, "--scenario"))
            .unwrap_or_else(|| engine.scenario_ids()[0].to_string());
        let mut req = PredictRequest::new(&g, sc_id.clone());
        if let Some(m) = or_die(cli::method_flag_opt(rest)) {
            req = req.with_method(m);
        }
        let resp = engine.predict(&req).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
        println!(
            "{}: predicted end-to-end latency on {} = {} ms  (bundle {bp}, no retraining)",
            g.name,
            sc_id,
            ms(resp.e2e_ms)
        );
        for (b, m) in resp.per_unit.iter().take(30) {
            println!("  {b:<24} {} ms", ms(*m));
        }
        if resp.per_unit.len() > 30 {
            println!("  ... ({} more units)", resp.per_unit.len() - 30);
        }
        if resp.fallback_units > 0 {
            println!("note: {} unit(s) fell back to the global mean (bucket unseen in training)", resp.fallback_units);
        }
        return;
    }

    // Train-in-place path (one-off): same shared flags as `evaluate`.
    let reg = or_die(cli::registry_flag(rest));
    let sc = or_die(cli::scenario_flag(rest, &reg));
    let method = or_die(cli::method_flag(rest, Method::Gbdt));
    let (n_train, seed, runs) = (
        or_die(cli::train_flag(rest)),
        or_die(cli::seed_flag(rest)),
        or_die(cli::runs_flag(rest)),
    );
    let pred = train_predictor(&sc, method, DeductionMode::Full, n_train, seed, runs);
    let e = pred.predict(&g);
    println!("{}: predicted end-to-end latency on {} = {} ms", g.name, sc.id, ms(e));
    for (b, m) in pred.predict_units(&g).iter().take(30) {
        println!("  {b:<24} {} ms", ms(*m));
    }
    maybe_save_bundle(rest, &pred);
}

fn cmd_search(rest: &[String]) {
    let reg = or_die(cli::registry_flag(rest));
    let scenarios = or_die(cli::scenario_list_flag(rest, &reg));
    let method = or_die(cli::method_flag(rest, Method::Gbdt));
    if method == Method::Mlp {
        eprintln!("search serves from engine bundles (lasso|rf|gbdt); the MLP is engine-external");
        std::process::exit(2);
    }
    let quick = cli::has(rest, "--quick");
    let mut cfg = if quick {
        edgelat::search::SearchConfig::quick()
    } else {
        edgelat::search::SearchConfig::full()
    };
    cfg.seed = or_die(cli::seed_flag(rest));
    // Bad sizes are rejected, not clamped — same contract as --train/--runs.
    cfg.population = or_die(cli::usize_flag(rest, "--population", cfg.population));
    if cfg.population < 2 {
        eprintln!("--population needs at least 2 candidates");
        std::process::exit(2);
    }
    cfg.generations = or_die(cli::usize_flag(rest, "--generations", cfg.generations));
    if cfg.generations == 0 {
        eprintln!("--generations needs at least 1 generation");
        std::process::exit(2);
    }
    cfg.budget_ms = or_die(cli::positive_f64_flag(rest, "--budget"));
    let n_train = or_die(cli::usize_flag(rest, "--train", if quick { 16 } else { 40 })).max(1);
    let runs = or_die(cli::usize_flag(rest, "--runs", if quick { 2 } else { 3 })).max(1);
    let threads = or_die(cli::threads_flag(rest));
    let out_path = or_die(cli::flag(rest, "--out"));
    let mode = or_die(cli::mode_flag(rest));

    // One-time profiling + training per scenario, frozen into bundles and
    // loaded into a single multi-scenario engine.
    let t0 = std::time::Instant::now();
    let mut builder = EngineBuilder::new();
    for sc in &scenarios {
        let pred = train_predictor(sc, method, mode, n_train, cfg.seed, runs);
        let bundle = PredictorBundle::from_predictor(&pred).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
        builder = builder.bundle(bundle);
    }
    if let Some(t) = threads {
        builder = builder.threads(t);
    }
    let engine = builder.build().unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let train_s = t0.elapsed().as_secs_f64();

    let ids: Vec<String> = scenarios.iter().map(|s| s.id.clone()).collect();
    let t1 = std::time::Instant::now();
    let outcome = edgelat::search::run(&engine, &ids, &cfg).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let search_s = t1.elapsed().as_secs_f64();

    println!(
        "search: {} candidate evaluations over {} scenario(s), population {}, {} generations{}",
        outcome.candidates_evaluated,
        ids.len(),
        cfg.population,
        cfg.generations,
        match cfg.budget_ms {
            Some(b) => format!(", budget {b} ms"),
            None => ", unconstrained".into(),
        }
    );
    for s in &outcome.scenarios {
        println!(
            "\n[{}] front {} pts, {}/{} feasible evaluations",
            s.scenario_id,
            s.front.len(),
            s.feasible,
            s.evaluated
        );
        for p in s.front.iter().take(10) {
            println!(
                "  {:<12} {:>10} ms  proxy {:>7.2}  flops {:>13}",
                p.name,
                ms(p.latency_ms),
                p.proxy,
                p.flops
            );
        }
        if s.front.len() > 10 {
            println!("  ... ({} more points)", s.front.len() - 10);
        }
    }
    if !outcome.rank_correlation.is_empty() {
        println!("\ncross-device rank correlation (Spearman over the shared gen-0 population):");
        for (a, b, r) in &outcome.rank_correlation {
            println!("  {a:<32} vs {b:<32} rho {r:.3}");
        }
    }
    let hit_rate = engine.cache_stats().hit_rate();
    eprintln!(
        "trained {} bundle(s) in {train_s:.1}s; searched in {search_s:.1}s \
         ({:.0} candidates/s, plan-cache hit rate {:.0}%)",
        ids.len(),
        outcome.candidates_evaluated as f64 / search_s.max(1e-9),
        hit_rate * 100.0
    );
    if let Some(out) = out_path {
        let doc = edgelat::search::report_json(&cfg, &outcome);
        std::fs::write(&out, doc.to_string()).unwrap_or_else(|e| {
            eprintln!("writing {out}: {e}");
            std::process::exit(2);
        });
        println!("\nwrote {out}");
    }
}

fn cmd_serve(rest: &[String]) {
    use edgelat::serve::{BundleFleet, ServeConfig, Server};
    let bundles = or_die(cli::flag(rest, "--bundles")).unwrap_or_else(|| {
        eprintln!("need --bundles DIR (a directory of trained predictor bundles)");
        std::process::exit(2);
    });
    let addr = or_die(cli::addr_flag(rest, "127.0.0.1:0"));
    let threads = or_die(cli::threads_flag(rest));
    let d = ServeConfig::default();
    let cfg = ServeConfig {
        max_batch: or_die(cli::usize_flag(rest, "--max-batch", d.max_batch)).max(1),
        max_wait: std::time::Duration::from_micros(or_die(cli::u64_flag(
            rest,
            "--max-wait-us",
            d.max_wait.as_micros() as u64,
        ))),
        queue_cap: or_die(cli::usize_flag(rest, "--queue-cap", d.queue_cap)),
        drain_grace: std::time::Duration::from_millis(or_die(cli::u64_flag(
            rest,
            "--drain-grace-ms",
            d.drain_grace.as_millis() as u64,
        ))),
    };
    // `--lut`: compile the direct-lookup predictor tier into the engine
    // (and into every hot-reloaded generation). Counters show up under
    // `stats` -> "lut".
    let lut = cli::has(rest, "--lut").then(edgelat::predict::lut::LutSpec::default);
    let fleet = BundleFleet::load_opts(&bundles, threads, lut).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let srv = Server::bind(addr, cfg, fleet).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    println!("serving bundles from {bundles}: {}", srv.scenario_ids().join(", "));
    println!("listening on {}", srv.addr());
    // Scripts parse the line above from a pipe; without the flush it sits
    // in the block buffer until the daemon exits.
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    match srv.run() {
        Ok(s) => println!(
            "drained: {} ok, {} errors, {} malformed, {} batches (mean {:.2}), \
             {} reload(s), up {:.1}s",
            s.served_ok, s.served_err, s.malformed, s.batches, s.mean_batch, s.reloads, s.uptime_s
        ),
        Err(e) => {
            eprintln!("serve: {e}");
            std::process::exit(1);
        }
    }
}

fn cmd_serve_bench(rest: &[String]) {
    use edgelat::serve::loadgen;
    use edgelat::serve::LoadConfig;
    if or_die(cli::flag(rest, "--addr")).is_none() {
        eprintln!("need --addr IP:PORT (where `edgelat serve` printed 'listening on ...')");
        std::process::exit(2);
    }
    let addr = or_die(cli::addr_flag(rest, "127.0.0.1:0"));
    let quick = cli::has(rest, "--quick");
    let seed = or_die(cli::seed_flag(rest));
    let (d_clients, d_rps, d_duration) = if quick { (4, 400.0, 1.0) } else { (8, 1500.0, 4.0) };
    let cfg = LoadConfig {
        clients: or_die(cli::usize_flag(rest, "--clients", d_clients)).max(1),
        rps: or_die(cli::positive_f64_flag(rest, "--rps")).unwrap_or(d_rps),
        duration: std::time::Duration::from_secs_f64(
            or_die(cli::positive_f64_flag(rest, "--duration-s")).unwrap_or(d_duration),
        ),
    };
    // Self-configure: ask the daemon which scenarios it serves and spread
    // the workload across all of them.
    let stats = loadgen::request_stats(addr).unwrap_or_else(|e| {
        eprintln!("cannot reach daemon at {addr}: {e}");
        std::process::exit(1);
    });
    let ids: Vec<String> = stats
        .get("scenarios")
        .and_then(|s| s.as_arr())
        .map(|a| a.iter().filter_map(|j| j.as_str().map(str::to_string)).collect())
        .unwrap_or_default();
    if ids.is_empty() {
        eprintln!("daemon at {addr} reports no scenarios");
        std::process::exit(1);
    }
    let archs = edgelat::nas::sample_dataset(seed, 16);
    let lines: Vec<String> = archs
        .iter()
        .enumerate()
        .map(|(i, a)| {
            edgelat::serve::protocol::predict_line(
                &ids[i % ids.len()],
                &a.graph,
                Some(i as u64),
                None,
                false,
            )
        })
        .collect();
    let t0 = std::time::Instant::now();
    let report = loadgen::run_load(addr, &cfg, &lines).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(1);
    });
    println!(
        "serve-bench @ {addr}: {} clients, target {:.0} rps for {:.1}s over {} scenario(s)",
        cfg.clients,
        cfg.rps,
        cfg.duration.as_secs_f64(),
        ids.len()
    );
    println!(
        "  sent {}  ok {}  errors {}  -> {:.0} requests/s  p50 {:.0} us  p95 {:.0} us  p99 {:.0} us",
        report.sent,
        report.ok,
        report.errors,
        report.requests_per_s,
        report.p50_us,
        report.p95_us,
        report.p99_us
    );
    if cli::has(rest, "--drain") {
        let reply = loadgen::request_drain(addr).unwrap_or_else(|e| {
            eprintln!("drain: {e}");
            std::process::exit(1);
        });
        if reply.get("ok") != Some(&edgelat::util::Json::Bool(true)) {
            eprintln!("drain was not acknowledged: {}", reply.to_string());
            std::process::exit(1);
        }
        println!("  drain acknowledged");
    }
    if let Some(out) = or_die(cli::flag(rest, "--out")) {
        use edgelat::util::Json;
        let fin = |v: f64| Json::num(if v.is_finite() { v } else { 0.0 });
        let doc = Json::obj(vec![
            ("addr", Json::str(addr.to_string())),
            ("clients", Json::num(cfg.clients as f64)),
            ("target_rps", Json::num(cfg.rps)),
            ("duration_s", Json::num(cfg.duration.as_secs_f64())),
            ("sent", Json::num(report.sent as f64)),
            ("ok", Json::num(report.ok as f64)),
            ("errors", Json::num(report.errors as f64)),
            ("elapsed_s", Json::num(report.elapsed_s)),
            ("requests_per_s", fin(report.requests_per_s)),
            ("p50_us", fin(report.p50_us)),
            ("p95_us", fin(report.p95_us)),
            ("p99_us", fin(report.p99_us)),
        ]);
        std::fs::write(&out, doc.to_string()).unwrap_or_else(|e| {
            eprintln!("writing {out}: {e}");
            std::process::exit(2);
        });
        println!("  wrote {out}");
    }
    if report.ok == 0 {
        eprintln!("no successful replies in {:.1}s", t0.elapsed().as_secs_f64());
        std::process::exit(1);
    }
}

fn cmd_bench(rest: &[String]) {
    let mut cfg = if cli::has(rest, "--quick") {
        edgelat::bench::BenchConfig::quick()
    } else {
        edgelat::bench::BenchConfig::full()
    };
    if let Some(t) = or_die(cli::threads_flag(rest)) {
        cfg.threads = t;
    }
    let out = or_die(cli::flag(rest, "--out")).unwrap_or_else(|| "BENCH_pipeline.json".into());
    let t0 = std::time::Instant::now();
    println!("== edgelat bench ({}, {} threads) ==", cfg.label, cfg.threads);
    let doc = edgelat::bench::run(&cfg);
    std::fs::write(&out, doc.to_string()).unwrap_or_else(|e| {
        eprintln!("writing {out}: {e}");
        std::process::exit(2);
    });
    let derived = doc.req("derived").expect("bench derived section");
    println!(
        "\nbatch-predict speedup vs single-predict loop: {:.2}x",
        derived.req_f64("batch_predict_speedup").unwrap_or(f64::NAN)
    );
    println!(
        "predict-over-plan speedup vs single-predict:  {:.2}x",
        derived.req_f64("plan_predict_speedup").unwrap_or(f64::NAN)
    );
    println!(
        "scenario-sweep speedup vs sequential:         {:.2}x",
        derived.req_f64("sweep_parallel_speedup").unwrap_or(f64::NAN)
    );
    if let Ok(lowering) = derived.req("lowering") {
        println!(
            "plan lowering throughput:                     {:.0} graphs/s",
            lowering.req_f64("graphs_per_s").unwrap_or(f64::NAN)
        );
    }
    if let Ok(search) = derived.req("search") {
        println!(
            "NAS search throughput:                        {:.0} candidates/s",
            search.req_f64("candidates_per_s").unwrap_or(f64::NAN)
        );
    }
    println!("wrote {out} in {:.1}s", t0.elapsed().as_secs_f64());
}

/// `edgelat devices` — inspect and validate the open device universe.
/// `edgelat bundle convert IN OUT | inspect FILE`: lossless conversion
/// between the JSON and binary bundle formats (direction picked by the
/// output extension — `.bin` writes binary, anything else JSON) and a
/// validated header/content summary. Inputs load in either format.
fn cmd_bundle(rest: &[String]) {
    let sub = rest.first().filter(|a| !a.starts_with("--")).map(|s| s.as_str());
    let positional = |i: usize, what: &str| -> &String {
        rest.get(i).filter(|a| !a.starts_with("--")).unwrap_or_else(|| {
            eprintln!("need {what}: edgelat bundle convert IN OUT | inspect FILE");
            std::process::exit(2);
        })
    };
    match sub.unwrap_or("help") {
        "convert" => {
            let inp = positional(1, "an input bundle");
            let out = positional(2, "an output path");
            let b = PredictorBundle::load_auto(inp).unwrap_or_else(|e| {
                eprintln!("{e}");
                std::process::exit(2);
            });
            let to_bin = std::path::Path::new(out).extension().and_then(|x| x.to_str())
                == Some("bin");
            let res = if to_bin { b.save_bin(out) } else { b.save(out) };
            res.unwrap_or_else(|e| {
                eprintln!("{e}");
                std::process::exit(2);
            });
            println!(
                "wrote {} bundle {out} ({} bucket models, scenario {})",
                if to_bin { "binary" } else { "JSON" },
                b.models.len(),
                b.scenario_id()
            );
        }
        "inspect" => {
            let path = positional(1, "a bundle file");
            let bytes = std::fs::read(path).unwrap_or_else(|e| {
                eprintln!("reading {path}: {e}");
                std::process::exit(2);
            });
            let doc = if bytes.starts_with(&edgelat::engine::BIN_MAGIC) {
                edgelat::engine::binfmt::inspect_bin(&bytes).unwrap_or_else(|e| {
                    eprintln!("{path}: {e}");
                    std::process::exit(2);
                })
            } else {
                // JSON bundle: load (full validation), then summarize in
                // the same shape so scripts can consume either.
                let b = PredictorBundle::load_auto(path).unwrap_or_else(|e| {
                    eprintln!("{e}");
                    std::process::exit(2);
                });
                edgelat::util::Json::obj(vec![
                    ("format", edgelat::util::Json::str(edgelat::engine::BUNDLE_FORMAT)),
                    ("scenario", edgelat::util::Json::str(b.scenario_id().to_string())),
                    ("device", edgelat::util::Json::str(b.scenario.soc.name.clone())),
                    ("method", edgelat::util::Json::str(b.method.name())),
                    ("mode", edgelat::util::Json::str(b.mode.name())),
                    ("t_overhead_ms", edgelat::util::Json::Num(b.t_overhead_ms)),
                    ("fallback_ms", edgelat::util::Json::Num(b.fallback_ms)),
                    (
                        "buckets",
                        edgelat::util::Json::Arr(
                            b.models
                                .keys()
                                .map(|k| edgelat::util::Json::str(k.clone()))
                                .collect(),
                        ),
                    ),
                    ("n_models", edgelat::util::Json::num(b.models.len() as f64)),
                    ("total_bytes", edgelat::util::Json::num(bytes.len() as f64)),
                ])
            };
            println!("{}", doc.to_string());
        }
        other => {
            eprintln!("unknown bundle subcommand '{other}' (convert|inspect)");
            std::process::exit(2);
        }
    }
}

/// `edgelat transfer`: few-shot onboard a target device from a trained
/// source bundle — profile K target graphs, fit the per-bucket scales and
/// the monotone latency map, and write a `TransferBundle` that serves
/// under the target scenario id anywhere a trained bundle does.
fn cmd_transfer(rest: &[String]) {
    if rest.first().map(|s| s.as_str()) == Some("eval") {
        return cmd_transfer_eval(&rest[1..]);
    }
    let a = or_die(cli::transfer_args(rest));
    let reg = or_die(cli::registry_flag(rest));
    let source = PredictorBundle::load_auto(&a.from_bundle).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    let target = reg.by_id(&a.scenario_id).unwrap_or_else(|| {
        eprintln!("unknown scenario '{}' (see `edgelat list scenarios`)", a.scenario_id);
        std::process::exit(2);
    });
    let graphs: Vec<_> =
        edgelat::nas::sample_dataset(a.seed, a.budget).into_iter().map(|x| x.graph).collect();
    let profiles = profile_set(&target, &graphs, a.seed, a.runs);
    let report =
        edgelat::transfer::adapt(&source, &target, &graphs, &profiles).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2);
        });
    let to_bin =
        std::path::Path::new(&a.out).extension().and_then(|x| x.to_str()) == Some("bin");
    let b = &report.bundle;
    let res = if to_bin { b.save_bin(&a.out) } else { b.save(&a.out) };
    res.unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    println!(
        "wrote {} transfer bundle {} ({} -> {}, budget {}, {} map knots, {} scaled buckets{}{})",
        if to_bin { "binary" } else { "JSON" },
        a.out,
        b.source.scenario.id,
        b.target.id,
        b.budget,
        b.map.knots(),
        b.scales.len(),
        if report.per_bucket_scales { ", per-bucket" } else { ", uniform" },
        if report.dropped_rows > 0 {
            format!(", {} rows dropped", report.dropped_rows)
        } else {
            String::new()
        }
    );
}

/// `edgelat transfer eval`: emit the byte-reproducible accuracy-vs-budget
/// curve artifact (proxy baseline vs transferred predictor across target
/// SoCs and profiling budgets K).
fn cmd_transfer_eval(rest: &[String]) {
    let a = or_die(cli::transfer_eval_args(rest));
    let cfg = edgelat::transfer::eval::EvalConfig {
        quick: a.quick,
        seed: a.seed,
        threads: a.threads.unwrap_or(0),
    };
    let doc = edgelat::transfer::eval::run(&cfg).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    });
    match &a.out {
        Some(p) => {
            std::fs::write(p, doc.to_string()).unwrap_or_else(|e| {
                eprintln!("writing {p}: {e}");
                std::process::exit(2);
            });
            println!("wrote transfer curve {p}");
        }
        None => println!("{}", doc.to_string()),
    }
}

/// The shared workload-axis summary behind `devices list` and `list
/// scenarios`: registered workloads with their axis values, plus the
/// isolated-vs-contended scenario split.
fn print_workload_universe(reg: &Registry) {
    println!(
        "\n{} scenarios: {} isolated, {} contended ({} workload(s))",
        reg.scenario_count(),
        reg.isolated_count(),
        reg.contended_count(),
        reg.workload_count()
    );
    for wl in reg.workloads() {
        println!(
            "  @{:<16} batch {:<3} load {:.2} gpu_share {:.2}",
            wl.name,
            wl.batch,
            wl.max_load(),
            wl.gpu_share
        );
    }
}

fn cmd_devices(rest: &[String]) {
    // A leading flag is not a subcommand: `devices --device-spec f.json`
    // defaults to `list` over the extended universe.
    let sub = rest.first().filter(|a| !a.starts_with("--")).map(|s| s.as_str());
    match sub.unwrap_or("list") {
        "list" => {
            let reg = or_die(cli::registry_flag(rest));
            println!(
                "{:<16} {:<22} {:>8} {:>7} {:>10}  gpu",
                "soc", "platform", "clusters", "combos", "scenarios"
            );
            for spec in reg.specs() {
                println!(
                    "{:<16} {:<22} {:>8} {:>7} {:>10}  {}",
                    spec.soc.name,
                    spec.soc.platform,
                    spec.soc.clusters.len(),
                    spec.combos.len(),
                    spec.scenario_count(),
                    spec.soc.gpu.name
                );
            }
            print_workload_universe(&reg);
        }
        "show" => {
            let name = rest.get(1).filter(|a| !a.starts_with("--")).unwrap_or_else(|| {
                eprintln!("need a SoC name: edgelat devices show SOC [--device-spec F.json]");
                std::process::exit(2);
            });
            let reg = or_die(cli::registry_flag(rest));
            let spec = reg.spec(name).unwrap_or_else(|| {
                eprintln!("unknown SoC '{name}' (see `edgelat devices list`)");
                std::process::exit(2);
            });
            println!("{}", spec.to_json().to_string());
            // Summary on stderr — stdout stays a pure spec document.
            let per_soc = spec.scenario_count();
            eprintln!(
                "{}: {} isolated scenario(s) + {} contended ({} workload(s) registered)",
                spec.soc.name,
                per_soc,
                per_soc * reg.workload_count(),
                reg.workload_count()
            );
            for wl in reg.workloads() {
                eprintln!(
                    "  @{:<16} batch {:<3} load {:.2} gpu_share {:.2}",
                    wl.name,
                    wl.batch,
                    wl.max_load(),
                    wl.gpu_share
                );
            }
        }
        "validate" => {
            // Validate spec files standalone: parse + schema + semantic
            // checks + a registration dry-run into a fresh registry, so a
            // committed builtin spec validates too (no duplicate clash).
            let paths = or_die(cli::flag_all(rest, "--spec"));
            if paths.is_empty() {
                eprintln!("need --spec FILE.json (repeatable)");
                std::process::exit(2);
            }
            let mut failed = false;
            for path in &paths {
                let mut fresh = Registry::new();
                match fresh.load_spec_file(path) {
                    Ok(name) => {
                        println!("OK   {path}: {name} ({} scenarios)", fresh.scenario_count())
                    }
                    Err(e) => {
                        eprintln!("FAIL {e}");
                        failed = true;
                    }
                }
            }
            if failed {
                std::process::exit(2);
            }
        }
        other => {
            eprintln!("unknown devices subcommand '{other}' (list|show|validate)");
            std::process::exit(2);
        }
    }
}

/// `edgelat workload` — validate workload-spec files standalone and emit
/// the contended-universe accuracy artifact (`workload eval`).
fn cmd_workload(rest: &[String]) {
    let sub = rest.first().filter(|a| !a.starts_with("--")).map(|s| s.as_str());
    match sub.unwrap_or("help") {
        "validate" => {
            // Parse + schema + semantic checks + a registration dry-run
            // against the builtin universe, mirroring `devices validate`.
            let paths = or_die(cli::flag_all(rest, "--spec"));
            if paths.is_empty() {
                eprintln!("need --spec FILE.json (repeatable)");
                std::process::exit(2);
            }
            let mut failed = false;
            for path in &paths {
                let mut fresh = Registry::with_builtin();
                match fresh.load_workload_file(path) {
                    Ok(name) => println!(
                        "OK   {path}: {name} (+{} contended scenarios)",
                        fresh.contended_count()
                    ),
                    Err(e) => {
                        eprintln!("FAIL {e}");
                        failed = true;
                    }
                }
            }
            if failed {
                std::process::exit(2);
            }
        }
        "eval" => {
            let seed = or_die(cli::seed_flag(rest));
            let cfg = if cli::has(rest, "--quick") {
                edgelat::workload::eval::EvalConfig::quick(seed)
            } else {
                edgelat::workload::eval::EvalConfig::full(seed)
            };
            let t0 = std::time::Instant::now();
            let report = edgelat::workload::eval::run(&cfg);
            let doc = report.to_json();
            match or_die(cli::flag(rest, "--out")) {
                Some(p) => {
                    std::fs::write(&p, doc.to_string()).unwrap_or_else(|e| {
                        eprintln!("writing {p}: {e}");
                        std::process::exit(2);
                    });
                    println!("wrote workload eval artifact {p}");
                }
                None => println!("{}", doc.to_string()),
            }
            eprintln!(
                "workload eval: {} scenario rows ({} contended), max RMSPE {:.3} \
                 (bound {}), {:.1}s",
                report.rows.len(),
                report.contended_rows(),
                report.max_rmspe(),
                report.bound,
                t0.elapsed().as_secs_f64()
            );
            if !report.ok() {
                eprintln!("FAIL: contended-scenario accuracy out of bounds");
                std::process::exit(1);
            }
        }
        other => {
            eprintln!("unknown workload subcommand '{other}' (validate|eval)");
            std::process::exit(2);
        }
    }
}

fn cmd_list(rest: &[String]) {
    let sub = rest.first().filter(|a| !a.starts_with("--")).map(|s| s.as_str());
    match sub.unwrap_or("scenarios") {
        "scenarios" => {
            let reg = or_die(cli::registry_flag(rest));
            for s in reg.all() {
                println!("{}", s.id);
            }
            // Scripts pipe stdout as one id per line; the axis summary
            // goes to stderr.
            eprintln!(
                "{} scenarios: {} isolated, {} contended ({} workload(s))",
                reg.scenario_count(),
                reg.isolated_count(),
                reg.contended_count(),
                reg.workload_count()
            );
            for wl in reg.workloads() {
                eprintln!(
                    "  @{:<16} batch {:<3} load {:.2} gpu_share {:.2}",
                    wl.name,
                    wl.batch,
                    wl.max_load(),
                    wl.gpu_share
                );
            }
        }
        "models" => {
            for g in edgelat::zoo::all_graphs() {
                println!(
                    "{:<28} params={:>9}  flops={:>12}  ops={}",
                    g.name,
                    g.params(),
                    g.flops(),
                    g.nodes.len()
                );
            }
        }
        "figures" => println!("{}", all_ids().join("\n")),
        other => {
            eprintln!("unknown list target '{other}'");
            std::process::exit(2);
        }
    }
}
