//! Feature extraction (Table 3 of the paper): per-operation feature vectors
//! combining shape parameters with memory-cost features (input/output/param
//! sizes) and compute-cost features (FLOPs), plus the standardization used
//! before model fitting (Section 4.2).

use crate::graph::{Graph, Node, Op, OpType};
use crate::tflite::FusedKernel;

/// Predictor bucket name for an op or kernel: one ML model is trained per
/// bucket per scenario. GPU convolutions split into Conv2D / Winograd /
/// GroupedConv2D per the selected kernel (Section 5.4).
pub fn bucket_of(g: &Graph, k: &FusedKernel) -> String {
    let root_type = g.nodes[k.root()].op.op_type();
    k.impl_.predictor_bucket(root_type).to_string()
}

/// Bucket for a CPU op (no kernel selection on CPU).
pub fn cpu_bucket(node: &Node) -> String {
    node.op.op_type().name().to_string()
}

/// Feature vector of an op (Table 3 layout per op category).
pub fn features(g: &Graph, node: &Node) -> Vec<f64> {
    let ins = g.input_shapes(node);
    let outs = g.output_shapes(node);
    let in0 = ins[0];
    let out0 = outs[0];
    let in_size: f64 = ins.iter().map(|s| s.numel() as f64).sum();
    let out_size: f64 = outs.iter().map(|s| s.numel() as f64).sum();
    let flops = node.op.flops(&ins, &outs) as f64;
    let params = node.op.param_count(&ins, &outs) as f64;

    match &node.op {
        Op::Conv2D { kh, kw, stride, out_c, groups, .. } => {
            let mut v = vec![
                in0.h as f64,
                in0.w as f64,
                in0.c as f64,
                out0.h as f64,
                out0.w as f64,
                *out_c as f64,
                *stride as f64,
                *kh as f64,
                *kw as f64,
                in_size,
                out_size,
                params,
                flops,
            ];
            if *groups > 1 {
                v.push(*groups as f64);
            }
            v
        }
        Op::DepthwiseConv2D { kh, kw, stride, .. } => vec![
            in0.h as f64,
            in0.w as f64,
            in0.c as f64,
            out0.h as f64,
            out0.w as f64,
            out0.c as f64,
            *stride as f64,
            *kh as f64,
            *kw as f64,
            in_size,
            out_size,
            params,
            flops,
        ],
        Op::FullyConnected { out_features } => {
            vec![in0.c as f64, *out_features as f64, params, flops]
        }
        Op::Mean => vec![in0.h as f64, in0.w as f64, in0.c as f64, in_size, flops],
        Op::Concat | Op::Split { .. } => vec![
            in0.h as f64,
            in0.w as f64,
            in0.c as f64,
            out0.c as f64,
            in_size,
            out_size,
        ],
        Op::Pooling { kh, kw, stride, .. } => vec![
            in0.h as f64,
            in0.w as f64,
            in0.c as f64,
            out0.h as f64,
            out0.w as f64,
            *stride as f64,
            *kh as f64,
            *kw as f64,
            in_size,
            out_size,
            flops,
        ],
        Op::Pad { pad_h, pad_w } => vec![
            in0.h as f64,
            in0.w as f64,
            in0.c as f64,
            out0.h as f64,
            out0.w as f64,
            (*pad_h + *pad_w) as f64,
            out_size,
        ],
        Op::ElementWise { .. } => vec![in0.h as f64, in0.w as f64, in0.c as f64, in_size],
        Op::Activation { .. } => {
            vec![in0.h as f64, in0.w as f64, in0.c as f64, in_size, flops]
        }
        Op::Softmax | Op::Reshape => vec![in_size, out_size],
    }
}

/// Features of a fused GPU kernel: the root op's features plus the total
/// size of extra fused inputs (residual shortcuts read by the kernel).
pub fn kernel_features(g: &Graph, k: &FusedKernel) -> Vec<f64> {
    let root = &g.nodes[k.root()];
    let mut v = features(g, root);
    let root_in: usize = root.inputs.len();
    let extra: f64 = k.src.iter().skip(root_in).map(|&t| g.shape(t).numel() as f64).sum();
    v.push(extra);
    v.push(k.fused_ops().len() as f64);
    v
}

/// Number of features for each bucket (kernel features = op features + 2).
pub fn feature_dim(op_type: OpType, grouped: bool) -> usize {
    match op_type {
        OpType::Conv2D | OpType::DepthwiseConv2D => 13,
        OpType::GroupedConv2D => {
            if grouped {
                14
            } else {
                13
            }
        }
        OpType::FullyConnected => 4,
        OpType::Mean => 5,
        OpType::ConcatSplit => 6,
        OpType::Pooling => 11,
        OpType::Pad => 7,
        OpType::ElementWise => 4,
        OpType::Activation => 5,
        OpType::Softmax | OpType::Reshape => 2,
    }
}

/// Feature standardizer: per-feature mean/std from the training set
/// (Section 4.2), applied before every model.
#[derive(Debug, Clone)]
pub struct Standardizer {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl Standardizer {
    pub fn fit(rows: &[Vec<f64>]) -> Standardizer {
        assert!(!rows.is_empty(), "cannot fit standardizer on empty data");
        let d = rows[0].len();
        let n = rows.len() as f64;
        let mut mean = vec![0.0; d];
        for r in rows {
            for (m, x) in mean.iter_mut().zip(r) {
                *m += x;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = vec![0.0; d];
        for r in rows {
            for ((v, x), m) in var.iter_mut().zip(r).zip(&mean) {
                *v += (x - m) * (x - m);
            }
        }
        let std = var
            .into_iter()
            .map(|v| {
                let s = (v / n).sqrt();
                if s < 1e-12 {
                    1.0
                } else {
                    s
                }
            })
            .collect();
        Standardizer { mean, std }
    }

    pub fn transform(&self, x: &[f64]) -> Vec<f64> {
        x.iter()
            .zip(&self.mean)
            .zip(&self.std)
            .map(|((x, m), s)| (x - m) / s)
            .collect()
    }

    pub fn transform_all(&self, rows: &[Vec<f64>]) -> Vec<Vec<f64>> {
        rows.iter().map(|r| self.transform(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Padding};
    use crate::tflite::{compile, CompileOptions, GpuKind};

    #[test]
    fn conv_features_have_13_dims() {
        let mut b = GraphBuilder::new("t", 28, 28, 32);
        let x = b.input_tensor();
        let t = b.conv(x, 64, 3, 1, Padding::Same);
        let g = b.finish(vec![t]);
        let f = features(&g, &g.nodes[0]);
        assert_eq!(f.len(), 13);
        // flops is last and positive
        assert!(f[12] > 0.0);
        assert_eq!(f[2], 32.0); // in_c
        assert_eq!(f[5], 64.0); // out_c (filters)
    }

    #[test]
    fn grouped_conv_adds_group_feature() {
        let mut b = GraphBuilder::new("t", 28, 28, 32);
        let x = b.input_tensor();
        let t = b.grouped_conv(x, 64, 3, 1, 4);
        let g = b.finish(vec![t]);
        let f = features(&g, &g.nodes[0]);
        assert_eq!(f.len(), 14);
        assert_eq!(f[13], 4.0);
    }

    #[test]
    fn kernel_features_include_fused_extras() {
        let mut b = GraphBuilder::new("t", 8, 8, 8);
        let x = b.input_tensor();
        let y = b.conv(x, 8, 3, 1, Padding::Same);
        let t = b.add_t(y, x);
        let t = b.relu(t);
        let g = b.finish(vec![t]);
        let ks = compile(&g, GpuKind::Mali, CompileOptions::default()).kernels;
        assert_eq!(ks.len(), 1);
        let f = kernel_features(&g, &ks[0]);
        // conv features (13) + extra-input size + fused count
        assert_eq!(f.len(), 15);
        assert_eq!(f[13], 8.0 * 8.0 * 8.0); // the shortcut tensor
        assert_eq!(f[14], 2.0); // add + relu fused
    }

    #[test]
    fn standardizer_zero_mean_unit_std() {
        let rows: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64, 5.0]).collect();
        let s = Standardizer::fit(&rows);
        let t = s.transform_all(&rows);
        let mean0: f64 = t.iter().map(|r| r[0]).sum::<f64>() / 100.0;
        assert!(mean0.abs() < 1e-9);
        // constant feature: std fallback 1.0, transformed to 0
        assert!(t.iter().all(|r| r[1].abs() < 1e-9));
    }

    #[test]
    fn feature_dims_consistent_with_extractor() {
        let mut b = GraphBuilder::new("t", 28, 28, 32);
        let x = b.input_tensor();
        let t = b.dwconv(x, 3, 1);
        let t = b.mean(t);
        let t = b.fc(t, 10);
        let t = b.softmax(t);
        let g = b.finish(vec![t]);
        for n in &g.nodes {
            let f = features(&g, n);
            assert_eq!(
                f.len(),
                feature_dim(n.op.op_type(), false),
                "{:?}",
                n.op.op_type()
            );
        }
    }
}
