//! Feature extraction (Table 3 of the paper): per-operation feature vectors
//! combining shape parameters with memory-cost features (input/output/param
//! sizes) and compute-cost features (FLOPs), plus the standardization used
//! before model fitting (Section 4.2).

use crate::graph::{Graph, Node, Op, OpType};
use crate::tflite::FusedKernel;
use crate::util::Json;

/// Feature-vector width of a conv-family op row (Table 3): 9 shape
/// parameters + in/out sizes + params + FLOPs. The single source of truth
/// for the truncate-and-pad logic in `framework` and for bundle metadata.
pub const CONV_FEATURE_DIM: usize = 13;
/// Conv rows gain a group-count column when `groups > 1`.
pub const GROUPED_CONV_FEATURE_DIM: usize = CONV_FEATURE_DIM + 1;
/// Extra features appended to fused GPU kernel rows (extra-input size +
/// fused-op count, see [`kernel_features`]).
pub const FUSED_KERNEL_EXTRA_FEATURES: usize = 2;
/// Width of a fused GPU conv kernel row.
pub const CONV_KERNEL_FEATURE_DIM: usize = CONV_FEATURE_DIM + FUSED_KERNEL_EXTRA_FEATURES;
/// Columns a workload-qualified scenario appends to every row
/// (`[batch, co-runner load, gpu share]` — `workload::feature_cols`).
/// Isolated scenarios append nothing, keeping historic bundle widths.
pub const WORKLOAD_FEATURE_DIM: usize = 3;

/// Truncate or zero-pad a feature row to exactly `dim` entries.
pub fn pad_features(v: &mut Vec<f64>, dim: usize) {
    v.truncate(dim);
    while v.len() < dim {
        v.push(0.0);
    }
}

/// Conform a kernel feature row to the merged-Conv2D layout used by the
/// NoSelection ablation: drop selection-specific tail features (the group
/// count) and re-pad to the fused conv kernel width so rows from the
/// Conv2D / Winograd / GroupedConv2D buckets align.
pub fn conform_conv_kernel_row(v: &mut Vec<f64>) {
    v.truncate(CONV_FEATURE_DIM);
    pad_features(v, CONV_KERNEL_FEATURE_DIM);
}

/// Predictor bucket name for an op or kernel: one ML model is trained per
/// bucket per scenario. GPU convolutions split into Conv2D / Winograd /
/// GroupedConv2D per the selected kernel (Section 5.4). The bucket universe
/// is static — `plan::BucketInterner` assigns every name a dense id.
pub fn bucket_name_of(g: &Graph, k: &FusedKernel) -> &'static str {
    let root_type = g.nodes[k.root()].op.op_type();
    k.impl_.predictor_bucket(root_type)
}

/// Owned-`String` variant of [`bucket_name_of`] for string-keyed callers.
pub fn bucket_of(g: &Graph, k: &FusedKernel) -> String {
    bucket_name_of(g, k).to_string()
}

/// Bucket for a CPU op (no kernel selection on CPU).
pub fn cpu_bucket_name(node: &Node) -> &'static str {
    node.op.op_type().name()
}

/// Owned-`String` variant of [`cpu_bucket_name`].
pub fn cpu_bucket(node: &Node) -> String {
    cpu_bucket_name(node).to_string()
}

/// Feature vector of an op (Table 3 layout per op category).
pub fn features(g: &Graph, node: &Node) -> Vec<f64> {
    let ins = g.input_shapes(node);
    let outs = g.output_shapes(node);
    let in0 = ins[0];
    let out0 = outs[0];
    let in_size: f64 = ins.iter().map(|s| s.numel() as f64).sum();
    let out_size: f64 = outs.iter().map(|s| s.numel() as f64).sum();
    let flops = node.op.flops(&ins, &outs) as f64;
    let params = node.op.param_count(&ins, &outs) as f64;

    match &node.op {
        Op::Conv2D { kh, kw, stride, out_c, groups, .. } => {
            let mut v = vec![
                in0.h as f64,
                in0.w as f64,
                in0.c as f64,
                out0.h as f64,
                out0.w as f64,
                *out_c as f64,
                *stride as f64,
                *kh as f64,
                *kw as f64,
                in_size,
                out_size,
                params,
                flops,
            ];
            if *groups > 1 {
                v.push(*groups as f64);
            }
            v
        }
        Op::DepthwiseConv2D { kh, kw, stride, .. } => vec![
            in0.h as f64,
            in0.w as f64,
            in0.c as f64,
            out0.h as f64,
            out0.w as f64,
            out0.c as f64,
            *stride as f64,
            *kh as f64,
            *kw as f64,
            in_size,
            out_size,
            params,
            flops,
        ],
        Op::FullyConnected { out_features } => {
            vec![in0.c as f64, *out_features as f64, params, flops]
        }
        Op::Mean => vec![in0.h as f64, in0.w as f64, in0.c as f64, in_size, flops],
        Op::Concat | Op::Split { .. } => vec![
            in0.h as f64,
            in0.w as f64,
            in0.c as f64,
            out0.c as f64,
            in_size,
            out_size,
        ],
        Op::Pooling { kh, kw, stride, .. } => vec![
            in0.h as f64,
            in0.w as f64,
            in0.c as f64,
            out0.h as f64,
            out0.w as f64,
            *stride as f64,
            *kh as f64,
            *kw as f64,
            in_size,
            out_size,
            flops,
        ],
        Op::Pad { pad_h, pad_w } => vec![
            in0.h as f64,
            in0.w as f64,
            in0.c as f64,
            out0.h as f64,
            out0.w as f64,
            (*pad_h + *pad_w) as f64,
            out_size,
        ],
        Op::ElementWise { .. } => vec![in0.h as f64, in0.w as f64, in0.c as f64, in_size],
        Op::Activation { .. } => {
            vec![in0.h as f64, in0.w as f64, in0.c as f64, in_size, flops]
        }
        Op::Softmax | Op::Reshape => vec![in_size, out_size],
    }
}

/// Features of a fused GPU kernel: the root op's features plus the total
/// size of extra fused inputs (residual shortcuts read by the kernel).
pub fn kernel_features(g: &Graph, k: &FusedKernel) -> Vec<f64> {
    let root = &g.nodes[k.root()];
    let mut v = features(g, root);
    let root_in: usize = root.inputs.len();
    let extra: f64 = k.src.iter().skip(root_in).map(|&t| g.shape(t).numel() as f64).sum();
    v.push(extra);
    v.push(k.fused_ops().len() as f64);
    v
}

/// Number of features for each bucket (kernel features = op features +
/// [`FUSED_KERNEL_EXTRA_FEATURES`]).
pub fn feature_dim(op_type: OpType, grouped: bool) -> usize {
    match op_type {
        OpType::Conv2D | OpType::DepthwiseConv2D => CONV_FEATURE_DIM,
        OpType::GroupedConv2D => {
            if grouped {
                GROUPED_CONV_FEATURE_DIM
            } else {
                CONV_FEATURE_DIM
            }
        }
        OpType::FullyConnected => 4,
        OpType::Mean => 5,
        OpType::ConcatSplit => 6,
        OpType::Pooling => 11,
        OpType::Pad => 7,
        OpType::ElementWise => 4,
        OpType::Activation => 5,
        OpType::Softmax | OpType::Reshape => 2,
    }
}

/// Feature standardizer: per-feature mean/std from the training set
/// (Section 4.2), applied before every model.
#[derive(Debug, Clone)]
pub struct Standardizer {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl Standardizer {
    pub fn fit(rows: &[Vec<f64>]) -> Standardizer {
        assert!(!rows.is_empty(), "cannot fit standardizer on empty data");
        let d = rows[0].len();
        let n = rows.len() as f64;
        let mut mean = vec![0.0; d];
        for r in rows {
            for (m, x) in mean.iter_mut().zip(r) {
                *m += x;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = vec![0.0; d];
        for r in rows {
            for ((v, x), m) in var.iter_mut().zip(r).zip(&mean) {
                *v += (x - m) * (x - m);
            }
        }
        let std = var
            .into_iter()
            .map(|v| {
                let s = (v / n).sqrt();
                if s < 1e-12 {
                    1.0
                } else {
                    s
                }
            })
            .collect();
        Standardizer { mean, std }
    }

    pub fn transform(&self, x: &[f64]) -> Vec<f64> {
        let mut out = Vec::with_capacity(x.len());
        self.transform_into(x, &mut out);
        out
    }

    /// Standardize into a caller-provided buffer — the allocation-free
    /// variant the predict-over-plan hot paths reuse one scratch `Vec`
    /// across every unit of a [`LoweredGraph`](crate::plan::LoweredGraph).
    /// Identical arithmetic to [`transform`](Self::transform), so results
    /// are bit-identical.
    pub fn transform_into(&self, x: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.extend(x.iter().zip(&self.mean).zip(&self.std).map(|((x, m), s)| (x - m) / s));
    }

    pub fn transform_all(&self, rows: &[Vec<f64>]) -> Vec<Vec<f64>> {
        rows.iter().map(|r| self.transform(r)).collect()
    }

    /// Serialize for `engine::bundle` (mean/std round-trip bit-exactly).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mean", Json::from_f64s(&self.mean)),
            ("std", Json::from_f64s(&self.std)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Standardizer, String> {
        let mean = j.req_f64_arr("mean")?;
        let std = j.req_f64_arr("std")?;
        if mean.is_empty() || mean.len() != std.len() {
            return Err(format!(
                "standardizer: mean/std length mismatch ({} vs {})",
                mean.len(),
                std.len()
            ));
        }
        // A corrupted bundle must fail here, not serve inf/NaN predictions:
        // transform divides by std, and fit() never produces std <= 0.
        if mean.iter().any(|m| !m.is_finite()) {
            return Err("standardizer: non-finite mean".into());
        }
        if std.iter().any(|s| !s.is_finite() || *s <= 0.0) {
            return Err("standardizer: std values must be finite and positive".into());
        }
        Ok(Standardizer { mean, std })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Padding};
    use crate::tflite::{compile, CompileOptions, GpuKind};

    #[test]
    fn conv_features_have_13_dims() {
        let mut b = GraphBuilder::new("t", 28, 28, 32);
        let x = b.input_tensor();
        let t = b.conv(x, 64, 3, 1, Padding::Same);
        let g = b.finish(vec![t]);
        let f = features(&g, &g.nodes[0]);
        assert_eq!(f.len(), CONV_FEATURE_DIM);
        // flops is last and positive
        assert!(f[CONV_FEATURE_DIM - 1] > 0.0);
        assert_eq!(f[2], 32.0); // in_c
        assert_eq!(f[5], 64.0); // out_c (filters)
    }

    #[test]
    fn grouped_conv_adds_group_feature() {
        let mut b = GraphBuilder::new("t", 28, 28, 32);
        let x = b.input_tensor();
        let t = b.grouped_conv(x, 64, 3, 1, 4);
        let g = b.finish(vec![t]);
        let f = features(&g, &g.nodes[0]);
        assert_eq!(f.len(), GROUPED_CONV_FEATURE_DIM);
        assert_eq!(f[CONV_FEATURE_DIM], 4.0);
    }

    #[test]
    fn kernel_features_include_fused_extras() {
        let mut b = GraphBuilder::new("t", 8, 8, 8);
        let x = b.input_tensor();
        let y = b.conv(x, 8, 3, 1, Padding::Same);
        let t = b.add_t(y, x);
        let t = b.relu(t);
        let g = b.finish(vec![t]);
        let ks = compile(&g, GpuKind::Mali, CompileOptions::default()).kernels;
        assert_eq!(ks.len(), 1);
        let f = kernel_features(&g, &ks[0]);
        // conv features + extra-input size + fused count
        assert_eq!(f.len(), CONV_KERNEL_FEATURE_DIM);
        assert_eq!(f[CONV_FEATURE_DIM], 8.0 * 8.0 * 8.0); // the shortcut tensor
        assert_eq!(f[CONV_FEATURE_DIM + 1], 2.0); // add + relu fused
    }

    #[test]
    fn pad_features_truncates_and_pads() {
        let mut v = vec![1.0, 2.0, 3.0];
        pad_features(&mut v, 5);
        assert_eq!(v, vec![1.0, 2.0, 3.0, 0.0, 0.0]);
        pad_features(&mut v, 2);
        assert_eq!(v, vec![1.0, 2.0]);
    }

    #[test]
    fn conform_conv_kernel_row_aligns_grouped_rows() {
        // A grouped-conv kernel row (14 op features + 2 fused extras) must
        // conform to the merged Conv2D layout: group count and fused extras
        // dropped, zero-padded back to the fused conv kernel width.
        let mut v: Vec<f64> = (1..=16).map(|i| i as f64).collect();
        conform_conv_kernel_row(&mut v);
        assert_eq!(v.len(), CONV_KERNEL_FEATURE_DIM);
        assert_eq!(v[CONV_FEATURE_DIM - 1], 13.0);
        assert_eq!(&v[CONV_FEATURE_DIM..], &[0.0, 0.0]);
    }

    #[test]
    fn standardizer_json_roundtrip_bit_identical() {
        let rows: Vec<Vec<f64>> = (0..40)
            .map(|i| vec![i as f64 * 0.37, (i * i) as f64 * 0.011, 5.0])
            .collect();
        let s = Standardizer::fit(&rows);
        let back =
            Standardizer::from_json(&Json::parse(&s.to_json().to_string()).unwrap()).unwrap();
        for (a, b) in s.mean.iter().zip(&back.mean) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in s.std.iter().zip(&back.std) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Mismatched lengths rejected.
        assert!(Standardizer::from_json(
            &Json::parse(r#"{"mean":[1,2],"std":[1]}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn standardizer_zero_mean_unit_std() {
        let rows: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64, 5.0]).collect();
        let s = Standardizer::fit(&rows);
        let t = s.transform_all(&rows);
        let mean0: f64 = t.iter().map(|r| r[0]).sum::<f64>() / 100.0;
        assert!(mean0.abs() < 1e-9);
        // constant feature: std fallback 1.0, transformed to 0
        assert!(t.iter().all(|r| r[1].abs() < 1e-9));
    }

    #[test]
    fn transform_into_bit_identical_and_reuses_buffer() {
        let rows: Vec<Vec<f64>> = (0..50)
            .map(|i| vec![i as f64 * 1.7, (i % 7) as f64, i as f64 * -0.3])
            .collect();
        let s = Standardizer::fit(&rows);
        let mut scratch = Vec::new();
        for r in &rows {
            let a = s.transform(r);
            s.transform_into(r, &mut scratch);
            assert_eq!(a.len(), scratch.len());
            for (x, y) in a.iter().zip(&scratch) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn feature_dims_consistent_with_extractor() {
        let mut b = GraphBuilder::new("t", 28, 28, 32);
        let x = b.input_tensor();
        let t = b.dwconv(x, 3, 1);
        let t = b.mean(t);
        let t = b.fc(t, 10);
        let t = b.softmax(t);
        let g = b.finish(vec![t]);
        for n in &g.nodes {
            let f = features(&g, n);
            assert_eq!(
                f.len(),
                feature_dim(n.op.op_type(), false),
                "{:?}",
                n.op.op_type()
            );
        }
    }
}
