//! Few-shot cross-device transfer: onboard a new device from a trained
//! proxy predictor plus a handful of profiled samples.
//!
//! PR 5 made a SoC a JSON file, but a *predictor* for a new device still
//! required a full profiling run. This module closes that gap with the
//! two transfer mechanisms the related work establishes:
//!
//! - **Proxy transfer** ("One Proxy Device Is Enough"): latencies of two
//!   devices are related by an approximately monotone map. Given a trained
//!   source [`PredictorBundle`] and K profiled (graph, latency) pairs from
//!   the target, fit a monotone piecewise-linear latency map — isotonic
//!   regression via pool-adjacent-violators, deterministic, no RNG — from
//!   proxy predictions to target latencies ([`MonotoneMap`]).
//! - **Few-shot adaptation** (MAPLE-Edge, ~10 samples): per-bucket
//!   residual recalibration of the source's native models using only the K
//!   target rows, routed through the existing lowered-plan featurizer
//!   (profiled op records carry the same feature rows `plan::lower`
//!   produces). Each bucket's scale is a shrunken ratio-of-sums
//!   (actual / proxy-predicted op latency), so buckets with thin evidence
//!   fall back to the global ratio and never distort rankings.
//!
//! The result is a [`TransferBundle`]: the wrapped source bundle plus the
//! target scenario descriptor, the monotone map, and the per-bucket
//! scales. It serializes through the existing v3 JSON *and* the PR 8
//! binary path (magic `EDGELATT`, embedding the source bundle's own
//! `EDGELATB` section block), and every directory-scanning loader
//! (`EngineBuilder::bundle_file`, the serve fleet, hot reload) sniffs and
//! accepts it — a transfer bundle serves anywhere a trained bundle does,
//! under the *target* scenario id.
//!
//! `transfer::eval` ([`eval_curve`](eval::run)) emits the byte-reproducible
//! accuracy-vs-budget curve artifact behind `edgelat transfer eval`.

pub mod eval;

use crate::device::{soc_from_json, soc_to_json};
use crate::engine::bundle::{
    scenario_from_descriptor, target_to_json, validate_bundle_scenario, workload_from_descriptor,
};
use crate::engine::{EngineError, PredictorBundle, BIN_MAGIC};
use crate::framework::DeductionMode;
use crate::graph::Graph;
use crate::plan::{self, LoweredGraph};
use crate::predict::BucketModel;
use crate::profiler::ModelProfile;
use crate::scenario::Scenario;
use crate::util::stats::MIN_PCT_DENOM;
use crate::util::{rmspe_guarded, spearman, Json};
use std::collections::BTreeMap;
use std::path::Path;

/// Identifies a transfer-bundle JSON document.
pub const TRANSFER_FORMAT: &str = "edgelat.transfer_bundle";
/// Schema version this build reads and writes.
pub const TRANSFER_VERSION: u64 = 1;
/// Magic prefix of the binary transfer-bundle format (the embedded source
/// bundle keeps its own `EDGELATB` encoding).
pub const TRANSFER_BIN_MAGIC: [u8; 8] = *b"EDGELATT";

/// Per-bucket scales are clamped here: a ratio outside this range means
/// the bucket's K-row evidence is garbage, not a real device difference.
const SCALE_CLAMP: (f64, f64) = (0.05, 20.0);

/// Shrinkage strength for per-bucket scales, in virtual rows of
/// global-ratio evidence: a bucket seen in few target rows stays near the
/// global ratio (which preserves the proxy ranking exactly), and only
/// well-evidenced buckets earn an individual correction.
const SCALE_VIRTUAL_ROWS: f64 = 4.0;

/// A monotone non-decreasing piecewise-linear map fit by isotonic
/// regression (pool-adjacent-violators). Deterministic: no RNG, ties
/// broken by value. Knots are strictly increasing in both coordinates
/// (PAV merges violating blocks until block means strictly increase), so
/// [`apply`](Self::apply) is strictly increasing and never introduces
/// rank ties of its own.
#[derive(Debug, Clone, PartialEq)]
pub struct MonotoneMap {
    xs: Vec<f64>,
    ys: Vec<f64>,
}

impl MonotoneMap {
    /// Fit by PAV on (x, y) pairs. Non-finite pairs are skipped; an empty
    /// usable set is an error. Equal-x pairs merge into their mean y
    /// before the isotonic pass.
    pub fn fit(pairs: &[(f64, f64)]) -> Result<MonotoneMap, String> {
        let mut pts: Vec<(f64, f64)> =
            pairs.iter().copied().filter(|(x, y)| x.is_finite() && y.is_finite()).collect();
        if pts.is_empty() {
            return Err("no finite (proxy, target) pairs to fit".into());
        }
        pts.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        // Merge duplicate x into one weighted point.
        let mut merged: Vec<(f64, f64, f64)> = Vec::with_capacity(pts.len()); // (x, y, w)
        for (x, y) in pts {
            match merged.last_mut() {
                Some(last) if last.0 == x => {
                    last.1 = (last.1 * last.2 + y) / (last.2 + 1.0);
                    last.2 += 1.0;
                }
                _ => merged.push((x, y, 1.0)),
            }
        }
        // Pool adjacent violators: blocks carry (weight, mean x, mean y);
        // a block whose mean y does not exceed its predecessor's merges
        // into it, so surviving block means strictly increase.
        let mut blocks: Vec<(f64, f64, f64)> = Vec::with_capacity(merged.len());
        for (x, y, w) in merged {
            blocks.push((w, x, y));
            while blocks.len() >= 2 {
                let n = blocks.len();
                if blocks[n - 2].2 >= blocks[n - 1].2 {
                    let (w2, x2, y2) = blocks.pop().expect("len checked");
                    let (w1, x1, y1) = blocks.pop().expect("len checked");
                    let w = w1 + w2;
                    blocks.push((w, (x1 * w1 + x2 * w2) / w, (y1 * w1 + y2 * w2) / w));
                } else {
                    break;
                }
            }
        }
        let xs: Vec<f64> = blocks.iter().map(|b| b.1).collect();
        let ys: Vec<f64> = blocks.iter().map(|b| b.2).collect();
        Ok(MonotoneMap { xs, ys })
    }

    /// Number of knots (isotonic blocks) the fit produced.
    pub fn knots(&self) -> usize {
        self.xs.len()
    }

    /// Evaluate the map: linear interpolation between knots; below the
    /// first knot, the chord through the origin (latency maps pass near
    /// zero, and the clamp keeps the extension monotone and non-negative);
    /// above the last knot, the first→last chord slope (the global trend —
    /// more robust for extrapolation than the last local segment).
    pub fn apply(&self, x: f64) -> f64 {
        let (xs, ys) = (&self.xs, &self.ys);
        let n = xs.len();
        let origin_chord =
            |x: f64| if xs[0] > 0.0 { ys[0] * (x / xs[0]).max(0.0) } else { ys[0] };
        if n == 1 || x <= xs[0] {
            return origin_chord(x.min(xs[0]));
        }
        if x >= xs[n - 1] {
            let slope = (ys[n - 1] - ys[0]) / (xs[n - 1] - xs[0]);
            return ys[n - 1] + (x - xs[n - 1]) * slope;
        }
        let hi = xs.partition_point(|&k| k <= x);
        let lo = hi - 1;
        let t = (x - xs[lo]) / (xs[hi] - xs[lo]);
        ys[lo] + t * (ys[hi] - ys[lo])
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![("x", Json::from_f64s(&self.xs)), ("y", Json::from_f64s(&self.ys))])
    }

    /// Parse and validate: both coordinate lists non-empty, equal length,
    /// finite, and strictly increasing — the invariants
    /// [`apply`](Self::apply) relies on.
    pub fn from_json(j: &Json) -> Result<MonotoneMap, String> {
        let xs = j.req_f64_arr("x")?;
        let ys = j.req_f64_arr("y")?;
        if xs.is_empty() || xs.len() != ys.len() {
            return Err(format!("map has {} x knots but {} y knots", xs.len(), ys.len()));
        }
        for w in [&xs, &ys] {
            if w.iter().any(|v| !v.is_finite()) {
                return Err("non-finite map knot".into());
            }
            if w.windows(2).any(|p| p[0] >= p[1]) {
                return Err("map knots are not strictly increasing".into());
            }
        }
        Ok(MonotoneMap { xs, ys })
    }
}

/// A serialized transferred predictor: the source [`PredictorBundle`]
/// wrapped with the target scenario, the monotone latency map, and the
/// per-bucket few-shot scales. Serves under `target.id`.
#[derive(Clone)]
pub struct TransferBundle {
    /// The proxy-device predictor whose models do the per-row work.
    pub source: PredictorBundle,
    /// The target scenario (full embedded descriptor, like a v3 bundle).
    pub target: Scenario,
    /// Proxy-prediction → target-latency monotone map.
    pub map: MonotoneMap,
    /// Per-bucket recalibration factors over the source's models (every
    /// source-model bucket has an entry; model-less buckets are served by
    /// the adapted fallback and are never scaled).
    pub scales: BTreeMap<String, f64>,
    /// Framework overhead re-estimated from the K target profiles.
    pub t_overhead_ms: f64,
    /// Fallback unit latency: the source fallback scaled by the global
    /// target/source latency ratio (keeps the uniform candidate
    /// rank-identical to the proxy — see [`adapt`]).
    pub fallback_ms: f64,
    /// Number of target profiles the adaptation consumed.
    pub budget: usize,
}

/// Outcome of [`adapt`]: the bundle plus fit diagnostics.
pub struct AdaptReport {
    pub bundle: TransferBundle,
    /// Profiled rows (and map pairs) skipped for zero/near-zero or
    /// non-finite latency — surfaced instead of silently poisoning the
    /// fit (see `util::stats::MIN_PCT_DENOM`).
    pub dropped_rows: usize,
    /// Whether the per-bucket scales beat the uniform global ratio on the
    /// K training rows (otherwise every bucket holds the global ratio,
    /// which preserves the proxy ranking exactly).
    pub per_bucket_scales: bool,
}

/// Dense by-`BucketId` view of a bundle's models, parallel to the intern
/// table — the same resolution the engine performs at build time.
fn dense_models(source: &PredictorBundle) -> Result<Vec<Option<&BucketModel>>, EngineError> {
    let it = plan::interner();
    let mut v: Vec<Option<&BucketModel>> = (0..it.len()).map(|_| None).collect();
    for (b, m) in &source.models {
        let id = crate::engine::resolve_bundle_bucket(&source.scenario.id, b)?;
        v[id.index()] = Some(m);
    }
    Ok(v)
}

/// Sum a lowered plan's per-unit predictions: model rows (optionally
/// scaled per bucket), model-less buckets charged `fallback`.
fn plan_sum(
    models: &[Option<&BucketModel>],
    pl: &LoweredGraph,
    fallback: f64,
    scales: Option<&[f64]>,
) -> f64 {
    let mut scratch: Vec<f64> = Vec::new();
    let mut sum = 0.0;
    for i in 0..pl.len() {
        let bi = pl.bucket(i).index();
        let ms = match models[bi] {
            Some(m) => m.predict_raw_with(pl.row(i), &mut scratch),
            None => fallback,
        };
        sum += ms * scales.map_or(1.0, |s| s[bi]);
    }
    sum
}

/// The proxy-only baseline: the source predictor applied unchanged to
/// graphs lowered under the *target* scenario — no scales, no map, source
/// overhead and fallback. What transfer must beat.
pub struct ProxyPredictor<'a> {
    models: Vec<Option<&'a BucketModel>>,
    source: &'a PredictorBundle,
}

impl<'a> ProxyPredictor<'a> {
    pub fn new(source: &'a PredictorBundle) -> Result<ProxyPredictor<'a>, EngineError> {
        Ok(ProxyPredictor { models: dense_models(source)?, source })
    }

    /// Predict a target-scenario end-to-end latency with the raw proxy.
    pub fn predict(&self, target: &Scenario, g: &Graph) -> f64 {
        self.predict_plan(&plan::lower(target, self.source.mode, g))
    }

    pub fn predict_plan(&self, pl: &LoweredGraph) -> f64 {
        self.source.t_overhead_ms + plan_sum(&self.models, pl, self.source.fallback_ms, None)
    }
}

/// A [`TransferBundle`] compiled for prediction: dense model and scale
/// tables, ready to evaluate lowered plans. The in-process counterpart of
/// loading the bundle into a `LatencyEngine`.
pub struct TransferPredictor<'a> {
    models: Vec<Option<&'a BucketModel>>,
    scales: Vec<f64>,
    bundle: &'a TransferBundle,
}

impl TransferBundle {
    /// The scenario id this bundle serves (the *target*).
    pub fn scenario_id(&self) -> &str {
        &self.target.id
    }

    /// Dense by-`BucketId` scale table: stored per-bucket scales for
    /// source-model buckets, 1.0 everywhere else (fallback rows are
    /// already in target units).
    pub(crate) fn dense_scales(&self) -> Result<Vec<f64>, EngineError> {
        let it = plan::interner();
        let mut v = vec![1.0; it.len()];
        for (b, s) in &self.scales {
            let id = crate::engine::resolve_bundle_bucket(&self.target.id, b)?;
            v[id.index()] = *s;
        }
        Ok(v)
    }

    /// Compile for in-process prediction.
    pub fn predictor(&self) -> Result<TransferPredictor<'_>, EngineError> {
        Ok(TransferPredictor {
            models: dense_models(&self.source)?,
            scales: self.dense_scales()?,
            bundle: self,
        })
    }
}

impl<'a> TransferPredictor<'a> {
    /// Predict the target end-to-end latency of a graph: lower under the
    /// target scenario, scale per bucket, add the adapted overhead, then
    /// apply the monotone map.
    pub fn predict(&self, g: &Graph) -> f64 {
        let b = self.bundle;
        self.predict_plan(&plan::lower(&b.target, b.source.mode, g))
    }

    pub fn predict_plan(&self, pl: &LoweredGraph) -> f64 {
        let b = self.bundle;
        let sum = plan_sum(&self.models, pl, b.fallback_ms, Some(&self.scales));
        b.map.apply(b.t_overhead_ms + sum)
    }
}

/// Adapt a trained source bundle to a target scenario from K profiled
/// (graph, profile) pairs — the few-shot onboarding path behind
/// `edgelat transfer`.
///
/// Deterministic (no RNG): per-bucket ratio-of-sums scales with shrinkage
/// toward the global ratio, overhead re-estimated from the K profiles,
/// and a PAV monotone map from pre-map predictions to profiled end-to-end
/// latencies. Two candidates are fit — per-bucket scales and the uniform
/// global ratio — and per-bucket wins only when it improves training
/// RMSPE without hurting training Spearman. The uniform candidate's
/// pre-map score is an affine positive transform of the proxy score (see
/// the fallback note inline), so its ranking equals the proxy's exactly:
/// transfer never ranks worse than the baseline it starts from.
pub fn adapt(
    source: &PredictorBundle,
    target: &Scenario,
    graphs: &[Graph],
    profiles: &[ModelProfile],
) -> Result<AdaptReport, EngineError> {
    if graphs.is_empty() || graphs.len() != profiles.len() {
        return Err(EngineError::Unsupported(format!(
            "adaptation needs parallel non-empty graph/profile sets (got {} graphs, {} profiles)",
            graphs.len(),
            profiles.len()
        )));
    }
    validate_bundle_scenario(&source.scenario)?;
    validate_bundle_scenario(target)?;
    let models = dense_models(source)?;

    // Per-bucket evidence from the profiled op rows: the profiler routes
    // every op through the lowered-plan featurizer, so `rec.features` is
    // exactly the row the source model would see for that unit.
    let mut num: BTreeMap<&str, f64> = BTreeMap::new();
    let mut den: BTreeMap<&str, f64> = BTreeMap::new();
    let mut dropped = 0usize;
    let mut kept_rows = 0usize;
    let mut scratch: Vec<f64> = Vec::new();
    for prof in profiles {
        for rec in &prof.ops {
            let pred = match source.models.get(&rec.bucket) {
                Some(m) if m.feature_dim() == rec.features.len() => {
                    m.predict_raw_with(&rec.features, &mut scratch)
                }
                _ => source.fallback_ms,
            };
            let lat = rec.latency_ms;
            if !lat.is_finite() || lat.abs() < MIN_PCT_DENOM || !pred.is_finite() || pred <= 0.0 {
                dropped += 1;
                continue;
            }
            kept_rows += 1;
            if source.models.contains_key(&rec.bucket) {
                *num.entry(rec.bucket.as_str()).or_default() += lat;
                *den.entry(rec.bucket.as_str()).or_default() += pred;
            }
        }
    }
    let total_num: f64 = num.values().sum();
    let total_den: f64 = den.values().sum();
    let rows = kept_rows.max(1) as f64;
    let clamp = |s: f64| s.clamp(SCALE_CLAMP.0, SCALE_CLAMP.1);
    let g_ratio = if total_den > 0.0 && (total_num / total_den).is_finite() {
        clamp(total_num / total_den)
    } else {
        1.0
    };
    let den_bar = (total_den / rows).max(MIN_PCT_DENOM);
    let mut per_bucket: BTreeMap<String, f64> = BTreeMap::new();
    let mut uniform: BTreeMap<String, f64> = BTreeMap::new();
    for b in source.models.keys() {
        let scale = match (num.get(b.as_str()), den.get(b.as_str())) {
            (Some(&n), Some(&d)) if d > 0.0 => clamp(
                (n + SCALE_VIRTUAL_ROWS * g_ratio * den_bar) / (d + SCALE_VIRTUAL_ROWS * den_bar),
            ),
            _ => g_ratio,
        };
        per_bucket.insert(b.clone(), scale);
        uniform.insert(b.clone(), g_ratio);
    }

    // Overhead and fallback re-estimated on the target, mirroring
    // `ScenarioPredictor::train_from`.
    let gaps: Vec<f64> = profiles.iter().map(|p| p.overhead_ms()).filter(|v| v.is_finite()).collect();
    let t_overhead_ms = if gaps.is_empty() {
        source.t_overhead_ms.max(0.0)
    } else {
        (gaps.iter().sum::<f64>() / gaps.len() as f64).max(0.0)
    };
    // The fallback is the source fallback scaled by the global ratio —
    // NOT a mean of the kept target rows. This keeps the uniform
    // candidate's pre-map score an affine positive transform of the proxy
    // score (every per-unit term times `g_ratio`, plus a constant
    // overhead), so the uniform variant's ranking — and therefore its
    // tie-aware Spearman — equals the proxy's exactly. Few-shot
    // adaptation can then never rank worse than the proxy baseline.
    let fallback_ms = g_ratio * source.fallback_ms;

    // Fit both candidates' maps on (pre-map prediction, profiled e2e).
    // Per-bucket scales must beat uniform on training RMSPE *without*
    // hurting training Spearman to be kept; ties keep uniform.
    let plans: Vec<LoweredGraph> =
        graphs.iter().map(|g| plan::lower(target, source.mode, g)).collect();
    let actual: Vec<f64> = profiles.iter().map(|p| p.end_to_end_ms).collect();
    let candidate = |scales: &BTreeMap<String, f64>| -> Result<(MonotoneMap, f64, f64, usize), EngineError> {
        let it = plan::interner();
        let mut dense = vec![1.0; it.len()];
        for (b, s) in scales {
            let id = crate::engine::resolve_bundle_bucket(&target.id, b)?;
            dense[id.index()] = *s;
        }
        let xs: Vec<f64> = plans
            .iter()
            .map(|pl| t_overhead_ms + plan_sum(&models, pl, fallback_ms, Some(&dense)))
            .collect();
        let bad = xs.iter().zip(&actual).filter(|(x, y)| !x.is_finite() || !y.is_finite()).count();
        let pairs: Vec<(f64, f64)> = xs.iter().copied().zip(actual.iter().copied()).collect();
        let map = MonotoneMap::fit(&pairs).map_err(EngineError::Parse)?;
        let mapped: Vec<f64> = xs.iter().map(|&x| map.apply(x)).collect();
        let (train_rmspe, _) = rmspe_guarded(&mapped, &actual);
        let train_spear = spearman(&mapped, &actual);
        Ok((map, train_rmspe, train_spear, bad))
    };
    let (map_pb, rmspe_pb, spear_pb, bad_pb) = candidate(&per_bucket)?;
    let (map_un, rmspe_un, spear_un, bad_un) = candidate(&uniform)?;
    // `!(a < b)` rather than `a >= b`: a NaN Spearman (constant inputs) on
    // either side must not veto the RMSPE comparison.
    let use_per_bucket = rmspe_pb.is_finite()
        && (!rmspe_un.is_finite() || rmspe_pb < rmspe_un)
        && !(spear_pb < spear_un);
    let (map, scales, bad_pairs) = if use_per_bucket {
        (map_pb, per_bucket, bad_pb)
    } else {
        (map_un, uniform, bad_un)
    };

    Ok(AdaptReport {
        bundle: TransferBundle {
            source: source.clone(),
            target: target.clone(),
            map,
            scales,
            t_overhead_ms,
            fallback_ms,
            budget: graphs.len(),
        },
        dropped_rows: dropped + bad_pairs,
        per_bucket_scales: use_per_bucket,
    })
}

/// Either kind of bundle a fleet directory may hold — what the
/// format-sniffing [`load_any`] returns and `EngineBuilder::bundle_file`
/// dispatches on.
pub enum LoadedBundle {
    Predictor(PredictorBundle),
    Transfer(TransferBundle),
}

/// Load a bundle file of either kind and either encoding, sniffing the
/// binary magics first and the JSON `format` field second. Every error
/// names the path.
pub fn load_any(path: impl AsRef<Path>) -> Result<LoadedBundle, EngineError> {
    let path = path.as_ref();
    let bytes = std::fs::read(path)
        .map_err(|e| EngineError::Io(format!("reading {}: {e}", path.display())))?;
    let ctx = |e: String| EngineError::Parse(format!("{}: {e}", path.display()));
    if bytes.starts_with(&TRANSFER_BIN_MAGIC) {
        return TransferBundle::from_bin_bytes(&bytes)
            .map(LoadedBundle::Transfer)
            .map_err(|e| ctx(e.to_string()));
    }
    if bytes.starts_with(&BIN_MAGIC) {
        return PredictorBundle::from_bin_bytes(&bytes)
            .map(LoadedBundle::Predictor)
            .map_err(|e| ctx(e.to_string()));
    }
    let s = String::from_utf8(bytes).map_err(|_| {
        ctx("neither a binary bundle (no magic) nor UTF-8 JSON".into())
    })?;
    let j = Json::parse(&s).map_err(ctx)?;
    if j.get("format").and_then(Json::as_str) == Some(TRANSFER_FORMAT) {
        TransferBundle::from_json(&j).map(LoadedBundle::Transfer).map_err(ctx)
    } else {
        PredictorBundle::from_json(&j).map(LoadedBundle::Predictor).map_err(ctx)
    }
}

/// The wrapper fields shared by the JSON document and the binary header
/// section (everything except the embedded source bundle).
struct Wrapper {
    target: Scenario,
    map: MonotoneMap,
    scales: BTreeMap<String, f64>,
    t_overhead_ms: f64,
    fallback_ms: f64,
    budget: usize,
}

fn wrapper_from_json(j: &Json) -> Result<Wrapper, String> {
    let format = j.req_str("format")?;
    if format != TRANSFER_FORMAT {
        return Err(format!(
            "not a transfer bundle (format '{format}', expected '{TRANSFER_FORMAT}')"
        ));
    }
    let version = j.req_usize("version")? as u64;
    if version != TRANSFER_VERSION {
        return Err(format!(
            "unsupported transfer-bundle version {version} (this build reads {TRANSFER_VERSION})"
        ));
    }
    let scenario_id = j.req_str("scenario")?.to_string();
    let soc = soc_from_json(j.req("device")?).map_err(|e| format!("device: {e}"))?;
    let workload = workload_from_descriptor(j)?;
    let target = scenario_from_descriptor(soc, j.req("target")?, &scenario_id, workload)?;
    validate_bundle_scenario(&target).map_err(|e| e.to_string())?;
    let map = MonotoneMap::from_json(j.req("map")?).map_err(|e| format!("map: {e}"))?;
    let Json::Obj(smap) = j.req("scales")? else {
        return Err("'scales' is not an object".into());
    };
    let mut scales = BTreeMap::new();
    for (b, v) in smap {
        crate::engine::resolve_bundle_bucket(&scenario_id, b).map_err(|e| e.to_string())?;
        let s = v.as_f64().ok_or_else(|| format!("scale for bucket '{b}' is not a number"))?;
        if !s.is_finite() || s <= 0.0 {
            return Err(format!("scale for bucket '{b}' is not positive and finite"));
        }
        scales.insert(b.clone(), s);
    }
    let t_overhead_ms = j.req_f64("t_overhead_ms")?;
    let fallback_ms = j.req_f64("fallback_ms")?;
    if !t_overhead_ms.is_finite() || !fallback_ms.is_finite() {
        return Err("non-finite t_overhead_ms/fallback_ms".into());
    }
    let budget = j.req_usize("budget")?;
    Ok(Wrapper { target, map, scales, t_overhead_ms, fallback_ms, budget })
}

impl TransferBundle {
    fn wrapper_to_json(&self) -> Json {
        let scales: BTreeMap<String, Json> =
            self.scales.iter().map(|(b, s)| (b.clone(), Json::Num(*s))).collect();
        let mut fields = vec![
            ("format", Json::str(TRANSFER_FORMAT)),
            ("version", Json::num(TRANSFER_VERSION as f64)),
            ("budget", Json::num(self.budget as f64)),
            ("scenario", Json::str(self.target.id.clone())),
            ("device", soc_to_json(&self.target.soc)),
            ("target", target_to_json(&self.target.target)),
            ("t_overhead_ms", Json::Num(self.t_overhead_ms)),
            ("fallback_ms", Json::Num(self.fallback_ms)),
            ("map", self.map.to_json()),
            ("scales", Json::Obj(scales)),
        ];
        // The target's contention/batch regime, only when there is one —
        // isolated transfer bundles keep their pre-workload field set.
        if let Some(wl) = &self.target.workload {
            fields.push(("workload", wl.to_json()));
        }
        Json::obj(fields)
    }

    pub fn to_json(&self) -> Json {
        let Json::Obj(mut m) = self.wrapper_to_json() else { unreachable!("obj built above") };
        m.insert("source".into(), self.source.to_json());
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<TransferBundle, String> {
        let w = wrapper_from_json(j)?;
        let source =
            PredictorBundle::from_json(j.req("source")?).map_err(|e| format!("source: {e}"))?;
        Ok(TransferBundle {
            source,
            target: w.target,
            map: w.map,
            scales: w.scales,
            t_overhead_ms: w.t_overhead_ms,
            fallback_ms: w.fallback_ms,
            budget: w.budget,
        })
    }

    /// Serialize to the binary format: `EDGELATT` magic, version, the
    /// wrapper JSON (bit-exact float emit, like every edgelat JSON), then
    /// the source bundle in its own PR 8 `EDGELATB` encoding at an
    /// 8-aligned offset. Lossless both ways.
    pub fn to_bin_bytes(&self) -> Result<Vec<u8>, EngineError> {
        let wrapper = self.wrapper_to_json().to_string().into_bytes();
        let src = self.source.to_bin_bytes()?;
        let mut out = Vec::with_capacity(24 + wrapper.len() + 8 + src.len());
        out.extend_from_slice(&TRANSFER_BIN_MAGIC);
        out.extend_from_slice(&(TRANSFER_VERSION as u32).to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes()); // reserved
        out.extend_from_slice(&(wrapper.len() as u64).to_le_bytes());
        out.extend_from_slice(&wrapper);
        while out.len() % 8 != 0 {
            out.push(0);
        }
        out.extend_from_slice(&src);
        Ok(out)
    }

    /// Decode the binary format; every offset is bounds-checked and every
    /// failure is a typed error, never a panic.
    pub fn from_bin_bytes(data: &[u8]) -> Result<TransferBundle, EngineError> {
        let err = |m: &str| EngineError::Parse(format!("transfer bundle: {m}"));
        if data.len() < 24 {
            return Err(err("truncated header"));
        }
        if data[0..8] != TRANSFER_BIN_MAGIC {
            return Err(err("bad magic"));
        }
        let version = u32::from_le_bytes(data[8..12].try_into().expect("4 bytes"));
        if version as u64 != TRANSFER_VERSION {
            return Err(EngineError::Parse(format!(
                "transfer bundle: unsupported version {version} (this build reads {TRANSFER_VERSION})"
            )));
        }
        let wlen = u64::from_le_bytes(data[16..24].try_into().expect("8 bytes")) as usize;
        let wend = 24usize.checked_add(wlen).ok_or_else(|| err("wrapper length overflows"))?;
        if wend > data.len() {
            return Err(err("wrapper section out of bounds"));
        }
        let wrapper = std::str::from_utf8(&data[24..wend])
            .map_err(|_| err("wrapper section is not UTF-8"))?;
        let j = Json::parse(wrapper).map_err(|e| EngineError::Parse(format!("transfer bundle: {e}")))?;
        let w = wrapper_from_json(&j).map_err(|e| EngineError::Parse(format!("transfer bundle: {e}")))?;
        let src_off = wend.div_ceil(8) * 8;
        if src_off >= data.len() {
            return Err(err("missing embedded source bundle"));
        }
        let source = PredictorBundle::from_bin_bytes(&data[src_off..])?;
        Ok(TransferBundle {
            source,
            target: w.target,
            map: w.map,
            scales: w.scales,
            t_overhead_ms: w.t_overhead_ms,
            fallback_ms: w.fallback_ms,
            budget: w.budget,
        })
    }

    /// Write as compact JSON. I/O errors name the path.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), EngineError> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| EngineError::Io(format!("writing {}: {e}", path.display())))
    }

    /// Write in the binary format. I/O errors name the path.
    pub fn save_bin(&self, path: impl AsRef<Path>) -> Result<(), EngineError> {
        let path = path.as_ref();
        std::fs::write(path, self.to_bin_bytes()?)
            .map_err(|e| EngineError::Io(format!("writing {}: {e}", path.display())))
    }

    /// Load a transfer bundle in either encoding, sniffing the magic.
    pub fn load_auto(path: impl AsRef<Path>) -> Result<TransferBundle, EngineError> {
        let path = path.as_ref();
        match load_any(path)? {
            LoadedBundle::Transfer(t) => Ok(t),
            LoadedBundle::Predictor(_) => Err(EngineError::Parse(format!(
                "{}: a predictor bundle, not a transfer bundle",
                path.display()
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pav_recovers_a_monotone_relation() {
        // y = 2x with one violating pair: PAV pools it away.
        let pairs = [(1.0, 2.0), (2.0, 4.5), (3.0, 4.0), (4.0, 8.0), (5.0, 10.0)];
        let m = MonotoneMap::fit(&pairs).unwrap();
        // Strictly increasing knots in both coordinates.
        assert!(m.xs.windows(2).all(|w| w[0] < w[1]));
        assert!(m.ys.windows(2).all(|w| w[0] < w[1]));
        // Monotone over a sweep, interpolation inside the hull.
        let mut prev = f64::NEG_INFINITY;
        for i in 0..200 {
            let x = i as f64 * 0.05;
            let y = m.apply(x);
            assert!(y >= prev, "x={x}: {y} < {prev}");
            prev = y;
        }
        assert!((m.apply(5.0) - 10.0).abs() < 1e-9);
        assert!((m.apply(1.0) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn pav_on_sorted_data_is_exact_interpolation() {
        let pairs: Vec<(f64, f64)> = (1..=6).map(|i| (i as f64, 3.0 * i as f64)).collect();
        let m = MonotoneMap::fit(&pairs).unwrap();
        assert_eq!(m.knots(), 6);
        assert!((m.apply(2.5) - 7.5).abs() < 1e-12);
        // Extrapolation follows the global chord (slope 3).
        assert!((m.apply(10.0) - 30.0).abs() < 1e-9);
        // Below the hull: chord through the origin.
        assert!((m.apply(0.5) - 1.5).abs() < 1e-12);
        assert_eq!(m.apply(-1.0), 0.0);
    }

    #[test]
    fn pav_constant_targets_collapse_to_one_knot() {
        let pairs = [(1.0, 5.0), (2.0, 5.0), (3.0, 5.0)];
        let m = MonotoneMap::fit(&pairs).unwrap();
        assert_eq!(m.knots(), 1);
        // Degenerate map: ratio scaling through the pooled knot.
        assert!((m.apply(2.0) - 5.0).abs() < 1e-12);
        assert!(m.apply(1.0) < 5.0);
    }

    #[test]
    fn pav_skips_non_finite_pairs_and_rejects_empty() {
        let m = MonotoneMap::fit(&[(1.0, 2.0), (f64::NAN, 3.0), (2.0, f64::INFINITY), (3.0, 6.0)])
            .unwrap();
        assert_eq!(m.knots(), 2);
        assert!(MonotoneMap::fit(&[(f64::NAN, 1.0)]).is_err());
        assert!(MonotoneMap::fit(&[]).is_err());
    }

    #[test]
    fn monotone_map_json_roundtrip_bit_exact() {
        let pairs = [(0.37, 1.12), (1.91, 2.83), (2.5, 2.2), (4.0, 9.7)];
        let m = MonotoneMap::fit(&pairs).unwrap();
        let back = MonotoneMap::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(m.xs.len(), back.xs.len());
        for (a, b) in m.xs.iter().zip(&back.xs) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in m.ys.iter().zip(&back.ys) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Validation rejects broken invariants.
        let bad = Json::parse(r#"{"x":[1.0,1.0],"y":[1.0,2.0]}"#).unwrap();
        assert!(MonotoneMap::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"x":[1.0,2.0],"y":[2.0,1.0]}"#).unwrap();
        assert!(MonotoneMap::from_json(&bad).is_err());
    }

    #[test]
    fn adapt_rejects_mismatched_inputs() {
        let sc = crate::scenario::one_large_core("Snapdragon855").unwrap();
        let graphs = crate::nas::sample_dataset(3, 2);
        let gs: Vec<Graph> = graphs.into_iter().map(|a| a.graph).collect();
        let profiles = crate::profiler::profile_set(&sc, &gs, 3, 1);
        let bundle = PredictorBundle::train(
            &sc,
            &profiles,
            crate::predict::Method::Lasso,
            DeductionMode::Full,
            3,
        )
        .unwrap();
        let err = adapt(&bundle, &sc, &gs[..1], &profiles).unwrap_err();
        assert!(err.to_string().contains("parallel"), "{err}");
        let err = adapt(&bundle, &sc, &[], &[]).unwrap_err();
        assert!(err.to_string().contains("non-empty"), "{err}");
    }
}
