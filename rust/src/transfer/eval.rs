//! The accuracy-vs-budget evaluation harness behind `edgelat transfer
//! eval`: for every (source SoC, target SoC) pair, compare the proxy-only
//! baseline against the transferred predictor at increasing profiling
//! budgets K, on an eval split the adaptation never saw.
//!
//! The artifact is **byte-reproducible**: no wall-clock, no RNG outside
//! the seeded samplers, and profiling runs through
//! [`profiler::profile_set_with`], which is bit-identical across thread
//! counts — `--threads` changes only how fast the curve is computed,
//! never its bytes.

use crate::engine::{EngineError, PredictorBundle};
use crate::exec_pool::ExecPool;
use crate::framework::DeductionMode;
use crate::graph::Graph;
use crate::plan::{self, LoweredGraph};
use crate::predict::Method;
use crate::profiler::{self, ModelProfile};
use crate::scenario::{Registry, Scenario};
use crate::transfer::{adapt, ProxyPredictor};
use crate::util::{rmspe_guarded, spearman, Json};

/// Identifies a transfer-eval curve artifact.
pub const EVAL_FORMAT: &str = "edgelat.transfer_eval";
/// Schema version of the curve artifact.
pub const EVAL_VERSION: u64 = 1;
/// The budget the gate and the summary judge pairs at (MAPLE-Edge's ~10
/// samples).
pub const HEADLINE_BUDGET: usize = 10;

/// Configuration for one eval run.
pub struct EvalConfig {
    /// Small matrix for CI: one builtin source, 3 builtin + 3 sampled
    /// targets, a 40-graph pool. Full mode holds out all builtin pairs
    /// plus 10 sampled SoCs with budgets up to the whole pool.
    pub quick: bool,
    pub seed: u64,
    /// Profiling worker threads (0 = machine default). Affects speed only.
    pub threads: usize,
}

impl Default for EvalConfig {
    fn default() -> EvalConfig {
        EvalConfig { quick: false, seed: 2022, threads: 0 }
    }
}

/// FNV-1a over a label — derives disjoint per-target profiling seeds from
/// the run seed without any RNG state to thread through.
fn derive_seed(seed: u64, label: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed.wrapping_mul(0x100_0000_01b3);
    for b in label.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

struct TargetData {
    sc: Scenario,
    pool_profiles: Vec<ModelProfile>,
    eval_actual: Vec<f64>,
    eval_plans: Vec<LoweredGraph>,
}

/// Run the evaluation and return the curve artifact.
pub fn run(cfg: &EvalConfig) -> Result<Json, EngineError> {
    let (n_sampled, train_pool, n_eval, runs, budgets): (usize, usize, usize, usize, Vec<usize>) =
        if cfg.quick {
            (3, 40, 16, 2, vec![5, 10, 20, 40])
        } else {
            (10, 64, 32, 3, vec![5, 10, 20, 50, 64])
        };
    let scenario_err = |e: crate::scenario::ScenarioError| EngineError::Parse(e.to_string());

    let mut registry = Registry::with_builtin();
    for spec in crate::device::sample_specs(cfg.seed, n_sampled) {
        registry.register_soc(spec).map_err(scenario_err)?;
    }
    let builtin_names: Vec<String> =
        crate::device::builtin_specs().iter().map(|s| s.soc.name.clone()).collect();
    let sampled_names: Vec<String> = crate::device::sample_specs(cfg.seed, n_sampled)
        .into_iter()
        .map(|s| s.soc.name)
        .collect();
    let source_names: Vec<String> =
        if cfg.quick { vec![builtin_names[0].clone()] } else { builtin_names.clone() };

    let pool = if cfg.threads == 0 { ExecPool::default() } else { ExecPool::new(cfg.threads) };
    let pool_graphs: Vec<Graph> = crate::nas::sample_dataset(derive_seed(cfg.seed, "pool"), train_pool)
        .into_iter()
        .map(|a| a.graph)
        .collect();
    let eval_graphs: Vec<Graph> = crate::nas::sample_dataset(derive_seed(cfg.seed, "eval"), n_eval)
        .into_iter()
        .map(|a| a.graph)
        .collect();

    // Train one source bundle per source SoC on its own profile pool.
    let mut sources: Vec<PredictorBundle> = Vec::new();
    for name in &source_names {
        let sc = registry.one_large_core(name).map_err(scenario_err)?;
        let profiles = profiler::profile_set_with(
            &pool,
            &sc,
            &pool_graphs,
            derive_seed(cfg.seed, &format!("train:{}", sc.id)),
            runs,
        );
        sources.push(PredictorBundle::train(
            &sc,
            &profiles,
            Method::Lasso,
            DeductionMode::Full,
            cfg.seed,
        )?);
    }

    // Profile every distinct target once (train pool + held-out eval
    // split, disjoint seeds), shared across all sources.
    let target_names: Vec<String> =
        builtin_names.iter().chain(sampled_names.iter()).cloned().collect();
    let mut targets: Vec<TargetData> = Vec::new();
    for name in &target_names {
        let sc = registry.one_large_core(name).map_err(scenario_err)?;
        let pool_profiles = profiler::profile_set_with(
            &pool,
            &sc,
            &pool_graphs,
            derive_seed(cfg.seed, &format!("pool:{}", sc.id)),
            runs,
        );
        let eval_profiles = profiler::profile_set_with(
            &pool,
            &sc,
            &eval_graphs,
            derive_seed(cfg.seed, &format!("eval:{}", sc.id)),
            runs,
        );
        let eval_actual: Vec<f64> = eval_profiles.iter().map(|p| p.end_to_end_ms).collect();
        let eval_plans: Vec<LoweredGraph> =
            eval_graphs.iter().map(|g| plan::lower(&sc, DeductionMode::Full, g)).collect();
        targets.push(TargetData { sc, pool_profiles, eval_actual, eval_plans });
    }

    // Evaluate every (source, target) pair with source != target.
    let opt_num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
    let mut pairs_json: Vec<Json> = Vec::new();
    let mut degenerate_pairs = 0usize;
    let mut dropped_rows_total = 0usize;
    let mut beats_rmspe = true;
    let mut no_worse_spearman = true;
    let mut proxy_rmspes: Vec<f64> = Vec::new();
    let mut adapted_rmspes: Vec<f64> = Vec::new();
    let mut proxy_spears: Vec<f64> = Vec::new();
    let mut adapted_spears: Vec<f64> = Vec::new();
    for src in &sources {
        let proxy = ProxyPredictor::new(src)?;
        for td in &targets {
            if td.sc.soc.name == src.scenario.soc.name {
                continue;
            }
            let proxy_pred: Vec<f64> =
                td.eval_plans.iter().map(|pl| proxy.predict_plan(pl)).collect();
            let (proxy_rmspe, proxy_dropped) = rmspe_guarded(&proxy_pred, &td.eval_actual);
            let proxy_spear = spearman(&proxy_pred, &td.eval_actual);
            dropped_rows_total += proxy_dropped;

            let mut curve: Vec<Json> = Vec::new();
            for &k in &budgets {
                let k = k.min(pool_graphs.len());
                let report =
                    adapt(src, &td.sc, &pool_graphs[..k], &td.pool_profiles[..k])?;
                let tp = report.bundle.predictor()?;
                let pred: Vec<f64> =
                    td.eval_plans.iter().map(|pl| tp.predict_plan(pl)).collect();
                let (rmspe, eval_dropped) = rmspe_guarded(&pred, &td.eval_actual);
                let spear = spearman(&pred, &td.eval_actual);
                dropped_rows_total += report.dropped_rows + eval_dropped;
                if k == HEADLINE_BUDGET {
                    // NaN-aware: a degenerate Spearman on either side is
                    // counted and skipped, never averaged or compared.
                    if !proxy_spear.is_finite() || !spear.is_finite() {
                        degenerate_pairs += 1;
                    } else {
                        proxy_spears.push(proxy_spear);
                        adapted_spears.push(spear);
                        if spear < proxy_spear {
                            no_worse_spearman = false;
                        }
                    }
                    if proxy_rmspe.is_finite() && rmspe.is_finite() {
                        proxy_rmspes.push(proxy_rmspe);
                        adapted_rmspes.push(rmspe);
                        if rmspe >= proxy_rmspe {
                            beats_rmspe = false;
                        }
                    }
                }
                curve.push(Json::obj(vec![
                    ("budget", Json::num(k as f64)),
                    ("rmspe", opt_num(rmspe)),
                    ("spearman", opt_num(spear)),
                    ("dropped_rows", Json::num((report.dropped_rows + eval_dropped) as f64)),
                    ("knots", Json::num(report.bundle.map.knots() as f64)),
                    ("per_bucket_scales", Json::Bool(report.per_bucket_scales)),
                ]));
            }
            pairs_json.push(Json::obj(vec![
                ("source", Json::str(src.scenario.id.clone())),
                ("target", Json::str(td.sc.id.clone())),
                (
                    "proxy",
                    Json::obj(vec![
                        ("rmspe", opt_num(proxy_rmspe)),
                        ("spearman", opt_num(proxy_spear)),
                    ]),
                ),
                ("curve", Json::Arr(curve)),
            ]));
        }
    }

    let mean = |v: &[f64]| {
        if v.is_empty() { Json::Null } else { Json::Num(v.iter().sum::<f64>() / v.len() as f64) }
    };
    let summary = Json::obj(vec![
        ("pairs", Json::num(pairs_json.len() as f64)),
        ("headline_budget", Json::num(HEADLINE_BUDGET as f64)),
        // Pairs whose proxy or adapted Spearman was NaN (constant inputs):
        // counted and skipped, never silently averaged in.
        ("degenerate_pairs", Json::num(degenerate_pairs as f64)),
        ("dropped_rows", Json::num(dropped_rows_total as f64)),
        ("proxy_mean_rmspe", mean(&proxy_rmspes)),
        ("adapted_mean_rmspe", mean(&adapted_rmspes)),
        ("proxy_mean_spearman", mean(&proxy_spears)),
        ("adapted_mean_spearman", mean(&adapted_spears)),
        ("adapted_beats_proxy_rmspe", Json::Bool(beats_rmspe)),
        ("adapted_no_worse_spearman", Json::Bool(no_worse_spearman)),
    ]);

    Ok(Json::obj(vec![
        ("format", Json::str(EVAL_FORMAT)),
        ("version", Json::num(EVAL_VERSION as f64)),
        ("quick", Json::Bool(cfg.quick)),
        ("seed", Json::num(cfg.seed as f64)),
        ("train_pool", Json::num(train_pool as f64)),
        ("eval_graphs", Json::num(n_eval as f64)),
        ("runs", Json::num(runs as f64)),
        ("budgets", Json::Arr(budgets.iter().map(|&k| Json::num(k as f64)).collect())),
        ("method", Json::str(Method::Lasso.name())),
        ("pairs", Json::Arr(pairs_json)),
        ("summary", summary),
    ]))
}
