//! The serving-oriented engine layer: train once, serialize, load,
//! batch-predict.
//!
//! The paper's predictor is trained from a small one-time profiling run and
//! then queried cheaply for thousands of candidate architectures during NAS
//! (Section 1). `framework::ScenarioPredictor` is the training-side view of
//! that pipeline; this module is the serving side:
//!
//! - [`PredictorBundle`]: a versioned, JSON-serialized trained predictor
//!   (per-bucket Lasso/RF/GBDT models + standardizers + `T_overhead` and
//!   fallback metadata) — the deployable artifact written by
//!   `edgelat train` and read by `edgelat predict --bundle`.
//! - [`LatencyEngine`]: an owned, `Send + Sync` facade built via
//!   [`EngineBuilder`] from one or more bundles (multi-scenario). It
//!   memoizes the lowered plan (`plan::LoweredGraph`) per graph
//!   fingerprint (lowering is pure in the graph) and serves typed
//!   [`PredictRequest`]s by scanning the plan against dense
//!   `BucketId`-indexed model tables; [`predict_batch`] fans requests out
//!   across `std::thread` for throughput.
//!
//! The MLP predictor stays engine-external: it holds PJRT handles, so it is
//! neither serializable nor `Send`; it remains available through
//! `framework::ScenarioPredictor` behind the `Regressor` trait.
//!
//! [`predict_batch`]: LatencyEngine::predict_batch

pub mod binfmt;
pub mod bundle;

pub use binfmt::{BIN_MAGIC, BIN_VERSION};
pub use bundle::{PredictorBundle, BUNDLE_COMPAT_VERSION, BUNDLE_FORMAT, BUNDLE_VERSION};

use crate::exec_pool::{CacheStats, ExecPool, ShardedCache};
use crate::framework::DeductionMode;
use crate::graph::Graph;
use crate::plan::{self, LoweredGraph};
use crate::predict::lut::{LutCounts, LutPack, LutSpec};
use crate::predict::{soa, BucketModel, Method};
use crate::scenario::Scenario;
use std::fmt;
use std::sync::Arc;

/// Errors from bundle I/O and engine serving.
#[derive(Debug, Clone)]
pub enum EngineError {
    /// Filesystem failure reading/writing a bundle.
    Io(String),
    /// Malformed bundle contents (bad JSON, schema, or version).
    Parse(String),
    /// A scenario id that resolves in no loaded registry. v3 bundles embed
    /// their scenario so loading never hits this; it remains for callers
    /// resolving ids (CLI flags, v2-era tooling).
    UnknownScenario(String),
    /// No loaded bundle matches the request.
    NoPredictor { scenario_id: String, method: Option<Method> },
    /// Operation not supported (e.g. serializing an MLP predictor).
    Unsupported(String),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::Io(e) => write!(f, "bundle I/O error: {e}"),
            EngineError::Parse(e) => write!(f, "bundle parse error: {e}"),
            EngineError::UnknownScenario(id) => {
                write!(f, "unknown scenario '{id}' (see `edgelat list scenarios`)")
            }
            EngineError::NoPredictor { scenario_id, method } => match method {
                Some(m) => write!(
                    f,
                    "no loaded predictor for scenario '{scenario_id}' with method {}",
                    m.name()
                ),
                None => write!(f, "no loaded predictor for scenario '{scenario_id}'"),
            },
            EngineError::Unsupported(e) => write!(f, "unsupported: {e}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Resolve a bundle's bucket symbol against the build's intern table — the
/// one copy of the check (and message) every bundle-loading path uses:
/// [`PredictorBundle::from_json`], [`PredictorBundle::to_predictor`], and
/// [`EngineBuilder::build`].
pub(crate) fn resolve_bundle_bucket(
    scenario_id: &str,
    bucket: &str,
) -> Result<plan::BucketId, EngineError> {
    plan::interner().resolve(bucket).ok_or_else(|| {
        EngineError::Parse(format!(
            "bundle for '{scenario_id}' holds a model for bucket '{bucket}', which this \
             build's intern table does not know"
        ))
    })
}

/// One prediction request against a loaded engine.
#[derive(Debug, Clone)]
pub struct PredictRequest<'g> {
    pub graph: &'g Graph,
    pub scenario_id: String,
    /// Restrict to a bundle trained with this method; `None` picks the
    /// first loaded bundle for the scenario.
    pub method: Option<Method>,
}

impl<'g> PredictRequest<'g> {
    pub fn new(graph: &'g Graph, scenario_id: impl Into<String>) -> PredictRequest<'g> {
        PredictRequest { graph, scenario_id: scenario_id.into(), method: None }
    }

    pub fn with_method(mut self, method: Method) -> PredictRequest<'g> {
        self.method = Some(method);
        self
    }
}

/// A served prediction: end-to-end estimate plus its decomposition.
#[derive(Debug, Clone)]
pub struct PredictResponse {
    /// `T_overhead + Σ_c f*_c(x_c)` (Section 4.2).
    pub e2e_ms: f64,
    /// Per-unit (bucket, predicted ms), in execution order. Bucket names
    /// come straight from the interner table — no per-unit allocation.
    pub per_unit: Vec<(&'static str, f64)>,
    /// Framework-overhead component of `e2e_ms`.
    pub t_overhead_ms: f64,
    /// Units predicted with the global-mean fallback (bucket unseen during
    /// training).
    pub fallback_units: usize,
}

/// One loaded bundle, resolved against this build's scenario table.
/// Models sit in a dense table indexed by `plan::BucketId` — the serve
/// loop never hashes a bucket string.
struct EnginePredictor {
    scenario: Arc<Scenario>,
    method: Method,
    mode: DeductionMode,
    t_overhead_ms: f64,
    fallback_ms: f64,
    models: Vec<Option<BucketModel>>,
    /// Vectorized SoA kernels compiled once per loaded model at build time
    /// (parallel to `models`); the serve loop evaluates whole plans through
    /// these, bit-identical to the scalar model path.
    kernels: Vec<Option<soa::BucketKernel>>,
    /// Opt-in compiled lookup-table tier (`EngineBuilder::lut`): per-bucket
    /// direct-lookup tables pre-evaluated over a quantized feature grid at
    /// build time. Rows on a grid point are served bit-identically to the
    /// model; near-grid rows interpolate within the spec's error bound;
    /// everything else falls back to the SoA kernels untouched.
    lut: Option<LutPack>,
    /// Present when this predictor was loaded from a `TransferBundle`:
    /// per-bucket recalibration scales (dense by `BucketId`, applied to
    /// each evaluated row — after the SoA/LUT tiers, so those stay
    /// transfer-agnostic) and the monotone latency map applied to the
    /// summed end-to-end prediction.
    transfer: Option<TransferParams>,
}

/// The runtime half of a loaded [`TransferBundle`].
struct TransferParams {
    map: crate::transfer::MonotoneMap,
    scales: Vec<f64>,
}

/// Builder for [`LatencyEngine`]: collect bundles, then `build()`.
#[derive(Default)]
pub struct EngineBuilder {
    bundles: Vec<PredictorBundle>,
    transfers: Vec<crate::transfer::TransferBundle>,
    threads: Option<usize>,
    lut: Option<LutSpec>,
}

/// Graphs lowered at build time to calibrate the LUT feature grids:
/// deterministic NAS samples, so an engine built twice from the same
/// bundles compiles the same tables.
const LUT_CALIBRATION_SEED: u64 = 0xed6e;
const LUT_CALIBRATION_GRAPHS: usize = 16;

impl EngineBuilder {
    pub fn new() -> EngineBuilder {
        EngineBuilder { bundles: Vec::new(), transfers: Vec::new(), threads: None, lut: None }
    }

    /// Add an in-memory bundle (e.g. freshly trained).
    pub fn bundle(mut self, b: PredictorBundle) -> EngineBuilder {
        self.bundles.push(b);
        self
    }

    /// Add an in-memory transfer bundle (e.g. freshly adapted via
    /// `transfer::adapt`). Serves under its *target* scenario id.
    pub fn transfer(mut self, t: crate::transfer::TransferBundle) -> EngineBuilder {
        self.transfers.push(t);
        self
    }

    /// Load and add a bundle file written by `edgelat train` or `edgelat
    /// transfer` — predictor or transfer bundle, JSON or binary, all four
    /// combinations sniffed by magic / the `format` field. This is the
    /// path every directory-scanning loader (the serve fleet, hot reload)
    /// goes through, so a transfer bundle dropped into a fleet directory
    /// serves like any trained bundle.
    pub fn bundle_file(self, path: impl AsRef<std::path::Path>) -> Result<EngineBuilder, EngineError> {
        match crate::transfer::load_any(path)? {
            crate::transfer::LoadedBundle::Predictor(b) => Ok(self.bundle(b)),
            crate::transfer::LoadedBundle::Transfer(t) => Ok(self.transfer(t)),
        }
    }

    /// Worker threads for `predict_batch` (default: available parallelism).
    pub fn threads(mut self, n: usize) -> EngineBuilder {
        self.threads = Some(n.max(1));
        self
    }

    /// Compile the opt-in LUT tier at build time: per-bucket lookup
    /// tables calibrated on deterministic NAS graphs, verified against
    /// the full models within `spec.max_rel_err`. Buckets whose grid
    /// would be too large (or miss the bound) simply keep the SoA path.
    pub fn lut(mut self, spec: LutSpec) -> EngineBuilder {
        self.lut = Some(spec);
        self
    }

    pub fn build(self) -> Result<LatencyEngine, EngineError> {
        let EngineBuilder { bundles, transfers, threads, lut } = self;
        if bundles.is_empty() && transfers.is_empty() {
            return Err(EngineError::Unsupported(
                "an engine needs at least one predictor bundle".into(),
            ));
        }
        let it = plan::interner();
        let mut predictors = Vec::with_capacity(bundles.len() + transfers.len());
        for b in bundles {
            // The builder is consumed, so the models — and the bundle's
            // embedded scenario descriptor — move in for free. No registry
            // lookup, no `Scenario` clone: a bundle trained on a device
            // this build never saw resolves against itself. Fields are
            // pub, so re-validate the descriptor before it reaches the
            // cost model (same contract as `to_predictor`).
            bundle::validate_bundle_scenario(&b.scenario)?;
            let scenario = Arc::new(b.scenario);
            // Intern the by-name bundle models into the dense table the
            // serve loop indexes by `BucketId`.
            let mut models: Vec<Option<BucketModel>> = (0..it.len()).map(|_| None).collect();
            for (bucket, m) in b.models {
                let id = resolve_bundle_bucket(&scenario.id, &bucket)?;
                models[id.index()] = Some(m);
            }
            // Compile each loaded model's SoA kernel once; every predict
            // call reuses them instead of walking enum arenas per row.
            let kernels =
                models.iter().map(|m| m.as_ref().map(soa::BucketKernel::compile)).collect();
            predictors.push(EnginePredictor {
                scenario,
                method: b.method,
                mode: b.mode,
                t_overhead_ms: b.t_overhead_ms,
                fallback_ms: b.fallback_ms,
                models,
                kernels,
                lut: None,
                transfer: None,
            });
        }
        for t in transfers {
            // A transfer bundle serves under its *target* scenario: the
            // source models do the per-row work, the dense scale table
            // recalibrates them, and the monotone map finishes the sum.
            bundle::validate_bundle_scenario(&t.target)?;
            bundle::validate_bundle_scenario(&t.source.scenario)?;
            let scales = t.dense_scales()?;
            let scenario = Arc::new(t.target);
            let mut models: Vec<Option<BucketModel>> = (0..it.len()).map(|_| None).collect();
            for (bucket, m) in t.source.models {
                let id = resolve_bundle_bucket(&scenario.id, &bucket)?;
                models[id.index()] = Some(m);
            }
            let kernels =
                models.iter().map(|m| m.as_ref().map(soa::BucketKernel::compile)).collect();
            predictors.push(EnginePredictor {
                scenario,
                method: t.source.method,
                mode: t.source.mode,
                t_overhead_ms: t.t_overhead_ms,
                fallback_ms: t.fallback_ms,
                models,
                kernels,
                lut: None,
                transfer: Some(TransferParams { map: t.map, scales }),
            });
        }
        // Deduction only depends on (scenario, mode), not on the trained
        // method — predictors sharing both share one cache slot. Compared
        // structurally (SoC parameters + target), not by id: two embedded
        // descriptors claiming the same id but different cost-model
        // parameters must not share lowered plans.
        let dedup: Vec<usize> = (0..predictors.len())
            .map(|i| {
                (0..i)
                    .find(|&j| {
                        predictors[j].scenario == predictors[i].scenario
                            && predictors[j].mode == predictors[i].mode
                    })
                    .unwrap_or(i)
            })
            .collect();
        if let Some(spec) = &lut {
            // Calibration plans: the same deterministic graph set lowered
            // once per distinct (scenario, mode) — shared via the dedup
            // map, like the plan cache — then a LUT compiled per
            // predictor from its own models.
            let graphs: Vec<Graph> =
                crate::nas::sample_dataset(LUT_CALIBRATION_SEED, LUT_CALIBRATION_GRAPHS)
                    .into_iter()
                    .map(|a| a.graph)
                    .collect();
            let mut lowered: Vec<Option<Arc<Vec<LoweredGraph>>>> = vec![None; predictors.len()];
            for i in 0..predictors.len() {
                let c = dedup[i];
                if lowered[c].is_none() {
                    let p = &predictors[c];
                    lowered[c] = Some(Arc::new(
                        graphs.iter().map(|g| plan::lower(&p.scenario, p.mode, g)).collect(),
                    ));
                }
                let plans = lowered[c].clone().expect("lowered above");
                let refs: Vec<&LoweredGraph> = plans.iter().collect();
                let p = &mut predictors[i];
                let dims: Vec<Option<usize>> =
                    p.models.iter().map(|m| m.as_ref().map(|m| m.feature_dim())).collect();
                let mut scratch: Vec<f64> = Vec::new();
                let pack = LutPack::compile(spec, &dims, &refs, |bi, row| {
                    p.models[bi].as_ref().map(|m| m.predict_raw_with(row, &mut scratch))
                });
                p.lut = Some(pack);
            }
        }
        let pool = threads.map(ExecPool::new).unwrap_or_default();
        Ok(LatencyEngine {
            predictors,
            dedup,
            pool,
            plan_cache: ShardedCache::new(PLAN_CACHE_SHARDS, PLAN_CACHE_CAP),
        })
    }
}

/// Memoized lowering of one graph under one (scenario, mode): the dense
/// plan IR, shared between concurrent readers.
type CachedPlan = Arc<LoweredGraph>;

/// An owned, `Send + Sync` latency-prediction engine serving one or more
/// scenarios from loaded [`PredictorBundle`]s.
pub struct LatencyEngine {
    predictors: Vec<EnginePredictor>,
    /// `dedup[i]` is the canonical predictor index whose (scenario, mode)
    /// matches predictor `i` — same-lowering predictors share cache slots.
    dedup: Vec<usize>,
    /// Shared worker pool behind [`predict_batch`](Self::predict_batch).
    pool: ExecPool,
    /// Plan memo: (canonical predictor index, graph fingerprint) →
    /// [`LoweredGraph`]. Lowering is pure in the graph, so repeated
    /// queries for the same architecture (NAS search, figure regeneration)
    /// skip straight to the per-bucket model evaluations over the cached
    /// plan. Sharded ([`PLAN_CACHE_SHARDS`] locks) so concurrent batch
    /// workers stop serializing on one global mutex; bounded by
    /// [`PLAN_CACHE_CAP`] with per-shard eviction (an overflow costs one
    /// shard's warmth, not the whole cache's).
    plan_cache: ShardedCache<(usize, u64), CachedPlan>,
}

/// Cap on memoized plans; a long-lived engine serving an unbounded
/// stream of distinct graphs must not grow without limit (it is a pure
/// cache — eviction only loses warmth).
const PLAN_CACHE_CAP: usize = 4096;

/// Lock shards for the plan memo.
const PLAN_CACHE_SHARDS: usize = 16;

impl LatencyEngine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Scenario ids with at least one loaded predictor, in load order.
    pub fn scenario_ids(&self) -> Vec<&str> {
        self.predictors.iter().map(|p| p.scenario.id.as_str()).collect()
    }

    /// Number of loaded predictors.
    pub fn len(&self) -> usize {
        self.predictors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.predictors.is_empty()
    }

    fn find(
        &self,
        scenario_id: &str,
        method: Option<Method>,
    ) -> Result<(usize, &EnginePredictor), EngineError> {
        for (i, p) in self.predictors.iter().enumerate() {
            if p.scenario.id == scenario_id && method.map(|m| m == p.method).unwrap_or(true) {
                return Ok((i, p));
            }
        }
        Err(EngineError::NoPredictor { scenario_id: scenario_id.to_string(), method })
    }

    fn plan_for(&self, idx: usize, p: &EnginePredictor, g: &Graph) -> CachedPlan {
        let key = (self.dedup[idx], g.fingerprint());
        if let Some(u) = self.plan_cache.get(&key) {
            return u;
        }
        // Lower outside any lock; a racing duplicate computes the same
        // value (lowering is pure), and the first insert wins.
        let plan = Arc::new(plan::lower(&p.scenario, p.mode, g));
        self.plan_cache.insert(key, plan)
    }

    /// Hit/miss/eviction counters of the sharded plan memo.
    pub fn cache_stats(&self) -> CacheStats {
        self.plan_cache.stats()
    }

    /// Lock shards of the plan memo.
    pub fn cache_shards(&self) -> usize {
        self.plan_cache.shard_count()
    }

    /// Whether any loaded predictor carries a compiled LUT tier.
    pub fn lut_enabled(&self) -> bool {
        self.predictors.iter().any(|p| p.lut.is_some())
    }

    /// Aggregated LUT-tier counters across all loaded predictors (all
    /// zero when the engine was built without [`EngineBuilder::lut`]).
    pub fn lut_counts(&self) -> LutCounts {
        let mut total = LutCounts::default();
        for p in &self.predictors {
            if let Some(l) = &p.lut {
                total = total.merge(&l.counts());
            }
        }
        total
    }

    /// Buckets with a compiled table, summed across loaded predictors.
    pub fn lut_tables(&self) -> usize {
        self.predictors.iter().filter_map(|p| p.lut.as_ref()).map(LutPack::coverage).sum()
    }

    /// Worker threads used by [`predict_batch`](Self::predict_batch).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Serve one prediction: fetch (or build) the memoized plan, then
    /// evaluate it bucket-grouped through the SoA kernels compiled at
    /// build time (`predict::soa::eval_plan_grouped`) — bit-identical to
    /// the old per-unit scalar scan, with model-less buckets charged the
    /// fallback and rows narrower than a model's feature dim kept on the
    /// scalar path.
    pub fn predict(&self, req: &PredictRequest) -> Result<PredictResponse, EngineError> {
        let (idx, p) = self.find(&req.scenario_id, req.method)?;
        let it = plan::interner();
        let pl = self.plan_for(idx, p, req.graph);
        let (rows, fallback_units) = soa::eval_plan_grouped(
            &pl,
            &p.kernels,
            p.fallback_ms,
            p.lut.as_ref(),
            |bi, row, scratch| p.models[bi].as_ref().map(|m| m.predict_raw_with(row, scratch)),
        );
        let mut per_unit = Vec::with_capacity(pl.len());
        let mut sum = 0.0;
        for (i, ms) in rows.into_iter().enumerate() {
            // Transfer-loaded predictors recalibrate each row by its
            // bucket's scale (after the SoA/LUT tiers, which stay
            // transfer-agnostic), so per-unit figures are in target units.
            let ms = match &p.transfer {
                Some(t) => ms * t.scales[pl.bucket(i).index()],
                None => ms,
            };
            sum += ms;
            per_unit.push((it.name(pl.bucket(i)), ms));
        }
        let e2e_ms = match &p.transfer {
            Some(t) => t.map.apply(p.t_overhead_ms + sum),
            None => p.t_overhead_ms + sum,
        };
        Ok(PredictResponse { e2e_ms, per_unit, t_overhead_ms: p.t_overhead_ms, fallback_units })
    }

    /// Serve a batch of predictions, fanned out on the shared
    /// [`ExecPool`] (chunked work queue — uneven graph sizes balance
    /// across workers). Results preserve request order; each slot carries
    /// its own error so one bad request doesn't poison the batch.
    pub fn predict_batch(
        &self,
        reqs: &[PredictRequest],
    ) -> Vec<Result<PredictResponse, EngineError>> {
        self.pool.map(reqs, |_, r| self.predict(r))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<LatencyEngine>();
        assert_send_sync::<PredictorBundle>();
        assert_send_sync::<PredictResponse>();
        assert_send_sync::<EngineError>();
    }

    #[test]
    fn empty_builder_is_rejected() {
        let err = EngineBuilder::new().build().unwrap_err();
        assert!(err.to_string().contains("at least one"), "{err}");
    }

    #[test]
    fn error_display_names_the_scenario() {
        let e = EngineError::NoPredictor {
            scenario_id: "X/gpu".into(),
            method: Some(Method::Gbdt),
        };
        let s = e.to_string();
        assert!(s.contains("X/gpu") && s.contains("GBDT"), "{s}");
        assert!(EngineError::UnknownScenario("Y".into()).to_string().contains("Y"));
    }
}
