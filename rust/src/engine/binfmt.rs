//! The compact binary serialization of a [`PredictorBundle`] — a
//! load-time fast path next to the JSON interchange format.
//!
//! JSON stays the golden format: human-diffable, versioned, and the one
//! the goldens under `tests/data/` pin. The binary format is a lossless
//! re-encoding of the same document for serving fleets that load many
//! bundles at boot (or on hot reload): no text parsing, no per-number
//! shortest-repr round-trip — floats are stored as raw little-endian
//! IEEE-754 bits, so `decode(encode(b))` reproduces `b` **bit-exactly**
//! and converting JSON → bin → JSON is the identity on the emitted text.
//!
//! Layout (all integers little-endian, sections 8-byte aligned, zero
//! padding between them):
//!
//! ```text
//! 0    magic "EDGELATB"                              8 bytes
//! 8    version u32  | method u32 | mode u32          (codes, see below)
//! 20   n_strings u32 | n_models u32 | reserved u32
//! 32   t_overhead_ms f64 | fallback_ms f64
//! 48   strings_off u64 | strings_len u64
//! 64   desc_off u64    | desc_len u64
//! 80   models_off u64  | models_len u64
//! 96   total_len u64
//! 104  strings:  n_strings u32 byte-lengths, pad8, concatenated UTF-8
//!      desc:     UTF-8 JSON {device, scenario, target} (the v3 bundle
//!                descriptor — binary bundles are self-describing too)
//!      models:   n_models records, bucket-name (BTreeMap) order
//! ```
//!
//! Each model record: `name_idx u32, kind u32, dim u32, aux u32`,
//! `floor f64`, `mean[dim] f64`, `std[dim] f64`, then the payload —
//! Lasso (`aux == dim`): `intercept f64, alpha f64, weights[dim] f64`;
//! RF: `n_trees u32, min_samples_split u32` + tree arenas; GBDT:
//! `init f64, learning_rate f64, n_stages u32, min_samples_split u32,
//! max_depth u32` + tree arenas. Tree arenas are the exact flat SoA
//! layout `predict::soa` evaluates (`Tree::flatten_into`): `tree_count
//! u32, node_count u32, pad8, roots[] u32 pad8, feature[] u32 pad8,
//! left[] u32 pad8, right[] u32 pad8, threshold[] f64, value[] f64`,
//! rebuilt through `Tree::from_flat` which validates every structural
//! invariant (leaf self-loops, +inf sentinels, children strictly before
//! parents). The string table is the build's bucket interner in id
//! order; models reference it by index and re-resolve by *name* against
//! the reading build — same contract as the JSON `interner` array.
//!
//! Decoding is pure safe Rust over a bounds-checked cursor: a truncated,
//! corrupted, or adversarially patched file produces a typed
//! [`EngineError`], never a panic or an out-of-bounds read. Section
//! offsets are not trusted — they must tile the file exactly in
//! declared order with zero inter-section padding.

use crate::device::{soc_from_json, soc_to_json};
use crate::engine::bundle::{
    scenario_from_descriptor, target_to_json, validate_bundle_scenario, workload_from_descriptor,
};
use crate::engine::{resolve_bundle_bucket, EngineError, PredictorBundle};
use crate::features::Standardizer;
use crate::framework::DeductionMode;
use crate::plan;
use crate::predict::forest::{ForestParams, RandomForest};
use crate::predict::gbdt::{Gbdt, GbdtParams};
use crate::predict::lasso::Lasso;
use crate::predict::tree::Tree;
use crate::predict::{BucketModel, Method, NativeModel};
use crate::util::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// First 8 bytes of every binary bundle; `load_auto` sniffs this.
pub const BIN_MAGIC: [u8; 8] = *b"EDGELATB";
/// Binary schema version for isolated bundles (descriptor holds
/// `{device, scenario, target}`).
pub const BIN_VERSION: u32 = 1;
/// Version written when the bundle carries a `workload` descriptor key.
/// The version is conditional on the content — isolated bundles keep
/// writing version 1, so their encodings stay byte-identical to
/// pre-workload builds (the golden `.bin` under `tests/data/` pins that),
/// and a version-2 file without a workload key (or vice versa) is
/// rejected as non-canonical.
pub const BIN_VERSION_WORKLOAD: u32 = 2;

const HEADER_LEN: usize = 104;
/// Caps keep a corrupted header from driving huge allocations before
/// the (cheap) bounds checks behind them would fail anyway.
const MAX_STRINGS: u32 = 4096;
const MAX_STRING_LEN: u32 = 1 << 20;
const MAX_MODELS: u32 = 65_536;
const MAX_DIM: u32 = 65_536;
const MAX_TREE_NODES: u32 = 1 << 24;

fn method_code(m: Method) -> Option<u32> {
    match m {
        Method::Lasso => Some(0),
        Method::RandomForest => Some(1),
        Method::Gbdt => Some(2),
        Method::Mlp => None,
    }
}

fn method_from_code(c: u32) -> Result<Method, String> {
    match c {
        0 => Ok(Method::Lasso),
        1 => Ok(Method::RandomForest),
        2 => Ok(Method::Gbdt),
        other => Err(format!("unknown method code {other} (0=lasso, 1=rf, 2=gbdt)")),
    }
}

fn mode_code(m: DeductionMode) -> u32 {
    match m {
        DeductionMode::Full => 0,
        DeductionMode::NoFusion => 1,
        DeductionMode::NoSelection => 2,
    }
}

fn mode_from_code(c: u32) -> Result<DeductionMode, String> {
    match c {
        0 => Ok(DeductionMode::Full),
        1 => Ok(DeductionMode::NoFusion),
        2 => Ok(DeductionMode::NoSelection),
        other => Err(format!(
            "unknown deduction mode code {other} (0=full, 1=nofusion, 2=noselection)"
        )),
    }
}

fn align8(n: u64) -> Option<u64> {
    n.checked_add(7).map(|v| v & !7)
}

// ---------------------------------------------------------------------------
// Writer

#[derive(Default)]
struct BinWriter {
    buf: Vec<u8>,
}

impl BinWriter {
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }
    fn pad8(&mut self) {
        while self.buf.len() % 8 != 0 {
            self.buf.push(0);
        }
    }
}

// ---------------------------------------------------------------------------
// Reader: a bounds-checked cursor. Every read is `Result` — no slicing
// outside `take`, no unchecked arithmetic.

struct BinReader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    fn new(data: &'a [u8]) -> BinReader<'a> {
        BinReader { data, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.data.len())
            .ok_or_else(|| format!("truncated: need {n} bytes at offset {}", self.pos))?;
        let s = &self.data[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>, String> {
        let raw = self.take(n.checked_mul(4).ok_or("u32 array length overflow")?)?;
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().expect("4"))).collect())
    }

    fn f64s(&mut self, n: usize) -> Result<Vec<f64>, String> {
        let raw = self.take(n.checked_mul(8).ok_or("f64 array length overflow")?)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().expect("8"))).collect())
    }

    /// Skip to the next 8-byte boundary, requiring zero padding — a
    /// nonzero pad byte is corruption (and would break the byte-stable
    /// `encode(decode(x)) == x` round-trip if tolerated).
    fn pad8(&mut self) -> Result<(), String> {
        while self.pos % 8 != 0 {
            let b = self.take(1)?[0];
            if b != 0 {
                return Err(format!("nonzero padding byte at offset {}", self.pos - 1));
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Encode

fn require_finite(v: f64, what: &str) -> Result<f64, String> {
    if v.is_finite() {
        Ok(v)
    } else {
        Err(format!("non-finite {what}"))
    }
}

fn encode_trees(w: &mut BinWriter, trees: &[Tree]) -> Result<(), String> {
    let mut feature: Vec<u32> = Vec::new();
    let mut threshold: Vec<f64> = Vec::new();
    let mut left: Vec<u32> = Vec::new();
    let mut right: Vec<u32> = Vec::new();
    let mut value: Vec<f64> = Vec::new();
    let mut roots: Vec<u32> = Vec::with_capacity(trees.len());
    for t in trees {
        roots.push(t.flatten_into(&mut feature, &mut threshold, &mut left, &mut right, &mut value));
    }
    if trees.is_empty() {
        return Err("no trees".into());
    }
    if feature.len() > MAX_TREE_NODES as usize || trees.len() > MAX_TREE_NODES as usize {
        return Err(format!("tree ensemble too large ({} nodes)", feature.len()));
    }
    w.u32(trees.len() as u32);
    w.u32(feature.len() as u32);
    w.pad8();
    for r in &roots {
        w.u32(*r);
    }
    w.pad8();
    for v in &feature {
        w.u32(*v);
    }
    w.pad8();
    for v in &left {
        w.u32(*v);
    }
    w.pad8();
    for v in &right {
        w.u32(*v);
    }
    w.pad8();
    for v in &threshold {
        w.f64(*v);
    }
    for v in &value {
        w.f64(*v);
    }
    Ok(())
}

fn encode_model(
    w: &mut BinWriter,
    name_idx: u32,
    method_c: u32,
    m: &BucketModel,
) -> Result<(), String> {
    let dim = m.standardizer.mean.len();
    if dim == 0 || dim > MAX_DIM as usize {
        return Err(format!("unsupported feature dim {dim}"));
    }
    if m.standardizer.std.len() != dim {
        return Err(format!(
            "standardizer mean/std length mismatch ({dim} vs {})",
            m.standardizer.std.len()
        ));
    }
    let kind = method_code(m.model.method()).expect("native model");
    if kind != method_c {
        return Err(format!(
            "holds a {} model but the bundle method differs",
            m.model.method().name()
        ));
    }
    let aux = match &m.model {
        NativeModel::Lasso(l) => {
            if l.weights.len() != dim {
                return Err(format!(
                    "lasso weight count {} disagrees with feature dim {dim}",
                    l.weights.len()
                ));
            }
            dim as u32
        }
        _ => 0,
    };
    w.u32(name_idx);
    w.u32(kind);
    w.u32(dim as u32);
    w.u32(aux);
    w.f64(require_finite(m.floor, "floor")?);
    for &v in &m.standardizer.mean {
        w.f64(require_finite(v, "standardizer mean")?);
    }
    for &v in &m.standardizer.std {
        if !(v.is_finite() && v > 0.0) {
            return Err("non-positive standardizer std".into());
        }
        w.f64(v);
    }
    match &m.model {
        NativeModel::Lasso(l) => {
            w.f64(require_finite(l.intercept, "lasso intercept")?);
            w.f64(require_finite(l.alpha, "lasso alpha")?);
            for &v in &l.weights {
                w.f64(require_finite(v, "lasso weight")?);
            }
        }
        NativeModel::RandomForest(rf) => {
            w.u32(rf.params.n_trees as u32);
            w.u32(rf.params.min_samples_split as u32);
            encode_trees(w, &rf.trees)?;
        }
        NativeModel::Gbdt(g) => {
            w.f64(require_finite(g.init, "gbdt init")?);
            w.f64(require_finite(g.params.learning_rate, "gbdt learning_rate")?);
            w.u32(g.params.n_stages as u32);
            w.u32(g.params.min_samples_split as u32);
            w.u32(g.params.max_depth as u32);
            encode_trees(w, &g.trees)?;
        }
    }
    Ok(())
}

fn encode(b: &PredictorBundle) -> Result<Vec<u8>, String> {
    let method_c = method_code(b.method).ok_or_else(|| {
        "bundles hold the native methods (lasso|rf|gbdt); the MLP stays engine-external"
            .to_string()
    })?;
    if b.models.is_empty() {
        return Err("bundle has no bucket models".into());
    }
    if b.models.len() > MAX_MODELS as usize {
        return Err(format!("too many bucket models ({})", b.models.len()));
    }
    let it = plan::interner();
    let names = it.names();

    // String table: the interner names in id order (same table the JSON
    // format serializes as the `interner` array).
    let mut sw = BinWriter::default();
    for &n in names {
        sw.u32(n.len() as u32);
    }
    sw.pad8();
    for &n in names {
        sw.bytes(n.as_bytes());
    }
    let strings = sw.buf;

    // The self-describing scenario descriptor, as compact JSON — the one
    // part of the format where text wins (it is tiny, schema'd elsewhere,
    // and reuses the spec-file SoC codec verbatim). The workload key is
    // present exactly when the scenario is workload-qualified, and the
    // header version follows it.
    let mut desc_fields = vec![
        ("device", soc_to_json(&b.scenario.soc)),
        ("scenario", Json::str(b.scenario.id.clone())),
        ("target", target_to_json(&b.scenario.target)),
    ];
    if let Some(wl) = &b.scenario.workload {
        desc_fields.push(("workload", wl.to_json()));
    }
    let desc = Json::obj(desc_fields).to_string().into_bytes();

    let mut mw = BinWriter::default();
    for (name, m) in &b.models {
        let id = it.resolve(name).ok_or_else(|| {
            format!("bucket '{name}' is not in this build's intern table")
        })?;
        encode_model(&mut mw, id.index() as u32, method_c, m)
            .map_err(|e| format!("bucket '{name}': {e}"))?;
    }
    let models = mw.buf;

    let strings_off = HEADER_LEN as u64;
    let desc_off = align8(strings_off + strings.len() as u64).expect("offset fits u64");
    let models_off = align8(desc_off + desc.len() as u64).expect("offset fits u64");
    let total_len = align8(models_off + models.len() as u64).expect("offset fits u64");

    let mut w = BinWriter { buf: Vec::with_capacity(total_len as usize) };
    w.bytes(&BIN_MAGIC);
    w.u32(if b.scenario.workload.is_some() { BIN_VERSION_WORKLOAD } else { BIN_VERSION });
    w.u32(method_c);
    w.u32(mode_code(b.mode));
    w.u32(names.len() as u32);
    w.u32(b.models.len() as u32);
    w.u32(0); // reserved
    w.f64(require_finite(b.t_overhead_ms, "t_overhead_ms")?);
    w.f64(require_finite(b.fallback_ms, "fallback_ms")?);
    w.u64(strings_off);
    w.u64(strings.len() as u64);
    w.u64(desc_off);
    w.u64(desc.len() as u64);
    w.u64(models_off);
    w.u64(models.len() as u64);
    w.u64(total_len);
    debug_assert_eq!(w.buf.len(), HEADER_LEN);
    w.bytes(&strings);
    w.pad8();
    w.bytes(&desc);
    w.pad8();
    w.bytes(&models);
    w.pad8();
    debug_assert_eq!(w.buf.len() as u64, total_len);
    Ok(w.buf)
}

// ---------------------------------------------------------------------------
// Decode

/// The header fields, validated structurally (magic/version/codes/layout)
/// but before any section content is parsed.
struct Header {
    version: u32,
    method_c: u32,
    mode_c: u32,
    n_strings: u32,
    n_models: u32,
    t_overhead_ms: f64,
    fallback_ms: f64,
    strings: (u64, u64),
    desc: (u64, u64),
    models: (u64, u64),
}

fn decode_header(data: &[u8]) -> Result<Header, String> {
    if data.len() < HEADER_LEN {
        return Err(format!("truncated header: {} bytes (need {HEADER_LEN})", data.len()));
    }
    if data[..8] != BIN_MAGIC {
        return Err("not a binary predictor bundle (bad magic)".into());
    }
    let mut r = BinReader::new(&data[8..HEADER_LEN]);
    let version = r.u32()?;
    if !(BIN_VERSION..=BIN_VERSION_WORKLOAD).contains(&version) {
        return Err(format!(
            "unsupported binary bundle version {version} (this build reads versions \
             {BIN_VERSION}..={BIN_VERSION_WORKLOAD})"
        ));
    }
    let method_c = r.u32()?;
    method_from_code(method_c)?;
    let mode_c = r.u32()?;
    mode_from_code(mode_c)?;
    let n_strings = r.u32()?;
    let n_models = r.u32()?;
    if r.u32()? != 0 {
        return Err("nonzero reserved header field".into());
    }
    let t_overhead_ms = r.f64()?;
    let fallback_ms = r.f64()?;
    if !t_overhead_ms.is_finite() || !fallback_ms.is_finite() {
        return Err("non-finite t_overhead_ms/fallback_ms".into());
    }
    let strings = (r.u64()?, r.u64()?);
    let desc = (r.u64()?, r.u64()?);
    let models = (r.u64()?, r.u64()?);
    let total_len = r.u64()?;
    if total_len != data.len() as u64 {
        return Err(format!(
            "length mismatch: header says {total_len} bytes, file has {}",
            data.len()
        ));
    }
    if n_strings == 0 || n_strings > MAX_STRINGS {
        return Err(format!("string table has {n_strings} entries (1..={MAX_STRINGS})"));
    }
    if n_models == 0 {
        return Err("bundle has no bucket models".into());
    }
    if n_models > MAX_MODELS {
        return Err(format!("too many bucket models ({n_models})"));
    }
    // The declared sections must tile the file exactly: header, strings,
    // descriptor, models, each 8-aligned, nothing in between or after.
    // Swapped or overlapping offsets fail here, not deep in a parser.
    if strings.0 != HEADER_LEN as u64 {
        return Err("strings section does not follow the header".into());
    }
    let exp_desc = align8(strings.0.checked_add(strings.1).ok_or("section overflow")?)
        .ok_or("section overflow")?;
    if desc.0 != exp_desc {
        return Err("descriptor section offset disagrees with the strings section".into());
    }
    let exp_models =
        align8(desc.0.checked_add(desc.1).ok_or("section overflow")?).ok_or("section overflow")?;
    if models.0 != exp_models {
        return Err("models section offset disagrees with the descriptor section".into());
    }
    let exp_end = align8(models.0.checked_add(models.1).ok_or("section overflow")?)
        .ok_or("section overflow")?;
    if exp_end != total_len {
        return Err("trailing bytes after the models section".into());
    }
    Ok(Header {
        version,
        method_c,
        mode_c,
        n_strings,
        n_models,
        t_overhead_ms,
        fallback_ms,
        strings,
        desc,
        models,
    })
}

fn section(data: &[u8], (off, len): (u64, u64), name: &str) -> Result<&[u8], String> {
    let end = off.checked_add(len).ok_or_else(|| format!("{name} section overflow"))?;
    if end > data.len() as u64 {
        return Err(format!("{name} section out of bounds ({off}+{len} > {})", data.len()));
    }
    // Inter-section padding must be zero (see `BinReader::pad8`).
    let padded = align8(end).expect("end fits");
    for i in end..padded.min(data.len() as u64) {
        if data[i as usize] != 0 {
            return Err(format!("nonzero padding byte after the {name} section"));
        }
    }
    Ok(&data[off as usize..end as usize])
}

fn decode_strings(sec: &[u8], n: usize) -> Result<Vec<String>, String> {
    let mut r = BinReader::new(sec);
    let lens = r.u32s(n)?;
    r.pad8()?;
    let mut out = Vec::with_capacity(n);
    for (i, &l) in lens.iter().enumerate() {
        if l > MAX_STRING_LEN {
            return Err(format!("string {i} oversized ({l} bytes)"));
        }
        let raw = r.take(l as usize).map_err(|e| format!("string {i}: {e}"))?;
        let s = std::str::from_utf8(raw).map_err(|_| format!("string {i} is not UTF-8"))?;
        out.push(s.to_string());
    }
    if r.pos != sec.len() {
        return Err("trailing bytes in the string table".into());
    }
    Ok(out)
}

fn decode_trees(r: &mut BinReader, dim: u32) -> Result<Vec<Tree>, String> {
    let tree_count = r.u32()?;
    let node_count = r.u32()?;
    if tree_count == 0 {
        return Err("no trees".into());
    }
    if tree_count > MAX_TREE_NODES || node_count > MAX_TREE_NODES {
        return Err(format!("tree ensemble too large ({tree_count} trees, {node_count} nodes)"));
    }
    if node_count < tree_count {
        return Err(format!("{tree_count} trees cannot fit in {node_count} nodes"));
    }
    r.pad8()?;
    let roots = r.u32s(tree_count as usize)?;
    r.pad8()?;
    let feature = r.u32s(node_count as usize)?;
    r.pad8()?;
    let left = r.u32s(node_count as usize)?;
    r.pad8()?;
    let right = r.u32s(node_count as usize)?;
    r.pad8()?;
    let threshold = r.f64s(node_count as usize)?;
    let value = r.f64s(node_count as usize)?;
    // Split nodes (non-self-loops) must index a feature inside the
    // standardized vector this record declares.
    for i in 0..node_count as usize {
        let leaf = left[i] as usize == i && right[i] as usize == i;
        if !leaf && feature[i] >= dim {
            return Err(format!(
                "tree node {i}: feature index {} out of range (dim {dim})",
                feature[i]
            ));
        }
    }
    let mut trees = Vec::with_capacity(tree_count as usize);
    let mut start = 0usize;
    for (t, &root) in roots.iter().enumerate() {
        let root = root as usize;
        if root < start || root >= node_count as usize {
            return Err(format!("tree {t}: root {root} out of order (span starts at {start})"));
        }
        trees.push(
            Tree::from_flat(&feature, &threshold, &left, &right, &value, start, root)
                .map_err(|e| format!("tree {t}: {e}"))?,
        );
        start = root + 1;
    }
    if start != node_count as usize {
        return Err(format!(
            "tree spans cover {start} of {node_count} arena nodes"
        ));
    }
    Ok(trees)
}

fn decode_model(
    r: &mut BinReader,
    h: &Header,
    strings: &[String],
    scenario_id: &str,
) -> Result<(String, BucketModel), String> {
    let name_idx = r.u32()?;
    let name = strings
        .get(name_idx as usize)
        .ok_or_else(|| format!("bucket name index {name_idx} out of range"))?
        .clone();
    let fail = |e: String| format!("bucket '{name}': {e}");
    let kind = r.u32()?;
    if kind != h.method_c {
        let kind_name = method_from_code(kind).map(|m| m.name().to_string()).map_err(fail)?;
        let method = method_from_code(h.method_c).expect("validated").name();
        return Err(format!(
            "bucket '{name}' holds a {kind_name} model but the bundle method is {method}"
        ));
    }
    let dim = r.u32()?;
    if dim == 0 || dim > MAX_DIM {
        return Err(fail(format!("unsupported feature dim {dim}")));
    }
    let aux = r.u32()?;
    let floor = r.f64()?;
    if !floor.is_finite() {
        return Err(fail("non-finite floor".into()));
    }
    let mean = r.f64s(dim as usize)?;
    let std = r.f64s(dim as usize)?;
    if mean.iter().any(|v| !v.is_finite()) {
        return Err(fail("non-finite standardizer mean".into()));
    }
    if std.iter().any(|v| !(v.is_finite() && *v > 0.0)) {
        return Err(fail("non-positive standardizer std".into()));
    }
    let model = match method_from_code(h.method_c).expect("validated") {
        Method::Lasso => {
            if aux != dim {
                return Err(fail(format!(
                    "lasso weight count {aux} disagrees with feature dim {dim}"
                )));
            }
            let intercept = r.f64()?;
            let alpha = r.f64()?;
            let weights = r.f64s(dim as usize)?;
            if weights.iter().any(|w| !w.is_finite()) || !intercept.is_finite() {
                return Err(fail("lasso: non-finite weights/intercept".into()));
            }
            if !alpha.is_finite() {
                return Err(fail("lasso: non-finite alpha".into()));
            }
            NativeModel::Lasso(Lasso { weights, intercept, alpha })
        }
        Method::RandomForest => {
            if aux != 0 {
                return Err(fail("nonzero aux field for a tree model".into()));
            }
            let n_trees = r.u32()? as usize;
            let min_samples_split = r.u32()? as usize;
            let trees = decode_trees(r, dim).map_err(|e| fail(format!("rf: {e}")))?;
            NativeModel::RandomForest(RandomForest {
                trees,
                params: ForestParams { n_trees, min_samples_split },
            })
        }
        Method::Gbdt => {
            if aux != 0 {
                return Err(fail("nonzero aux field for a tree model".into()));
            }
            let init = r.f64()?;
            let learning_rate = r.f64()?;
            if !init.is_finite() || !learning_rate.is_finite() {
                return Err(fail("gbdt: non-finite init/learning_rate".into()));
            }
            let n_stages = r.u32()? as usize;
            let min_samples_split = r.u32()? as usize;
            let max_depth = r.u32()? as usize;
            let trees = decode_trees(r, dim).map_err(|e| fail(format!("gbdt: {e}")))?;
            NativeModel::Gbdt(Gbdt {
                init,
                trees,
                params: GbdtParams { n_stages, min_samples_split, learning_rate, max_depth },
            })
        }
        Method::Mlp => unreachable!("method codes cover native methods only"),
    };
    // Same contract as the JSON loader: the name must resolve in this
    // build's intern table before the model can serve.
    resolve_bundle_bucket(scenario_id, &name).map_err(|e| e.to_string())?;
    Ok((name, BucketModel { standardizer: Standardizer { mean, std }, model, floor }))
}

fn decode(data: &[u8]) -> Result<PredictorBundle, String> {
    let h = decode_header(data)?;
    let strings = decode_strings(section(data, h.strings, "strings")?, h.n_strings as usize)?;

    let desc_raw = section(data, h.desc, "descriptor")?;
    let desc_txt = std::str::from_utf8(desc_raw)
        .map_err(|_| "descriptor is not UTF-8".to_string())?;
    let dj = Json::parse(desc_txt).map_err(|e| format!("descriptor: {e}"))?;
    let scenario_id = dj.req_str("scenario").map_err(|e| format!("descriptor: {e}"))?.to_string();
    let soc = soc_from_json(dj.req("device").map_err(|e| format!("descriptor: {e}"))?)
        .map_err(|e| format!("device: {e}"))?;
    let workload = workload_from_descriptor(&dj).map_err(|e| format!("descriptor: {e}"))?;
    // The version byte is canonical: 2 exactly when a workload rides in
    // the descriptor. Either mismatch is a tampered or miswritten file.
    if workload.is_some() && h.version < BIN_VERSION_WORKLOAD {
        return Err(format!(
            "version-{} bundle carries a workload descriptor (needs version \
             {BIN_VERSION_WORKLOAD})",
            h.version
        ));
    }
    if workload.is_none() && h.version >= BIN_VERSION_WORKLOAD {
        return Err(format!(
            "version-{} bundle is missing its workload descriptor",
            h.version
        ));
    }
    let scenario = scenario_from_descriptor(
        soc,
        dj.req("target").map_err(|e| format!("descriptor: {e}"))?,
        &scenario_id,
        workload,
    )?;
    validate_bundle_scenario(&scenario).map_err(|e| e.to_string())?;

    let msec = section(data, h.models, "models")?;
    let mut r = BinReader::new(msec);
    let mut models = BTreeMap::new();
    for _ in 0..h.n_models {
        let (name, m) = decode_model(&mut r, &h, &strings, &scenario_id)?;
        if models.insert(name.clone(), m).is_some() {
            return Err(format!("duplicate model for bucket '{name}'"));
        }
    }
    if r.pos != msec.len() {
        return Err("trailing bytes after the last model record".into());
    }
    Ok(PredictorBundle {
        scenario,
        method: method_from_code(h.method_c).expect("validated"),
        mode: mode_from_code(h.mode_c).expect("validated"),
        t_overhead_ms: h.t_overhead_ms,
        fallback_ms: h.fallback_ms,
        models,
    })
}

/// Header + content summary of a binary bundle, as a JSON document for
/// `edgelat bundle inspect`. Fully validates the file first — an inspect
/// that succeeds is an inspect of a loadable bundle.
pub fn inspect_bin(data: &[u8]) -> Result<Json, String> {
    let b = decode(data)?;
    let h = decode_header(data).expect("decode validated the header");
    let sect = |(off, len): (u64, u64)| {
        Json::obj(vec![("off", Json::num(off as f64)), ("len", Json::num(len as f64))])
    };
    Ok(Json::obj(vec![
        ("format", Json::str("edgelat.predictor_bundle.bin")),
        ("version", Json::num(h.version as f64)),
        ("scenario", Json::str(b.scenario.id.clone())),
        ("device", Json::str(b.scenario.soc.name.clone())),
        ("method", Json::str(b.method.name())),
        ("mode", Json::str(b.mode.name())),
        ("t_overhead_ms", Json::Num(b.t_overhead_ms)),
        ("fallback_ms", Json::Num(b.fallback_ms)),
        ("buckets", Json::Arr(b.models.keys().map(|k| Json::str(k.clone())).collect())),
        ("n_models", Json::num(b.models.len() as f64)),
        ("n_strings", Json::num(h.n_strings as f64)),
        (
            "sections",
            Json::obj(vec![
                ("strings", sect(h.strings)),
                ("descriptor", sect(h.desc)),
                ("models", sect(h.models)),
            ]),
        ),
        ("total_bytes", Json::num(data.len() as f64)),
    ]))
}

impl PredictorBundle {
    /// Serialize to the binary format. Lossless: decoding the bytes
    /// reproduces this bundle bit-exactly (same JSON text, same
    /// predictions). Fails for MLP bundles and for models whose bucket
    /// names this build's intern table does not know.
    pub fn to_bin_bytes(&self) -> Result<Vec<u8>, EngineError> {
        if self.method == Method::Mlp {
            return Err(EngineError::Unsupported(
                "bundles hold the native methods (lasso|rf|gbdt); the MLP stays \
                 engine-external (PJRT handles are not serializable)"
                    .into(),
            ));
        }
        encode(self).map_err(EngineError::Parse)
    }

    /// Decode a binary bundle from bytes, validating every offset and
    /// every structural invariant — corrupted input is a typed error,
    /// never a panic.
    pub fn from_bin_bytes(data: &[u8]) -> Result<PredictorBundle, EngineError> {
        decode(data).map_err(EngineError::Parse)
    }

    /// Write the bundle in the binary format. I/O errors name the path.
    pub fn save_bin(&self, path: impl AsRef<Path>) -> Result<(), EngineError> {
        let path = path.as_ref();
        let bytes = self.to_bin_bytes()?;
        std::fs::write(path, bytes)
            .map_err(|e| EngineError::Io(format!("writing {}: {e}", path.display())))
    }

    /// Load a binary bundle file. I/O and parse errors name the path.
    pub fn load_bin(path: impl AsRef<Path>) -> Result<PredictorBundle, EngineError> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .map_err(|e| EngineError::Io(format!("reading {}: {e}", path.display())))?;
        decode(&bytes).map_err(|e| EngineError::Parse(format!("{}: {e}", path.display())))
    }

    /// Load a bundle in either format, sniffing the binary magic — the
    /// path every directory-scanning loader (`EngineBuilder::bundle_file`,
    /// the serve fleet) goes through, so `.bin` bundles work everywhere
    /// `.json` ones do, hot reload included.
    pub fn load_auto(path: impl AsRef<Path>) -> Result<PredictorBundle, EngineError> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .map_err(|e| EngineError::Io(format!("reading {}: {e}", path.display())))?;
        if bytes.starts_with(&BIN_MAGIC) {
            return decode(&bytes)
                .map_err(|e| EngineError::Parse(format!("{}: {e}", path.display())));
        }
        let s = String::from_utf8(bytes).map_err(|_| {
            EngineError::Parse(format!(
                "{}: neither a binary bundle (no magic) nor UTF-8 JSON",
                path.display()
            ))
        })?;
        let j = Json::parse(&s)
            .map_err(|e| EngineError::Parse(format!("{}: {e}", path.display())))?;
        PredictorBundle::from_json(&j)
            .map_err(|e| EngineError::Parse(format!("{}: {e}", path.display())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario;

    fn lasso_bundle() -> PredictorBundle {
        let sc = scenario::one_large_core("Snapdragon855").expect("builtin soc");
        let names = plan::interner().names();
        let mut models = BTreeMap::new();
        for (i, &name) in names.iter().take(2).enumerate() {
            models.insert(
                name.to_string(),
                BucketModel {
                    standardizer: Standardizer {
                        mean: vec![1.5 + i as f64, -0.25, 3.0],
                        std: vec![2.0, 0.5, 1.0],
                    },
                    model: NativeModel::Lasso(Lasso {
                        weights: vec![0.125, -0.5, 2.5e-3],
                        intercept: 4.75 + i as f64,
                        alpha: 0.01,
                    }),
                    floor: 0.0625,
                },
            );
        }
        PredictorBundle {
            scenario: sc,
            method: Method::Lasso,
            mode: DeductionMode::Full,
            t_overhead_ms: 1.375,
            fallback_ms: 0.875,
            models,
        }
    }

    fn gbdt_bundle() -> PredictorBundle {
        let sc = scenario::one_large_core("Snapdragon855").expect("builtin soc");
        // Two hand-built trees: a lone leaf and a one-split stump.
        let leaf = Tree::from_json(&Json::parse("[[0, 2.5]]").unwrap()).unwrap();
        let stump =
            Tree::from_json(&Json::parse("[[0, 1.0], [0, 2.0], [1, 1, 0.5, 0, 1]]").unwrap())
                .unwrap();
        let name = plan::interner().names()[0];
        let mut models = BTreeMap::new();
        models.insert(
            name.to_string(),
            BucketModel {
                standardizer: Standardizer { mean: vec![0.5, 1.5], std: vec![1.0, 2.0] },
                model: NativeModel::Gbdt(Gbdt {
                    init: 1.25,
                    trees: vec![leaf, stump],
                    params: GbdtParams {
                        n_stages: 2,
                        min_samples_split: 2,
                        learning_rate: 0.1,
                        max_depth: 3,
                    },
                }),
                floor: 0.0,
            },
        );
        PredictorBundle {
            scenario: sc,
            method: Method::Gbdt,
            mode: DeductionMode::NoFusion,
            t_overhead_ms: 0.5,
            fallback_ms: 0.25,
            models,
        }
    }

    #[test]
    fn lasso_and_gbdt_bundles_roundtrip_bit_exactly() {
        for b in [lasso_bundle(), gbdt_bundle()] {
            let bytes = b.to_bin_bytes().expect("encode");
            let back = PredictorBundle::from_bin_bytes(&bytes).expect("decode");
            // The JSON emitter is bit-faithful, so text equality is
            // bit-exact equality of every float in the bundle.
            assert_eq!(b.to_json().to_string(), back.to_json().to_string());
            // And re-encoding is byte-stable.
            assert_eq!(bytes, back.to_bin_bytes().expect("re-encode"));
        }
    }

    #[test]
    fn rf_bundle_roundtrips() {
        let mut b = gbdt_bundle();
        let NativeModel::Gbdt(g) = b.models.values().next().unwrap().model.clone() else {
            unreachable!()
        };
        b.method = Method::RandomForest;
        for m in b.models.values_mut() {
            m.model = NativeModel::RandomForest(RandomForest {
                trees: g.trees.clone(),
                params: ForestParams { n_trees: 2, min_samples_split: 2 },
            });
        }
        let bytes = b.to_bin_bytes().expect("encode");
        let back = PredictorBundle::from_bin_bytes(&bytes).expect("decode");
        assert_eq!(b.to_json().to_string(), back.to_json().to_string());
    }

    #[test]
    fn workload_bundles_use_version_2_and_roundtrip() {
        // Isolated bundles keep the version-1 byte (byte-stability of the
        // pre-workload encoding); workload-qualified ones flip it to 2 and
        // carry the spec losslessly.
        let iso = lasso_bundle();
        let iso_bytes = iso.to_bin_bytes().expect("encode isolated");
        assert_eq!(u32::from_le_bytes(iso_bytes[8..12].try_into().unwrap()), BIN_VERSION);

        let wl = std::sync::Arc::new(crate::workload::builtin_presets()[0].clone());
        let mut b = lasso_bundle();
        b.scenario = b.scenario.with_workload(wl.clone());
        let bytes = b.to_bin_bytes().expect("encode workload bundle");
        assert_eq!(
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()),
            BIN_VERSION_WORKLOAD
        );
        let back = PredictorBundle::from_bin_bytes(&bytes).expect("decode");
        assert_eq!(back.scenario.id, b.scenario.id);
        assert_eq!(back.scenario.workload.as_deref(), Some(&*wl));
        assert_eq!(b.to_json().to_string(), back.to_json().to_string());
        assert_eq!(bytes, back.to_bin_bytes().expect("re-encode"));

        // Non-canonical version/content pairings are rejected. Patching
        // the version byte alone must fail both ways.
        let mut v1_with_wl = bytes.clone();
        v1_with_wl[8..12].copy_from_slice(&BIN_VERSION.to_le_bytes());
        let err = PredictorBundle::from_bin_bytes(&v1_with_wl).unwrap_err();
        assert!(err.to_string().contains("workload"), "{err}");
        let mut v2_without = iso_bytes.clone();
        v2_without[8..12].copy_from_slice(&BIN_VERSION_WORKLOAD.to_le_bytes());
        let err = PredictorBundle::from_bin_bytes(&v2_without).unwrap_err();
        assert!(err.to_string().contains("workload"), "{err}");
    }

    #[test]
    fn every_truncation_errors_without_panicking() {
        let bytes = gbdt_bundle().to_bin_bytes().expect("encode");
        for n in 0..bytes.len() {
            assert!(
                PredictorBundle::from_bin_bytes(&bytes[..n]).is_err(),
                "decode of {n}-byte prefix should fail"
            );
        }
    }

    #[test]
    fn header_byte_flips_never_panic() {
        let bytes = lasso_bundle().to_bin_bytes().expect("encode");
        for i in 0..HEADER_LEN.min(bytes.len()) {
            for bit in [0x01u8, 0x80] {
                let mut m = bytes.clone();
                m[i] ^= bit;
                // Must not panic; most flips fail, a float-bit flip may
                // legally decode to a different finite value.
                let _ = PredictorBundle::from_bin_bytes(&m);
            }
        }
    }

    #[test]
    fn mlp_method_is_unsupported() {
        let mut b = lasso_bundle();
        b.method = Method::Mlp;
        let err = b.to_bin_bytes().unwrap_err();
        assert!(matches!(err, EngineError::Unsupported(_)), "{err}");
    }

    #[test]
    fn inspect_reports_layout_and_content() {
        let b = gbdt_bundle();
        let bytes = b.to_bin_bytes().expect("encode");
        let j = inspect_bin(&bytes).expect("inspect");
        assert_eq!(j.req_str("method").unwrap(), "GBDT");
        assert_eq!(j.req_str("mode").unwrap(), "nofusion");
        assert_eq!(j.req_usize("n_models").unwrap(), 1);
        assert_eq!(j.req_usize("total_bytes").unwrap(), bytes.len());
    }
}
