//! The versioned on-disk format for a trained predictor: scenario id,
//! method, deduction mode, `T_overhead`/fallback metadata, the bucket
//! intern table (`plan::BucketInterner` names in id order — models load
//! by name and re-intern against the reading build's table; the
//! serialized table lets the loader reject symbols that no longer
//! resolve), and every per-bucket model (standardizer + Lasso/RF/GBDT weights)
//! serialized via `util::json`. All floats round-trip bit-exactly
//! (shortest-repr emit + exact parse), so a loaded bundle reproduces the
//! in-memory predictor's outputs bit-identically.

use crate::engine::EngineError;
use crate::framework::{DeductionMode, ScenarioPredictor};
use crate::predict::{BucketModel, Method, TrainedModel};
use crate::profiler::ModelProfile;
use crate::scenario::Scenario;
use crate::util::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Identifies a predictor-bundle JSON document.
pub const BUNDLE_FORMAT: &str = "edgelat.predictor_bundle";
/// Schema version this build writes and reads. v2 added the `interner`
/// bucket symbol table (v1 bundles predate the plan IR and are rejected;
/// retrain with `edgelat train`).
pub const BUNDLE_VERSION: u64 = 2;

/// A serialized trained predictor for one (scenario, method, mode).
#[derive(Clone)]
pub struct PredictorBundle {
    pub scenario_id: String,
    pub method: Method,
    pub mode: DeductionMode,
    /// Estimated framework overhead (mean end-to-end minus op-sum gap).
    pub t_overhead_ms: f64,
    /// Global mean op latency, used for buckets unseen during training.
    pub fallback_ms: f64,
    pub models: BTreeMap<String, BucketModel>,
}

impl PredictorBundle {
    /// Train a bundle from profiles with one of the native methods. The
    /// convenience path behind `edgelat train`.
    pub fn train(
        sc: &Scenario,
        profiles: &[ModelProfile],
        method: Method,
        mode: DeductionMode,
        seed: u64,
    ) -> Result<PredictorBundle, EngineError> {
        if method == Method::Mlp {
            return Err(EngineError::Unsupported(
                "bundles hold the native methods (lasso|rf|gbdt); the MLP stays \
                 engine-external (PJRT handles are not serializable)"
                    .into(),
            ));
        }
        let pred = ScenarioPredictor::train_from(sc, profiles, method, mode, seed, None);
        PredictorBundle::from_predictor(&pred)
    }

    /// Extract the owned models from a trained predictor. Fails for MLP
    /// predictors, whose models are engine-external.
    pub fn from_predictor(pred: &ScenarioPredictor<'_>) -> Result<PredictorBundle, EngineError> {
        let mut models = BTreeMap::new();
        for (bucket, m) in pred.models() {
            let owned = m.as_owned().ok_or_else(|| {
                EngineError::Unsupported(format!(
                    "bucket '{bucket}' uses a non-serializable model (MLP); only \
                     Lasso/RF/GBDT predictors can be bundled"
                ))
            })?;
            models.insert(bucket.to_string(), owned.clone());
        }
        Ok(PredictorBundle {
            scenario_id: pred.scenario.id.clone(),
            method: pred.method,
            mode: pred.mode,
            t_overhead_ms: pred.t_overhead_ms,
            fallback_ms: pred.fallback_ms,
            models,
        })
    }

    /// Reassemble a full `ScenarioPredictor` (owned models, `'static`) by
    /// resolving the scenario id against this build's scenario table.
    /// `to_`: an expensive borrowed→owned conversion (the models clone).
    pub fn to_predictor(&self) -> Result<ScenarioPredictor<'static>, EngineError> {
        let scenario = crate::scenario::by_id(&self.scenario_id)
            .ok_or_else(|| EngineError::UnknownScenario(self.scenario_id.clone()))?;
        // Validate bucket symbols up front (fields are pub, so a bundle
        // need not have come through `from_json`): an unresolvable name is
        // an error here, the same as in `EngineBuilder::build`, not a
        // panic inside the dense-table interning.
        for b in self.models.keys() {
            crate::engine::resolve_bundle_bucket(&self.scenario_id, b)?;
        }
        let models: BTreeMap<String, TrainedModel<'static>> = self
            .models
            .iter()
            .map(|(b, m)| (b.clone(), TrainedModel::Owned(m.clone())))
            .collect();
        Ok(ScenarioPredictor::from_parts(
            scenario,
            self.method,
            self.mode,
            models,
            self.t_overhead_ms,
            self.fallback_ms,
        ))
    }

    /// Feature-vector width per bucket — metadata derived from the trained
    /// standardizers (shares its source of truth with `features::*_DIM`).
    pub fn feature_dims(&self) -> BTreeMap<String, usize> {
        self.models.iter().map(|(b, m)| (b.clone(), m.feature_dim())).collect()
    }

    pub fn to_json(&self) -> Json {
        let mut buckets = BTreeMap::new();
        for (b, m) in &self.models {
            buckets.insert(b.clone(), m.to_json());
        }
        // The intern table, names in BucketId order: the id ↔ name mapping
        // every model key resolves through on load.
        let interner = crate::plan::interner().names().iter().map(|&n| Json::str(n)).collect();
        Json::obj(vec![
            ("format", Json::str(BUNDLE_FORMAT)),
            ("version", Json::Num(BUNDLE_VERSION as f64)),
            ("scenario", Json::str(self.scenario_id.clone())),
            ("method", Json::str(self.method.name())),
            ("mode", Json::str(self.mode.name())),
            ("t_overhead_ms", Json::Num(self.t_overhead_ms)),
            ("fallback_ms", Json::Num(self.fallback_ms)),
            ("interner", Json::Arr(interner)),
            ("buckets", Json::Obj(buckets)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<PredictorBundle, String> {
        let format = j.req_str("format")?;
        if format != BUNDLE_FORMAT {
            return Err(format!(
                "not a predictor bundle (format '{format}', expected '{BUNDLE_FORMAT}')"
            ));
        }
        let version = j.req_f64("version")? as u64;
        if version != BUNDLE_VERSION {
            return Err(format!(
                "unsupported bundle version {version} (this build reads version {BUNDLE_VERSION})"
            ));
        }
        let scenario_id = j.req_str("scenario")?.to_string();
        let method_name = j.req_str("method")?;
        let method = Method::parse(method_name)
            .ok_or_else(|| format!("unknown method '{method_name}'"))?;
        let mode_name = j.req_str("mode")?;
        let mode = DeductionMode::parse(mode_name)
            .ok_or_else(|| format!("unknown deduction mode '{mode_name}'"))?;
        let t_overhead_ms = j.req_f64("t_overhead_ms")?;
        let fallback_ms = j.req_f64("fallback_ms")?;
        if !t_overhead_ms.is_finite() || !fallback_ms.is_finite() {
            return Err("non-finite t_overhead_ms/fallback_ms".into());
        }
        // The serialized bucket symbol table: every model key must appear
        // in it AND resolve in this build's interner. Models re-intern by
        // name, so a bundle from a diverged build fails loudly here
        // instead of silently mapping models onto the wrong buckets.
        let Json::Arr(tbl) = j.req("interner")? else {
            return Err("'interner' is not an array".into());
        };
        let mut table = Vec::with_capacity(tbl.len());
        for (i, n) in tbl.iter().enumerate() {
            let name = n.as_str().ok_or_else(|| format!("interner[{i}] is not a string"))?;
            table.push(name.to_string());
        }
        let Json::Obj(bmap) = j.req("buckets")? else {
            return Err("'buckets' is not an object".into());
        };
        let mut models = BTreeMap::new();
        for (b, mj) in bmap {
            if !table.iter().any(|n| n == b) {
                return Err(format!("bucket '{b}' missing from the bundle's intern table"));
            }
            crate::engine::resolve_bundle_bucket(&scenario_id, b).map_err(|e| e.to_string())?;
            let m = BucketModel::from_json(mj).map_err(|e| format!("bucket '{b}': {e}"))?;
            if m.model.method() != method {
                return Err(format!(
                    "bucket '{b}' holds a {} model but the bundle method is {}",
                    m.model.method().name(),
                    method.name()
                ));
            }
            models.insert(b.clone(), m);
        }
        if models.is_empty() {
            return Err("bundle has no bucket models".into());
        }
        Ok(PredictorBundle { scenario_id, method, mode, t_overhead_ms, fallback_ms, models })
    }

    /// Write the bundle as compact JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), EngineError> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| EngineError::Io(format!("writing {}: {e}", path.display())))
    }

    /// Load and validate a bundle file.
    pub fn load(path: impl AsRef<Path>) -> Result<PredictorBundle, EngineError> {
        let path = path.as_ref();
        let s = std::fs::read_to_string(path)
            .map_err(|e| EngineError::Io(format!("reading {}: {e}", path.display())))?;
        let j = Json::parse(&s)
            .map_err(|e| EngineError::Parse(format!("{}: {e}", path.display())))?;
        PredictorBundle::from_json(&j)
            .map_err(|e| EngineError::Parse(format!("{}: {e}", path.display())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_json_requires_format_and_version() {
        let err = PredictorBundle::from_json(&Json::parse("{}").unwrap()).unwrap_err();
        assert!(err.contains("format"), "{err}");
        let j = Json::obj(vec![("format", Json::str("something.else"))]);
        let err = PredictorBundle::from_json(&j).unwrap_err();
        assert!(err.contains("not a predictor bundle"), "{err}");
    }
}
