//! The versioned on-disk format for a trained predictor: the **full
//! scenario descriptor** (embedded SoC spec + target — a v3 bundle is
//! self-describing and loads on builds that have never seen its device),
//! method, deduction mode, `T_overhead`/fallback metadata, the bucket
//! intern table (`plan::BucketInterner` names in id order — models load
//! by name and re-intern against the reading build's table; the
//! serialized table lets the loader reject symbols that no longer
//! resolve), and every per-bucket model (standardizer + Lasso/RF/GBDT weights)
//! serialized via `util::json`. All floats round-trip bit-exactly
//! (shortest-repr emit + exact parse), so a loaded bundle reproduces the
//! in-memory predictor's outputs bit-identically.

use crate::device::{soc_from_json, soc_to_json, validate_soc, CoreCombo, DataRep, Soc, Target};
use crate::engine::EngineError;
use crate::framework::{DeductionMode, ScenarioPredictor};
use crate::predict::{BucketModel, Method, TrainedModel};
use crate::profiler::ModelProfile;
use crate::scenario::{Registry, Scenario};
use crate::tflite::CompileOptions;
use crate::util::Json;
use crate::workload::WorkloadSpec;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

/// Identifies a predictor-bundle JSON document.
pub const BUNDLE_FORMAT: &str = "edgelat.predictor_bundle";
/// Schema version this build writes. v4 adds the optional `workload`
/// descriptor — a bundle trained under a contention/batch regime carries
/// that regime with it (absent = isolated/batch-1, so every v3 bundle
/// upgrades losslessly). v3 embeds the full scenario descriptor
/// (`device` + `target`), so a bundle trained on a runtime-registered SoC
/// loads anywhere — no spec file, no registry needed at load time. (v2
/// added the `interner` symbol table; v1 bundles predate the plan IR and
/// are rejected; retrain with `edgelat train`.)
pub const BUNDLE_VERSION: u64 = 4;
/// Oldest version this build still reads: v2 bundles carry only a
/// scenario id, resolved against the builtin registry on load.
pub const BUNDLE_COMPAT_VERSION: u64 = 2;

/// A serialized trained predictor for one (scenario, method, mode).
#[derive(Clone)]
pub struct PredictorBundle {
    /// The full scenario (SoC + target), embedded in the v3 document.
    pub scenario: Scenario,
    pub method: Method,
    pub mode: DeductionMode,
    /// Estimated framework overhead (mean end-to-end minus op-sum gap).
    pub t_overhead_ms: f64,
    /// Global mean op latency, used for buckets unseen during training.
    pub fallback_ms: f64,
    pub models: BTreeMap<String, BucketModel>,
}

/// The target half of the scenario descriptor.
pub(crate) fn target_to_json(t: &Target) -> Json {
    match t {
        Target::Cpu { combo, rep } => Json::obj(vec![
            ("kind", Json::str("cpu")),
            (
                "counts",
                Json::Arr(combo.counts.iter().map(|&c| Json::num(c as f64)).collect()),
            ),
            ("rep", Json::str(rep.name())),
        ]),
        Target::Gpu { options } => Json::obj(vec![
            ("kind", Json::str("gpu")),
            ("fusion", Json::Bool(options.fusion)),
            ("winograd", Json::Bool(options.winograd)),
            ("grouped", Json::Bool(options.grouped)),
        ]),
    }
}

/// Rebuild a scenario from an embedded SoC, target descriptor, optional
/// workload, and stored id. Structural parsing only — semantic checks
/// (SoC ranges, combo realizability, id/workload consistency) live in one
/// place, [`validate_bundle_scenario`], which every loading path runs.
pub(crate) fn scenario_from_descriptor(
    soc: Soc,
    target: &Json,
    id: &str,
    workload: Option<Arc<WorkloadSpec>>,
) -> Result<Scenario, String> {
    let target = match target.req_str("kind")? {
        "cpu" => {
            let counts =
                target.req("counts")?.usize_arr().map_err(|e| format!("target counts{e}"))?;
            let rep_name = target.req_str("rep")?;
            let rep = DataRep::parse(rep_name)
                .ok_or_else(|| format!("unknown data representation '{rep_name}'"))?;
            Target::Cpu { combo: CoreCombo::new(counts), rep }
        }
        "gpu" => Target::Gpu {
            options: CompileOptions {
                fusion: target_bool(target, "fusion")?,
                winograd: target_bool(target, "winograd")?,
                grouped: target_bool(target, "grouped")?,
            },
        },
        other => return Err(format!("unknown target kind '{other}' (cpu|gpu)")),
    };
    Ok(Scenario { id: id.to_string(), soc, target, workload })
}

/// Parse the optional embedded workload descriptor (absent on v3 bundles
/// and on every isolated v4 bundle).
pub(crate) fn workload_from_descriptor(j: &Json) -> Result<Option<Arc<WorkloadSpec>>, String> {
    match j.get("workload") {
        Some(wj) => Ok(Some(Arc::new(
            WorkloadSpec::from_json(wj).map_err(|e| format!("workload: {e}"))?,
        ))),
        None => Ok(None),
    }
}

fn target_bool(target: &Json, key: &str) -> Result<bool, String> {
    match target.req(key)? {
        Json::Bool(b) => Ok(*b),
        _ => Err(format!("target '{key}' is not a boolean")),
    }
}

/// Validate a bundle's scenario the way the v3 loader validates an embedded
/// descriptor: SoC parameters in range and, for CPU targets, a combo the
/// clusters can realize. Bundle fields are `pub`, so a programmatically
/// assembled bundle need not have come through `from_json` — every loading
/// path ([`PredictorBundle::to_predictor`], `EngineBuilder::build`) checks
/// here first instead of letting a bad descriptor panic inside the cost
/// model (mirrors the bucket-symbol check just below).
pub(crate) fn validate_bundle_scenario(sc: &Scenario) -> Result<(), EngineError> {
    validate_soc(&sc.soc)
        .map_err(|e| EngineError::Parse(format!("bundle for '{}': {e}", sc.id)))?;
    // A workload-qualified bundle must carry a valid spec AND an id whose
    // `@WORKLOAD` suffix names it — the id is what the engine serves
    // under, so a mismatched suffix would serve one regime's cost model
    // under another's name. The base id then passes the same checks as an
    // isolated bundle's. ('@' is reserved in SoC and workload names, so
    // the suffix split is unambiguous.)
    let base_id = match &sc.workload {
        Some(wl) => {
            wl.validate()
                .map_err(|e| EngineError::Parse(format!("bundle for '{}': {e}", sc.id)))?;
            let suffix = format!("@{}", wl.name);
            sc.id.strip_suffix(suffix.as_str()).ok_or_else(|| {
                EngineError::Parse(format!(
                    "bundle scenario id '{}' does not end with its workload qualifier \
                     '{suffix}'",
                    sc.id
                ))
            })?
        }
        None => sc.id.as_str(),
    };
    match &sc.target {
        Target::Cpu { combo, rep } => {
            // Re-derive through the one id-owning constructor (validates
            // the combo too) — same rule as `scenario_from_descriptor`:
            // the id must agree with the device/target, or the engine
            // would serve one device's cost model under another's id.
            let derived = Scenario::cpu(&sc.soc, combo.counts.clone(), *rep)
                .map_err(|e| EngineError::Parse(format!("bundle for '{}': {e}", sc.id)))?;
            if base_id != derived.id {
                return Err(EngineError::Parse(format!(
                    "bundle scenario id '{}' disagrees with its device/target ('{}')",
                    sc.id, derived.id
                )));
            }
        }
        Target::Gpu { .. } => {
            // "{soc}/gpu" exactly, or "{soc}/gpu/<ablation>" — nothing
            // else ("{soc}/gpux" is a tampered id, not an ablation).
            let prefix = format!("{}/gpu", sc.soc.name);
            let tail = base_id.strip_prefix(&prefix);
            if !matches!(tail, Some(t) if t.is_empty() || t.starts_with('/')) {
                return Err(EngineError::Parse(format!(
                    "bundle scenario id '{}' does not match its device '{}'",
                    sc.id, sc.soc.name
                )));
            }
        }
    }
    Ok(())
}

impl PredictorBundle {
    /// Train a bundle from profiles with one of the native methods. The
    /// convenience path behind `edgelat train`.
    pub fn train(
        sc: &Scenario,
        profiles: &[ModelProfile],
        method: Method,
        mode: DeductionMode,
        seed: u64,
    ) -> Result<PredictorBundle, EngineError> {
        if method == Method::Mlp {
            return Err(EngineError::Unsupported(
                "bundles hold the native methods (lasso|rf|gbdt); the MLP stays \
                 engine-external (PJRT handles are not serializable)"
                    .into(),
            ));
        }
        let pred = ScenarioPredictor::train_from(sc, profiles, method, mode, seed, None);
        PredictorBundle::from_predictor(&pred)
    }

    /// Extract the owned models from a trained predictor. Fails for MLP
    /// predictors, whose models are engine-external.
    pub fn from_predictor(pred: &ScenarioPredictor<'_>) -> Result<PredictorBundle, EngineError> {
        let mut models = BTreeMap::new();
        for (bucket, m) in pred.models() {
            let owned = m.as_owned().ok_or_else(|| {
                EngineError::Unsupported(format!(
                    "bucket '{bucket}' uses a non-serializable model (MLP); only \
                     Lasso/RF/GBDT predictors can be bundled"
                ))
            })?;
            models.insert(bucket.to_string(), owned.clone());
        }
        Ok(PredictorBundle {
            scenario: pred.scenario.clone(),
            method: pred.method,
            mode: pred.mode,
            t_overhead_ms: pred.t_overhead_ms,
            fallback_ms: pred.fallback_ms,
            models,
        })
    }

    /// The scenario id this bundle serves.
    pub fn scenario_id(&self) -> &str {
        &self.scenario.id
    }

    /// Reassemble a full `ScenarioPredictor` (owned models, `'static`) from
    /// the embedded scenario descriptor — no registry or spec file needed.
    /// `to_`: an expensive borrowed→owned conversion (the models clone).
    pub fn to_predictor(&self) -> Result<ScenarioPredictor<'static>, EngineError> {
        // Validate the scenario and bucket symbols up front (fields are
        // pub, so a bundle need not have come through `from_json`): an
        // invalid descriptor or unresolvable name is an error here, the
        // same as in `EngineBuilder::build`, not a panic inside the cost
        // model or the dense-table interning.
        validate_bundle_scenario(&self.scenario)?;
        for b in self.models.keys() {
            crate::engine::resolve_bundle_bucket(&self.scenario.id, b)?;
        }
        let models: BTreeMap<String, TrainedModel<'static>> = self
            .models
            .iter()
            .map(|(b, m)| (b.clone(), TrainedModel::Owned(m.clone())))
            .collect();
        Ok(ScenarioPredictor::from_parts(
            self.scenario.clone(),
            self.method,
            self.mode,
            models,
            self.t_overhead_ms,
            self.fallback_ms,
        ))
    }

    /// Feature-vector width per bucket — metadata derived from the trained
    /// standardizers (shares its source of truth with `features::*_DIM`).
    pub fn feature_dims(&self) -> BTreeMap<String, usize> {
        self.models.iter().map(|(b, m)| (b.clone(), m.feature_dim())).collect()
    }

    pub fn to_json(&self) -> Json {
        let mut buckets = BTreeMap::new();
        for (b, m) in &self.models {
            buckets.insert(b.clone(), m.to_json());
        }
        // The intern table, names in BucketId order: the id ↔ name mapping
        // every model key resolves through on load.
        let interner = crate::plan::interner().names().iter().map(|&n| Json::str(n)).collect();
        let mut fields = vec![
            ("format", Json::str(BUNDLE_FORMAT)),
            ("version", Json::Num(BUNDLE_VERSION as f64)),
            ("scenario", Json::str(self.scenario.id.clone())),
            // The self-describing device descriptor: the spec-shaped SoC
            // block plus the concrete target — what makes the bundle load
            // on a build/process that never registered this device.
            ("device", soc_to_json(&self.scenario.soc)),
            ("target", target_to_json(&self.scenario.target)),
            ("method", Json::str(self.method.name())),
            ("mode", Json::str(self.mode.name())),
            ("t_overhead_ms", Json::Num(self.t_overhead_ms)),
            ("fallback_ms", Json::Num(self.fallback_ms)),
            ("interner", Json::Arr(interner)),
            ("buckets", Json::Obj(buckets)),
        ];
        // The contention/batch regime, only when there is one — isolated
        // bundles keep the v3 field set (plus the version bump).
        if let Some(wl) = &self.scenario.workload {
            fields.push(("workload", wl.to_json()));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<PredictorBundle, String> {
        let format = j.req_str("format")?;
        if format != BUNDLE_FORMAT {
            return Err(format!(
                "not a predictor bundle (format '{format}', expected '{BUNDLE_FORMAT}')"
            ));
        }
        let version = j.req_usize("version")? as u64;
        if !(BUNDLE_COMPAT_VERSION..=BUNDLE_VERSION).contains(&version) {
            return Err(format!(
                "unsupported bundle version {version} (this build reads versions \
                 {BUNDLE_COMPAT_VERSION}..={BUNDLE_VERSION})"
            ));
        }
        let scenario_id = j.req_str("scenario")?.to_string();
        let scenario = if version >= 3 {
            // Self-describing: rebuild the scenario from the embedded
            // descriptor, then run the one shared semantic check (SoC
            // ranges like a spec file, combo realizability, id/workload
            // consistency).
            let soc = soc_from_json(j.req("device")?).map_err(|e| format!("device: {e}"))?;
            let workload = workload_from_descriptor(j)?;
            let sc = scenario_from_descriptor(soc, j.req("target")?, &scenario_id, workload)?;
            validate_bundle_scenario(&sc).map_err(|e| e.to_string())?;
            sc
        } else {
            // v2: id only — resolve against the builtin registry.
            Registry::builtin()
                .by_id(&scenario_id)
                .map(|s| (*s).clone())
                .ok_or_else(|| {
                    format!(
                        "v2 bundle is for scenario '{scenario_id}', which is not in the builtin \
                         registry; re-save it (or retrain) to get a v3 bundle that embeds its \
                         device descriptor"
                    )
                })?
        };
        let method_name = j.req_str("method")?;
        let method = Method::parse(method_name)
            .ok_or_else(|| format!("unknown method '{method_name}'"))?;
        let mode_name = j.req_str("mode")?;
        let mode = DeductionMode::parse(mode_name)
            .ok_or_else(|| format!("unknown deduction mode '{mode_name}'"))?;
        let t_overhead_ms = j.req_f64("t_overhead_ms")?;
        let fallback_ms = j.req_f64("fallback_ms")?;
        if !t_overhead_ms.is_finite() || !fallback_ms.is_finite() {
            return Err("non-finite t_overhead_ms/fallback_ms".into());
        }
        // The serialized bucket symbol table: every model key must appear
        // in it AND resolve in this build's interner. Models re-intern by
        // name, so a bundle from a diverged build fails loudly here
        // instead of silently mapping models onto the wrong buckets.
        let Json::Arr(tbl) = j.req("interner")? else {
            return Err("'interner' is not an array".into());
        };
        let mut table = Vec::with_capacity(tbl.len());
        for (i, n) in tbl.iter().enumerate() {
            let name = n.as_str().ok_or_else(|| format!("interner[{i}] is not a string"))?;
            table.push(name.to_string());
        }
        let Json::Obj(bmap) = j.req("buckets")? else {
            return Err("'buckets' is not an object".into());
        };
        let mut models = BTreeMap::new();
        for (b, mj) in bmap {
            if !table.iter().any(|n| n == b) {
                return Err(format!("bucket '{b}' missing from the bundle's intern table"));
            }
            crate::engine::resolve_bundle_bucket(&scenario_id, b).map_err(|e| e.to_string())?;
            let m = BucketModel::from_json(mj).map_err(|e| format!("bucket '{b}': {e}"))?;
            if m.model.method() != method {
                return Err(format!(
                    "bucket '{b}' holds a {} model but the bundle method is {}",
                    m.model.method().name(),
                    method.name()
                ));
            }
            models.insert(b.clone(), m);
        }
        if models.is_empty() {
            return Err("bundle has no bucket models".into());
        }
        Ok(PredictorBundle { scenario, method, mode, t_overhead_ms, fallback_ms, models })
    }

    /// Write the bundle as compact JSON. I/O errors name the path.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), EngineError> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| EngineError::Io(format!("writing {}: {e}", path.display())))
    }

    /// Load and validate a bundle file. I/O and parse errors name the path.
    pub fn load(path: impl AsRef<Path>) -> Result<PredictorBundle, EngineError> {
        let path = path.as_ref();
        let s = std::fs::read_to_string(path)
            .map_err(|e| EngineError::Io(format!("reading {}: {e}", path.display())))?;
        let j = Json::parse(&s)
            .map_err(|e| EngineError::Parse(format!("{}: {e}", path.display())))?;
        PredictorBundle::from_json(&j)
            .map_err(|e| EngineError::Parse(format!("{}: {e}", path.display())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_json_requires_format_and_version() {
        let err = PredictorBundle::from_json(&Json::parse("{}").unwrap()).unwrap_err();
        assert!(err.contains("format"), "{err}");
        let j = Json::obj(vec![("format", Json::str("something.else"))]);
        let err = PredictorBundle::from_json(&j).unwrap_err();
        assert!(err.contains("not a predictor bundle"), "{err}");
    }

    #[test]
    fn target_descriptor_roundtrips() {
        for sc in [
            crate::scenario::one_large_core("Exynos9820").unwrap(),
            Scenario::gpu(&crate::device::soc_by_name("HelioP35").unwrap()),
        ] {
            let t = target_to_json(&sc.target);
            let back = scenario_from_descriptor(sc.soc.clone(), &t, &sc.id, None).unwrap();
            assert_eq!(back, sc);
            validate_bundle_scenario(&back).expect("round-tripped scenario validates");
        }
        // A tampered id is rejected for CPU targets (the id is derivable).
        let sc = crate::scenario::one_large_core("Exynos9820").unwrap();
        let t = target_to_json(&sc.target);
        let back =
            scenario_from_descriptor(sc.soc.clone(), &t, "Exynos9820/cpu/2M/fp32", None).unwrap();
        let err = validate_bundle_scenario(&back).unwrap_err();
        assert!(err.to_string().contains("disagrees"), "{err}");
        // A GPU id must belong to the embedded device: exactly "{soc}/gpu"
        // or an ablation suffix after '/', never a sibling like "gpux".
        let g = Scenario::gpu(&sc.soc);
        let t = target_to_json(&g.target);
        for bad in ["OtherSoc/gpu", "Exynos9820/gpux", "Exynos9820/gp"] {
            let back = scenario_from_descriptor(sc.soc.clone(), &t, bad, None).unwrap();
            let err = validate_bundle_scenario(&back).unwrap_err();
            assert!(err.to_string().contains("does not match"), "{bad}: {err}");
        }
        for good in ["Exynos9820/gpu", "Exynos9820/gpu/nofusion"] {
            let back = scenario_from_descriptor(sc.soc.clone(), &t, good, None).unwrap();
            validate_bundle_scenario(&back).unwrap_or_else(|e| panic!("{good}: {e}"));
        }
    }

    #[test]
    fn workload_qualified_descriptor_roundtrips_and_validates() {
        let base = crate::scenario::one_large_core("Exynos9820").unwrap();
        let wl = Arc::new(crate::workload::builtin_presets()[0].clone());
        let sc = base.with_workload(wl.clone());
        let t = target_to_json(&sc.target);
        let back =
            scenario_from_descriptor(sc.soc.clone(), &t, &sc.id, Some(wl.clone())).unwrap();
        assert_eq!(back, sc);
        validate_bundle_scenario(&back).expect("workload-qualified scenario validates");
        // A workload without its id suffix (or with the wrong one) is a
        // regime/id mismatch, not a servable bundle.
        for bad in [base.id.clone(), format!("{}@other", base.id)] {
            let back =
                scenario_from_descriptor(sc.soc.clone(), &t, &bad, Some(wl.clone())).unwrap();
            let err = validate_bundle_scenario(&back).unwrap_err();
            assert!(err.to_string().contains("workload qualifier"), "{bad}: {err}");
        }
        // A suffix with no workload attached fails the base checks.
        let back = scenario_from_descriptor(sc.soc.clone(), &t, &sc.id, None).unwrap();
        assert!(validate_bundle_scenario(&back).is_err());
        // An invalid embedded spec is rejected before any id logic.
        let broken = Arc::new(crate::workload::WorkloadSpec { batch: 3, ..(*wl).clone() });
        let back =
            scenario_from_descriptor(sc.soc.clone(), &t, &sc.id, Some(broken)).unwrap();
        let err = validate_bundle_scenario(&back).unwrap_err();
        assert!(err.to_string().contains("power of two"), "{err}");
    }
}
