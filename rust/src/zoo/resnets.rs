//! The residual family: ResNet (incl. reduced-depth/width variants used on
//! mobile), PreResNet, SE-ResNet/SE-PreResNet, ResNeXt, RegNetX (grouped
//! convolutions), DiracNetV2 (residual-free) and BagNet (small receptive
//! fields). ResNet16 here is the network whose three convolutions appear in
//! Table 2 of the paper (Winograd applicability).

use crate::graph::{Graph, GraphBuilder, Padding};
use crate::zoo::mobilenets::scale_c;

/// Stage plan per imgclsmob-style reduced ResNets.
fn resnet_stages(depth: usize) -> (Vec<usize>, bool) {
    // (blocks per stage, bottleneck?)
    match depth {
        10 => (vec![1, 1, 1, 1], false),
        12 => (vec![2, 1, 1, 1], false),
        14 => (vec![2, 2, 1, 1], false),
        16 => (vec![2, 2, 2, 1], false),
        18 => (vec![2, 2, 2, 2], false),
        26 => (vec![2, 2, 2, 2], true),
        34 => (vec![3, 4, 6, 3], false),
        50 => (vec![3, 4, 6, 3], true),
        other => panic!("unsupported resnet depth {other}"),
    }
}

/// ResNet [23] with optional width scale (the paper's mobile study includes
/// width-scaled variants, e.g. ResNet18 at 0.25).
pub fn resnet(depth: usize, width: f64) -> Graph {
    let name = if (width - 1.0).abs() < 1e-9 {
        format!("resnet{depth}")
    } else {
        format!("resnet{depth}_wd{}", (width * 100.0) as usize)
    };
    let (stages, bottleneck) = resnet_stages(depth);
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, scale_c(64, width), 7, 2, Padding::Same);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    let base = [64usize, 128, 256, 512];
    for (si, &n) in stages.iter().enumerate() {
        let c = scale_c(base[si], width);
        for i in 0..n {
            let stride = if si > 0 && i == 0 { 2 } else { 1 };
            t = if bottleneck {
                b.res_bottleneck(t, c, c * 4, stride, 1, false)
            } else {
                b.res_basic(t, c, stride)
            };
        }
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// PreResNet [24]: pre-activation ordering — activation precedes each conv.
pub fn preresnet(depth: usize) -> Graph {
    let (stages, bottleneck) = resnet_stages(depth);
    let mut b = GraphBuilder::new(&format!("preresnet{depth}"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 64, 7, 2, Padding::Same);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    let base = [64usize, 128, 256, 512];
    for (si, &n) in stages.iter().enumerate() {
        let c = base[si];
        for i in 0..n {
            let stride = if si > 0 && i == 0 { 2 } else { 1 };
            t = preres_block(&mut b, t, c, stride, bottleneck);
        }
    }
    t = b.relu(t);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

fn preres_block(b: &mut GraphBuilder, x: usize, c: usize, stride: usize, bottleneck: bool) -> usize {
    let in_c = b.shape(x).c;
    let out_c = if bottleneck { c * 4 } else { c };
    let pre = b.relu(x);
    let t = if bottleneck {
        let t = b.conv(pre, c, 1, 1, Padding::Same);
        let t = b.relu(t);
        let t = b.conv(t, c, 3, stride, Padding::Same);
        let t = b.relu(t);
        b.conv(t, out_c, 1, 1, Padding::Same)
    } else {
        let t = b.conv(pre, c, 3, stride, Padding::Same);
        let t = b.relu(t);
        b.conv(t, c, 3, 1, Padding::Same)
    };
    let short = if stride != 1 || in_c != out_c {
        b.conv(pre, out_c, 1, stride, Padding::Same)
    } else {
        x
    };
    b.add_t(t, short)
}

/// SE-ResNet [27].
pub fn se_resnet(depth: usize) -> Graph {
    let (stages, bottleneck) = resnet_stages(depth);
    let mut b = GraphBuilder::new(&format!("seresnet{depth}"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 64, 7, 2, Padding::Same);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    let base = [64usize, 128, 256, 512];
    for (si, &n) in stages.iter().enumerate() {
        let c = base[si];
        for i in 0..n {
            let stride = if si > 0 && i == 0 { 2 } else { 1 };
            t = if bottleneck {
                b.res_bottleneck(t, c, c * 4, stride, 1, true)
            } else {
                // basic block + SE before the residual add
                let in_c = b.shape(t).c;
                let y = b.conv(t, c, 3, stride, Padding::Same);
                let y = b.relu(y);
                let y = b.conv(y, c, 3, 1, Padding::Same);
                let y = b.se_block(y, 16);
                let short = if stride != 1 || in_c != c {
                    b.conv(t, c, 1, stride, Padding::Same)
                } else {
                    t
                };
                let y = b.add_t(y, short);
                b.relu(y)
            };
        }
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// SE-PreResNet [27].
pub fn se_preresnet(depth: usize) -> Graph {
    let g = preresnet(depth);
    // Rebuild with SE: simplest faithful approach is a dedicated builder.
    let (stages, bottleneck) = resnet_stages(depth);
    let mut b = GraphBuilder::new(&format!("sepreresnet{depth}"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 64, 7, 2, Padding::Same);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    let base = [64usize, 128, 256, 512];
    for (si, &n) in stages.iter().enumerate() {
        for i in 0..n {
            let stride = if si > 0 && i == 0 { 2 } else { 1 };
            let pre_out = preres_block(&mut b, t, base[si], stride, bottleneck);
            t = b.se_block(pre_out, 16);
        }
    }
    t = b.relu(t);
    let out = b.head(t, 1000);
    drop(g);
    b.finish(vec![out])
}

/// ResNeXt [58]: bottlenecks with 32-way grouped 3x3 convolutions.
pub fn resnext(depth: usize) -> Graph {
    let stages: Vec<usize> = match depth {
        26 => vec![2, 2, 2, 2],
        38 => vec![3, 3, 3, 3],
        other => panic!("unsupported resnext depth {other}"),
    };
    let mut b = GraphBuilder::new(&format!("resnext{depth}_32x4d"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 64, 7, 2, Padding::Same);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    let base = [128usize, 256, 512, 1024];
    for (si, &n) in stages.iter().enumerate() {
        for i in 0..n {
            let stride = if si > 0 && i == 0 { 2 } else { 1 };
            t = b.res_bottleneck(t, base[si], base[si] * 2, stride, 32, false);
        }
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// RegNetX [45]: stages of bottleneck blocks with fixed group width.
pub fn regnetx(variant: &str) -> Graph {
    // (stage widths, stage depths, group width) from the RegNetX design space.
    let (widths, depths, gw): (Vec<usize>, Vec<usize>, usize) = match variant {
        "002" => (vec![24, 56, 152, 368], vec![1, 1, 4, 7], 8),
        "004" => (vec![32, 64, 160, 384], vec![1, 2, 7, 12], 16),
        "006" => (vec![48, 96, 240, 528], vec![1, 3, 5, 7], 24),
        "008" => (vec![64, 128, 288, 672], vec![1, 3, 7, 5], 16),
        "016" => (vec![72, 168, 408, 912], vec![2, 4, 10, 2], 24),
        "032" => (vec![96, 192, 432, 1008], vec![2, 6, 15, 2], 48),
        other => panic!("unsupported regnetx variant {other}"),
    };
    let mut b = GraphBuilder::new(&format!("regnetx{variant}"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 32, 3, 2, Padding::Same);
    t = b.relu(t);
    for (si, (&w, &d)) in widths.iter().zip(&depths).enumerate() {
        for i in 0..d {
            let stride = if i == 0 { 2 } else { 1 };
            let groups = (w / gw).max(1);
            t = regnet_block(&mut b, t, w, stride, groups);
            let _ = si;
        }
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

fn regnet_block(b: &mut GraphBuilder, x: usize, w: usize, stride: usize, groups: usize) -> usize {
    let in_c = b.shape(x).c;
    let t = b.conv(x, w, 1, 1, Padding::Same);
    let t = b.relu(t);
    let t = if groups > 1 {
        b.grouped_conv(t, w, 3, stride, groups)
    } else {
        b.conv(t, w, 3, stride, Padding::Same)
    };
    let t = b.relu(t);
    let t = b.conv(t, w, 1, 1, Padding::Same);
    let short = if stride != 1 || in_c != w {
        b.conv(x, w, 1, stride, Padding::Same)
    } else {
        x
    };
    let t = b.add_t(t, short);
    b.relu(t)
}

/// DiracNetV2 [61]: plain (residual-free) deep conv stacks.
pub fn diracnet_v2(depth: usize) -> Graph {
    let stages: Vec<usize> = match depth {
        18 => vec![4, 4, 4, 4],
        34 => vec![6, 8, 12, 6],
        other => panic!("unsupported diracnet depth {other}"),
    };
    let mut b = GraphBuilder::new(&format!("diracnet{depth}v2"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 64, 7, 2, Padding::Same);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    let base = [64usize, 128, 256, 512];
    for (si, &n) in stages.iter().enumerate() {
        for _ in 0..n {
            t = b.conv(t, base[si], 3, 1, Padding::Same);
            t = b.relu(t);
        }
        if si < 3 {
            t = b.max_pool(t, 2, 2);
        }
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// BagNet [5]: ResNet50-style bottlenecks where most 3x3s are 1x1s and
/// convolutions use VALID padding, limiting the receptive field.
pub fn bagnet(rf: usize) -> Graph {
    // rf in {9, 17}: number of stages that get a real 3x3.
    let threes = match rf {
        9 => 2,
        17 => 3,
        other => panic!("unsupported bagnet rf {other}"),
    };
    let mut b = GraphBuilder::new(&format!("bagnet{rf}"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 64, 1, 1, Padding::Same);
    t = b.conv(t, 64, 3, 2, Padding::Valid);
    t = b.relu(t);
    let stages = [3usize, 4, 6, 3];
    let base = [64usize, 128, 256, 512];
    for (si, &n) in stages.iter().enumerate() {
        for i in 0..n {
            let stride = if si > 0 && i == 0 { 2 } else { 1 };
            let k = if i == 0 && si < threes { 3 } else { 1 };
            t = bagnet_block(&mut b, t, base[si], stride, k);
        }
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

fn bagnet_block(b: &mut GraphBuilder, x: usize, c: usize, stride: usize, k: usize) -> usize {
    let in_c = b.shape(x).c;
    let out_c = c * 4;
    let t = b.conv(x, c, 1, 1, Padding::Same);
    let t = b.relu(t);
    let t = b.conv(t, c, k, stride, Padding::Same);
    let t = b.relu(t);
    let t = b.conv(t, out_c, 1, 1, Padding::Same);
    let short = if stride != 1 || in_c != out_c {
        b.conv(x, out_c, 1, stride, Padding::Same)
    } else {
        x
    };
    let t = b.add_t(t, short);
    b.relu(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Op, OpType};

    #[test]
    fn resnet18_structure() {
        let g = resnet(18, 1.0);
        g.validate().unwrap();
        // 11.7M params canonical
        let p = g.params();
        assert!((10_000_000..13_500_000).contains(&p), "params={p}");
    }

    #[test]
    fn resnet16_has_table2_convs() {
        // Table 2 of the paper: ResNet16 contains 3x3/stride-1/group-1 convs
        // with (in=64,out=64,out_h=56), (128,128,28), (256,256,14).
        let g = resnet(16, 1.0);
        let mut found = [false; 3];
        for n in &g.nodes {
            if let Op::Conv2D { kh: 3, kw: 3, stride: 1, groups: 1, out_c, .. } = n.op {
                let i = g.shape(n.inputs[0]);
                let o = g.shape(n.outputs[0]);
                if i.c == 64 && out_c == 64 && o.h == 56 {
                    found[0] = true;
                }
                if i.c == 128 && out_c == 128 && o.h == 28 {
                    found[1] = true;
                }
                if i.c == 256 && out_c == 256 && o.h == 14 {
                    found[2] = true;
                }
            }
        }
        assert_eq!(found, [true; 3], "ResNet16 missing Table 2 convolutions");
    }

    #[test]
    fn width_scaling_reduces_params() {
        assert!(resnet(18, 0.25).params() < resnet(18, 1.0).params() / 8);
    }

    #[test]
    fn resnext_uses_grouped_convs() {
        let g = resnext(26);
        g.validate().unwrap();
        assert!(g.op_type_histogram()[&OpType::GroupedConv2D] >= 8);
    }

    #[test]
    fn regnetx_group_widths() {
        let g = regnetx("004");
        g.validate().unwrap();
        let grouped = g
            .nodes
            .iter()
            .filter_map(|n| match n.op {
                Op::Conv2D { groups, .. } if groups > 1 => Some(groups),
                _ => None,
            })
            .count();
        assert!(grouped >= 10, "regnetx004 should be dominated by grouped convs");
    }

    #[test]
    fn se_variants_have_sigmoid() {
        for g in [se_resnet(10), se_preresnet(10)] {
            g.validate().unwrap();
            assert!(g
                .nodes
                .iter()
                .any(|n| matches!(n.op, Op::Activation { kind: crate::graph::ActKind::Sigmoid })));
        }
    }

    #[test]
    fn diracnet_has_no_residual_adds() {
        let g = diracnet_v2(18);
        assert!(!g
            .nodes
            .iter()
            .any(|n| matches!(n.op, Op::ElementWise { kind: crate::graph::EwKind::Add, .. })));
    }

    #[test]
    fn all_resnet_depths_validate() {
        for d in [10, 12, 14, 16, 18, 26, 34, 50] {
            resnet(d, 1.0).validate().unwrap();
        }
        for d in [10, 18, 26, 34] {
            preresnet(d).validate().unwrap();
        }
        bagnet(9).validate().unwrap();
        bagnet(17).validate().unwrap();
    }
}
