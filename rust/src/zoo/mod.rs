//! The real-world neural-architecture zoo: 102 state-of-the-art models from
//! 25 papers (Appendix A of the paper), used as the *test* distribution in
//! the dataset-shift experiments (Sections 5.3, 5.5) and throughout the
//! measurement study (Section 3).
//!
//! Architectures follow the imgclsmob reference implementations the paper
//! profiled, at inference form (batch-norm folded into convolutions). Exact
//! layer counts differ from the originals only where an op has no analogue
//! in our IR (channel shuffle, bilinear upsampling) — substitutions are
//! documented on the builders.

pub mod misc;
pub mod mobilenets;
pub mod resnets;

use crate::graph::Graph;

/// A zoo entry: model name, source-paper family, and lazy builder.
pub struct ZooModel {
    pub family: &'static str,
    pub build: fn() -> Graph,
}

macro_rules! zoo {
    ($($family:literal => $f:expr),+ $(,)?) => {
        vec![$(ZooModel { family: $family, build: $f }),+]
    };
}

/// The full catalogue of 102 real-world models.
pub fn catalog() -> Vec<ZooModel> {
    use misc::*;
    use mobilenets::*;
    use resnets::*;
    zoo![
        // --- MobileNetV1 (4) ---
        "MobileNet" => || mobilenet_v1(0.25),
        "MobileNet" => || mobilenet_v1(0.5),
        "MobileNet" => || mobilenet_v1(0.75),
        "MobileNet" => || mobilenet_v1(1.0),
        // --- FD-MobileNet (4) ---
        "FD-MobileNet" => || fd_mobilenet(0.25),
        "FD-MobileNet" => || fd_mobilenet(0.5),
        "FD-MobileNet" => || fd_mobilenet(0.75),
        "FD-MobileNet" => || fd_mobilenet(1.0),
        // --- MobileNetV2 (4) ---
        "MobileNetV2" => || mobilenet_v2(0.35),
        "MobileNetV2" => || mobilenet_v2(0.5),
        "MobileNetV2" => || mobilenet_v2(0.75),
        "MobileNetV2" => || mobilenet_v2(1.0),
        // --- MobileNetV3 (4) ---
        "MobileNetV3" => || mobilenet_v3_large(0.75),
        "MobileNetV3" => || mobilenet_v3_large(1.0),
        "MobileNetV3" => || mobilenet_v3_small(0.75),
        "MobileNetV3" => || mobilenet_v3_small(1.0),
        // --- ResNet (10: depths + width-scaled mobile variants) ---
        "ResNet" => || resnet(10, 1.0),
        "ResNet" => || resnet(12, 1.0),
        "ResNet" => || resnet(14, 1.0),
        "ResNet" => || resnet(16, 1.0),
        "ResNet" => || resnet(18, 1.0),
        "ResNet" => || resnet(26, 1.0),
        "ResNet" => || resnet(34, 1.0),
        "ResNet" => || resnet(18, 0.25),
        "ResNet" => || resnet(18, 0.5),
        "ResNet" => || resnet(50, 0.5),
        // --- PreResNet (4) ---
        "PreResNet" => || preresnet(10),
        "PreResNet" => || preresnet(18),
        "PreResNet" => || preresnet(26),
        "PreResNet" => || preresnet(34),
        // --- SE-ResNet / SE-PreResNet (5) ---
        "SE-ResNet" => || se_resnet(10),
        "SE-ResNet" => || se_resnet(18),
        "SE-ResNet" => || se_resnet(26),
        "SE-PreResNet" => || se_preresnet(10),
        "SE-PreResNet" => || se_preresnet(18),
        // --- ResNeXt (2) ---
        "ResNeXt" => || resnext(26),
        "ResNeXt" => || resnext(38),
        // --- RegNetX (6) ---
        "RegNet" => || regnetx("002"),
        "RegNet" => || regnetx("004"),
        "RegNet" => || regnetx("006"),
        "RegNet" => || regnetx("008"),
        "RegNet" => || regnetx("016"),
        "RegNet" => || regnetx("032"),
        // --- DiracNetV2 (2) ---
        "DiracNetV2" => || diracnet_v2(18),
        "DiracNetV2" => || diracnet_v2(34),
        // --- BagNet (2) ---
        "BagNet" => || bagnet(9),
        "BagNet" => || bagnet(17),
        // --- ShuffleNetV2 (4) ---
        "ShuffleNetV2" => || shufflenet_v2(0.5),
        "ShuffleNetV2" => || shufflenet_v2(1.0),
        "ShuffleNetV2" => || shufflenet_v2(1.5),
        "ShuffleNetV2" => || shufflenet_v2(2.0),
        // --- SqueezeNet / SqueezeResNet (4) ---
        "SqueezeNet" => || squeezenet(false, false),
        "SqueezeNet" => || squeezenet(true, false),
        "SqueezeResNet" => || squeezenet(false, true),
        "SqueezeResNet" => || squeezenet(true, true),
        // --- EfficientNet (3) ---
        "EfficientNet" => || efficientnet("b0"),
        "EfficientNet" => || efficientnet("b1"),
        "EfficientNet" => || efficientnet("b2"),
        // --- MnasNet (3) ---
        "MnasNet" => || mnasnet("a1"),
        "MnasNet" => || mnasnet("b1"),
        "MnasNet" => || mnasnet("small"),
        // --- DenseNet (3) ---
        "DenseNet" => || densenet("small"),
        "DenseNet" => || densenet("121"),
        "DenseNet" => || densenet("169"),
        // --- GhostNet (3) ---
        "GhostNet" => || ghostnet(0.5),
        "GhostNet" => || ghostnet(1.0),
        "GhostNet" => || ghostnet(1.3),
        // --- ProxylessNAS (3) ---
        "ProxylessNAS" => || proxylessnas("cpu"),
        "ProxylessNAS" => || proxylessnas("gpu"),
        "ProxylessNAS" => || proxylessnas("mobile"),
        // --- SPNASNet (2) ---
        "SPNASNet" => || spnasnet(0.75),
        "SPNASNet" => || spnasnet(1.0),
        // --- FBNet (2) ---
        "FBNet" => || fbnet_c(0.75),
        "FBNet" => || fbnet_c(1.0),
        // --- PeleeNet (2) ---
        "PeleeNet" => || peleenet(0.5),
        "PeleeNet" => || peleenet(1.0),
        // --- DLA (3) ---
        "DLA" => || dla(34),
        "DLA" => || dla(46),
        "DLA" => || dla(60),
        // --- HarDNet (2) ---
        "HarDNet" => || hardnet(39),
        "HarDNet" => || hardnet(68),
        // --- VoVNet (2) ---
        "VoVNet" => || vovnet("27slim"),
        "VoVNet" => || vovnet("39"),
        // --- BN-Inception (1) ---
        "BN-Inception" => bn_inception,
        // --- HRNet (2) ---
        "HRNet" => || hrnet_small(false),
        "HRNet" => || hrnet_small(true),
        // --- Padded stems exercising PAD (1) ---
        "ResNet" => padded_resnet10,
        // --- extra width variants rounding the set to 102 (paper profiles
        //     multiple width multipliers per family) ---
        "MobileNet" => || mobilenet_v1(0.375),
        "MobileNet" => || mobilenet_v1(0.625),
        "MobileNetV2" => || mobilenet_v2(0.625),
        "MobileNetV2" => || mobilenet_v2(1.25),
        "FD-MobileNet" => || fd_mobilenet(0.375),
        "ShuffleNetV2" => || shufflenet_v2(0.5),
        "ResNet" => || resnet(26, 0.5),
        "ResNet" => || resnet(34, 0.5),
        "PreResNet" => || preresnet(12),
        "PreResNet" => || preresnet(14),
        "PreResNet" => || preresnet(16),
        "SE-ResNet" => || se_resnet(12),
        "SE-ResNet" => || se_resnet(14),
        "SE-PreResNet" => || se_preresnet(16),
        "GhostNet" => || ghostnet(0.75),
    ]
}

/// Build all 102 graphs (order is the catalogue order; deterministic).
pub fn all_graphs() -> Vec<Graph> {
    catalog().iter().map(|m| (m.build)()).collect()
}

/// Build a model by name; `None` if absent.
pub fn by_name(name: &str) -> Option<Graph> {
    all_graphs().into_iter().find(|g| g.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn zoo_has_102_models() {
        assert_eq!(catalog().len(), 102);
    }

    #[test]
    fn all_graphs_validate() {
        for g in all_graphs() {
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }

    #[test]
    fn family_count_matches_paper_appendix() {
        let fams: HashSet<&'static str> = catalog().iter().map(|m| m.family).collect();
        // 25 source papers in Appendix A; SqueezeNet/SqueezeResNet and
        // SE-ResNet/SE-PreResNet pairs are each one paper.
        assert!(fams.len() >= 25, "only {} families", fams.len());
    }

    #[test]
    fn params_mostly_under_18m() {
        // Paper: models restricted to <= 18M parameters. The canonical
        // depth-34 variants land slightly above (as do their imgclsmob
        // counterparts); everything else must be under.
        let over: Vec<String> = all_graphs()
            .iter()
            .filter(|g| g.params() > 18_000_000)
            .map(|g| format!("{}={}", g.name, g.params()))
            .collect();
        assert!(
            over.len() <= 4,
            "too many models over 18M params: {over:?}"
        );
        assert!(all_graphs().iter().all(|g| g.params() < 23_000_000));
    }

    #[test]
    fn by_name_finds_models() {
        assert!(by_name("resnet18").is_some());
        assert!(by_name("mobilenet_wd100").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn flops_span_wide_range() {
        let fl: Vec<u64> = all_graphs().iter().map(|g| g.flops()).collect();
        let min = *fl.iter().min().unwrap();
        let max = *fl.iter().max().unwrap();
        // From tiny MobileNet 0.25 to ResNet34-class: > 40x span.
        assert!(max / min.max(1) > 40, "flops span {min}..{max}");
    }
}
