//! The inverted-residual family of real-world architectures: MobileNet
//! V1/V2/V3, FD-MobileNet, MnasNet, EfficientNet, ProxylessNAS, SPNASNet,
//! FBNet and GhostNet. Structures follow the original papers (and the
//! imgclsmob reference implementations the paper profiled), with batch-norm
//! folded into the preceding convolution, as TFLite does at conversion time.

use crate::graph::{ActKind, Graph, GraphBuilder, Padding};

/// Scale a channel count by a width multiplier, rounding to a multiple of 8
/// (the divisor used by the official MobileNet implementations).
pub fn scale_c(base: usize, w: f64) -> usize {
    let v = (base as f64 * w).round() as usize;
    ((v + 4) / 8 * 8).max(8)
}

/// MobileNetV1 [26]: 3x3 stem + 13 depthwise-separable blocks.
pub fn mobilenet_v1(width: f64) -> Graph {
    let name = format!("mobilenet_wd{}", (width * 100.0) as usize);
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv_act(x, scale_c(32, width), 3, 2, ActKind::Relu);
    let cfg: &[(usize, usize)] = &[
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for &(c, s) in cfg {
        t = b.dw_separable(t, scale_c(c, width), 3, s, ActKind::Relu);
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// FD-MobileNet [44]: fast-downsampling MobileNet — reaches 7x7 early.
pub fn fd_mobilenet(width: f64) -> Graph {
    let name = format!("fdmobilenet_wd{}", (width * 100.0) as usize);
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv_act(x, scale_c(32, width), 3, 2, ActKind::Relu);
    let cfg: &[(usize, usize)] = &[
        (64, 2),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 1),
    ];
    for &(c, s) in cfg {
        t = b.dw_separable(t, scale_c(c, width), 3, s, ActKind::Relu);
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// MobileNetV2 [46]: linear bottlenecks with expansion 6.
pub fn mobilenet_v2(width: f64) -> Graph {
    let name = format!("mobilenetv2_wd{}", (width * 100.0) as usize);
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv_act(x, scale_c(32, width), 3, 2, ActKind::Relu6);
    // (expansion, out_c, repeats, first stride)
    let cfg: &[(usize, usize, usize, usize)] = &[
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    for &(e, c, n, s) in cfg {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            t = b.inverted_residual(t, scale_c(c, width), 3, stride, e, false, ActKind::Relu6);
        }
    }
    let last = if width > 1.0 { scale_c(1280, width) } else { 1280 };
    t = b.conv_act(t, last, 1, 1, ActKind::Relu6);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// MobileNetV3-Large [25]: mixed ReLU/h-swish, selective SE.
pub fn mobilenet_v3_large(width: f64) -> Graph {
    let name = format!("mobilenetv3_large_w{}", (width * 100.0) as usize);
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv_act(x, scale_c(16, width), 3, 2, ActKind::HSwish);
    // (kernel, expansion ratio x100, out_c, SE, act, stride)
    let cfg: &[(usize, usize, usize, bool, ActKind, usize)] = &[
        (3, 100, 16, false, ActKind::Relu, 1),
        (3, 400, 24, false, ActKind::Relu, 2),
        (3, 300, 24, false, ActKind::Relu, 1),
        (5, 300, 40, true, ActKind::Relu, 2),
        (5, 300, 40, true, ActKind::Relu, 1),
        (5, 300, 40, true, ActKind::Relu, 1),
        (3, 600, 80, false, ActKind::HSwish, 2),
        (3, 250, 80, false, ActKind::HSwish, 1),
        (3, 230, 80, false, ActKind::HSwish, 1),
        (3, 230, 80, false, ActKind::HSwish, 1),
        (3, 600, 112, true, ActKind::HSwish, 1),
        (3, 600, 112, true, ActKind::HSwish, 1),
        (5, 600, 160, true, ActKind::HSwish, 2),
        (5, 600, 160, true, ActKind::HSwish, 1),
        (5, 600, 160, true, ActKind::HSwish, 1),
    ];
    for &(k, e100, c, se, act, s) in cfg {
        t = mbv3_block(&mut b, t, k, e100, scale_c(c, width), se, act, s);
    }
    t = b.conv_act(t, scale_c(960, width), 1, 1, ActKind::HSwish);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// MobileNetV3-Small [25].
pub fn mobilenet_v3_small(width: f64) -> Graph {
    let name = format!("mobilenetv3_small_w{}", (width * 100.0) as usize);
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv_act(x, scale_c(16, width), 3, 2, ActKind::HSwish);
    let cfg: &[(usize, usize, usize, bool, ActKind, usize)] = &[
        (3, 100, 16, true, ActKind::Relu, 2),
        (3, 450, 24, false, ActKind::Relu, 2),
        (3, 367, 24, false, ActKind::Relu, 1),
        (5, 400, 40, true, ActKind::HSwish, 2),
        (5, 600, 40, true, ActKind::HSwish, 1),
        (5, 600, 40, true, ActKind::HSwish, 1),
        (5, 300, 48, true, ActKind::HSwish, 1),
        (5, 300, 48, true, ActKind::HSwish, 1),
        (5, 600, 96, true, ActKind::HSwish, 2),
        (5, 600, 96, true, ActKind::HSwish, 1),
        (5, 600, 96, true, ActKind::HSwish, 1),
    ];
    for &(k, e100, c, se, act, s) in cfg {
        t = mbv3_block(&mut b, t, k, e100, scale_c(c, width), se, act, s);
    }
    t = b.conv_act(t, scale_c(576, width), 1, 1, ActKind::HSwish);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// MobileNetV3 building block with percentage expansion ratios.
#[allow(clippy::too_many_arguments)]
fn mbv3_block(
    b: &mut GraphBuilder,
    x: usize,
    k: usize,
    e100: usize,
    out_c: usize,
    se: bool,
    act: ActKind,
    stride: usize,
) -> usize {
    let in_c = b.shape(x).c;
    let mid = ((in_c * e100 + 50) / 100).max(8);
    let mut t = x;
    if mid != in_c {
        t = b.conv_act(t, mid, 1, 1, act);
    }
    t = b.dwconv(t, k, stride);
    t = b.act(t, act);
    if se {
        t = b.se_block(t, 4);
    }
    t = b.conv(t, out_c, 1, 1, Padding::Same);
    if stride == 1 && in_c == out_c {
        t = b.add_t(x, t);
    }
    t
}

/// MnasNet [49]: A1 (with SE), B1 (no SE), Small.
pub fn mnasnet(variant: &str) -> Graph {
    let mut b = GraphBuilder::new(&format!("mnasnet_{variant}"), 224, 224, 3);
    let x = b.input_tensor();
    // (kernel, expansion, out_c, repeats, stride, SE)
    let cfg: Vec<(usize, usize, usize, usize, usize, bool)> = match variant {
        "a1" => vec![
            (3, 1, 16, 1, 1, false),
            (3, 6, 24, 2, 2, false),
            (5, 3, 40, 3, 2, true),
            (3, 6, 80, 4, 2, false),
            (3, 6, 112, 2, 1, true),
            (5, 6, 160, 3, 2, true),
            (3, 6, 320, 1, 1, false),
        ],
        "b1" => vec![
            (3, 1, 16, 1, 1, false),
            (3, 3, 24, 3, 2, false),
            (5, 3, 40, 3, 2, false),
            (5, 6, 80, 3, 2, false),
            (3, 6, 96, 2, 1, false),
            (5, 6, 192, 4, 2, false),
            (3, 6, 320, 1, 1, false),
        ],
        "small" => vec![
            (3, 1, 8, 1, 1, false),
            (3, 3, 16, 1, 2, false),
            (3, 6, 16, 2, 2, false),
            (5, 6, 32, 4, 2, true),
            (3, 6, 32, 3, 1, true),
            (5, 6, 88, 3, 2, true),
            (3, 6, 144, 1, 1, false),
        ],
        other => panic!("unknown mnasnet variant {other}"),
    };
    let mut t = b.conv_act(x, 32, 3, 2, ActKind::Relu);
    for (k, e, c, n, s, se) in cfg {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            t = b.inverted_residual(t, c, k, stride, e, se, ActKind::Relu);
        }
    }
    t = b.conv_act(t, 1280, 1, 1, ActKind::Relu);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// EfficientNet [50] B0-B2 via compound scaling of MBConv stages.
pub fn efficientnet(variant: &str) -> Graph {
    let (wmul, dmul, res) = match variant {
        "b0" => (1.0, 1.0, 224),
        "b1" => (1.0, 1.1, 240),
        "b2" => (1.1, 1.2, 260),
        other => panic!("unknown efficientnet variant {other}"),
    };
    let mut b = GraphBuilder::new(&format!("efficientnet_{variant}"), res, res, 3);
    let x = b.input_tensor();
    let depth = |n: usize| -> usize { ((n as f64 * dmul).ceil()) as usize };
    let mut t = b.conv_act(x, scale_c(32, wmul), 3, 2, ActKind::Swish);
    // (kernel, expansion, out_c, repeats, stride) — SE everywhere in EfficientNet.
    let cfg: &[(usize, usize, usize, usize, usize)] = &[
        (3, 1, 16, 1, 1),
        (3, 6, 24, 2, 2),
        (5, 6, 40, 2, 2),
        (3, 6, 80, 3, 2),
        (5, 6, 112, 3, 1),
        (5, 6, 192, 4, 2),
        (3, 6, 320, 1, 1),
    ];
    for &(k, e, c, n, s) in cfg {
        for i in 0..depth(n) {
            let stride = if i == 0 { s } else { 1 };
            t = b.inverted_residual(t, scale_c(c, wmul), k, stride, e, true, ActKind::Swish);
        }
    }
    t = b.conv_act(t, scale_c(1280, wmul), 1, 1, ActKind::Swish);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// ProxylessNAS [8]: per-target searched MBConv stacks (kernel 3/5/7 mix).
pub fn proxylessnas(target: &str) -> Graph {
    let mut b = GraphBuilder::new(&format!("proxylessnas_{target}"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv_act(x, 32, 3, 2, ActKind::Relu6);
    // (kernel, expansion, out_c, stride) flattened block list per target.
    let cfg: Vec<(usize, usize, usize, usize)> = match target {
        "cpu" => vec![
            (3, 1, 24, 1),
            (3, 6, 32, 2),
            (3, 3, 32, 1),
            (3, 3, 32, 1),
            (3, 6, 48, 2),
            (3, 3, 48, 1),
            (5, 3, 48, 1),
            (3, 6, 88, 2),
            (3, 3, 88, 1),
            (5, 3, 104, 1),
            (3, 3, 104, 1),
            (3, 3, 104, 1),
            (5, 6, 216, 2),
            (5, 3, 216, 1),
            (5, 3, 216, 1),
            (5, 6, 360, 1),
        ],
        "gpu" => vec![
            (3, 1, 24, 1),
            (5, 3, 40, 2),
            (7, 3, 56, 2),
            (3, 3, 56, 1),
            (7, 6, 112, 2),
            (5, 3, 112, 1),
            (5, 3, 128, 1),
            (3, 3, 128, 1),
            (7, 6, 256, 2),
            (7, 6, 256, 1),
            (7, 3, 256, 1),
            (5, 6, 432, 1),
        ],
        "mobile" => vec![
            (3, 1, 16, 1),
            (5, 3, 32, 2),
            (3, 3, 32, 1),
            (7, 3, 40, 2),
            (3, 3, 40, 1),
            (5, 6, 80, 2),
            (5, 3, 80, 1),
            (5, 3, 80, 1),
            (5, 3, 96, 1),
            (5, 3, 96, 1),
            (7, 6, 192, 2),
            (7, 6, 192, 1),
            (7, 3, 192, 1),
            (7, 6, 320, 1),
        ],
        other => panic!("unknown proxylessnas target {other}"),
    };
    for (k, e, c, s) in cfg {
        t = b.inverted_residual(t, c, k, s, e, false, ActKind::Relu6);
    }
    t = b.conv_act(t, 1280, 1, 1, ActKind::Relu6);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// Single-Path NAS [47].
pub fn spnasnet(width: f64) -> Graph {
    let name = format!("spnasnet_w{}", (width * 100.0) as usize);
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv_act(x, scale_c(32, width), 3, 2, ActKind::Relu);
    let cfg: &[(usize, usize, usize, usize, usize)] = &[
        (3, 1, 16, 1, 1),
        (3, 3, 24, 3, 2),
        (5, 3, 40, 4, 2),
        (5, 6, 80, 4, 2),
        (5, 6, 96, 4, 1),
        (5, 6, 192, 4, 2),
        (3, 6, 320, 1, 1),
    ];
    for &(k, e, c, n, s) in cfg {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            t = b.inverted_residual(t, scale_c(c, width), k, stride, e, false, ActKind::Relu);
        }
    }
    t = b.conv_act(t, 1280, 1, 1, ActKind::Relu);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// FBNet-C [56].
pub fn fbnet_c(width: f64) -> Graph {
    let name = format!("fbnet_cb_w{}", (width * 100.0) as usize);
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv_act(x, scale_c(16, width), 3, 2, ActKind::Relu);
    let cfg: &[(usize, usize, usize, usize)] = &[
        (3, 1, 16, 1),
        (3, 6, 24, 2),
        (3, 1, 24, 1),
        (3, 1, 24, 1),
        (5, 6, 32, 2),
        (5, 3, 32, 1),
        (3, 6, 32, 1),
        (5, 6, 64, 2),
        (5, 3, 64, 1),
        (5, 6, 64, 1),
        (3, 6, 112, 1),
        (5, 6, 112, 1),
        (5, 3, 112, 1),
        (5, 6, 184, 2),
        (5, 6, 184, 1),
        (5, 6, 184, 1),
        (3, 6, 352, 1),
    ];
    for &(k, e, c, s) in cfg {
        t = b.inverted_residual(t, scale_c(c, width), k, s, e, false, ActKind::Relu);
    }
    t = b.conv_act(t, 1984, 1, 1, ActKind::Relu);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// GhostNet [22]: ghost modules = primary 1x1 conv producing half the
/// channels + cheap depthwise producing the other half, concatenated.
pub fn ghostnet(width: f64) -> Graph {
    let name = format!("ghostnet_w{}", (width * 100.0) as usize);
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv_act(x, scale_c(16, width), 3, 2, ActKind::Relu);
    // (kernel, mid_c, out_c, SE, stride)
    let cfg: &[(usize, usize, usize, bool, usize)] = &[
        (3, 16, 16, false, 1),
        (3, 48, 24, false, 2),
        (3, 72, 24, false, 1),
        (5, 72, 40, true, 2),
        (5, 120, 40, true, 1),
        (3, 240, 80, false, 2),
        (3, 200, 80, false, 1),
        (3, 184, 80, false, 1),
        (3, 184, 80, false, 1),
        (3, 480, 112, true, 1),
        (3, 672, 112, true, 1),
        (5, 672, 160, true, 2),
        (5, 960, 160, false, 1),
        (5, 960, 160, true, 1),
    ];
    for &(k, mid, c, se, s) in cfg {
        t = ghost_bottleneck(&mut b, t, k, scale_c(mid, width), scale_c(c, width), se, s);
    }
    t = b.conv_act(t, scale_c(960, width), 1, 1, ActKind::Relu);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

fn ghost_module(b: &mut GraphBuilder, x: usize, out_c: usize, relu: bool) -> usize {
    let primary = (out_c + 1) / 2;
    let mut p = b.conv(x, primary, 1, 1, Padding::Same);
    if relu {
        p = b.relu(p);
    }
    let mut cheap = b.dwconv(p, 3, 1);
    if relu {
        cheap = b.relu(cheap);
    }
    let cat = b.concat(vec![p, cheap]);
    // Trim to out_c if odd — our channel counts are even, so concat is exact.
    debug_assert_eq!(b.shape(cat).c, 2 * primary);
    cat
}

fn ghost_bottleneck(
    b: &mut GraphBuilder,
    x: usize,
    k: usize,
    mid_c: usize,
    out_c: usize,
    se: bool,
    stride: usize,
) -> usize {
    let in_c = b.shape(x).c;
    let mut t = ghost_module(b, x, mid_c, true);
    if stride == 2 {
        t = b.dwconv(t, k, 2);
    }
    if se {
        t = b.se_block(t, 4);
    }
    t = ghost_module(b, t, out_c, false);
    let t_c = b.shape(t).c;
    if stride == 1 && in_c == t_c {
        b.add_t(x, t)
    } else {
        // Shortcut: dwconv + 1x1 conv to match.
        let s = b.dwconv(x, k, stride);
        let s = b.conv(s, t_c, 1, 1, Padding::Same);
        b.add_t(s, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpType;

    #[test]
    fn mobilenet_v1_structure() {
        let g = mobilenet_v1(1.0);
        g.validate().unwrap();
        let h = g.op_type_histogram();
        assert_eq!(h[&OpType::DepthwiseConv2D], 13);
        // 13 pointwise convs + stem
        assert_eq!(h[&OpType::Conv2D], 14);
        // ~4.2M params at width 1.0
        let p = g.params();
        assert!((3_000_000..6_000_000).contains(&p), "params={p}");
    }

    #[test]
    fn mobilenet_v1_width_monotonic() {
        let p25 = mobilenet_v1(0.25).params();
        let p50 = mobilenet_v1(0.5).params();
        let p100 = mobilenet_v1(1.0).params();
        assert!(p25 < p50 && p50 < p100);
    }

    #[test]
    fn mobilenet_v2_params_in_range() {
        let g = mobilenet_v2(1.0);
        g.validate().unwrap();
        let p = g.params();
        assert!((2_500_000..4_500_000).contains(&p), "params={p}");
    }

    #[test]
    fn mobilenet_v3_has_se_and_hswish() {
        let g = mobilenet_v3_large(1.0);
        g.validate().unwrap();
        assert!(g.nodes.iter().any(|n| matches!(
            n.op,
            crate::graph::Op::Activation { kind: ActKind::HSwish }
        )));
        assert!(g.nodes.iter().any(|n| matches!(
            n.op,
            crate::graph::Op::Activation { kind: ActKind::Sigmoid }
        )));
    }

    #[test]
    fn efficientnet_scales_up() {
        let b0 = efficientnet("b0");
        let b2 = efficientnet("b2");
        assert!(b2.flops() > b0.flops());
        assert!(b2.params() > b0.params());
    }

    #[test]
    fn ghostnet_has_concats() {
        let g = ghostnet(1.0);
        g.validate().unwrap();
        assert!(g.op_type_histogram()[&OpType::ConcatSplit] >= 20);
    }

    #[test]
    fn all_families_validate() {
        for g in [
            mobilenet_v1(0.25),
            fd_mobilenet(0.5),
            mobilenet_v2(0.75),
            mobilenet_v3_small(1.0),
            mnasnet("a1"),
            mnasnet("b1"),
            mnasnet("small"),
            efficientnet("b1"),
            proxylessnas("cpu"),
            proxylessnas("gpu"),
            proxylessnas("mobile"),
            spnasnet(1.0),
            fbnet_c(1.0),
            ghostnet(0.5),
        ] {
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }
}
