//! Concat-heavy and branchy families: SqueezeNet/SqueezeResNet (fire
//! modules), ShuffleNetV2 (split + concat units; channel shuffle is modeled
//! as split/concat traffic, matching its memory-movement cost), DenseNet,
//! PeleeNet, DLA, HarDNet, VoVNet, BN-Inception and HRNet-small.
//!
//! HRNet's bilinear upsampling has no counterpart in our op set; the
//! high-resolution branches are kept parallel and fused with stride-2
//! convolutions at the end, which preserves the op mix and latency scale
//! (documented substitution; HRNet contributes 2 of the 102 models).

use crate::graph::{EwKind, Graph, GraphBuilder, Padding, TensorId};

/// SqueezeNet [29] fire module: squeeze 1x1 + expand (1x1 ‖ 3x3) + concat.
fn fire(b: &mut GraphBuilder, x: TensorId, squeeze: usize, expand: usize) -> TensorId {
    let s = b.conv(x, squeeze, 1, 1, Padding::Same);
    let s = b.relu(s);
    let e1 = b.conv(s, expand, 1, 1, Padding::Same);
    let e1 = b.relu(e1);
    let e3 = b.conv(s, expand, 3, 1, Padding::Same);
    let e3 = b.relu(e3);
    b.concat(vec![e1, e3])
}

pub fn squeezenet(v11: bool, residual: bool) -> Graph {
    let name = match (v11, residual) {
        (false, false) => "squeezenet_v1_0".to_string(),
        (true, false) => "squeezenet_v1_1".to_string(),
        (false, true) => "squeezeresnet_v1_0".to_string(),
        (true, true) => "squeezeresnet_v1_1".to_string(),
    };
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    let mut t = if v11 {
        let t = b.conv(x, 64, 3, 2, Padding::Same);
        b.relu(t)
    } else {
        let t = b.conv(x, 96, 7, 2, Padding::Same);
        b.relu(t)
    };
    t = b.max_pool(t, 3, 2);
    let cfg: &[(usize, usize, bool)] = if v11 {
        // (squeeze, expand, pool after)
        &[
            (16, 64, false),
            (16, 64, true),
            (32, 128, false),
            (32, 128, true),
            (48, 192, false),
            (48, 192, false),
            (64, 256, false),
            (64, 256, false),
        ]
    } else {
        &[
            (16, 64, false),
            (16, 64, false),
            (32, 128, true),
            (32, 128, false),
            (48, 192, true),
            (48, 192, false),
            (64, 256, false),
            (64, 256, false),
        ]
    };
    for (i, &(s, e, pool)) in cfg.iter().enumerate() {
        let prev = t;
        t = fire(&mut b, t, s, e);
        // SqueezeResNet adds identity shortcuts around alternating fires.
        if residual && i % 2 == 1 && b.shape(prev).c == b.shape(t).c {
            t = b.add_t(prev, t);
        }
        if pool {
            t = b.max_pool(t, 3, 2);
        }
    }
    // Classifier: 1x1 conv to 1000 + global mean (as in the original).
    t = b.conv(t, 1000, 1, 1, Padding::Same);
    t = b.relu(t);
    let t = b.mean(t);
    let out = b.softmax(t);
    b.finish(vec![out])
}

/// ShuffleNetV2 [39] unit. Channel shuffle is represented as the split +
/// concat data movement it costs at inference time.
fn shuffle_unit(b: &mut GraphBuilder, x: TensorId, out_c: usize, downsample: bool) -> TensorId {
    if downsample {
        // Both branches process the full input.
        let left = b.dwconv(x, 3, 2);
        let left = b.conv(left, out_c / 2, 1, 1, Padding::Same);
        let left = b.relu(left);
        let right = b.conv(x, out_c / 2, 1, 1, Padding::Same);
        let right = b.relu(right);
        let right = b.dwconv(right, 3, 2);
        let right = b.conv(right, out_c / 2, 1, 1, Padding::Same);
        let right = b.relu(right);
        b.concat(vec![left, right])
    } else {
        let parts = b.split(x, 2);
        let (left, right) = (parts[0], parts[1]);
        let c = b.shape(right).c;
        let r = b.conv(right, c, 1, 1, Padding::Same);
        let r = b.relu(r);
        let r = b.dwconv(r, 3, 1);
        let r = b.conv(r, c, 1, 1, Padding::Same);
        let r = b.relu(r);
        b.concat(vec![left, r])
    }
}

pub fn shufflenet_v2(width: f64) -> Graph {
    let name = format!("shufflenetv2_w{}", (width * 100.0) as usize);
    let stage_c: Vec<usize> = match (width * 100.0) as usize {
        50 => vec![48, 96, 192, 1024],
        100 => vec![116, 232, 464, 1024],
        150 => vec![176, 352, 704, 1024],
        200 => vec![244, 488, 976, 2048],
        other => panic!("unsupported shufflenetv2 width {other}"),
    };
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 24, 3, 2, Padding::Same);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    let repeats = [4usize, 8, 4];
    for (si, &n) in repeats.iter().enumerate() {
        // Make channels even for split(2).
        let c = stage_c[si] / 2 * 2;
        t = shuffle_unit(&mut b, t, c, true);
        for _ in 1..n {
            t = shuffle_unit(&mut b, t, c, false);
        }
    }
    t = b.conv(t, stage_c[3], 1, 1, Padding::Same);
    t = b.relu(t);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// DenseNet [28]: dense blocks concatenate every layer's output.
pub fn densenet(variant: &str) -> Graph {
    // (growth rate, per-stage layers, init channels)
    let (k, stages, init): (usize, Vec<usize>, usize) = match variant {
        "121" => (32, vec![6, 12, 24, 16], 64),
        "169" => (32, vec![6, 12, 32, 32], 64),
        "small" => (24, vec![4, 8, 12, 8], 48),
        other => panic!("unsupported densenet variant {other}"),
    };
    let mut b = GraphBuilder::new(&format!("densenet{variant}"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, init, 7, 2, Padding::Same);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    for (si, &n) in stages.iter().enumerate() {
        for _ in 0..n {
            // Bottleneck dense layer: 1x1 (4k) + 3x3 (k), concat with input.
            let y = b.conv(t, 4 * k, 1, 1, Padding::Same);
            let y = b.relu(y);
            let y = b.conv(y, k, 3, 1, Padding::Same);
            let y = b.relu(y);
            t = b.concat(vec![t, y]);
        }
        if si < stages.len() - 1 {
            // Transition: 1x1 halving channels + 2x2 avg pool.
            let c = b.shape(t).c / 2;
            t = b.conv(t, c, 1, 1, Padding::Same);
            t = b.relu(t);
            t = b.avg_pool(t, 2, 2);
        }
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// PeleeNet [54]: two-way dense layers + stem block.
pub fn peleenet(width: f64) -> Graph {
    let name = format!("peleenet_w{}", (width * 100.0) as usize);
    let sc = |c: usize| ((c as f64 * width) as usize / 8 * 8).max(8);
    let mut b = GraphBuilder::new(&name, 224, 224, 3);
    let x = b.input_tensor();
    // Stem: conv s2, then two branches (conv s2 / maxpool) + concat + 1x1.
    let mut t = b.conv(x, sc(32), 3, 2, Padding::Same);
    t = b.relu(t);
    let l = b.conv(t, sc(16), 1, 1, Padding::Same);
    let l = b.relu(l);
    let l = b.conv(l, sc(32), 3, 2, Padding::Same);
    let l = b.relu(l);
    let r = b.max_pool(t, 2, 2);
    t = b.concat(vec![l, r]);
    t = b.conv(t, sc(32), 1, 1, Padding::Same);
    t = b.relu(t);
    let k = sc(32);
    let stages = [3usize, 4, 8, 6];
    for (si, &n) in stages.iter().enumerate() {
        for _ in 0..n {
            // Two-way dense layer: both branches produce k/2 channels.
            let half = (k / 2).max(8);
            let a = b.conv(t, half * 2, 1, 1, Padding::Same);
            let a = b.relu(a);
            let a = b.conv(a, half, 3, 1, Padding::Same);
            let a = b.relu(a);
            let c2 = b.conv(t, half * 2, 1, 1, Padding::Same);
            let c2 = b.relu(c2);
            let c2 = b.conv(c2, half, 3, 1, Padding::Same);
            let c2 = b.relu(c2);
            let c2 = b.conv(c2, half, 3, 1, Padding::Same);
            let c2 = b.relu(c2);
            t = b.concat(vec![t, a, c2]);
        }
        // Transition
        let c = b.shape(t).c;
        t = b.conv(t, c, 1, 1, Padding::Same);
        t = b.relu(t);
        if si < stages.len() - 1 {
            t = b.avg_pool(t, 2, 2);
        }
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// DLA [60]: iterative deep aggregation of basic residual blocks.
pub fn dla(depth: usize) -> Graph {
    let stages: Vec<usize> = match depth {
        34 => vec![1, 2, 2, 1],
        46 => vec![2, 2, 3, 1],
        60 => vec![2, 3, 4, 1],
        other => panic!("unsupported dla depth {other}"),
    };
    let mut b = GraphBuilder::new(&format!("dla{depth}"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 32, 7, 2, Padding::Same);
    t = b.relu(t);
    let base = [64usize, 128, 256, 512];
    for (si, &n) in stages.iter().enumerate() {
        let c = base[si];
        let mut level_outputs: Vec<TensorId> = Vec::new();
        for i in 0..n {
            let stride = if i == 0 { 2 } else { 1 };
            t = b.res_basic(t, c, stride);
            level_outputs.push(t);
        }
        if level_outputs.len() > 1 {
            // Aggregation node: concat level outputs + 1x1 conv back to c.
            let cat = b.concat(level_outputs);
            t = b.conv(cat, c, 1, 1, Padding::Same);
            t = b.relu(t);
        }
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// HarDNet [9]: harmonic dense blocks — each layer concatenates a
/// power-of-two pattern of predecessors.
pub fn hardnet(depth: usize) -> Graph {
    let (stages, k): (Vec<usize>, usize) = match depth {
        39 => (vec![4, 4, 8, 4], 20),
        68 => (vec![8, 8, 12, 8], 24),
        other => panic!("unsupported hardnet depth {other}"),
    };
    let mut b = GraphBuilder::new(&format!("hardnet{depth}"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 48, 3, 2, Padding::Same);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    for (si, &n) in stages.iter().enumerate() {
        let mut outs: Vec<TensorId> = vec![t];
        for i in 1..=n {
            // Harmonic connection pattern: link to outs[i - 2^j] for 2^j | i.
            let mut links: Vec<TensorId> = Vec::new();
            let mut p = 1usize;
            while p <= i {
                if i % p == 0 {
                    links.push(outs[i - p]);
                }
                p *= 2;
            }
            let inp = if links.len() > 1 {
                b.concat(links)
            } else {
                links[0]
            };
            let y = b.conv(inp, k * (si + 1), 3, 1, Padding::Same);
            let y = b.relu(y);
            outs.push(y);
        }
        let cat = b.concat(outs.split_off(outs.len().saturating_sub(3)));
        t = b.conv(cat, 128 * (si + 1), 1, 1, Padding::Same);
        t = b.relu(t);
        if si < stages.len() - 1 {
            t = b.avg_pool(t, 2, 2);
        }
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// VoVNet [35]: one-shot aggregation (OSA) modules.
pub fn vovnet(variant: &str) -> Graph {
    let (stage_convs, stage_c, agg_c): (usize, Vec<usize>, Vec<usize>) = match variant {
        "27slim" => (5, vec![64, 80, 96, 112], vec![128, 256, 384, 512]),
        "39" => (5, vec![128, 160, 192, 224], vec![256, 512, 768, 1024]),
        other => panic!("unsupported vovnet variant {other}"),
    };
    let mut b = GraphBuilder::new(&format!("vovnet{variant}"), 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 64, 3, 2, Padding::Same);
    t = b.relu(t);
    t = b.conv(t, 64, 3, 1, Padding::Same);
    t = b.relu(t);
    for si in 0..4 {
        if si > 0 {
            t = b.max_pool(t, 3, 2);
        }
        let mut outs: Vec<TensorId> = vec![t];
        let mut cur = t;
        for _ in 0..stage_convs {
            cur = b.conv(cur, stage_c[si], 3, 1, Padding::Same);
            cur = b.relu(cur);
            outs.push(cur);
        }
        let cat = b.concat(outs);
        t = b.conv(cat, agg_c[si], 1, 1, Padding::Same);
        t = b.relu(t);
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// BN-Inception [30]: inception modules with 1x1 / 3x3 / double-3x3 / pool
/// branches.
pub fn bn_inception() -> Graph {
    let mut b = GraphBuilder::new("bninception", 224, 224, 3);
    let x = b.input_tensor();
    let mut t = b.conv(x, 64, 7, 2, Padding::Same);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    t = b.conv(t, 64, 1, 1, Padding::Same);
    t = b.relu(t);
    t = b.conv(t, 192, 3, 1, Padding::Same);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    // (b1x1, b3x3_reduce, b3x3, db3x3_reduce, db3x3, pool_proj, stride)
    let cfg: &[(usize, usize, usize, usize, usize, usize, usize)] = &[
        (64, 64, 64, 64, 96, 32, 1),
        (64, 64, 96, 64, 96, 64, 1),
        (0, 128, 160, 64, 96, 0, 2),
        (224, 64, 96, 96, 128, 128, 1),
        (192, 96, 128, 96, 128, 128, 1),
        (160, 128, 160, 128, 160, 96, 1),
        (96, 128, 192, 160, 192, 96, 1),
        (0, 128, 192, 192, 256, 0, 2),
        (352, 192, 320, 160, 224, 128, 1),
        (352, 192, 320, 192, 224, 128, 1),
    ];
    for &(b1, r3, c3, rd3, cd3, pp, s) in cfg {
        t = inception_block(&mut b, t, b1, r3, c3, rd3, cd3, pp, s);
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

#[allow(clippy::too_many_arguments)]
fn inception_block(
    b: &mut GraphBuilder,
    x: TensorId,
    b1: usize,
    r3: usize,
    c3: usize,
    rd3: usize,
    cd3: usize,
    pp: usize,
    stride: usize,
) -> TensorId {
    let mut branches: Vec<TensorId> = Vec::new();
    if b1 > 0 {
        let t = b.conv(x, b1, 1, 1, Padding::Same);
        branches.push(b.relu(t));
    }
    {
        let t = b.conv(x, r3, 1, 1, Padding::Same);
        let t = b.relu(t);
        let t = b.conv(t, c3, 3, stride, Padding::Same);
        branches.push(b.relu(t));
    }
    {
        let t = b.conv(x, rd3, 1, 1, Padding::Same);
        let t = b.relu(t);
        let t = b.conv(t, cd3, 3, 1, Padding::Same);
        let t = b.relu(t);
        let t = b.conv(t, cd3, 3, stride, Padding::Same);
        branches.push(b.relu(t));
    }
    {
        let t = if stride == 1 {
            b.avg_pool(x, 3, 1)
        } else {
            b.max_pool(x, 3, 2)
        };
        if pp > 0 {
            let t = b.conv(t, pp, 1, 1, Padding::Same);
            branches.push(b.relu(t));
        } else {
            branches.push(t);
        }
    }
    b.concat(branches)
}

/// HRNet-small [53] (v1/v2): two parallel resolution branches with stride-2
/// exchange units (upsampling substituted as documented in the module docs).
pub fn hrnet_small(v2: bool) -> Graph {
    let name = if v2 { "hrnet_w18_small_v2" } else { "hrnet_w18_small_v1" };
    let mut b = GraphBuilder::new(name, 224, 224, 3);
    let x = b.input_tensor();
    let mut hi = b.conv(x, 64, 3, 2, Padding::Same);
    hi = b.relu(hi);
    hi = b.conv(hi, 64, 3, 2, Padding::Same);
    hi = b.relu(hi);
    let blocks = if v2 { 3 } else { 2 };
    // Branch channels: hi-res 18, lo-res 36.
    hi = b.res_basic(hi, 18, 1);
    let mut lo = b.conv(hi, 36, 3, 2, Padding::Same);
    lo = b.relu(lo);
    for _ in 0..blocks {
        hi = b.res_basic(hi, 18, 1);
        lo = b.res_basic(lo, 36, 1);
        // Exchange: hi->lo via stride-2 conv, fused into lo by addition.
        let down = b.conv(hi, 36, 3, 2, Padding::Same);
        lo = b.add_t(lo, down);
    }
    // Head: downsample hi to lo resolution, concat, classify.
    let hi_down = b.conv(hi, 36, 3, 2, Padding::Same);
    let cat = b.concat(vec![hi_down, lo]);
    let t = b.conv(cat, 512, 1, 1, Padding::Same);
    let t = b.relu(t);
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

/// A few architectures include explicit PAD ops before strided convolutions
/// (TFLite inserts these for SAME padding with stride > 1 on some convertors).
/// This helper graph family exercises Pad in the dataset.
pub fn padded_resnet10() -> Graph {
    let mut b = GraphBuilder::new("resnet10_padded", 224, 224, 3);
    let x = b.input_tensor();
    let p = b.pad(x, 3);
    let mut t = b.conv(p, 64, 7, 2, Padding::Valid);
    t = b.relu(t);
    t = b.max_pool(t, 3, 2);
    for (c, s) in [(64, 1), (128, 2), (256, 2), (512, 2)] {
        let pd = b.pad(t, 1);
        let in_c = b.shape(t).c;
        let y = b.conv(pd, c, 3, s, Padding::Valid);
        let y = b.relu(y);
        let y = b.conv(y, c, 3, 1, Padding::Same);
        let short = if s != 1 || in_c != c {
            b.conv(t, c, 1, s, Padding::Same)
        } else {
            t
        };
        t = b.ew(EwKind::Add, y, short);
        t = b.relu(t);
    }
    let out = b.head(t, 1000);
    b.finish(vec![out])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::OpType;

    #[test]
    fn squeezenet_fire_concats() {
        let g = squeezenet(true, false);
        g.validate().unwrap();
        assert_eq!(g.op_type_histogram()[&OpType::ConcatSplit], 8);
        let p = g.params();
        assert!((900_000..1_800_000).contains(&p), "params={p}");
    }

    #[test]
    fn squeezeresnet_has_adds() {
        let g = squeezenet(true, true);
        assert!(g.op_type_histogram().contains_key(&OpType::ElementWise));
    }

    #[test]
    fn shufflenet_split_concat_units() {
        let g = shufflenet_v2(1.0);
        g.validate().unwrap();
        let h = g.op_type_histogram();
        // 13 non-downsample units have a split; every unit has a concat.
        assert!(h[&OpType::ConcatSplit] >= 26, "{h:?}");
    }

    #[test]
    fn densenet_channel_growth() {
        let g = densenet("121");
        g.validate().unwrap();
        let p = g.params();
        assert!((6_000_000..10_000_000).contains(&p), "params={p}");
    }

    #[test]
    fn all_misc_validate() {
        for g in [
            squeezenet(false, false),
            squeezenet(false, true),
            peleenet(1.0),
            dla(34),
            dla(46),
            hardnet(39),
            hardnet(68),
            vovnet("27slim"),
            vovnet("39"),
            bn_inception(),
            hrnet_small(false),
            hrnet_small(true),
            padded_resnet10(),
        ] {
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }

    #[test]
    fn padded_variant_has_pad_ops() {
        let g = padded_resnet10();
        assert!(g.op_type_histogram()[&OpType::Pad] >= 5);
    }
}
