//! Fluent graph construction. Used by the real-world zoo (`zoo/`), the NAS
//! sampler (`nas/`), and tests. Shape inference happens on every `add`, so a
//! finished graph is valid by construction (and `Graph::validate` re-checks).

use crate::graph::op::{ActKind, EwKind, Op, Padding, PoolKind};
use crate::graph::{infer_shapes, Graph, Node, Shape, Tensor, TensorId};

pub struct GraphBuilder {
    name: String,
    tensors: Vec<Tensor>,
    nodes: Vec<Node>,
    inputs: Vec<TensorId>,
}

impl GraphBuilder {
    /// Start a graph with a single HxWxC image input.
    pub fn new(name: &str, h: usize, w: usize, c: usize) -> GraphBuilder {
        let t = Tensor { id: 0, shape: Shape::new(h, w, c) };
        GraphBuilder {
            name: name.to_string(),
            tensors: vec![t],
            nodes: Vec::new(),
            inputs: vec![0],
        }
    }

    /// The id of the (single) graph input.
    pub fn input_tensor(&self) -> TensorId {
        self.inputs[0]
    }

    pub fn shape(&self, t: TensorId) -> Shape {
        self.tensors[t].shape
    }

    fn new_tensor(&mut self, shape: Shape) -> TensorId {
        let id = self.tensors.len();
        self.tensors.push(Tensor { id, shape });
        id
    }

    /// Append an op; panics on shape errors (zoo definitions are static, and
    /// the NAS sampler guarantees constraints before calling).
    pub fn add(&mut self, op: Op, inputs: Vec<TensorId>) -> Vec<TensorId> {
        let in_shapes: Vec<Shape> = inputs.iter().map(|&t| self.tensors[t].shape).collect();
        let out_shapes = infer_shapes(&op, &in_shapes)
            .unwrap_or_else(|e| panic!("graph '{}': {} on {:?}: {e}", self.name, op.name(), in_shapes));
        let outputs: Vec<TensorId> = out_shapes.into_iter().map(|s| self.new_tensor(s)).collect();
        self.nodes.push(Node { id: self.nodes.len(), op, inputs, outputs: outputs.clone() });
        outputs
    }

    fn add1(&mut self, op: Op, inputs: Vec<TensorId>) -> TensorId {
        self.add(op, inputs)[0]
    }

    // ---- convenience wrappers ------------------------------------------

    pub fn conv(&mut self, x: TensorId, out_c: usize, k: usize, stride: usize, padding: Padding) -> TensorId {
        self.add1(Op::Conv2D { kh: k, kw: k, stride, padding, out_c, groups: 1 }, vec![x])
    }

    pub fn grouped_conv(
        &mut self,
        x: TensorId,
        out_c: usize,
        k: usize,
        stride: usize,
        groups: usize,
    ) -> TensorId {
        self.add1(
            Op::Conv2D { kh: k, kw: k, stride, padding: Padding::Same, out_c, groups },
            vec![x],
        )
    }

    pub fn dwconv(&mut self, x: TensorId, k: usize, stride: usize) -> TensorId {
        self.add1(Op::DepthwiseConv2D { kh: k, kw: k, stride, padding: Padding::Same }, vec![x])
    }

    pub fn fc(&mut self, x: TensorId, out: usize) -> TensorId {
        self.add1(Op::FullyConnected { out_features: out }, vec![x])
    }

    pub fn avg_pool(&mut self, x: TensorId, k: usize, stride: usize) -> TensorId {
        self.add1(
            Op::Pooling { kind: PoolKind::Avg, kh: k, kw: k, stride, padding: Padding::Same },
            vec![x],
        )
    }

    pub fn max_pool(&mut self, x: TensorId, k: usize, stride: usize) -> TensorId {
        self.add1(
            Op::Pooling { kind: PoolKind::Max, kh: k, kw: k, stride, padding: Padding::Same },
            vec![x],
        )
    }

    pub fn mean(&mut self, x: TensorId) -> TensorId {
        self.add1(Op::Mean, vec![x])
    }

    pub fn concat(&mut self, xs: Vec<TensorId>) -> TensorId {
        self.add1(Op::Concat, xs)
    }

    pub fn split(&mut self, x: TensorId, num: usize) -> Vec<TensorId> {
        self.add(Op::Split { num }, vec![x])
    }

    pub fn pad(&mut self, x: TensorId, pad: usize) -> TensorId {
        self.add1(Op::Pad { pad_h: pad, pad_w: pad }, vec![x])
    }

    pub fn ew(&mut self, kind: EwKind, a: TensorId, b: TensorId) -> TensorId {
        self.add1(Op::ElementWise { kind, with_const: false }, vec![a, b])
    }

    pub fn ew_const(&mut self, kind: EwKind, a: TensorId) -> TensorId {
        self.add1(Op::ElementWise { kind, with_const: true }, vec![a])
    }

    pub fn add_t(&mut self, a: TensorId, b: TensorId) -> TensorId {
        self.ew(EwKind::Add, a, b)
    }

    pub fn mul_t(&mut self, a: TensorId, b: TensorId) -> TensorId {
        self.ew(EwKind::Mul, a, b)
    }

    pub fn act(&mut self, x: TensorId, kind: ActKind) -> TensorId {
        self.add1(Op::Activation { kind }, vec![x])
    }

    pub fn relu(&mut self, x: TensorId) -> TensorId {
        self.act(x, ActKind::Relu)
    }

    pub fn relu6(&mut self, x: TensorId) -> TensorId {
        self.act(x, ActKind::Relu6)
    }

    pub fn hswish(&mut self, x: TensorId) -> TensorId {
        self.act(x, ActKind::HSwish)
    }

    pub fn softmax(&mut self, x: TensorId) -> TensorId {
        self.add1(Op::Softmax, vec![x])
    }

    pub fn reshape(&mut self, x: TensorId) -> TensorId {
        self.add1(Op::Reshape, vec![x])
    }

    // ---- composite blocks shared by zoo + NAS sampler ------------------

    /// conv + activation ("conv-bn-act"; BN folds into conv at inference).
    pub fn conv_act(
        &mut self,
        x: TensorId,
        out_c: usize,
        k: usize,
        stride: usize,
        act: ActKind,
    ) -> TensorId {
        let t = self.conv(x, out_c, k, stride, Padding::Same);
        self.act(t, act)
    }

    /// Depthwise-separable block: dwconv(k, s) + act + 1x1 conv + act.
    pub fn dw_separable(
        &mut self,
        x: TensorId,
        out_c: usize,
        k: usize,
        stride: usize,
        act: ActKind,
    ) -> TensorId {
        let t = self.dwconv(x, k, stride);
        let t = self.act(t, act);
        let t = self.conv(t, out_c, 1, 1, Padding::Same);
        self.act(t, act)
    }

    /// Squeeze-and-Excite: mean -> fc(c/r) -> relu -> fc(c) -> sigmoid -> mul.
    pub fn se_block(&mut self, x: TensorId, reduction: usize) -> TensorId {
        let c = self.shape(x).c;
        let mid = (c / reduction).max(1);
        let s = self.mean(x);
        let s = self.fc(s, mid);
        let s = self.relu(s);
        let s = self.fc(s, c);
        let s = self.act(s, ActKind::Sigmoid);
        self.mul_t(x, s)
    }

    /// MobileNetV2 inverted residual (linear bottleneck): optional 1x1 expand,
    /// dwconv, 1x1 project; residual add when stride 1 and channels match.
    pub fn inverted_residual(
        &mut self,
        x: TensorId,
        out_c: usize,
        k: usize,
        stride: usize,
        expand: usize,
        se: bool,
        act: ActKind,
    ) -> TensorId {
        let in_c = self.shape(x).c;
        let mut t = x;
        if expand != 1 {
            t = self.conv(t, in_c * expand, 1, 1, Padding::Same);
            t = self.act(t, act);
        }
        t = self.dwconv(t, k, stride);
        t = self.act(t, act);
        if se {
            t = self.se_block(t, 4);
        }
        t = self.conv(t, out_c, 1, 1, Padding::Same);
        if stride == 1 && in_c == out_c {
            t = self.add_t(x, t);
        }
        t
    }

    /// Basic ResNet block (two 3x3 convs + shortcut).
    pub fn res_basic(&mut self, x: TensorId, out_c: usize, stride: usize) -> TensorId {
        let in_c = self.shape(x).c;
        let t = self.conv(x, out_c, 3, stride, Padding::Same);
        let t = self.relu(t);
        let t = self.conv(t, out_c, 3, 1, Padding::Same);
        let short = if stride != 1 || in_c != out_c {
            self.conv(x, out_c, 1, stride, Padding::Same)
        } else {
            x
        };
        let t = self.add_t(t, short);
        self.relu(t)
    }

    /// Bottleneck ResNet block (1x1 down, 3x3, 1x1 up + shortcut), with
    /// optional grouping on the 3x3 (ResNeXt) and optional SE.
    pub fn res_bottleneck(
        &mut self,
        x: TensorId,
        mid_c: usize,
        out_c: usize,
        stride: usize,
        groups: usize,
        se: bool,
    ) -> TensorId {
        let in_c = self.shape(x).c;
        let t = self.conv(x, mid_c, 1, 1, Padding::Same);
        let t = self.relu(t);
        let t = if groups > 1 {
            self.grouped_conv(t, mid_c, 3, stride, groups)
        } else {
            self.conv(t, mid_c, 3, stride, Padding::Same)
        };
        let t = self.relu(t);
        let mut t = self.conv(t, out_c, 1, 1, Padding::Same);
        if se {
            t = self.se_block(t, 16);
        }
        let short = if stride != 1 || in_c != out_c {
            self.conv(x, out_c, 1, stride, Padding::Same)
        } else {
            x
        };
        let t = self.add_t(t, short);
        self.relu(t)
    }

    /// Classifier head: global mean + FC(classes) + softmax.
    pub fn head(&mut self, x: TensorId, classes: usize) -> TensorId {
        let t = self.mean(x);
        let t = self.fc(t, classes);
        self.softmax(t)
    }

    pub fn finish(self, outputs: Vec<TensorId>) -> Graph {
        let g = Graph {
            name: self.name,
            tensors: self.tensors,
            nodes: self.nodes,
            inputs: self.inputs,
            outputs,
        };
        debug_assert!(g.validate().is_ok(), "{:?}", g.validate());
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inverted_residual_has_residual_add_when_possible() {
        let mut b = GraphBuilder::new("t", 16, 16, 24);
        let x = b.input_tensor();
        let t = b.inverted_residual(x, 24, 3, 1, 6, false, ActKind::Relu6);
        let g = b.finish(vec![t]);
        g.validate().unwrap();
        assert!(g.nodes.iter().any(|n| matches!(n.op, Op::ElementWise { kind: EwKind::Add, .. })));
    }

    #[test]
    fn inverted_residual_no_add_on_stride2() {
        let mut b = GraphBuilder::new("t", 16, 16, 24);
        let x = b.input_tensor();
        let t = b.inverted_residual(x, 24, 3, 2, 6, false, ActKind::Relu6);
        let g = b.finish(vec![t]);
        assert!(!g.nodes.iter().any(|n| matches!(n.op, Op::ElementWise { kind: EwKind::Add, .. })));
    }

    #[test]
    fn se_block_shapes() {
        let mut b = GraphBuilder::new("t", 8, 8, 32);
        let x = b.input_tensor();
        let t = b.se_block(x, 4);
        let g = b.finish(vec![t]);
        g.validate().unwrap();
        assert_eq!(g.shape(t), Shape::new(8, 8, 32));
        // mean, fc, relu, fc, sigmoid, mul
        assert_eq!(g.nodes.len(), 6);
    }

    #[test]
    fn res_basic_downsamples_shortcut() {
        let mut b = GraphBuilder::new("t", 16, 16, 32);
        let x = b.input_tensor();
        let t = b.res_basic(x, 64, 2);
        let g = b.finish(vec![t]);
        g.validate().unwrap();
        assert_eq!(g.shape(t), Shape::new(8, 8, 64));
        // two 3x3 convs + 1x1 projection
        let convs = g.nodes.iter().filter(|n| matches!(n.op, Op::Conv2D { .. })).count();
        assert_eq!(convs, 3);
    }

    #[test]
    #[should_panic]
    fn builder_panics_on_bad_split() {
        let mut b = GraphBuilder::new("t", 8, 8, 9);
        let x = b.input_tensor();
        b.split(x, 2);
    }
}
