//! Tensor shapes. Inference on mobile uses batch size 1 throughout the
//! paper, so shapes are HWC feature maps (vectors are 1x1xC).

use crate::graph::op::Padding;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Shape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Shape {
    pub fn new(h: usize, w: usize, c: usize) -> Shape {
        Shape { h, w, c }
    }

    /// A 1-D feature vector (output of Mean / FullyConnected / Reshape).
    pub fn vec(c: usize) -> Shape {
        Shape { h: 1, w: 1, c }
    }

    pub fn numel(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Spatial output extent of a strided window op under a padding policy.
    pub fn conv_out_dim(in_dim: usize, k: usize, stride: usize, padding: Padding) -> usize {
        match padding {
            Padding::Same => in_dim.div_ceil(stride),
            Padding::Valid => {
                assert!(in_dim >= k, "VALID padding needs input >= kernel ({in_dim} < {k})");
                (in_dim - k) / stride + 1
            }
        }
    }

    pub fn render(&self) -> String {
        format!("{}x{}x{}", self.h, self.w, self.c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_halves_with_stride2() {
        assert_eq!(Shape::conv_out_dim(224, 3, 2, Padding::Same), 112);
        assert_eq!(Shape::conv_out_dim(7, 3, 2, Padding::Same), 4);
    }

    #[test]
    fn valid_padding() {
        assert_eq!(Shape::conv_out_dim(224, 3, 1, Padding::Valid), 222);
        assert_eq!(Shape::conv_out_dim(7, 7, 1, Padding::Valid), 1);
    }

    #[test]
    fn numel() {
        assert_eq!(Shape::new(7, 7, 64).numel(), 3136);
        assert_eq!(Shape::vec(1000).numel(), 1000);
    }
}
