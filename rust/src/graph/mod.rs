//! Computational-graph IR — our analogue of the `.tflite` model file.
//!
//! A [`Graph`] is a DAG of [`Node`]s over [`Tensor`]s, stored in topological
//! order (the builder only lets you consume tensors that already exist).
//! Shape inference runs at construction time; FLOPs, parameter counts and
//! tensor sizes are derived on demand for the feature extractor (Table 3 of
//! the paper).

pub mod builder;
pub mod modelfile;
pub mod op;
pub mod shape;

pub use builder::GraphBuilder;
pub use op::{ActKind, EwKind, Op, OpArity, OpType, Padding, PoolKind};
pub use shape::Shape;

use std::collections::HashMap;

pub type TensorId = usize;
pub type OpId = usize;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub id: TensorId,
    pub shape: Shape,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: OpId,
    pub op: Op,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
}

/// A neural-architecture computational graph (batch size 1).
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    pub name: String,
    pub tensors: Vec<Tensor>,
    /// Nodes in topological order.
    pub nodes: Vec<Node>,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
}

impl Graph {
    pub fn shape(&self, t: TensorId) -> Shape {
        self.tensors[t].shape
    }

    pub fn input_shapes(&self, node: &Node) -> Vec<Shape> {
        node.inputs.iter().map(|&t| self.shape(t)).collect()
    }

    pub fn output_shapes(&self, node: &Node) -> Vec<Shape> {
        node.outputs.iter().map(|&t| self.shape(t)).collect()
    }

    /// All nodes consuming tensor `t`, in topological order.
    pub fn consumers(&self, t: TensorId) -> Vec<OpId> {
        self.nodes
            .iter()
            .filter(|n| n.inputs.contains(&t))
            .map(|n| n.id)
            .collect()
    }

    /// The node producing tensor `t`, if any (graph inputs have none).
    pub fn producer(&self, t: TensorId) -> Option<OpId> {
        self.nodes.iter().find(|n| n.outputs.contains(&t)).map(|n| n.id)
    }

    /// Total MAC-based FLOPs of the architecture.
    pub fn flops(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| n.op.flops(&self.input_shapes(n), &self.output_shapes(n)))
            .sum()
    }

    /// Total learned parameters.
    pub fn params(&self) -> u64 {
        self.nodes
            .iter()
            .map(|n| n.op.param_count(&self.input_shapes(n), &self.output_shapes(n)))
            .sum()
    }

    /// Structural fingerprint (FNV-1a over ops, shapes and connectivity).
    /// Excludes the model name, so renamed copies of the same architecture
    /// hash alike; `engine::LatencyEngine` uses it to memoize kernel
    /// deduction. Stable within a process run (in-memory cache key only —
    /// not a persisted format).
    pub fn fingerprint(&self) -> u64 {
        fn eat(h: u64, bytes: &[u8]) -> u64 {
            let mut h = h;
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
            h
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        h = eat(h, &(self.tensors.len() as u64).to_le_bytes());
        for t in &self.tensors {
            for d in [t.shape.h, t.shape.w, t.shape.c] {
                h = eat(h, &(d as u64).to_le_bytes());
            }
        }
        for n in &self.nodes {
            h = eat(h, format!("{:?}", n.op).as_bytes());
            for &i in &n.inputs {
                h = eat(h, &(i as u64).to_le_bytes());
            }
            h = eat(h, b"|");
            for &o in &n.outputs {
                h = eat(h, &(o as u64).to_le_bytes());
            }
            h = eat(h, b";");
        }
        for &t in &self.inputs {
            h = eat(h, &(t as u64).to_le_bytes());
        }
        h = eat(h, b"#");
        for &t in &self.outputs {
            h = eat(h, &(t as u64).to_le_bytes());
        }
        h
    }

    /// Count of nodes per coarse op type.
    pub fn op_type_histogram(&self) -> HashMap<OpType, usize> {
        let mut h = HashMap::new();
        for n in &self.nodes {
            *h.entry(n.op.op_type()).or_insert(0) += 1;
        }
        h
    }

    /// Structural validation; used by property tests and after model-file
    /// loading. Checks topological ordering, arity, shape consistency, and
    /// tensor linkage.
    pub fn validate(&self) -> Result<(), String> {
        let mut produced: Vec<bool> = vec![false; self.tensors.len()];
        for &t in &self.inputs {
            if t >= self.tensors.len() {
                return Err(format!("input tensor {t} out of range"));
            }
            produced[t] = true;
        }
        for (idx, node) in self.nodes.iter().enumerate() {
            if node.id != idx {
                return Err(format!("node {idx} has id {}", node.id));
            }
            match node.op.arity() {
                OpArity::Exact(k) if node.inputs.len() != k => {
                    return Err(format!(
                        "node {idx} ({}) expects {k} inputs, has {}",
                        node.op.name(),
                        node.inputs.len()
                    ));
                }
                OpArity::Variadic if node.inputs.len() < 2 => {
                    return Err(format!("node {idx} (Concat) needs >= 2 inputs"));
                }
                _ => {}
            }
            for &t in &node.inputs {
                if t >= self.tensors.len() {
                    return Err(format!("node {idx} reads missing tensor {t}"));
                }
                if !produced[t] {
                    return Err(format!("node {idx} reads tensor {t} before production"));
                }
            }
            // Shape consistency.
            let ins = self.input_shapes(node);
            let outs = self.output_shapes(node);
            let expect = infer_shapes(&node.op, &ins).map_err(|e| format!("node {idx}: {e}"))?;
            if expect != outs {
                return Err(format!(
                    "node {idx} ({}) shape mismatch: expected {:?}, stored {:?}",
                    node.op.name(),
                    expect,
                    outs
                ));
            }
            for &t in &node.outputs {
                if t >= self.tensors.len() {
                    return Err(format!("node {idx} writes missing tensor {t}"));
                }
                if produced[t] {
                    return Err(format!("tensor {t} produced twice"));
                }
                produced[t] = true;
            }
        }
        for &t in &self.outputs {
            if t >= self.tensors.len() || !produced[t] {
                return Err(format!("graph output {t} never produced"));
            }
        }
        Ok(())
    }
}

/// Shape inference for one op. Errors on inconsistent inputs (e.g. concat of
/// mismatched spatial dims, split of indivisible channels).
pub fn infer_shapes(op: &Op, inputs: &[Shape]) -> Result<Vec<Shape>, String> {
    let one = |s: Shape| Ok(vec![s]);
    match op {
        Op::Conv2D { kh, kw, stride, padding, out_c, groups } => {
            let i = inputs[0];
            if i.c % groups != 0 || out_c % groups != 0 {
                return Err(format!(
                    "groups {groups} must divide in_c {} and out_c {out_c}",
                    i.c
                ));
            }
            one(Shape::new(
                Shape::conv_out_dim(i.h, *kh, *stride, *padding),
                Shape::conv_out_dim(i.w, *kw, *stride, *padding),
                *out_c,
            ))
        }
        Op::DepthwiseConv2D { kh, kw, stride, padding } => {
            let i = inputs[0];
            one(Shape::new(
                Shape::conv_out_dim(i.h, *kh, *stride, *padding),
                Shape::conv_out_dim(i.w, *kw, *stride, *padding),
                i.c,
            ))
        }
        Op::FullyConnected { out_features } => one(Shape::vec(*out_features)),
        Op::Pooling { kh, kw, stride, padding, .. } => {
            let i = inputs[0];
            one(Shape::new(
                Shape::conv_out_dim(i.h, *kh, *stride, *padding),
                Shape::conv_out_dim(i.w, *kw, *stride, *padding),
                i.c,
            ))
        }
        Op::Mean => one(Shape::vec(inputs[0].c)),
        Op::Concat => {
            let (h, w) = (inputs[0].h, inputs[0].w);
            if inputs.iter().any(|s| s.h != h || s.w != w) {
                return Err("concat inputs must share spatial dims".into());
            }
            one(Shape::new(h, w, inputs.iter().map(|s| s.c).sum()))
        }
        Op::Split { num } => {
            let i = inputs[0];
            if i.c % num != 0 {
                return Err(format!("split {num} must divide channels {}", i.c));
            }
            Ok((0..*num).map(|_| Shape::new(i.h, i.w, i.c / num)).collect())
        }
        Op::Pad { pad_h, pad_w } => {
            let i = inputs[0];
            one(Shape::new(i.h + 2 * pad_h, i.w + 2 * pad_w, i.c))
        }
        Op::ElementWise { .. } => {
            if inputs.len() == 2 && inputs[0] != inputs[1] {
                // Broadcast: a 1x1xC tensor may combine with HxWxC.
                let (a, b) = (inputs[0], inputs[1]);
                let big = if a.numel() >= b.numel() { a } else { b };
                let small = if a.numel() >= b.numel() { b } else { a };
                if small.h == 1 && small.w == 1 && (small.c == big.c || small.c == 1) {
                    return one(big);
                }
                return Err(format!(
                    "elementwise shape mismatch: {} vs {}",
                    a.render(),
                    b.render()
                ));
            }
            one(inputs[0])
        }
        Op::Activation { .. } | Op::Softmax => one(inputs[0]),
        Op::Reshape => one(Shape::vec(inputs[0].numel())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Graph {
        let mut b = GraphBuilder::new("tiny", 8, 8, 3);
        let x = b.input_tensor();
        let t = b.conv(x, 16, 3, 2, Padding::Same);
        let t = b.relu(t);
        let t = b.mean(t);
        let t = b.fc(t, 10);
        b.finish(vec![t])
    }

    #[test]
    fn tiny_graph_validates() {
        let g = tiny_graph();
        g.validate().unwrap();
        assert_eq!(g.nodes.len(), 4);
        assert_eq!(g.shape(g.outputs[0]), Shape::vec(10));
    }

    #[test]
    fn flops_positive_and_consistent() {
        let g = tiny_graph();
        // conv: 2*4*4*16*3*9 ; fc: 2*16*10 ; relu: 256 ; mean: 256
        let conv = 2 * 4 * 4 * 16 * 3 * 9u64;
        let fc = 2 * 16 * 10u64;
        assert_eq!(g.flops(), conv + fc + 256 + 256);
    }

    #[test]
    fn consumers_and_producer() {
        let g = tiny_graph();
        let conv_out = g.nodes[0].outputs[0];
        assert_eq!(g.consumers(conv_out), vec![1]);
        assert_eq!(g.producer(conv_out), Some(0));
        assert_eq!(g.producer(g.inputs[0]), None);
    }

    #[test]
    fn infer_split_divisibility() {
        assert!(infer_shapes(&Op::Split { num: 3 }, &[Shape::new(4, 4, 8)]).is_err());
        let out = infer_shapes(&Op::Split { num: 2 }, &[Shape::new(4, 4, 8)]).unwrap();
        assert_eq!(out, vec![Shape::new(4, 4, 4), Shape::new(4, 4, 4)]);
    }

    #[test]
    fn infer_concat_checks_spatial() {
        assert!(infer_shapes(&Op::Concat, &[Shape::new(4, 4, 8), Shape::new(2, 2, 8)]).is_err());
        let out = infer_shapes(&Op::Concat, &[Shape::new(4, 4, 8), Shape::new(4, 4, 4)]).unwrap();
        assert_eq!(out[0], Shape::new(4, 4, 12));
    }

    #[test]
    fn infer_broadcast_elementwise() {
        let out = infer_shapes(
            &Op::ElementWise { kind: EwKind::Mul, with_const: false },
            &[Shape::new(8, 8, 32), Shape::vec(32)],
        )
        .unwrap();
        assert_eq!(out[0], Shape::new(8, 8, 32));
    }

    #[test]
    fn grouped_conv_divisibility_enforced() {
        let op = Op::Conv2D { kh: 3, kw: 3, stride: 1, padding: Padding::Same, out_c: 32, groups: 5 };
        assert!(infer_shapes(&op, &[Shape::new(8, 8, 30)]).is_err());
    }

    #[test]
    fn fingerprint_ignores_name_but_not_structure() {
        let g1 = tiny_graph();
        let mut g2 = tiny_graph();
        g2.name = "renamed".into();
        assert_eq!(g1.fingerprint(), g2.fingerprint());
        // A structural change must move the fingerprint.
        let mut b = GraphBuilder::new("tiny", 8, 8, 3);
        let x = b.input_tensor();
        let t = b.conv(x, 32, 3, 2, Padding::Same); // 32 filters, not 16
        let t = b.relu(t);
        let t = b.mean(t);
        let t = b.fc(t, 10);
        let g3 = b.finish(vec![t]);
        assert_ne!(g1.fingerprint(), g3.fingerprint());
    }

    #[test]
    fn histogram_counts() {
        let g = tiny_graph();
        let h = g.op_type_histogram();
        assert_eq!(h[&OpType::Conv2D], 1);
        assert_eq!(h[&OpType::Activation], 1);
        assert_eq!(h[&OpType::FullyConnected], 1);
    }
}
