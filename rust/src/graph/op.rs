//! Operation taxonomy of the computational graph.
//!
//! Mirrors the TFLite op set covered by the paper (Table 3): convolutions
//! (standard / depthwise / grouped), fully-connected, pooling, mean
//! (global average pooling), concat/split, padding, element-wise binary and
//! unary ops, activations and softmax.

use crate::graph::shape::Shape;

/// Spatial padding policy (TFLite SAME / VALID).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Padding {
    Same,
    Valid,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    Avg,
    Max,
}

/// Element-wise op kinds. The list matches the `IsLinkable` set in TFLite's
/// GPU-delegate fusion pass (Algorithm C.1, line 23), plus Copy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EwKind {
    Add,
    Sub,
    Mul,
    Div,
    Exp,
    Log,
    Sqrt,
    Square,
    Abs,
    Neg,
    Pow,
    Equal,
    Greater,
    Less,
    Maximum,
    Minimum,
    Copy,
}

impl EwKind {
    pub fn name(&self) -> &'static str {
        match self {
            EwKind::Add => "ADD",
            EwKind::Sub => "SUB",
            EwKind::Mul => "MUL",
            EwKind::Div => "DIV",
            EwKind::Exp => "EXP",
            EwKind::Log => "LOG",
            EwKind::Sqrt => "SQRT",
            EwKind::Square => "SQUARE",
            EwKind::Abs => "ABS",
            EwKind::Neg => "NEG",
            EwKind::Pow => "POW",
            EwKind::Equal => "EQUAL",
            EwKind::Greater => "GREATER",
            EwKind::Less => "LESS",
            EwKind::Maximum => "MAXIMUM",
            EwKind::Minimum => "MINIMUM",
            EwKind::Copy => "COPY",
        }
    }
    pub fn all() -> &'static [EwKind] {
        use EwKind::*;
        &[
            Add, Sub, Mul, Div, Exp, Log, Sqrt, Square, Abs, Neg, Pow, Equal, Greater, Less,
            Maximum, Minimum, Copy,
        ]
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActKind {
    Relu,
    Relu6,
    HSwish,
    HSigmoid,
    Sigmoid,
    Swish,
    Tanh,
}

impl ActKind {
    pub fn name(&self) -> &'static str {
        match self {
            ActKind::Relu => "RELU",
            ActKind::Relu6 => "RELU6",
            ActKind::HSwish => "HSWISH",
            ActKind::HSigmoid => "HSIGMOID",
            ActKind::Sigmoid => "SIGMOID",
            ActKind::Swish => "SWISH",
            ActKind::Tanh => "TANH",
        }
    }
}

/// An operation in the computational graph. Weights are not materialized —
/// only their shapes matter for latency (parameter size features).
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Standard 2-D convolution; `groups > 1` makes it a grouped convolution.
    Conv2D {
        kh: usize,
        kw: usize,
        stride: usize,
        padding: Padding,
        out_c: usize,
        groups: usize,
    },
    /// Depthwise convolution (channel multiplier fixed to 1, as in the zoo).
    DepthwiseConv2D {
        kh: usize,
        kw: usize,
        stride: usize,
        padding: Padding,
    },
    FullyConnected {
        out_features: usize,
    },
    Pooling {
        kind: PoolKind,
        kh: usize,
        kw: usize,
        stride: usize,
        padding: Padding,
    },
    /// Global spatial mean (TFLite MEAN over H,W) — used by SE blocks and
    /// classifier heads.
    Mean,
    /// Channel-axis concatenation of >= 2 tensors.
    Concat,
    /// Channel-axis split into `num` equal parts.
    Split {
        num: usize,
    },
    /// Explicit spatial zero-padding.
    Pad {
        pad_h: usize,
        pad_w: usize,
    },
    /// Element-wise op; unary kinds take 1 input, binary kinds take 2
    /// (or 1 input + broadcast constant when `with_const` is set).
    ElementWise {
        kind: EwKind,
        with_const: bool,
    },
    Activation {
        kind: ActKind,
    },
    Softmax,
    /// Flatten HxWxC -> 1x1x(HWC); zero-cost view in TFLite but present in
    /// graphs between conv trunk and FC head.
    Reshape,
}

/// Coarse operation types; one latency predictor is trained per `OpType`
/// per scenario (Section 4.2 / Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpType {
    Conv2D,
    GroupedConv2D,
    DepthwiseConv2D,
    FullyConnected,
    Pooling,
    Mean,
    ConcatSplit,
    Pad,
    ElementWise,
    Activation,
    Softmax,
    Reshape,
}

impl OpType {
    pub fn name(&self) -> &'static str {
        match self {
            OpType::Conv2D => "Conv2D",
            OpType::GroupedConv2D => "GroupedConv2D",
            OpType::DepthwiseConv2D => "DepthwiseConv2D",
            OpType::FullyConnected => "FullyConnected",
            OpType::Pooling => "Pooling",
            OpType::Mean => "Mean",
            OpType::ConcatSplit => "Concat/Split",
            OpType::Pad => "Pad",
            OpType::ElementWise => "ElementWise",
            OpType::Activation => "Activation",
            OpType::Softmax => "Softmax",
            OpType::Reshape => "Reshape",
        }
    }

    pub fn all() -> &'static [OpType] {
        &[
            OpType::Conv2D,
            OpType::GroupedConv2D,
            OpType::DepthwiseConv2D,
            OpType::FullyConnected,
            OpType::Pooling,
            OpType::Mean,
            OpType::ConcatSplit,
            OpType::Pad,
            OpType::ElementWise,
            OpType::Activation,
            OpType::Softmax,
            OpType::Reshape,
        ]
    }
}

impl Op {
    /// The coarse type used to route this op to a latency predictor.
    pub fn op_type(&self) -> OpType {
        match self {
            Op::Conv2D { groups, .. } if *groups > 1 => OpType::GroupedConv2D,
            Op::Conv2D { .. } => OpType::Conv2D,
            Op::DepthwiseConv2D { .. } => OpType::DepthwiseConv2D,
            Op::FullyConnected { .. } => OpType::FullyConnected,
            Op::Pooling { .. } => OpType::Pooling,
            Op::Mean => OpType::Mean,
            Op::Concat | Op::Split { .. } => OpType::ConcatSplit,
            Op::Pad { .. } => OpType::Pad,
            Op::ElementWise { .. } => OpType::ElementWise,
            Op::Activation { .. } => OpType::Activation,
            Op::Softmax => OpType::Softmax,
            Op::Reshape => OpType::Reshape,
        }
    }

    /// Whether TFLite parallelizes this op across CPU threads (Insight 1:
    /// only convolution, depthwise convolution, and fully-connected have
    /// multithreaded implementations).
    pub fn cpu_parallel(&self) -> bool {
        matches!(
            self,
            Op::Conv2D { .. } | Op::DepthwiseConv2D { .. } | Op::FullyConnected { .. }
        )
    }

    /// Whether the GPU-delegate fusion pass may merge this op into its
    /// producer (`IsLinkable`, Algorithm C.1 line 23).
    pub fn is_linkable(&self) -> bool {
        matches!(self, Op::Activation { .. } | Op::ElementWise { .. })
    }

    /// Number of graph inputs this op consumes.
    pub fn arity(&self) -> OpArity {
        match self {
            Op::Concat => OpArity::Variadic,
            Op::ElementWise { kind, with_const } => {
                let binary = matches!(
                    kind,
                    EwKind::Add
                        | EwKind::Sub
                        | EwKind::Mul
                        | EwKind::Div
                        | EwKind::Pow
                        | EwKind::Equal
                        | EwKind::Greater
                        | EwKind::Less
                        | EwKind::Maximum
                        | EwKind::Minimum
                );
                if binary && !with_const {
                    OpArity::Exact(2)
                } else {
                    OpArity::Exact(1)
                }
            }
            _ => OpArity::Exact(1),
        }
    }

    /// Multiply-accumulate-based FLOP count (2 FLOPs per MAC), matching the
    /// convention in the paper's feature table.
    pub fn flops(&self, inputs: &[Shape], outputs: &[Shape]) -> u64 {
        match self {
            Op::Conv2D { kh, kw, groups, .. } => {
                let out = &outputs[0];
                let in_c = inputs[0].c;
                let macs = out.numel() as u64 * (in_c / groups) as u64 * (*kh as u64) * (*kw as u64);
                2 * macs
            }
            Op::DepthwiseConv2D { kh, kw, .. } => {
                let out = &outputs[0];
                2 * out.numel() as u64 * (*kh as u64) * (*kw as u64)
            }
            Op::FullyConnected { out_features } => {
                2 * inputs[0].numel() as u64 * *out_features as u64
            }
            Op::Pooling { kh, kw, .. } => outputs[0].numel() as u64 * (*kh as u64) * (*kw as u64),
            Op::Mean => inputs[0].numel() as u64,
            Op::Concat | Op::Split { .. } | Op::Reshape => 0,
            Op::Pad { .. } => 0,
            Op::ElementWise { .. } => inputs.iter().map(|s| s.numel() as u64).max().unwrap_or(0),
            Op::Activation { kind } => {
                let n = inputs[0].numel() as u64;
                match kind {
                    ActKind::Relu | ActKind::Relu6 => n,
                    ActKind::HSwish | ActKind::HSigmoid => 3 * n,
                    ActKind::Sigmoid | ActKind::Swish | ActKind::Tanh => 4 * n,
                }
            }
            Op::Softmax => 5 * inputs[0].numel() as u64,
        }
    }

    /// Number of learned parameters (weights + biases).
    pub fn param_count(&self, inputs: &[Shape], outputs: &[Shape]) -> u64 {
        match self {
            Op::Conv2D { kh, kw, out_c, groups, .. } => {
                let in_c = inputs[0].c;
                (*kh as u64) * (*kw as u64) * (in_c / groups) as u64 * (*out_c as u64)
                    + *out_c as u64
            }
            Op::DepthwiseConv2D { kh, kw, .. } => {
                let c = outputs[0].c as u64;
                (*kh as u64) * (*kw as u64) * c + c
            }
            Op::FullyConnected { out_features } => {
                inputs[0].numel() as u64 * *out_features as u64 + *out_features as u64
            }
            _ => 0,
        }
    }

    /// Human-readable op name for traces and model files.
    pub fn name(&self) -> String {
        match self {
            Op::Conv2D { kh, kw, groups, .. } if *groups > 1 => {
                format!("GroupedConv2D{kh}x{kw}g{groups}")
            }
            Op::Conv2D { kh, kw, .. } => format!("Conv2D{kh}x{kw}"),
            Op::DepthwiseConv2D { kh, kw, .. } => format!("DepthwiseConv2D{kh}x{kw}"),
            Op::FullyConnected { .. } => "FullyConnected".into(),
            Op::Pooling { kind: PoolKind::Avg, .. } => "AvgPool".into(),
            Op::Pooling { kind: PoolKind::Max, .. } => "MaxPool".into(),
            Op::Mean => "Mean".into(),
            Op::Concat => "Concat".into(),
            Op::Split { num } => format!("Split{num}"),
            Op::Pad { .. } => "Pad".into(),
            Op::ElementWise { kind, .. } => kind.name().into(),
            Op::Activation { kind } => kind.name().into(),
            Op::Softmax => "Softmax".into(),
            Op::Reshape => "Reshape".into(),
        }
    }
}

/// Input arity of an op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpArity {
    Exact(usize),
    /// >= 2 inputs (Concat).
    Variadic,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::shape::Shape;

    #[test]
    fn conv_flops_standard() {
        // 3x3 conv, 16->32 channels, 8x8 output: 2 * 8*8*32 * 16*9
        let op = Op::Conv2D { kh: 3, kw: 3, stride: 1, padding: Padding::Same, out_c: 32, groups: 1 };
        let f = op.flops(&[Shape::new(8, 8, 16)], &[Shape::new(8, 8, 32)]);
        assert_eq!(f, 2 * 8 * 8 * 32 * 16 * 9);
    }

    #[test]
    fn grouped_conv_flops_divide_by_groups() {
        let op1 = Op::Conv2D { kh: 3, kw: 3, stride: 1, padding: Padding::Same, out_c: 32, groups: 1 };
        let op4 = Op::Conv2D { kh: 3, kw: 3, stride: 1, padding: Padding::Same, out_c: 32, groups: 4 };
        let i = [Shape::new(8, 8, 16)];
        let o = [Shape::new(8, 8, 32)];
        assert_eq!(op1.flops(&i, &o), 4 * op4.flops(&i, &o));
    }

    #[test]
    fn depthwise_flops() {
        let op = Op::DepthwiseConv2D { kh: 3, kw: 3, stride: 1, padding: Padding::Same };
        let f = op.flops(&[Shape::new(8, 8, 16)], &[Shape::new(8, 8, 16)]);
        assert_eq!(f, 2 * 8 * 8 * 16 * 9);
    }

    #[test]
    fn op_type_distinguishes_grouped() {
        let op = Op::Conv2D { kh: 3, kw: 3, stride: 1, padding: Padding::Same, out_c: 32, groups: 4 };
        assert_eq!(op.op_type(), OpType::GroupedConv2D);
    }

    #[test]
    fn only_conv_dw_fc_parallel() {
        assert!(Op::FullyConnected { out_features: 10 }.cpu_parallel());
        assert!(!Op::Mean.cpu_parallel());
        assert!(!Op::Softmax.cpu_parallel());
        assert!(!Op::ElementWise { kind: EwKind::Add, with_const: false }.cpu_parallel());
    }

    #[test]
    fn linkable_matches_algorithm_c1() {
        assert!(Op::Activation { kind: ActKind::Relu }.is_linkable());
        assert!(Op::ElementWise { kind: EwKind::Add, with_const: false }.is_linkable());
        assert!(!Op::Concat.is_linkable());
        assert!(!Op::Pooling { kind: PoolKind::Max, kh: 2, kw: 2, stride: 2, padding: Padding::Valid }
            .is_linkable());
    }

    #[test]
    fn binary_ew_arity() {
        assert_eq!(
            Op::ElementWise { kind: EwKind::Add, with_const: false }.arity(),
            OpArity::Exact(2)
        );
        assert_eq!(
            Op::ElementWise { kind: EwKind::Add, with_const: true }.arity(),
            OpArity::Exact(1)
        );
        assert_eq!(Op::ElementWise { kind: EwKind::Sqrt, with_const: false }.arity(), OpArity::Exact(1));
        assert_eq!(Op::Concat.arity(), OpArity::Variadic);
    }

    #[test]
    fn param_counts() {
        let conv = Op::Conv2D { kh: 3, kw: 3, stride: 1, padding: Padding::Same, out_c: 8, groups: 1 };
        assert_eq!(conv.param_count(&[Shape::new(4, 4, 4)], &[Shape::new(4, 4, 8)]), 3 * 3 * 4 * 8 + 8);
        let fc = Op::FullyConnected { out_features: 10 };
        assert_eq!(fc.param_count(&[Shape::new(1, 1, 64)], &[Shape::new(1, 1, 10)]), 64 * 10 + 10);
        assert_eq!(Op::Mean.param_count(&[Shape::new(4, 4, 4)], &[Shape::new(1, 1, 4)]), 0);
    }
}
