//! Model-file (de)serialization — the crate's analogue of `.tflite`.
//!
//! The paper's framework takes a model file produced on a cloud server and
//! predicts latency without touching the device (Section 4). Our model files
//! are JSON documents carrying the full computational graph; `save`/`load`
//! round-trip exactly, so predictions can be made from the file alone.

use crate::graph::op::{ActKind, EwKind, Op, Padding, PoolKind};
use crate::graph::{Graph, Node, Shape, Tensor};
use crate::util::Json;

fn padding_str(p: Padding) -> &'static str {
    match p {
        Padding::Same => "SAME",
        Padding::Valid => "VALID",
    }
}

fn padding_from(s: &str) -> Result<Padding, String> {
    match s {
        "SAME" => Ok(Padding::Same),
        "VALID" => Ok(Padding::Valid),
        _ => Err(format!("bad padding {s}")),
    }
}

fn op_to_json(op: &Op) -> Json {
    match op {
        Op::Conv2D { kh, kw, stride, padding, out_c, groups } => Json::obj(vec![
            ("type", Json::str("CONV_2D")),
            ("kh", Json::num(*kh as f64)),
            ("kw", Json::num(*kw as f64)),
            ("stride", Json::num(*stride as f64)),
            ("padding", Json::str(padding_str(*padding))),
            ("out_c", Json::num(*out_c as f64)),
            ("groups", Json::num(*groups as f64)),
        ]),
        Op::DepthwiseConv2D { kh, kw, stride, padding } => Json::obj(vec![
            ("type", Json::str("DEPTHWISE_CONV_2D")),
            ("kh", Json::num(*kh as f64)),
            ("kw", Json::num(*kw as f64)),
            ("stride", Json::num(*stride as f64)),
            ("padding", Json::str(padding_str(*padding))),
        ]),
        Op::FullyConnected { out_features } => Json::obj(vec![
            ("type", Json::str("FULLY_CONNECTED")),
            ("out", Json::num(*out_features as f64)),
        ]),
        Op::Pooling { kind, kh, kw, stride, padding } => Json::obj(vec![
            (
                "type",
                Json::str(match kind {
                    PoolKind::Avg => "AVERAGE_POOL_2D",
                    PoolKind::Max => "MAX_POOL_2D",
                }),
            ),
            ("kh", Json::num(*kh as f64)),
            ("kw", Json::num(*kw as f64)),
            ("stride", Json::num(*stride as f64)),
            ("padding", Json::str(padding_str(*padding))),
        ]),
        Op::Mean => Json::obj(vec![("type", Json::str("MEAN"))]),
        Op::Concat => Json::obj(vec![("type", Json::str("CONCATENATION"))]),
        Op::Split { num } => Json::obj(vec![
            ("type", Json::str("SPLIT")),
            ("num", Json::num(*num as f64)),
        ]),
        Op::Pad { pad_h, pad_w } => Json::obj(vec![
            ("type", Json::str("PAD")),
            ("pad_h", Json::num(*pad_h as f64)),
            ("pad_w", Json::num(*pad_w as f64)),
        ]),
        Op::ElementWise { kind, with_const } => Json::obj(vec![
            ("type", Json::str("ELEMENTWISE")),
            ("kind", Json::str(kind.name())),
            ("with_const", Json::Bool(*with_const)),
        ]),
        Op::Activation { kind } => Json::obj(vec![
            ("type", Json::str("ACTIVATION")),
            ("kind", Json::str(kind.name())),
        ]),
        Op::Softmax => Json::obj(vec![("type", Json::str("SOFTMAX"))]),
        Op::Reshape => Json::obj(vec![("type", Json::str("RESHAPE"))]),
    }
}

fn ew_from(s: &str) -> Result<EwKind, String> {
    EwKind::all()
        .iter()
        .find(|k| k.name() == s)
        .copied()
        .ok_or_else(|| format!("bad ew kind {s}"))
}

fn act_from(s: &str) -> Result<ActKind, String> {
    [
        ActKind::Relu,
        ActKind::Relu6,
        ActKind::HSwish,
        ActKind::HSigmoid,
        ActKind::Sigmoid,
        ActKind::Swish,
        ActKind::Tanh,
    ]
    .into_iter()
    .find(|k| k.name() == s)
    .ok_or_else(|| format!("bad act kind {s}"))
}

fn op_from_json(j: &Json) -> Result<Op, String> {
    let ty = j.get("type").and_then(Json::as_str).ok_or("op missing type")?;
    let u = |k: &str| -> Result<usize, String> {
        j.get(k).and_then(Json::as_usize).ok_or(format!("op missing {k}"))
    };
    Ok(match ty {
        "CONV_2D" => Op::Conv2D {
            kh: u("kh")?,
            kw: u("kw")?,
            stride: u("stride")?,
            padding: padding_from(j.get("padding").and_then(Json::as_str).ok_or("padding")?)?,
            out_c: u("out_c")?,
            groups: u("groups")?,
        },
        "DEPTHWISE_CONV_2D" => Op::DepthwiseConv2D {
            kh: u("kh")?,
            kw: u("kw")?,
            stride: u("stride")?,
            padding: padding_from(j.get("padding").and_then(Json::as_str).ok_or("padding")?)?,
        },
        "FULLY_CONNECTED" => Op::FullyConnected { out_features: u("out")? },
        "AVERAGE_POOL_2D" | "MAX_POOL_2D" => Op::Pooling {
            kind: if ty == "AVERAGE_POOL_2D" { PoolKind::Avg } else { PoolKind::Max },
            kh: u("kh")?,
            kw: u("kw")?,
            stride: u("stride")?,
            padding: padding_from(j.get("padding").and_then(Json::as_str).ok_or("padding")?)?,
        },
        "MEAN" => Op::Mean,
        "CONCATENATION" => Op::Concat,
        "SPLIT" => Op::Split { num: u("num")? },
        "PAD" => Op::Pad { pad_h: u("pad_h")?, pad_w: u("pad_w")? },
        "ELEMENTWISE" => Op::ElementWise {
            kind: ew_from(j.get("kind").and_then(Json::as_str).ok_or("kind")?)?,
            with_const: matches!(j.get("with_const"), Some(Json::Bool(true))),
        },
        "ACTIVATION" => Op::Activation {
            kind: act_from(j.get("kind").and_then(Json::as_str).ok_or("kind")?)?,
        },
        "SOFTMAX" => Op::Softmax,
        "RESHAPE" => Op::Reshape,
        other => return Err(format!("unknown op type {other}")),
    })
}

/// Serialize a graph to a model-file JSON string.
pub fn to_model_file(g: &Graph) -> String {
    let tensors = g
        .tensors
        .iter()
        .map(|t| {
            Json::arr(vec![
                Json::num(t.shape.h as f64),
                Json::num(t.shape.w as f64),
                Json::num(t.shape.c as f64),
            ])
        })
        .collect();
    let nodes = g
        .nodes
        .iter()
        .map(|n| {
            let mut o = op_to_json(&n.op);
            if let Json::Obj(m) = &mut o {
                m.insert(
                    "inputs".into(),
                    Json::arr(n.inputs.iter().map(|&t| Json::num(t as f64)).collect()),
                );
                m.insert(
                    "outputs".into(),
                    Json::arr(n.outputs.iter().map(|&t| Json::num(t as f64)).collect()),
                );
            }
            o
        })
        .collect();
    Json::obj(vec![
        ("format", Json::str("edgelat-model-v1")),
        ("name", Json::str(g.name.clone())),
        ("tensors", Json::Arr(tensors)),
        ("nodes", Json::Arr(nodes)),
        ("inputs", Json::arr(g.inputs.iter().map(|&t| Json::num(t as f64)).collect())),
        ("outputs", Json::arr(g.outputs.iter().map(|&t| Json::num(t as f64)).collect())),
    ])
    .to_string()
}

/// Parse a model file back into a validated graph.
pub fn from_model_file(s: &str) -> Result<Graph, String> {
    let j = Json::parse(s)?;
    if j.get("format").and_then(Json::as_str) != Some("edgelat-model-v1") {
        return Err("not an edgelat-model-v1 file".into());
    }
    let name = j.get("name").and_then(Json::as_str).unwrap_or("model").to_string();
    let tensors = j
        .get("tensors")
        .and_then(Json::as_arr)
        .ok_or("missing tensors")?
        .iter()
        .enumerate()
        .map(|(id, t)| {
            let a = t.as_arr().ok_or("tensor must be array")?;
            if a.len() != 3 {
                return Err("tensor must be [h,w,c]".to_string());
            }
            Ok(Tensor {
                id,
                shape: Shape::new(
                    a[0].as_usize().ok_or("h")?,
                    a[1].as_usize().ok_or("w")?,
                    a[2].as_usize().ok_or("c")?,
                ),
            })
        })
        .collect::<Result<Vec<_>, String>>()?;
    let ids = |key: &str| -> Result<Vec<usize>, String> {
        j.get(key)
            .and_then(Json::as_arr)
            .ok_or(format!("missing {key}"))?
            .iter()
            .map(|x| x.as_usize().ok_or(format!("bad id in {key}")))
            .collect()
    };
    let nodes = j
        .get("nodes")
        .and_then(Json::as_arr)
        .ok_or("missing nodes")?
        .iter()
        .enumerate()
        .map(|(id, nj)| {
            let op = op_from_json(nj)?;
            let get_ids = |key: &str| -> Result<Vec<usize>, String> {
                nj.get(key)
                    .and_then(Json::as_arr)
                    .ok_or(format!("node missing {key}"))?
                    .iter()
                    .map(|x| x.as_usize().ok_or(format!("bad id in node {key}")))
                    .collect()
            };
            Ok(Node { id, op, inputs: get_ids("inputs")?, outputs: get_ids("outputs")? })
        })
        .collect::<Result<Vec<_>, String>>()?;
    let g = Graph {
        name,
        tensors,
        nodes,
        inputs: ids("inputs")?,
        outputs: ids("outputs")?,
    };
    g.validate()?;
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ActKind, GraphBuilder, Padding};

    fn sample() -> Graph {
        let mut b = GraphBuilder::new("sample", 32, 32, 3);
        let x = b.input_tensor();
        let t = b.conv_act(x, 16, 3, 2, ActKind::Relu6);
        let t = b.inverted_residual(t, 16, 5, 1, 3, true, ActKind::HSwish);
        let parts = b.split(t, 2);
        let a = b.ew_const(EwKind::Abs, parts[0]);
        let t = b.concat(vec![a, parts[1]]);
        let t = b.pad(t, 1);
        let t = b.max_pool(t, 3, 2);
        let t = b.head(t, 10);
        b.finish(vec![t])
    }

    #[test]
    fn roundtrip_preserves_graph() {
        let g = sample();
        let s = to_model_file(&g);
        let back = from_model_file(&s).unwrap();
        assert_eq!(g, back);
    }

    #[test]
    fn rejects_bad_format() {
        assert!(from_model_file("{\"format\":\"bogus\"}").is_err());
        assert!(from_model_file("not json").is_err());
    }

    #[test]
    fn rejects_corrupted_topology() {
        let g = sample();
        let s = to_model_file(&g);
        // Point an input at a tensor that doesn't exist yet.
        let bad = s.replace("\"inputs\":[0]", "\"inputs\":[9999]");
        assert!(from_model_file(&bad).is_err());
    }
}
