//! `edgelat bench` — machine-readable benchmarks of the serving hot
//! paths, written as `BENCH_pipeline.json`.
//!
//! Times the pipeline stages the worker-pool and plan-IR subsystems
//! accelerate: kernel deduction (string-keyed reference vs `plan::lower`
//! into the dense IR), one-time predictor training, single-predict,
//! engine `predict_batch`, predict-over-plan, parallel scenario-sweep
//! profiling, and the evolutionary NAS-search loop (candidates/s plus the
//! plan-cache hit rate it sustains), plus the engine's plan-cache
//! hit/miss counters. The
//! emitted JSON is the artifact the CI bench job uploads and gates on
//! (`scripts/bench_gate.py`). Gated quantities are **ratios between
//! workloads measured back-to-back in the same process** (e.g.
//! batch-predict vs a single-predict loop over the same requests), never
//! absolute wall-clock, so the gate is robust to runner speed.

use crate::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use crate::exec_pool::ExecPool;
use crate::framework::{deduce_units, DeductionMode, ScenarioPredictor};
use crate::graph::Graph;
use crate::plan::{self, LoweredGraph};
use crate::predict::Method;
use crate::profiler::profile_set_with;
use crate::scenario::{Registry, Scenario};
use crate::util::timing::{time_named, Sample};
use crate::util::Json;
use std::hint::black_box;

/// Workload sizes for one bench run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Label recorded in the artifact ("quick" | "full" | "custom").
    pub label: &'static str,
    /// Graphs served through the engine batch benches.
    pub n_batch: usize,
    /// Training NAs profiled for the one-time train.
    pub n_train: usize,
    /// Profiling repetitions per (model, scenario).
    pub runs: usize,
    /// Timed iterations per benchmark.
    pub iters: usize,
    /// Scenarios in the sweep-throughput comparison.
    pub n_sweep: usize,
    /// Graphs profiled per sweep scenario.
    pub sweep_graphs: usize,
    /// Population of the NAS-search throughput stage.
    pub search_pop: usize,
    /// Generations of the NAS-search throughput stage.
    pub search_gens: usize,
    /// Workload seed (timings vary; the workload itself must not).
    pub seed: u64,
    /// Worker threads (engine pool and sweep pool).
    pub threads: usize,
}

fn default_threads() -> usize {
    // Single source of truth: size the bench exactly like the pools it
    // measures.
    ExecPool::default().threads()
}

impl BenchConfig {
    /// CI smoke scale: completes in well under a minute on a laptop.
    pub fn quick() -> BenchConfig {
        BenchConfig {
            label: "quick",
            n_batch: 64,
            n_train: 12,
            runs: 2,
            iters: 3,
            n_sweep: 6,
            sweep_graphs: 8,
            search_pop: 10,
            search_gens: 3,
            seed: 2022,
            threads: default_threads(),
        }
    }

    /// Default scale for local measurement.
    pub fn full() -> BenchConfig {
        BenchConfig {
            label: "full",
            n_batch: 256,
            n_train: 40,
            runs: 5,
            iters: 8,
            n_sweep: 12,
            sweep_graphs: 16,
            search_pop: 24,
            search_gens: 5,
            seed: 2022,
            threads: default_threads(),
        }
    }
}

fn sample_json(s: &Sample) -> Json {
    Json::obj(vec![
        ("name", Json::str(s.name.clone())),
        ("iters", Json::num(s.iters as f64)),
        ("mean_s", Json::num(s.mean_s)),
        ("min_s", Json::num(s.min_s)),
        ("p50_s", Json::num(s.p50_s)),
    ])
}

fn nas_graphs(seed: u64, n: usize) -> Vec<Graph> {
    crate::nas::sample_dataset(seed, n).into_iter().map(|a| a.graph).collect()
}

fn bench_line(samples: &mut Vec<Sample>, s: Sample) {
    println!("{}", s.render());
    samples.push(s);
}

/// Run the suite and return the `BENCH_pipeline.json` document. Prints a
/// human-readable line per bench as it goes.
pub fn run(cfg: &BenchConfig) -> Json {
    let mut samples: Vec<Sample> = Vec::new();

    // --- Registry build: parse the committed device specs and materialize
    // every scenario + the id index. Each iteration re-parses the JSON
    // text into a fresh registry (`Registry::with_builtin` would hit the
    // `builtin_specs()` OnceLock after the first build), so the measured
    // rate is the true cold startup cost of the open device universe;
    // the gate checks the built registry actually yields scenarios.
    let spec_texts: Vec<String> =
        Registry::builtin().specs().iter().map(|s| s.to_json().to_string()).collect();
    let registry_s = time_named("registry/build from specs", cfg.iters * 10, || {
        let mut r = Registry::new();
        for text in &spec_texts {
            r.load_spec_json(text).expect("builtin spec text re-registers");
        }
        black_box(r);
    });
    bench_line(&mut samples, registry_s.clone());
    let registry = Registry::with_builtin();

    let sc_cpu = registry.one_large_core("Snapdragon855").expect("builtin soc");
    let soc = crate::device::soc_by_name("Snapdragon855").expect("known soc");
    let sc_gpu = Scenario::gpu(&soc);
    let pool = ExecPool::new(cfg.threads);
    let mv2 = crate::zoo::mobilenets::mobilenet_v2(1.0);

    // --- Kernel deduction (GPU: fusion + selection): the string-keyed
    // reference path vs lowering into the dense plan IR (the memoized
    // unit the engine actually caches).
    bench_line(
        &mut samples,
        time_named("deduce/mobilenet_v2 gpu full", cfg.iters * 10, || {
            black_box(deduce_units(&sc_gpu, DeductionMode::Full, &mv2));
        }),
    );
    let lower_s = time_named("lower/mobilenet_v2 gpu full", cfg.iters * 10, || {
        black_box(plan::lower(&sc_gpu, DeductionMode::Full, &mv2));
    });
    bench_line(&mut samples, lower_s.clone());
    let mv2_plan_units = plan::lower(&sc_gpu, DeductionMode::Full, &mv2).len();

    // --- One-time profile + train.
    let train_g = nas_graphs(cfg.seed, cfg.n_train);
    let profiles = profile_set_with(&pool, &sc_cpu, &train_g, cfg.seed, cfg.runs);
    bench_line(
        &mut samples,
        time_named("train/gbdt scenario predictor", cfg.iters, || {
            black_box(ScenarioPredictor::train_from(
                &sc_cpu,
                &profiles,
                Method::Gbdt,
                DeductionMode::Full,
                cfg.seed,
                None,
            ));
        }),
    );

    // --- Serving: single-predict loop vs pooled predict_batch over the
    // same requests on the same loaded engine. Warmup fills the sharded
    // deduction memo, so both sides measure the serve path proper and the
    // ratio isolates the pool + cache behaviour the CI gate watches.
    let pred = ScenarioPredictor::train_from(
        &sc_cpu,
        &profiles,
        Method::Gbdt,
        DeductionMode::Full,
        cfg.seed,
        None,
    );
    let bundle = PredictorBundle::from_predictor(&pred).expect("native bundle");
    let engine = EngineBuilder::new().bundle(bundle).threads(cfg.threads).build().expect("engine");
    let workload = nas_graphs(cfg.seed ^ 0xbe9c, cfg.n_batch);
    let reqs: Vec<PredictRequest> =
        workload.iter().map(|g| PredictRequest::new(g, sc_cpu.id.clone())).collect();
    let single = time_named("serve/single-predict x batch", cfg.iters, || {
        for r in &reqs {
            black_box(engine.predict(r).expect("served"));
        }
    });
    bench_line(&mut samples, single.clone());
    let batch = time_named("serve/predict_batch", cfg.iters, || {
        black_box(engine.predict_batch(&reqs));
    });
    bench_line(&mut samples, batch.clone());
    let batch_speedup = single.mean_s / batch.mean_s.max(1e-12);

    // --- Predict-over-plan: the featurize-once hot path. The plans are
    // pre-lowered, so this isolates the dense BucketId model scan the
    // plan IR buys over per-request deduction.
    let plans: Vec<LoweredGraph> = workload.iter().map(|g| pred.lower(g)).collect();
    let plan_scan = time_named("serve/predict_plan x batch", cfg.iters, || {
        for pl in &plans {
            black_box(pred.predict_plan(pl));
        }
    });
    bench_line(&mut samples, plan_scan.clone());
    let plan_scan_speedup = single.mean_s / plan_scan.mean_s.max(1e-12);

    // --- Scenario-sweep throughput: profiling K scenarios one at a time
    // vs fanned out on the pool (the report prefetch pattern).
    let sweep_scenarios: Vec<Scenario> =
        registry.all().iter().take(cfg.n_sweep).map(|s| (**s).clone()).collect();
    let sweep_g = nas_graphs(cfg.seed ^ 0x57ee, cfg.sweep_graphs);
    let seq = ExecPool::new(1);
    let sweep_iters = (cfg.iters / 2).max(1);
    let sweep_seq = time_named("sweep/profile scenarios sequential", sweep_iters, || {
        for sc in &sweep_scenarios {
            black_box(profile_set_with(&seq, sc, &sweep_g, cfg.seed, cfg.runs));
        }
    });
    bench_line(&mut samples, sweep_seq.clone());
    let sweep_par = time_named("sweep/profile scenarios pooled", sweep_iters, || {
        black_box(pool.map(&sweep_scenarios, |_, sc| {
            profile_set_with(&seq, sc, &sweep_g, cfg.seed, cfg.runs)
        }));
    });
    bench_line(&mut samples, sweep_par.clone());
    let sweep_speedup = sweep_seq.mean_s / sweep_par.mean_s.max(1e-12);

    // --- NAS-search throughput: the predictor-in-the-loop workload the
    // paper motivates, driving the loaded engine generation by generation.
    // Candidates/s counts engine predictions served; elite survivors
    // re-scored across generations land in the fingerprint-keyed plan
    // cache, so the stage also isolates the cache's hit rate under
    // realistic sustained traffic.
    let search_cfg = crate::search::SearchConfig {
        seed: cfg.seed,
        population: cfg.search_pop,
        generations: cfg.search_gens,
        ..crate::search::SearchConfig::quick()
    };
    let search_ids = [sc_cpu.id.clone()];
    let cache_before = engine.cache_stats();
    let mut search_evaluated = 0usize;
    let search_s = time_named("search/evolve x generations", (cfg.iters / 2).max(1), || {
        let outcome =
            crate::search::run(&engine, &search_ids, &search_cfg).expect("search served");
        search_evaluated = outcome.candidates_evaluated;
        black_box(outcome);
    });
    bench_line(&mut samples, search_s.clone());
    let cache_after = engine.cache_stats();
    let search_hits = cache_after.hits - cache_before.hits;
    let search_misses = cache_after.misses - cache_before.misses;
    let search_hit_rate = search_hits as f64 / (search_hits + search_misses).max(1) as f64;
    let candidates_per_s = search_evaluated as f64 / search_s.mean_s.max(1e-12);

    let cache = engine.cache_stats();
    Json::obj(vec![
        ("format", Json::str("edgelat.bench")),
        ("version", Json::num(1.0)),
        ("profile", Json::str(cfg.label)),
        ("threads", Json::num(cfg.threads as f64)),
        ("benches", Json::Arr(samples.iter().map(sample_json).collect())),
        (
            "derived",
            Json::obj(vec![
                (
                    // The open device universe: scenarios and SoCs the
                    // built registry serves, plus its build rate. The CI
                    // gate fails on a registry reporting 0 scenarios.
                    "registry",
                    Json::obj(vec![
                        ("scenarios", Json::num(registry.scenario_count() as f64)),
                        ("socs", Json::num(registry.soc_count() as f64)),
                        (
                            "builds_per_s",
                            Json::num(1.0 / registry_s.mean_s.max(1e-12)),
                        ),
                    ]),
                ),
                ("batch_predict_speedup", Json::num(batch_speedup)),
                ("plan_predict_speedup", Json::num(plan_scan_speedup)),
                ("sweep_parallel_speedup", Json::num(sweep_speedup)),
                (
                    // Lowering throughput: graphs (and plan units) lowered
                    // per second at the single-graph bench's rate.
                    "lowering",
                    Json::obj(vec![
                        ("graphs_per_s", Json::num(1.0 / lower_s.mean_s.max(1e-12))),
                        (
                            "units_per_s",
                            Json::num(mv2_plan_units as f64 / lower_s.mean_s.max(1e-12)),
                        ),
                        ("units_per_graph", Json::num(mv2_plan_units as f64)),
                    ]),
                ),
                (
                    // NAS-search throughput over the loaded engine: the
                    // `search --quick` CI smoke gates on candidates/s > 0.
                    "search",
                    Json::obj(vec![
                        ("candidates_per_s", Json::num(candidates_per_s)),
                        ("evaluated", Json::num(search_evaluated as f64)),
                        ("plan_cache_hit_rate", Json::num(search_hit_rate)),
                    ]),
                ),
                (
                    "plan_cache",
                    Json::obj(vec![
                        ("hits", Json::num(cache.hits as f64)),
                        ("misses", Json::num(cache.misses as f64)),
                        ("evictions", Json::num(cache.evictions as f64)),
                        ("shards", Json::num(engine.cache_shards() as f64)),
                    ]),
                ),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_emits_a_valid_gateable_artifact() {
        // Tiny sizes: this validates the artifact contract, not timings.
        let cfg = BenchConfig {
            label: "custom",
            n_batch: 6,
            n_train: 4,
            runs: 1,
            iters: 1,
            n_sweep: 2,
            sweep_graphs: 2,
            search_pop: 4,
            search_gens: 2,
            seed: 7,
            threads: 2,
        };
        let doc = run(&cfg);
        // The document round-trips through the JSON emitter/parser.
        let doc = Json::parse(&doc.to_string()).expect("valid JSON");
        assert_eq!(doc.req_str("format").unwrap(), "edgelat.bench");
        assert_eq!(doc.req_usize("version").unwrap(), 1);
        assert_eq!(doc.req_str("profile").unwrap(), "custom");
        assert_eq!(doc.req_usize("threads").unwrap(), 2);
        let benches = doc.req("benches").unwrap().as_arr().expect("array");
        assert!(benches.len() >= 10, "expected all pipeline benches, got {}", benches.len());
        for b in benches {
            assert!(b.req_str("name").is_ok());
            let mean = b.req_f64("mean_s").unwrap();
            assert!(mean.is_finite() && mean >= 0.0);
        }
        // The lowering stage is present by name (the gate's artifact
        // contract).
        assert!(benches
            .iter()
            .any(|b| b.req_str("name").unwrap().starts_with("lower/")));
        let derived = doc.req("derived").unwrap();
        // The registry-build stage: the open device universe must actually
        // materialize (the gate fails on 0 scenarios).
        assert!(benches.iter().any(|b| b.req_str("name").unwrap().starts_with("registry/")));
        let registry = derived.req("registry").unwrap();
        assert_eq!(registry.req_usize("scenarios").unwrap(), 72);
        assert_eq!(registry.req_usize("socs").unwrap(), 4);
        assert!(registry.req_f64("builds_per_s").unwrap() > 0.0);
        let speedup = derived.req_f64("batch_predict_speedup").unwrap();
        assert!(speedup.is_finite() && speedup > 0.0, "speedup={speedup}");
        assert!(derived.req_f64("plan_predict_speedup").unwrap().is_finite());
        assert!(derived.req_f64("sweep_parallel_speedup").unwrap().is_finite());
        let lowering = derived.req("lowering").unwrap();
        assert!(lowering.req_f64("graphs_per_s").unwrap() > 0.0);
        assert!(lowering.req_f64("units_per_graph").unwrap() > 0.0);
        // The NAS-search stage: throughput is positive and the hit rate
        // is a real rate — the generation loop re-scores elite survivors,
        // and the warmup run primes every plan, so hits must occur.
        let search = derived.req("search").unwrap();
        assert!(search.req_f64("candidates_per_s").unwrap() > 0.0);
        assert!(search.req_f64("evaluated").unwrap() > 0.0);
        let hit_rate = search.req_f64("plan_cache_hit_rate").unwrap();
        assert!((0.0..=1.0).contains(&hit_rate), "hit_rate={hit_rate}");
        assert!(hit_rate > 0.0, "search stage must hit the plan cache");
        assert!(benches.iter().any(|b| b.req_str("name").unwrap().starts_with("search/")));
        let cache = derived.req("plan_cache").unwrap();
        // The serve benches queried the same graphs repeatedly: the
        // sharded memo must have seen real hits.
        assert!(cache.req_f64("hits").unwrap() > 0.0);
        assert!(cache.req_f64("misses").unwrap() > 0.0);
    }
}
