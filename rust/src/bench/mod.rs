//! `edgelat bench` — machine-readable benchmarks of the serving hot
//! paths, written as `BENCH_pipeline.json`.
//!
//! Times the pipeline stages the worker-pool and plan-IR subsystems
//! accelerate: kernel deduction (string-keyed reference vs `plan::lower`
//! into the dense IR), one-time predictor training, single-predict,
//! engine `predict_batch`, predict-over-plan, cold bundle loads (JSON
//! parse vs the zero-copy binary decode of the same models), the
//! compiled LUT tier vs the SoA model scan on identical plan rows
//! (with the measured interpolation error), parallel scenario-sweep
//! profiling, a fleet stage that samples hundreds of synthetic SoC specs
//! (`device::sample_specs`) and drives the vectorized SoA predictor
//! kernels over every resulting scenario (scenarios/s, predictions/s, and
//! the gated vectorized-vs-scalar speedup on identical standardized
//! matrices), and the evolutionary NAS-search loop (candidates/s plus the
//! plan-cache hit rate it sustains), plus the engine's plan-cache
//! hit/miss counters. A final stage boots the `serve` daemon on an
//! ephemeral port around a two-scenario bundle fleet, drives it with the
//! open-loop load generator, and records requests/s, p50/p99 service
//! latency, the mean coalesced batch size and the plan-cache hit rate
//! under concurrent TCP traffic. The
//! A workload stage registers the builtin contention/batch presets plus a
//! sampled workload (`device::sample_workloads`) into the scenario
//! cross-product, re-trains a predictor under every regime
//! (`workload::eval`) and times lower+predict across contended scenarios —
//! `derived.workload` carries the universe size, axis coverage, and the
//! gated max RMSPE. The
//! emitted JSON is the artifact the CI bench job uploads and gates on
//! (`scripts/bench_gate.py`). Gated quantities are **ratios between
//! workloads measured back-to-back in the same process** (e.g.
//! batch-predict vs a single-predict loop over the same requests), never
//! absolute wall-clock, so the gate is robust to runner speed.

use crate::engine::{EngineBuilder, PredictRequest, PredictorBundle};
use crate::exec_pool::ExecPool;
use crate::framework::{deduce_units, DeductionMode, ScenarioPredictor};
use crate::graph::Graph;
use crate::plan::{self, LoweredGraph};
use crate::predict::lut::LutSpec;
use crate::predict::{FeatureMatrix, Method, NativeModel, Regressor};
use crate::profiler::profile_set_with;
use crate::scenario::{Registry, Scenario};
use crate::serve;
use crate::util::timing::{time_named, Sample};
use crate::util::{rmspe_guarded, spearman, Json};
use std::collections::HashMap;
use std::hint::black_box;

/// Workload sizes for one bench run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Label recorded in the artifact ("quick" | "full" | "custom").
    pub label: &'static str,
    /// Graphs served through the engine batch benches.
    pub n_batch: usize,
    /// Training NAs profiled for the one-time train.
    pub n_train: usize,
    /// Profiling repetitions per (model, scenario).
    pub runs: usize,
    /// Timed iterations per benchmark.
    pub iters: usize,
    /// Scenarios in the sweep-throughput comparison.
    pub n_sweep: usize,
    /// Graphs profiled per sweep scenario.
    pub sweep_graphs: usize,
    /// Synthetic SoCs sampled for the fleet stage (`device::sample_specs`).
    pub fleet_socs: usize,
    /// Graphs lowered+predicted per fleet scenario.
    pub fleet_graphs: usize,
    /// Population of the NAS-search throughput stage.
    pub search_pop: usize,
    /// Generations of the NAS-search throughput stage.
    pub search_gens: usize,
    /// Workload seed (timings vary; the workload itself must not).
    pub seed: u64,
    /// Worker threads (engine pool and sweep pool).
    pub threads: usize,
    /// Concurrent connections in the serve-daemon stage.
    pub serve_clients: usize,
    /// Offered load (requests/s) in the serve-daemon stage.
    pub serve_rps: f64,
    /// Duration of the serve-daemon open-loop run, in seconds.
    pub serve_duration_s: f64,
}

fn default_threads() -> usize {
    // Single source of truth: size the bench exactly like the pools it
    // measures.
    ExecPool::default().threads()
}

impl BenchConfig {
    /// CI smoke scale: completes in well under a minute on a laptop.
    pub fn quick() -> BenchConfig {
        BenchConfig {
            label: "quick",
            n_batch: 64,
            n_train: 12,
            runs: 2,
            iters: 3,
            n_sweep: 6,
            sweep_graphs: 8,
            fleet_socs: 100,
            fleet_graphs: 2,
            search_pop: 10,
            search_gens: 3,
            seed: 2022,
            threads: default_threads(),
            serve_clients: 4,
            serve_rps: 600.0,
            serve_duration_s: 0.8,
        }
    }

    /// Default scale for local measurement.
    pub fn full() -> BenchConfig {
        BenchConfig {
            label: "full",
            n_batch: 256,
            n_train: 40,
            runs: 5,
            iters: 8,
            n_sweep: 12,
            sweep_graphs: 16,
            fleet_socs: 300,
            fleet_graphs: 3,
            search_pop: 24,
            search_gens: 5,
            seed: 2022,
            threads: default_threads(),
            serve_clients: 8,
            serve_rps: 2000.0,
            serve_duration_s: 2.0,
        }
    }
}

fn sample_json(s: &Sample) -> Json {
    Json::obj(vec![
        ("name", Json::str(s.name.clone())),
        ("iters", Json::num(s.iters as f64)),
        ("mean_s", Json::num(s.mean_s)),
        ("min_s", Json::num(s.min_s)),
        ("p50_s", Json::num(s.p50_s)),
    ])
}

fn nas_graphs(seed: u64, n: usize) -> Vec<Graph> {
    crate::nas::sample_dataset(seed, n).into_iter().map(|a| a.graph).collect()
}

fn bench_line(samples: &mut Vec<Sample>, s: Sample) {
    println!("{}", s.render());
    samples.push(s);
}

/// Run the suite and return the `BENCH_pipeline.json` document. Prints a
/// human-readable line per bench as it goes.
pub fn run(cfg: &BenchConfig) -> Json {
    let mut samples: Vec<Sample> = Vec::new();

    // --- Registry build: parse the committed device specs and materialize
    // every scenario + the id index. Each iteration re-parses the JSON
    // text into a fresh registry (`Registry::with_builtin` would hit the
    // `builtin_specs()` OnceLock after the first build), so the measured
    // rate is the true cold startup cost of the open device universe;
    // the gate checks the built registry actually yields scenarios.
    let spec_texts: Vec<String> =
        Registry::builtin().specs().iter().map(|s| s.to_json().to_string()).collect();
    let registry_s = time_named("registry/build from specs", cfg.iters * 10, || {
        let mut r = Registry::new();
        for text in &spec_texts {
            r.load_spec_json(text).expect("builtin spec text re-registers");
        }
        black_box(r);
    });
    bench_line(&mut samples, registry_s.clone());
    let registry = Registry::with_builtin();

    let sc_cpu = registry.one_large_core("Snapdragon855").expect("builtin soc");
    let soc = crate::device::soc_by_name("Snapdragon855").expect("known soc");
    let sc_gpu = Scenario::gpu(&soc);
    let pool = ExecPool::new(cfg.threads);
    let mv2 = crate::zoo::mobilenets::mobilenet_v2(1.0);

    // --- Kernel deduction (GPU: fusion + selection): the string-keyed
    // reference path vs lowering into the dense plan IR (the memoized
    // unit the engine actually caches).
    bench_line(
        &mut samples,
        time_named("deduce/mobilenet_v2 gpu full", cfg.iters * 10, || {
            black_box(deduce_units(&sc_gpu, DeductionMode::Full, &mv2));
        }),
    );
    let lower_s = time_named("lower/mobilenet_v2 gpu full", cfg.iters * 10, || {
        black_box(plan::lower(&sc_gpu, DeductionMode::Full, &mv2));
    });
    bench_line(&mut samples, lower_s.clone());
    let mv2_plan_units = plan::lower(&sc_gpu, DeductionMode::Full, &mv2).len();

    // --- One-time profile + train.
    let train_g = nas_graphs(cfg.seed, cfg.n_train);
    let profiles = profile_set_with(&pool, &sc_cpu, &train_g, cfg.seed, cfg.runs);
    bench_line(
        &mut samples,
        time_named("train/gbdt scenario predictor", cfg.iters, || {
            black_box(ScenarioPredictor::train_from(
                &sc_cpu,
                &profiles,
                Method::Gbdt,
                DeductionMode::Full,
                cfg.seed,
                None,
            ));
        }),
    );

    // --- Serving: single-predict loop vs pooled predict_batch over the
    // same requests on the same loaded engine. Warmup fills the sharded
    // deduction memo, so both sides measure the serve path proper and the
    // ratio isolates the pool + cache behaviour the CI gate watches.
    let pred = ScenarioPredictor::train_from(
        &sc_cpu,
        &profiles,
        Method::Gbdt,
        DeductionMode::Full,
        cfg.seed,
        None,
    );
    let bundle = PredictorBundle::from_predictor(&pred).expect("native bundle");
    let engine = EngineBuilder::new().bundle(bundle).threads(cfg.threads).build().expect("engine");
    let workload = nas_graphs(cfg.seed ^ 0xbe9c, cfg.n_batch);
    let reqs: Vec<PredictRequest> =
        workload.iter().map(|g| PredictRequest::new(g, sc_cpu.id.clone())).collect();
    let single = time_named("serve/single-predict x batch", cfg.iters, || {
        for r in &reqs {
            black_box(engine.predict(r).expect("served"));
        }
    });
    bench_line(&mut samples, single.clone());
    let batch = time_named("serve/predict_batch", cfg.iters, || {
        black_box(engine.predict_batch(&reqs));
    });
    bench_line(&mut samples, batch.clone());
    let batch_speedup = single.mean_s / batch.mean_s.max(1e-12);

    // --- Predict-over-plan: the featurize-once hot path. The plans are
    // pre-lowered, so this isolates the dense BucketId model scan the
    // plan IR buys over per-request deduction.
    let plans: Vec<LoweredGraph> = workload.iter().map(|g| pred.lower(g)).collect();
    let plan_scan = time_named("serve/predict_plan x batch", cfg.iters, || {
        for pl in &plans {
            black_box(pred.predict_plan(pl));
        }
    });
    bench_line(&mut samples, plan_scan.clone());
    let plan_scan_speedup = single.mean_s / plan_scan.mean_s.max(1e-12);

    // --- Bundle load: the trained bundle persisted as JSON and as the
    // zero-copy binary format, then cold-loaded from disk back to back.
    // Both sides read + validate the same model arenas; the ratio
    // isolates text parsing vs the sectioned binary decode and the CI
    // gate requires the binary side to be no slower (speedup >= 1).
    let bundle_dir =
        std::env::temp_dir().join(format!("edgelat_bench_bundle_{}", std::process::id()));
    std::fs::create_dir_all(&bundle_dir).expect("mkdir bench bundle dir");
    let json_path = bundle_dir.join("cpu.json");
    let bin_path = bundle_dir.join("cpu.bin");
    let persisted = PredictorBundle::from_predictor(&pred).expect("native bundle");
    persisted.save(&json_path).expect("save json bundle");
    persisted.save_bin(&bin_path).expect("save binary bundle");
    let load_iters = (cfg.iters * 8).max(8);
    let load_json = time_named("bundle/load json", load_iters, || {
        black_box(PredictorBundle::load(&json_path).expect("json bundle loads"));
    });
    bench_line(&mut samples, load_json.clone());
    let load_bin = time_named("bundle/load binary", load_iters, || {
        black_box(PredictorBundle::load_bin(&bin_path).expect("binary bundle loads"));
    });
    bench_line(&mut samples, load_bin.clone());
    let _ = std::fs::remove_dir_all(&bundle_dir);
    let bundle_load_speedup = load_json.min_s / load_bin.min_s.max(1e-12);

    // --- Compiled LUT tier: per-bucket lookup tables compiled over the
    // benched plans themselves, then the same plan rows predicted through
    // the table probe vs the SoA model scan. Calibrating on the benched
    // plans keeps every row in-grid, so the measured error is the
    // interpolation error the compiler already verified against the
    // spec's bound (buckets exceeding it fall back and never serve).
    let lut_spec = LutSpec::default();
    let plan_refs: Vec<&LoweredGraph> = plans.iter().collect();
    let lut_pack = pred.compile_lut(&lut_spec, &plan_refs);
    let lut_rows: usize = plans.iter().map(|pl| pl.len()).sum();
    let lut_soa = time_named("lut/soa model scan", cfg.iters, || {
        for pl in &plans {
            black_box(pred.predict_plan_rows(pl));
        }
    });
    bench_line(&mut samples, lut_soa.clone());
    let lut_fast = time_named("lut/table probe", cfg.iters, || {
        for pl in &plans {
            black_box(pred.predict_plan_rows_lut(pl, Some(&lut_pack)));
        }
    });
    bench_line(&mut samples, lut_fast.clone());
    let lut_vs_soa_speedup = lut_soa.min_s / lut_fast.min_s.max(1e-12);
    let lut_predictions_per_s = lut_rows as f64 / lut_fast.mean_s.max(1e-12);
    let mut lut_max_rel_err = 0.0f64;
    for pl in &plans {
        let base = pred.predict_plan_rows(pl);
        let fast = pred.predict_plan_rows_lut(pl, Some(&lut_pack));
        for (a, b) in base.iter().zip(fast.iter()) {
            lut_max_rel_err = lut_max_rel_err.max((a - b).abs() / a.abs().max(1e-9));
        }
    }

    // --- Scenario-sweep throughput: profiling K scenarios one at a time
    // vs fanned out on the pool (the report prefetch pattern).
    let sweep_scenarios: Vec<Scenario> =
        registry.all().iter().take(cfg.n_sweep).map(|s| (**s).clone()).collect();
    let sweep_g = nas_graphs(cfg.seed ^ 0x57ee, cfg.sweep_graphs);
    let seq = ExecPool::new(1);
    let sweep_iters = (cfg.iters / 2).max(1);
    let sweep_seq = time_named("sweep/profile scenarios sequential", sweep_iters, || {
        for sc in &sweep_scenarios {
            black_box(profile_set_with(&seq, sc, &sweep_g, cfg.seed, cfg.runs));
        }
    });
    bench_line(&mut samples, sweep_seq.clone());
    let sweep_par = time_named("sweep/profile scenarios pooled", sweep_iters, || {
        black_box(pool.map(&sweep_scenarios, |_, sc| {
            profile_set_with(&seq, sc, &sweep_g, cfg.seed, cfg.runs)
        }));
    });
    bench_line(&mut samples, sweep_par.clone());
    let sweep_speedup = sweep_seq.mean_s / sweep_par.mean_s.max(1e-12);

    // --- Fleet stage: a seed-deterministic universe of sampled synthetic
    // SoCs (`device::sample_specs`) registered into a fresh registry, every
    // scenario lowered and evaluated through the trained predictor's
    // vectorized plan path (scenarios/s covers lower + predict). The kernel
    // comparison then gathers every modeled unit row across the fleet's
    // plans into per-bucket standardized dense matrices and times the SoA
    // kernels against the scalar per-row reference on identical inputs —
    // the `vectorized_speedup` ratio the CI gate requires to be >= 1.
    let fleet_specs = crate::device::sample_specs(cfg.seed ^ 0xf1ee7, cfg.fleet_socs);
    let mut fleet_reg = Registry::new();
    for s in &fleet_specs {
        fleet_reg.register_soc(s.clone()).expect("sampled spec registers");
    }
    let fleet_g = nas_graphs(cfg.seed ^ 0xf00d, cfg.fleet_graphs);
    let fleet_iters = (cfg.iters / 2).max(1);
    let fleet_sweep = time_named("fleet/lower+predict universe", fleet_iters, || {
        for sc in fleet_reg.all() {
            for g in &fleet_g {
                let pl = plan::lower(sc, DeductionMode::Full, g);
                black_box(pred.predict_plan_rows(&pl));
            }
        }
    });
    bench_line(&mut samples, fleet_sweep.clone());
    let fleet_scenarios_per_s = fleet_reg.scenario_count() as f64 / fleet_sweep.mean_s.max(1e-12);
    // Standardize once, outside the timers, so both sides measure pure
    // model evaluation on identical inputs. Buckets without a trained
    // native model (fallback or engine-external) are not kernel work.
    let mut agg: Vec<(&NativeModel, usize, Vec<f64>)> = Vec::new();
    {
        let mut slots: HashMap<usize, usize> = HashMap::new();
        let mut scratch = Vec::new();
        for sc in fleet_reg.all() {
            for g in &fleet_g {
                let pl = plan::lower(sc, DeductionMode::Full, g);
                for (b, row) in pl.iter() {
                    let Some(bm) = pred.model(b).and_then(|m| m.as_owned()) else {
                        continue;
                    };
                    let d = bm.feature_dim();
                    if d == 0 || row.len() < d {
                        continue;
                    }
                    let slot = *slots.entry(b.index()).or_insert_with(|| {
                        agg.push((&bm.model, d, Vec::new()));
                        agg.len() - 1
                    });
                    bm.standardizer.transform_into(row, &mut scratch);
                    agg[slot].2.extend_from_slice(&scratch[..d]);
                }
            }
        }
    }
    let fleet_rows: usize = agg.iter().map(|(_, d, m)| m.len() / d).sum();
    assert!(fleet_rows > 0, "fleet stage gathered no modeled unit rows");
    let fleet_vec = time_named("fleet/kernel matrix predict", cfg.iters, || {
        for (model, d, m) in &agg {
            black_box(model.predict(&FeatureMatrix::dense(m, *d)));
        }
    });
    bench_line(&mut samples, fleet_vec.clone());
    let fleet_scalar = time_named("fleet/scalar row predict", cfg.iters, || {
        for (model, d, m) in &agg {
            for row in m.chunks_exact(*d) {
                black_box(model.predict_one(row));
            }
        }
    });
    bench_line(&mut samples, fleet_scalar.clone());
    let fleet_predictions_per_s = fleet_rows as f64 / fleet_vec.mean_s.max(1e-12);
    let vectorized_speedup = fleet_scalar.mean_s / fleet_vec.mean_s.max(1e-12);

    // --- NAS-search throughput: the predictor-in-the-loop workload the
    // paper motivates, driving the loaded engine generation by generation.
    // Candidates/s counts engine predictions served; elite survivors
    // re-scored across generations land in the fingerprint-keyed plan
    // cache, so the stage also isolates the cache's hit rate under
    // realistic sustained traffic.
    let search_cfg = crate::search::SearchConfig {
        seed: cfg.seed,
        population: cfg.search_pop,
        generations: cfg.search_gens,
        ..crate::search::SearchConfig::quick()
    };
    let search_ids = [sc_cpu.id.clone()];
    let cache_before = engine.cache_stats();
    let mut search_evaluated = 0usize;
    let search_s = time_named("search/evolve x generations", (cfg.iters / 2).max(1), || {
        let outcome =
            crate::search::run(&engine, &search_ids, &search_cfg).expect("search served");
        search_evaluated = outcome.candidates_evaluated;
        black_box(outcome);
    });
    bench_line(&mut samples, search_s.clone());
    let cache_after = engine.cache_stats();
    let search_hit_rate = cache_after.delta_since(&cache_before).hit_rate();
    let candidates_per_s = search_evaluated as f64 / search_s.mean_s.max(1e-12);

    // --- Few-shot transfer: adapt the trained CPU bundle to a different
    // builtin SoC from K≈10 profiled target samples and compare against
    // the proxy-only baseline on a held-out eval split. adaptations/s
    // times the whole `transfer::adapt` fit (per-bucket scales + PAV
    // monotone map); the proxy-vs-adapted accuracy deltas are
    // same-process quantities the CI gate compares directly.
    let transfer_src = PredictorBundle::from_predictor(&pred).expect("native bundle");
    let transfer_target = registry.one_large_core("Exynos9820").expect("builtin scenario");
    let transfer_budget = 10usize.min(train_g.len());
    let transfer_graphs = &train_g[..transfer_budget];
    let transfer_profiles =
        profile_set_with(&pool, &transfer_target, transfer_graphs, cfg.seed ^ 0x7a5f, cfg.runs);
    let transfer_eval_g = nas_graphs(cfg.seed ^ 0x77aa, cfg.n_batch.min(16));
    let transfer_eval_profiles =
        profile_set_with(&pool, &transfer_target, &transfer_eval_g, cfg.seed ^ 0x77ab, cfg.runs);
    let transfer_eval_actual: Vec<f64> =
        transfer_eval_profiles.iter().map(|p| p.end_to_end_ms).collect();
    let mut transfer_report = None;
    let transfer_s = time_named("transfer/adapt few-shot", cfg.iters, || {
        transfer_report = Some(
            crate::transfer::adapt(
                &transfer_src,
                &transfer_target,
                transfer_graphs,
                &transfer_profiles,
            )
            .expect("transfer adapt"),
        );
    });
    bench_line(&mut samples, transfer_s.clone());
    let transfer_report = transfer_report.expect("adapt ran");
    let adaptations_per_s = 1.0 / transfer_s.mean_s.max(1e-12);
    let transfer_plans: Vec<LoweredGraph> = transfer_eval_g
        .iter()
        .map(|g| plan::lower(&transfer_target, transfer_src.mode, g))
        .collect();
    let transfer_proxy = crate::transfer::ProxyPredictor::new(&transfer_src).expect("proxy");
    let proxy_pred: Vec<f64> =
        transfer_plans.iter().map(|pl| transfer_proxy.predict_plan(pl)).collect();
    let transfer_pred = transfer_report.bundle.predictor().expect("transfer predictor");
    let adapted_pred: Vec<f64> =
        transfer_plans.iter().map(|pl| transfer_pred.predict_plan(pl)).collect();
    let (transfer_proxy_rmspe, _) = rmspe_guarded(&proxy_pred, &transfer_eval_actual);
    let (transfer_adapted_rmspe, _) = rmspe_guarded(&adapted_pred, &transfer_eval_actual);
    let transfer_proxy_spear = spearman(&proxy_pred, &transfer_eval_actual);
    let transfer_adapted_spear = spearman(&adapted_pred, &transfer_eval_actual);
    // NaN-aware Spearman aggregation (count-and-skip, never average in).
    let transfer_degenerate = [transfer_proxy_spear, transfer_adapted_spear]
        .iter()
        .filter(|v| !v.is_finite())
        .count();

    // --- Contended workload universe: every builtin workload preset plus
    // one sampled workload (`device::sample_workloads`) registered over
    // the builtin SoCs — the batch/contention cross-product the scenario
    // registry enumerates. The sweep re-trains a GBDT under every regime
    // (isolated + each preset) via `workload::eval` and reports the worst
    // per-scenario RMSPE, the accuracy tripwire the CI gate requires to be
    // finite; the predict stage then times lower+predict across one SoC's
    // contended scenarios through a predictor trained *under* a workload,
    // so the extra feature columns flow through the real serving path.
    let mut wl_reg = Registry::with_builtin();
    wl_reg.register_builtin_workloads().expect("builtin presets register");
    for wl in crate::device::sample_workloads(cfg.seed ^ 0x31d, 1) {
        wl_reg.register_workload(wl).expect("sampled workload registers");
    }
    let wl_eval_cfg = crate::workload::eval::EvalConfig {
        seed: cfg.seed,
        n_train: cfg.n_train.min(12),
        n_test: 4,
        runs: cfg.runs.min(2),
        socs: 1,
    };
    let mut wl_report = None;
    let wl_sweep = time_named("workload/contended sweep", 1, || {
        wl_report = Some(crate::workload::eval::run(&wl_eval_cfg));
    });
    bench_line(&mut samples, wl_sweep.clone());
    let wl_report = wl_report.expect("workload sweep ran");
    let wl_sc = registry
        .one_large_core("Snapdragon855")
        .expect("builtin soc")
        .with_workload(std::sync::Arc::new(crate::workload::builtin_presets()[1].clone()));
    let wl_profiles = profile_set_with(&pool, &wl_sc, &train_g, cfg.seed, cfg.runs);
    let wl_pred = ScenarioPredictor::train_from(
        &wl_sc,
        &wl_profiles,
        Method::Gbdt,
        DeductionMode::Full,
        cfg.seed,
        None,
    );
    let wl_contended: Vec<Scenario> = wl_reg
        .all()
        .iter()
        .filter(|s| s.workload.is_some() && s.soc.name == "Snapdragon855")
        .map(|s| (**s).clone())
        .collect();
    assert!(!wl_contended.is_empty(), "workload stage found no contended scenarios");
    let wl_rows: usize = wl_contended
        .iter()
        .map(|sc| {
            fleet_g.iter().map(|g| plan::lower(sc, DeductionMode::Full, g).len()).sum::<usize>()
        })
        .sum();
    let wl_predict = time_named("workload/lower+predict contended", fleet_iters, || {
        for sc in &wl_contended {
            for g in &fleet_g {
                let pl = plan::lower(sc, DeductionMode::Full, g);
                black_box(wl_pred.predict_plan_rows(&pl));
            }
        }
    });
    bench_line(&mut samples, wl_predict.clone());
    let wl_predictions_per_s = wl_rows as f64 / wl_predict.mean_s.max(1e-12);
    // Axis coverage of the registered universe: distinct batch sizes
    // (including the isolated batch-1 baseline) and workloads that perturb
    // the contention axis (co-runner load or a fractional GPU quota).
    let mut wl_batches: std::collections::BTreeSet<usize> =
        wl_reg.workloads().iter().map(|w| w.batch).collect();
    wl_batches.insert(1);
    let wl_contention_axes = wl_reg
        .workloads()
        .iter()
        .filter(|w| w.max_load() > 0.0 || w.gpu_share < 1.0)
        .count();

    // --- Serve daemon: boot the TCP daemon on an ephemeral port around a
    // two-scenario fleet (the GBDT bundle trained above plus a quick GPU
    // Lasso bundle), offer open-loop load with the `serve-bench`
    // generator, and read throughput, tail latency, the mean coalesced
    // batch size, and the plan-cache hit rate under concurrent traffic.
    // All numbers go through the daemon's real TCP + micro-batching path.
    let serve_dir =
        std::env::temp_dir().join(format!("edgelat_bench_serve_{}", std::process::id()));
    std::fs::create_dir_all(&serve_dir).expect("mkdir serve bundle dir");
    PredictorBundle::from_predictor(&pred)
        .expect("native bundle")
        .save(serve_dir.join("cpu.json"))
        .expect("save cpu bundle");
    let gpu_profiles = profile_set_with(&pool, &sc_gpu, &train_g, cfg.seed, cfg.runs);
    let gpu_pred = ScenarioPredictor::train_from(
        &sc_gpu,
        &gpu_profiles,
        Method::Lasso,
        DeductionMode::Full,
        cfg.seed,
        None,
    );
    PredictorBundle::from_predictor(&gpu_pred)
        .expect("native bundle")
        .save(serve_dir.join("gpu.json"))
        .expect("save gpu bundle");
    let fleet = serve::BundleFleet::load(&serve_dir, Some(cfg.threads)).expect("serve fleet");
    let serve_cfg = serve::ServeConfig {
        max_batch: 16,
        max_wait: std::time::Duration::from_micros(300),
        ..serve::ServeConfig::default()
    };
    let srv = serve::Server::bind("127.0.0.1:0".parse().expect("loopback"), serve_cfg, fleet)
        .expect("serve bind");
    let serve_addr = srv.addr();
    let srv_thread = std::thread::spawn(move || srv.run());
    let serve_ids = [sc_cpu.id.clone(), sc_gpu.id.clone()];
    let serve_g = nas_graphs(cfg.seed ^ 0x5e47e, 16);
    let serve_lines: Vec<String> = serve_g
        .iter()
        .enumerate()
        .map(|(i, g)| {
            serve::protocol::predict_line(&serve_ids[i % 2], g, Some(i as u64), None, false)
        })
        .collect();
    let load_cfg = serve::LoadConfig {
        clients: cfg.serve_clients,
        rps: cfg.serve_rps,
        duration: std::time::Duration::from_secs_f64(cfg.serve_duration_s),
    };
    let serve_report = serve::run_load(serve_addr, &load_cfg, &serve_lines).expect("serve load");
    assert!(serve_report.ok > 0, "serve stage produced no successful replies");
    println!(
        "serve/daemon open-loop          {:>8.0} req/s   p50 {:>8.0} us   p99 {:>8.0} us",
        serve_report.requests_per_s, serve_report.p50_us, serve_report.p99_us
    );
    let serve_stats = serve::loadgen::request_stats(serve_addr).expect("serve stats");
    let serve_mean_batch =
        serve_stats.req("batches").and_then(|b| b.req_f64("mean")).unwrap_or(0.0);
    let serve_hit_rate =
        serve_stats.req("plan_cache").and_then(|c| c.req_f64("hit_rate")).unwrap_or(0.0);
    let drain_reply = serve::loadgen::request_drain(serve_addr).expect("serve drain");
    assert_eq!(
        drain_reply.get("ok"),
        Some(&Json::Bool(true)),
        "drain not acknowledged: {}",
        drain_reply.to_string()
    );
    srv_thread.join().expect("serve thread").expect("clean drain summary");
    let _ = std::fs::remove_dir_all(&serve_dir);
    // Non-finite would either emit invalid JSON or sail through a naive
    // gate; -1.0 is visibly out of range for every gated serve quantity.
    let fin = |v: f64| if v.is_finite() { v } else { -1.0 };

    let cache = engine.cache_stats();
    Json::obj(vec![
        ("format", Json::str("edgelat.bench")),
        ("version", Json::num(1.0)),
        ("profile", Json::str(cfg.label)),
        ("threads", Json::num(cfg.threads as f64)),
        ("benches", Json::Arr(samples.iter().map(sample_json).collect())),
        (
            "derived",
            Json::obj(vec![
                (
                    // The open device universe: scenarios and SoCs the
                    // built registry serves, plus its build rate. The CI
                    // gate fails on a registry reporting 0 scenarios.
                    "registry",
                    Json::obj(vec![
                        ("scenarios", Json::num(registry.scenario_count() as f64)),
                        ("socs", Json::num(registry.soc_count() as f64)),
                        (
                            "builds_per_s",
                            Json::num(1.0 / registry_s.mean_s.max(1e-12)),
                        ),
                    ]),
                ),
                ("batch_predict_speedup", Json::num(batch_speedup)),
                ("plan_predict_speedup", Json::num(plan_scan_speedup)),
                ("sweep_parallel_speedup", Json::num(sweep_speedup)),
                (
                    // Cold bundle loads from disk: the binary decode must
                    // beat the JSON parse (the gate fails on speedup < 1).
                    "bundle_load",
                    Json::obj(vec![
                        ("json_ms", Json::num(fin(load_json.min_s * 1e3))),
                        ("bin_ms", Json::num(fin(load_bin.min_s * 1e3))),
                        ("speedup", Json::num(fin(bundle_load_speedup))),
                    ]),
                ),
                (
                    // The compiled LUT tier vs the SoA scan on identical
                    // plan rows. The gate fails on a table probe slower
                    // than the model scan or a measured error above the
                    // compile-time bound.
                    "lut",
                    Json::obj(vec![
                        ("tables", Json::num(lut_pack.coverage() as f64)),
                        ("table_entries", Json::num(lut_pack.table_entries() as f64)),
                        ("predictions_per_s", Json::num(fin(lut_predictions_per_s))),
                        ("lut_vs_soa_speedup", Json::num(fin(lut_vs_soa_speedup))),
                        ("max_rel_err", Json::num(fin(lut_max_rel_err))),
                        ("bound", Json::num(lut_spec.max_rel_err)),
                    ]),
                ),
                (
                    // The fleet stage over the sampled spec universe: the
                    // CI gate fails on non-positive throughput or a
                    // vectorized/scalar ratio below 1.
                    "fleet",
                    Json::obj(vec![
                        ("socs", Json::num(cfg.fleet_socs as f64)),
                        ("scenarios", Json::num(fleet_reg.scenario_count() as f64)),
                        ("graphs", Json::num(cfg.fleet_graphs as f64)),
                        ("unit_rows", Json::num(fleet_rows as f64)),
                        ("scenarios_per_s", Json::num(fin(fleet_scenarios_per_s))),
                        ("predictions_per_s", Json::num(fin(fleet_predictions_per_s))),
                        ("vectorized_speedup", Json::num(fin(vectorized_speedup))),
                    ]),
                ),
                (
                    // Lowering throughput: graphs (and plan units) lowered
                    // per second at the single-graph bench's rate.
                    "lowering",
                    Json::obj(vec![
                        ("graphs_per_s", Json::num(1.0 / lower_s.mean_s.max(1e-12))),
                        (
                            "units_per_s",
                            Json::num(mv2_plan_units as f64 / lower_s.mean_s.max(1e-12)),
                        ),
                        ("units_per_graph", Json::num(mv2_plan_units as f64)),
                    ]),
                ),
                (
                    // NAS-search throughput over the loaded engine: the
                    // `search --quick` CI smoke gates on candidates/s > 0.
                    "search",
                    Json::obj(vec![
                        ("candidates_per_s", Json::num(candidates_per_s)),
                        ("evaluated", Json::num(search_evaluated as f64)),
                        ("plan_cache_hit_rate", Json::num(search_hit_rate)),
                    ]),
                ),
                (
                    // Few-shot transfer: the CI gate fails on non-positive
                    // adaptations/s, an adapted RMSPE above the proxy's,
                    // or an adapted Spearman below the proxy's at the
                    // headline budget.
                    "transfer",
                    Json::obj(vec![
                        ("budget", Json::num(transfer_budget as f64)),
                        ("adaptations_per_s", Json::num(fin(adaptations_per_s))),
                        ("proxy_rmspe", Json::num(fin(transfer_proxy_rmspe))),
                        ("adapted_rmspe", Json::num(fin(transfer_adapted_rmspe))),
                        ("proxy_spearman", Json::num(fin(transfer_proxy_spear))),
                        ("adapted_spearman", Json::num(fin(transfer_adapted_spear))),
                        ("dropped_rows", Json::num(transfer_report.dropped_rows as f64)),
                        ("degenerate_pairs", Json::num(transfer_degenerate as f64)),
                        ("map_knots", Json::num(transfer_report.bundle.map.knots() as f64)),
                    ]),
                ),
                (
                    // The contended workload universe: the CI gate fails
                    // on zero contended scenarios, missing axis coverage,
                    // non-positive throughput, or a non-finite max RMSPE.
                    "workload",
                    Json::obj(vec![
                        ("scenarios", Json::num(wl_reg.scenario_count() as f64)),
                        ("contended_scenarios", Json::num(wl_reg.contended_count() as f64)),
                        ("workloads", Json::num(wl_reg.workload_count() as f64)),
                        ("batch_axes", Json::num(wl_batches.len() as f64)),
                        ("contention_axes", Json::num(wl_contention_axes as f64)),
                        ("unit_rows", Json::num(wl_rows as f64)),
                        ("predictions_per_s", Json::num(fin(wl_predictions_per_s))),
                        ("max_rmspe", Json::num(fin(wl_report.max_rmspe()))),
                        ("eval_rows", Json::num(wl_report.rows.len() as f64)),
                        ("eval_contended", Json::num(wl_report.contended_rows() as f64)),
                    ]),
                ),
                (
                    // The serve daemon under open-loop TCP load: the CI
                    // gate fails on requests_per_s <= 0, mean_batch < 1,
                    // or a non-finite/non-positive p99.
                    "serve",
                    Json::obj(vec![
                        ("requests_per_s", Json::num(fin(serve_report.requests_per_s))),
                        ("p50_us", Json::num(fin(serve_report.p50_us))),
                        ("p99_us", Json::num(fin(serve_report.p99_us))),
                        ("mean_batch", Json::num(fin(serve_mean_batch))),
                        ("plan_cache_hit_rate", Json::num(fin(serve_hit_rate))),
                        ("sent", Json::num(serve_report.sent as f64)),
                        ("ok", Json::num(serve_report.ok as f64)),
                        ("errors", Json::num(serve_report.errors as f64)),
                    ]),
                ),
                (
                    "plan_cache",
                    Json::obj(vec![
                        ("hits", Json::num(cache.hits as f64)),
                        ("misses", Json::num(cache.misses as f64)),
                        ("evictions", Json::num(cache.evictions as f64)),
                        ("shards", Json::num(engine.cache_shards() as f64)),
                    ]),
                ),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_emits_a_valid_gateable_artifact() {
        // Tiny sizes: this validates the artifact contract, not timings.
        let cfg = BenchConfig {
            label: "custom",
            n_batch: 6,
            n_train: 4,
            runs: 1,
            iters: 1,
            n_sweep: 2,
            sweep_graphs: 2,
            fleet_socs: 12,
            fleet_graphs: 2,
            search_pop: 4,
            search_gens: 2,
            seed: 7,
            threads: 2,
            serve_clients: 2,
            serve_rps: 150.0,
            serve_duration_s: 0.4,
        };
        let doc = run(&cfg);
        // The document round-trips through the JSON emitter/parser.
        let doc = Json::parse(&doc.to_string()).expect("valid JSON");
        assert_eq!(doc.req_str("format").unwrap(), "edgelat.bench");
        assert_eq!(doc.req_usize("version").unwrap(), 1);
        assert_eq!(doc.req_str("profile").unwrap(), "custom");
        assert_eq!(doc.req_usize("threads").unwrap(), 2);
        let benches = doc.req("benches").unwrap().as_arr().expect("array");
        assert!(benches.len() >= 10, "expected all pipeline benches, got {}", benches.len());
        for b in benches {
            assert!(b.req_str("name").is_ok());
            let mean = b.req_f64("mean_s").unwrap();
            assert!(mean.is_finite() && mean >= 0.0);
        }
        // The lowering stage is present by name (the gate's artifact
        // contract).
        assert!(benches
            .iter()
            .any(|b| b.req_str("name").unwrap().starts_with("lower/")));
        let derived = doc.req("derived").unwrap();
        // The registry-build stage: the open device universe must actually
        // materialize (the gate fails on 0 scenarios).
        assert!(benches.iter().any(|b| b.req_str("name").unwrap().starts_with("registry/")));
        let registry = derived.req("registry").unwrap();
        assert_eq!(registry.req_usize("scenarios").unwrap(), 72);
        assert_eq!(registry.req_usize("socs").unwrap(), 4);
        assert!(registry.req_f64("builds_per_s").unwrap() > 0.0);
        let speedup = derived.req_f64("batch_predict_speedup").unwrap();
        assert!(speedup.is_finite() && speedup > 0.0, "speedup={speedup}");
        assert!(derived.req_f64("plan_predict_speedup").unwrap().is_finite());
        assert!(derived.req_f64("sweep_parallel_speedup").unwrap().is_finite());
        // The bundle-load stage: both cold loads are live measurements and
        // the ratio is a real finite number. The >= 1 bar is the CI
        // gate's business at CI scale.
        let bundle_load = derived.req("bundle_load").unwrap();
        assert!(bundle_load.req_f64("json_ms").unwrap() > 0.0);
        assert!(bundle_load.req_f64("bin_ms").unwrap() > 0.0);
        let bl = bundle_load.req_f64("speedup").unwrap();
        assert!(bl.is_finite() && bl > 0.0, "bundle_load speedup={bl}");
        assert!(benches.iter().any(|b| b.req_str("name").unwrap().starts_with("bundle/")));
        // The LUT stage: tables actually compiled, rows flowed through the
        // probe, and the measured error respects the compile-time bound
        // (buckets exceeding it must fall back, never serve bad numbers).
        let lut = derived.req("lut").unwrap();
        assert!(lut.req_usize("tables").unwrap() > 0, "no LUT tables compiled");
        assert!(lut.req_usize("table_entries").unwrap() > 0);
        assert!(lut.req_f64("predictions_per_s").unwrap() > 0.0);
        let ls = lut.req_f64("lut_vs_soa_speedup").unwrap();
        assert!(ls.is_finite() && ls > 0.0, "lut_vs_soa_speedup={ls}");
        let err = lut.req_f64("max_rel_err").unwrap();
        let bound = lut.req_f64("bound").unwrap();
        assert!(err.is_finite() && err >= 0.0 && err <= bound, "max_rel_err={err} bound={bound}");
        assert!(benches.iter().any(|b| b.req_str("name").unwrap().starts_with("lut/")));
        // The fleet stage: the sampled universe registered, real unit rows
        // flowed through the kernels, and both throughputs are live
        // measurements. The >= 1 speedup bar is the CI gate's business at
        // CI scale, not this smoke test's — here it just has to be a real
        // finite ratio.
        let fleet = derived.req("fleet").unwrap();
        assert_eq!(fleet.req_usize("socs").unwrap(), 12);
        assert!(fleet.req_usize("scenarios").unwrap() >= 12 * 3);
        assert!(fleet.req_usize("unit_rows").unwrap() > 0);
        assert!(fleet.req_f64("scenarios_per_s").unwrap() > 0.0);
        assert!(fleet.req_f64("predictions_per_s").unwrap() > 0.0);
        let vs = fleet.req_f64("vectorized_speedup").unwrap();
        assert!(vs.is_finite() && vs > 0.0, "vectorized_speedup={vs}");
        assert!(benches.iter().any(|b| b.req_str("name").unwrap().starts_with("fleet/")));
        let lowering = derived.req("lowering").unwrap();
        assert!(lowering.req_f64("graphs_per_s").unwrap() > 0.0);
        assert!(lowering.req_f64("units_per_graph").unwrap() > 0.0);
        // The NAS-search stage: throughput is positive and the hit rate
        // is a real rate — the generation loop re-scores elite survivors,
        // and the warmup run primes every plan, so hits must occur.
        let search = derived.req("search").unwrap();
        assert!(search.req_f64("candidates_per_s").unwrap() > 0.0);
        assert!(search.req_f64("evaluated").unwrap() > 0.0);
        let hit_rate = search.req_f64("plan_cache_hit_rate").unwrap();
        assert!((0.0..=1.0).contains(&hit_rate), "hit_rate={hit_rate}");
        assert!(hit_rate > 0.0, "search stage must hit the plan cache");
        assert!(benches.iter().any(|b| b.req_str("name").unwrap().starts_with("search/")));
        let cache = derived.req("plan_cache").unwrap();
        // The serve benches queried the same graphs repeatedly: the
        // sharded memo must have seen real hits.
        assert!(cache.req_f64("hits").unwrap() > 0.0);
        assert!(cache.req_f64("misses").unwrap() > 0.0);
        // The transfer stage: the adaptation actually ran against a
        // different builtin SoC, the accuracy comparison is live, and the
        // few-shot calibration beats the raw proxy on this same-process
        // eval split (the monotone map fixes the cross-device magnitude
        // bias even at smoke scale).
        let transfer = derived.req("transfer").unwrap();
        assert!(transfer.req_usize("budget").unwrap() >= 1);
        assert!(transfer.req_f64("adaptations_per_s").unwrap() > 0.0);
        let t_proxy = transfer.req_f64("proxy_rmspe").unwrap();
        let t_adapted = transfer.req_f64("adapted_rmspe").unwrap();
        assert!(t_proxy.is_finite() && t_proxy > 0.0, "proxy_rmspe={t_proxy}");
        assert!(t_adapted.is_finite() && t_adapted > 0.0, "adapted_rmspe={t_adapted}");
        assert!(t_adapted < t_proxy, "adapted_rmspe={t_adapted} proxy_rmspe={t_proxy}");
        let t_pspear = transfer.req_f64("proxy_spearman").unwrap();
        let t_aspear = transfer.req_f64("adapted_spearman").unwrap();
        let t_degenerate = transfer.req_usize("degenerate_pairs").unwrap();
        if t_degenerate == 0 {
            assert!(t_aspear >= t_pspear, "adapted={t_aspear} proxy={t_pspear}");
        }
        assert!(transfer.req_usize("map_knots").unwrap() >= 1);
        assert!(benches.iter().any(|b| b.req_str("name").unwrap().starts_with("transfer/")));
        // The workload stage: the contended cross-product actually
        // enumerated (builtin presets + one sampled workload over the 72
        // isolated scenarios), both axes are covered, rows flowed through
        // the contended predict path, and the re-train sweep stayed finite
        // — the accuracy tripwire the CI gate checks.
        let wl = derived.req("workload").unwrap();
        assert_eq!(wl.req_usize("workloads").unwrap(), 4);
        assert_eq!(wl.req_usize("scenarios").unwrap(), 72 * 5);
        assert_eq!(wl.req_usize("contended_scenarios").unwrap(), 72 * 4);
        assert!(wl.req_usize("batch_axes").unwrap() >= 3);
        assert!(wl.req_usize("contention_axes").unwrap() >= 2);
        assert!(wl.req_usize("unit_rows").unwrap() > 0);
        assert!(wl.req_f64("predictions_per_s").unwrap() > 0.0);
        let wl_rmspe = wl.req_f64("max_rmspe").unwrap();
        assert!(wl_rmspe.is_finite() && wl_rmspe >= 0.0, "max_rmspe={wl_rmspe}");
        assert!(wl.req_usize("eval_contended").unwrap() > 0);
        assert!(benches.iter().any(|b| b.req_str("name").unwrap().starts_with("workload/")));
        // The serve-daemon stage: real TCP traffic got through, requests
        // coalesced (mean batch >= 1 whenever any batch flushed), tail
        // latency is a real measurement, and the hit rate is a rate.
        let serve = derived.req("serve").unwrap();
        assert!(serve.req_f64("requests_per_s").unwrap() > 0.0);
        assert!(serve.req_f64("ok").unwrap() > 0.0);
        let mean_batch = serve.req_f64("mean_batch").unwrap();
        assert!(mean_batch >= 1.0, "mean_batch={mean_batch}");
        let p99 = serve.req_f64("p99_us").unwrap();
        assert!(p99.is_finite() && p99 > 0.0, "p99_us={p99}");
        assert!(serve.req_f64("p50_us").unwrap() <= p99);
        let serve_hit = serve.req_f64("plan_cache_hit_rate").unwrap();
        assert!((0.0..=1.0).contains(&serve_hit), "serve hit_rate={serve_hit}");
    }
}
