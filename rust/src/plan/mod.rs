//! The lowered-plan IR: the dense, id-keyed representation of "what will
//! this graph execute under this scenario".
//!
//! The paper's framework predicts `T_overhead + Σ_c f*_c(x_c)` over deduced
//! per-kernel units (Section 4). Deduction is pure in (scenario, mode,
//! graph), so serving systems should pay for it once per architecture and
//! then evaluate any number of per-bucket models against the result —
//! the same featurize-once/predict-many amortization MAPLE-Edge-style
//! runtime predictors and NAS predictor pipelines use. This module makes
//! that representation first-class instead of an ad-hoc
//! `Vec<(String, Vec<f64>)>`:
//!
//! - [`BucketId`] / [`BucketInterner`]: a fixed symbol table mapping bucket
//!   names ("Conv2D", "Winograd", ...) to dense `u32` ids. The bucket
//!   universe is closed (the 12 op types plus the two GPU-only kernel
//!   buckets), so ids are stable across processes and builds of the same
//!   table; `engine::PredictorBundle` serializes the table so a loaded
//!   bundle can verify its buckets resolve to the same symbols.
//! - [`LoweredGraph`]: execution-ordered units, each a `BucketId`, the
//!   selected [`KernelImpl`], and one row in a single flat `f64` feature
//!   arena (row boundaries in `offsets`). No strings, no per-unit `Vec`s —
//!   a plan is cheap to share (`Arc`) and cheap to scan.
//! - [`lower`]: the single entry point that deduces and packs a plan.
//!
//! `framework::deduce_units` remains as the string-keyed reference
//! implementation; `tests/properties.rs` asserts `lower` matches it
//! bit-for-bit across all 72 scenarios and every deduction mode.

use crate::device::Target;
use crate::features::{
    bucket_name_of, conform_conv_kernel_row, cpu_bucket_name, features, kernel_features,
};
use crate::framework::DeductionMode;
use crate::graph::Graph;
use crate::scenario::Scenario;
use crate::tflite::{compile, CompileOptions, KernelImpl};
use std::collections::HashMap;
use std::sync::OnceLock;

/// Dense id of a predictor bucket in the [`BucketInterner`] table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BucketId(u32);

impl BucketId {
    /// Index into tables laid out by the interner (e.g. per-bucket model
    /// vectors).
    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// The raw id value.
    pub fn raw(self) -> u32 {
        self.0
    }
}

/// Bucket string ↔ [`BucketId`] symbol table.
///
/// The universe is closed: every bucket a plan can mention is either an
/// [`OpType`](crate::graph::OpType) name or one of the two GPU-only kernel
/// buckets (`Winograd`, `NaiveGroupedConv2D`). [`interner`] exposes the
/// build-wide table; all `BucketId`s in this crate refer to it.
pub struct BucketInterner {
    names: Vec<&'static str>,
    index: HashMap<&'static str, u32>,
}

impl BucketInterner {
    /// The full bucket universe, in stable id order: the 12 op types of
    /// Table 3 followed by the kernel-selection-only buckets.
    pub fn builtin() -> BucketInterner {
        let mut names: Vec<&'static str> =
            crate::graph::OpType::all().iter().map(|t| t.name()).collect();
        names.push("Winograd");
        names.push("NaiveGroupedConv2D");
        let index = names.iter().enumerate().map(|(i, &n)| (n, i as u32)).collect();
        BucketInterner { names, index }
    }

    /// Number of interned buckets (the width of dense per-bucket tables).
    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Resolve a bucket name to its id.
    pub fn resolve(&self, name: &str) -> Option<BucketId> {
        self.index.get(name).map(|&i| BucketId(i))
    }

    /// The name of an interned bucket.
    pub fn name(&self, id: BucketId) -> &'static str {
        self.names[id.index()]
    }

    /// All bucket names in id order — the serialized form of the table.
    /// `engine::PredictorBundle` writes this so a loader can check that
    /// the bundle's bucket symbols all resolve in the reading build
    /// (resolution itself is by name; ids are re-derived from this table).
    pub fn names(&self) -> &[&'static str] {
        &self.names
    }
}

/// The build-wide bucket symbol table.
pub fn interner() -> &'static BucketInterner {
    static TABLE: OnceLock<BucketInterner> = OnceLock::new();
    TABLE.get_or_init(BucketInterner::builtin)
}

/// A lowered execution plan: the predicted units of one graph under one
/// (scenario, deduction mode), in execution order, over a flat feature
/// arena. Built once by [`lower`], then scanned by every model family —
/// no bucket strings and no per-unit allocations at predict time.
#[derive(Debug, Clone, PartialEq)]
pub struct LoweredGraph {
    buckets: Vec<BucketId>,
    impls: Vec<KernelImpl>,
    /// Flat feature arena; unit `i`'s row is `features[offsets[i] as
    /// usize..offsets[i + 1] as usize]`.
    features: Vec<f64>,
    offsets: Vec<u32>,
}

impl LoweredGraph {
    fn with_capacity(units: usize) -> LoweredGraph {
        let mut offsets = Vec::with_capacity(units + 1);
        offsets.push(0);
        LoweredGraph {
            buckets: Vec::with_capacity(units),
            impls: Vec::with_capacity(units),
            features: Vec::with_capacity(units * 8),
            offsets,
        }
    }

    fn push(&mut self, bucket: BucketId, impl_: KernelImpl, row: &[f64]) {
        self.buckets.push(bucket);
        self.impls.push(impl_);
        self.features.extend_from_slice(row);
        self.offsets.push(self.features.len() as u32);
    }

    /// Number of predicted units.
    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// Bucket of unit `i`.
    pub fn bucket(&self, i: usize) -> BucketId {
        self.buckets[i]
    }

    /// All unit buckets, in execution order.
    pub fn buckets(&self) -> &[BucketId] {
        &self.buckets
    }

    /// Selected kernel implementation of unit `i` (`Generic` on CPU).
    pub fn kernel(&self, i: usize) -> KernelImpl {
        self.impls[i]
    }

    /// Feature row of unit `i`, borrowed from the arena.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.features[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }

    /// Iterate `(bucket, feature row)` in execution order.
    pub fn iter(&self) -> impl Iterator<Item = (BucketId, &[f64])> + '_ {
        self.buckets.iter().enumerate().map(|(i, &b)| (b, self.row(i)))
    }

    /// Total length of the feature arena (all rows, concatenated).
    pub fn arena_len(&self) -> usize {
        self.features.len()
    }

    /// Expand back into the string-keyed tuple form — the compatibility
    /// bridge to pre-plan APIs. Allocates; not for hot paths.
    pub fn to_units(&self) -> Vec<(String, Vec<f64>)> {
        let it = interner();
        (0..self.len())
            .map(|i| (it.name(self.bucket(i)).to_string(), self.row(i).to_vec()))
            .collect()
    }
}

/// Merge the selection-split convolution buckets for the NoSelection
/// ablation. The single copy of the rule — the string-keyed reference
/// path (`framework::deduce_units`) delegates here too.
pub(crate) fn ablate(name: &'static str, mode: DeductionMode) -> &'static str {
    if mode == DeductionMode::NoSelection
        && matches!(name, "Winograd" | "GroupedConv2D" | "NaiveGroupedConv2D")
    {
        "Conv2D"
    } else {
        name
    }
}

/// Lower a graph under a scenario: deduce the predicted units (CPU ops, or
/// GPU kernels via fusion + selection per Section 4.1) and pack them into a
/// [`LoweredGraph`]. Pure in (scenario, mode, graph); `engine` memoizes the
/// result per graph fingerprint and `report` shares one plan across all
/// model families.
pub fn lower(sc: &Scenario, mode: DeductionMode, g: &Graph) -> LoweredGraph {
    let it = interner();
    // Workload-qualified scenarios append [batch, load, share] columns to
    // every row; isolated scenarios keep the original widths, so existing
    // bundles' feature dimensions are untouched.
    let wl_cols = crate::workload::feature_cols(sc);
    match &sc.target {
        Target::Cpu { .. } => {
            let mut plan = LoweredGraph::with_capacity(g.nodes.len());
            for n in &g.nodes {
                let b = it.resolve(cpu_bucket_name(n)).expect("op-type bucket interned");
                let mut f = features(g, n);
                if let Some(cols) = wl_cols {
                    f.extend_from_slice(&cols);
                }
                plan.push(b, KernelImpl::Generic, &f);
            }
            plan
        }
        Target::Gpu { options } => {
            let opts = match mode {
                DeductionMode::Full | DeductionMode::NoSelection => *options,
                DeductionMode::NoFusion => CompileOptions { fusion: false, ..*options },
            };
            // `compile` runs no_fuse + per-kernel selection when fusion is
            // off, which is exactly the NoFusion ablation's deduction.
            let kernels = compile(g, sc.soc.gpu.kind, opts).kernels;
            let mut plan = LoweredGraph::with_capacity(kernels.len());
            for k in &kernels {
                let name = ablate(bucket_name_of(g, k), mode);
                let mut f = kernel_features(g, k);
                if mode == DeductionMode::NoSelection {
                    conform_conv_kernel_row(&mut f);
                }
                if let Some(cols) = wl_cols {
                    f.extend_from_slice(&cols);
                }
                let b = it.resolve(name).expect("kernel bucket interned");
                plan.push(b, k.impl_, &f);
            }
            plan
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario;

    #[test]
    fn interner_covers_the_closed_bucket_universe() {
        let it = interner();
        assert_eq!(it.len(), crate::graph::OpType::all().len() + 2);
        // Round-trip every name.
        for (i, &name) in it.names().iter().enumerate() {
            let id = it.resolve(name).unwrap();
            assert_eq!(id.index(), i);
            assert_eq!(it.name(id), name);
        }
        // Op-type names and the kernel-only buckets are all present.
        for t in crate::graph::OpType::all() {
            assert!(it.resolve(t.name()).is_some(), "{}", t.name());
        }
        assert!(it.resolve("Winograd").is_some());
        assert!(it.resolve("NaiveGroupedConv2D").is_some());
        assert!(it.resolve("NoSuchBucket").is_none());
    }

    #[test]
    fn lower_matches_reference_deduction_cpu_and_gpu() {
        let graphs = [
            crate::zoo::mobilenets::mobilenet_v2(0.5),
            crate::zoo::resnets::resnet(10, 1.0),
        ];
        let socs = crate::device::socs();
        let scenarios =
            [scenario::one_large_core("Snapdragon855").unwrap(), Scenario::gpu(&socs[0])];
        for sc in &scenarios {
            for g in &graphs {
                for mode in
                    [DeductionMode::Full, DeductionMode::NoFusion, DeductionMode::NoSelection]
                {
                    let plan = lower(sc, mode, g);
                    let reference = crate::framework::deduce_units(sc, mode, g);
                    assert_eq!(plan.len(), reference.len(), "{} {}", sc.id, g.name);
                    assert_eq!(plan.to_units(), reference, "{} {}", sc.id, g.name);
                }
            }
        }
    }

    #[test]
    fn rows_are_arena_slices_with_consistent_offsets() {
        let sc = scenario::one_large_core("HelioP35").unwrap();
        let g = crate::zoo::mobilenets::mobilenet_v1(0.25);
        let plan = lower(&sc, DeductionMode::Full, &g);
        assert_eq!(plan.len(), g.nodes.len());
        let total: usize = (0..plan.len()).map(|i| plan.row(i).len()).sum();
        assert_eq!(total, plan.arena_len());
        for (i, (b, row)) in plan.iter().enumerate() {
            assert_eq!(b, plan.bucket(i));
            assert_eq!(row, plan.row(i));
            assert!(!row.is_empty());
        }
        // CPU plans select no GPU kernels.
        assert!((0..plan.len()).all(|i| plan.kernel(i) == KernelImpl::Generic));
    }
}
