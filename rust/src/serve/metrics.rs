//! Daemon observability: lock-free counters + streaming histograms.
//!
//! One [`ServeMetrics`] is shared by every connection handler and the
//! batch flusher. All mutation is relaxed atomics or
//! [`LogHistogram::record`] — nothing on the request path takes a lock or
//! allocates. [`snapshot`](ServeMetrics::snapshot) folds the counters
//! into a plain-value [`MetricsSnapshot`] for the `stats` endpoint and
//! the drain summary; histogram percentiles that would be NaN on an
//! empty histogram are reported as 0.0 there, because the snapshot feeds
//! straight into JSON (where NaN is not a value).

use crate::util::timing::LogHistogram;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Shared serve-daemon counters. Constructed once at bind time.
#[derive(Debug)]
pub struct ServeMetrics {
    started: Instant,
    predict_requests: AtomicU64,
    predict_ok: AtomicU64,
    predict_err: AtomicU64,
    /// Typed submit rejections (queue full / draining) — disjoint from
    /// `predict_err`, which counts engine-side per-item failures.
    rejected: AtomicU64,
    /// Lines that failed to parse into any request.
    malformed: AtomicU64,
    /// stats / reload / drain requests.
    control: AtomicU64,
    connections: AtomicU64,
    reloads: AtomicU64,
    batches: AtomicU64,
    batched_items: AtomicU64,
    max_batch: AtomicU64,
    batch_sizes: LogHistogram,
    service_us: LogHistogram,
}

/// Point-in-time plain-value view of [`ServeMetrics`].
#[derive(Debug, Clone, Copy)]
pub struct MetricsSnapshot {
    pub uptime_s: f64,
    pub predict_requests: u64,
    pub predict_ok: u64,
    pub predict_err: u64,
    pub rejected: u64,
    pub malformed: u64,
    pub control: u64,
    pub connections: u64,
    pub reloads: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub max_batch: u64,
    /// Mean coalesced batch size; 0.0 before the first flush.
    pub mean_batch: f64,
    /// Submit→reply service latency percentiles in µs; 0.0 when no
    /// prediction has completed yet (never NaN — this feeds JSON).
    pub service_p50_us: f64,
    pub service_p95_us: f64,
    pub service_p99_us: f64,
}

impl Default for ServeMetrics {
    fn default() -> ServeMetrics {
        ServeMetrics::new()
    }
}

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        ServeMetrics {
            started: Instant::now(),
            predict_requests: AtomicU64::new(0),
            predict_ok: AtomicU64::new(0),
            predict_err: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            malformed: AtomicU64::new(0),
            control: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_items: AtomicU64::new(0),
            max_batch: AtomicU64::new(0),
            batch_sizes: LogHistogram::new(),
            service_us: LogHistogram::new(),
        }
    }

    pub fn note_predict(&self) {
        self.predict_requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_predict_ok(&self) {
        self.predict_ok.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_predict_err(&self) {
        self.predict_err.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_malformed(&self) {
        self.malformed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_control(&self) {
        self.control.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_reload(&self) {
        self.reloads.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one flushed batch of `n` coalesced predictions.
    pub fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_items.fetch_add(n as u64, Ordering::Relaxed);
        self.max_batch.fetch_max(n as u64, Ordering::Relaxed);
        self.batch_sizes.record(n as f64);
    }

    /// Record one served prediction's submit→reply latency.
    pub fn record_service_us(&self, us: f64) {
        self.service_us.record(us);
    }

    /// Coalesced-batch-size histogram (for the `stats` wire form).
    pub fn batch_hist(&self) -> &LogHistogram {
        &self.batch_sizes
    }

    /// Service-latency histogram in µs (for the `stats` wire form).
    pub fn service_hist(&self) -> &LogHistogram {
        &self.service_us
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let batches = self.batches.load(Ordering::Relaxed);
        let batched_items = self.batched_items.load(Ordering::Relaxed);
        let finite_or_zero = |v: f64| if v.is_finite() { v } else { 0.0 };
        MetricsSnapshot {
            uptime_s: self.started.elapsed().as_secs_f64(),
            predict_requests: self.predict_requests.load(Ordering::Relaxed),
            predict_ok: self.predict_ok.load(Ordering::Relaxed),
            predict_err: self.predict_err.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            malformed: self.malformed.load(Ordering::Relaxed),
            control: self.control.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
            reloads: self.reloads.load(Ordering::Relaxed),
            batches,
            batched_items,
            max_batch: self.max_batch.load(Ordering::Relaxed),
            mean_batch: if batches == 0 {
                0.0
            } else {
                batched_items as f64 / batches as f64
            },
            service_p50_us: finite_or_zero(self.service_us.percentile(0.50)),
            service_p95_us: finite_or_zero(self.service_us.percentile(0.95)),
            service_p99_us: finite_or_zero(self.service_us.percentile(0.99)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_snapshot_is_all_zero_and_json_safe() {
        let m = ServeMetrics::new();
        let s = m.snapshot();
        assert_eq!(s.predict_requests, 0);
        assert_eq!(s.batches, 0);
        // The empty-histogram NaN must not leak into the snapshot: these
        // values are emitted as JSON numbers verbatim.
        assert_eq!(s.mean_batch, 0.0);
        assert_eq!(s.service_p50_us, 0.0);
        assert_eq!(s.service_p99_us, 0.0);
        assert!(s.uptime_s >= 0.0);
    }

    /// Pin the full NaN-when-empty chain: `LogHistogram::percentile` on
    /// an empty histogram is NaN by contract, the snapshot's
    /// `finite_or_zero` guard turns it into 0.0, and the serialized
    /// `stats` fields built from the snapshot (the same shapes
    /// `serve::stats_json` emits for `batches` / `service_us`) render as
    /// valid JSON with no bare `NaN` / `inf` token anywhere.
    #[test]
    fn empty_histogram_snapshot_serializes_without_nan() {
        use crate::util::Json;

        let m = ServeMetrics::new();
        // The raw contract this module guards against: empty → NaN.
        assert!(m.service_hist().percentile(0.50).is_nan());
        assert!(m.batch_hist().percentile(0.99).is_nan());

        let s = m.snapshot();
        let batch_hist: Vec<Json> = m
            .batch_hist()
            .nonzero_buckets()
            .into_iter()
            .map(|(edge, n)| Json::arr(vec![Json::num(edge), Json::num(n as f64)]))
            .collect();
        let doc = Json::obj(vec![
            (
                "batches",
                Json::obj(vec![
                    ("count", Json::num(s.batches as f64)),
                    ("items", Json::num(s.batched_items as f64)),
                    ("mean", Json::num(s.mean_batch)),
                    ("max", Json::num(s.max_batch as f64)),
                    ("hist", Json::Arr(batch_hist)),
                ]),
            ),
            (
                "service_us",
                Json::obj(vec![
                    ("count", Json::num(m.service_hist().count() as f64)),
                    ("p50", Json::num(s.service_p50_us)),
                    ("p95", Json::num(s.service_p95_us)),
                    ("p99", Json::num(s.service_p99_us)),
                ]),
            ),
        ]);
        let text = doc.to_string();
        assert!(!text.contains("NaN"), "bare NaN leaked into stats JSON: {text}");
        assert!(!text.contains("inf"), "bare inf leaked into stats JSON: {text}");
        let back = Json::parse(&text).expect("empty-histogram stats must stay valid JSON");
        assert_eq!(back.req("service_us").unwrap().req_f64("p50").unwrap(), 0.0);
        assert_eq!(back.req("batches").unwrap().req_f64("mean").unwrap(), 0.0);
    }

    #[test]
    fn batch_and_service_accounting() {
        let m = ServeMetrics::new();
        m.record_batch(4);
        m.record_batch(8);
        for us in [100.0, 200.0, 400.0] {
            m.record_service_us(us);
        }
        m.note_predict();
        m.note_predict_ok();
        let s = m.snapshot();
        assert_eq!(s.batches, 2);
        assert_eq!(s.batched_items, 12);
        assert_eq!(s.max_batch, 8);
        assert_eq!(s.mean_batch, 6.0);
        assert!(s.service_p50_us >= 200.0 && s.service_p50_us <= 220.0, "{}", s.service_p50_us);
        assert!(s.service_p99_us >= 400.0);
        assert_eq!(s.predict_requests, 1);
        assert_eq!(s.predict_ok, 1);
        assert_eq!(m.service_hist().count(), 3);
        assert_eq!(m.batch_hist().count(), 2);
    }
}
