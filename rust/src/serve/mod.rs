//! `edgelat serve`: a persistent micro-batching prediction daemon.
//!
//! The offline CLI pays bundle load + plan lowering on every invocation;
//! an edge deployment asking "how fast is this candidate architecture on
//! that phone?" thousands of times (NAS search loops, fleet schedulers)
//! wants those costs paid once. This subsystem keeps a
//! [`LatencyEngine`](crate::engine::LatencyEngine) resident behind a
//! line-oriented JSON-over-TCP protocol and coalesces concurrent
//! requests into `predict_batch` calls so the fingerprint-keyed plan
//! cache and the `ExecPool` amortize across clients.
//!
//! Layout:
//! - [`protocol`] — the wire format: request parsing, typed error codes,
//!   reply rendering, client-side line builders.
//! - [`fleet`] — [`BundleFleet`]: a directory of predictor bundles as one
//!   hot-reloadable engine (build-then-swap, in-flight work keeps its
//!   generation).
//! - [`batcher`] — [`MicroBatcher`]: bounded queue coalescing requests,
//!   flush on size or deadline, per-slot error containment.
//! - [`metrics`] — [`ServeMetrics`]: lock-free counters + streaming
//!   latency/batch histograms for the `stats` verb.
//! - [`loadgen`] — open-loop load generator backing `edgelat serve-bench`
//!   and the bench pipeline's serve stage.
//!
//! Threading: one accept loop (this module), one connection-reader and
//! one connection-writer thread per client, one batch flusher. A reader
//! parses and enqueues; the writer drains an ordered channel of
//! ready-or-pending replies, so pipelined requests on one connection are
//! answered strictly in order even though predictions complete on the
//! flusher thread.
//!
//! Shutdown (`drain`): stop accepting, reject new submits with a typed
//! `draining` error, flush everything already queued, give open
//! connections a grace period, then force-close stragglers. Every
//! accepted prediction is answered before the daemon exits.

pub mod batcher;
pub mod fleet;
pub mod loadgen;
pub mod metrics;
pub mod protocol;

pub use batcher::{BatchConfig, JobResult, MicroBatcher, PredictJob};
pub use fleet::BundleFleet;
pub use loadgen::{run_load, LoadConfig, LoadReport};
pub use metrics::{MetricsSnapshot, ServeMetrics};

use crate::engine::EngineError;
use crate::util::Json;
use std::collections::HashMap;
use std::fmt;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use protocol::{engine_error_code, WireError};

/// Errors from the serving subsystem (daemon setup, fleet loading, load
/// generation). Per-request failures travel as typed wire errors instead.
#[derive(Debug, Clone)]
pub enum ServeError {
    /// Socket / filesystem failures, with context.
    Io(String),
    /// Bad daemon configuration: empty bundle dir, corrupt bundle, bad
    /// flag combinations.
    Config(String),
    /// Engine construction failed.
    Engine(EngineError),
    /// A submit was rejected because the queue is at capacity.
    Overloaded,
    /// A submit was rejected because the daemon is draining.
    Draining,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Io(s) => write!(f, "io error: {s}"),
            ServeError::Config(s) => write!(f, "{s}"),
            ServeError::Engine(e) => write!(f, "engine error: {e}"),
            ServeError::Overloaded => write!(f, "server overloaded (queue full)"),
            ServeError::Draining => write!(f, "server is draining"),
        }
    }
}

/// Daemon tuning knobs. `Default` is sized for a small edge box: batches
/// of up to 32 with a 1 ms coalescing window keep single-request latency
/// interactive while still amortizing bursts.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Flush a batch at this many coalesced requests.
    pub max_batch: usize,
    /// Flush a batch when its oldest request has waited this long.
    pub max_wait: Duration,
    /// Reject (`overloaded`) submits beyond this queue depth.
    pub queue_cap: usize,
    /// How long `drain` waits for open connections to finish before
    /// force-closing them.
    pub drain_grace: Duration,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(1000),
            queue_cap: 1024,
            drain_grace: Duration::from_secs(2),
        }
    }
}

/// What the daemon did over its lifetime, returned by [`Server::run`]
/// after a clean drain.
#[derive(Debug, Clone, Copy)]
pub struct ServeSummary {
    pub served_ok: u64,
    pub served_err: u64,
    pub malformed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub reloads: u64,
    pub uptime_s: f64,
}

/// State shared by the accept loop, every connection and the flusher.
struct Shared {
    fleet: BundleFleet,
    batcher: MicroBatcher,
    metrics: ServeMetrics,
    draining: AtomicBool,
    /// Clones of live connection sockets, for forced shutdown at the end
    /// of the drain grace period.
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_conn_id: AtomicU64,
}

/// A bound (but not yet running) serve daemon.
pub struct Server {
    shared: Arc<Shared>,
    listener: TcpListener,
    addr: SocketAddr,
    drain_grace: Duration,
}

impl Server {
    /// Bind the listener (port 0 picks an ephemeral port — read it back
    /// with [`addr`](Server::addr)) around an already-loaded fleet.
    pub fn bind(
        addr: SocketAddr,
        cfg: ServeConfig,
        fleet: BundleFleet,
    ) -> Result<Server, ServeError> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| ServeError::Io(format!("binding {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| ServeError::Io(format!("local_addr: {e}")))?;
        Ok(Server {
            shared: Arc::new(Shared {
                fleet,
                batcher: MicroBatcher::new(BatchConfig {
                    max_batch: cfg.max_batch,
                    max_wait: cfg.max_wait,
                    queue_cap: cfg.queue_cap,
                }),
                metrics: ServeMetrics::new(),
                draining: AtomicBool::new(false),
                conns: Mutex::new(HashMap::new()),
                next_conn_id: AtomicU64::new(1),
            }),
            listener,
            addr: local,
            drain_grace: cfg.drain_grace,
        })
    }

    /// The actually-bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Scenario ids the daemon's live engine serves.
    pub fn scenario_ids(&self) -> Vec<String> {
        self.shared.fleet.scenario_ids()
    }

    /// Serve until a client sends `drain`, then flush and return the
    /// lifetime summary. Consumes the server; run it on its own thread
    /// when the caller needs to keep going (the integration tests and the
    /// bench stage do exactly that).
    pub fn run(self) -> Result<ServeSummary, ServeError> {
        let Server { shared, listener, addr, drain_grace } = self;
        listener
            .set_nonblocking(true)
            .map_err(|e| ServeError::Io(format!("nonblocking accept on {addr}: {e}")))?;
        let flusher = {
            let sh = Arc::clone(&shared);
            std::thread::spawn(move || sh.batcher.run_flusher(&sh.fleet, &sh.metrics))
        };
        let mut handlers = Vec::new();
        while !shared.draining.load(Ordering::Acquire) {
            match listener.accept() {
                Ok((sock, _peer)) => {
                    // Accepted sockets must block: the reader parks on
                    // read_line and the drain path unblocks it by
                    // shutting the socket down.
                    sock.set_nonblocking(false).ok();
                    sock.set_nodelay(true).ok();
                    let id = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
                    if let Ok(clone) = sock.try_clone() {
                        shared.conns.lock().unwrap().insert(id, clone);
                    }
                    shared.metrics.note_connection();
                    let sh = Arc::clone(&shared);
                    handlers.push(std::thread::spawn(move || handle_conn(&sh, id, sock)));
                }
                // WouldBlock is the idle case; transient accept errors
                // (e.g. ECONNABORTED) must not kill the daemon either.
                Err(_) => std::thread::sleep(Duration::from_millis(2)),
            }
        }
        drop(listener); // stop accepting: connect() now fails fast
        let deadline = Instant::now() + drain_grace;
        while Instant::now() < deadline {
            if shared.conns.lock().unwrap().is_empty() {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        // Force-close stragglers; their readers wake with EOF/error and
        // the handlers unwind through the normal path.
        for (_, s) in shared.conns.lock().unwrap().drain() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        for h in handlers {
            let _ = h.join();
        }
        // Idempotent if the drain verb already stopped the batcher; also
        // covers the (unreachable today) path where the loop exits
        // without one. The flusher answers everything queued, then exits.
        shared.batcher.begin_drain();
        let _ = flusher.join();
        let m = shared.metrics.snapshot();
        Ok(ServeSummary {
            served_ok: m.predict_ok,
            served_err: m.predict_err,
            malformed: m.malformed,
            batches: m.batches,
            mean_batch: m.mean_batch,
            reloads: m.reloads,
            uptime_s: m.uptime_s,
        })
    }
}

/// A reply slot in a connection's ordered outgoing queue: either already
/// rendered, or waiting on the flusher.
enum Outgoing {
    Ready(String),
    Pending {
        rx: Receiver<JobResult>,
        id: Option<Json>,
        scenario_id: String,
        detail: bool,
    },
}

/// Per-connection reader: parse each line, resolve it to an [`Outgoing`],
/// and feed the writer thread. Ordering is the channel's FIFO — replies
/// leave in request order no matter when predictions complete.
fn handle_conn(sh: &Arc<Shared>, conn_id: u64, sock: TcpStream) {
    let (out_tx, out_rx) = channel::<Outgoing>();
    let writer = match sock.try_clone() {
        Ok(w) => std::thread::spawn(move || write_loop(w, out_rx)),
        Err(_) => {
            sh.conns.lock().unwrap().remove(&conn_id);
            return;
        }
    };
    let mut rd = BufReader::new(sock);
    let mut line = String::new();
    loop {
        line.clear();
        match rd.read_line(&mut line) {
            Ok(0) | Err(_) => break, // client hung up or drain closed us
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue; // blank keep-alive lines are not an error
        }
        if out_tx.send(handle_line(sh, trimmed)).is_err() {
            break; // writer died (socket gone): no point parsing more
        }
    }
    drop(out_tx); // writer drains what's queued, then exits
    let _ = writer.join();
    sh.conns.lock().unwrap().remove(&conn_id);
}

/// Dispatch one request line. Never panics, never drops the connection:
/// every outcome — including unparseable garbage — is a reply line.
fn handle_line(sh: &Arc<Shared>, line: &str) -> Outgoing {
    let req = match protocol::parse_request(line) {
        Ok(r) => r,
        Err(e) => {
            sh.metrics.note_malformed();
            return Outgoing::Ready(protocol::render_error(&e));
        }
    };
    match req {
        protocol::Request::Stats => {
            sh.metrics.note_control();
            Outgoing::Ready(protocol::render_stats(stats_json(sh)))
        }
        protocol::Request::Reload => {
            sh.metrics.note_control();
            match sh.fleet.reload() {
                Ok((generation, bundles, ids)) => {
                    sh.metrics.note_reload();
                    Outgoing::Ready(protocol::render_reload(generation, bundles, &ids))
                }
                Err(e) => Outgoing::Ready(protocol::render_error(&WireError::new(
                    "reload_failed",
                    e.to_string(),
                ))),
            }
        }
        protocol::Request::Drain => {
            sh.metrics.note_control();
            sh.draining.store(true, Ordering::Release);
            sh.batcher.begin_drain();
            Outgoing::Ready(protocol::render_drain(sh.metrics.snapshot().predict_ok))
        }
        protocol::Request::Predict(w) => {
            sh.metrics.note_predict();
            let protocol::PredictWire { id, scenario_id, method, graph, detail } = *w;
            match sh.batcher.submit(PredictJob {
                graph,
                scenario_id: scenario_id.clone(),
                method,
            }) {
                Ok(rx) => Outgoing::Pending { rx, id, scenario_id, detail },
                Err(e) => {
                    sh.metrics.note_rejected();
                    let code = match e {
                        ServeError::Overloaded => "overloaded",
                        ServeError::Draining => "draining",
                        _ => "internal",
                    };
                    Outgoing::Ready(protocol::render_error(&WireError::with_id(
                        code,
                        e.to_string(),
                        id,
                    )))
                }
            }
        }
    }
}

/// Per-connection writer: drain the ordered reply queue, blocking on
/// pending slots so replies keep request order.
fn write_loop(sock: TcpStream, rx: Receiver<Outgoing>) {
    let mut w = BufWriter::new(sock);
    for item in rx {
        let line = match item {
            Outgoing::Ready(s) => s,
            Outgoing::Pending { rx, id, scenario_id, detail } => match rx.recv() {
                Ok(Ok(resp)) => protocol::render_predict(id.as_ref(), &scenario_id, detail, &resp),
                Ok(Err(e)) => protocol::render_error(&WireError::with_id(
                    engine_error_code(&e),
                    e.to_string(),
                    id,
                )),
                // The flusher dropped the sender without answering — only
                // possible if the daemon is being torn down around us.
                Err(_) => protocol::render_error(&WireError::with_id(
                    "internal",
                    "prediction dropped (server shutting down)",
                    id,
                )),
            },
        };
        if w.write_all(line.as_bytes()).is_err()
            || w.write_all(b"\n").is_err()
            || w.flush().is_err()
        {
            break;
        }
    }
}

/// The `stats` document: counters, coalescing histogram, plan-cache
/// stats, service percentiles. Every number is finite (the snapshot and
/// `CacheStats::hit_rate` both guard the empty cases) — NaN would emit
/// invalid JSON.
fn stats_json(sh: &Shared) -> Json {
    let m = sh.metrics.snapshot();
    let cache = sh.fleet.plan_cache_stats();
    let batch_hist: Vec<Json> = sh
        .metrics
        .batch_hist()
        .nonzero_buckets()
        .into_iter()
        .map(|(edge, n)| Json::arr(vec![Json::num(edge), Json::num(n as f64)]))
        .collect();
    Json::obj(vec![
        ("uptime_s", Json::num(m.uptime_s)),
        ("generation", Json::num(sh.fleet.generation() as f64)),
        (
            "scenarios",
            Json::Arr(sh.fleet.scenario_ids().into_iter().map(Json::str).collect()),
        ),
        ("queue_len", Json::num(sh.batcher.queue_len() as f64)),
        ("draining", Json::Bool(sh.draining.load(Ordering::Acquire))),
        ("connections", Json::num(m.connections as f64)),
        ("reloads", Json::num(m.reloads as f64)),
        (
            "requests",
            Json::obj(vec![
                ("predict", Json::num(m.predict_requests as f64)),
                ("ok", Json::num(m.predict_ok as f64)),
                ("errors", Json::num(m.predict_err as f64)),
                ("rejected", Json::num(m.rejected as f64)),
                ("malformed", Json::num(m.malformed as f64)),
                ("control", Json::num(m.control as f64)),
            ]),
        ),
        (
            "batches",
            Json::obj(vec![
                ("count", Json::num(m.batches as f64)),
                ("items", Json::num(m.batched_items as f64)),
                ("mean", Json::num(m.mean_batch)),
                ("max", Json::num(m.max_batch as f64)),
                ("hist", Json::Arr(batch_hist)),
            ]),
        ),
        (
            "service_us",
            Json::obj(vec![
                ("count", Json::num(sh.metrics.service_hist().count() as f64)),
                ("p50", Json::num(m.service_p50_us)),
                ("p95", Json::num(m.service_p95_us)),
                ("p99", Json::num(m.service_p99_us)),
            ]),
        ),
        (
            "plan_cache",
            Json::obj(vec![
                ("hits", Json::num(cache.hits as f64)),
                ("misses", Json::num(cache.misses as f64)),
                ("evictions", Json::num(cache.evictions as f64)),
                ("hit_rate", Json::num(cache.hit_rate())),
            ]),
        ),
        (
            // The compiled LUT predictor tier (`--lut`): counters over
            // the fleet's lifetime, reload-surviving like plan_cache.
            // All zero (enabled=false) when serving without the tier.
            "lut",
            {
                let lut = sh.fleet.lut_counts();
                Json::obj(vec![
                    ("enabled", Json::Bool(sh.fleet.lut_enabled())),
                    ("lookups", Json::num(lut.lookups as f64)),
                    ("interpolations", Json::num(lut.interpolations as f64)),
                    ("fallbacks", Json::num(lut.fallbacks as f64)),
                ])
            },
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_error_display_is_specific() {
        assert_eq!(ServeError::Overloaded.to_string(), "server overloaded (queue full)");
        assert_eq!(ServeError::Draining.to_string(), "server is draining");
        assert!(ServeError::Io("reading bundle dir /x: gone".into()).to_string().contains("/x"));
        assert_eq!(
            ServeError::Config("no *.json or *.bin predictor bundles in /y".into()).to_string(),
            "no *.json or *.bin predictor bundles in /y"
        );
    }

    #[test]
    fn serve_config_default_is_sane() {
        let d = ServeConfig::default();
        assert_eq!(d.max_batch, 32);
        assert_eq!(d.max_wait, Duration::from_micros(1000));
        assert!(d.queue_cap >= d.max_batch);
        assert!(d.drain_grace > Duration::from_millis(100));
    }
}
