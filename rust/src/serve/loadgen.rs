//! Synthetic open-loop load generator for the serve daemon.
//!
//! Open-loop means arrivals are scheduled on a fixed cadence derived from
//! the target rate, **not** gated on the previous reply — a server that
//! falls behind keeps receiving requests and the measured latency
//! includes its queueing, which is the number an edge deployment actually
//! cares about (closed-loop generators hide overload by slowing down with
//! the server — the classic coordinated-omission trap).
//!
//! Each client owns one connection, a writer thread on the cadence and a
//! reader thread. The protocol guarantees in-order replies per
//! connection, so the reader matches reply `k` to send-instant `k`
//! without correlation ids, and every reply's latency streams into one
//! shared [`LogHistogram`](crate::util::timing::LogHistogram) — constant
//! memory at any request count.
//!
//! Also here: one-shot helpers ([`request_stats`], [`request_reload`],
//! [`request_drain`], [`request_line`]) used by `serve-bench`, the bench
//! pipeline stage and the integration tests to speak single control
//! requests without hand-rolling sockets each time.

use crate::util::timing::LogHistogram;
use crate::util::Json;
use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::ServeError;

/// Open-loop load shape.
#[derive(Debug, Clone, Copy)]
pub struct LoadConfig {
    /// Concurrent connections (clamped to ≥ 1).
    pub clients: usize,
    /// Aggregate target request rate across all clients, in requests/s.
    pub rps: f64,
    /// How long to keep offering load.
    pub duration: Duration,
}

/// What an open-loop run measured.
#[derive(Debug, Clone, Copy)]
pub struct LoadReport {
    pub sent: u64,
    /// Replies with `ok:true`.
    pub ok: u64,
    /// Replies with `ok:false` or that failed to parse, plus reply slots
    /// lost to read errors/timeouts.
    pub errors: u64,
    pub elapsed_s: f64,
    /// Completed-`ok` throughput over the whole run.
    pub requests_per_s: f64,
    /// Send→reply latency percentiles in µs. NaN when no reply was
    /// measured — deliberately poisonous, so a gate on these fields fails
    /// loudly instead of passing on an empty run.
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

/// Offer `lines` (round-robin across clients and time) to `addr` at
/// `cfg`'s aggregate rate and measure send→reply latency. Connects every
/// client up front so a dead daemon fails fast instead of producing a
/// zero-reply report.
pub fn run_load(
    addr: SocketAddr,
    cfg: &LoadConfig,
    lines: &[String],
) -> Result<LoadReport, ServeError> {
    if lines.is_empty() {
        return Err(ServeError::Config("load generator needs at least one request line".into()));
    }
    let clients = cfg.clients.max(1);
    let rps = if cfg.rps.is_finite() && cfg.rps > 0.0 { cfg.rps } else { 1.0 };
    let duration_s = cfg.duration.as_secs_f64().max(0.0);
    // Per-client quota: ceil, so short --quick runs still send work.
    let per_client = ((duration_s * rps / clients as f64).ceil() as usize).max(1);
    // Each client fires every `clients/rps` seconds → aggregate ≈ rps.
    let interval = Duration::from_secs_f64(clients as f64 / rps);

    let mut writers = Vec::with_capacity(clients);
    let mut readers = Vec::with_capacity(clients);
    for _ in 0..clients {
        let s = TcpStream::connect(addr)
            .map_err(|e| ServeError::Io(format!("connecting to {addr}: {e}")))?;
        s.set_nodelay(true).ok();
        let r = s
            .try_clone()
            .map_err(|e| ServeError::Io(format!("cloning socket for {addr}: {e}")))?;
        r.set_read_timeout(Some(Duration::from_secs(10)))
            .map_err(|e| ServeError::Io(format!("read timeout on {addr}: {e}")))?;
        writers.push(s);
        readers.push(r);
    }

    let sent = AtomicU64::new(0);
    let ok = AtomicU64::new(0);
    let errors = AtomicU64::new(0);
    let hist = LogHistogram::new();
    // Per-client send instants: writer pushes back, reader pops front —
    // valid because replies on one connection arrive in request order.
    let send_times: Vec<Mutex<VecDeque<Instant>>> =
        (0..clients).map(|_| Mutex::new(VecDeque::new())).collect();
    let t0 = Instant::now();

    std::thread::scope(|scope| {
        for (c, (mut w, r)) in writers.into_iter().zip(readers).enumerate() {
            let (sent, ok, errors, hist) = (&sent, &ok, &errors, &hist);
            let times = &send_times[c];
            // Stagger client start phases evenly across one interval so
            // the aggregate arrival process is smooth, not N-bursty.
            let stagger = interval.mul_f64(c as f64 / clients as f64);
            scope.spawn(move || {
                for i in 0..per_client {
                    let target = t0 + stagger + interval.mul_f64(i as f64);
                    let now = Instant::now();
                    if target > now {
                        std::thread::sleep(target - now);
                    }
                    let line = &lines[(i * clients + c) % lines.len()];
                    // Stamp *before* the write so queueing in the kernel
                    // and the daemon counts against measured latency.
                    times.lock().unwrap().push_back(Instant::now());
                    if w.write_all(line.as_bytes()).is_err()
                        || w.write_all(b"\n").is_err()
                        || w.flush().is_err()
                    {
                        times.lock().unwrap().pop_back();
                        break;
                    }
                    sent.fetch_add(1, Ordering::Relaxed);
                }
            });
            scope.spawn(move || {
                let mut rd = BufReader::new(r);
                let mut line = String::new();
                for _ in 0..per_client {
                    line.clear();
                    match rd.read_line(&mut line) {
                        Ok(0) | Err(_) => break, // writer quit or daemon gone
                        Ok(_) => {}
                    }
                    let sent_at = times.lock().unwrap().pop_front();
                    if let Some(at) = sent_at {
                        hist.record(at.elapsed().as_secs_f64() * 1e6);
                    }
                    let is_ok = Json::parse(line.trim())
                        .ok()
                        .and_then(|j| j.get("ok").cloned())
                        == Some(Json::Bool(true));
                    if is_ok {
                        ok.fetch_add(1, Ordering::Relaxed);
                    } else {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });

    let elapsed_s = t0.elapsed().as_secs_f64().max(1e-9);
    let sent_n = sent.load(Ordering::Relaxed);
    let ok_n = ok.load(Ordering::Relaxed);
    let answered = ok_n + errors.load(Ordering::Relaxed);
    Ok(LoadReport {
        sent: sent_n,
        ok: ok_n,
        // Sent-but-never-answered slots are failures too.
        errors: sent_n.saturating_sub(answered) + errors.load(Ordering::Relaxed),
        elapsed_s,
        requests_per_s: ok_n as f64 / elapsed_s,
        p50_us: hist.percentile(0.50),
        p95_us: hist.percentile(0.95),
        p99_us: hist.percentile(0.99),
    })
}

/// Send one request line and return the parsed reply. Used for control
/// verbs and smoke checks; opens a fresh connection per call.
pub fn request_line(
    addr: SocketAddr,
    line: &str,
    timeout: Duration,
) -> Result<Json, ServeError> {
    let mut s = TcpStream::connect(addr)
        .map_err(|e| ServeError::Io(format!("connecting to {addr}: {e}")))?;
    s.set_nodelay(true).ok();
    s.set_read_timeout(Some(timeout))
        .map_err(|e| ServeError::Io(format!("read timeout on {addr}: {e}")))?;
    s.write_all(line.as_bytes())
        .and_then(|_| s.write_all(b"\n"))
        .and_then(|_| s.flush())
        .map_err(|e| ServeError::Io(format!("writing to {addr}: {e}")))?;
    let mut rd = BufReader::new(s);
    let mut reply = String::new();
    rd.read_line(&mut reply)
        .map_err(|e| ServeError::Io(format!("reading reply from {addr}: {e}")))?;
    if reply.is_empty() {
        return Err(ServeError::Io(format!("{addr} closed without replying")));
    }
    Json::parse(reply.trim())
        .map_err(|e| ServeError::Io(format!("unparseable reply from {addr}: {e}")))
}

/// Fetch the daemon's `stats` document (the reply's `stats` object).
pub fn request_stats(addr: SocketAddr) -> Result<Json, ServeError> {
    let j = request_line(addr, &super::protocol::stats_line(), Duration::from_secs(5))?;
    j.get("stats")
        .cloned()
        .ok_or_else(|| ServeError::Io(format!("stats reply from {addr} has no 'stats' object")))
}

/// Ask the daemon to hot-reload its bundle directory; returns the reply.
pub fn request_reload(addr: SocketAddr) -> Result<Json, ServeError> {
    request_line(addr, &super::protocol::reload_line(), Duration::from_secs(5))
}

/// Ask the daemon to drain; returns the acknowledgement reply.
pub fn request_drain(addr: SocketAddr) -> Result<Json, ServeError> {
    request_line(addr, &super::protocol::drain_line(), Duration::from_secs(5))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// A minimal line-reply server: answers every line with a canned
    /// reply, so the generator's pacing, matching and accounting can be
    /// tested without booting the whole daemon.
    fn spawn_echo_server(reply: &'static str, conns: usize) -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for _ in 0..conns {
                let Ok((sock, _)) = listener.accept() else { return };
                std::thread::spawn(move || {
                    let mut rd = BufReader::new(sock.try_clone().unwrap());
                    let mut w = sock;
                    let mut line = String::new();
                    loop {
                        line.clear();
                        match rd.read_line(&mut line) {
                            Ok(0) | Err(_) => return,
                            Ok(_) => {}
                        }
                        if w.write_all(reply.as_bytes()).is_err()
                            || w.write_all(b"\n").is_err()
                        {
                            return;
                        }
                    }
                });
            }
        });
        addr
    }

    #[test]
    fn measures_a_cooperative_server_with_finite_percentiles() {
        let addr = spawn_echo_server(r#"{"ok":true,"op":"predict"}"#, 2);
        let cfg = LoadConfig {
            clients: 2,
            rps: 200.0,
            duration: Duration::from_millis(200),
        };
        let lines = vec![r#"{"op":"predict"}"#.to_string()];
        let report = run_load(addr, &cfg, &lines).expect("load runs");
        assert!(report.sent >= 2, "sent {}", report.sent);
        assert_eq!(report.ok, report.sent, "every reply is ok:true");
        assert_eq!(report.errors, 0);
        assert!(report.requests_per_s > 0.0);
        assert!(report.p50_us.is_finite() && report.p50_us > 0.0);
        assert!(report.p50_us <= report.p95_us && report.p95_us <= report.p99_us);
    }

    #[test]
    fn error_replies_are_counted_as_errors_not_ok() {
        let addr = spawn_echo_server(r#"{"ok":false,"error":{"code":"bad_json","message":"x"}}"#, 1);
        let cfg = LoadConfig {
            clients: 1,
            rps: 100.0,
            duration: Duration::from_millis(100),
        };
        let report = run_load(addr, &cfg, &[r#"garbage"#.to_string()]).expect("load runs");
        assert!(report.sent >= 1);
        assert_eq!(report.ok, 0);
        assert_eq!(report.errors, report.sent);
        assert_eq!(report.requests_per_s, 0.0);
    }

    #[test]
    fn refuses_an_empty_request_set_and_a_dead_address() {
        let cfg = LoadConfig { clients: 1, rps: 10.0, duration: Duration::from_millis(10) };
        let err = run_load("127.0.0.1:9".parse().unwrap(), &cfg, &[]).unwrap_err();
        assert!(err.to_string().contains("at least one request line"), "{err}");
        // Port 9 (discard) is unbound in the test environment: connect
        // must fail fast rather than report zeros.
        let err = run_load("127.0.0.1:9".parse().unwrap(), &cfg, &["x".into()]).unwrap_err();
        assert!(err.to_string().contains("connecting"), "{err}");
    }
}
