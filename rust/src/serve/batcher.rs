//! Dynamic micro-batching: coalesce concurrent predict requests into
//! `LatencyEngine::predict_batch` calls.
//!
//! Connection handlers [`submit`](MicroBatcher::submit) jobs into a
//! bounded queue and get back an `mpsc::Receiver` for their slot's
//! result. A single flusher thread pulls batches out and executes them:
//! a batch flushes when it reaches `max_batch` jobs **or** when the
//! oldest queued job has waited `max_wait` (whichever comes first), so an
//! idle daemon answers a lone request within one `max_wait` and a busy
//! one amortizes deduction/lowering across the whole batch on the
//! engine's `ExecPool` (where the fingerprint-keyed plan cache does the
//! cross-client heavy lifting).
//!
//! Error containment is per-slot: `predict_batch` already returns one
//! `Result` per request, so a poisoned request (unknown scenario, method
//! mismatch) fails alone and the rest of its batch serves normally.
//! Overflow (`queue_cap`) and post-drain submits are rejected *at
//! submit*, with typed errors — the queue never grows unboundedly and a
//! draining daemon never accepts work it won't finish.
//!
//! The flush *decision* is a pure function of (queue, config, clock),
//! exposed to tests as [`take_ready`](MicroBatcher::take_ready) — given a
//! scripted arrival order and an explicit `now`, coalescing is
//! deterministic; the unit tests below script both flush paths.

use crate::engine::{EngineError, LatencyEngine, PredictRequest, PredictResponse};
use crate::graph::Graph;
use crate::predict::Method;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::fleet::BundleFleet;
use super::metrics::ServeMetrics;
use super::ServeError;

/// The per-slot outcome delivered back to the submitting connection.
pub type JobResult = Result<PredictResponse, EngineError>;

/// One prediction to be coalesced. The graph is owned: the submitting
/// connection hands it off and is free to read its next request while
/// the batch executes.
#[derive(Debug)]
pub struct PredictJob {
    pub graph: Graph,
    pub scenario_id: String,
    pub method: Option<Method>,
}

struct Pending {
    job: PredictJob,
    reply: Sender<JobResult>,
    submitted: Instant,
}

/// Coalescing knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Flush as soon as this many jobs are queued (clamped to ≥ 1).
    pub max_batch: usize,
    /// Flush when the oldest queued job has waited this long.
    pub max_wait: Duration,
    /// Reject submits beyond this many queued jobs (clamped to ≥
    /// `max_batch`).
    pub queue_cap: usize,
}

impl Default for BatchConfig {
    fn default() -> BatchConfig {
        BatchConfig {
            max_batch: 32,
            max_wait: Duration::from_micros(1000),
            queue_cap: 1024,
        }
    }
}

/// The micro-batcher: bounded queue + condvar + one flusher loop.
pub struct MicroBatcher {
    cfg: BatchConfig,
    queue: Mutex<VecDeque<Pending>>,
    nonempty: Condvar,
    stop: AtomicBool,
}

impl MicroBatcher {
    pub fn new(cfg: BatchConfig) -> MicroBatcher {
        let max_batch = cfg.max_batch.max(1);
        MicroBatcher {
            cfg: BatchConfig {
                max_batch,
                max_wait: cfg.max_wait,
                queue_cap: cfg.queue_cap.max(max_batch),
            },
            queue: Mutex::new(VecDeque::new()),
            nonempty: Condvar::new(),
            stop: AtomicBool::new(false),
        }
    }

    pub fn config(&self) -> BatchConfig {
        self.cfg
    }

    /// Jobs currently queued (point in time).
    pub fn queue_len(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    pub fn is_draining(&self) -> bool {
        self.stop.load(Ordering::Acquire)
    }

    /// Enqueue a job, returning the receiver its result will arrive on.
    /// Typed rejections, decided under the queue lock: `Draining` once
    /// [`begin_drain`](MicroBatcher::begin_drain) ran, `Overloaded` at
    /// `queue_cap`.
    pub fn submit(&self, job: PredictJob) -> Result<Receiver<JobResult>, ServeError> {
        let (tx, rx) = channel();
        let mut q = self.queue.lock().unwrap();
        if self.stop.load(Ordering::Acquire) {
            return Err(ServeError::Draining);
        }
        if q.len() >= self.cfg.queue_cap {
            return Err(ServeError::Overloaded);
        }
        q.push_back(Pending { job, reply: tx, submitted: Instant::now() });
        drop(q);
        self.nonempty.notify_one();
        Ok(rx)
    }

    /// Whether the queue is due to flush at `now`.
    fn due(&self, q: &VecDeque<Pending>, now: Instant) -> bool {
        match q.front() {
            None => false,
            Some(first) => {
                q.len() >= self.cfg.max_batch
                    || self.stop.load(Ordering::Acquire)
                    || now.saturating_duration_since(first.submitted) >= self.cfg.max_wait
            }
        }
    }

    fn drain_front(q: &mut VecDeque<Pending>, max: usize) -> Vec<Pending> {
        let n = q.len().min(max);
        q.drain(..n).collect()
    }

    /// Non-blocking flush decision at an explicit `now` — the
    /// deterministic core the flusher loops over and the unit tests
    /// script directly. Returns a batch iff one is due (size reached,
    /// oldest job past its deadline, or draining).
    fn take_ready(&self, now: Instant) -> Option<Vec<Pending>> {
        let mut q = self.queue.lock().unwrap();
        if self.due(&q, now) {
            Some(Self::drain_front(&mut q, self.cfg.max_batch))
        } else {
            None
        }
    }

    /// Block until a batch is due and take it. `None` means drained:
    /// stopped *and* empty — every accepted job is flushed before the
    /// flusher is released.
    fn next_batch(&self) -> Option<Vec<Pending>> {
        let mut q = self.queue.lock().unwrap();
        loop {
            let now = Instant::now();
            if self.due(&q, now) {
                return Some(Self::drain_front(&mut q, self.cfg.max_batch));
            }
            if self.stop.load(Ordering::Acquire) && q.is_empty() {
                return None;
            }
            match q.front() {
                None => q = self.nonempty.wait(q).unwrap(),
                Some(first) => {
                    let deadline = first.submitted + self.cfg.max_wait;
                    let timeout = deadline.saturating_duration_since(now);
                    q = self.nonempty.wait_timeout(q, timeout).unwrap().0;
                }
            }
        }
    }

    /// Execute one batch on `engine` and route each per-slot result back
    /// to its submitter. A dead receiver (client hung up mid-flight) is
    /// ignored — the rest of the batch still delivers.
    fn execute(engine: &LatencyEngine, batch: Vec<Pending>, metrics: &ServeMetrics) {
        let reqs: Vec<PredictRequest> = batch
            .iter()
            .map(|p| {
                let mut r = PredictRequest::new(&p.job.graph, p.job.scenario_id.clone());
                if let Some(m) = p.job.method {
                    r = r.with_method(m);
                }
                r
            })
            .collect();
        let results = engine.predict_batch(&reqs);
        drop(reqs);
        metrics.record_batch(batch.len());
        let done = Instant::now();
        for (p, res) in batch.into_iter().zip(results) {
            match &res {
                Ok(_) => metrics.note_predict_ok(),
                Err(_) => metrics.note_predict_err(),
            }
            metrics
                .record_service_us(done.saturating_duration_since(p.submitted).as_secs_f64() * 1e6);
            let _ = p.reply.send(res);
        }
    }

    /// The flusher loop the daemon runs on one dedicated thread. Grabs
    /// the fleet's engine `Arc` fresh per batch, so a hot reload takes
    /// effect on the next flush while the current batch finishes on the
    /// engine it started with. Returns once drained.
    pub fn run_flusher(&self, fleet: &BundleFleet, metrics: &ServeMetrics) {
        while let Some(batch) = self.next_batch() {
            let engine = fleet.engine();
            Self::execute(&engine, batch, metrics);
        }
    }

    /// Stop accepting submits and wake the flusher to drain what's
    /// queued. Idempotent.
    pub fn begin_drain(&self) {
        self.stop.store(true, Ordering::Release);
        self.nonempty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineBuilder;

    const GOLDEN_BUNDLE: &str = include_str!("../../tests/data/golden_bundle.json");
    const SCENARIO: &str = "Snapdragon855/cpu/1L/fp32";

    fn golden_engine() -> LatencyEngine {
        let j = crate::util::Json::parse(GOLDEN_BUNDLE).expect("golden json");
        let b = crate::engine::PredictorBundle::from_json(&j).expect("golden bundle");
        EngineBuilder::new().bundle(b).threads(2).build().expect("engine")
    }

    fn jobs(n: usize, scenario: &str) -> Vec<PredictJob> {
        crate::nas::sample_dataset(17, n)
            .into_iter()
            .map(|a| PredictJob {
                graph: a.graph,
                scenario_id: scenario.to_string(),
                method: None,
            })
            .collect()
    }

    fn far_future() -> Instant {
        Instant::now() + Duration::from_secs(3600)
    }

    #[test]
    fn coalescing_is_deterministic_for_a_scripted_arrival_order() {
        // Large max_wait: only the size trigger and the scripted clock
        // decide flushes, never the test host's scheduling.
        let b = MicroBatcher::new(BatchConfig {
            max_batch: 4,
            max_wait: Duration::from_secs(3600),
            queue_cap: 64,
        });
        let mut rxs = Vec::new();
        for job in jobs(6, SCENARIO) {
            rxs.push(b.submit(job).expect("accepted"));
        }
        // Flush-on-size: 6 queued, max_batch 4 → exactly one full batch.
        let now = Instant::now();
        let first = b.take_ready(now).expect("size trigger fires");
        assert_eq!(first.len(), 4);
        // The 2 leftovers are under size and under deadline: no flush.
        assert!(b.take_ready(now).is_none(), "no premature deadline flush");
        assert_eq!(b.queue_len(), 2);
        // Flush-on-deadline: advance the scripted clock past max_wait.
        let second = b.take_ready(far_future()).expect("deadline trigger fires");
        assert_eq!(second.len(), 2);
        assert_eq!(b.queue_len(), 0);
        assert!(b.take_ready(far_future()).is_none(), "empty queue never flushes");
    }

    #[test]
    fn responses_route_back_to_the_correct_client_in_order() {
        let engine = golden_engine();
        let b = MicroBatcher::new(BatchConfig::default());
        let metrics = ServeMetrics::new();
        let js = jobs(5, SCENARIO);
        // Direct predictions on the same engine are the ground truth.
        let expected: Vec<f64> = js
            .iter()
            .map(|j| engine.predict(&PredictRequest::new(&j.graph, SCENARIO)).unwrap().e2e_ms)
            .collect();
        let rxs: Vec<_> = js.into_iter().map(|j| b.submit(j).expect("accepted")).collect();
        let batch = b.take_ready(far_future()).expect("due");
        assert_eq!(batch.len(), 5);
        MicroBatcher::execute(&engine, batch, &metrics);
        for (rx, want) in rxs.iter().zip(&expected) {
            let got = rx.recv().expect("slot delivered").expect("served");
            // Same engine, same graph → bit-identical through the batcher.
            assert_eq!(got.e2e_ms.to_bits(), want.to_bits());
        }
        let s = metrics.snapshot();
        assert_eq!(s.predict_ok, 5);
        assert_eq!(s.batches, 1);
        assert_eq!(s.mean_batch, 5.0);
        assert!(s.service_p50_us > 0.0);
    }

    #[test]
    fn a_poisoned_request_fails_alone_and_the_batch_survives() {
        let engine = golden_engine();
        let b = MicroBatcher::new(BatchConfig::default());
        let metrics = ServeMetrics::new();
        let mut js = jobs(3, SCENARIO);
        js[1].scenario_id = "NoSuchSoc/gpu".to_string(); // the poison
        let rxs: Vec<_> = js.into_iter().map(|j| b.submit(j).expect("accepted")).collect();
        let batch = b.take_ready(far_future()).expect("due");
        MicroBatcher::execute(&engine, batch, &metrics);
        assert!(rxs[0].recv().unwrap().is_ok());
        let err = rxs[1].recv().unwrap().expect_err("poisoned slot fails");
        assert!(matches!(err, EngineError::NoPredictor { .. }), "{err:?}");
        assert!(rxs[2].recv().unwrap().is_ok());
        let s = metrics.snapshot();
        assert_eq!((s.predict_ok, s.predict_err), (2, 1));
    }

    #[test]
    fn overflow_and_drain_are_rejected_at_submit_and_drain_flushes() {
        let b = MicroBatcher::new(BatchConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(3600),
            queue_cap: 2,
        });
        let metrics = ServeMetrics::new();
        let mut js = jobs(3, SCENARIO);
        let rx_keep = b.submit(js.remove(0)).expect("first accepted");
        let _rx2 = b.submit(js.remove(0)).expect("second accepted");
        match b.submit(js.remove(0)) {
            Err(ServeError::Overloaded) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        // Drain: further submits are refused, queued work still flushes.
        b.begin_drain();
        assert!(b.is_draining());
        let extra = jobs(1, SCENARIO).remove(0);
        match b.submit(extra) {
            Err(ServeError::Draining) => {}
            other => panic!("expected Draining, got {other:?}"),
        }
        // run_flusher on a stopped batcher drains the queue, then exits —
        // no accepted slot is left without a result.
        let fleet_dir =
            std::env::temp_dir().join(format!("edgelat_drainflush_{}", std::process::id()));
        std::fs::create_dir_all(&fleet_dir).unwrap();
        std::fs::write(fleet_dir.join("golden.json"), GOLDEN_BUNDLE).unwrap();
        let fleet = BundleFleet::load(&fleet_dir, Some(2)).unwrap();
        b.run_flusher(&fleet, &metrics); // returns immediately after the drain flush
        assert!(rx_keep.recv().expect("drained slot still answered").is_ok());
        assert_eq!(metrics.snapshot().predict_ok, 2);
        let _ = std::fs::remove_dir_all(&fleet_dir);
    }

    #[test]
    fn flush_on_deadline_fires_through_the_real_flusher_thread() {
        // End-to-end through next_batch's wait_timeout: one lone request
        // must be answered within ~max_wait, without a size trigger.
        let dir = std::env::temp_dir().join(format!("edgelat_deadline_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("golden.json"), GOLDEN_BUNDLE).unwrap();
        let fleet = BundleFleet::load(&dir, Some(2)).unwrap();
        let metrics = ServeMetrics::new();
        let b = MicroBatcher::new(BatchConfig {
            max_batch: 64, // far above 1: only the deadline can flush
            max_wait: Duration::from_millis(5),
            queue_cap: 64,
        });
        std::thread::scope(|s| {
            let flusher = s.spawn(|| b.run_flusher(&fleet, &metrics));
            let rx = b.submit(jobs(1, SCENARIO).remove(0)).expect("accepted");
            let resp = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("deadline flush delivers")
                .expect("served");
            assert!(resp.e2e_ms.is_finite());
            b.begin_drain();
            flusher.join().unwrap();
        });
        assert_eq!(metrics.snapshot().batches, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
