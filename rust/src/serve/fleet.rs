//! The bundle fleet: a directory of predictor bundles behind one
//! hot-swappable engine.
//!
//! `BundleFleet::load` scans a directory for `*.json` and `*.bin`
//! predictor bundles (JSON v2/v3 or the binary format —
//! [`crate::engine::PredictorBundle::load_auto`] sniffs the magic),
//! builds one multi-bundle [`LatencyEngine`], and hands out the engine as
//! an `Arc` clone per batch. `reload` builds a **complete replacement
//! engine first** and only then swaps the `Arc` under a write lock, so:
//!
//! - in-flight batches keep predicting on the engine they started with
//!   (their `Arc` keeps the old generation alive until they finish);
//! - a reload that fails — unreadable directory, corrupt bundle — leaves
//!   the serving engine untouched and returns a typed error;
//! - plan-cache and LUT-tier counters survive swaps: the retiring
//!   engine's [`CacheStats`] and [`LutCounts`] are folded into running
//!   totals, and [`plan_cache_stats`](BundleFleet::plan_cache_stats) /
//!   [`lut_counts`](BundleFleet::lut_counts) report retired + live
//!   merged.

use crate::engine::{EngineBuilder, LatencyEngine};
use crate::exec_pool::CacheStats;
use crate::predict::lut::{LutCounts, LutSpec};
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};

use super::ServeError;

struct FleetState {
    engine: Arc<LatencyEngine>,
    generation: u64,
    bundles: usize,
    /// Cache counters accumulated by engines that have been swapped out.
    retired_cache: CacheStats,
    /// LUT-tier counters accumulated by engines that have been swapped out.
    retired_lut: LutCounts,
}

/// A directory of bundles serving as one engine, with hot reload.
pub struct BundleFleet {
    dir: PathBuf,
    threads: Option<usize>,
    /// Compile the LUT tier into every built engine (initial load and
    /// every reload) when set — the serve daemon's `--lut` flag.
    lut: Option<LutSpec>,
    state: RwLock<FleetState>,
}

impl BundleFleet {
    /// Load every `*.json` / `*.bin` bundle in `dir` (sorted by filename —
    /// load order is route priority for scenarios served by several
    /// bundles) into one engine. An empty or unreadable directory is an
    /// error: a daemon with nothing to serve should fail at startup, not
    /// at the first request.
    pub fn load(dir: impl AsRef<Path>, threads: Option<usize>) -> Result<BundleFleet, ServeError> {
        Self::load_opts(dir, threads, None)
    }

    /// [`load`](Self::load), optionally compiling the LUT predictor tier
    /// into the engine (and into every hot-reloaded generation).
    pub fn load_opts(
        dir: impl AsRef<Path>,
        threads: Option<usize>,
        lut: Option<LutSpec>,
    ) -> Result<BundleFleet, ServeError> {
        let dir = dir.as_ref().to_path_buf();
        let (engine, bundles) = Self::build_engine(&dir, threads, lut.as_ref())?;
        Ok(BundleFleet {
            dir,
            threads,
            lut,
            state: RwLock::new(FleetState {
                engine: Arc::new(engine),
                generation: 1,
                bundles,
                retired_cache: CacheStats::default(),
                retired_lut: LutCounts::default(),
            }),
        })
    }

    fn bundle_files(dir: &Path) -> Result<Vec<PathBuf>, ServeError> {
        let entries = std::fs::read_dir(dir)
            .map_err(|e| ServeError::Io(format!("reading bundle dir {}: {e}", dir.display())))?;
        let mut files: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                matches!(p.extension().and_then(|x| x.to_str()), Some("json") | Some("bin"))
            })
            .collect();
        files.sort();
        if files.is_empty() {
            return Err(ServeError::Config(format!(
                "no *.json or *.bin predictor bundles in {} (train some with `edgelat train`)",
                dir.display()
            )));
        }
        Ok(files)
    }

    fn build_engine(
        dir: &Path,
        threads: Option<usize>,
        lut: Option<&LutSpec>,
    ) -> Result<(LatencyEngine, usize), ServeError> {
        let files = Self::bundle_files(dir)?;
        let n = files.len();
        let mut builder = EngineBuilder::new();
        for f in &files {
            builder = builder
                .bundle_file(f)
                .map_err(|e| ServeError::Config(format!("bundle {}: {e}", f.display())))?;
        }
        if let Some(t) = threads {
            builder = builder.threads(t);
        }
        if let Some(spec) = lut {
            builder = builder.lut(spec.clone());
        }
        let engine = builder.build().map_err(ServeError::Engine)?;
        Ok((engine, n))
    }

    /// The directory this fleet (re)loads from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The live engine. Batches clone the `Arc` once and predict on that
    /// clone, so a concurrent reload can never pull the engine out from
    /// under an in-flight batch.
    pub fn engine(&self) -> Arc<LatencyEngine> {
        self.state.read().unwrap().engine.clone()
    }

    /// Monotonic engine generation (1 after load, +1 per reload).
    pub fn generation(&self) -> u64 {
        self.state.read().unwrap().generation
    }

    /// Bundles loaded into the live engine.
    pub fn bundle_count(&self) -> usize {
        self.state.read().unwrap().bundles
    }

    /// Scenario ids the live engine serves (owned: the engine `Arc` this
    /// borrows from dies with the call frame).
    pub fn scenario_ids(&self) -> Vec<String> {
        self.engine().scenario_ids().iter().map(|s| s.to_string()).collect()
    }

    /// Rebuild from the directory and atomically swap the engine.
    /// Building happens *outside* the lock: readers keep serving the old
    /// generation for the whole rebuild, and a failed rebuild changes
    /// nothing. Returns the new generation and its scenario ids.
    pub fn reload(&self) -> Result<(u64, usize, Vec<String>), ServeError> {
        let (engine, bundles) = Self::build_engine(&self.dir, self.threads, self.lut.as_ref())?;
        let ids: Vec<String> = engine.scenario_ids().iter().map(|s| s.to_string()).collect();
        let mut st = self.state.write().unwrap();
        st.retired_cache = st.retired_cache.merge(&st.engine.cache_stats());
        st.retired_lut = st.retired_lut.merge(&st.engine.lut_counts());
        st.engine = Arc::new(engine);
        st.generation += 1;
        st.bundles = bundles;
        Ok((st.generation, bundles, ids))
    }

    /// Plan-cache counters over the fleet's whole lifetime: every retired
    /// generation's totals merged with the live engine's.
    pub fn plan_cache_stats(&self) -> CacheStats {
        let st = self.state.read().unwrap();
        st.retired_cache.merge(&st.engine.cache_stats())
    }

    /// LUT-tier counters over the fleet's whole lifetime (all zero when
    /// the fleet was loaded without the LUT tier).
    pub fn lut_counts(&self) -> LutCounts {
        let st = self.state.read().unwrap();
        st.retired_lut.merge(&st.engine.lut_counts())
    }

    /// Whether the live engine carries a compiled LUT tier.
    pub fn lut_enabled(&self) -> bool {
        self.state.read().unwrap().engine.lut_enabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{PredictRequest, PredictorBundle};

    /// The golden-trace fixture: a handcrafted all-integer Lasso bundle
    /// for Snapdragon855/cpu/1L/fp32 — loads instantly, no training.
    const GOLDEN_BUNDLE: &str = include_str!("../../tests/data/golden_bundle.json");

    fn fixture_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("edgelat_fleet_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a_golden.json"), GOLDEN_BUNDLE).unwrap();
        dir
    }

    #[test]
    fn load_serves_reload_swaps_and_cache_stats_survive() {
        let dir = fixture_dir("reload");
        let fleet = BundleFleet::load(&dir, Some(2)).expect("fleet loads");
        assert_eq!(fleet.generation(), 1);
        assert_eq!(fleet.bundle_count(), 1);
        assert_eq!(fleet.scenario_ids(), vec!["Snapdragon855/cpu/1L/fp32".to_string()]);

        // Serve a couple of predictions to put counters on the live cache.
        let g = crate::nas::sample_dataset(3, 1).remove(0).graph;
        let engine = fleet.engine();
        let req = PredictRequest::new(&g, "Snapdragon855/cpu/1L/fp32");
        let first = engine.predict(&req).expect("served");
        engine.predict(&req).expect("served again");
        let before = fleet.plan_cache_stats();
        assert!(before.lookups() >= 2);
        assert!(before.hits >= 1, "second predict must hit the plan cache");

        // Reload: generation bumps, and an engine Arc taken before the
        // swap keeps serving bit-identically (in-flight work is safe).
        let old_engine = fleet.engine();
        let (generation, bundles, ids) = fleet.reload().expect("reload");
        assert_eq!(generation, 2);
        assert_eq!(bundles, 1);
        assert_eq!(ids, fleet.scenario_ids());
        let after_old = old_engine.predict(&req).expect("old generation still serves");
        assert_eq!(after_old.e2e_ms.to_bits(), first.e2e_ms.to_bits());
        // Same fixture on disk → the swapped-in engine agrees exactly.
        let after_new = fleet.engine().predict(&req).expect("new generation serves");
        assert_eq!(after_new.e2e_ms.to_bits(), first.e2e_ms.to_bits());

        // The retiring engine's counters were folded in, not dropped.
        let merged = fleet.plan_cache_stats();
        assert!(merged.lookups() >= before.lookups() + 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn binary_bundles_load_and_hot_reload_transparently() {
        let dir =
            std::env::temp_dir().join(format!("edgelat_fleet_bin_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Convert the golden JSON fixture to the binary format on disk.
        let j = crate::util::Json::parse(GOLDEN_BUNDLE).unwrap();
        let b = PredictorBundle::from_json(&j).expect("golden parses");
        b.save_bin(dir.join("a_golden.bin")).expect("bin saved");
        let fleet = BundleFleet::load(&dir, None).expect("fleet loads .bin");
        assert_eq!(fleet.scenario_ids(), vec!["Snapdragon855/cpu/1L/fp32".to_string()]);
        let g = crate::nas::sample_dataset(3, 1).remove(0).graph;
        let req = PredictRequest::new(&g, "Snapdragon855/cpu/1L/fp32");
        let from_bin = fleet.engine().predict(&req).expect("served from .bin");
        // The binary re-encoding is lossless: predictions agree bit-for-
        // bit with an engine built from the JSON fixture.
        let json_dir = fixture_dir("binref");
        let json_fleet = BundleFleet::load(&json_dir, None).expect("fleet loads .json");
        let from_json = json_fleet.engine().predict(&req).expect("served from .json");
        assert_eq!(from_bin.e2e_ms.to_bits(), from_json.e2e_ms.to_bits());
        // Hot reload keeps working with binary bundles on disk.
        let (generation, bundles, _) = fleet.reload().expect("reload over .bin");
        assert_eq!((generation, bundles), (2, 1));
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&json_dir);
    }

    #[test]
    fn lut_fleet_counts_survive_reload() {
        let dir = fixture_dir("lut");
        let fleet = BundleFleet::load_opts(&dir, None, Some(LutSpec::default()))
            .expect("fleet loads with LUT tier");
        assert!(fleet.lut_enabled());
        let g = crate::nas::sample_dataset(5, 1).remove(0).graph;
        let req = PredictRequest::new(&g, "Snapdragon855/cpu/1L/fp32");
        fleet.engine().predict(&req).expect("served");
        let before = fleet.lut_counts();
        // Every plan row either hit the tier or was counted as a fallback.
        assert!(before.served() + before.fallbacks > 0);
        let (generation, _, _) = fleet.reload().expect("reload");
        assert_eq!(generation, 2);
        assert!(fleet.lut_enabled(), "reloaded generation keeps the LUT tier");
        // Retired counters were folded in, not dropped.
        let after = fleet.lut_counts();
        assert!(after.served() + after.fallbacks >= before.served() + before.fallbacks);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Transfer bundles are first-class fleet citizens: a directory mixing
    /// predictor and transfer bundles loads into one engine, the
    /// transferred target scenario serves, both transfer encodings (JSON
    /// and `EDGELATT` binary) agree bit-for-bit, and hot reload keeps
    /// working over them.
    #[test]
    fn transfer_bundles_load_and_serve_through_the_fleet() {
        let j = crate::util::Json::parse(GOLDEN_BUNDLE).unwrap();
        let src = PredictorBundle::from_json(&j).expect("golden parses");
        let target = crate::scenario::one_large_core("Exynos9820").expect("builtin target");
        let graphs: Vec<_> = crate::nas::sample_dataset(11, 6)
            .into_iter()
            .map(|s| s.graph)
            .collect();
        let profiles = crate::profiler::profile_set(&target, &graphs, 11, 2);
        let report =
            crate::transfer::adapt(&src, &target, &graphs, &profiles).expect("few-shot adapt");
        let tb = report.bundle;
        let target_id = tb.scenario_id().to_string();

        // One fleet dir per encoding, each mixing a plain bundle with the
        // transfer bundle so the loader has to dispatch on content.
        let dir_json = fixture_dir("xfer_json");
        tb.save(dir_json.join("b_transfer.json")).expect("transfer json saved");
        let dir_bin = fixture_dir("xfer_bin");
        tb.save_bin(dir_bin.join("b_transfer.bin")).expect("transfer bin saved");

        let fleet = BundleFleet::load(&dir_json, Some(2)).expect("fleet loads transfer json");
        assert_eq!(fleet.bundle_count(), 2);
        let ids = fleet.scenario_ids();
        assert!(ids.contains(&target_id), "{ids:?}");
        assert!(ids.contains(&"Snapdragon855/cpu/1L/fp32".to_string()), "{ids:?}");

        let g = crate::nas::sample_dataset(7, 1).remove(0).graph;
        let req = PredictRequest::new(&g, &target_id);
        let from_json = fleet.engine().predict(&req).expect("transferred scenario serves");
        assert!(
            from_json.e2e_ms.is_finite() && from_json.e2e_ms > 0.0,
            "{}",
            from_json.e2e_ms
        );

        // The binary encoding is lossless: a fleet loaded from the
        // `EDGELATT` file predicts bit-identically.
        let bin_fleet = BundleFleet::load(&dir_bin, Some(2)).expect("fleet loads transfer bin");
        let from_bin = bin_fleet.engine().predict(&req).expect("served from .bin");
        assert_eq!(from_bin.e2e_ms.to_bits(), from_json.e2e_ms.to_bits());

        // Hot reload over a directory containing a transfer bundle.
        let (generation, bundles, ids) = fleet.reload().expect("reload over transfer bundle");
        assert_eq!((generation, bundles), (2, 2));
        assert!(ids.contains(&target_id));
        let again = fleet.engine().predict(&req).expect("reloaded generation serves");
        assert_eq!(again.e2e_ms.to_bits(), from_json.e2e_ms.to_bits());

        let _ = std::fs::remove_dir_all(&dir_json);
        let _ = std::fs::remove_dir_all(&dir_bin);
    }

    #[test]
    fn failed_reload_leaves_the_live_engine_untouched() {
        let dir = fixture_dir("failpath");
        let fleet = BundleFleet::load(&dir, None).expect("fleet loads");
        // Corrupt the only bundle on disk: reload must fail...
        std::fs::write(dir.join("a_golden.json"), "{ not json").unwrap();
        let err = fleet.reload().expect_err("corrupt bundle rejected");
        assert!(err.to_string().contains("a_golden.json"), "{err}");
        // ...and the generation-1 engine keeps serving.
        assert_eq!(fleet.generation(), 1);
        let g = crate::nas::sample_dataset(3, 1).remove(0).graph;
        fleet
            .engine()
            .predict(&PredictRequest::new(&g, "Snapdragon855/cpu/1L/fp32"))
            .expect("still serving after failed reload");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_and_missing_directories_fail_at_startup() {
        let dir = std::env::temp_dir().join(format!("edgelat_fleet_empty_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let err = BundleFleet::load(&dir, None).expect_err("empty dir rejected");
        assert!(err.to_string().contains("no *.json or *.bin"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
        let err = BundleFleet::load("/no/such/dir/anywhere", None)
            .expect_err("missing dir rejected");
        assert!(err.to_string().contains("/no/such/dir"), "{err}");
    }
}
