//! The serve wire protocol: one JSON object per line, each way.
//!
//! Requests (`op` selects the verb):
//! - `{"op":"predict","scenario":ID,"model":<edgelat-model-v1 object>,
//!    "id":<any JSON, echoed>,"method":"lasso|rf|gbdt"?,"detail":bool?}`
//! - `{"op":"stats"}` — uptime, counters, coalescing histogram, cache
//!   stats, service-latency percentiles.
//! - `{"op":"reload"}` — re-read the daemon's configured bundle
//!   directory and swap the engine (the path is server-side config, never
//!   client input).
//! - `{"op":"drain"}` — stop accepting connections, flush queues, exit.
//!
//! Replies are `{"ok":true,"op":...,...}` or `{"ok":false,"error":
//! {"code":...,"message":...},"id":...?}`. Every malformed line gets a
//! typed error reply on the same connection — never a panic or a dropped
//! socket. Replies on one connection arrive strictly in request order.
//!
//! The model travels inline as an `edgelat-model-v1` document
//! ([`crate::graph::modelfile`]). `Json` round-trips f64 bit-exactly
//! (shortest-repr emit + exact parse, asserted in `util::json` tests) and
//! `Graph::fingerprint` is rename-stable, so a prediction served over
//! this protocol is bit-identical to calling `predict_batch` in-process
//! on the same bundles — the integration suite asserts exactly that.

use crate::engine::{EngineError, PredictResponse};
use crate::graph::{modelfile, Graph};
use crate::predict::Method;
use crate::util::Json;

/// Protocol identifier echoed by the `stats` endpoint.
pub const PROTOCOL: &str = "edgelat.serve/1";

/// A parsed client request.
#[derive(Debug)]
pub enum Request {
    /// Boxed: a predict carries a whole parsed `Graph`; the other verbs
    /// are unit-sized and shouldn't pay its footprint.
    Predict(Box<PredictWire>),
    Stats,
    Reload,
    Drain,
}

/// The payload of a `predict` request.
#[derive(Debug)]
pub struct PredictWire {
    /// Client correlation id, echoed verbatim in the reply.
    pub id: Option<Json>,
    pub scenario_id: String,
    pub method: Option<Method>,
    pub graph: Graph,
    /// Include the per-unit latency decomposition in the reply.
    pub detail: bool,
}

/// A typed protocol-level error, rendered as an `ok:false` reply.
#[derive(Debug, Clone)]
pub struct WireError {
    /// Stable machine-readable code: `bad_json`, `bad_request`,
    /// `bad_model`, `no_predictor`, `overloaded`, `draining`,
    /// `reload_failed`, `io`, `bad_bundle`, `unsupported`, `internal`.
    pub code: &'static str,
    pub message: String,
    /// The request's `id`, when it could be extracted, echoed back so
    /// pipelined clients can correlate the failure.
    pub id: Option<Json>,
}

impl WireError {
    pub fn new(code: &'static str, message: impl Into<String>) -> WireError {
        WireError { code, message: message.into(), id: None }
    }

    pub fn with_id(code: &'static str, message: impl Into<String>, id: Option<Json>) -> WireError {
        WireError { code, message: message.into(), id }
    }
}

/// The stable error code for an engine-side per-request failure.
pub fn engine_error_code(e: &EngineError) -> &'static str {
    match e {
        EngineError::UnknownScenario(_) | EngineError::NoPredictor { .. } => "no_predictor",
        EngineError::Io(_) => "io",
        EngineError::Parse(_) => "bad_bundle",
        EngineError::Unsupported(_) => "unsupported",
    }
}

/// Parse one request line. Every failure is a typed [`WireError`] carrying
/// the request id when one was present.
pub fn parse_request(line: &str) -> Result<Request, WireError> {
    let j = Json::parse(line.trim()).map_err(|e| {
        WireError::new("bad_json", format!("request is not one JSON object per line: {e}"))
    })?;
    let id = j.get("id").cloned();
    let Some(op) = j.get("op").and_then(Json::as_str) else {
        return Err(WireError::with_id(
            "bad_request",
            "missing 'op' (predict|stats|reload|drain)",
            id,
        ));
    };
    match op {
        "stats" => Ok(Request::Stats),
        "reload" => Ok(Request::Reload),
        "drain" => Ok(Request::Drain),
        "predict" => {
            let Some(scenario_id) = j.get("scenario").and_then(Json::as_str) else {
                return Err(WireError::with_id(
                    "bad_request",
                    "predict needs 'scenario' (a scenario id, e.g. Snapdragon855/gpu)",
                    id,
                ));
            };
            let scenario_id = scenario_id.to_string();
            let method = match j.get("method") {
                None => None,
                Some(v) => match v.as_str().and_then(Method::parse) {
                    Some(m) => Some(m),
                    None => {
                        return Err(WireError::with_id(
                            "bad_request",
                            format!("unknown 'method' {} (lasso|rf|gbdt)", v.to_string()),
                            id,
                        ))
                    }
                },
            };
            let Some(model) = j.get("model") else {
                return Err(WireError::with_id(
                    "bad_request",
                    "predict needs 'model' (an inline edgelat-model-v1 document)",
                    id,
                ));
            };
            let graph = match modelfile::from_model_file(&model.to_string()) {
                Ok(g) => g,
                Err(e) => {
                    return Err(WireError::with_id("bad_model", format!("bad 'model': {e}"), id))
                }
            };
            let detail = matches!(j.get("detail"), Some(Json::Bool(true)));
            Ok(Request::Predict(Box::new(PredictWire { id, scenario_id, method, graph, detail })))
        }
        other => Err(WireError::with_id(
            "bad_request",
            format!("unknown op '{other}' (predict|stats|reload|drain)"),
            id,
        )),
    }
}

/// Render an `ok:false` reply line.
pub fn render_error(e: &WireError) -> String {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        (
            "error",
            Json::obj(vec![
                ("code", Json::str(e.code)),
                ("message", Json::str(e.message.clone())),
            ]),
        ),
    ];
    if let Some(id) = &e.id {
        pairs.push(("id", id.clone()));
    }
    Json::obj(pairs).to_string()
}

/// Render a successful predict reply line.
pub fn render_predict(
    id: Option<&Json>,
    scenario_id: &str,
    detail: bool,
    resp: &PredictResponse,
) -> String {
    let mut pairs = vec![
        ("ok", Json::Bool(true)),
        ("op", Json::str("predict")),
        ("scenario", Json::str(scenario_id)),
        ("e2e_ms", Json::num(resp.e2e_ms)),
        ("t_overhead_ms", Json::num(resp.t_overhead_ms)),
        ("units", Json::num(resp.per_unit.len() as f64)),
        ("fallback_units", Json::num(resp.fallback_units as f64)),
    ];
    if let Some(id) = id {
        pairs.push(("id", id.clone()));
    }
    if detail {
        pairs.push((
            "per_unit",
            Json::Arr(
                resp.per_unit
                    .iter()
                    .map(|(bucket, ms)| Json::arr(vec![Json::str(*bucket), Json::num(*ms)]))
                    .collect(),
            ),
        ));
    }
    Json::obj(pairs).to_string()
}

/// Render a reload acknowledgement.
pub fn render_reload(generation: u64, bundles: usize, scenario_ids: &[String]) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("op", Json::str("reload")),
        ("generation", Json::num(generation as f64)),
        ("bundles", Json::num(bundles as f64)),
        (
            "scenarios",
            Json::Arr(scenario_ids.iter().map(|s| Json::str(s.clone())).collect()),
        ),
    ])
    .to_string()
}

/// Render a drain acknowledgement (`served` = predictions answered so far).
pub fn render_drain(served: u64) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("op", Json::str("drain")),
        ("served", Json::num(served as f64)),
    ])
    .to_string()
}

/// Render the `stats` reply around a stats document.
pub fn render_stats(stats: Json) -> String {
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("op", Json::str("stats")),
        ("protocol", Json::str(PROTOCOL)),
        ("stats", stats),
    ])
    .to_string()
}

/// Build a `predict` request line for a graph (client side: the load
/// generator, the example client, and the tests all emit through here).
pub fn predict_line(
    scenario_id: &str,
    graph: &Graph,
    id: Option<u64>,
    method: Option<Method>,
    detail: bool,
) -> String {
    let model =
        Json::parse(&modelfile::to_model_file(graph)).expect("model files emit valid JSON");
    let mut pairs = vec![
        ("op", Json::str("predict")),
        ("scenario", Json::str(scenario_id)),
        ("model", model),
    ];
    if let Some(i) = id {
        pairs.push(("id", Json::num(i as f64)));
    }
    if let Some(m) = method {
        pairs.push(("method", Json::str(m.name())));
    }
    if detail {
        pairs.push(("detail", Json::Bool(true)));
    }
    Json::obj(pairs).to_string()
}

pub fn stats_line() -> String {
    Json::obj(vec![("op", Json::str("stats"))]).to_string()
}

pub fn reload_line() -> String {
    Json::obj(vec![("op", Json::str("reload"))]).to_string()
}

pub fn drain_line() -> String {
    Json::obj(vec![("op", Json::str("drain"))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(line: &str) -> (&'static str, Option<Json>) {
        match parse_request(line) {
            Err(e) => (e.code, e.id),
            Ok(r) => panic!("expected a wire error, parsed {r:?}"),
        }
    }

    #[test]
    fn predict_line_round_trips_through_parse_request() {
        let g = crate::nas::sample_dataset(11, 1).remove(0).graph;
        let line = predict_line("Snapdragon855/gpu", &g, Some(42), Some(Method::Gbdt), true);
        let Request::Predict(w) = parse_request(&line).expect("round-trips") else {
            panic!("not a predict");
        };
        assert_eq!(w.scenario_id, "Snapdragon855/gpu");
        assert_eq!(w.method, Some(Method::Gbdt));
        assert!(w.detail);
        assert_eq!(w.id, Some(Json::num(42.0)));
        // The graph survives the inline model-file round trip exactly.
        assert_eq!(w.graph, g);
        assert_eq!(w.graph.fingerprint(), g.fingerprint());
    }

    #[test]
    fn control_verbs_parse() {
        assert!(matches!(parse_request(&stats_line()), Ok(Request::Stats)));
        assert!(matches!(parse_request(&reload_line()), Ok(Request::Reload)));
        assert!(matches!(parse_request(&drain_line()), Ok(Request::Drain)));
    }

    #[test]
    fn malformed_lines_get_typed_codes_with_id_echo() {
        assert_eq!(code_of("not json at all").0, "bad_json");
        assert_eq!(code_of("{}").0, "bad_request");
        assert_eq!(code_of(r#"{"op":"fly"}"#).0, "bad_request");
        // The id is recovered even when the request itself is bad, so a
        // pipelined client can correlate the failure.
        let (code, id) = code_of(r#"{"op":"predict","id":7}"#);
        assert_eq!(code, "bad_request");
        assert_eq!(id, Some(Json::num(7.0)));
        let (code, _) = code_of(r#"{"op":"predict","id":7,"scenario":"X"}"#);
        assert_eq!(code, "bad_request"); // missing model
        let (code, _) =
            code_of(r#"{"op":"predict","id":7,"scenario":"X","model":{"nope":1}}"#);
        assert_eq!(code, "bad_model");
        let (code, _) = code_of(
            r#"{"op":"predict","id":7,"scenario":"X","model":{},"method":"svm"}"#,
        );
        assert_eq!(code, "bad_request"); // unknown method, checked before the model
    }

    #[test]
    fn error_rendering_echoes_the_id_and_is_valid_json() {
        let e = WireError::with_id("bad_model", "nope", Some(Json::str("req-9")));
        let line = render_error(&e);
        let j = Json::parse(&line).expect("error replies are valid JSON");
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.req("error").unwrap().req_str("code").unwrap(), "bad_model");
        assert_eq!(j.req_str("id").unwrap(), "req-9");
        // Without an id the key is absent, not null.
        let bare = render_error(&WireError::new("bad_json", "x"));
        assert_eq!(Json::parse(&bare).unwrap().get("id"), None);
    }

    #[test]
    fn predict_rendering_carries_the_decomposition_only_on_detail() {
        let resp = PredictResponse {
            e2e_ms: 12.5,
            per_unit: vec![("Conv2D", 10.0), ("Softmax", 0.5)],
            t_overhead_ms: 2.0,
            fallback_units: 1,
        };
        let id = Json::num(3.0);
        let terse = Json::parse(&render_predict(Some(&id), "S/gpu", false, &resp)).unwrap();
        assert_eq!(terse.req_f64("e2e_ms").unwrap(), 12.5);
        assert_eq!(terse.req_usize("units").unwrap(), 2);
        assert_eq!(terse.req_usize("fallback_units").unwrap(), 1);
        assert_eq!(terse.get("per_unit"), None);
        let full = Json::parse(&render_predict(Some(&id), "S/gpu", true, &resp)).unwrap();
        let units = full.req("per_unit").unwrap().as_arr().unwrap();
        assert_eq!(units.len(), 2);
        assert_eq!(units[0].as_arr().unwrap()[0].as_str(), Some("Conv2D"));
    }

    #[test]
    fn engine_errors_map_to_stable_codes() {
        assert_eq!(
            engine_error_code(&EngineError::NoPredictor {
                scenario_id: "X".into(),
                method: None
            }),
            "no_predictor"
        );
        assert_eq!(engine_error_code(&EngineError::UnknownScenario("X".into())), "no_predictor");
        assert_eq!(engine_error_code(&EngineError::Io("x".into())), "io");
        assert_eq!(engine_error_code(&EngineError::Parse("x".into())), "bad_bundle");
        assert_eq!(engine_error_code(&EngineError::Unsupported("x".into())), "unsupported");
    }
}
