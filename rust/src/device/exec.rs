//! The device executor: runs an inference of a computational graph on a
//! simulated SoC and returns the per-op (CPU) or per-kernel (GPU) latency
//! trace plus end-to-end latency — the analogue of the TFLite Model
//! Benchmark Tool + OpenCL command-queue timestamps (Section 4.3.1).

use crate::device::cost::{cpu_op_ms_under, gpu_kernel_ms_under};
use crate::device::noise::{cpu_noise_under, gpu_noise_under};
use crate::device::{CoreCombo, DataRep, Soc};
use crate::graph::{Graph, OpId, OpType};
use crate::tflite::{compile, CompileOptions, FusedKernel, KernelImpl};
use crate::util::Rng;
use crate::workload::WorkloadSpec;

/// Execution target for one scenario.
#[derive(Debug, Clone, PartialEq)]
pub enum Target {
    Cpu { combo: CoreCombo, rep: DataRep },
    Gpu { options: CompileOptions },
}

/// Latency record of one executed op / kernel.
#[derive(Debug, Clone)]
pub struct OpTrace {
    /// Root op of the kernel (CPU: the op itself).
    pub op: OpId,
    pub op_type: OpType,
    pub kernel: KernelImpl,
    /// Ops fused into this kernel (empty on CPU).
    pub fused: Vec<OpId>,
    pub latency_ms: f64,
}

/// One inference run.
#[derive(Debug, Clone)]
pub struct RunTrace {
    pub per_op: Vec<OpTrace>,
    /// Framework overhead outside op execution (the Fig 10 gap).
    pub overhead_ms: f64,
    pub end_to_end_ms: f64,
}

impl RunTrace {
    pub fn op_sum_ms(&self) -> f64 {
        self.per_op.iter().map(|t| t.latency_ms).sum()
    }
}

/// Execute one inference run. Fully deterministic in
/// `(seed, graph name, target, run_idx)`.
pub fn run(soc: &Soc, g: &Graph, target: &Target, seed: u64, run_idx: usize) -> RunTrace {
    run_under(soc, g, target, None, seed, run_idx)
}

/// Execute one inference run under an optional workload (whole-batch
/// latency with contention multipliers). `None` is the isolated regime
/// and reproduces [`run`] bit-identically: the RNG label stream only
/// extends when a workload is present.
pub fn run_under(
    soc: &Soc,
    g: &Graph,
    target: &Target,
    workload: Option<&WorkloadSpec>,
    seed: u64,
    run_idx: usize,
) -> RunTrace {
    let mut rng = run_rng(soc, g, target, workload, seed, run_idx);
    match target {
        Target::Cpu { combo, rep } => run_cpu(soc, g, combo, *rep, workload, &mut rng),
        Target::Gpu { options } => run_gpu(soc, g, *options, workload, &mut rng),
    }
}

fn target_label(target: &Target) -> u64 {
    match target {
        Target::Cpu { combo, rep } => {
            let mut h: u64 = match rep {
                DataRep::Fp32 => 1,
                DataRep::Int8 => 2,
            };
            for &c in &combo.counts {
                h = h.wrapping_mul(31).wrapping_add(c as u64 + 1);
            }
            h
        }
        Target::Gpu { options } => {
            0x4000 | (options.fusion as u64) | (options.winograd as u64) << 1
                | (options.grouped as u64) << 2
        }
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

fn run_rng(
    soc: &Soc,
    g: &Graph,
    target: &Target,
    workload: Option<&WorkloadSpec>,
    seed: u64,
    run_idx: usize,
) -> Rng {
    let name_hash = fnv1a(&g.name);
    let soc_hash = fnv1a(&soc.name);
    let mut labels = vec![soc_hash, name_hash, target_label(target), run_idx as u64];
    // Isolated runs keep the original 4-label stream (bit-identical
    // traces); a workload opens its own stream keyed by name.
    if let Some(wl) = workload {
        labels.push(fnv1a(&wl.name));
    }
    Rng::derive(seed, &labels)
}

fn run_cpu(
    soc: &Soc,
    g: &Graph,
    combo: &CoreCombo,
    rep: DataRep,
    workload: Option<&WorkloadSpec>,
    rng: &mut Rng,
) -> RunTrace {
    combo.validate(soc).expect("invalid core combo");
    let params = cpu_noise_under(soc, combo, workload);
    let noise = params.sample_run(rng);
    // TFLite's non-parallel ops land on whichever core hosts the
    // interpreter thread this run.
    let cores = combo.cores();
    let serial_cluster = *rng.choice(&cores);
    let mut per_op = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let base = cpu_op_ms_under(soc, g, node, combo, rep, serial_cluster, workload);
        let ms = base * noise.op_factor(rng);
        per_op.push(OpTrace {
            op: node.id,
            op_type: node.op.op_type(),
            kernel: KernelImpl::Generic,
            fused: Vec::new(),
            latency_ms: ms,
        });
    }
    let overhead = soc.cpu_overhead_ms * rng.lognormal_unit_mean(0.15);
    let total: f64 = per_op.iter().map(|t| t.latency_ms).sum::<f64>() + overhead;
    RunTrace { per_op, overhead_ms: overhead, end_to_end_ms: total }
}

fn run_gpu(
    soc: &Soc,
    g: &Graph,
    options: CompileOptions,
    workload: Option<&WorkloadSpec>,
    rng: &mut Rng,
) -> RunTrace {
    let compiled = compile(g, soc.gpu.kind, options);
    let params = gpu_noise_under(soc, workload);
    let noise = params.sample_run(rng);
    let mut per_op = Vec::with_capacity(compiled.kernels.len());
    for k in &compiled.kernels {
        let base = gpu_kernel_ms_under(soc, g, k, workload);
        let ms = base * noise.op_factor(rng);
        per_op.push(trace_of(g, k, ms));
    }
    let overhead = soc.gpu.overhead_ms * rng.lognormal_unit_mean(soc.gpu.overhead_sigma);
    let total: f64 = per_op.iter().map(|t| t.latency_ms).sum::<f64>() + overhead;
    RunTrace { per_op, overhead_ms: overhead, end_to_end_ms: total }
}

fn trace_of(g: &Graph, k: &FusedKernel, ms: f64) -> OpTrace {
    OpTrace {
        op: k.root(),
        op_type: g.nodes[k.root()].op.op_type(),
        kernel: k.impl_,
        fused: k.fused_ops().to_vec(),
        latency_ms: ms,
    }
}

/// Run `n` times and return the median end-to-end latency with all traces.
pub fn run_many(soc: &Soc, g: &Graph, target: &Target, seed: u64, n: usize) -> Vec<RunTrace> {
    run_many_under(soc, g, target, None, seed, n)
}

/// [`run_many`] under an optional workload.
pub fn run_many_under(
    soc: &Soc,
    g: &Graph,
    target: &Target,
    workload: Option<&WorkloadSpec>,
    seed: u64,
    n: usize,
) -> Vec<RunTrace> {
    (0..n).map(|i| run_under(soc, g, target, workload, seed, i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::soc_by_name;

    fn g() -> Graph {
        crate::zoo::mobilenets::mobilenet_v2(0.5)
    }

    fn cpu_target(counts: Vec<usize>) -> Target {
        Target::Cpu { combo: CoreCombo::new(counts), rep: DataRep::Fp32 }
    }

    #[test]
    fn deterministic_per_run_index() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let g = g();
        let t = cpu_target(vec![1, 0, 0]);
        let a = run(&soc, &g, &t, 42, 0);
        let b = run(&soc, &g, &t, 42, 0);
        assert_eq!(a.end_to_end_ms, b.end_to_end_ms);
        let c = run(&soc, &g, &t, 42, 1);
        assert_ne!(a.end_to_end_ms, c.end_to_end_ms);
    }

    #[test]
    fn end_to_end_exceeds_op_sum() {
        // Fig 10: end-to-end latency > sum of op latencies (overhead).
        let soc = soc_by_name("Exynos9820").unwrap();
        let g = g();
        for t in [cpu_target(vec![1, 0, 0]), Target::Gpu { options: CompileOptions::default() }] {
            let r = run(&soc, &g, &t, 1, 0);
            assert!(r.end_to_end_ms > r.op_sum_ms());
            assert!((r.end_to_end_ms - r.op_sum_ms() - r.overhead_ms).abs() < 1e-9);
        }
    }

    #[test]
    fn gpu_trace_counts_kernels_not_ops() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let g = g();
        let r = run(&soc, &g, &Target::Gpu { options: CompileOptions::default() }, 1, 0);
        assert!(r.per_op.len() < g.nodes.len());
        let fused_total: usize = r.per_op.iter().map(|t| 1 + t.fused.len()).sum();
        assert_eq!(fused_total, g.nodes.len());
    }

    #[test]
    fn quantization_speeds_up_end_to_end() {
        // Fig 4: int8 faster end-to-end on all devices.
        for soc in crate::device::socs() {
            let g = g();
            let counts = vec![0; soc.clusters.len()];
            let mut c1 = counts.clone();
            c1[0] = 1;
            let f = run(
                &soc,
                &g,
                &Target::Cpu { combo: CoreCombo::new(c1.clone()), rep: DataRep::Fp32 },
                3,
                0,
            );
            let q = run(
                &soc,
                &g,
                &Target::Cpu { combo: CoreCombo::new(c1), rep: DataRep::Int8 },
                3,
                0,
            );
            assert!(
                f.end_to_end_ms / q.end_to_end_ms > 1.3,
                "{}: fp32={} int8={}",
                soc.name,
                f.end_to_end_ms,
                q.end_to_end_ms
            );
        }
    }

    #[test]
    fn latencies_in_plausible_mobile_range() {
        // MobileNetV2 0.5 on a Pixel 4 big core: O(10ms), not µs or seconds.
        let soc = soc_by_name("Snapdragon855").unwrap();
        let g = g();
        let r = run(&soc, &g, &cpu_target(vec![1, 0, 0]), 5, 0);
        assert!(
            (3.0..80.0).contains(&r.end_to_end_ms),
            "end_to_end={}ms",
            r.end_to_end_ms
        );
    }

    #[test]
    fn helio_much_slower_than_flagship() {
        let g = g();
        let s855 = soc_by_name("Snapdragon855").unwrap();
        let p35 = soc_by_name("HelioP35").unwrap();
        let fast = run(&s855, &g, &cpu_target(vec![1, 0, 0]), 5, 0).end_to_end_ms;
        let slow = run(&p35, &g, &cpu_target(vec![1, 0]), 5, 0).end_to_end_ms;
        assert!(slow / fast > 2.0, "fast={fast} slow={slow}");
    }

    #[test]
    fn workload_opens_its_own_noise_stream() {
        let soc = soc_by_name("Snapdragon855").unwrap();
        let g = g();
        let t = cpu_target(vec![1, 0, 0]);
        let wl = WorkloadSpec {
            name: "w".into(),
            batch: 1,
            cpu_load: vec![0.5],
            gpu_share: 1.0,
        };
        let iso = run(&soc, &g, &t, 42, 0);
        // None reproduces the isolated run bit-identically.
        let none = run_under(&soc, &g, &t, None, 42, 0);
        assert_eq!(iso.end_to_end_ms.to_bits(), none.end_to_end_ms.to_bits());
        // A workload perturbs both the cost model and the RNG stream, but
        // stays deterministic in (seed, run_idx, workload name).
        let a = run_under(&soc, &g, &t, Some(&wl), 42, 0);
        let b = run_under(&soc, &g, &t, Some(&wl), 42, 0);
        assert_eq!(a.end_to_end_ms.to_bits(), b.end_to_end_ms.to_bits());
        assert_ne!(a.end_to_end_ms, iso.end_to_end_ms);
    }

    #[test]
    fn run_many_produces_variance() {
        let soc = soc_by_name("Snapdragon710").unwrap();
        let g = g();
        let rs = run_many(&soc, &g, &cpu_target(vec![0, 6]), 9, 20);
        let e2e: Vec<f64> = rs.iter().map(|r| r.end_to_end_ms).collect();
        let cov = crate::util::cov(&e2e);
        assert!(cov > 0.02, "cov={cov}");
    }
}
